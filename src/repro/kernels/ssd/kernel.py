"""Mamba2 SSD (state-space duality) chunked scan as a Pallas TPU kernel.

TPU adaptation notes:

- The SSD chunk decomposition (Dao & Gu 2024) maps naturally onto the
  MXU: the intra-chunk term is a (Q x Q) masked matmul against the chunk
  inputs, and the inter-chunk term is a (Q x N) @ (N x P) matmul against
  the carried state — all MXU-shaped when Q, N, P are multiples of the
  128-lane tile (we default Q=128; N, P per config).
- The grid is (BH, L/Q) with the chunk dimension innermost/sequential
  ("arbitrary" semantics); the running state S (N x P, f32) lives in VMEM
  scratch across chunk steps, initialised at chunk 0 of each (b, h) row.
  This replaces the GPU version's inter-block recurrence via separate
  kernel launches + global memory round trips.
- Decay factors use within-chunk cumulative sums computed on the VPU;
  everything is f32 in VMEM regardless of the input dtype.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, s_scr, *, q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    a = a_ref[0].astype(jnp.float32)          # (Q,)  -- wait: block (1, Q)
    a = a.reshape(q)
    B = b_ref[0].astype(jnp.float32)          # (Q, N)
    C = c_ref[0].astype(jnp.float32)          # (Q, N)

    s_cum = jnp.cumsum(a)                     # (Q,)
    # intra-chunk: scores[i,j] = (C_i . B_j) * exp(s_i - s_j) for j <= i
    cb = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    si = s_cum[:, None]
    sj = s_cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    decay = jnp.where(ii >= jj, jnp.exp(si - sj), 0.0)
    y_intra = jax.lax.dot_general(cb * decay, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    # inter-chunk: y_i += (C_i * exp(s_i)) @ S_prev
    c_scaled = C * jnp.exp(s_cum)[:, None]
    y_inter = jax.lax.dot_general(c_scaled, s_scr[...],
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S = exp(s_Q) S + sum_j exp(s_Q - s_j) B_j^T x_j
    decay_out = jnp.exp(s_cum[-1] - s_cum)    # (Q,)
    b_scaled = B * decay_out[:, None]
    s_scr[...] = s_scr[...] * jnp.exp(s_cum[-1]) + jax.lax.dot_general(
        b_scaled, x, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def ssd_scan(x, a, B, C, *, chunk: int = 128, interpret: bool = False):
    """Chunked SSD scan.

    x : (BH, L, P) dt-premultiplied inputs; a : (BH, L) log-decays;
    B, C : (BH, L, N).  L % chunk == 0.  Returns y: (BH, L, P).
    """
    bh, l, p = x.shape
    n = B.shape[-1]
    q = min(chunk, l)
    nc = l // q
    assert nc * q == l, (l, q)

    def xmap(bi, ci):
        return (bi, ci, 0)

    def amap(bi, ci):
        return (bi, ci)

    kernel = functools.partial(_ssd_kernel, q=q)
    return pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, q, p), xmap),
            pl.BlockSpec((1, q), amap),
            pl.BlockSpec((1, q, n), xmap),
            pl.BlockSpec((1, q, n), xmap),
        ],
        out_specs=pl.BlockSpec((1, q, p), xmap),
        out_shape=jax.ShapeDtypeStruct((bh, l, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, a, B, C)
