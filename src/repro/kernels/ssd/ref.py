"""Pure-jnp oracle for the Mamba2 SSD kernel: the naive per-step
recurrence (exact semantics, O(L) sequential)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x, a, B, C):
    """Naive SSD recurrence.

    x : (BH, L, P)  -- dt-premultiplied inputs (dt_j * x_j)
    a : (BH, L)     -- log-decay increments (dt_j * A_h, negative)
    B : (BH, L, N)
    C : (BH, L, N)
    returns y: (BH, L, P), final state (BH, N, P)

    Recurrence:  S_t = exp(a_t) S_{t-1} + B_t^T x_t ;  y_t = C_t S_t.
    """
    bh, l, p = x.shape
    n = B.shape[-1]

    def step(S, inp):
        xt, at, Bt, Ct = inp
        S = S * jnp.exp(at)[:, None, None] + jnp.einsum(
            "bn,bp->bnp", Bt, xt)
        y = jnp.einsum("bn,bnp->bp", Ct, S)
        return S, y

    S0 = jnp.zeros((bh, n, p), jnp.float32)
    xs = (jnp.moveaxis(x.astype(jnp.float32), 1, 0),
          jnp.moveaxis(a.astype(jnp.float32), 1, 0),
          jnp.moveaxis(B.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C.astype(jnp.float32), 1, 0))
    S, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), S
