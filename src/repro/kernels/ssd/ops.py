"""jit'd public wrapper for the SSD kernel, in model-layer layout.

Accepts the mamba_block quantities (x, dt, A, B, C per head group) and
returns y, matching repro.models.layers.ssd_reference semantics (without
the final state, which training doesn't need).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import ssd_scan


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, B, C, *, chunk: int = 128, interpret: bool | None = None):
    """x: (b, l, h, p); dt: (b, l, h); A: (h,); B, C: (b, l, n).

    Returns y: (b, l, h, p).
    """
    if interpret is None:
        interpret = not _on_tpu()
    b, l, h, p = x.shape
    n = B.shape[-1]
    # fold (b, h) into the kernel's row dim; broadcast B/C across heads
    xdt = (x * dt[..., None]).transpose(0, 2, 1, 3).reshape(b * h, l, p)
    a = (dt * A[None, None, :]).transpose(0, 2, 1).reshape(b * h, l)
    Bb = jnp.broadcast_to(B[:, None], (b, h, l, n)).reshape(b * h, l, n)
    Cb = jnp.broadcast_to(C[:, None], (b, h, l, n)).reshape(b * h, l, n)
    y = ssd_scan(xdt, a, Bb, Cb, chunk=min(chunk, l), interpret=interpret)
    return y.reshape(b, h, l, p).transpose(0, 2, 1, 3).astype(x.dtype)
