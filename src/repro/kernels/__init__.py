"""Pallas TPU kernels for the substrate's compute hot-spots.

flash_attention/  causal/SWA/softcap GQA attention (VMEM online softmax)
ssd/              Mamba2 state-space-duality chunked scan

Each kernel ships kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper, interpret-mode fallback off-TPU) and ref.py (pure-jnp oracle);
tests/test_kernels.py sweeps shapes/dtypes against the oracles.
"""
