"""Candidate-mapping scoring as a fused Pallas TPU kernel.

One launch scores a whole stack of candidate mappings: grid
``(ncandidates, message_tiles)`` with the candidate dimension parallel
and the tile dimension sequential.  Message tiles (src/dst coordinates
and weights) stream through VMEM; the circular difference-array
range-add of the dimension-ordered router accumulates into per-(dim,
direction) VMEM scratch link-load buffers that live across the tile
steps of a candidate — the same carried-in-VMEM state trick the repo's
SSD kernel uses instead of global-memory round trips.  On the last tile
the prefix sums, the weighted-hops accumulators and the link maxima
reduce on-chip, so only an 8-wide per-candidate metric vector ever
returns to HBM (no materialised ``(ncand, nlinks)`` load arrays).

TPU adaptation notes:

- Scatter-free scatter: TPUs have no fast scatter, so the range-add
  becomes a matmul.  For machine dim ``k`` each message contributes
  difference-array entries at (column ``c`` along dim k, row key ``r``
  over the remaining dims).  A tile builds ``A = sum_i onehot(c_i) *
  val_i`` (T, s+1) on the VPU and ``B = onehot(r)`` (T, rows) once per
  dim, then ``acc += A^T @ B`` lands every entry on the MXU — the pos
  and neg directions share ``B``.  The row axis is chunked so the
  one-hot never exceeds a fixed VMEM footprint.
- The accumulator layout is (s+1 sublanes, rows lanes): the dump column
  that closes wrapped intervals is sublane ``s``, and the prefix sum of
  the final reduction runs along sublanes (``jnp.cumsum`` axis 0), so
  no irregular reshape is needed between scatter and reduction.
- Coordinates are int32 (T, 1) column vectors; all arithmetic (wrap
  direction choice, interval lengths, mixed-radix row keys) is
  elementwise VPU work.  Scalar accumulators (weighted/total hops)
  live in SMEM scratch across tiles.

Zero-weight padded messages and zero-length (src == dst) messages
contribute exact zeros, which is what makes the power-of-two message
bucketing of :mod:`ops` exact rather than approximate.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

ROW_CHUNK = 512  # lanes of the row one-hot built per matmul

# renamed TPUCompilerParams -> CompilerParams across jax versions
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def acc_shapes(dims, core_dims):
    """Per network dim the (sublanes, lanes) link-accumulator shape:
    (s_k + 1 padded to 8, rows over the other dims padded to 128)."""
    nd = len(dims) - core_dims
    shapes = []
    for k in range(nd):
        nrows = 1
        for j, d in enumerate(dims):
            if j != k:
                nrows *= d
        shapes.append((_round_up(dims[k] + 1, 8), _round_up(nrows, 128)))
    return shapes


def _onehot(idx, width):
    """(T, 1) int32 indices -> (T, width) f32 one-hot via a broadcast
    compare against a lane iota (the TPU-native scatter primitive)."""
    lanes = jax.lax.broadcasted_iota(jnp.int32, (1, width), 1)
    return (idx == lanes).astype(jnp.float32)


def _interval_matrix(start, length, w, s, width):
    """Difference-array contributions of circular intervals
    [start, start+length) as a dense (T, width) column matrix.

    Four weighted one-hots per message: open at ``start``, close at
    ``min(end, s)`` (``s`` is the dump column), and for wrapped
    intervals open the tail at 0 and close it at ``end - s``.
    Zero-length messages get zero weight, so padding is exact.
    """
    end = start + length
    wz = jnp.where(length > 0, w, 0.0)
    wrapped = end > s
    wwr = jnp.where(wrapped, wz, 0.0)
    m = _onehot(start, width) * wz
    m = m - _onehot(jnp.minimum(end, s), width) * wz
    m = m + _onehot(jnp.zeros_like(start), width) * wwr
    m = m - _onehot(jnp.where(wrapped, end - s, 0), width) * wwr
    return m


def _mapscore_kernel(*refs, dims, wrap, core_dims, traffic, sdims):
    """Kernel body.  ``refs`` (in order): src, dst, w, [inv_bw], outf,
    outi, wh_scr, th_scr, [acc_pos_0, acc_neg_0, acc_pos_1, ...]."""
    nd = len(dims) - core_dims
    if traffic:
        src_ref, dst_ref, w_ref, invbw_ref = refs[:4]
        outf_ref, outi_ref, wh_s, th_s = refs[4:8]
        accs = refs[8:]
    else:
        src_ref, dst_ref, w_ref = refs[:3]
        outf_ref, outi_ref, wh_s, th_s = refs[3:7]
        accs = ()
    ti = pl.program_id(1)
    ntiles = pl.num_programs(1)

    @pl.when(ti == 0)
    def _init():
        wh_s[0] = jnp.float32(0.0)
        th_s[0] = jnp.int32(0)
        for acc in accs:
            acc[...] = jnp.zeros_like(acc)

    src = src_ref[0]                       # (T, ndims) int32
    dst = dst_ref[0]
    w = w_ref[...].astype(jnp.float32)     # (T, 1)

    # hop metrics: shortest per-dim distance, accumulated across tiles
    hops = jnp.zeros_like(src[:, :1])
    for k in range(nd):
        s = dims[k]
        d = jnp.abs(src[:, k:k + 1] - dst[:, k:k + 1])
        if wrap[k]:
            d = jnp.minimum(d, s - d)
        hops = hops + d
    wh_s[0] += jnp.sum(hops.astype(jnp.float32) * w)
    # pin the accumulation dtype: under jax_enable_x64 (flipped on by
    # the device partition backend) an unpinned int sum promotes to
    # int64 and no longer matches the int32 SMEM scratch
    th_s[0] += jnp.sum(hops, dtype=jnp.int32)

    if traffic:
        for k in range(nd):
            s = dims[k]
            sp, rp = sdims[k]
            a = src[:, k:k + 1]
            b = dst[:, k:k + 1]
            if wrap[k]:
                fwd = (b - a) % s
                bwd = (a - b) % s
                use_fwd = fwd <= bwd
                len_f = jnp.where(use_fwd, fwd, 0)
                len_b = jnp.where(use_fwd, 0, bwd)
                start_b = (a - len_b) % s
            else:
                use_fwd = b >= a
                len_f = jnp.where(use_fwd, b - a, 0)
                len_b = jnp.where(use_fwd, 0, a - b)
                start_b = a - len_b
            # dimension-ordered routing: dims before k already sit at
            # the destination, dims after k (and core dims) at the src
            rkey = jnp.zeros_like(a)
            for j in range(len(dims)):
                if j == k:
                    continue
                col = dst[:, j:j + 1] if j < k else src[:, j:j + 1]
                rkey = rkey * dims[j] + col
            a_pos = _interval_matrix(a, len_f, w, s, sp)       # (T, sp)
            a_neg = _interval_matrix(start_b, len_b, w, s, sp)
            for off in range(0, rp, ROW_CHUNK):
                width = min(ROW_CHUNK, rp - off)
                b_oh = _onehot(rkey - off, width)              # (T, width)
                for acc, amat in ((accs[2 * k], a_pos),
                                  (accs[2 * k + 1], a_neg)):
                    acc[:, off:off + width] += jax.lax.dot_general(
                        amat, b_oh, (((0,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32)

    @pl.when(ti == ntiles - 1)
    def _finish():
        data = jnp.float32(0.0)
        lat = jnp.float32(0.0)
        if traffic:
            off_s = 0
            for k in range(nd):
                s = dims[k]
                inv_bw = invbw_ref[off_s:off_s + s, :]          # (s, 1)
                off_s += s
                for acc in (accs[2 * k], accs[2 * k + 1]):
                    # prefix sum over the s real columns (sublane axis);
                    # the dump column s and the sublane padding stay out
                    cum = jnp.cumsum(acc[0:s, :], axis=0)       # (s, rp)
                    data = jnp.maximum(data, jnp.max(cum))
                    per_c = jnp.max(cum, axis=1, keepdims=True)  # (s, 1)
                    lat = jnp.maximum(lat, jnp.max(per_c * inv_bw))
        vf = jnp.zeros((1, 8), dtype=jnp.float32)
        vf = vf.at[0, 0].set(wh_s[0])
        vf = vf.at[0, 1].set(data)
        vf = vf.at[0, 2].set(lat)
        outf_ref[...] = vf
        vi = jnp.zeros((1, 8), dtype=jnp.int32)
        vi = vi.at[0, 0].set(th_s[0])
        outi_ref[...] = vi


def mapscore_call(src, dst, w, inv_bw=None, *, dims, wrap, core_dims,
                  traffic, tile, interpret=False):
    """Launch the scoring kernel over a padded candidate stack.

    src, dst : (nb, E, ncols) int32 message coordinates (E a multiple
               of ``tile``; ncols == len(dims) when routing).
    w        : (E, 1) f32 weights, shared across candidates.
    inv_bw   : (sum of network extents, 1) f32 — 1/bandwidth per link
               column, concatenated per dim (``traffic`` only).

    Returns ``(outf, outi)``: (nb, 8) f32 [weighted_hops, data_max,
    latency_max, 0...] and (nb, 8) i32 [total_hops, 0...].
    """
    nb, e, ncols = src.shape
    ntiles = e // tile
    assert ntiles * tile == e, (e, tile)
    sdims = tuple(acc_shapes(dims, core_dims)) if traffic else ()
    kernel = functools.partial(
        _mapscore_kernel, dims=tuple(dims), wrap=tuple(wrap),
        core_dims=core_dims, traffic=traffic, sdims=sdims)

    in_specs = [
        pl.BlockSpec((1, tile, ncols), lambda bi, ti: (bi, ti, 0)),
        pl.BlockSpec((1, tile, ncols), lambda bi, ti: (bi, ti, 0)),
        pl.BlockSpec((tile, 1), lambda bi, ti: (ti, 0)),
    ]
    args = [src, dst, w]
    if traffic:
        in_specs.append(
            pl.BlockSpec(inv_bw.shape, lambda bi, ti: (0, 0)))
        args.append(inv_bw)
    scratch = [pltpu.SMEM((1,), jnp.float32), pltpu.SMEM((1,), jnp.int32)]
    for sp, rp in sdims:
        scratch.append(pltpu.VMEM((sp, rp), jnp.float32))
        scratch.append(pltpu.VMEM((sp, rp), jnp.float32))
    return pl.pallas_call(
        kernel,
        grid=(nb, ntiles),
        in_specs=in_specs,
        out_specs=(pl.BlockSpec((1, 8), lambda bi, ti: (bi, 0)),
                   pl.BlockSpec((1, 8), lambda bi, ti: (bi, 0))),
        out_shape=(jax.ShapeDtypeStruct((nb, 8), jnp.float32),
                   jax.ShapeDtypeStruct((nb, 8), jnp.int32)),
        scratch_shapes=scratch,
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(*args)
