"""Public wrapper for the mapscore kernel: the ``evaluate_candidates``
contract with shape bucketing and a keyed compile cache.

``evaluate_candidates_pallas`` is what
``repro.core.metrics.evaluate_candidates(backend="pallas")`` resolves
to.  It gathers the per-message coordinate stacks, buckets BOTH dynamic
axes to padded power-of-two shapes — message count (zero-weight
self-edge padding, exact in the difference-array formulation) and
candidate count (zero rows, sliced away) — and launches the fused
kernel once per (machine structure, bucket) via a compile cache whose
hit/miss counters land in ``benchmarks/run.py --json``.  On CPU the
kernel runs in Pallas interpret mode (the parity-tested path); machines
whose link accumulators would not fit the VMEM budget fall back
silently to the jax scorer (and from there to numpy).
"""

from __future__ import annotations

import functools
import warnings

import numpy as np

import jax
import jax.numpy as jnp

from repro import faults, obs
from repro.core.machine import Machine
# bucketing + padding rules shared with the jax backend: the two
# accelerator paths must agree on bucket boundaries or cache keys drift
from repro.core.metrics_jax import bucket_size, pad_axis

from .kernel import acc_shapes, mapscore_call

TILE_MAX = 512         # messages per VMEM tile
VMEM_ACC_BUDGET = 10 << 20  # link-accumulator bytes before jax fallback


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.lru_cache(maxsize=None)
def _compiled(dims, wrap, core_dims, traffic, ne_b, tile, nb_b, ncols,
              interpret):
    """One jitted kernel launcher per (machine structure, shape bucket).

    Every cache entry sees exactly one input shape, so the ``lru_cache``
    hit/miss counters are a truthful compile-count proxy (mirrors
    ``repro.core.metrics_jax._scorer``).
    """
    del ne_b, nb_b, ncols  # shape part of the key only
    return jax.jit(functools.partial(
        mapscore_call, dims=dims, wrap=wrap, core_dims=core_dims,
        traffic=traffic, tile=tile, interpret=interpret))


# registry-backed stat/reset pair (repro.obs); auto-registers with
# ``obs.snapshot()`` under "scorer_pallas"
scorer_cache_stats, reset_scorer_cache = obs.instrument_compile_cache(
    "scorer_pallas", _compiled)


_VMEM_WARNED: set = set()


def _warn_vmem_fallback(machine: Machine) -> None:
    """Once-per-machine-shape warning for the VMEM-budget jax fallback."""
    key = (tuple(int(x) for x in machine.dims), machine.core_dims)
    if key in _VMEM_WARNED:
        return
    _VMEM_WARNED.add(key)
    warnings.warn(
        f"mapscore link accumulators for machine dims {key[0]} exceed "
        f"the VMEM budget ({vmem_accumulator_bytes(machine)} > "
        f"{VMEM_ACC_BUDGET} bytes); scoring falls back to the jax "
        "backend", RuntimeWarning, stacklevel=3)


def vmem_accumulator_bytes(machine: Machine) -> int:
    """Bytes of VMEM link-accumulator scratch the kernel would allocate
    for ``machine`` (two f32 buffers per network dim)."""
    dims = tuple(int(x) for x in machine.dims)
    return sum(2 * sp * rp * 4
               for sp, rp in acc_shapes(dims, machine.core_dims))


def evaluate_candidates_pallas(machine: Machine, task_edges: np.ndarray,
                               edge_weights: np.ndarray | None,
                               coord_stack: np.ndarray, *,
                               traffic: bool = False,
                               chunk_elems: int = 1 << 24,
                               interpret: bool | None = None) -> dict:
    """Pallas implementation of ``evaluate_candidates`` (same contract;
    results within fp tolerance of the numpy reference, winner
    orderings pinned bit-identical by tests/test_mapscore.py).

    A candidate stack typically goes down in ONE kernel launch: VMEM
    usage is bounded by the tile size and the machine's link
    accumulators, not by the stack.  The candidate axis is still
    chunked by ``chunk_elems`` message-coordinates — like the other
    backends — so the HOST-side src/dst gathers stay bounded for huge
    sweeps; chunks run at power-of-two sizes (rounded down, so the
    bound holds) to keep the compile-cache key set O(log) per machine.
    """
    coord_stack = np.asarray(coord_stack)
    nb = len(coord_stack)
    ne = len(task_edges)
    out = {
        "weighted_hops": np.zeros(nb),
        "total_hops": np.zeros(nb, dtype=np.int64),
        "average_hops": np.zeros(nb),
    }
    if traffic:
        out["data_max"] = np.zeros(nb)
        out["latency_max"] = np.zeros(nb)
    if ne == 0 or nb == 0:
        return out
    if traffic and vmem_accumulator_bytes(machine) > VMEM_ACC_BUDGET:
        # machine too large for on-chip link state: jax fallback (warned
        # once per process so the rung change is observable)
        _warn_vmem_fallback(machine)
        from repro.core import metrics
        _, fn = metrics.get_evaluator("jax")
        return fn(machine, task_edges, edge_weights, coord_stack,
                  traffic=traffic, chunk_elems=chunk_elems)
    # marker span at the fault hook: an injected kernel fault lands in
    # the trace error-annotated exactly where a real one would; the
    # enclosing score.pallas span carries the wall-clock
    with obs.span("kernel.mapscore", candidates=int(nb), edges=int(ne)):
        faults.fire("kernel.mapscore")
    if interpret is None:
        interpret = not _on_tpu()

    nd = machine.ndim - machine.core_dims
    dims = tuple(int(x) for x in machine.dims)
    wrap = tuple(bool(x) for x in machine.wrap)
    ncols = machine.ndim if traffic else min(coord_stack.shape[-1],
                                             machine.ndim)
    if coord_stack.shape[-1] < ncols:  # hop-only stacks may omit core dims
        coord_stack = pad_axis(coord_stack, ncols, axis=2)

    edges = np.asarray(task_edges, dtype=np.int64)
    w = np.ones(ne) if edge_weights is None else \
        np.asarray(edge_weights, dtype=np.float64)

    ne_b = bucket_size(ne)
    tile = min(TILE_MAX, ne_b)
    w_p = jnp.asarray(
        pad_axis(w.astype(np.float32).reshape(-1, 1), ne_b, axis=0))
    inv_bw = None
    if traffic:
        inv = np.concatenate([
            1.0 / np.asarray(machine.bw(k, np.arange(dims[k])),
                             dtype=np.float64)
            for k in range(nd)]) if nd else np.zeros(0)
        inv_bw = jnp.asarray(inv.reshape(-1, 1), dtype=jnp.float32)

    # pow2 candidate chunks bounded by chunk_elems (rounded down so the
    # bound holds): the host-side src/dst gathers stay capped on huge
    # sweeps while the compile-cache key set stays O(log) per machine
    per_cand = max(1, 2 * ne_b * ncols)
    chunk = 1 << (max(1, chunk_elems // per_cand).bit_length() - 1)
    c0 = 0
    while c0 < nb:
        n_here = min(chunk, nb - c0)
        nb_b = n_here if n_here == chunk else bucket_size(n_here, lo=1)
        cs = coord_stack[c0:c0 + n_here]
        src = pad_axis(pad_axis(
            cs[:, edges[:, 0], :ncols].astype(np.int32), ne_b, axis=1),
            nb_b, axis=0)
        dst = pad_axis(pad_axis(
            cs[:, edges[:, 1], :ncols].astype(np.int32), ne_b, axis=1),
            nb_b, axis=0)
        args = [jnp.asarray(src), jnp.asarray(dst), w_p]
        if traffic:
            args.append(inv_bw)
        misses0 = _compiled.cache_info().misses
        fn = _compiled(dims, wrap, machine.core_dims, traffic, ne_b, tile,
                       nb_b, ncols, bool(interpret))
        obs.annotate(compile_cache=(
            "miss" if _compiled.cache_info().misses > misses0 else "hit"))
        outf, outi = fn(*args)
        outf = np.asarray(outf)
        outi = np.asarray(outi)
        sl = slice(c0, c0 + n_here)
        out["weighted_hops"][sl] = outf[:n_here, 0].astype(np.float64)
        out["total_hops"][sl] = outi[:n_here, 0].astype(np.int64)
        if traffic:
            out["data_max"][sl] = outf[:n_here, 1].astype(np.float64)
            out["latency_max"][sl] = outf[:n_here, 2].astype(np.float64)
        c0 += n_here
    out["average_hops"] = out["total_hops"] / ne
    return out
