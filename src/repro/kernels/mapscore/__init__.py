"""Fused candidate-scoring kernel (Pallas TPU) for the mapping sweep.

Layout mirrors the repo's other kernels (flash_attention, ssd):

- :mod:`ref`    — numpy oracle of the kernel contract;
- :mod:`kernel` — the Pallas TPU kernel (per-candidate grid dimension,
  message tiles streamed through VMEM, difference-array range-add in
  VMEM link-load scratch, on-chip metric reduction);
- :mod:`ops`    — the jit-friendly public wrapper with shape bucketing,
  a keyed compile cache and the ``evaluate_candidates`` contract.
"""

from .ops import evaluate_candidates_pallas, scorer_cache_stats  # noqa: F401
