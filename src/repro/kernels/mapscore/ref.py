"""Numpy oracle of the mapscore kernel contract.

The kernel scores a stack of candidate mappings — per candidate the
weighted/total hop sums and, for traffic objectives, the max directed
link load and max link latency under dimension-ordered routing.  This
reference produces exactly those quantities through the repo's
parity-tested numpy router (:mod:`repro.core.metrics`), so the
interpret-mode suite in ``tests/test_mapscore.py`` pins the kernel to
the same spec every other backend answers to.
"""

from __future__ import annotations

import numpy as np

from repro.core.machine import Machine
from repro.core.metrics import _batched_route, pairwise_hops


def mapscore_ref(machine: Machine, src: np.ndarray, dst: np.ndarray,
                 w: np.ndarray, *, traffic: bool = True) -> dict:
    """Per-candidate metric sums for message stacks.

    src, dst : (B, E, ndim) int machine coordinates per message.
    w        : (E,) message weights (shared across candidates).

    Returns (B,) arrays: ``weighted_hops``, ``total_hops`` and — when
    ``traffic`` — ``data_max`` / ``latency_max``.  Zero-length messages
    (src == dst) and zero-weight padding rows contribute exact zeros,
    matching the kernel's padded-bucket semantics.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    w = np.asarray(w, dtype=np.float64)
    h = pairwise_hops(machine, src, dst)
    out = {
        "weighted_hops": (h * w[None]).sum(axis=-1),
        "total_hops": h.sum(axis=-1),
    }
    if traffic:
        nb = len(src)
        nd = machine.ndim - machine.core_dims
        pos, neg = _batched_route(machine, src, dst, w)
        data = np.zeros(nb)
        lat = np.zeros(nb)
        for k in range(nd):
            bw_full = machine.bw_field(k)[None]
            for arr in (pos[k], neg[k]):
                data = np.maximum(data, arr.reshape(nb, -1).max(axis=1))
                lat = np.maximum(lat,
                                 (arr / bw_full).reshape(nb, -1).max(axis=1))
        out["data_max"] = data
        out["latency_max"] = lat
    return out
