"""jit'd public wrapper for the flash-attention kernel.

Adapts the model layout (B, S, H, D) to the kernel layout (B, H, S, D),
pads sequence lengths to block multiples, and falls back to interpret
mode automatically on non-TPU backends (this container is CPU-only; the
kernel body still executes, validating it end-to-end).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "cap",
                                             "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    cap: float = 0.0, bq: int = 512, bk: int = 512,
                    interpret: bool | None = None):
    """q: (B, S, H, D); k/v: (B, T, KH, D).  Returns (B, S, H, D)."""
    if interpret is None:
        interpret = not _on_tpu()
    b, s, h, d = q.shape
    t = k.shape[1]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    bq_ = min(bq, s)
    bk_ = min(bk, t)
    pad_q = (-s) % bq_
    pad_k = (-t) % bk_
    if pad_k and not causal:
        raise ValueError("key padding requires causal masking to be safe; "
                         "pass block sizes dividing T for non-causal use")
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               cap=cap, bq=bq_, bk=bk_, interpret=interpret)
    if pad_q:
        out = out[:, :, :s]
    return out.transpose(0, 2, 1, 3)
