"""Pure-jnp oracle for the flash-attention kernel.

Layout is the kernel's (B, H, S, D) — the ops.py wrapper adapts the model
layout.  Supports GQA (kv_heads divides heads), causal masking, sliding
windows and gemma-style logit soft-capping, matching the kernel feature
set exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  cap: float = 0.0):
    """q: (B, H, S, D); k/v: (B, KH, T, D) with KH | H."""
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    g = h // kh
    qf = q.astype(jnp.float32) * (d ** -0.5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(b, kh, g, s, d)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qf, kf)
    if cap:
        scores = jnp.tanh(scores / cap) * cap
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(t)[None, :]
    ok = jnp.ones((s, t), bool)
    if causal:
        ok &= qpos >= kpos
    if window:
        ok &= qpos - kpos < window
    scores = jnp.where(ok, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, vf)
    return out.reshape(b, h, s, d).astype(q.dtype)
