"""Flash attention as a Pallas TPU kernel.

TPU adaptation notes (vs the CUDA flash-attention algorithm):

- Tiling targets the MXU: block shapes are multiples of 128 in the lane
  dim and the (bq, bk) tile is sized so q-block, k-block, v-block and the
  f32 accumulators fit VMEM (~16 MB/core budget; the default 512x512
  blocks with D<=256 use < 4 MB).
- The grid is (B*H, S/bq, T/bk) with the key dimension innermost and
  "arbitrary" semantics: TPU grids execute sequentially, so the running
  max / denominator / accumulator live in VMEM scratch across the k
  sweep (no atomics, no shared-memory cross-warp reductions as on GPU —
  the online-softmax state is private to the core).
- GQA is handled by the k/v BlockSpec index maps (kv head = h // G), so
  grouped keys are never materialised per query head.
- Causal + sliding-window tiles that are fully masked are skipped via
  pl.when on the block indices (structural skip, not a data branch).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 bq: int, bk: int, nk: int, scale: float, causal: bool,
                 window: int, cap: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk

    # structural skip of fully-masked tiles
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + bq - 1)
    if window:
        live = jnp.logical_and(live, k_start + bk - 1 >= q_start - window + 1)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale       # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)               # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)               # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if cap:
            s = jnp.tanh(s / cap) * cap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok = jnp.logical_and(ok, qpos >= kpos)
        if window:
            ok = jnp.logical_and(ok, qpos - kpos < window)
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_scr[...]                               # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         cap: float = 0.0, bq: int = 512, bk: int = 512,
                         interpret: bool = False):
    """q: (B, H, S, D); k/v: (B, KH, T, D), KH | H.  S % bq == T % bk == 0."""
    b, h, s, d = q.shape
    kh, t = k.shape[1], k.shape[2]
    g = h // kh
    bq = min(bq, s)
    bk = min(bk, t)
    nq = s // bq
    nk = t // bk
    assert nq * bq == s and nk * bk == t, (s, t, bq, bk)

    def q_map(bh, qi, ki):
        return (bh // h, bh % h, qi, 0)

    def kv_map(bh, qi, ki):
        return (bh // h, (bh % h) // g, ki, 0)

    kernel = functools.partial(
        _attn_kernel, bq=bq, bk=bk, nk=nk, scale=d ** -0.5, causal=causal,
        window=window, cap=cap)

    return pl.pallas_call(
        kernel,
        grid=(b * h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), q_map),
            pl.BlockSpec((1, 1, bk, d), kv_map),
            pl.BlockSpec((1, 1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
