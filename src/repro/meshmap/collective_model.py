"""Mapping-aware collective cost model.

The plain roofline collective term (bytes / link_bw) assumes every
collective runs at full per-link bandwidth — true only if each mesh
axis's rings map onto disjoint physical links.  This module refines the
term using the paper's machinery: the compiled per-device collective
bytes are attributed to logical mesh axes, laid onto the physical torus
through a device mapping as ring traffic (XLA's TPU lowering), routed
with the paper's dimension-ordered model, and the bottleneck link's
Latency(M) (Eqn. 7) becomes the mapping-aware collective term.

This closes the loop between the paper and the framework: the same
metric that scored MiniGhost/HOMME mappings scores device orders for a
compiled training step.
"""

from __future__ import annotations

import numpy as np

from repro.core import (Allocation, MappingResult, latency_metric,
                        logical_mesh_graph, route_traffic)


def split_axis_bytes(total_bytes: float, axis_sizes, axis_weights=None):
    """Attribute a cell's total collective bytes to mesh axes.

    Without per-op axis attribution from the HLO (replica-group parsing
    is future work), bytes are split proportionally to ``axis_weights``
    (defaults to the relative traffic weights used for mapping).
    """
    axis_weights = axis_weights or [1.0] * len(axis_sizes)
    w = np.asarray(axis_weights, float)
    w = np.where(np.asarray(axis_sizes) > 1, w, 0.0)
    if w.sum() == 0:
        return [0.0] * len(axis_sizes)
    return list(total_bytes * w / w.sum())


def collective_term(alloc: Allocation, axis_sizes, mapping: MappingResult,
                    axis_bytes) -> float:
    """Seconds for the bottleneck link to carry one step's collectives.

    axis_bytes: bytes each device contributes along each mesh axis's
    ring (per step).  Ring traffic per link of an axis ~ the per-device
    bytes (each device forwards its share around the ring).
    """
    graph = logical_mesh_graph(tuple(axis_sizes), tuple(axis_bytes))
    coords = alloc.coords[mapping.task_to_proc]
    src = coords[graph.edges[:, 0]]
    dst = coords[graph.edges[:, 1]]
    traffic = route_traffic(alloc.machine, src, dst, graph.weights)
    return latency_metric(traffic) / 1e9  # bw in GB/s -> seconds


def compare_mappings(alloc: Allocation, axis_sizes, axis_bytes,
                     mappings: dict) -> dict:
    """Mapping-aware collective term for several device orders."""
    return {name: collective_term(alloc, axis_sizes, m, axis_bytes)
            for name, m in mappings.items()}
