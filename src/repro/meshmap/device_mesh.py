"""Topology-aware jax.sharding.Mesh construction — the paper's technique
as a first-class framework feature.

The logical mesh (e.g. ("pod","data","model")) is the *task graph*: jit
emits collectives per axis, with very different traffic weights (TP
all-reduces per layer on "model" >> FSDP gathers on "data" >> cross-DCN
on "pod").  The physical chips form the *machine graph* (ICI torus +
slow DCN dim).  geometric_map (MJ + FZ ordering, Alg. 1) consistently
orders both and hands us the device permutation that puts heavy-traffic
logical neighbours on adjacent chips.

On real TPU backends the chip coordinates come from device attributes;
on this CPU container they are synthesised from the machine model (the
512 fake host devices are assigned coords in allocation order).
"""

from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh

from repro.core import (Allocation, block_allocation, evaluate,
                        identity_mapping, logical_mesh_graph,
                        tpu_v5e_multipod, tpu_v5e_pod)
from repro.mapping import CandidateSearch, PipelineConfig, shared_pipeline

# Relative per-link traffic of one training step along each logical axis
# (bytes are arbitrary units; only ratios steer the mapper).
DEFAULT_AXIS_BYTES = {"pod": 1.0, "data": 8.0, "model": 64.0}


def machine_for(devices=None, *, pods: int = 1, side: int = 16):
    if pods > 1:
        return tpu_v5e_multipod(npods=pods, side=side)
    return tpu_v5e_pod(side=side)


def device_coords(devices, machine) -> np.ndarray:
    """Physical coordinates per device.

    Real TPUs expose ``device.coords`` (+ slice_index for multislice);
    fake CPU devices get machine coordinates in enumeration order.
    """
    coords = []
    have_real = all(hasattr(d, "coords") and d.platform == "tpu"
                    for d in devices)
    if have_real:  # pragma: no cover - no TPU in this container
        for d in devices:
            c = list(d.coords)[:2]
            pod = getattr(d, "slice_index", 0)
            coords.append([pod] + c if machine.ndim == 3 else c)
        return np.asarray(coords, float)
    return block_allocation(machine).coords[: len(devices)].astype(float)


def topology_mesh(axis_sizes: tuple[int, ...], axis_names: tuple[str, ...],
                  *, devices=None, machine=None, axis_bytes=None,
                  rotations: int = 16, return_report: bool = False,
                  score_backend: str = "numpy",
                  partition_backend: str = "numpy",
                  hierarchy="flat", sfc: str = "FZ", service=None):
    """Build a Mesh whose device order minimises modeled link traffic.

    Candidate-selection (the paper's §4.3 rotation search, generalised):
    we generate the default enumeration plus FZ geometric mappings under
    two task-coordinate scalings (raw indices and traffic-weighted
    1/bytes extents) x dimension rotations, score every candidate with
    the Latency(M)/WeightedHops model, and keep the winner.  The result
    is never worse than jax's enumeration order, and substantially
    better when the logical shape does not match the physical torus or
    the allocation is fragmented.

    Returns the Mesh (and optionally a report comparing the winner vs
    the default enumeration).
    """
    n = int(np.prod(axis_sizes))
    if devices is None:
        devices = jax.devices()[:n]
    if len(devices) < n:
        raise ValueError(f"need {n} devices, have {len(devices)}")
    devices = list(devices)[:n]
    if machine is None:
        pods = axis_sizes[axis_names.index("pod")] \
            if "pod" in axis_names else 1
        side = int(round((n // max(pods, 1)) ** 0.5))
        machine = machine_for(pods=pods, side=side)
    ab = axis_bytes or [DEFAULT_AXIS_BYTES.get(a, 8.0) for a in axis_names]
    graph = logical_mesh_graph(axis_sizes, tuple(ab), tuple(axis_names))
    alloc = Allocation(machine, device_coords(devices, machine).astype(int))
    best, best_metrics, base_metrics = select_mapping(
        graph, alloc, ab, rotations=rotations, score_backend=score_backend,
        partition_backend=partition_backend, hierarchy=hierarchy,
        sfc=sfc, service=service)
    order = best.task_to_proc  # logical flat index -> device index
    dev_array = np.array(devices, dtype=object)[order].reshape(axis_sizes)
    mesh = Mesh(dev_array, tuple(axis_names))
    if not return_report:
        return mesh
    return mesh, {"mapped": best_metrics, "default": base_metrics}


def select_mapping(graph, alloc, axis_bytes, *, rotations: int = 16,
                   score_backend: str = "numpy",
                   partition_backend: str = "numpy",
                   hierarchy="flat", sfc: str = "FZ",
                   service=None):
    """Candidate search: default order + SFC-geometric mappings (``sfc``
    picks the part numbering — "FZ" is the paper's winner, "H" the
    Hilbert curve; all five kinds run on-device under
    ``partition_backend="jax"``) under raw and traffic-scaled task
    coordinates x rotations; returns
    (best MappingResult, best metrics, default metrics).

    Candidate generation and scoring both run through the unified
    ``repro.mapping`` pipeline: each (scaling, rotation-budget) entry is
    one ``MappingPipeline.map`` call whose internal rotation search —
    the paper's WeightedHops objective — partitions the whole sweep in
    ~2 batched engine passes, and the outer selection scores every
    candidate in one batched (Latency(M), WeightedHops) pass
    (``score_backend="jax"`` routes it through the jit-compiled
    scorer; ``"pallas"`` through the fused on-chip kernel of
    :mod:`repro.kernels.mapscore`, falling back jax -> numpy when the
    kernel stack is unavailable).  The identity/default mapping is
    listed first, so on ties the search is never worse than jax's
    enumeration order.  ``partition_backend="jax"`` additionally moves
    the level-synchronous partitioner on device (silent jax -> numpy
    fallback, bit-identical permutations); combined with a jax/pallas
    score backend each pipeline pass's partition -> match -> score ->
    select chain runs as ONE compiled program per candidate stack
    (:mod:`repro.mapping.fused`) — the cold-path win the ``end2end``
    benchmark guards.

    ``hierarchy`` takes a :class:`repro.hier.HierarchySpec`
    (``HierarchySpec.node()``, ``.with_depth(3)``, ``.from_machine``;
    the strings "flat"/"node" remain deprecated aliases) and routes
    each pipeline call through the recursive coarsen* -> map ->
    refine/expand* subsystem (:mod:`repro.hier`) — worthwhile on
    machines with core dims or very large logical meshes; on a machine
    without core dims depth 2 degenerates to the router-granularity map
    plus the monotone swap refinement.

    Pipelines come from the process-wide :func:`shared_pipeline`
    registry (evaluator + compile caches resolved once per config, not
    once per mesh build).  Passing a :class:`repro.serve.MappingService`
    as ``service`` additionally serves each candidate pipeline pass
    through its content-addressed result cache, so REPEAT mesh builds —
    the same logical shape on the same allocation — skip the geometric
    search entirely (mapping-as-a-service for the mesh builder).
    """
    candidates = [identity_mapping(graph, alloc)]
    for scaled in (False, True):
        tc = graph.coords.astype(float)
        if scaled:
            tc = tc / np.asarray(axis_bytes, dtype=float)
        for rot in (0, rotations):
            config = PipelineConfig(
                sfc=sfc, shift=True, bandwidth_scale=True, rotations=rot,
                score_backend=score_backend,
                partition_backend=partition_backend, hierarchy=hierarchy)
            if service is not None:
                from repro.serve.engine import MappingRequest
                resp = service.map(MappingRequest(graph, alloc, config,
                                                  task_coords=tc))
                candidates.append(resp.result)
            else:
                candidates.append(shared_pipeline(config).map(
                    graph, alloc, task_coords=tc))
    search = CandidateSearch(objective=("latency_max", "weighted_hops"),
                             backend=score_backend)
    best, _, _ = search.best(graph, alloc, candidates)
    best_metrics = evaluate(graph, alloc, best)
    base_metrics = evaluate(graph, alloc, candidates[0])
    return best, best_metrics, base_metrics
