import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell, lower + compile the real
train/prefill/decode step on the production mesh (16x16 single pod and
2x16x16 two-pod), and record:

- compiled.memory_analysis()  -> per-device bytes (proves it fits)
- compiled.cost_analysis()    -> HLO FLOPs / bytes for the roofline
- collective bytes parsed from the optimized HLO (all-gather,
  all-reduce, reduce-scatter, all-to-all, collective-permute)

Artifacts land in benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json
and feed benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--topology]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs import ARCHS, get_config
from repro.models import (cache_axes, count_params, init_cache, params_spec,
                          prefill, tree_abstract, tree_axes)
from repro.models.config import SHAPES, ModelConfig, ShapeConfig
from repro.sharding.rules import DEFAULT_RULES, spec_for_axes, tree_shardings
from repro.train.optimizer import OptConfig, abstract_state
from repro.train.step import batch_specs, make_train_step
from repro.launch.mesh import make_production_mesh, make_topology_mesh

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")


# ---------------------------------------------------------------------------
# input_specs (spec-mandated): ShapeDtypeStruct stand-ins, no allocation
# ---------------------------------------------------------------------------

def input_specs(arch: str, shape_name: str) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return batch_specs(cfg, shape)
    if shape.kind == "prefill":
        b, s = shape.global_batch, shape.seq_len
        out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        if cfg.family == "encdec":
            out["frames"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder_seq, cfg.frontend_dim), jnp.dtype(cfg.dtype))
        if cfg.family == "vlm":
            out["patches"] = jax.ShapeDtypeStruct(
                (b, cfg.num_patches, cfg.frontend_dim), jnp.dtype(cfg.dtype))
        return out
    # decode: one token against a cache of seq_len
    b = shape.global_batch
    cache = jax.eval_shape(lambda: init_cache(cfg, b, shape.seq_len))
    return {
        "cache": cache,
        "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str:
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return ("full-attention arch: long_500k requires sub-quadratic "
                "attention (DESIGN.md §4)")
    return ""


def rules_for(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    rules = dict(DEFAULT_RULES)
    if shape.name == "long_500k":
        # batch=1: the data axis is idle for batch sharding; spend it on
        # sequence-sharding the KV cache (flash-decoding across the pod)
        rules["kv_seq"] = ("data", "model")
    return rules


def opt_for(cfg: ModelConfig) -> OptConfig:
    big = count_params(params_spec(cfg)) > 1e11
    return OptConfig(moment_dtype="bfloat16" if big else "float32")


def micro_for(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    """Gradient-accumulation factor for training cells: the MoE giants
    need microbatching to fit activations (see EXPERIMENTS.md §Perf)."""
    if shape.kind != "train":
        return 1
    if count_params(params_spec(cfg)) > 1e11:
        # largest micro count that keeps the batch shardable over data
        dp = 1
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp = sizes.get("data", 1) * sizes.get("pod", 1)
        return max(1, min(16, shape.global_batch // dp))
    return 1


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def _cache_shardings(cfg, cache_ab, rules, mesh):
    cax = cache_axes(cfg)
    return {k: NamedSharding(mesh,
                             spec_for_axes(cax[k], rules, mesh, v.shape))
            for k, v in cache_ab.items()}


def lower_cell(arch: str, shape_name: str, mesh, *, rules=None):
    """Lower the cell's step function; returns (lowered, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules = rules or rules_for(cfg, shape)
    spec = params_spec(cfg)
    params_ab = tree_abstract(spec, cfg.dtype)
    axes = tree_axes(spec)
    param_sh = tree_shardings(axes, params_ab, rules, mesh)

    with mesh:
        if shape.kind == "train":
            opt_cfg = opt_for(cfg)
            opt_ab = abstract_state(opt_cfg, params_ab)
            step = make_train_step(cfg, opt_cfg, mesh, rules=rules,
                                   microbatch=micro_for(cfg, shape, mesh))
            lowered = step.lower(params_ab, opt_ab,
                                 input_specs(arch, shape_name))
        elif shape.kind == "prefill":
            bs = input_specs(arch, shape_name)
            dp = spec_for_axes(("batch",), rules, mesh,
                               (shape.global_batch,))
            in_sh = {k: NamedSharding(
                mesh, PartitionSpec(*dp, *([None] * (len(v.shape) - 1))))
                for k, v in bs.items()}
            fn = jax.jit(
                lambda p, b: prefill(cfg, p, b),
                in_shardings=(param_sh, in_sh))
            lowered = fn.lower(params_ab, bs)
        else:  # decode
            ins = input_specs(arch, shape_name)
            cache_sh = _cache_shardings(cfg, ins["cache"], rules, mesh)
            from repro.models import decode_step
            fn = jax.jit(
                lambda p, c, t, i: decode_step(cfg, p, c, t, i),
                in_shardings=(param_sh, cache_sh, None, None),
                donate_argnums=(1,))
            lowered = fn.lower(params_ab, ins["cache"], ins["tokens"],
                               ins["pos"])
    meta = {"arch": arch, "shape": shape_name, "kind": shape.kind,
            "params": count_params(spec),
            "mesh_shape": list(mesh.devices.shape),
            "mesh_axes": list(mesh.axis_names)}
    return lowered, meta


def run_cell(arch: str, shape_name: str, mesh, out_dir: str,
             mesh_tag: str) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec: dict = {"arch": arch, "shape": shape_name,
                 "mesh": mesh_tag, "status": "ok"}
    reason = skip_reason(cfg, shape)
    if reason:
        rec.update(status="skipped", reason=reason)
        _write(out_dir, arch, shape_name, rec)
        return rec
    try:
        t0 = time.perf_counter()
        lowered, meta = lower_cell(arch, shape_name, mesh)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0
        mem = compiled.memory_analysis()
        from repro.launch.hlo_cost import analyze, xla_cost_analysis
        cost = xla_cost_analysis(compiled)
        hlo = compiled.as_text()
        t0 = time.perf_counter()
        model = analyze(hlo)  # trip-count-scaled per-device costs
        t_analyze = time.perf_counter() - t0
        rec.update(meta)
        rec["time_lower_s"] = round(t_lower, 2)
        rec["time_compile_s"] = round(t_compile, 2)
        rec["time_analyze_s"] = round(t_analyze, 2)
        rec["memory"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes",
             "alias_size_in_bytes") if hasattr(mem, k)}
        # raw XLA numbers (undercount while bodies; kept for reference)
        rec["cost_xla_raw"] = {
            k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and (
                "flops" in k or k == "bytes accessed")}
        # trip-scaled per-device model (see hlo_cost.py)
        rec["cost"] = {"flops": model["flops"],
                       "bytes_hbm": model["bytes_hbm"]}
        rec["collectives"] = {
            "bytes": model["collective_bytes"],
            "counts": model["collective_counts"],
            "total_bytes": model["collective_total_bytes"]}
        rec["hlo_lines"] = hlo.count("\n")
    except Exception as e:  # noqa: BLE001 - record and continue
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
    _write(out_dir, arch, shape_name, rec)
    return rec


def _write(out_dir, arch, shape_name, rec):
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}.json")
    slim = {k: v for k, v in rec.items() if k != "trace"}
    with open(path, "w") as f:
        json.dump(slim, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--topology", action="store_true",
                    help="use the paper-mapped device order")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    mesh_tag = ("pod2" if args.multi_pod else "pod1") + (
        "-topo" if args.topology else "")
    mesh = (make_topology_mesh(multi_pod=args.multi_pod) if args.topology
            else make_production_mesh(multi_pod=args.multi_pod))
    out_dir = args.out or os.path.abspath(
        os.path.join(ART_DIR, mesh_tag))

    cells = []
    archs = [args.arch] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)
    for a in archs:
        for s in shapes:
            cells.append((a, s))

    results = []
    for a, s in cells:
        t0 = time.perf_counter()
        rec = run_cell(a, s, mesh, out_dir, mesh_tag)
        dt = time.perf_counter() - t0
        status = rec["status"]
        extra = ""
        if status == "ok":
            gb = rec["memory"].get("argument_size_in_bytes", 0) / 2**30
            extra = (f" args/dev={gb:.2f}GiB "
                     f"coll={rec['collectives']['total_bytes']/2**30:.3f}GiB "
                     f"flops={rec['cost'].get('flops', 0):.3g}")
        elif status == "error":
            extra = " " + rec["error"][:120]
        elif status == "skipped":
            extra = " (" + rec["reason"][:60] + ")"
        print(f"[dryrun {mesh_tag}] {a} x {s}: {status} ({dt:.0f}s){extra}",
              flush=True)
        results.append(rec)
    ok = sum(r["status"] == "ok" for r in results)
    sk = sum(r["status"] == "skipped" for r in results)
    er = sum(r["status"] == "error" for r in results)
    print(f"[dryrun {mesh_tag}] done: {ok} ok, {sk} skipped, {er} errors")
    return 1 if er else 0


if __name__ == "__main__":
    raise SystemExit(main())
