"""Production meshes for the dry-run.

``make_production_mesh`` is the spec-mandated function (single-pod 16x16
or 2-pod 2x16x16).  ``make_topology_mesh`` is the same geometry built
through the unified ``repro.mapping`` pipeline (via
``repro.meshmap.topology_mesh``): candidate device orders are generated
by the vectorised Multi-Jagged partitioner and scored in one batched
(Latency(M), WeightedHops) pass, so the device order is never worse
than jax's enumeration.  Importing this module never touches jax device
state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_topology_mesh(*, multi_pod: bool = False, return_report=False,
                       axis_bytes=None, rotations: int = 8):
    from repro.meshmap.device_mesh import topology_mesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return topology_mesh(shape, axes, return_report=return_report,
                         axis_bytes=axis_bytes, rotations=rotations)
