"""Production meshes for the dry-run.

``make_production_mesh`` is the spec-mandated function (single-pod 16x16
or 2-pod 2x16x16).  ``make_topology_mesh`` is the same geometry built
through the paper's geometric mapper (repro.meshmap) — device order is
permuted to minimise modeled ICI/DCN link traffic.  Importing this
module never touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_topology_mesh(*, multi_pod: bool = False, return_report=False):
    from repro.meshmap.device_mesh import topology_mesh
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return topology_mesh(shape, axes, return_report=return_report)
