"""HLO-text cost model with while-loop trip-count scaling.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
by calibration in tests/test_hlo_cost.py), which silently drops ~L× the
FLOPs of a scanned L-layer model and all collectives inside the scan.
This module parses the optimized (post-SPMD, per-device) HLO text and
walks the call graph with multipliers:

- while loops: trip count extracted from the condition's comparison
  constant (lax.scan/fori_loop always lower to ``compare(iv, const)``),
- fusions / calls / reduces: descend with unchanged multiplier,
- conditionals: each branch weighted 1/num_branches (our conditional
  branches are FLOP-identical — see dryrun notes),

producing:
- ``flops``   : dot FLOPs (2*M*N*K, batch-aware) + elementwise FLOPs,
- ``bytes``   : HBM traffic proxy — operand+result bytes of *top-level*
  (non-fused-interior) instructions, trip-scaled,
- ``collectives`` : per-type trip-scaled operand bytes + counts.

Everything is per-device (the HLO is the per-device SPMD module).
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def xla_cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` normalised across jax versions.

    Older jaxlibs return a one-element list of dicts (per device
    program), newer ones a plain dict; both collapse to a dict here so
    callers can index ``["flops"]`` unconditionally.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})

_ELTWISE_1 = {"add", "subtract", "multiply", "divide", "maximum", "minimum",
              "and", "or", "xor", "compare", "select", "negate", "abs",
              "floor", "ceil", "round-nearest-afz", "sign"}
_ELTWISE_T = {"exponential", "tanh", "log", "rsqrt", "sqrt", "logistic",
              "power", "sine", "cosine", "erf", "exponential-minus-one",
              "log-plus-one", "cbrt", "atan2"}  # transcendental ~ 4 flops

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?\s*->.*{")
_SHAPE_TOK = re.compile(r"(\w[\w\d]*)\[([\d,]*)\]")
_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OPCODE_RE = re.compile(r"^([\w\-]+)\((.*)$")


def _split_instr(line: str):
    """(name, shape_str, opcode, rest) or None.

    Handles tuple result shapes containing /*index=N*/ comments by
    matching the balanced outer parens of the shape.
    """
    m = _ASSIGN_RE.match(line)
    if not m:
        return None
    name, rest = m.group(1), m.group(2).lstrip()
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape, tail = rest[:end + 1], rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape, tail = rest[:sp], rest[sp + 1:].lstrip()
    m2 = _OPCODE_RE.match(tail)
    if not m2:
        return None
    return name, shape, m2.group(1), m2.group(2)
_CALLED = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?"
    r"([\w.\-{}%, ]+?)\}?(?:,|$| )")


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    opcode: str
    rest: str  # operands + attributes (raw)

    @property
    def result_dims(self):
        shapes = _SHAPE_TOK.findall(self.shape_str)
        return shapes

    def result_bytes(self) -> int:
        return _shape_bytes(self.shape_str)

    def result_elems(self) -> int:
        total = 0
        for _, dims in _SHAPE_TOK.findall(self.shape_str):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n
        return total


def _shape_bytes(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOK.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(s: str):
    m = _SHAPE_TOK.search(s)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


def parse_hlo(text: str) -> dict:
    """computation name -> list[Instr]."""
    comps: dict = {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        parsed = _split_instr(line)
        if parsed:
            name, shape_str, opcode, rest = parsed
            comps[cur].append(Instr(name, shape_str.strip(), opcode, rest))
    return comps


def _operand_names(rest: str) -> list:
    # operands end at the first ")" at depth 0 of the leading parens;
    # depth must track [] and {} too — operand types like
    # ``f32[8,8]{1,0}`` contain commas that are NOT operand separators
    ops = []
    depth = 0
    buf = ""
    for ch in rest:
        if ch in "([{":
            depth += 1
            buf += ch
        elif ch == ")" and depth == 0:
            break
        elif ch in ")]}":
            depth -= 1
            buf += ch
        elif ch == "," and depth == 0:
            ops.append(buf)
            buf = ""
        else:
            buf += ch
    if buf.strip():
        ops.append(buf)
    names = []
    for o in ops:
        o = o.strip()
        # strip inline types: "f32[8,16] %foo.1" -> foo.1
        m = re.search(r"%([\w.\-]+)\s*$", o)
        if m:
            names.append(m.group(1))
        elif o and not o.startswith("("):
            names.append(o.split(" ")[-1].lstrip("%"))
    return names


def _called_comps(rest: str) -> list:
    out = []
    for m in _CALLED.finditer(rest):
        blob = m.group(1)
        for piece in blob.split(","):
            piece = piece.strip().strip("{}").lstrip("%").strip()
            if piece:
                out.append(piece)
    return out


def _dot_flops(instr: Instr, shapes: dict) -> float:
    """2*M*N*K*batch from result shape + contracting/batch dims."""
    res = _first_shape_dims(instr.shape_str)
    if res is None:
        return 0.0
    # lhs operand name
    opnames = _operand_names(instr.rest)
    if not opnames:
        return 0.0
    lhs_dims = shapes.get(opnames[0])
    if lhs_dims is None:
        return 0.0
    cm = re.search(r"lhs_contracting_dims=\{([\d, ]*)\}", instr.rest)
    contract = [int(x) for x in cm.group(1).split(",")] if cm and \
        cm.group(1).strip() else []
    k = 1
    for d in contract:
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    n_res = 1
    for d in res:
        n_res *= d
    return 2.0 * n_res * max(k, 1)


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')


def _while_trip(while_rest: str, cond_instrs: list) -> int:
    """Trip count: XLA's known_trip_count backend_config, else the
    condition computation's comparison constant."""
    m = _TRIP_RE.search(while_rest)
    if m:
        return max(int(m.group(1)), 1)
    consts = []
    for ins in cond_instrs:
        if ins.opcode == "constant":
            mm = re.search(r"^(-?\d+)\)", ins.rest)
            if mm:
                consts.append(int(mm.group(1)))
    if consts:
        return max(max(consts), 1)
    return 1


def analyze(text: str) -> dict:
    comps = parse_hlo(text)
    entry = None
    for name in comps:
        if name.startswith("main") or entry is None:
            if name.startswith("main"):
                entry = name
    if entry is None:
        entry = next(iter(comps))

    # global shape registry (names are unique across the module in
    # practice; collisions resolve to last writer which is fine for dims)
    shapes: dict = {}
    for instrs in comps.values():
        for ins in instrs:
            dims = _first_shape_dims(ins.shape_str)
            if dims is not None:
                shapes[ins.name] = dims

    flops = 0.0
    bytes_hbm = 0.0
    coll_bytes = {c: 0.0 for c in _COLLECTIVES}
    coll_counts = {c: 0.0 for c in _COLLECTIVES}
    warnings: list = []

    def size_of(name: str, comp_instrs_by_name: dict) -> int:
        ins = comp_instrs_by_name.get(name)
        if ins is None:
            return 0
        return ins.result_bytes()

    # ---- fusion parameter access model -----------------------------------
    # A fusion operand that is only consumed by (dynamic-)slice / gather
    # ops inside the fused computation is read partially: count the slice
    # results, not the full parameter (this is how stacked layer weights
    # are accessed inside lax.scan bodies — without this rule the memory
    # term overcounts by ~num_layers x).
    _SLICING = ("dynamic-slice", "slice", "gather")

    def fusion_param_bytes(fcomp: str) -> dict:
        """param index -> bytes actually read (None = full)."""
        out: dict = {}
        instrs = comps.get(fcomp, [])
        params = {}
        for ins in instrs:
            if ins.opcode == "parameter":
                m = re.search(r"^(\d+)\)", ins.rest)
                if m:
                    params[ins.name] = int(m.group(1))
        # consumers per param
        consume: dict = {name: [] for name in params}
        for ins in instrs:
            for opn in _operand_names(ins.rest):
                if opn in consume:
                    consume[opn].append(ins)
        for pname, idx in params.items():
            users = consume[pname]
            if users and all(u.opcode in _SLICING for u in users):
                out[idx] = sum(u.result_bytes() for u in users)
        return out

    _fusion_cache: dict = {}

    def instr_bytes(ins: Instr, by_name: dict) -> float:
        """HBM traffic model for one top-level instruction."""
        op = ins.opcode
        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast"):
            return 0.0
        if op in _SLICING:
            return 2.0 * ins.result_bytes()  # read slice + write result
        if op == "dynamic-update-slice":
            opnames = _operand_names(ins.rest)
            upd = size_of(opnames[1], by_name) if len(opnames) > 1 else 0
            return 2.0 * upd  # read + write the updated region (aliased)
        b = float(ins.result_bytes())
        if op == "fusion":
            called = _called_comps(ins.rest)
            pb = _fusion_cache.get(called[0]) if called else None
            if called and pb is None:
                pb = fusion_param_bytes(called[0])
                _fusion_cache[called[0]] = pb
            for i, opn in enumerate(_operand_names(ins.rest)):
                if pb is not None and i in pb:
                    b += pb[i]
                else:
                    b += size_of(opn, by_name)
            return b
        for opn in _operand_names(ins.rest):
            b += size_of(opn, by_name)
        return b

    def visit(comp: str, mult: float, top_level: bool, seen: tuple):
        nonlocal flops, bytes_hbm
        if comp not in comps or comp in seen:
            return
        instrs = comps[comp]
        by_name = {i.name: i for i in instrs}
        for ins in instrs:
            op = ins.opcode
            # --- flops ---
            if op == "dot":
                flops += mult * _dot_flops(ins, shapes)
            elif op in _ELTWISE_1:
                flops += mult * ins.result_elems()
            elif op in _ELTWISE_T:
                flops += mult * 4 * ins.result_elems()
            # --- bytes (top-level only; fusion interiors don't touch HBM)
            if top_level:
                bytes_hbm += mult * instr_bytes(ins, by_name)
            # --- collectives ---
            base = op.replace("-start", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                total = 0
                for opn in _operand_names(ins.rest):
                    total += size_of(opn, by_name)
                if total == 0:
                    total = ins.result_bytes()
                coll_bytes[base] += mult * total
                coll_counts[base] += mult
            # --- descend ---
            called = _called_comps(ins.rest)
            if op == "while":
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", ins.rest)
                cm = re.search(r"condition=%?([\w.\-]+)", ins.rest)
                if bm:
                    body = bm.group(1)
                if cm:
                    cond = cm.group(1)
                trips = _while_trip(ins.rest, comps.get(cond, []))
                if body:
                    visit(body, mult * trips, top_level, seen + (comp,))
            elif op == "conditional":
                branches = [c for c in called]
                w = 1.0 / max(len(branches), 1)
                for b in branches:
                    visit(b, mult * w, top_level, seen + (comp,))
            elif op == "fusion":
                for c in called:
                    visit(c, mult, False, seen + (comp,))
            elif called and op in ("call", "custom-call", "reduce",
                                   "reduce-window", "scatter", "sort",
                                   "map", "select-and-scatter",
                                   "async-start"):
                for c in called:
                    visit(c, mult, False, seen + (comp,))

    visit(entry, 1.0, True, ())
    return {
        "flops": flops,
        "bytes_hbm": bytes_hbm,
        "collective_bytes": {k: int(v) for k, v in coll_bytes.items()},
        "collective_counts": {k: round(v, 2)
                              for k, v in coll_counts.items()},
        "collective_total_bytes": int(sum(coll_bytes.values())),
        "warnings": warnings,
    }
