"""gemma3-27b [dense]: 5:1 local:global attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]

62L, d_model=5376, 32H (kv=16), d_ff=21504, vocab=262144.  Every 6th
layer is global; local layers use a 1024-token sliding window.
Sliding-window locals make long_500k decode tractable -> run it.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    head_dim=128,
    window_size=1024,
    global_every=6,            # 5 local : 1 global
    rope_theta=1e6,
    act="gelu",
    supports_long_context=True,
)
