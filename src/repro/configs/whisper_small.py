"""whisper-small [audio]: enc-dec transformer backbone, conv frontend
stubbed as precomputed frame embeddings.  [arXiv:2212.04356; unverified]

12L decoder + 12L encoder, d_model=768, 12H (GQA kv=12 == MHA),
d_ff=3072, vocab=51865.  Decoder-side sequence shapes per cell; encoder
fixed at 1500 frames (30 s).  long_500k skipped: full attention + 448-
token decoder makes a 500k decode meaningless (DESIGN.md §4).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,
    encoder_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51865,
    head_dim=64,
    encoder_seq=1500,
    frontend="audio_stub",
    frontend_dim=128,          # precomputed mel-frame embedding dim (stub)
    act="gelu",
    supports_long_context=False,
)
