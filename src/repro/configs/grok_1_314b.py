"""grok-1-314b [moe]: 8 experts top-2.  [hf:xai-org/grok-1; unverified]

64L, d_model=6144, 48H (kv=8), d_ff=32768 per expert, vocab=131072.
Full attention -> long_500k skipped.  Optimizer moments run in bf16 at
this scale (DESIGN.md §6).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    head_dim=128,
    num_experts=8,
    experts_per_tok=2,
    supports_long_context=False,
)
