"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]

38 mamba2 layers, d_model=2048, d_ff=8192, vocab=32000, ssm_state=64;
one shared transformer block (32H, kv=32) applied every 6 layers (the
paper's two alternating shared blocks are modelled as one; DESIGN.md).
State-space decode -> long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    head_dim=64,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_chunk=128,
    attn_every=6,
    supports_long_context=True,
)
