"""Architecture registry: --arch <id> resolves here."""

from __future__ import annotations

import importlib

ARCHS = [
    "whisper_small",
    "yi_6b",
    "gemma3_27b",
    "minitron_4b",
    "gemma2_27b",
    "grok_1_314b",
    "mixtral_8x22b",
    "zamba2_1p2b",
    "mamba2_2p7b",
    "internvl2_26b",
]

_ALIASES = {
    "whisper-small": "whisper_small",
    "yi-6b": "yi_6b",
    "gemma3-27b": "gemma3_27b",
    "minitron-4b": "minitron_4b",
    "gemma2-27b": "gemma2_27b",
    "grok-1-314b": "grok_1_314b",
    "mixtral-8x22b": "mixtral_8x22b",
    "zamba2-1.2b": "zamba2_1p2b",
    "mamba2-2.7b": "mamba2_2p7b",
    "internvl2-26b": "internvl2_26b",
}


def get_config(name: str):
    mod_name = _ALIASES.get(name, name).replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict:
    return {a: get_config(a) for a in ARCHS}
