"""mixtral-8x22b [moe]: 8 experts top-2 + sliding-window attention.
[arXiv:2401.04088; hf]

56L, d_model=6144, 48H (kv=8), d_ff=16384 per expert, vocab=32768,
SWA window 4096 on all layers (global_every=0) -> long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    head_dim=128,
    num_experts=8,
    experts_per_tok=2,
    window_size=4096,
    global_every=0,            # pure SWA
    supports_long_context=True,
)
