"""mamba2-2.7b [ssm]: attention-free SSD.  [arXiv:2405.21060; unverified]

64L, d_model=2560, vocab=50280, ssm_state=128, expand=2 (inner 5120,
80 heads x head_dim 64).  O(1)-state decode -> long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    head_dim=0,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=128,
    supports_long_context=True,
)
