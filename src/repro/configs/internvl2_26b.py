"""internvl2-26b [vlm]: InternViT + InternLM2 backbone; the vision tower
is a stub feeding precomputed patch embeddings.  [arXiv:2404.16821; hf]

48L, d_model=6144, 48H (kv=8), d_ff=16384, vocab=92553.  The first
num_patches positions of each sequence are patch embeddings projected
into the LM.  Full attention -> long_500k skipped.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92553,
    head_dim=128,
    frontend="patch_stub",
    frontend_dim=3200,         # InternViT-6B hidden size (stubbed)
    num_patches=1024,
    supports_long_context=False,
)
