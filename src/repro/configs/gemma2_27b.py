"""gemma2-27b [dense]: alternating local/global attention with logit
softcaps.  [arXiv:2408.00118; hf]

46L, d_model=4608, 32H (kv=16), d_ff=36864, vocab=256000.  Every 2nd
layer global; locals use a 4096 sliding window; attn softcap 50, final
logit softcap 30.  Sliding windows -> long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    window_size=4096,
    global_every=2,            # 1 local : 1 global alternating
    attn_softcap=50.0,
    final_softcap=30.0,
    act="gelu",
    supports_long_context=True,
)
