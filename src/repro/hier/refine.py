"""Intra-node core assignment + inter-node swap refinement (stage 3).

After the coarse map places one task cluster per allocated node, two
things remain:

- :func:`assign_cores` expands the node-level assignment to core
  granularity: each cluster's tasks are laid onto its node's cores in
  intra-node SFC (Hilbert) order.  Cores of a node sit at hop distance
  zero, so this choice never changes a metric — it only keeps the
  within-node rank order deterministic and geometrically contiguous
  (neighbouring tasks get neighbouring ranks, which real applications
  exploit for shared-memory optimisations).

- :func:`refine_swaps` is a bounded greedy local search in the spirit of
  Schulz & Träff's process-mapping refinement: swap the clusters of two
  network-adjacent nodes when it lowers the objective.  Because every
  fine task carries its node's router coordinates, the coarse graph's
  volume-weighted metrics (``weighted_hops``, ``latency_max``,
  ``data_max``) are EXACTLY the fine mapping's — scoring swaps on the
  contracted graph loses nothing.  Candidate swaps of a round are scored
  in batched :func:`repro.core.metrics.evaluate_candidates` passes (one
  coordinate stack per chunk), and a whole accepted set is re-verified
  against the base score so the pass is monotone by construction.

- :func:`refine_qap` (ISSUE 10) is the sparse-QAP local search used by
  the N-level hierarchy's grouping levels (``Level.refine_mode="qap"``):
  per-cluster best-single-move (into empty units) AND pairwise-swap
  neighbourhoods drawn from the SPARSE inter-cluster edge set — the
  units of graph-adjacent clusters, heaviest communication first — in
  addition to the network-nearest units ``refine_swaps`` considers.
  Improving proposals are applied in gain-bucket order (quantized gain
  buckets, deterministic proposal-id tie-break), and the accepted set
  is re-verified on the full graph exactly like ``refine_swaps``, so
  the pass is monotone by the same construction.  Both passes share the
  batched proposal scorer (:func:`_proposal_scores`); a hierarchy level
  selects between them with its ``refine_mode``.

- :func:`polish_groups` (ISSUE 10) repairs a GROUP expansion: when a
  depth >= 3 hierarchy expands a group-level assignment one level down,
  the members of every group were placed by intra-group geometry alone
  — blind to which neighbouring group their heavy edges point at — and
  the medoid abstraction hid ~1 hop on every inter-cluster edge.  The
  bounded ``refine_swaps``/``refine_qap`` passes cannot recover that
  (they touch ``top`` clusters a round; the damage is in EVERY group
  interior), so this pass runs a cheap exact local search restricted to
  same-group swaps: closed-form KL-style weighted-hops deltas (O(deg)
  per candidate, no proposal stacks), the best improving swap of every
  group applied Jacobi-style in one batch, the batch re-verified
  against the full objective exactly like the other passes (monotone),
  and later rounds narrowed to the groups a previous accept touched.
"""

from __future__ import annotations

import numpy as np

from repro.core.metrics import get_evaluator, pairwise_hops
from repro.core.orderings import hilbert_key

# host-memory cap on a proposal-round's edited coordinate stacks (the
# per-slice build below; one slice for every realistic round)
_STACK_BYTE_BUDGET = 1 << 28

# gain quantization of refine_qap's bucket-ordered accept: more buckets
# = closer to strict best-first, fewer = more id-deterministic batching
_QAP_BUCKETS = 32


def assign_cores(
    labels: np.ndarray,
    cluster_to_router: np.ndarray,
    core_router: np.ndarray,
    task_coords: np.ndarray,
    nrouters: int,
) -> np.ndarray:
    """Expand cluster -> node into task -> core (intra-node SFC order).

    labels            : (n,) cluster id per task.
    cluster_to_router : (nclusters,) router id per cluster.
    core_router       : (ncores,) router id of every allocation core row.
    task_coords       : (n, d) task coordinates (for the Hilbert order).
    nrouters          : number of distinct routers in the allocation.

    Returns ``task_to_proc``: (n,) index into the allocation's core rows.
    Tasks of a cluster are sorted by their Hilbert index and dealt onto
    the node's cores in allocation order.  No core ever receives more
    than ``ceil(n / ncores)`` tasks: a node holds at most that many
    tasks per core (round-robin, like the flat mapper's tnum > pnum
    case), and tasks beyond a node's capacity — possible when router
    core counts are uneven (a trimmed allocation) or task clusters are
    weight-balanced — spill onto the remaining free core slots in
    allocation (SFC) order.  In particular ``n == ncores`` is always a
    BIJECTION, matching the flat pipeline's tnum == pnum contract.
    """
    labels = np.asarray(labels)
    n = len(labels)
    ncores = len(core_router)
    # tasks grouped by ROUTER (then cluster, then Hilbert index): the
    # rank is taken within the router group, so even a non-injective
    # cluster -> router map (possible when skewed task weights starve a
    # coarse part) packs co-located clusters without collisions
    r_task = np.asarray(cluster_to_router)[labels]
    order = np.lexsort((hilbert_key(task_coords), labels, r_task))
    rsz_t = np.bincount(r_task, minlength=nrouters)  # tasks per router
    rstarts_t = np.cumsum(rsz_t) - rsz_t
    r = r_task[order]
    rank = np.arange(n) - rstarts_t[r]

    # allocation core rows grouped by router (allocation order within)
    core_order = np.argsort(core_router, kind="stable").astype(np.int64)
    rsizes = np.bincount(core_router, minlength=nrouters)
    rstarts = np.cumsum(rsizes) - rsizes

    q = -(-n // ncores)  # global per-core task budget (>= 1)
    fits = rank < rsizes[r] * q
    t2p = np.empty(n, dtype=np.int64)
    t2p[order[fits]] = core_order[rstarts[r[fits]]
                                  + rank[fits] % rsizes[r[fits]]]
    if not fits.all():
        # spill: fill the remaining per-core budget in allocation order
        counts = np.bincount(t2p[order[fits]], minlength=ncores)
        free = np.repeat(core_order, q - counts[core_order])
        t2p[order[~fits]] = free[: int((~fits).sum())]
    return t2p


def _pad_stack(stack: np.ndarray, ndim: int) -> np.ndarray:
    """Zero-pad network-dim coordinate stacks to the machine's full ndim
    (the batched router indexes every machine dimension)."""
    pad = ndim - stack.shape[-1]
    if pad <= 0:
        return stack
    z = np.zeros(stack.shape[:-1] + (pad,), dtype=stack.dtype)
    return np.concatenate([stack, z], axis=-1)


def _scores(machine, edges, weights, stack, objective, evaluator,
            chunk_elems: int = 1 << 24):
    """(B, len(objective)) score matrix for a coordinate stack.

    ``evaluator`` is a RESOLVED scoring callable (one
    :func:`repro.core.metrics.get_evaluator` call per refinement run,
    hoisted out of the swap loop instead of re-walking the backend
    fallback chain per scoring pass).  Hops-only objectives read just
    the network columns, so the stack is zero-padded to the machine's
    full ndim only when the batched router runs (traffic objectives
    index every machine dimension)."""
    traffic = any(k in ("latency_max", "data_max") for k in objective)
    if traffic:
        stack = _pad_stack(stack, machine.ndim)
    ev = evaluator(machine, edges, weights, stack, traffic=traffic,
                   chunk_elems=chunk_elems)
    return np.stack([np.asarray(ev[k], dtype=np.float64)
                     for k in objective], axis=1)


def _lex_less(a: np.ndarray, b: np.ndarray, tol: float = 1e-12) -> bool:
    """Strict lexicographic a < b with a tolerance per component."""
    for x, y in zip(a, b):
        if x < y - tol:
            return True
        if x > y + tol:
            return False
    return False


def _proposal_scores(machine, edges, w, router_coords, cc, proposals,
                     objective, evaluator, base, separable, chunk):
    """(nb, len(objective)) FULL-graph scores of move/swap proposals.

    ``proposals`` rows are ``(a, ra, b, rb)``: move cluster ``a`` from
    unit ``ra`` to unit ``rb``, exchanging with occupant ``b`` (``-1``
    when ``rb`` is empty).  Shared by :func:`refine_swaps` and
    :func:`refine_qap` — the operations are exactly the former's
    original inline block, so the greedy pass keeps its bit-identity
    with the fused device refinement.

    The edge set the scores run on: a proposal only moves two clusters,
    so for separable objectives the edges incident to a touched cluster
    carry ALL the score difference — ``score = base_full - base_union +
    union(proposal)``, exact.  Max-based objectives score full stacks.
    """
    nclusters = len(cc)
    if separable:
        touched_c = np.zeros(nclusters, dtype=bool)
        for a, ra, b, rb in proposals:
            touched_c[a] = True
            if b >= 0:
                touched_c[b] = True
        em = touched_c[edges[:, 0]] | touched_c[edges[:, 1]]
        s_edges, s_w = edges[em], w[em]
        # compact the stacks to the clusters the union edges touch:
        # an edited row outside the union cannot change the score
        uc = np.unique(s_edges)
        remap = np.full(nclusters, -1, dtype=np.int64)
        remap[uc] = np.arange(len(uc))
        s_edges = remap[s_edges]
        s_cc = cc[uc]
        base_union = _scores(machine, s_edges, s_w, s_cc[None],
                             objective, evaluator)[0]
        offset = base - base_union
    else:
        s_edges, s_w = edges, w
        remap = np.arange(nclusters)
        s_cc = cc
        offset = np.zeros_like(base)

    # score every proposal through ONE batched entry per (large)
    # slice: the edited stacks are built with two vectorised
    # scatters (a proposal only swaps two rows of the base stack)
    # and the evaluator chunks internally — no per-proposal Python
    # re-entry, so an accelerator backend sees a whole slice as one
    # launch.  Slices exist only to bound HOST memory: at least
    # ``chunk`` proposals each, growing to whatever fits the stack
    # byte budget (one slice for every realistic round).
    nb = len(proposals)
    prop = np.asarray(proposals, dtype=np.int64)  # (nb, 4) columns
    a_c, ra_c, b_c, rb_c = prop.T
    rows_a = remap[a_c]
    rows_b = np.where(b_c >= 0, remap[np.maximum(b_c, 0)], -1)
    sc = max(max(chunk, 1), _STACK_BYTE_BUDGET // max(s_cc.nbytes, 1))
    scores = np.empty((nb, len(base)))
    for c0 in range(0, nb, sc):
        sl = slice(c0, min(c0 + sc, nb))
        stack = np.repeat(s_cc[None], sl.stop - c0, axis=0)
        va = np.flatnonzero(rows_a[sl] >= 0)
        stack[va, rows_a[sl][va]] = router_coords[rb_c[sl][va]]
        vb = np.flatnonzero(rows_b[sl] >= 0)
        stack[vb, rows_b[sl][vb]] = router_coords[ra_c[sl][vb]]
        scores[sl] = offset + _scores(
            machine, s_edges, s_w, stack, objective, evaluator)
    return scores


def refine_swaps(
    machine,
    coarse,
    router_coords: np.ndarray,
    cluster_to_router: np.ndarray,
    *,
    objective: tuple = ("weighted_hops",),
    rounds: int = 2,
    top: int = 64,
    degree: int = 4,
    chunk: int = 64,
    score_backend: str = "numpy",
) -> tuple[np.ndarray, dict]:
    """Bounded greedy swap refinement of a cluster -> router assignment.

    Each round: rank clusters by their contribution to the weighted-hops
    objective, take the ``top`` hottest, and propose exchanging each with
    the occupants of its ``degree`` network-nearest allocated routers
    (a move when the target router is empty).  All proposals of a round
    are scored through batched ``evaluate_candidates`` entries: the
    edited proposal stacks are built with two vectorised scatters in
    slices of at least ``chunk`` proposals (one slice for every
    realistic round — slices exist only to bound host memory), and the
    evaluator bounds device memory internally, so a round no longer
    re-enters Python per proposal and an accelerator backend typically
    sees it as a single launch.  The backend named by
    ``score_backend`` ("numpy", "jax" or "pallas") is resolved ONCE
    per call via :func:`repro.core.metrics.get_evaluator` and reused
    for every round.  For sum-separable objectives (``weighted_hops``,
    ``total_hops``) the pass restricts the edge list to edges incident
    to a touched cluster — a proposal only moves two clusters, so
    ``score = base_full - base_union + union(proposal)`` is EXACT while
    costing |union edges| instead of |all edges| per proposal (the
    bound that keeps refinement out of the hier benchmark's critical
    path).  Max-based objectives (``latency_max``/``data_max``) score
    full stacks.  A greedy disjoint set of strictly-improving proposals
    is applied, then the combined assignment is re-scored on the FULL
    graph — if the interactions between accepted swaps ever made it
    worse, the round falls back to the single best proposal, whose
    exact score is known to improve.  The returned assignment therefore
    NEVER scores worse than the input (monotone; asserted in
    tests/test_hier.py).

    Returns ``(refined cluster_to_router, stats)`` where stats carries
    the per-round objective history and acceptance counts.
    """
    router_coords = np.asarray(router_coords, dtype=np.int64)
    c2r = np.asarray(cluster_to_router, dtype=np.int64).copy()
    nclusters = len(c2r)
    nrouters = len(router_coords)
    # occupant table (last writer wins if c2r is ever non-injective —
    # proposal bookkeeping only; scores always come from the true c2r)
    r2c = np.full(nrouters, -1, dtype=np.int64)
    r2c[c2r] = np.arange(nclusters)

    edges = coarse.edges
    w = np.asarray(coarse.weights, dtype=np.float64)
    separable = all(k in ("weighted_hops", "total_hops") for k in objective)
    if separable:
        # hop sums of integer-valued volumes are EXACT in f64 whatever
        # the summation order — the numpy evaluator on the compacted
        # union graphs is both the cheapest and the one the fused
        # device refinement (repro.mapping.fused) matches bit for bit
        _, evaluator = get_evaluator("numpy")
    else:
        _, evaluator = get_evaluator(score_backend)  # resolve once

    base = _scores(machine, edges, w, router_coords[c2r][None],
                   objective, evaluator)[0]
    history = [base.copy()]
    accepted_total = 0
    evaluated_total = 0

    for _ in range(max(rounds, 0)):
        cc = router_coords[c2r]
        if len(edges) == 0:
            break
        # per-cluster objective contribution (weighted hops both ways)
        h = pairwise_hops(machine, cc[edges[:, 0]], cc[edges[:, 1]]) * w
        contrib = (np.bincount(edges[:, 0], weights=h, minlength=nclusters)
                   + np.bincount(edges[:, 1], weights=h,
                                 minlength=nclusters))
        hot = np.argsort(-contrib, kind="stable")[:top]
        hot = hot[contrib[hot] > 0]
        if len(hot) == 0:
            break

        # network-nearest allocated routers per hot cluster
        shape = (len(hot), nrouters, cc.shape[1])
        d = pairwise_hops(machine,
                          np.broadcast_to(cc[hot][:, None, :], shape),
                          np.broadcast_to(router_coords[None, :, :], shape)
                          ).astype(np.float64)
        d[np.arange(len(hot)), c2r[hot]] = np.inf  # not itself
        k = min(degree, nrouters - 1)
        if k <= 0:
            break
        # full stable argsort (NOT argpartition): integer hop distances
        # tie constantly, and the stable order — distance, then router
        # id — is the one the fused device refinement reproduces bit
        # for bit (argpartition picks tie-holders arbitrarily)
        near = np.argsort(d, axis=1, kind="stable")[:, :k]

        # dedup unordered proposals: (cluster a, target router rb)
        seen = set()
        proposals = []  # (a, ra, b_or_minus1, rb)
        for i, a in enumerate(hot):
            ra = int(c2r[a])
            for rb in near[i]:
                rb = int(rb)
                b = int(r2c[rb])
                key = (min(ra, rb), max(ra, rb))
                if key in seen:
                    continue
                seen.add(key)
                proposals.append((int(a), ra, b, rb))
        if not proposals:
            break
        evaluated_total += len(proposals)
        scores = _proposal_scores(machine, edges, w, router_coords, cc,
                                  proposals, objective, evaluator, base,
                                  separable, chunk)

        # greedy disjoint accept, best improvement first
        order = np.lexsort(tuple(scores[:, j]
                                 for j in reversed(range(scores.shape[1]))))
        touched = set()
        chosen = []
        for i in order:
            if not _lex_less(scores[i], base):
                break  # sorted: nothing further improves
            a, ra, b, rb = proposals[i]
            if {ra, rb} & touched:
                continue
            touched |= {ra, rb}
            chosen.append(i)
        if not chosen:
            break

        def _apply(sel, c2r=c2r, r2c=r2c):
            nc, nr = c2r.copy(), r2c.copy()
            for i in sel:
                a, ra, b, rb = proposals[i]
                nc[a] = rb
                nr[rb] = a
                nr[ra] = b
                if b >= 0:
                    nc[b] = ra
            return nc, nr

        new_c2r, new_r2c = _apply(chosen)
        combined = _scores(machine, edges, w, router_coords[new_c2r][None],
                           objective, evaluator)[0]
        if len(chosen) > 1 and not _lex_less(combined, base):
            # accepted swaps interacted badly: keep only the best one,
            # whose exact score is already known to beat the base
            chosen = [int(order[0])]
            new_c2r, new_r2c = _apply(chosen)
            combined = scores[chosen[0]]
        if not _lex_less(combined, base):
            break  # cannot happen for a single exact swap; safety net
        c2r, r2c = new_c2r, new_r2c
        base = np.asarray(combined, dtype=np.float64)
        history.append(base.copy())
        accepted_total += len(chosen)

    stats = {
        "refine_rounds_run": len(history) - 1,
        "refine_accepted": accepted_total,
        "refine_evaluated": evaluated_total,
        "refine_history": [tuple(float(x) for x in h) for h in history],
        "refine_initial": float(history[0][0]),
        "refine_final": float(history[-1][0]),
    }
    return c2r, stats

def refine_qap(
    machine,
    coarse,
    router_coords: np.ndarray,
    cluster_to_router: np.ndarray,
    *,
    objective: tuple = ("weighted_hops",),
    rounds: int = 2,
    top: int = 64,
    degree: int = 4,
    chunk: int = 64,
    score_backend: str = "numpy",
) -> tuple[np.ndarray, dict]:
    """Sparse-QAP local search over a cluster -> unit assignment.

    The mapping problem at one hierarchy level IS a sparse quadratic
    assignment problem: flow matrix = the coarse graph's inter-cluster
    volumes (sparse), distance matrix = unit pairwise hops.  Following
    Schulz & Träff's local search, each round:

    1. ranks clusters by their weighted-hops contribution and takes the
       ``top`` hottest;
    2. builds each hot cluster's neighbourhood from the SPARSE edge
       set — the units of its ``degree`` heaviest-communication graph
       neighbours (pulling chatty clusters together regardless of
       current distance) — plus its ``degree`` network-nearest units
       (the ``refine_swaps`` neighbourhood, catching moves the graph
       cannot see).  A proposal is a best-single-MOVE when the target
       unit is empty, a pairwise SWAP with the occupant otherwise;
    3. scores all proposals in one batched pass
       (:func:`_proposal_scores`: exact separable deltas on the union
       edge set) and applies a disjoint improving subset in GAIN-BUCKET
       order — gains quantized into ``_QAP_BUCKETS`` buckets of the
       round's best gain, buckets visited best-first, proposal id
       breaking ties inside a bucket, so the accept order is
       deterministic and independent of float argsort jitter;
    4. re-scores the combined assignment on the FULL graph and falls
       back to the single best proposal if the accepted set interacted
       badly — the pass is monotone by the same construction as
       ``refine_swaps`` (asserted in tests/test_hierarchy_spec.py).

    Same signature and stats contract as :func:`refine_swaps`; hierarchy
    levels select it with ``Level(refine_mode="qap")`` (the default for
    the grouping levels of ``HierarchySpec.with_depth``).
    """
    router_coords = np.asarray(router_coords, dtype=np.int64)
    c2r = np.asarray(cluster_to_router, dtype=np.int64).copy()
    nclusters = len(c2r)
    nrouters = len(router_coords)
    r2c = np.full(nrouters, -1, dtype=np.int64)
    r2c[c2r] = np.arange(nclusters)

    edges = coarse.edges
    w = np.asarray(coarse.weights, dtype=np.float64)
    separable = all(k in ("weighted_hops", "total_hops") for k in objective)
    if separable:
        _, evaluator = get_evaluator("numpy")
    else:
        _, evaluator = get_evaluator(score_backend)  # resolve once

    # symmetric CSR adjacency, heaviest edge first per cluster (built
    # once: the flow matrix never changes, only the assignment does)
    if len(edges):
        src = np.concatenate([edges[:, 0], edges[:, 1]])
        dst = np.concatenate([edges[:, 1], edges[:, 0]])
        ew = np.concatenate([w, w])
        adj_order = np.lexsort((dst, -ew, src))
        adj_src = src[adj_order]
        adj_dst = dst[adj_order]
        adj_starts = np.searchsorted(adj_src, np.arange(nclusters + 1))
    else:
        adj_dst = np.empty(0, dtype=np.int64)
        adj_starts = np.zeros(nclusters + 1, dtype=np.int64)

    base = _scores(machine, edges, w, router_coords[c2r][None],
                   objective, evaluator)[0]
    history = [base.copy()]
    accepted_total = 0
    evaluated_total = 0

    for _ in range(max(rounds, 0)):
        cc = router_coords[c2r]
        if len(edges) == 0:
            break
        h = pairwise_hops(machine, cc[edges[:, 0]], cc[edges[:, 1]]) * w
        contrib = (np.bincount(edges[:, 0], weights=h, minlength=nclusters)
                   + np.bincount(edges[:, 1], weights=h,
                                 minlength=nclusters))
        hot = np.argsort(-contrib, kind="stable")[:top]
        hot = hot[contrib[hot] > 0]
        if len(hot) == 0:
            break

        # network-nearest allocated units (the refine_swaps
        # neighbourhood; stable distance-then-id order)
        shape = (len(hot), nrouters, cc.shape[1])
        d = pairwise_hops(machine,
                          np.broadcast_to(cc[hot][:, None, :], shape),
                          np.broadcast_to(router_coords[None, :, :], shape)
                          ).astype(np.float64)
        d[np.arange(len(hot)), c2r[hot]] = np.inf  # not itself
        k = min(degree, nrouters - 1)
        if k <= 0:
            break
        near = np.argsort(d, axis=1, kind="stable")[:, :k]

        seen = set()
        proposals = []  # (a, ra, b_or_minus1, rb)

        def _propose(a, rb, seen=seen, proposals=proposals):
            ra = int(c2r[a])
            rb = int(rb)
            if rb == ra:
                return
            key = (min(ra, rb), max(ra, rb))
            if key in seen:
                return
            seen.add(key)
            proposals.append((int(a), ra, int(r2c[rb]), rb))

        for i, a in enumerate(hot):
            # sparse-QAP neighbourhood: units of the ``degree``
            # heaviest graph neighbours — move next to who you talk to
            nbrs = adj_dst[adj_starts[a]:adj_starts[a + 1]][:degree]
            for b in nbrs:
                rb = int(c2r[b])
                _propose(a, rb)
                # best-single-move half of the neighbourhood: the empty
                # units network-nearest to the chatty neighbour's unit
                # (swap-free relocation when the machine has slack)
                for rn in near[i]:
                    if r2c[rn] < 0:
                        _propose(a, rn)
                        break
            for rb in near[i]:
                _propose(a, rb)
        if not proposals:
            break
        evaluated_total += len(proposals)
        scores = _proposal_scores(machine, edges, w, router_coords, cc,
                                  proposals, objective, evaluator, base,
                                  separable, chunk)

        # gain-bucket ordered disjoint accept: quantized primary-
        # objective gains, best bucket first, proposal id inside
        gain = base[0] - scores[:, 0]
        improving = np.flatnonzero(gain > 1e-12)
        if len(improving) == 0:
            break
        gmax = float(gain[improving].max())
        bucket = np.zeros(len(proposals), dtype=np.int64)
        bucket[improving] = 1 + np.minimum(
            (gain[improving] / gmax * (_QAP_BUCKETS - 1)).astype(np.int64),
            _QAP_BUCKETS - 1)
        order = np.lexsort((np.arange(len(proposals)), -bucket))
        touched = set()
        chosen = []
        for i in order:
            if bucket[i] == 0:
                break  # sorted: only non-improving proposals remain
            if not _lex_less(scores[i], base):
                continue  # primary gain but lex-worse on a tiebreaker
            a, ra, b, rb = proposals[i]
            if {ra, rb} & touched:
                continue
            touched |= {ra, rb}
            chosen.append(i)
        if not chosen:
            break

        def _apply(sel, c2r=c2r, r2c=r2c):
            nc, nr = c2r.copy(), r2c.copy()
            for i in sel:
                a, ra, b, rb = proposals[i]
                nc[a] = rb
                nr[rb] = a
                nr[ra] = b
                if b >= 0:
                    nc[b] = ra
            return nc, nr

        new_c2r, new_r2c = _apply(chosen)
        combined = _scores(machine, edges, w, router_coords[new_c2r][None],
                           objective, evaluator)[0]
        if len(chosen) > 1 and not _lex_less(combined, base):
            # accepted proposals interacted badly: keep only the single
            # truly best one (exact score known to beat the base)
            best = np.lexsort(tuple(scores[:, j] for j in
                                    reversed(range(scores.shape[1]))))[0]
            chosen = [int(best)]
            new_c2r, new_r2c = _apply(chosen)
            combined = scores[chosen[0]]
        if not _lex_less(combined, base):
            break  # cannot happen for a single exact proposal; safety
        c2r, r2c = new_c2r, new_r2c
        base = np.asarray(combined, dtype=np.float64)
        history.append(base.copy())
        accepted_total += len(chosen)

    stats = {
        "refine_rounds_run": len(history) - 1,
        "refine_accepted": accepted_total,
        "refine_evaluated": evaluated_total,
        "refine_history": [tuple(float(x) for x in h) for h in history],
        "refine_initial": float(history[0][0]),
        "refine_final": float(history[-1][0]),
    }
    return c2r, stats


def polish_groups(
    machine,
    coarse,
    unit_coords: np.ndarray,
    cluster_to_unit: np.ndarray,
    member: np.ndarray,
    *,
    objective: tuple = ("weighted_hops",),
    rounds: int = 4,
    chunk_elems: int = 1 << 24,
    score_backend: str = "numpy",
) -> tuple[np.ndarray, dict]:
    """Intra-group polish of an expanded cluster -> unit assignment.

    ``member[u]`` is the group (level above) that unit ``u`` belongs
    to.  The pass ONLY exchanges clusters within a group — group
    contents are exactly what the level above decided; this repairs the
    member ORDER inside each group, which the expansion chose from
    intra-group geometry alone.

    Per round:

    1. For every cluster ``a`` in an active group, the closed-form
       weighted-hops cost ``S[a, j]`` of parking ``a`` on each unit
       slot ``j`` of its group: one ``pairwise_hops`` + ``bincount``
       sweep over the edges incident to active clusters per slot —
       no per-proposal coordinate stacks, so ALL groups are searched
       at once for the cost ``refine_swaps`` pays on ``top`` clusters.
    2. The best improving same-group swap (or move to an empty member
       unit) of every group by exact KL delta
       ``S[a,k]-S[a,j] + S[b,j]-S[b,k] + 2*vol_ab*hops(ra,rb)``.
    3. All per-group winners applied in ONE Jacobi batch and re-scored
       against the FULL objective (cross-group edges couple the
       updates); a worse batch falls back to the single best group's
       swap, so the pass is monotone exactly like ``refine_swaps``.
    4. The active set shrinks to the groups holding or graph-adjacent
       to a swapped cluster — converged regions stop paying step 1.

    The deltas drive proposals from the weighted-hops objective; the
    accept decision uses the caller's full (possibly lexicographic)
    ``objective``, so a latency-first config stays monotone too (it
    just converges earlier when hop-driven swaps do not help it).

    Returns ``(polished cluster_to_unit, stats)`` with the same shape
    of stats contract as the refine passes (``polish_*`` keys).
    """
    unit_coords = np.asarray(unit_coords, dtype=np.int64)
    c2u = np.asarray(cluster_to_unit, dtype=np.int64).copy()
    member = np.asarray(member, dtype=np.int64)
    edges = coarse.edges
    w = np.asarray(coarse.weights, dtype=np.float64)
    nclusters = len(c2u)
    nunits = len(unit_coords)
    separable = all(k in ("weighted_hops", "total_hops") for k in objective)
    if separable:
        _, evaluator = get_evaluator("numpy")
    else:
        _, evaluator = get_evaluator(score_backend)  # resolve once

    base = _scores(machine, edges, w, unit_coords[c2u][None],
                   objective, evaluator)[0]
    history = [base.copy()]
    stats = {
        "polish_rounds_run": 0,
        "polish_accepted": 0,
        "polish_evaluated": 0,
        "polish_initial": float(base[0]),
        "polish_final": float(base[0]),
        "polish_history": [tuple(float(x) for x in base)],
    }
    if rounds <= 0 or len(edges) == 0 or nclusters < 2:
        return c2u, stats

    # group slot tables: slot_unit[g, j] = j-th unit of group g (unit id
    # order; -1 pads ragged groups), unit_slot the inverse
    ngroups = int(member.max()) + 1
    order = np.argsort(member, kind="stable").astype(np.int64)
    gsz = np.bincount(member, minlength=ngroups)
    arity = int(gsz.max()) if len(gsz) else 0
    if arity < 2:
        return c2u, stats  # singleton groups: nothing to exchange
    gstart = np.cumsum(gsz) - gsz
    slot_unit = np.full((ngroups, arity), -1, dtype=np.int64)
    for j in range(arity):
        sel = gsz > j
        slot_unit[sel, j] = order[gstart[sel] + j]

    src, dst = edges[:, 0], edges[:, 1]
    tol = 1e-12
    active = np.ones(ngroups, dtype=bool)
    accepted_total = 0
    evaluated_total = 0

    for _ in range(max(rounds, 0)):
        pos = unit_coords[c2u]
        u2c = np.full(nunits, -1, dtype=np.int64)
        u2c[c2u] = np.arange(nclusters)
        ga = member[c2u]                      # group of each cluster
        act_c = active[ga]
        em = act_c[src] | act_c[dst]
        es, ed, ew = src[em], dst[em], w[em]
        # step 1: S[a, j] over active clusters (both edge directions)
        S = np.zeros((nclusters, arity))
        for j in range(arity):
            uj = slot_unit[ga, j]             # slot-j unit of a's group
            m1 = act_c[es] & (uj[es] >= 0)
            if m1.any():
                v = pairwise_hops(machine, unit_coords[uj[es[m1]]],
                                  pos[ed[m1]]).astype(np.float64) * ew[m1]
                S[:, j] += np.bincount(es[m1], weights=v,
                                       minlength=nclusters)
            m2 = act_c[ed] & (uj[ed] >= 0)
            if m2.any():
                v = pairwise_hops(machine, unit_coords[uj[ed[m2]]],
                                  pos[es[m2]]).astype(np.float64) * ew[m2]
                S[:, j] += np.bincount(ed[m2], weights=v,
                                       minlength=nclusters)

        # step 2: best improving same-group swap/move per active group
        bestd = np.full(ngroups, -tol)
        best_a = np.full(ngroups, -1, dtype=np.int64)
        best_b = np.full(ngroups, -1, dtype=np.int64)
        best_ua = np.full(ngroups, -1, dtype=np.int64)
        best_ub = np.full(ngroups, -1, dtype=np.int64)
        for j in range(arity):
            for k in range(j + 1, arity):
                ua, ub = slot_unit[:, j], slot_unit[:, k]
                gi = np.flatnonzero(active & (ua >= 0) & (ub >= 0))
                if len(gi) == 0:
                    continue
                a = u2c[ua[gi]]
                b = u2c[ub[gi]]
                occ = (a >= 0) & (b >= 0)
                # swap candidates (both slots occupied)
                gs, as_, bs = gi[occ], a[occ], b[occ]
                d = (S[as_, k] - S[as_, j]) + (S[bs, j] - S[bs, k])
                evaluated_total += len(gs)
                imp = d < bestd[gs]
                gs, as_, bs, d = gs[imp], as_[imp], bs[imp], d[imp]
                bestd[gs] = d
                best_a[gs], best_b[gs] = as_, bs
                best_ua[gs], best_ub[gs] = ua[gi][occ][imp], ub[gi][occ][imp]
                # move candidates (exactly one of the two slots occupied)
                for aa, jj, kk, uu in ((a, j, k, ub[gi]), (b, k, j, ua[gi])):
                    mv = (aa >= 0) & ((a < 0) | (b < 0))
                    gm, am = gi[mv], aa[mv]
                    if len(gm) == 0:
                        continue
                    dm = S[am, kk] - S[am, jj]
                    evaluated_total += len(gm)
                    imp = dm < bestd[gm]
                    gm, am, dm = gm[imp], am[imp], dm[imp]
                    bestd[gm] = dm
                    best_a[gm], best_b[gm] = am, -1
                    best_ua[gm] = c2u[am]
                    best_ub[gm] = uu[mv][imp]
        gsel = np.flatnonzero(best_a >= 0)
        if len(gsel):
            # exact-delta correction for swapped pairs that share edges:
            # the slot costs double-count the a-b rows, so add back
            # 2 * vol_ab * hops(ra, rb) before the improving filter
            aa, bb = best_a[gsel], best_b[gsel]
            sw = bb >= 0
            if sw.any():
                ie = (ga[src] == ga[dst]) & (src != dst)
                ia, ib, iw = src[ie], dst[ie], w[ie]
                key = (np.minimum(ia, ib).astype(np.int64) * nclusters
                       + np.maximum(ia, ib))
                ks = np.argsort(key, kind="stable")
                qa, qb = aa[sw], bb[sw]
                qk = (np.minimum(qa, qb).astype(np.int64) * nclusters
                      + np.maximum(qa, qb))
                lo = np.searchsorted(key[ks], qk, "left")
                hi = np.searchsorted(key[ks], qk, "right")
                vab = np.array([float(iw[ks[l:h]].sum())
                                for l, h in zip(lo, hi)])
                hab = pairwise_hops(machine, pos[qa],
                                    pos[qb]).astype(np.float64)
                fixed = bestd[gsel].copy()
                fixed[sw] = fixed[sw] + 2.0 * vab * hab
                keep = fixed < -tol
            else:
                keep = np.ones(len(gsel), dtype=bool)
            gsel = gsel[keep]
        if len(gsel) == 0:
            break

        # step 3: Jacobi batch apply + full-objective verify
        def _apply(gs):
            prop = c2u.copy()
            pa, pb = best_a[gs], best_b[gs]
            prop[pa] = best_ub[gs]
            swp = pb >= 0
            prop[pb[swp]] = best_ua[gs][swp]
            return prop

        prop = _apply(gsel)
        combined = _scores(machine, edges, w, unit_coords[prop][None],
                           objective, evaluator, chunk_elems)[0]
        if len(gsel) > 1 and not _lex_less(combined, base):
            gsel = gsel[np.argsort(bestd[gsel], kind="stable")[:1]]
            prop = _apply(gsel)
            combined = _scores(machine, edges, w,
                               unit_coords[prop][None],
                               objective, evaluator, chunk_elems)[0]
        if not _lex_less(combined, base):
            break  # hop-driven deltas do not improve this objective
        c2u = prop
        base = np.asarray(combined, dtype=np.float64)
        history.append(base.copy())
        accepted_total += len(gsel)

        # step 4: shrink to the neighbourhood the accepts disturbed
        moved = np.zeros(nclusters, dtype=bool)
        moved[best_a[gsel]] = True
        bsel = best_b[gsel]
        moved[bsel[bsel >= 0]] = True
        ga = member[c2u]
        active[:] = False
        active[ga[moved]] = True
        tm = moved[src] | moved[dst]
        active[ga[src[tm]]] = True
        active[ga[dst[tm]]] = True

    stats.update({
        "polish_rounds_run": len(history) - 1,
        "polish_accepted": accepted_total,
        "polish_evaluated": evaluated_total,
        "polish_final": float(base[0]),
        "polish_history": [tuple(float(x) for x in h) for h in history],
    })
    return c2u, stats
