"""Intra-node core assignment + inter-node swap refinement (stage 3).

After the coarse map places one task cluster per allocated node, two
things remain:

- :func:`assign_cores` expands the node-level assignment to core
  granularity: each cluster's tasks are laid onto its node's cores in
  intra-node SFC (Hilbert) order.  Cores of a node sit at hop distance
  zero, so this choice never changes a metric — it only keeps the
  within-node rank order deterministic and geometrically contiguous
  (neighbouring tasks get neighbouring ranks, which real applications
  exploit for shared-memory optimisations).

- :func:`refine_swaps` is a bounded greedy local search in the spirit of
  Schulz & Träff's process-mapping refinement: swap the clusters of two
  network-adjacent nodes when it lowers the objective.  Because every
  fine task carries its node's router coordinates, the coarse graph's
  volume-weighted metrics (``weighted_hops``, ``latency_max``,
  ``data_max``) are EXACTLY the fine mapping's — scoring swaps on the
  contracted graph loses nothing.  Candidate swaps of a round are scored
  in batched :func:`repro.core.metrics.evaluate_candidates` passes (one
  coordinate stack per chunk), and a whole accepted set is re-verified
  against the base score so the pass is monotone by construction.
"""

from __future__ import annotations

import numpy as np

from repro.core.metrics import get_evaluator, pairwise_hops
from repro.core.orderings import hilbert_key

# host-memory cap on a proposal-round's edited coordinate stacks (the
# per-slice build below; one slice for every realistic round)
_STACK_BYTE_BUDGET = 1 << 28


def assign_cores(
    labels: np.ndarray,
    cluster_to_router: np.ndarray,
    core_router: np.ndarray,
    task_coords: np.ndarray,
    nrouters: int,
) -> np.ndarray:
    """Expand cluster -> node into task -> core (intra-node SFC order).

    labels            : (n,) cluster id per task.
    cluster_to_router : (nclusters,) router id per cluster.
    core_router       : (ncores,) router id of every allocation core row.
    task_coords       : (n, d) task coordinates (for the Hilbert order).
    nrouters          : number of distinct routers in the allocation.

    Returns ``task_to_proc``: (n,) index into the allocation's core rows.
    Tasks of a cluster are sorted by their Hilbert index and dealt onto
    the node's cores in allocation order.  No core ever receives more
    than ``ceil(n / ncores)`` tasks: a node holds at most that many
    tasks per core (round-robin, like the flat mapper's tnum > pnum
    case), and tasks beyond a node's capacity — possible when router
    core counts are uneven (a trimmed allocation) or task clusters are
    weight-balanced — spill onto the remaining free core slots in
    allocation (SFC) order.  In particular ``n == ncores`` is always a
    BIJECTION, matching the flat pipeline's tnum == pnum contract.
    """
    labels = np.asarray(labels)
    n = len(labels)
    ncores = len(core_router)
    # tasks grouped by ROUTER (then cluster, then Hilbert index): the
    # rank is taken within the router group, so even a non-injective
    # cluster -> router map (possible when skewed task weights starve a
    # coarse part) packs co-located clusters without collisions
    r_task = np.asarray(cluster_to_router)[labels]
    order = np.lexsort((hilbert_key(task_coords), labels, r_task))
    rsz_t = np.bincount(r_task, minlength=nrouters)  # tasks per router
    rstarts_t = np.cumsum(rsz_t) - rsz_t
    r = r_task[order]
    rank = np.arange(n) - rstarts_t[r]

    # allocation core rows grouped by router (allocation order within)
    core_order = np.argsort(core_router, kind="stable").astype(np.int64)
    rsizes = np.bincount(core_router, minlength=nrouters)
    rstarts = np.cumsum(rsizes) - rsizes

    q = -(-n // ncores)  # global per-core task budget (>= 1)
    fits = rank < rsizes[r] * q
    t2p = np.empty(n, dtype=np.int64)
    t2p[order[fits]] = core_order[rstarts[r[fits]]
                                  + rank[fits] % rsizes[r[fits]]]
    if not fits.all():
        # spill: fill the remaining per-core budget in allocation order
        counts = np.bincount(t2p[order[fits]], minlength=ncores)
        free = np.repeat(core_order, q - counts[core_order])
        t2p[order[~fits]] = free[: int((~fits).sum())]
    return t2p


def _pad_stack(stack: np.ndarray, ndim: int) -> np.ndarray:
    """Zero-pad network-dim coordinate stacks to the machine's full ndim
    (the batched router indexes every machine dimension)."""
    pad = ndim - stack.shape[-1]
    if pad <= 0:
        return stack
    z = np.zeros(stack.shape[:-1] + (pad,), dtype=stack.dtype)
    return np.concatenate([stack, z], axis=-1)


def _scores(machine, edges, weights, stack, objective, evaluator,
            chunk_elems: int = 1 << 24):
    """(B, len(objective)) score matrix for a coordinate stack.

    ``evaluator`` is a RESOLVED scoring callable (one
    :func:`repro.core.metrics.get_evaluator` call per refinement run,
    hoisted out of the swap loop instead of re-walking the backend
    fallback chain per scoring pass).  Hops-only objectives read just
    the network columns, so the stack is zero-padded to the machine's
    full ndim only when the batched router runs (traffic objectives
    index every machine dimension)."""
    traffic = any(k in ("latency_max", "data_max") for k in objective)
    if traffic:
        stack = _pad_stack(stack, machine.ndim)
    ev = evaluator(machine, edges, weights, stack, traffic=traffic,
                   chunk_elems=chunk_elems)
    return np.stack([np.asarray(ev[k], dtype=np.float64)
                     for k in objective], axis=1)


def _lex_less(a: np.ndarray, b: np.ndarray, tol: float = 1e-12) -> bool:
    """Strict lexicographic a < b with a tolerance per component."""
    for x, y in zip(a, b):
        if x < y - tol:
            return True
        if x > y + tol:
            return False
    return False


def refine_swaps(
    machine,
    coarse,
    router_coords: np.ndarray,
    cluster_to_router: np.ndarray,
    *,
    objective: tuple = ("weighted_hops",),
    rounds: int = 2,
    top: int = 64,
    degree: int = 4,
    chunk: int = 64,
    score_backend: str = "numpy",
) -> tuple[np.ndarray, dict]:
    """Bounded greedy swap refinement of a cluster -> router assignment.

    Each round: rank clusters by their contribution to the weighted-hops
    objective, take the ``top`` hottest, and propose exchanging each with
    the occupants of its ``degree`` network-nearest allocated routers
    (a move when the target router is empty).  All proposals of a round
    are scored through batched ``evaluate_candidates`` entries: the
    edited proposal stacks are built with two vectorised scatters in
    slices of at least ``chunk`` proposals (one slice for every
    realistic round — slices exist only to bound host memory), and the
    evaluator bounds device memory internally, so a round no longer
    re-enters Python per proposal and an accelerator backend typically
    sees it as a single launch.  The backend named by
    ``score_backend`` ("numpy", "jax" or "pallas") is resolved ONCE
    per call via :func:`repro.core.metrics.get_evaluator` and reused
    for every round.  For sum-separable objectives (``weighted_hops``,
    ``total_hops``) the pass restricts the edge list to edges incident
    to a touched cluster — a proposal only moves two clusters, so
    ``score = base_full - base_union + union(proposal)`` is EXACT while
    costing |union edges| instead of |all edges| per proposal (the
    bound that keeps refinement out of the hier benchmark's critical
    path).  Max-based objectives (``latency_max``/``data_max``) score
    full stacks.  A greedy disjoint set of strictly-improving proposals
    is applied, then the combined assignment is re-scored on the FULL
    graph — if the interactions between accepted swaps ever made it
    worse, the round falls back to the single best proposal, whose
    exact score is known to improve.  The returned assignment therefore
    NEVER scores worse than the input (monotone; asserted in
    tests/test_hier.py).

    Returns ``(refined cluster_to_router, stats)`` where stats carries
    the per-round objective history and acceptance counts.
    """
    router_coords = np.asarray(router_coords, dtype=np.int64)
    c2r = np.asarray(cluster_to_router, dtype=np.int64).copy()
    nclusters = len(c2r)
    nrouters = len(router_coords)
    # occupant table (last writer wins if c2r is ever non-injective —
    # proposal bookkeeping only; scores always come from the true c2r)
    r2c = np.full(nrouters, -1, dtype=np.int64)
    r2c[c2r] = np.arange(nclusters)

    edges = coarse.edges
    w = np.asarray(coarse.weights, dtype=np.float64)
    separable = all(k in ("weighted_hops", "total_hops") for k in objective)
    if separable:
        # hop sums of integer-valued volumes are EXACT in f64 whatever
        # the summation order — the numpy evaluator on the compacted
        # union graphs is both the cheapest and the one the fused
        # device refinement (repro.mapping.fused) matches bit for bit
        _, evaluator = get_evaluator("numpy")
    else:
        _, evaluator = get_evaluator(score_backend)  # resolve once

    base = _scores(machine, edges, w, router_coords[c2r][None],
                   objective, evaluator)[0]
    history = [base.copy()]
    accepted_total = 0
    evaluated_total = 0

    for _ in range(max(rounds, 0)):
        cc = router_coords[c2r]
        if len(edges) == 0:
            break
        # per-cluster objective contribution (weighted hops both ways)
        h = pairwise_hops(machine, cc[edges[:, 0]], cc[edges[:, 1]]) * w
        contrib = (np.bincount(edges[:, 0], weights=h, minlength=nclusters)
                   + np.bincount(edges[:, 1], weights=h,
                                 minlength=nclusters))
        hot = np.argsort(-contrib, kind="stable")[:top]
        hot = hot[contrib[hot] > 0]
        if len(hot) == 0:
            break

        # network-nearest allocated routers per hot cluster
        shape = (len(hot), nrouters, cc.shape[1])
        d = pairwise_hops(machine,
                          np.broadcast_to(cc[hot][:, None, :], shape),
                          np.broadcast_to(router_coords[None, :, :], shape)
                          ).astype(np.float64)
        d[np.arange(len(hot)), c2r[hot]] = np.inf  # not itself
        k = min(degree, nrouters - 1)
        if k <= 0:
            break
        # full stable argsort (NOT argpartition): integer hop distances
        # tie constantly, and the stable order — distance, then router
        # id — is the one the fused device refinement reproduces bit
        # for bit (argpartition picks tie-holders arbitrarily)
        near = np.argsort(d, axis=1, kind="stable")[:, :k]

        # dedup unordered proposals: (cluster a, target router rb)
        seen = set()
        proposals = []  # (a, ra, b_or_minus1, rb)
        for i, a in enumerate(hot):
            ra = int(c2r[a])
            for rb in near[i]:
                rb = int(rb)
                b = int(r2c[rb])
                key = (min(ra, rb), max(ra, rb))
                if key in seen:
                    continue
                seen.add(key)
                proposals.append((int(a), ra, b, rb))
        if not proposals:
            break
        evaluated_total += len(proposals)

        # edge set the proposal scores run on: a proposal only moves two
        # clusters, so for separable objectives the edges incident to a
        # touched cluster carry ALL the score difference — score =
        # base_full - base_union + union(proposal), exact
        if separable:
            touched_c = np.zeros(nclusters, dtype=bool)
            for a, ra, b, rb in proposals:
                touched_c[a] = True
                if b >= 0:
                    touched_c[b] = True
            em = touched_c[edges[:, 0]] | touched_c[edges[:, 1]]
            s_edges, s_w = edges[em], w[em]
            # compact the stacks to the clusters the union edges touch:
            # an edited row outside the union cannot change the score
            uc = np.unique(s_edges)
            remap = np.full(nclusters, -1, dtype=np.int64)
            remap[uc] = np.arange(len(uc))
            s_edges = remap[s_edges]
            s_cc = cc[uc]
            base_union = _scores(machine, s_edges, s_w, s_cc[None],
                                 objective, evaluator)[0]
            offset = base - base_union
        else:
            s_edges, s_w = edges, w
            remap = np.arange(nclusters)
            s_cc = cc
            offset = np.zeros_like(base)

        # score every proposal through ONE batched entry per (large)
        # slice: the edited stacks are built with two vectorised
        # scatters (a proposal only swaps two rows of the base stack)
        # and the evaluator chunks internally — no per-proposal Python
        # re-entry, so an accelerator backend sees a whole slice as one
        # launch.  Slices exist only to bound HOST memory: at least
        # ``chunk`` proposals each, growing to whatever fits the stack
        # byte budget (one slice for every realistic round).
        nb = len(proposals)
        prop = np.asarray(proposals, dtype=np.int64)  # (nb, 4) columns
        a_c, ra_c, b_c, rb_c = prop.T
        rows_a = remap[a_c]
        rows_b = np.where(b_c >= 0, remap[np.maximum(b_c, 0)], -1)
        sc = max(max(chunk, 1), _STACK_BYTE_BUDGET // max(s_cc.nbytes, 1))
        scores = np.empty((nb, len(base)))
        for c0 in range(0, nb, sc):
            sl = slice(c0, min(c0 + sc, nb))
            stack = np.repeat(s_cc[None], sl.stop - c0, axis=0)
            va = np.flatnonzero(rows_a[sl] >= 0)
            stack[va, rows_a[sl][va]] = router_coords[rb_c[sl][va]]
            vb = np.flatnonzero(rows_b[sl] >= 0)
            stack[vb, rows_b[sl][vb]] = router_coords[ra_c[sl][vb]]
            scores[sl] = offset + _scores(
                machine, s_edges, s_w, stack, objective, evaluator)

        # greedy disjoint accept, best improvement first
        order = np.lexsort(tuple(scores[:, j]
                                 for j in reversed(range(scores.shape[1]))))
        touched = set()
        chosen = []
        for i in order:
            if not _lex_less(scores[i], base):
                break  # sorted: nothing further improves
            a, ra, b, rb = proposals[i]
            if {ra, rb} & touched:
                continue
            touched |= {ra, rb}
            chosen.append(i)
        if not chosen:
            break

        def _apply(sel, c2r=c2r, r2c=r2c):
            nc, nr = c2r.copy(), r2c.copy()
            for i in sel:
                a, ra, b, rb = proposals[i]
                nc[a] = rb
                nr[rb] = a
                nr[ra] = b
                if b >= 0:
                    nc[b] = ra
            return nc, nr

        new_c2r, new_r2c = _apply(chosen)
        combined = _scores(machine, edges, w, router_coords[new_c2r][None],
                           objective, evaluator)[0]
        if len(chosen) > 1 and not _lex_less(combined, base):
            # accepted swaps interacted badly: keep only the best one,
            # whose exact score is already known to beat the base
            chosen = [int(order[0])]
            new_c2r, new_r2c = _apply(chosen)
            combined = scores[chosen[0]]
        if not _lex_less(combined, base):
            break  # cannot happen for a single exact swap; safety net
        c2r, r2c = new_c2r, new_r2c
        base = np.asarray(combined, dtype=np.float64)
        history.append(base.copy())
        accepted_total += len(chosen)

    stats = {
        "refine_rounds_run": len(history) - 1,
        "refine_accepted": accepted_total,
        "refine_evaluated": evaluated_total,
        "refine_history": [tuple(float(x) for x in h) for h in history],
        "refine_initial": float(history[0][0]),
        "refine_final": float(history[-1][0]),
    }
    return c2r, stats
