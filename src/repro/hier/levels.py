"""N-level (coarsen* -> map -> refine/expand*) driver (ISSUE 10).

``map_hierarchical`` generalises PR 3's hardwired node -> core scheme
to an arbitrary-depth recursive hierarchy described by the pipeline
config's :class:`repro.hier.HierarchySpec` (the recursive level
structure of Schulz & Woydt's shared-memory hierarchical process
mapping):

1. **coarsen, level by level** — the task graph is contracted bottom-up
   with :func:`repro.hier.aggregate.aggregate_tasks` (one bincount
   contraction per level; level 1 groups tasks into node-sized
   clusters, level j groups level j-1 clusters by the level's arity).
   The machine side mirrors it: level 1 units are the allocation's
   routers (:func:`router_view`), level j units are geometric groups of
   level j-1 units (:func:`group_units`) represented by their MEDOID —
   a real member's integer coordinates, so hop metrics treat a group
   exactly like a router.
2. **map at the top** — the UNCHANGED batched rotation sweep
   (``MappingPipeline.map_candidates`` + ``CandidateSearch``, or the
   fused one-program device path) runs once, at the top granularity:
   every added level divides the sweep's point count by its arity.
3. **expand downward, refining per level** — from the top down, each
   level's assignment is refined (``refine_mode="swap"`` — PR 3's
   bounded greedy network-nearest pass, fused-foldable;
   ``"qap"`` — the sparse-QAP local search of Schulz & Träff, see
   :func:`repro.hier.refine.refine_qap`) and then expanded one level
   with :func:`repro.hier.refine.assign_cores` (children dealt onto
   their group's member units in SFC order), until tasks sit on cores.

A depth-2 spec follows PR 3's exact code path — same calls, same
arguments, same order — so ``HierarchySpec.node()`` reproduces the
legacy ``hierarchy="node"`` results bit for bit (winners AND refine
trajectory; asserted in tests/test_hierarchy_spec.py).  Because every
task inherits its node's router coordinates, the level-1 refined score
equals the fine mapping's volume-weighted metrics exactly, whatever
the depth.
"""

from __future__ import annotations

import numpy as np

from repro import obs
from repro.core.machine import Allocation
from repro.core.mapping import MappingResult
from repro.core.orderings import order_points

from .aggregate import aggregate_tasks
from .refine import (assign_cores, hilbert_key, polish_groups, refine_qap,
                     refine_swaps)
from .spec import DEFAULT_GROUP_ARITY


def router_view(alloc: Allocation):
    """Collapse a core-granularity allocation to its routers.

    Returns ``(router_coords, core_router, router_alloc)``:

    router_coords : (r, nd_net) int coordinates of the distinct routers.
    core_router   : (ncores,) router id of every allocation core row.
    router_alloc  : an :class:`Allocation` with ONE row per router
                    (core dims zero-padded) — a drop-in for the machine
                    transforms and the candidate scorer.
    """
    machine = alloc.machine
    nd = machine.ndim - machine.core_dims
    rows = np.asarray(alloc.coords[:, :nd], dtype=np.int64)
    # flat router keys instead of np.unique(axis=0): one 1D unique pass
    # (the structured-dtype row compare is ~10x slower at 2^18 cores)
    rdims = machine.dims[:nd]
    keys = np.ravel_multi_index(tuple(rows.T), rdims)
    ukeys, core_router = np.unique(keys, return_inverse=True)
    router_coords = np.stack(np.unravel_index(ukeys, rdims), axis=1)
    core_router = core_router.reshape(-1)
    pad = np.zeros((len(router_coords), machine.core_dims), dtype=np.int64)
    router_alloc = Allocation(
        machine, np.concatenate([router_coords, pad], axis=1))
    return router_coords, core_router, router_alloc


def group_units(unit_coords: np.ndarray, ngroups: int, *,
                sfc: str = "FZ", longest_dim: bool = True,
                uneven_prime: bool = False,
                backend: str = "vectorized"):
    """Geometrically group machine units (routers, or groups thereof).

    One ``order_points`` pass over the unit coordinates — the same
    Multi-Jagged machinery that groups the task side — yields balanced
    group labels; each group is then REPRESENTED by its medoid: the
    member unit closest to the group centroid (squared Euclidean,
    lowest unit id on ties).  Medoids are real machine coordinates, so
    ``pairwise_hops`` / the rotation sweep / the swap refinement treat
    a group of nodes exactly like a single router.

    Returns ``(labels, rep_coords)`` — (n,) group id per unit and
    (ngroups, nd) integer medoid coordinates.
    """
    unit_coords = np.asarray(unit_coords)
    fc = unit_coords.astype(np.float64)
    labels = order_points(fc, int(ngroups), sfc,
                          longest_dim=longest_dim,
                          uneven_prime=uneven_prime, backend=backend)
    counts = np.maximum(np.bincount(labels, minlength=ngroups), 1)
    cents = np.stack([
        np.bincount(labels, weights=fc[:, j], minlength=ngroups) / counts
        for j in range(fc.shape[1])], axis=1)
    d2 = ((fc - cents[labels]) ** 2).sum(axis=1)
    # first-per-group of the (group, distance, id) stable order = medoid
    order = np.lexsort((np.arange(len(fc)), d2, labels))
    first = np.searchsorted(labels[order], np.arange(ngroups))
    return labels, unit_coords[order[first]]


def _refine_level(machine, coarse, unit_coords, assignment, lvl, *,
                  objective, score_backend):
    """Dispatch one level's refinement pass on its ``refine_mode``."""
    fn = refine_swaps if lvl.refine_mode == "swap" else refine_qap
    return fn(machine, coarse, unit_coords, assignment,
              objective=objective, rounds=lvl.refine_rounds,
              top=lvl.refine_top, degree=lvl.refine_degree,
              score_backend=score_backend)


def map_hierarchical(
    pipe,
    graph,
    alloc: Allocation,
    task_coords: np.ndarray | None = None,
    task_weights: np.ndarray | None = None,
) -> MappingResult:
    """Hierarchical coarsen* -> map -> refine/expand* for ``pipe``'s
    config (``pipe.config.hierarchy`` is a non-flat
    :class:`HierarchySpec`).

    ``pipe`` is the owning :class:`repro.mapping.MappingPipeline`; its
    config controls the partitioner/sweep/scoring stages exactly as in
    the flat path, plus the per-level arities and refinement budgets.
    Returns a core-level :class:`MappingResult` whose ``stats`` carry
    the schema-v2 per-level breakdown (``stats["levels"]``) plus the
    legacy flat keys, derived for one release.
    """
    from repro.mapping.candidates import rotation_candidates

    cfg = pipe.config
    spec = cfg.hierarchy
    levels = spec.levels  # fine -> coarse; T = len(levels) granularities
    T = len(levels)
    machine = alloc.machine
    tc = np.asarray(task_coords if task_coords is not None
                    else graph.coords, dtype=np.float64)
    tnum = len(tc)

    router_coords, core_router, router_alloc = router_view(alloc)
    nrouters = len(router_coords)
    cores_per_node = max(1, -(-alloc.n // nrouters))  # ceil: max cores/router

    # per-level unit/cluster counts (pure integer math, so the root
    # span can carry the top sweep size up front).  Level 1 clusters:
    # one per allocated node (fewer when the job has fewer tasks than
    # nodes; the coarse map then picks the closest unit subset exactly
    # like the flat tnum < pnum case).  Level j >= 2 groups the level
    # below by the level's arity, on both sides.
    arities = [levels[0].arity or cores_per_node]
    arities += [lv.arity or DEFAULT_GROUP_ARITY for lv in levels[1:]]
    unit_counts = [nrouters]
    cluster_counts = [min(nrouters, max(1, -(-tnum // arities[0])))]
    for a in arities[1:]:
        unit_counts.append(max(1, -(-unit_counts[-1] // a)))
        cluster_counts.append(
            min(unit_counts[-1], max(1, -(-cluster_counts[-1] // a))))

    timings = {"coarsen_s": 0.0, "refine_s": 0.0}
    level_stats = [
        {"level": i + 1, "name": levels[i].name,
         "points": int(cluster_counts[i] + unit_counts[i]),
         "clusters": int(cluster_counts[i]),
         "units": int(unit_counts[i]),
         "coarsen_s": 0.0, "map_s": 0.0, "refine_s": 0.0,
         "refine_accepted": 0, "refine_evaluated": 0}
        for i in range(T)]

    with obs.span("pipeline.map", hierarchy=spec.kind,
                  depth=int(spec.depth),
                  partition_backend=pipe.partition_backend,
                  score_backend=cfg.score_backend,
                  sweep_points=int(cluster_counts[-1] + unit_counts[-1])
                  ) as root:
        # -- stage 1: coarsen bottom-up, both sides ---------------------
        aggs = []      # aggs[i]: Aggregation at level i+1
        m_coords = []  # m_coords[i]: unit int coords at level i+1
        m_member = []  # m_member[i]: level-i unit -> level-(i+1) unit
        for i in range(T):
            fine_n = tnum if i == 0 else aggs[i - 1].nclusters
            with obs.span("pipeline.coarsen", level=i + 1,
                          points=int(fine_n),
                          nclusters=int(cluster_counts[i])) as sp:
                if i == 0:
                    aggs.append(aggregate_tasks(
                        graph, cluster_counts[0], task_coords=tc,
                        task_weights=task_weights,
                        sfc=cfg.sfc, longest_dim=cfg.longest_dim,
                        uneven_prime=cfg.uneven_prime,
                        backend=pipe.order_backend))
                    m_coords.append(router_coords)
                    m_member.append(core_router)
                else:
                    aggs.append(aggregate_tasks(
                        aggs[i - 1].coarse, cluster_counts[i],
                        task_weights=aggs[i - 1].weights,
                        sfc=cfg.sfc, longest_dim=cfg.longest_dim,
                        uneven_prime=cfg.uneven_prime,
                        backend=pipe.order_backend))
                    member, reps = group_units(
                        m_coords[i - 1], unit_counts[i], sfc=cfg.sfc,
                        longest_dim=cfg.longest_dim,
                        uneven_prime=cfg.uneven_prime,
                        backend=pipe.order_backend)
                    m_coords.append(reps)
                    m_member.append(member)
            timings["coarsen_s"] += sp.duration_s
            level_stats[i]["coarsen_s"] = sp.duration_s

        # -- stage 2: the UNCHANGED batched rotation sweep, at the TOP
        # granularity (depth 2: router granularity, exactly PR 3)
        top = T - 1
        if top == 0:
            top_alloc = router_alloc
        else:
            pad = np.zeros((len(m_coords[top]), machine.core_dims),
                           dtype=np.int64)
            top_alloc = Allocation(
                machine, np.concatenate([m_coords[top], pad], axis=1))
        pc = pipe.machine_coords(top_alloc)
        cands = rotation_candidates(aggs[top].coarse.coords.shape[1],
                                    pc.shape[1], cfg.rotations)
        root.annotate(candidates=len(cands))
        top_lvl = levels[top]
        coarse_best = None
        if pipe._fused is not None and top_lvl.refine_mode == "swap":
            # the refine spec folds the top level's swap-refinement
            # rounds into the SAME device program (sweep + refinement,
            # one compile); the refine span below then only unpacks
            # stats and expands downward
            with obs.span("pipeline.fused") as sp:
                coarse_best = pipe._fused.run(
                    aggs[top].coarse, top_alloc, aggs[top].coarse.coords,
                    pc, cands, task_weights=aggs[top].weights,
                    refine=dict(rounds=top_lvl.refine_rounds,
                                top=top_lvl.refine_top,
                                degree=top_lvl.refine_degree))
            if coarse_best is not None:
                timings["fused_s"] = sp.duration_s
                level_stats[top]["map_s"] = sp.duration_s
        if coarse_best is None:
            with obs.span("pipeline.partition",
                          points=int(cluster_counts[top]
                                     + unit_counts[top])) as sp:
                results = pipe.map_candidates(
                    aggs[top].coarse.coords, pc, cands,
                    task_weights=aggs[top].weights)
            timings["partition_s"] = sp.duration_s
            level_stats[top]["map_s"] = sp.duration_s
            with obs.span("pipeline.score",
                          candidates=len(cands)) as sp:
                if len(results) == 1:
                    coarse_best = results[0]
                else:
                    coarse_best, best_i, scores = pipe.search.best(
                        aggs[top].coarse, top_alloc, results)
                    coarse_best.score = float(scores[best_i][0])
            timings["score_s"] = sp.duration_s
            level_stats[top]["map_s"] += sp.duration_s

        # -- stage 3: refine + expand, top-down -------------------------
        # When the fused program already refined the top level on
        # device, its refine span only unpacks stats and expands — the
        # stats/timings schema is the same either way (refine_s always
        # present).
        fused_refined = (coarse_best is not None
                         and coarse_best.stats.get("fused_refine", False))
        cur = np.asarray(coarse_best.task_to_proc, dtype=np.int64)
        # the winning rotation of the top sweep, applied to BOTH sides
        # of every group-level expansion below: Alg. 1's consistent-
        # ordering requirement extends into the groups — matching
        # Hilbert curves drawn in UNROTATED task/machine axes would
        # misalign every group interior the sweep just aligned
        tperm, pperm = coarse_best.rotation
        tperm = np.asarray(tperm, dtype=np.int64) if len(tperm) else None
        pperm = np.asarray(pperm, dtype=np.int64) if len(pperm) else None
        rstats = None  # level-1 refinement stats (the legacy flat keys)
        for i in range(top, -1, -1):
            lvl = levels[i]
            with obs.span("pipeline.refine", level=i + 1,
                          rounds=int(lvl.refine_rounds),
                          mode=lvl.refine_mode,
                          fused=bool(fused_refined and i == top)) as sp:
                if fused_refined and i == top:
                    rstats_i = {k: coarse_best.stats[k] for k in (
                        "refine_rounds_run", "refine_accepted",
                        "refine_evaluated", "refine_history",
                        "refine_initial", "refine_final")}
                else:
                    cur, rstats_i = _refine_level(
                        machine, aggs[i].coarse, m_coords[i], cur, lvl,
                        objective=pipe.search.objective,
                        score_backend=cfg.score_backend)
                if i > 0:
                    # one-level expansion as a per-group GEOMETRIC
                    # match (paper Alg. 1's consistent-ordering trick):
                    # assign_cores deals Hilbert-ordered children onto
                    # member units in input order, so presenting each
                    # group's units in THEIR intra-group Hilbert order
                    # aligns both curves.  (The i == 0 core expansion
                    # keeps allocation order: cores of a node are hop-0,
                    # order cannot change a metric — and depth-2 stays
                    # bit-identical to the legacy path.)
                    child = aggs[i - 1].coarse.coords
                    if tperm is not None and child.shape[1] == len(tperm):
                        child = child[:, tperm]
                    units = m_coords[i - 1].astype(np.float64)
                    if pperm is not None and units.shape[1] == len(pperm):
                        units = units[:, pperm]
                    sub = np.lexsort((hilbert_key(units), m_member[i]))
                    cur = sub[assign_cores(
                        aggs[i].labels, cur, m_member[i][sub],
                        child, len(m_coords[i]))]
                else:
                    t2p = assign_cores(aggs[0].labels, cur, core_router,
                                       tc, nrouters)
            timings["refine_s"] += sp.duration_s
            level_stats[i]["refine_s"] = sp.duration_s
            level_stats[i]["refine_accepted"] = \
                rstats_i["refine_accepted"]
            level_stats[i]["refine_evaluated"] = \
                rstats_i["refine_evaluated"]
            level_stats[i]["refine_history"] = rstats_i["refine_history"]
            rstats = rstats_i
            if i > 0 and levels[i - 1].polish_rounds > 0:
                # intra-group polish of the freshly expanded level-i
                # assignment: the expansion above ordered each group's
                # members by geometry alone, blind to where their heavy
                # edges point; this repairs every group interior at
                # once with exact KL deltas BEFORE the level's own
                # bounded refinement (next loop iteration) spends its
                # budget on the residual
                with obs.span("pipeline.polish", level=i,
                              rounds=int(levels[i - 1].polish_rounds)
                              ) as psp:
                    cur, pstats = polish_groups(
                        machine, aggs[i - 1].coarse, m_coords[i - 1],
                        cur, m_member[i],
                        objective=pipe.search.objective,
                        rounds=levels[i - 1].polish_rounds,
                        score_backend=cfg.score_backend)
                timings["refine_s"] += psp.duration_s
                level_stats[i - 1]["polish_s"] = psp.duration_s
                for k in ("polish_rounds_run", "polish_accepted",
                          "polish_evaluated", "polish_initial",
                          "polish_final"):
                    level_stats[i - 1][k] = pstats[k]
    timings["total_s"] = root.duration_s

    stats = {
        # -- schema v2: the per-level breakdown -------------------------
        "schema": 2,
        "hierarchy": spec.kind,
        "depth": int(spec.depth),
        "levels": level_stats,
        # -- legacy keys, derived for one release (README schema doc) --
        "nclusters": int(cluster_counts[0]),
        "nrouters": int(nrouters),
        "cores_per_node": int(cores_per_node),
        "intra_volume": aggs[0].intra_volume,
        # points partitioned by ONE engine pass of the rotation sweep
        # (flat partitions tnum tasks + alloc.n cores instead)
        "sweep_points": int(cluster_counts[-1] + unit_counts[-1]),
        "flat_sweep_points": int(tnum + alloc.n),
        "coarsen_points": int(tnum),
        "partition_backend": pipe.partition_backend,
        "timings": timings,
        "trace_id": root.trace_id,
    }
    stats.update(rstats)
    if fused_refined:
        stats["fused_refine"] = True
        stats["fused_score_backend"] = \
            coarse_best.stats.get("fused_score_backend")
    return MappingResult(t2p, rotation=coarse_best.rotation,
                         score=float(rstats["refine_final"]), stats=stats)
