"""Two-level (coarsen -> map -> refine) driver (hierarchical stage 2).

``map_hierarchical`` runs the existing batched rotation-sweep pipeline
at *router* granularity: one point per allocated node instead of one
per core.  On a 16-core-per-node machine every engine pass therefore
partitions ~16x fewer points than the flat pipeline, while the mapping
quality is preserved — the paper's own machine transforms already give
all cores of a node identical (router) coordinates, so the flat
partitioner was spending its effort keeping points together that a
node-level map gets for free.

The flow (paper §2 node-granularity argument + the multilevel structure
of Schulz & Woydt's hierarchical process mapping):

1. :func:`repro.hier.aggregate.aggregate_tasks` contracts the task
   graph into one geometric cluster per allocated node;
2. the coarse problem runs through the UNCHANGED pipeline machinery —
   ``MappingPipeline.map_candidates`` batched rotation sweep over the
   cluster centroids and router coordinates, scored by the same
   :class:`repro.mapping.CandidateSearch`;
3. :func:`repro.hier.refine.refine_swaps` improves the winner with
   bounded greedy inter-node swaps (monotone), and
   :func:`repro.hier.refine.assign_cores` expands the node-level
   assignment to cores in intra-node SFC order.

Because every task inherits its node's router coordinates, the coarse
graph's volume-weighted metrics equal the fine mapping's exactly
(``weighted_hops``, ``latency_max``, ``data_max``); see
tests/test_hier.py for the asserted identity.
"""

from __future__ import annotations

import numpy as np

from repro import obs
from repro.core.machine import Allocation
from repro.core.mapping import MappingResult

from .aggregate import aggregate_tasks
from .refine import assign_cores, refine_swaps


def router_view(alloc: Allocation):
    """Collapse a core-granularity allocation to its routers.

    Returns ``(router_coords, core_router, router_alloc)``:

    router_coords : (r, nd_net) int coordinates of the distinct routers.
    core_router   : (ncores,) router id of every allocation core row.
    router_alloc  : an :class:`Allocation` with ONE row per router
                    (core dims zero-padded) — a drop-in for the machine
                    transforms and the candidate scorer.
    """
    machine = alloc.machine
    nd = machine.ndim - machine.core_dims
    rows = np.asarray(alloc.coords[:, :nd], dtype=np.int64)
    # flat router keys instead of np.unique(axis=0): one 1D unique pass
    # (the structured-dtype row compare is ~10x slower at 2^18 cores)
    rdims = machine.dims[:nd]
    keys = np.ravel_multi_index(tuple(rows.T), rdims)
    ukeys, core_router = np.unique(keys, return_inverse=True)
    router_coords = np.stack(np.unravel_index(ukeys, rdims), axis=1)
    core_router = core_router.reshape(-1)
    pad = np.zeros((len(router_coords), machine.core_dims), dtype=np.int64)
    router_alloc = Allocation(
        machine, np.concatenate([router_coords, pad], axis=1))
    return router_coords, core_router, router_alloc


def map_hierarchical(
    pipe,
    graph,
    alloc: Allocation,
    task_coords: np.ndarray | None = None,
    task_weights: np.ndarray | None = None,
) -> MappingResult:
    """Hierarchical coarsen -> map -> refine for ``pipe``'s config.

    ``pipe`` is the owning :class:`repro.mapping.MappingPipeline`; its
    config controls the partitioner/sweep/scoring stages exactly as in
    the flat path, plus the ``refine_*`` knobs.  Returns a core-level
    :class:`MappingResult` whose ``stats`` record the engine-pass point
    counts (the ~cores_per_node x reduction the ``hier`` benchmark
    asserts) and the refinement trajectory.
    """
    from repro.mapping.candidates import rotation_candidates

    cfg = pipe.config
    machine = alloc.machine
    tc = np.asarray(task_coords if task_coords is not None
                    else graph.coords, dtype=np.float64)
    tnum = len(tc)

    router_coords, core_router, router_alloc = router_view(alloc)
    nrouters = len(router_coords)
    cores_per_node = max(1, -(-alloc.n // nrouters))  # ceil: max cores/router

    # one geometric cluster per allocated node (fewer when the job has
    # fewer tasks than nodes; the coarse map then picks the closest
    # router subset exactly like the flat tnum < pnum case)
    nclusters = min(nrouters, max(1, -(-tnum // cores_per_node)))
    # span-derived stage timings (repro.obs): same schema as before —
    # coarsen_s + {fused_s | partition_s + score_s} + refine_s + total_s
    timings = {}
    with obs.span("pipeline.map", hierarchy="node",
                  partition_backend=pipe.partition_backend,
                  score_backend=cfg.score_backend,
                  sweep_points=int(nclusters + nrouters)) as root:
        with obs.span("pipeline.coarsen", points=int(tnum),
                      nclusters=int(nclusters)) as sp:
            agg = aggregate_tasks(
                graph, nclusters, task_coords=tc,
                task_weights=task_weights,
                sfc=cfg.sfc, longest_dim=cfg.longest_dim,
                uneven_prime=cfg.uneven_prime,
                backend=pipe.order_backend)
        timings["coarsen_s"] = sp.duration_s

        # stage 2: the UNCHANGED batched rotation sweep, at router
        # granularity
        pc = pipe.machine_coords(router_alloc)
        cands = rotation_candidates(agg.coarse.coords.shape[1],
                                    pc.shape[1], cfg.rotations)
        root.annotate(candidates=len(cands))
        coarse_best = None
        if pipe._fused is not None:
            # the refine spec folds the swap-refinement rounds into the
            # SAME device program (coarse sweep + refinement, one
            # compile); the refine_s span below then only times the
            # stats unpack + core expansion
            with obs.span("pipeline.fused") as sp:
                coarse_best = pipe._fused.run(
                    agg.coarse, router_alloc, agg.coarse.coords, pc,
                    cands, task_weights=agg.weights,
                    refine=dict(rounds=cfg.refine_rounds,
                                top=cfg.refine_top,
                                degree=cfg.refine_degree))
            if coarse_best is not None:
                timings["fused_s"] = sp.duration_s
        if coarse_best is None:
            with obs.span("pipeline.partition",
                          points=int(nclusters + nrouters)) as sp:
                results = pipe.map_candidates(
                    agg.coarse.coords, pc, cands,
                    task_weights=agg.weights)
            timings["partition_s"] = sp.duration_s
            with obs.span("pipeline.score",
                          candidates=len(cands)) as sp:
                if len(results) == 1:
                    coarse_best = results[0]
                else:
                    coarse_best, best_i, scores = pipe.search.best(
                        agg.coarse, router_alloc, results)
                    coarse_best.score = float(scores[best_i][0])
            timings["score_s"] = sp.duration_s

        # stage 3: bounded greedy inter-node swaps (monotone), expand.
        # When the fused program already refined on device, this span
        # only unpacks its stats and expands to cores — same
        # stats/timings schema either way (refine_s always present).
        fused_refined = (coarse_best is not None
                         and coarse_best.stats.get("fused_refine", False))
        with obs.span("pipeline.refine", rounds=int(cfg.refine_rounds),
                      fused=bool(fused_refined)) as sp:
            if fused_refined:
                c2r = np.asarray(coarse_best.task_to_proc,
                                 dtype=np.int64)
                rstats = {k: coarse_best.stats[k] for k in (
                    "refine_rounds_run", "refine_accepted",
                    "refine_evaluated", "refine_history",
                    "refine_initial", "refine_final")}
            else:
                c2r, rstats = refine_swaps(
                    machine, agg.coarse, router_coords,
                    coarse_best.task_to_proc,
                    objective=pipe.search.objective,
                    rounds=cfg.refine_rounds, top=cfg.refine_top,
                    degree=cfg.refine_degree,
                    score_backend=cfg.score_backend)
            t2p = assign_cores(agg.labels, c2r, core_router, tc,
                               nrouters)
        timings["refine_s"] = sp.duration_s
    timings["total_s"] = root.duration_s

    stats = {
        "hierarchy": "node",
        "nclusters": int(nclusters),
        "nrouters": int(nrouters),
        "cores_per_node": int(cores_per_node),
        "intra_volume": agg.intra_volume,
        # points partitioned by ONE engine pass of the rotation sweep
        # (flat partitions tnum tasks + alloc.n cores instead)
        "sweep_points": int(nclusters + nrouters),
        "flat_sweep_points": int(tnum + alloc.n),
        "coarsen_points": int(tnum),
        "partition_backend": pipe.partition_backend,
        "timings": timings,
        "trace_id": root.trace_id,
    }
    stats.update(rstats)
    if fused_refined:
        stats["fused_refine"] = True
        stats["fused_score_backend"] = \
            coarse_best.stats.get("fused_score_backend")
    return MappingResult(t2p, rotation=coarse_best.rotation,
                         score=float(rstats["refine_final"]), stats=stats)
