"""Geometric task-side coarsening (hierarchical mapping stage 1).

The paper treats intra-node communication as free (§2): a multicore
node's cores all carry the ROUTER's coordinates, so the mapping problem
is really a *node*-granularity problem.  This module contracts the task
graph the same way the machine side already is: tasks are clustered
geometrically into node-sized groups with the Multi-Jagged partitioner
(the SAME level-synchronous engine that cuts the fine problem), and the
communication structure is contracted onto the clusters.

Everything is built with the vectorised segment idioms of
``core/partition.py`` / ``core/metrics.py`` — one partitioner call for
the cluster labels, ``np.bincount`` segment sums for the weighted
centroids, cluster weights and contracted edge volumes — so coarsening
a 2^20-task graph costs one engine pass plus a few O(n + E) passes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.orderings import order_points
from repro.core.taskgraph import TaskGraph


@dataclasses.dataclass
class Aggregation:
    """A node-granularity contraction of a task graph.

    coarse      : TaskGraph of the clusters — weighted centroids as
                  coordinates, contracted inter-cluster edges carrying
                  the SUMMED message volumes of their fine edges.
    labels      : (n,) int64 cluster id per fine task, in SFC part-number
                  order (cluster ids are the partitioner's part numbers,
                  so consecutive ids are geometric neighbours).
    sizes       : (nclusters,) fine-task count per cluster.
    weights     : (nclusters,) summed fine-task weight per cluster (task
                  counts when the fine graph is unweighted).
    intra_volume: total message volume of edges internal to a cluster —
                  the traffic the two-level map renders free (both
                  endpoints land on one node).
    """

    coarse: TaskGraph
    labels: np.ndarray
    sizes: np.ndarray
    weights: np.ndarray
    intra_volume: float

    @property
    def nclusters(self) -> int:
        return len(self.sizes)


def aggregate_tasks(
    graph: TaskGraph,
    nclusters: int,
    *,
    task_coords: np.ndarray | None = None,
    task_weights: np.ndarray | None = None,
    sfc: str = "FZ",
    longest_dim: bool = True,
    uneven_prime: bool = False,
    backend: str = "vectorized",
) -> Aggregation:
    """Contract ``graph`` into ``nclusters`` geometric clusters.

    The cluster labels come from ONE ``order_points`` call over the fine
    task coordinates — the identical Algorithm-2 machinery the flat
    pipeline uses, just stopped at ``nclusters`` parts instead of one
    part per core.  Unit-weight tasks therefore land in clusters of
    ``floor/ceil(n / nclusters)`` members (the partitioner's balanced
    cuts), i.e. node-sized groups when ``nclusters = n / cores_per_node``.

    Centroids, cluster weights and the contracted edge list are segment
    sums (``np.bincount``) keyed by the labels; parallel inter-cluster
    edges collapse to one edge with summed volume, intra-cluster edges
    are dropped from the coarse graph and accounted in ``intra_volume``.
    """
    tc = np.asarray(task_coords if task_coords is not None
                    else graph.coords, dtype=np.float64)
    n, d = tc.shape
    nclusters = int(nclusters)
    if not 1 <= nclusters <= n:
        raise ValueError(f"nclusters={nclusters} outside [1, {n}]")
    w = None if task_weights is None else \
        np.asarray(task_weights, dtype=np.float64)

    labels = order_points(tc, nclusters, sfc, weights=w,
                          longest_dim=longest_dim,
                          uneven_prime=uneven_prime, backend=backend)

    sizes = np.bincount(labels, minlength=nclusters)
    wv = np.ones(n) if w is None else w
    cw = np.bincount(labels, weights=wv, minlength=nclusters)
    # weighted centroids: one segment sum per coordinate column
    denom = np.where(cw > 0, cw, 1.0)
    cents = np.stack([
        np.bincount(labels, weights=tc[:, j] * wv, minlength=nclusters)
        / denom for j in range(d)], axis=1)

    # contract the edge list: label endpoints, split intra/inter, then
    # sum parallel inter-cluster volumes with one flat bincount over the
    # pair key (same segment-sum idiom as the router's range-adds)
    ce = labels[graph.edges]
    ew = np.asarray(graph.weights, dtype=np.float64)
    intra = ce[:, 0] == ce[:, 1]
    intra_volume = float(ew[intra].sum())
    inter = ce[~intra]
    if len(inter):
        key = inter[:, 0] * nclusters + inter[:, 1]
        uniq, inv = np.unique(key, return_inverse=True)
        vol = np.bincount(inv, weights=ew[~intra], minlength=len(uniq))
        coarse_edges = np.stack([uniq // nclusters, uniq % nclusters],
                                axis=1)
    else:
        coarse_edges = np.zeros((0, 2), dtype=np.int64)
        vol = np.zeros(0)

    coarse = TaskGraph(cents, coarse_edges, vol,
                       meta={"kind": "aggregated",
                             "fine_n": n,
                             "fine_edges": len(graph.edges),
                             "intra_volume": intra_volume,
                             "parent_meta": dict(graph.meta)})
    return Aggregation(coarse, labels, sizes, cw, intra_volume)
