"""Structured description of the mapping hierarchy (ISSUE 10).

A :class:`HierarchySpec` replaces the old ``hierarchy="flat"|"node"``
string plus flat ``refine_rounds/top/degree`` knobs of
``PipelineConfig``/``MapperConfig``: it is an ordered tuple of
:class:`Level` coarsening steps (fine -> coarse), each carrying its own
group arity and refinement budget, so the N-level recursive hierarchy
of Schulz & Woydt's shared-memory process mapping — pod -> rack ->
node -> socket -> core — becomes a first-class, content-addressable
config value.

Depth terminology (``spec.depth == 1 + len(spec.levels)``):

- depth 1 (``levels == ()``)  : the classic FLAT pipeline — one point
  per core, no coarsening.
- depth 2 (one level)         : PR 3's node-granularity map — coarsen
  tasks to node-sized clusters, sweep at router granularity, refine,
  expand.  ``HierarchySpec.node()`` reproduces it bit for bit.
- depth >= 3                  : additional geometric grouping levels
  above the node level; each level divides the top-sweep point count
  by its ``arity`` and gets its own refinement pass on the way down.

The legacy strings keep working as deprecated aliases through
:meth:`HierarchySpec.from_string` (``PipelineConfig`` calls it when
handed a string); :meth:`HierarchySpec.from_machine` derives the node
arity from a :class:`repro.core.Machine`'s core dims.  Specs are frozen
dataclasses, so :func:`repro.core.signature.config_signature`
canonicalises them field by field — two equal specs built through
different constructors produce the SAME cache key (asserted in
tests/test_hierarchy_spec.py).
"""

from __future__ import annotations

import dataclasses
import re

__all__ = ["Level", "HierarchySpec", "HIERARCHY_ALIASES",
           "normalize_config_hierarchy"]

# arity of grouping levels ABOVE the node level when not given
# explicitly ("depth3"/"depth4" aliases, from_machine defaults).  4
# units per group still cuts the top sweep 4x per level, and smaller
# groups keep the medoid abstraction honest: the expansion error the
# intra-group polish must repair grows with group radius, and at arity
# 4 the polished depth-3 map lands within ~4% of depth-2 quality on
# the hier benchmark (arity 8 leaves ~6%, arity 16 ~13%).
DEFAULT_GROUP_ARITY = 4

# refinement-budget defaults — EXACTLY the old PipelineConfig
# refine_rounds/refine_top/refine_degree defaults, so
# ``HierarchySpec.node()`` is bit-identical to the legacy
# ``hierarchy="node"`` path.
DEFAULT_REFINE_ROUNDS = 2
DEFAULT_REFINE_TOP = 64
DEFAULT_REFINE_DEGREE = 4

# grouping-level default for the sparse-QAP pass of deep hierarchies:
# one round.  The intra-group polish below does the bulk repair after
# every group expansion, so the QAP search only needs to catch the
# cross-group moves the polish cannot make.
DEFAULT_QAP_ROUNDS = 1

# rounds of the intra-group polish pass run when a GROUP-level
# assignment is expanded one level down (depth >= 3 only; a depth-2
# spec has no group expansion, so the legacy path never sees it).  The
# per-round gain decays geometrically; 4 rounds capture ~90% of the
# converged improvement at a fraction of the cost.
DEFAULT_POLISH_ROUNDS = 4

HIERARCHY_ALIASES = ("flat", "node", "depth<N>")


@dataclasses.dataclass(frozen=True)
class Level:
    """One coarsening step of the hierarchy (fine -> coarse).

    name          : label of the units this level groups INTO ("node",
                    "rack", "pod", ...) — report/span attribution only.
    arity         : children per group.  ``None`` derives it at map
                    time: the first level takes the machine's
                    cores-per-node (the paper's node granularity),
                    deeper levels take :data:`DEFAULT_GROUP_ARITY`.
    refine_rounds / refine_top / refine_degree :
                    budget of the swap-refinement pass run at THIS
                    level's granularity (rounds, hottest clusters per
                    round, nearest units proposed per cluster) — the
                    same bounds the old flat knobs applied to the
                    single node level.
    refine_mode   : "swap" — the bounded greedy network-nearest swap
                    pass (PR 3, fused-foldable, the bit-identity
                    baseline); "qap" — the sparse-QAP local search
                    (:func:`repro.hier.refine.refine_qap`): per-cluster
                    best-single-move + pairwise-swap neighbourhoods
                    over the sparse inter-cluster edge set, gain-bucket
                    ordered, monotone with full re-score verify.
    polish_rounds : rounds of the intra-group polish pass
                    (:func:`repro.hier.refine.polish_groups`) run when
                    THIS level's assignment is produced by expanding a
                    group level above it — exact KL-style swap deltas
                    inside every group at once, repairing the member
                    placements the medoid abstraction could not see.
                    Only reachable at depth >= 3 (depth 2 has no group
                    expansion), so it never perturbs the legacy path.
    """

    name: str = "node"
    arity: int | None = None
    refine_rounds: int = DEFAULT_REFINE_ROUNDS
    refine_top: int = DEFAULT_REFINE_TOP
    refine_degree: int = DEFAULT_REFINE_DEGREE
    refine_mode: str = "swap"
    polish_rounds: int = DEFAULT_POLISH_ROUNDS

    def __post_init__(self):
        if self.arity is not None and int(self.arity) < 2:
            raise ValueError(
                f"level {self.name!r}: arity must be >= 2 or None "
                f"(got {self.arity!r})")
        if self.refine_mode not in ("swap", "qap"):
            raise ValueError(
                f"level {self.name!r}: unknown refine_mode "
                f"{self.refine_mode!r}; accepted: 'swap', 'qap'")
        for f in ("refine_rounds", "refine_top", "refine_degree",
                  "polish_rounds"):
            if int(getattr(self, f)) < 0:
                raise ValueError(
                    f"level {self.name!r}: {f} must be >= 0")


_DEPTH_RE = re.compile(r"^depth([0-9]+)$")

# default names for derived grouping levels, innermost first (level 1
# is always the node level; deeper levels walk outward)
_LEVEL_NAMES = ("node", "socket", "rack", "pod")


def _level_name(i: int) -> str:
    return _LEVEL_NAMES[i] if i < len(_LEVEL_NAMES) else f"level{i + 1}"


def _int_prod(seq) -> int:
    out = 1
    for x in seq:
        out *= int(x)
    return out


@dataclasses.dataclass(frozen=True)
class HierarchySpec:
    """Ordered coarsening levels of the mapping hierarchy.

    ``levels`` runs fine -> coarse: ``levels[0]`` groups tasks/cores
    into nodes, ``levels[1]`` groups nodes, and so on.  An empty tuple
    is the flat pipeline.  Instances are frozen and hashable — they are
    config values and cache-key material.
    """

    levels: tuple = ()

    def __post_init__(self):
        levels = tuple(self.levels)
        if not all(isinstance(lv, Level) for lv in levels):
            raise TypeError("HierarchySpec.levels must contain Level "
                            "instances")
        object.__setattr__(self, "levels", levels)

    # -- identity -------------------------------------------------------

    @property
    def depth(self) -> int:
        """Number of granularities mapped (1 = flat, 2 = node, ...)."""
        return 1 + len(self.levels)

    @property
    def kind(self) -> str:
        """Short label for spans/stats: "flat", "node" or "depthN"."""
        if not self.levels:
            return "flat"
        if len(self.levels) == 1:
            return "node"
        return f"depth{self.depth}"

    @property
    def is_flat(self) -> bool:
        return not self.levels

    # -- constructors ---------------------------------------------------

    @classmethod
    def flat(cls) -> "HierarchySpec":
        """The classic flat pipeline (depth 1, no coarsening)."""
        return cls(())

    @classmethod
    def node(cls, *, refine_rounds: int = DEFAULT_REFINE_ROUNDS,
             refine_top: int = DEFAULT_REFINE_TOP,
             refine_degree: int = DEFAULT_REFINE_DEGREE,
             refine_mode: str = "swap") -> "HierarchySpec":
        """PR 3's two-level node-granularity hierarchy (depth 2), with
        the node arity derived from the machine at map time.  Default
        budgets reproduce the legacy ``hierarchy="node"`` path bit for
        bit."""
        return cls((Level("node", None, refine_rounds, refine_top,
                          refine_degree, refine_mode),))

    @classmethod
    def with_depth(cls, n: int, *,
                   group_arity: int = DEFAULT_GROUP_ARITY,
                   refine_rounds: int | None = None,
                   refine_top: int = DEFAULT_REFINE_TOP,
                   refine_degree: int = DEFAULT_REFINE_DEGREE,
                   polish_rounds: int = DEFAULT_POLISH_ROUNDS
                   ) -> "HierarchySpec":
        """An ``n``-granularity hierarchy: the node level plus ``n - 2``
        grouping levels of ``group_arity`` units per group.  Grouping
        levels (depth >= 3) refine with the sparse-QAP local search;
        the node level keeps the fused-foldable swap pass.

        ``refine_rounds=None`` picks per-level defaults: at depth 2 the
        node level keeps :data:`DEFAULT_REFINE_ROUNDS` (bit-identical
        to :meth:`node`); at depth >= 3 the node level's bounded swap
        pass is OFF (``polish_rounds`` of intra-group polish at every
        group expansion supersede it — measured on the hier benchmark,
        two swap rounds after the polish buy < 0.1% quality for ~40%
        of depth-2's whole runtime) and grouping levels run
        :data:`DEFAULT_QAP_ROUNDS` of the QAP search.  An explicit
        ``refine_rounds`` applies to every level (the landing pad for
        the deprecated flat ``refine_rounds=`` kwarg)."""
        if n < 1:
            raise ValueError(f"depth must be >= 1, got {n}")
        if n == 1:
            return cls.flat()
        node_rounds = refine_rounds if refine_rounds is not None else (
            DEFAULT_REFINE_ROUNDS if n == 2 else 0)
        qap_rounds = (refine_rounds if refine_rounds is not None
                      else DEFAULT_QAP_ROUNDS)
        kw = dict(refine_top=refine_top, refine_degree=refine_degree,
                  polish_rounds=polish_rounds)
        levels = [Level("node", None, refine_mode="swap",
                        refine_rounds=node_rounds, **kw)]
        levels += [Level(_level_name(i), group_arity,
                         refine_mode="qap", refine_rounds=qap_rounds,
                         **kw)
                   for i in range(1, n - 1)]
        return cls(tuple(levels))

    @classmethod
    def from_string(cls, name: str, *,
                    refine_rounds: int | None = None,
                    refine_top: int | None = None,
                    refine_degree: int | None = None) -> "HierarchySpec":
        """Parse a hierarchy alias: ``"flat"``, ``"node"`` or
        ``"depthN"`` (N >= 1).  The optional refine knobs override every
        level's budget — this is the landing pad for the deprecated
        flat ``refine_*`` config fields."""
        kw = {k: int(v) for k, v in (("refine_rounds", refine_rounds),
                                     ("refine_top", refine_top),
                                     ("refine_degree", refine_degree))
              if v is not None}
        if name == "flat":
            return cls.flat()
        if name == "node":
            return cls.node(**kw)
        m = _DEPTH_RE.match(name)
        if m:
            return cls.with_depth(int(m.group(1)), **kw)
        raise ValueError(
            f"unknown hierarchy {name!r}; accepted: "
            f"{', '.join(repr(a) for a in HIERARCHY_ALIASES)} "
            f"or a HierarchySpec")

    @classmethod
    def from_machine(cls, machine, depth: int = 2, *,
                     group_arity: int = DEFAULT_GROUP_ARITY,
                     refine_rounds: int = DEFAULT_REFINE_ROUNDS,
                     refine_top: int = DEFAULT_REFINE_TOP,
                     refine_degree: int = DEFAULT_REFINE_DEGREE
                     ) -> "HierarchySpec":
        """Derive a spec from a :class:`repro.core.Machine`: the node
        level's arity is the machine's cores-per-node (product of its
        core dims), deeper levels use ``group_arity``.  Machines
        without core dims keep ``arity=None`` (one cluster per router,
        like the legacy path)."""
        spec = cls.with_depth(depth, group_arity=group_arity,
                              refine_rounds=refine_rounds,
                              refine_top=refine_top,
                              refine_degree=refine_degree)
        if not spec.levels or not machine.core_dims:
            return spec
        nd = machine.ndim - machine.core_dims
        cores = _int_prod(machine.dims[nd:])
        if cores >= 2:
            levels = (dataclasses.replace(spec.levels[0], arity=cores),
                      ) + spec.levels[1:]
            spec = cls(levels)
        return spec

    # -- derived specs (resilience ladder & shims) ----------------------

    def truncated(self, depth: int) -> "HierarchySpec":
        """The same spec cut down to ``depth`` granularities (the
        resilience ladder's depth-degradation rung)."""
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        return HierarchySpec(self.levels[: depth - 1])

    def with_refine(self, *, rounds: int | None = None,
                    top: int | None = None,
                    degree: int | None = None,
                    polish: int | None = None) -> "HierarchySpec":
        """Every level's refinement budget overridden (``refine_0`` on
        the degradation ladder uses ``rounds=0, polish=0``)."""
        changes = {k: v for k, v in (("refine_rounds", rounds),
                                     ("refine_top", top),
                                     ("refine_degree", degree),
                                     ("polish_rounds", polish))
                   if v is not None}
        return HierarchySpec(tuple(dataclasses.replace(lv, **changes)
                                   for lv in self.levels))

    @property
    def refine_rounds_total(self) -> int:
        return sum(lv.refine_rounds for lv in self.levels)

    @property
    def polish_rounds_total(self) -> int:
        """Total polish budget — nonzero only matters at depth >= 3."""
        return sum(lv.polish_rounds for lv in self.levels)


# -- the config-side deprecation shim ------------------------------------

_LEGACY_REFINE_FIELDS = ("refine_rounds", "refine_top", "refine_degree")


def normalize_config_hierarchy(config) -> None:
    """Shared ``__post_init__`` body of ``PipelineConfig`` and
    ``MapperConfig``: normalise ``config.hierarchy`` to a
    :class:`HierarchySpec` and fold the deprecated flat ``refine_*``
    knobs into it.

    Validation happens HERE — at config construction — so a bad
    hierarchy raises a 4xx-style ``ValueError`` listing the accepted
    values before any mapping work starts (in particular before the
    serve layer admits the request or burns a degradation-ladder
    rung).  The deprecation shim emits a single
    :class:`DeprecationWarning` per construction naming the
    replacement; re-normalising an already-normalised config
    (``dataclasses.replace`` re-runs this) is a silent no-op.
    """
    import warnings

    legacy = {k: getattr(config, k) for k in _LEGACY_REFINE_FIELDS
              if getattr(config, k) is not None}
    h = config.hierarchy
    if isinstance(h, str):
        spec = HierarchySpec.from_string(h, **legacy)  # raises on junk
        if h != "flat" or legacy:
            warnings.warn(
                f"hierarchy={h!r}"
                + (f" with {'/'.join(sorted(legacy))}=" if legacy else "")
                + " is deprecated; pass hierarchy=HierarchySpec"
                ".from_string(...) (or .node()/.with_depth()/"
                ".from_machine()) with per-level refine budgets instead",
                DeprecationWarning, stacklevel=4)
    elif isinstance(h, HierarchySpec):
        spec = h
        if legacy:
            warnings.warn(
                f"the flat {'/'.join(sorted(legacy))} config fields are "
                "deprecated; set the refine budgets on the "
                "HierarchySpec levels instead",
                DeprecationWarning, stacklevel=4)
            spec = spec.with_refine(
                rounds=legacy.get("refine_rounds"),
                top=legacy.get("refine_top"),
                degree=legacy.get("refine_degree"))
    else:
        raise ValueError(
            f"unknown hierarchy {h!r}; accepted: "
            f"{', '.join(repr(a) for a in HIERARCHY_ALIASES)} "
            f"or a HierarchySpec")
    config.hierarchy = spec
    for k in _LEGACY_REFINE_FIELDS:  # folded into the spec above
        setattr(config, k, None)
