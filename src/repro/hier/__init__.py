"""repro.hier — hierarchical (node-level) mapping subsystem.

The paper maps multicore machines at *node* granularity: intra-node
communication is free (§2), so partitioning one point per core only
multiplies the partitioner's work by cores_per_node without improving
the mapping.  This package reproduces that optimisation as a
coarsen -> map -> refine stack over the unified mapping pipeline:

1. :mod:`repro.hier.aggregate` — contract the task graph into
   node-sized geometric clusters (weighted centroids + summed message
   volumes), with the same vectorised segment idioms as the
   partitioning engine;
2. :mod:`repro.hier.levels` — run the existing batched rotation-sweep
   pipeline at router granularity (one point per allocated node);
3. :mod:`repro.hier.refine` — expand clusters onto cores in intra-node
   SFC order and improve the node assignment with a bounded, monotone
   greedy swap pass scored through batched ``evaluate_candidates``.

Select it with ``PipelineConfig(hierarchy="node")`` (or
``MapperConfig(hierarchy="node")`` / ``select_mapping(...,
hierarchy="node")``); ``hierarchy="flat"`` keeps the classic one-point-
per-core path.  The ``hier`` benchmark entry compares the two.
"""

from .aggregate import Aggregation, aggregate_tasks
from .levels import map_hierarchical, router_view
from .refine import assign_cores, hilbert_key, refine_swaps

__all__ = [
    "Aggregation", "aggregate_tasks", "assign_cores", "hilbert_key",
    "map_hierarchical", "refine_swaps", "router_view",
]
