"""repro.hier — the recursive hierarchical mapping subsystem.

The paper maps multicore machines at *node* granularity: intra-node
communication is free (§2), so partitioning one point per core only
multiplies the partitioner's work by cores_per_node without improving
the mapping.  This package generalises that optimisation to an N-level
recursive coarsen* -> map -> refine/expand* stack over the unified
mapping pipeline (the multilevel structure of Schulz & Woydt's
hierarchical process mapping):

1. :mod:`repro.hier.spec` — :class:`HierarchySpec`, the structured
   description of the hierarchy: ordered :class:`Level` entries
   (name, arity, per-level refinement budgets and mode), with
   ``flat()`` / ``node()`` / ``with_depth(n)`` / ``from_machine()`` /
   ``from_string()`` constructors;
2. :mod:`repro.hier.aggregate` — contract the task graph one level at
   a time into geometric clusters (weighted centroids + summed message
   volumes), with the same vectorised segment idioms as the
   partitioning engine;
3. :mod:`repro.hier.levels` — group the machine side to match
   (routers, then medoid-represented groups of routers), run the
   existing batched rotation-sweep pipeline ONCE at the top
   granularity, and expand downward level by level;
4. :mod:`repro.hier.refine` — per-level refinement (``refine_swaps``:
   the bounded monotone greedy pass, fused-foldable on device;
   ``refine_qap``: sparse-QAP local search with gain-bucket ordering),
   ``assign_cores`` expansion in intra-group SFC order, and
   ``polish_groups``: the exact-delta intra-group polish every group
   expansion runs before the level's bounded refinement.

Select it with ``PipelineConfig(hierarchy=HierarchySpec.node())`` /
``.with_depth(3)`` / ``.from_machine(machine, depth)`` (or the same
kwarg on ``MapperConfig`` / ``select_mapping``); the strings ``"flat"``
/ ``"node"`` remain as deprecated aliases and ``"depth<N>"`` as sugar.
The ``hier`` benchmark entry compares flat, depth-2 and depth-3.
"""

from .aggregate import Aggregation, aggregate_tasks
from .levels import group_units, map_hierarchical, router_view
from .refine import (assign_cores, hilbert_key, polish_groups, refine_qap,
                     refine_swaps)
from .spec import HierarchySpec, Level, normalize_config_hierarchy

__all__ = [
    "Aggregation", "HierarchySpec", "Level", "aggregate_tasks",
    "assign_cores", "group_units", "hilbert_key", "map_hierarchical",
    "normalize_config_hierarchy", "polish_groups", "refine_qap",
    "refine_swaps", "router_view",
]
