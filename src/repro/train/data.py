"""Deterministic synthetic LM data pipeline.

Properties a real pipeline needs and this one has:

- *Deterministic & resumable*: batch(step) is a pure function of
  (seed, step), so restart-from-checkpoint replays the exact stream with
  no state files.
- *Host-shardable*: each host materialises only its slice
  (make_global_array builds a jax.Array from per-shard callbacks).
- *Learnable structure*: tokens follow a noisy affine bigram process so
  small models show real loss decrease in examples/tests (pure uniform
  noise would pin loss at log V).
- *Prefetch*: a background thread keeps ``prefetch`` batches ready.
"""

from __future__ import annotations

import queue
import threading

import jax
import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, noise: float = 0.1):
        self.vocab = int(vocab_size)
        self.seq = int(seq_len)
        self.batch = int(global_batch)
        self.seed = seed
        self.noise = noise
        # fixed random affine bigram map
        rng = np.random.default_rng(seed)
        self.a = int(rng.integers(1, vocab_size - 1) | 1)
        self.b = int(rng.integers(0, vocab_size))

    def np_batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        b, s, v = self.batch, self.seq, self.vocab
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, size=b)
        noise_mask = rng.random((b, s)) < self.noise
        noise_tok = rng.integers(0, v, size=(b, s))
        for t in range(s):
            nxt = (self.a * toks[:, t] + self.b) % v
            toks[:, t + 1] = np.where(noise_mask[:, t], noise_tok[:, t], nxt)
        return {
            "tokens": toks[:, :-1],
            "labels": toks[:, 1:].astype(np.int32),
            "mask": np.ones((b, s), np.float32),
        }

    def batch_specs(self) -> dict:
        b, s = self.batch, self.seq
        return {
            "tokens": jax.ShapeDtypeStruct((b, s), np.int32),
            "labels": jax.ShapeDtypeStruct((b, s), np.int32),
            "mask": jax.ShapeDtypeStruct((b, s), np.float32),
        }


def make_global_array(np_arr: np.ndarray, sharding) -> jax.Array:
    """Build a (possibly multi-host) jax.Array from a numpy batch."""
    return jax.make_array_from_callback(
        np_arr.shape, sharding, lambda idx: np_arr[idx])


class Prefetcher:
    """Background-thread prefetch of pipeline batches."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.step = start_step
        self._stop = threading.Event()
        self.t = threading.Thread(target=self._run, daemon=True)
        self.t.start()

    def _run(self):
        s = self.step
        while not self._stop.is_set():
            batch = self.source.np_batch(s)
            while not self._stop.is_set():
                try:
                    self.q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self.t.join(timeout=2)
