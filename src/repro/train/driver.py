"""Training driver: checkpoint/restart, straggler monitoring, elasticity.

The loop is written so that *any* crash (or simulated failure) resumes
bit-identically: data is a pure function of the step index, checkpoints
are atomic, and the optimizer state rides along.  Restoring onto a
different mesh re-shards automatically (see checkpoint.restore).
"""

from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.models import ModelConfig, params_spec, tree_init
from repro.sharding.rules import DEFAULT_RULES

from . import checkpoint as ckpt
from .data import SyntheticLM, make_global_array
from .optimizer import OptConfig, init_state
from .step import batch_shardings, make_train_step


class StragglerMonitor:
    """Flags steps (or, fed per-host durations, hosts) that exceed
    ``threshold`` x the running median — the signal a production job uses
    to evict/replace slow hosts before they stall the collective."""

    def __init__(self, threshold: float = 2.0, window: int = 50):
        self.threshold = threshold
        self.window = window
        self.durations: list = []
        self.flagged: list = []

    def add(self, step: int, duration: float):
        self.durations.append(duration)
        hist = self.durations[-self.window:]
        med = float(np.median(hist))
        if len(hist) >= 5 and duration > self.threshold * med:
            self.flagged.append((step, duration, med))
            return True
        return False


@dataclasses.dataclass
class JobConfig:
    steps: int = 100
    ckpt_dir: str = ""
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    microbatch: int = 1
    fail_at_step: int = -1     # simulate a crash (tests)
    seed: int = 0


def train(cfg: ModelConfig, opt_cfg: OptConfig, job: JobConfig, mesh,
          *, data: SyntheticLM | None = None, shape=None, rules=None,
          log=print) -> dict:
    """Run (or resume) a training job.  Returns history dict."""
    rules = rules or DEFAULT_RULES
    if data is None:
        assert shape is not None
        data = SyntheticLM(cfg.vocab_size, shape.seq_len,
                           shape.global_batch, seed=job.seed)

    with mesh:
        step_fn = make_train_step(cfg, opt_cfg, mesh, rules=rules,
                                  microbatch=job.microbatch)
        start = 0
        params = opt_state = None
        if job.ckpt_dir:
            last = ckpt.latest_step(job.ckpt_dir)
            if last is not None:
                # build fresh then overwrite (simple; small-model driver)
                params = tree_init(params_spec(cfg),
                                   jax.random.PRNGKey(job.seed), cfg.dtype)
                opt_state = init_state(opt_cfg, params)
                restored = ckpt.restore(
                    job.ckpt_dir, last,
                    {"params": params, "opt": opt_state})
                params, opt_state = restored["params"], restored["opt"]
                start = last
                log(f"[train] resumed from step {last}")
        if params is None:
            params = tree_init(params_spec(cfg),
                               jax.random.PRNGKey(job.seed), cfg.dtype)
            opt_state = init_state(opt_cfg, params)

        bsh = None
        if shape is not None:
            bsh = batch_shardings(cfg, shape, mesh, rules)
        monitor = StragglerMonitor()
        history = {"loss": [], "steps": [], "stragglers": monitor.flagged}
        for step in range(start, job.steps):
            if step == job.fail_at_step:
                raise RuntimeError(f"simulated failure at step {step}")
            np_batch = data.np_batch(step)
            if bsh is not None:
                batch = {k: make_global_array(v, bsh[k])
                         for k, v in np_batch.items() if k in bsh}
            else:
                batch = {k: jax.numpy.asarray(v)
                         for k, v in np_batch.items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            monitor.add(step, dt)
            history["loss"].append(loss)
            history["steps"].append(step)
            if job.log_every and step % job.log_every == 0:
                log(f"[train] step {step} loss {loss:.4f} "
                    f"({dt*1e3:.0f} ms)")
            if job.ckpt_dir and (step + 1) % job.ckpt_every == 0:
                ckpt.save(job.ckpt_dir, step + 1,
                          {"params": params, "opt": opt_state},
                          keep=job.keep)
        if job.ckpt_dir:
            ckpt.save(job.ckpt_dir, job.steps,
                      {"params": params, "opt": opt_state}, keep=job.keep)
    history["params"] = params
    return history


def train_with_restarts(cfg, opt_cfg, job: JobConfig, mesh, *,
                        max_restarts: int = 3, shape=None, log=print):
    """Supervisor loop: restart from the latest checkpoint on failure —
    the single-process analogue of a cluster-level job controller."""
    attempts = 0
    while True:
        try:
            return train(cfg, opt_cfg, job, mesh, shape=shape, log=log)
        except RuntimeError as e:
            attempts += 1
            log(f"[train] failure: {e}; restart {attempts}")
            if attempts > max_restarts:
                raise
            job = dataclasses.replace(job, fail_at_step=-1)
