"""Fault-tolerant sharded checkpointing with elastic restore.

Design (tensorstore-free, numpy container):

- One ``.npy`` file per leaf under ``step_<N>.tmp/``, plus a
  ``manifest.json`` recording the flattened key paths, shapes, dtypes and
  the saving step.  The directory is atomically renamed to ``step_<N>``
  when complete — a crash mid-save never corrupts the latest checkpoint.
- ``restore`` rebuilds leaves onto *any* mesh/sharding (elastic scaling:
  save on a 4-way mesh, restore on 8-way — re-sharding happens via
  jax.make_array_from_callback per shard index).
- ``keep`` old checkpoints are pruned after a successful save.
"""

from __future__ import annotations

import json
import os
import re
import shutil

import jax
import numpy as np


def _flatten(tree):
    # jax.tree_util has carried tree_flatten_with_path since 0.4.x;
    # jax.tree.flatten_with_path only appeared in much newer releases.
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                     for k in path) for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    keys, vals, _ = _flatten(tree)
    manifest = {"step": step, "leaves": []}
    for i, (k, v) in enumerate(zip(keys, vals)):
        arr = np.asarray(jax.device_get(v))
        fname = f"leaf_{i:05d}.npy"
        logical_dtype = str(arr.dtype)
        if arr.dtype.kind == "V" or logical_dtype not in np.sctypeDict:
            # non-native dtypes (bfloat16, fp8): store raw bytes; the
            # logical dtype in the manifest restores the view on load
            store = arr.view(np.uint8).reshape(arr.shape + (arr.itemsize,))
        else:
            store = arr
        np.save(os.path.join(tmp, fname), store)
        manifest["leaves"].append(
            {"key": k, "file": fname, "shape": list(arr.shape),
             "dtype": logical_dtype,
             "raw": store is not arr})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int):
    steps = all_steps(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name,
                                             "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str):
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like_tree, *, shardings=None):
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional matching tree of NamedShardings — leaves are
    materialised shard-by-shard onto the (possibly different) mesh,
    giving elastic restore.  Without it, plain numpy->jnp arrays.
    """
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    keys, vals, treedef = _flatten(like_tree)
    by_key = {l["key"]: l for l in manifest["leaves"]}
    missing = [k for k in keys if k not in by_key]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}")
    shard_list = None
    if shardings is not None:
        _, shard_list, _ = _flatten(shardings)
    out = []
    for i, k in enumerate(keys):
        leaf = by_key[k]
        arr = np.load(os.path.join(path, leaf["file"]))
        if leaf.get("raw"):
            import ml_dtypes  # noqa: F401 - registers bfloat16 et al.
            dt = np.dtype(getattr(ml_dtypes, leaf["dtype"], None)
                          or leaf["dtype"])
            arr = arr.reshape(-1).view(dt).reshape(leaf["shape"])
        if shard_list is not None:
            arr_jax = jax.make_array_from_callback(
                arr.shape, shard_list[i], lambda idx, a=arr: a[idx])
        else:
            arr_jax = jax.numpy.asarray(arr)
        ref = vals[i]
        if hasattr(ref, "dtype") and arr_jax.dtype != ref.dtype:
            arr_jax = arr_jax.astype(ref.dtype)
        out.append(arr_jax)
    return jax.tree.unflatten(treedef, out)
