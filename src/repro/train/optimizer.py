"""AdamW in pure JAX with sharded state, clipping, schedules, and
optional int8 gradient compression with error feedback.

Optimizer state shards exactly like the parameters (the moment trees
inherit the param logical axes), which is what makes ZeRO-style sharding
fall out of the rules engine for free.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"   # bfloat16 at grok/mixtral scale
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"        # cosine | constant
    compress_grads: bool = False    # int8 + error feedback


def schedule_lr(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    t = jnp.clip((step - cfg.warmup_steps) /
                 jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_state(cfg: OptConfig, params) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)

    def zeros(p):
        return jnp.zeros(p.shape, dt)

    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }
    if cfg.compress_grads:
        state["err"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


def abstract_state(cfg: OptConfig, abstract_params) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)

    def mk(p):
        return jax.ShapeDtypeStruct(p.shape, dt)

    state = {
        "step": jax.ShapeDtypeStruct((), jnp.int32),
        "m": jax.tree.map(mk, abstract_params),
        "v": jax.tree.map(mk, abstract_params),
    }
    if cfg.compress_grads:
        state["err"] = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
            abstract_params)
    return state


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
             for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def _compress_int8(g, err):
    """Symmetric per-tensor int8 quantisation with error feedback.

    In a multi-pod deployment this runs *before* the cross-DCN gradient
    all-reduce (8x wire-format reduction on the slowest links); the error
    buffer re-injects the quantisation residual next step so convergence
    is unaffected (1-bit-Adam style analysis applies).
    """
    g32 = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.abs(g32).max(), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq.astype(g.dtype), (g32 - deq)


def apply_updates(cfg: OptConfig, params, grads, state):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12)) \
        if cfg.clip_norm else 1.0
    new_err = state.get("err")
    if cfg.compress_grads:
        pairs = jax.tree.map(_compress_int8, grads, state["err"])
        grads = jax.tree.map(lambda p: p[0], pairs,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda p: p[1], pairs,
                               is_leaf=lambda x: isinstance(x, tuple))
    lr = schedule_lr(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    trips = jax.tree.map(upd, params, grads, state["m"], state["v"])

    def is3(x):
        return isinstance(x, tuple) and len(x) == 3

    new_params = jax.tree.map(lambda t: t[0], trips, is_leaf=is3)
    new_m = jax.tree.map(lambda t: t[1], trips, is_leaf=is3)
    new_v = jax.tree.map(lambda t: t[2], trips, is_leaf=is3)
    new_state = {"step": step, "m": new_m, "v": new_v}
    if cfg.compress_grads:
        new_state["err"] = new_err
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
