"""Train/eval/serve step factories: jit + shardings + donation.

``make_train_step`` returns a jit'd function with in/out shardings
derived from the logical rules, donated params/optimizer buffers, and
optional gradient accumulation (microbatching) via lax.scan.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models import (ModelConfig, decode_step, loss_fn, params_spec,
                          tree_abstract, tree_axes)
from repro.sharding.rules import DEFAULT_RULES, spec_for_axes, tree_shardings

from .optimizer import OptConfig, abstract_state, apply_updates


def batch_shardings(cfg: ModelConfig, shape, mesh: Mesh,
                    rules=None) -> dict:
    rules = rules or DEFAULT_RULES
    dp = spec_for_axes(("batch",), rules, mesh, (shape.global_batch,))
    bs = NamedSharding(mesh, PartitionSpec(*dp, None))
    out = {"tokens": bs, "labels": bs, "mask": bs}
    if cfg.family == "encdec":
        out["frames"] = NamedSharding(mesh, PartitionSpec(*dp, None, None))
    if cfg.family == "vlm":
        out["patches"] = NamedSharding(mesh, PartitionSpec(*dp, None, None))
    return out


def batch_specs(cfg: ModelConfig, shape) -> dict:
    b, s = shape.global_batch, shape.seq_len
    out = {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
    }
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_seq, cfg.frontend_dim), jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        out["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.num_patches, cfg.frontend_dim), jnp.dtype(cfg.dtype))
    return out


def model_shardings(cfg: ModelConfig, mesh: Mesh, rules=None):
    rules = rules or DEFAULT_RULES
    spec = params_spec(cfg)
    axes = tree_axes(spec)
    ab = tree_abstract(spec, cfg.dtype)
    return tree_shardings(axes, ab, rules, mesh), ab, axes


def opt_shardings(opt_cfg: OptConfig, cfg: ModelConfig, mesh: Mesh,
                  rules=None):
    rules = rules or DEFAULT_RULES
    param_sh, ab, axes = model_shardings(cfg, mesh, rules)
    opt_ab = abstract_state(opt_cfg, ab)
    rep = NamedSharding(mesh, PartitionSpec())
    moment_sh = {  # moments shard exactly like params
        "step": rep,
        "m": tree_shardings(axes, ab, rules, mesh),
        "v": tree_shardings(axes, ab, rules, mesh),
    }
    if opt_cfg.compress_grads:
        moment_sh["err"] = tree_shardings(axes, ab, rules, mesh)
    return moment_sh, opt_ab


def _split_micro(batch, n):
    return jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def make_train_step(cfg: ModelConfig, opt_cfg: OptConfig, mesh: Mesh,
                    *, rules=None, microbatch: int = 1, jit: bool = True):
    rules = rules or DEFAULT_RULES

    def step_fn(params, opt_state, batch):
        def loss_of(p, b):
            return loss_fn(cfg, p, b)

        if microbatch > 1:
            micro = _split_micro(batch, microbatch)

            def acc(carry, mb):
                l, g = jax.value_and_grad(loss_of)(params, mb)
                return (carry[0] + l, jax.tree.map(
                    lambda a, b_: a + b_.astype(jnp.float32), carry[1], g)), \
                    None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(acc, (0.0, zero), micro)
            loss = loss_sum / microbatch
            grads = jax.tree.map(lambda g: g / microbatch, grads)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        params2, opt2, metrics = apply_updates(opt_cfg, params, grads,
                                               opt_state)
        metrics = dict(metrics, loss=loss)
        return params2, opt2, metrics

    if not jit:
        return step_fn

    param_sh, ab, axes = model_shardings(cfg, mesh, rules)
    opt_sh, _ = opt_shardings(opt_cfg, cfg, mesh, rules)
    rep = NamedSharding(mesh, PartitionSpec())
    metrics_sh = {"grad_norm": rep, "lr": rep, "loss": rep}
    return jax.jit(
        step_fn,
        in_shardings=(param_sh, opt_sh, None),
        out_shardings=(param_sh, opt_sh, metrics_sh),
        donate_argnums=(0, 1),
    )


def make_serve_step(cfg: ModelConfig, mesh: Mesh, *, rules=None,
                    jit: bool = True):
    """decode_step wrapped with cache shardings (one-token serving)."""
    rules = rules or DEFAULT_RULES

    def fn(params, cache, tokens, pos):
        return decode_step(cfg, params, cache, tokens, pos)

    if not jit:
        return fn
    param_sh, ab, axes = model_shardings(cfg, mesh, rules)
    return jax.jit(
        fn,
        in_shardings=(param_sh, None, None, None),
        donate_argnums=(1,),
    )
