"""Model layers in pure JAX (functional; params are nested dicts).

Attention comes in three implementations selected by cfg.attn_impl:

- "quadratic": materialises the score matrix — the readable oracle, used
  for small shapes and as the reference for everything else.
- "xla_flash": scan over key blocks with an online softmax — memory
  O(seq * block); the default for training/prefill at scale (and the
  oracle-validated XLA path the dry-run lowers).
- "pallas": the TPU kernel in repro.kernels (validated in interpret mode
  on CPU; selected on real TPU backends).

All attention paths support GQA, causal masking, sliding windows and
gemma-style logit soft-capping.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import logical


# ---------------------------------------------------------------------------
# Basics
# ---------------------------------------------------------------------------

def rms_norm(x, weight, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + weight.astype(jnp.float32))
    return out.astype(dt)


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def rope(x, positions, theta: float):
    """Rotary embeddings. x: (..., seq, heads, head_dim); positions (..., seq)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # (..., seq, half)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def activation(x, kind: str):
    if kind == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


# ---------------------------------------------------------------------------
# Attention (GQA + causal + sliding window + softcap)
# ---------------------------------------------------------------------------

def _mask_bias(q_pos, k_pos, causal: bool, window: int, dtype):
    """(q, k) additive bias: 0 where attendable, -inf otherwise."""
    ok = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), dtype=bool)
    d = q_pos[:, None] - k_pos[None, :]
    if causal:
        ok &= d >= 0
    if window:
        ok &= d < window
    return jnp.where(ok, 0.0, -1e30).astype(dtype)


def attention_quadratic(q, k, v, *, q_pos, k_pos, causal=True, window=0,
                        cap=0.0):
    """Reference attention.  q: (B,S,H,D); k/v: (B,T,KH,D)."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    qs = q.reshape(b, s, kh, g, d) * (d ** -0.5)
    scores = jnp.einsum("bskgd,btkd->bkgst", qs.astype(jnp.float32),
                        k.astype(jnp.float32))
    scores = softcap(scores, cap)
    scores = scores + _mask_bias(q_pos, k_pos, causal, window, scores.dtype)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, d).astype(q.dtype)


def attention_xla_flash(q, k, v, *, q_pos, k_pos, causal=True, window=0,
                        cap=0.0, block: int = 512):
    """Query-chunked attention in (b, h, ...) layout.

    Perf-iteration 1 (see EXPERIMENTS.md §Perf): the original key-block
    online-softmax scan carried full-length f32 (b,kh,g,s,d) accumulators
    through HBM every block step (~30 GB/layer of loop-carry traffic at
    seq 4096) and its kh*g factored layout blocked clean head sharding
    (kv_heads < model axis), triggering SPMD full-rematerialisation
    copies.  This version scans over QUERY chunks instead: per chunk the
    softmax runs over the full key length in one fused pass, nothing is
    carried between steps, and everything stays in (b, h, seq, d) layout
    so `heads` shards 16-way.  k/v are broadcast per query-head group
    lazily inside each chunk (einsum over the factored kv), keeping kv
    reads at kv_heads width.  Memory high-water per chunk:
    O(b * h * block * t) f32 scores.
    """
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    block = min(block, s)
    nblk = (s + block - 1) // block
    pad = nblk * block - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad), constant_values=10**9)
    qc = (q * (d ** -0.5)).astype(q.dtype)
    qc = qc.reshape(b, nblk, block, h, d)
    qc = jnp.moveaxis(qc, 1, 0)                       # (nblk,b,block,h,d)
    qp = q_pos.reshape(nblk, block)
    # broadcast kv across query-head groups ONCE: g x the (small) kv bytes
    # buys a unified `h` dim that shards 16-way over the model axis.
    # kv stays at model dtype (perf iter 5) — the einsum accumulates f32.
    kf = jnp.repeat(k, g, axis=2)
    vf = jnp.repeat(v, g, axis=2)
    kf = logical(kf, ("batch", None, "act_heads", None))
    vf = logical(vf, ("batch", None, "act_heads", None))

    def chunk(args):
        qi, qpi = args
        sc = jnp.einsum("bshd,bthd->bhst", qi, kf,
                        preferred_element_type=jnp.float32)
        sc = softcap(sc, cap)
        sc = sc + _mask_bias(qpi, k_pos, causal, window, sc.dtype)
        # shard scores over heads when h % model == 0, else fall back to
        # sharding the query-block dim (minitron h=24 / whisper h=12
        # cannot split 16 ways; the rules engine drops non-divisible
        # mappings and picks up the next requested axis — perf iter 5)
        sc = logical(sc, ("batch", "act_heads", "sp_seq", None))
        # softmax in f32; store/consume probabilities at model dtype —
        # halves the p-matrix HBM traffic of the p@v matmul (perf iter 2)
        p = jax.nn.softmax(sc, axis=-1).astype(q.dtype)
        o = jnp.einsum("bhst,bthd->bshd", p, vf)
        return o

    out = jax.lax.map(chunk, (qc, qp))                # (nblk,b,block,h,d)
    out = jnp.moveaxis(out, 0, 1).reshape(b, nblk * block, h, d)
    if pad:
        out = out[:, :s]
    # checkpoint name: with remat="names" the backward keeps this tensor
    # ((b,s,h,d) sharded over heads — small) instead of re-running the
    # whole score/softmax pipeline (perf iter 4)
    from jax.ad_checkpoint import checkpoint_name
    out = checkpoint_name(out.astype(q.dtype), "attn_out")
    return out


def attention_decode(q, k_cache, v_cache, *, pos, window=0, cap=0.0):
    """Single-token decode vs a (B,S,KH,D) cache filled up to ``pos``.

    q: (B,1,H,D).  The cache's sequence dim may be sharded ("kv_seq");
    the softmax reduction then lowers to the flash-decoding style
    all-reduce pair under SPMD.
    """
    b, _, h, d = q.shape
    t, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qs = (q.reshape(b, kh, g, d) * (d ** -0.5)).astype(jnp.float32)
    scores = jnp.einsum("bkgd,btkd->bkgt", qs, k_cache.astype(jnp.float32))
    scores = softcap(scores, cap)
    kpos = jnp.arange(t)
    valid = kpos[None, :] <= pos[:, None]                  # causal vs fill
    if window:
        valid &= kpos[None, :] > pos[:, None] - window
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


def attention(cfg, q, k, v, *, q_pos, k_pos, causal=True, window=0, cap=0.0):
    impl = cfg.attn_impl
    if impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal, window=window,
                                      cap=cap)
    if impl == "quadratic" or q.shape[1] <= 256:
        return attention_quadratic(q, k, v, q_pos=q_pos, k_pos=k_pos,
                                   causal=causal, window=window, cap=cap)
    return attention_xla_flash(q, k, v, q_pos=q_pos, k_pos=k_pos,
                               causal=causal, window=window, cap=cap)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + cache plumbing)
# ---------------------------------------------------------------------------

def attn_block(cfg, p, x, *, positions, window: int = 0, cache=None,
               cache_pos=None, cross_kv=None, causal=True):
    """Self- or cross-attention block.

    p: {"q","k","v","o"} projection kernels.
    window: static sliding-window size (0 = full attention).  Mixed
        local/global stacks dispatch via lax.cond over two static calls.
    cache: None (training/prefill without cache) or dict {"k","v"} of
        (B,S,KH,D) buffers to read/update at cache_pos (decode).
    cross_kv: (k, v) precomputed encoder projections for cross-attention.
    Returns (out, new_kv) where new_kv is the (k, v) pair produced by this
    call (prefill) or the updated cache (decode); None for cross-attn.
    """
    b, s, e = x.shape
    q = jnp.einsum("bse,ehd->bshd", x, p["q"])
    q = logical(q, ("batch", "act_seq", "act_heads", None))
    if cross_kv is not None:
        k, v = cross_kv
        out = attention(cfg, q, k, v, q_pos=positions,
                        k_pos=jnp.arange(k.shape[1]), causal=False,
                        window=0, cap=cfg.attn_softcap)
        new_kv = None
    else:
        k = jnp.einsum("bse,ekd->bskd", x, p["k"])
        v = jnp.einsum("bse,ekd->bskd", x, p["v"])
        k = logical(k, ("batch", "act_seq", "act_kv_heads", None))
        v = logical(v, ("batch", "act_seq", "act_kv_heads", None))
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if cache is None:
            out = attention(cfg, q, k, v, q_pos=positions, k_pos=positions,
                            causal=causal, window=window,
                            cap=cfg.attn_softcap)
            new_kv = (k, v)
        else:
            # decode: write this step's k/v at cache_pos, attend over cache
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], k.astype(cache["k"].dtype), cache_pos, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], v.astype(cache["v"].dtype), cache_pos, axis=1)
            kc = logical(kc, ("kv_batch", "kv_seq", "act_kv_heads", None))
            vc = logical(vc, ("kv_batch", "kv_seq", "act_kv_heads", None))
            pos_vec = jnp.full((b,), cache_pos, jnp.int32)
            out = attention_decode(q, kc, vc, pos=pos_vec, window=window,
                                   cap=cfg.attn_softcap)
            new_kv = {"k": kc, "v": vc}
    out = jnp.einsum("bshd,hde->bse", out, p["o"])
    return out, new_kv


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def mlp_block(cfg, p, x):
    """Gated MLP (llama-style).  p: {"wi","wg","wo"}."""
    h = jnp.einsum("bse,ef->bsf", x, p["wi"])
    g = jnp.einsum("bse,ef->bsf", x, p["wg"])
    h = activation(g, cfg.act) * h
    h = logical(h, ("batch", "act_seq", "act_mlp"))
    return jnp.einsum("bsf,fe->bse", h, p["wo"])


def moe_block(cfg, p, x):
    """Top-k token-choice MoE with GROUPED capacity dispatch.

    p: {"router": (E, e), "wi": (X, e, f), "wg": (X, e, f), "wo": (X, f, e)}

    Perf iteration (see EXPERIMENTS.md §Perf grok cell): dispatch is done
    per token GROUP (leading dim sharded over the data axis) with batched
    local argsort/scatter/gather, so routing never communicates across
    data shards — the original flat global sort/scatter forced XLA to
    all-reduce gather results, which was 67% of grok's collective bytes.
    FLOP cost stays proportional to *active* experts (tokens * k), which
    is what MODEL_FLOPS assumes for MoE.
    """
    b, s, e = x.shape
    E, k = cfg.num_experts, cfg.experts_per_tok
    t = b * s
    groups = b  # one group per sequence: divides every shape, data-sharded
    tg = t // groups
    xt = x.reshape(groups, tg, e)
    xt = logical(xt, ("batch", None, None))
    logits = jnp.einsum("gte,Ee->gtE", xt, p["router"].astype(x.dtype))
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topg, tope = jax.lax.top_k(gates, k)                   # (g, tg, k)
    topg = topg / jnp.maximum(topg.sum(-1, keepdims=True), 1e-9)

    cap = int(np.ceil(tg * k / E * cfg.capacity_factor))
    flat_e = tope.reshape(groups, tg * k)                  # (g, tg*k)
    flat_t = jnp.broadcast_to(
        jnp.repeat(jnp.arange(tg), k)[None], (groups, tg * k))
    flat_g = topg.reshape(groups, tg * k)
    order = jnp.argsort(flat_e, axis=1, stable=True)       # batched: local
    se = jnp.take_along_axis(flat_e, order, axis=1)
    st_ = jnp.take_along_axis(flat_t, order, axis=1)
    sg = jnp.take_along_axis(flat_g, order, axis=1)
    onehot = jax.nn.one_hot(se, E, dtype=jnp.int32)        # (g, tg*k, E)
    pos_in_e = jnp.take_along_axis(
        jnp.cumsum(onehot, axis=1) - onehot, se[..., None], axis=2)[..., 0]
    keep = pos_in_e < cap
    slot = jnp.where(keep, se * cap + pos_in_e, E * cap)   # overflow slot
    gidx = jnp.broadcast_to(jnp.arange(groups)[:, None], slot.shape)
    tok = jnp.take_along_axis(
        xt, st_[..., None], axis=1)                        # (g, tg*k, e)
    buf = jnp.zeros((groups, E * cap + 1, e), x.dtype).at[
        gidx, slot].set(tok)
    buf = buf[:, :E * cap].reshape(groups, E, cap, e)
    buf = logical(buf, ("batch", "act_experts", None, None))
    hh = jnp.einsum("gEce,Eef->gEcf", buf, p["wi"])
    gg = jnp.einsum("gEce,Eef->gEcf", buf, p["wg"])
    hh = activation(gg, cfg.act) * hh
    hh = logical(hh, ("batch", "act_experts", None, "act_mlp"))
    yy = jnp.einsum("gEcf,Efe->gEce", hh, p["wo"]).reshape(
        groups, E * cap, e)
    # gather back per group and combine with gate weights
    picked = jnp.take_along_axis(
        yy, jnp.clip(slot, 0, E * cap - 1)[..., None], axis=1)
    contrib = jnp.where(keep[..., None], sg[..., None] * picked, 0.0)
    out = jnp.zeros((groups, tg, e), x.dtype).at[
        gidx, st_].add(contrib.astype(x.dtype))
    return out.reshape(b, s, e)


# ---------------------------------------------------------------------------
# Mamba2 (SSD) block
# ---------------------------------------------------------------------------

def ssd_reference(x, dt, A, B, C, *, chunk: int):
    """Chunked SSD scan (Dao & Gu 2024), pure jnp.

    x : (b, l, h, p)   dt: (b, l, h)   A: (h,) negative decay
    B : (b, l, n)      C : (b, l, n)
    returns y: (b, l, h, p) and final state (b, h, p, n)
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    q = chunk
    nc = l // q
    assert nc * q == l, (l, q)
    xc = x.reshape(b, nc, q, h, p)
    dtc = dt.reshape(b, nc, q, h)
    Bc = B.reshape(b, nc, q, n)
    Cc = C.reshape(b, nc, q, n)
    a = dtc * A  # (b, nc, q, h) log-decay increments (negative)
    s_cum = jnp.cumsum(a, axis=2)  # within-chunk cumulative
    # intra-chunk: L[i,j] = exp(s_i - s_j) for j <= i
    si = s_cum[:, :, :, None, :]        # (b,nc,q,1,h)
    sj = s_cum[:, :, None, :, :]        # (b,nc,1,q,h)
    mask = jnp.tril(jnp.ones((q, q), bool))
    Lmat = jnp.where(mask[None, None, :, :, None],
                     jnp.exp(si - sj), 0.0)  # (b,nc,q,q,h)
    cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)  # (b,nc,q,q)
    scores = cb[..., None] * Lmat * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xc)
    # chunk-final states: S_c = sum_j exp(s_Q - s_j) dt_j B_j x_j
    decay_out = jnp.exp(s_cum[:, :, -1:, :] - s_cum)   # (b,nc,q,h)
    dBx = jnp.einsum("bcqh,bcqn,bcqhp->bchpn",
                     decay_out * dtc, Bc, xc)
    chunk_decay = jnp.exp(s_cum[:, :, -1, :])          # (b,nc,h)

    def scan_fn(S, inp):
        dBx_c, dec_c = inp
        S_new = S * dec_c[..., None, None] + dBx_c
        return S_new, S  # emit the state *entering* the chunk

    S0 = jnp.zeros((b, h, p, n), jnp.float32)
    S_last, S_enter = jax.lax.scan(
        scan_fn, S0,
        (jnp.moveaxis(dBx.astype(jnp.float32), 1, 0),
         jnp.moveaxis(chunk_decay.astype(jnp.float32), 1, 0)))
    S_enter = jnp.moveaxis(S_enter, 0, 1)  # (b,nc,h,p,n)
    # inter-chunk: y_i += C_i . (exp(s_i) * S_enter)
    y_inter = jnp.einsum("bcqn,bchpn,bcqh->bcqhp",
                         Cc, S_enter.astype(Cc.dtype),
                         jnp.exp(s_cum))
    y = (y_intra + y_inter).reshape(b, l, h, p)
    return y.astype(x.dtype), S_last


def ssd_step(x, dt, A, B, C, state):
    """Single-token SSD recurrence.  x: (b,h,p); state: (b,h,p,n)."""
    dA = jnp.exp(dt * A)                        # (b,h)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, B, x)
    state = state * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", C, state.astype(C.dtype))
    return y.astype(x.dtype), state


def _depthwise_conv(seq, w, state=None):
    """Causal depthwise conv1d.  seq: (b, l, c); w: (width, c).

    state: (b, width-1, c) carried context for decode; returns (out,
    new_state)."""
    width = w.shape[0]
    if state is None:
        ctx = jnp.pad(seq, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([state.astype(seq.dtype), seq], axis=1)
    out = sum(ctx[:, i:i + seq.shape[1], :] * w[i] for i in range(width))
    new_state = ctx[:, -(width - 1):, :] if width > 1 else None
    return out.astype(seq.dtype), new_state


def mamba_block(cfg, p, x, *, cache=None):
    """Mamba2 block.  p: {"z_proj","x_proj","bc_proj","dt_proj","conv_w",
    "A_log","D","dt_bias","norm","out_proj"}.

    cache: None for training (full sequence) or {"state": (b,h,hp,n),
    "conv": (b,w-1,conv_dim)} for single-token decode.
    Returns (y, new_cache_or_None).
    """
    b, l, e = x.shape
    inner = cfg.ssm_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    hp = cfg.ssm_head_dim
    z = jnp.einsum("ble,ed->bld", x, p["z_proj"])
    xin = jnp.einsum("ble,ed->bld", x, p["x_proj"])
    bc = jnp.einsum("ble,ed->bld", x, p["bc_proj"])
    dt = jnp.einsum("ble,eh->blh", x, p["dt_proj"])
    Bc, Cc = bc[..., :n], bc[..., n:]
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _depthwise_conv(conv_in, p["conv_w"], conv_state)
    conv_out = jax.nn.silu(conv_out)
    xin = conv_out[..., :inner]
    Bc = conv_out[..., inner:inner + n]
    Cc = conv_out[..., inner + n:]
    dt = jax.nn.softplus(dt + p["dt_bias"])                # (b,l,h)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))           # (h,)
    xh = xin.reshape(b, l, h, hp)
    if cache is None:
        y, _ = ssd_reference(xh, dt, A, Bc, Cc, chunk=min(cfg.ssm_chunk, l))
        new_cache = None
    else:
        y, new_state = ssd_step(xh[:, 0], dt[:, 0], A, Bc[:, 0], Cc[:, 0],
                                cache["state"])
        y = y[:, None]
        new_cache = {"state": new_state, "conv": new_conv}
    y = (y + xh * p["D"][None, None, :, None]).astype(x.dtype)
    y = y.reshape(b, l, inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("bld,de->ble", y, p["out_proj"]).astype(x.dtype)
    return out, new_cache
