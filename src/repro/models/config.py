"""Model and shape configuration for all assigned architectures."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config covers every assigned family via feature flags.

    family: dense | moe | ssm | hybrid | encdec | vlm
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # --- attention pattern ---
    window_size: int = 0          # sliding-window size (0 = full attention)
    global_every: int = 0         # >0: layer i is GLOBAL iff (i+1) % N == 0
                                  #  (gemma3 5:1 -> 6; gemma2 1:1 -> 2)
    attn_softcap: float = 0.0     # gemma2 attention logit soft-capping
    final_softcap: float = 0.0    # gemma2 final logit soft-capping
    rope_theta: float = 1e4

    # --- MoE ---
    num_experts: int = 0
    experts_per_tok: int = 0
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    conv_width: int = 4

    # --- hybrid (zamba2) ---
    attn_every: int = 0           # shared attention block every N ssm layers

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500       # whisper 30 s of audio frames

    # --- modality frontends (stubs per spec) ---
    frontend: str = ""            # "" | "audio_stub" | "patch_stub"
    frontend_dim: int = 0         # precomputed embedding dim fed by stub
    num_patches: int = 0          # vlm: patch positions at seq start

    # --- numerics / misc ---
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    act: str = "silu"             # mlp activation: silu | gelu
    dtype: str = "bfloat16"
    loss_chunk: int = 2048        # ce-loss seq chunking (0 = unchunked)
    remat: str = "full"           # none | full | dots
    scan_layers: bool = True
    attn_impl: str = "xla_flash"  # xla_flash | quadratic | pallas

    # long-context capability (drives long_500k cell skips)
    supports_long_context: bool = False

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    def layer_is_global(self, i: int) -> bool:
        if self.window_size == 0:
            return True
        if self.global_every == 0:
            return False  # pure SWA (mixtral-style)
        return (i + 1) % self.global_every == 0


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell: training or serving geometry."""

    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    small = dict(
        num_layers=max(2, min(4, cfg.num_layers)),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(4, max(1, cfg.num_kv_heads and 2 or 0)) or 0,
        d_ff=128,
        vocab_size=256,
        head_dim=16,
        window_size=16 if cfg.window_size else 0,
        num_experts=4 if cfg.num_experts else 0,
        experts_per_tok=2 if cfg.num_experts else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        encoder_layers=2 if cfg.encoder_layers else 0,
        encoder_seq=32 if cfg.encoder_layers else 1500,
        attn_every=2 if cfg.attn_every else 0,
        frontend_dim=32 if cfg.frontend else 0,
        num_patches=8 if cfg.num_patches else 0,
        loss_chunk=0,
        remat="none",
        name=cfg.name + "-smoke",
    )
    if cfg.num_heads == 0:  # attention-free
        small.update(num_heads=0, num_kv_heads=0, head_dim=0)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
