"""Parameter trees with logical sharding axes (no flax; MaxText pattern).

A model's parameters are declared once as a nested dict of :class:`P`
specs (shape + logical axis names + init).  From that single declaration
we derive:

- abstract params (ShapeDtypeStruct) for the dry-run,
- materialised params for smoke tests / real training,
- NamedShardings via the logical->mesh rules in repro.sharding.rules.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class P:
    """Declaration of one parameter tensor."""

    shape: tuple
    axes: tuple          # logical axis name (or None) per dim
    init: str = "normal"  # normal | zeros | ones | embed
    scale: float = 0.0    # stddev override (0 -> fan-in)
    dtype: str = ""       # override model dtype (e.g. "float32" for norms)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, P)


def tree_abstract(spec_tree, dtype: str):
    """ShapeDtypeStruct tree (used by jax.eval_shape / dry-run)."""
    def f(p: P):
        dt = p.dtype or dtype
        return jax.ShapeDtypeStruct(tuple(int(s) for s in p.shape),
                                    jnp.dtype(dt))
    return jax.tree.map(f, spec_tree, is_leaf=is_spec)


def tree_axes(spec_tree):
    """Tree of logical-axis tuples, same structure as the params."""
    return jax.tree.map(lambda p: tuple(p.axes), spec_tree, is_leaf=is_spec)


def tree_init(spec_tree, key, dtype: str):
    """Materialise parameters (smoke tests and real small-scale training)."""
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for p, k in zip(leaves, keys):
        dt = jnp.dtype(p.dtype or dtype)
        shape = tuple(int(s) for s in p.shape)
        if p.init == "zeros":
            v = jnp.zeros(shape, dt)
        elif p.init == "ones":
            v = jnp.ones(shape, dt)
        else:
            if p.scale:
                std = p.scale
            elif p.init == "embed":
                std = 1.0
            else:
                fan_in = shape[0] if len(shape) == 1 else int(
                    np.prod(shape[:-1]))
                std = 1.0 / math.sqrt(max(fan_in, 1))
            v = (jax.random.normal(k, shape, jnp.float32) * std).astype(dt)
        out.append(v)
    return jax.tree.unflatten(treedef, out)


def count_params(spec_tree) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=is_spec)
    return int(sum(np.prod([int(s) for s in p.shape]) for p in leaves))
