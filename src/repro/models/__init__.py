from .config import SHAPES, ModelConfig, ShapeConfig, reduced
from .decode import cache_axes, cache_spec, decode_step, init_cache, prefill
from .params import P, count_params, tree_abstract, tree_axes, tree_init
from .transformer import (forward_hidden, logits_fn, loss_fn, params_spec,
                          unembed)

__all__ = [
    "SHAPES", "ModelConfig", "P", "ShapeConfig", "cache_axes", "cache_spec",
    "count_params", "decode_step", "forward_hidden", "init_cache",
    "logits_fn", "loss_fn", "params_spec", "prefill", "reduced",
    "tree_abstract", "tree_axes", "tree_init", "unembed",
]
