"""Serving path: KV/SSM cache construction, prefill and decode steps.

Cache layout (leading ``layers`` axis so the decode step scans layers):

dense/moe/vlm : {"k","v": (L, B, S, KH, D)}
ssm           : {"state": (L, B, H, P, N), "conv": (L, B, W-1, C)}
hybrid        : ssm caches + {"attn_k","attn_v": (sites, B, S, KH, D)}
encdec        : {"k","v": (L, B, S, KH, D), "xk","xv": (L, B, T, KH, D)}

The decode step consumes one token per sequence at position ``pos`` and
returns next-token logits plus the updated cache.  The cache sequence dim
carries the "kv_seq" logical axis, so at scale it shards over the model
axis (flash-decoding style distributed attention).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.sharding.rules import logical

from . import layers as L
from .config import ModelConfig
from .transformer import (_dense_block, _layer_flags, _attn_windowed,
                          embed_tokens, unembed)


# ---------------------------------------------------------------------------
# Cache specs
# ---------------------------------------------------------------------------

def cache_spec(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """ShapeDtypeStructs for the cache (dry-run) — mirrors init_cache."""
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        init_cache(cfg, batch, max_seq, abstract=True))


def cache_axes(cfg: ModelConfig) -> dict:
    """Logical axes tree matching init_cache structure."""
    ax: dict = {}
    kv = ("layers", "kv_batch", "kv_seq", "act_kv_heads", None)
    if cfg.family in ("dense", "moe", "vlm"):
        ax = {"k": kv, "v": kv}
    elif cfg.family == "ssm":
        ax = {"state": ("layers", "kv_batch", "ssm_heads", None, None),
              "conv": ("layers", "kv_batch", None, "ssm_inner")}
    elif cfg.family == "hybrid":
        site_kv = ("layers", "kv_batch", "kv_seq", "act_kv_heads", None)
        ax = {"state": ("layers", "kv_batch", "ssm_heads", None, None),
              "conv": ("layers", "kv_batch", None, "ssm_inner"),
              "attn_k": site_kv, "attn_v": site_kv}
    elif cfg.family == "encdec":
        ax = {"k": kv, "v": kv,
              "xk": ("layers", "kv_batch", None, "act_kv_heads", None),
              "xv": ("layers", "kv_batch", None, "act_kv_heads", None)}
    return ax


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               abstract: bool = False) -> dict:
    zeros = (jax.ShapeDtypeStruct if abstract
             else (lambda s, d: jnp.zeros(s, d)))
    dt = jnp.dtype(cfg.dtype)
    nl, kh, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
    out: dict = {}
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        out["k"] = zeros((nl, batch, max_seq, kh, hd), dt)
        out["v"] = zeros((nl, batch, max_seq, kh, hd), dt)
    if cfg.family == "encdec":
        t = cfg.encoder_seq
        out["xk"] = zeros((nl, batch, t, kh, hd), dt)
        out["xv"] = zeros((nl, batch, t, kh, hd), dt)
    if cfg.family in ("ssm", "hybrid"):
        h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        conv_dim = cfg.ssm_inner + 2 * cfg.ssm_state
        out["state"] = zeros((nl, batch, h, p, n), jnp.dtype(jnp.float32))
        out["conv"] = zeros((nl, batch, cfg.conv_width - 1, conv_dim), dt)
    if cfg.family == "hybrid":
        sites = cfg.num_layers // cfg.attn_every
        out["attn_k"] = zeros((sites, batch, max_seq, kh, hd), dt)
        out["attn_v"] = zeros((sites, batch, max_seq, kh, hd), dt)
    return out


def _constrain_cache(cfg, cache):
    ax = cache_axes(cfg)
    return {k: logical(v, ax[k]) for k, v in cache.items()}


# ---------------------------------------------------------------------------
# Decode steps (one token) per family
# ---------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, params, cache: dict, tokens, pos):
    """One decoding step.

    tokens: (B, 1) int32 — the token just produced/fed.
    pos   : scalar int32 — its position (cache fill level).
    Returns (logits (B, 1, V), new_cache).
    """
    x = embed_tokens(cfg, params, tokens)
    positions = jnp.full((1,), pos, jnp.int32)
    cache = _constrain_cache(cfg, cache)

    if cfg.family in ("dense", "moe", "vlm"):
        flags = jnp.asarray(_layer_flags(cfg))

        def body(carry, xs):
            p, flag, kc, vc = xs
            y, kv = _attn_windowed(cfg, p, carry, positions, flag,
                                   cache={"k": kc, "v": vc}, cache_pos=pos)
            return y, (kv["k"], kv["v"])

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["layers"], flags, cache["k"], cache["v"]))
        new_cache = {"k": ks, "v": vs}

    elif cfg.family == "ssm":
        def body(carry, xs):
            p, st, cv = xs
            h, nc = L.mamba_block(
                cfg, p["mamba"],
                L.rms_norm(carry, p["ln1"], cfg.norm_eps),
                cache={"state": st, "conv": cv})
            return carry + h, (nc["state"], nc["conv"])

        x, (sts, cvs) = jax.lax.scan(
            body, x, (params["layers"], cache["state"], cache["conv"]))
        new_cache = {"state": sts, "conv": cvs}

    elif cfg.family == "hybrid":
        nl = cfg.num_layers
        is_site = jnp.asarray(
            [(i + 1) % cfg.attn_every == 0 for i in range(nl)], jnp.int32)
        sites = jnp.asarray(
            [i // cfg.attn_every for i in range(nl)], jnp.int32)
        shared = params["shared_attn"]

        def body(carry, xs):
            x, ak, av = carry
            p, st, cv, site_flag, site = xs
            h, nc = L.mamba_block(
                cfg, p["mamba"],
                L.rms_norm(x, p["ln1"], cfg.norm_eps),
                cache={"state": st, "conv": cv})
            x = x + h

            def with_attn(x, ak, av):
                kc = jax.lax.dynamic_index_in_dim(ak, site, 0, False)
                vc = jax.lax.dynamic_index_in_dim(av, site, 0, False)
                y, kv = _dense_block(cfg, shared, x, positions, 0,
                                     cache={"k": kc, "v": vc},
                                     cache_pos=pos)
                ak = jax.lax.dynamic_update_index_in_dim(
                    ak, kv["k"], site, 0)
                av = jax.lax.dynamic_update_index_in_dim(
                    av, kv["v"], site, 0)
                return y, ak, av

            x, ak, av = jax.lax.cond(
                site_flag > 0, with_attn, lambda x, a, b: (x, a, b),
                x, ak, av)
            return (x, ak, av), (nc["state"], nc["conv"])

        (x, ak, av), (sts, cvs) = jax.lax.scan(
            body, (x, cache["attn_k"], cache["attn_v"]),
            (params["layers"], cache["state"], cache["conv"], is_site,
             sites))
        new_cache = {"state": sts, "conv": cvs, "attn_k": ak, "attn_v": av}

    elif cfg.family == "encdec":
        def body(carry, xs):
            p, kc, vc, xk, xv = xs
            h, kv = L.attn_block(cfg, p["attn"],
                                 L.rms_norm(carry, p["ln1"], cfg.norm_eps),
                                 positions=positions, window=0,
                                 cache={"k": kc, "v": vc}, cache_pos=pos)
            x = carry + h
            h, _ = L.attn_block(cfg, p["xattn"],
                                L.rms_norm(x, p["ln2"], cfg.norm_eps),
                                positions=positions, cross_kv=(xk, xv))
            x = x + h
            x = x + L.mlp_block(cfg, p["mlp"],
                                L.rms_norm(x, p["ln3"], cfg.norm_eps))
            return x, (kv["k"], kv["v"])

        x, (ks, vs) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        new_cache = {"k": ks, "v": vs, "xk": cache["xk"],
                     "xv": cache["xv"]}
    else:
        raise ValueError(cfg.family)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x)
    new_cache = _constrain_cache(cfg, new_cache)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill (build the cache from a full prompt)
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params, batch, max_seq: int | None = None):
    """Run the prompt through the backbone, returning (last-token logits,
    cache filled to the prompt length).

    Implemented as the training-path forward that additionally collects
    per-layer k/v (dense) or final ssm states.  For simplicity the cache
    is sized to the prompt length unless ``max_seq`` is given.
    """
    from .transformer import _stack_dense, _stack_hybrid

    tokens = batch["tokens"]
    b, s = tokens.shape
    max_seq = max_seq or s
    positions = jnp.arange(s)

    if cfg.family in ("dense", "moe", "vlm"):
        x = embed_tokens(cfg, params, tokens)
        if cfg.family == "vlm":
            patches = jnp.einsum("bpf,fe->bpe",
                                 batch["patches"].astype(cfg.dtype),
                                 params["frontend_proj"])
            npatch = patches.shape[1]
            x = jnp.concatenate([patches, x[:, npatch:]], axis=1)
        x, kvs = _stack_dense(cfg, params["layers"], x, positions,
                              collect_kv=True)
        ks, vs = kvs  # (L, B, S, KH, D) each
        pad = max_seq - s
        if pad:
            ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        cache = {"k": ks, "v": vs}
    elif cfg.family in ("ssm", "hybrid"):
        # run the train path but additionally emit final states: cheap
        # approach — rerun mamba blocks collecting states via scan ys.
        cache = init_cache(cfg, b, max_seq)
        x = embed_tokens(cfg, params, tokens)

        def body(carry, p):
            h, _ = L.mamba_block(cfg, p["mamba"],
                                 L.rms_norm(carry, p["ln1"], cfg.norm_eps))
            return carry + h, None

        if cfg.family == "ssm":
            x, _ = jax.lax.scan(body, x, params["layers"])
        else:
            x, _ = _stack_hybrid(cfg, params["layers"],
                                 params["shared_attn"], x, positions)
        # states are not captured in this simplified prefill; decoding
        # resumes correctly only for attention caches.  The serve engine
        # uses decode_step in a fori_loop for ssm prompts (see
        # repro/serve/engine.py).
    else:  # encdec
        frames = batch["frames"]
        enc_in = jnp.einsum("btf,fe->bte", frames.astype(cfg.dtype),
                            params["frontend_proj"])
        from .transformer import _stack_encoder
        enc = _stack_encoder(cfg, params["encoder"], enc_in)
        enc = L.rms_norm(enc, params["enc_final_norm"], cfg.norm_eps)
        lp = params["layers"]
        xk = jnp.einsum("bse,lekd->lbskd", enc, lp["xattn"]["k"])
        xv = jnp.einsum("bse,lekd->lbskd", enc, lp["xattn"]["v"])
        cache = init_cache(cfg, b, max_seq)
        cache["xk"], cache["xv"] = xk, xv
        x = embed_tokens(cfg, params, tokens)
        from .transformer import _stack_encdec_decoder
        x = _stack_encdec_decoder(cfg, lp, x, positions, enc)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(cfg, params, x[:, -1:])
    return logits, cache
