"""Model assembly: param declarations + forward passes for all families.

Families: dense | moe | ssm (mamba2) | hybrid (zamba2) | encdec (whisper)
| vlm (internvl).  Layers are stacked and scanned (compile-time O(1) in
depth); heterogeneous per-layer behaviour (gemma local/global, zamba
shared-attention sites) dispatches on scanned per-layer flags with
lax.cond so the scan body stays uniform.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding.rules import logical

from . import layers as L
from .config import ModelConfig
from .params import P


# ---------------------------------------------------------------------------
# Parameter declarations
# ---------------------------------------------------------------------------

def _attn_spec(cfg: ModelConfig, nl: int, cross: bool = False):
    e, h, kh, d = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pre = (nl,) if nl else ()
    lax_ = ("layers",) if nl else ()
    return {
        "q": P(pre + (e, h, d), lax_ + ("embed", "heads", "head_dim")),
        "k": P(pre + (e, kh, d), lax_ + ("embed", "kv_heads", "head_dim")),
        "v": P(pre + (e, kh, d), lax_ + ("embed", "kv_heads", "head_dim")),
        "o": P(pre + (h, d, e), lax_ + ("heads", "head_dim", "embed")),
    }


def _mlp_spec(cfg: ModelConfig, nl: int):
    e, f = cfg.d_model, cfg.d_ff
    pre = (nl,) if nl else ()
    lax_ = ("layers",) if nl else ()
    return {
        "wi": P(pre + (e, f), lax_ + ("embed", "mlp")),
        "wg": P(pre + (e, f), lax_ + ("embed", "mlp")),
        "wo": P(pre + (f, e), lax_ + ("mlp", "embed")),
    }


def _moe_spec(cfg: ModelConfig, nl: int):
    e, f, x = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": P((nl, x, e), ("layers", "experts", "embed"),
                    dtype="float32"),
        "wi": P((nl, x, e, f), ("layers", "experts", "embed", "mlp")),
        "wg": P((nl, x, e, f), ("layers", "experts", "embed", "mlp")),
        "wo": P((nl, x, f, e), ("layers", "experts", "mlp", "embed")),
    }


def _mamba_spec(cfg: ModelConfig, nl: int):
    e = cfg.d_model
    inner, n, h = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = inner + 2 * n
    return {
        "z_proj": P((nl, e, inner), ("layers", "embed", "ssm_inner")),
        "x_proj": P((nl, e, inner), ("layers", "embed", "ssm_inner")),
        "bc_proj": P((nl, e, 2 * n), ("layers", "embed", None)),
        "dt_proj": P((nl, e, h), ("layers", "embed", "ssm_heads")),
        "conv_w": P((nl, cfg.conv_width, conv_dim),
                    ("layers", "conv", "ssm_inner")),
        "A_log": P((nl, h), ("layers", "ssm_heads"), init="zeros",
                   dtype="float32"),
        "D": P((nl, h), ("layers", "ssm_heads"), init="ones",
               dtype="float32"),
        "dt_bias": P((nl, h), ("layers", "ssm_heads"), init="zeros",
                     dtype="float32"),
        "norm": P((nl, inner), ("layers", "ssm_inner"), init="zeros",
                  dtype="float32"),
        "out_proj": P((nl, inner, e), ("layers", "ssm_inner", "embed")),
    }


def _norm(nl: int, e: int):
    if nl:
        return P((nl, e), ("layers", None), init="zeros", dtype="float32")
    return P((e,), (None,), init="zeros", dtype="float32")


def params_spec(cfg: ModelConfig) -> dict:
    e, v, nl = cfg.d_model, cfg.vocab_size, cfg.num_layers
    spec: dict = {"tok_embed": P((v, e), ("vocab", "embed"), init="embed")}

    if cfg.family in ("dense", "moe", "vlm"):
        blk = {"ln1": _norm(nl, e), "ln2": _norm(nl, e),
               "attn": _attn_spec(cfg, nl)}
        if cfg.family == "moe":
            blk["moe"] = _moe_spec(cfg, nl)
        else:
            blk["mlp"] = _mlp_spec(cfg, nl)
        spec["layers"] = blk
    elif cfg.family == "ssm":
        spec["layers"] = {"ln1": _norm(nl, e),
                          "mamba": _mamba_spec(cfg, nl)}
    elif cfg.family == "hybrid":
        spec["layers"] = {"ln1": _norm(nl, e),
                          "mamba": _mamba_spec(cfg, nl)}
        spec["shared_attn"] = {
            "ln1": _norm(0, e), "ln2": _norm(0, e),
            "attn": _attn_spec(cfg, 0), "mlp": _mlp_spec(cfg, 0)}
    elif cfg.family == "encdec":
        enc = {"ln1": _norm(cfg.encoder_layers, e),
               "ln2": _norm(cfg.encoder_layers, e),
               "attn": _attn_spec(cfg, cfg.encoder_layers),
               "mlp": _mlp_spec(cfg, cfg.encoder_layers)}
        dec = {"ln1": _norm(nl, e), "ln2": _norm(nl, e), "ln3": _norm(nl, e),
               "attn": _attn_spec(cfg, nl),
               "xattn": _attn_spec(cfg, nl, cross=True),
               "mlp": _mlp_spec(cfg, nl)}
        spec["encoder"] = enc
        spec["layers"] = dec
        spec["enc_final_norm"] = _norm(0, e)
    else:
        raise ValueError(cfg.family)

    if cfg.frontend:
        spec["frontend_proj"] = P((cfg.frontend_dim, e),
                                  ("frontend", "embed"))
    spec["final_norm"] = _norm(0, e)
    if not cfg.tie_embeddings:
        spec["unembed"] = P((e, v), ("embed", "vocab"))
    return spec


# ---------------------------------------------------------------------------
# Shared building blocks
# ---------------------------------------------------------------------------

def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "names":
        # save only named tensors (attention outputs): the backward
        # recomputes cheap projections/norms but never the score matrix
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out"))
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_saveable)
    if cfg.remat == "offload_dots":
        return jax.checkpoint(
            fn,
            policy=jax.checkpoint_policies.save_and_offload_only_these_names(
                names_which_can_be_saved=[],
                names_which_can_be_offloaded=[],
                offload_src="device", offload_dst="pinned_host"))
    return jax.checkpoint(fn)


def _sp(x):
    """Megatron-style sequence sharding of the residual stream."""
    return logical(x, ("batch", "sp_seq", "act_embed"))


def _dense_block(cfg, p, x, positions, window, *, cache=None, cache_pos=None):
    h, kv = L.attn_block(cfg, p["attn"],
                         L.rms_norm(x, p["ln1"], cfg.norm_eps),
                         positions=positions, window=window,
                         cache=cache, cache_pos=cache_pos)
    x = _sp(x + h)
    inner = L.rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        x = _sp(x + L.moe_block(cfg, p["moe"], inner))
    else:
        x = _sp(x + L.mlp_block(cfg, p["mlp"], inner))
    return x, kv


def _attn_windowed(cfg, p, x, positions, is_global, *, cache=None,
                   cache_pos=None):
    """lax.cond dispatch between global and local attention (static
    windows in both branches; is_global is a traced per-layer flag)."""
    if cfg.window_size == 0:
        return _dense_block(cfg, p, x, positions, 0, cache=cache,
                            cache_pos=cache_pos)
    if cfg.global_every == 0:  # pure sliding window
        return _dense_block(cfg, p, x, positions, cfg.window_size,
                            cache=cache, cache_pos=cache_pos)
    return jax.lax.cond(
        is_global > 0,
        lambda: _dense_block(cfg, p, x, positions, 0, cache=cache,
                             cache_pos=cache_pos),
        lambda: _dense_block(cfg, p, x, positions, cfg.window_size,
                             cache=cache, cache_pos=cache_pos),
    )


def _layer_flags(cfg: ModelConfig) -> np.ndarray:
    return np.array([1 if cfg.layer_is_global(i) else 0
                     for i in range(cfg.num_layers)], dtype=np.int32)


# ---------------------------------------------------------------------------
# Decoder stacks (training / prefill path: full-sequence, returns kv ys)
# ---------------------------------------------------------------------------

def _stack_dense(cfg, lp, x, positions, *, collect_kv=False):
    flags = jnp.asarray(_layer_flags(cfg))

    def body(carry, xs):
        p, flag = xs
        y, kv = _attn_windowed(cfg, p, carry, positions, flag)
        return y, (kv if collect_kv else None)

    body = _remat(cfg, body)
    if cfg.scan_layers:
        x, kvs = jax.lax.scan(body, x, (lp, flags))
    else:
        kvs = []
        for i in range(cfg.num_layers):
            p = jax.tree.map(lambda a: a[i], lp)
            x, kv = body(x, (p, flags[i]))
            kvs.append(kv)
        kvs = (jax.tree.map(lambda *a: jnp.stack(a), *kvs)
               if collect_kv else None)
    return x, kvs


def _stack_ssm(cfg, lp, x, positions):
    def body(carry, p):
        h, _ = L.mamba_block(cfg, p["mamba"],
                             L.rms_norm(carry, p["ln1"], cfg.norm_eps))
        return _sp(carry + h), None

    body = _remat(cfg, body)
    x, _ = jax.lax.scan(body, x, lp)
    return x, None


def _stack_hybrid(cfg, lp, shared, x, positions, *, collect_kv=False):
    """Zamba2-style: mamba backbone + one shared attention block applied
    every cfg.attn_every layers (same weights at every site)."""
    nl = cfg.num_layers
    is_site = jnp.asarray(
        [(i + 1) % cfg.attn_every == 0 for i in range(nl)], jnp.int32)

    def body(carry, xs):
        p, site = xs
        h, _ = L.mamba_block(cfg, p["mamba"],
                             L.rms_norm(carry, p["ln1"], cfg.norm_eps))
        x = _sp(carry + h)

        def with_attn(x):
            y, kv = _dense_block(cfg, shared, x, positions, 0)
            return y, kv

        if collect_kv:
            y, kv = with_attn(x)
            zero_kv = jax.tree.map(jnp.zeros_like, kv)
            x, kv = jax.lax.cond(site > 0, lambda: (y, kv),
                                 lambda: (x, zero_kv))
            return x, kv
        x = jax.lax.cond(site > 0, lambda: with_attn(x)[0], lambda: x)
        return x, None

    body = _remat(cfg, body)
    x, kvs = jax.lax.scan(body, x, (lp, is_site))
    return x, kvs


def _stack_encoder(cfg, lp, x):
    positions = jnp.arange(x.shape[1])

    def body(carry, p):
        h, _ = L.attn_block(cfg, p["attn"],
                            L.rms_norm(carry, p["ln1"], cfg.norm_eps),
                            positions=positions, window=0, causal=False)
        x = _sp(carry + h)
        x = _sp(x + L.mlp_block(cfg, p["mlp"],
                                L.rms_norm(x, p["ln2"], cfg.norm_eps)))
        return x, None

    body = _remat(cfg, body)
    x, _ = jax.lax.scan(body, x, lp)
    return x


def _stack_encdec_decoder(cfg, lp, x, positions, enc_out):
    def body(carry, p):
        h, _ = L.attn_block(cfg, p["attn"],
                            L.rms_norm(carry, p["ln1"], cfg.norm_eps),
                            positions=positions, window=0)
        x = _sp(carry + h)
        xk = jnp.einsum("bse,ekd->bskd", enc_out, p["xattn"]["k"])
        xv = jnp.einsum("bse,ekd->bskd", enc_out, p["xattn"]["v"])
        h, _ = L.attn_block(cfg, p["xattn"],
                            L.rms_norm(x, p["ln2"], cfg.norm_eps),
                            positions=positions, cross_kv=(xk, xv))
        x = _sp(x + h)
        x = _sp(x + L.mlp_block(cfg, p["mlp"],
                                L.rms_norm(x, p["ln3"], cfg.norm_eps)))
        return x, None

    body = _remat(cfg, body)
    x, _ = jax.lax.scan(body, x, lp)
    return x


# ---------------------------------------------------------------------------
# Embedding / logits / loss
# ---------------------------------------------------------------------------

def embed_tokens(cfg, params, tokens):
    x = params["tok_embed"][tokens] * (cfg.d_model ** 0.5)
    return logical(x.astype(cfg.dtype), ("batch", "act_seq", "act_embed"))


def unembed(cfg, params, x):
    w = (params["tok_embed"].T if cfg.tie_embeddings
         else params["unembed"])
    logits = jnp.einsum("bse,ev->bsv", x, w)
    logits = L.softcap(logits, cfg.final_softcap)
    return logical(logits, ("batch", "act_seq", "act_vocab"))


def _ce_loss_chunk(cfg, params, x, labels, mask):
    logits = unembed(cfg, params, x).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum(), mask.sum()


def lm_loss_from_hidden(cfg, params, x, labels, mask):
    """Cross-entropy with optional sequence chunking (avoids materialising
    the full (B,S,V) logits for 256k vocabularies)."""
    b, s, e = x.shape
    c = cfg.loss_chunk
    if not c or s <= c or s % c != 0:
        nll, denom = _ce_loss_chunk(cfg, params, x, labels, mask)
        return nll / jnp.maximum(denom, 1.0)
    nchunk = s // c

    def body(carry, inp):
        xs, ls, ms = inp
        nll, denom = _ce_loss_chunk(cfg, params, xs, ls, ms)
        return (carry[0] + nll, carry[1] + denom), None

    xc = x.reshape(b, nchunk, c, e).swapaxes(0, 1)
    lc = labels.reshape(b, nchunk, c).swapaxes(0, 1)
    mc = mask.reshape(b, nchunk, c).swapaxes(0, 1)
    (nll, denom), _ = jax.lax.scan(body, (0.0, 0.0), (xc, lc, mc))
    return nll / jnp.maximum(denom, 1.0)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def forward_hidden(cfg: ModelConfig, params, batch):
    """Run the backbone to final hidden states (no unembedding)."""
    if cfg.family == "encdec":
        frames = batch["frames"]
        enc_in = jnp.einsum("btf,fe->bte",
                            frames.astype(cfg.dtype),
                            params["frontend_proj"])
        enc = _stack_encoder(cfg, params["encoder"], _sp(enc_in))
        enc = L.rms_norm(enc, params["enc_final_norm"], cfg.norm_eps)
        tokens = batch["tokens"]
        x = embed_tokens(cfg, params, tokens)
        positions = jnp.arange(tokens.shape[1])
        x = _stack_encdec_decoder(cfg, params["layers"], x, positions, enc)
    else:
        tokens = batch["tokens"]
        x = embed_tokens(cfg, params, tokens)
        if cfg.family == "vlm":
            patches = jnp.einsum("bpf,fe->bpe",
                                 batch["patches"].astype(cfg.dtype),
                                 params["frontend_proj"])
            npatch = patches.shape[1]
            x = jnp.concatenate([patches, x[:, npatch:]], axis=1)
            x = logical(x, ("batch", "act_seq", "act_embed"))
        positions = jnp.arange(tokens.shape[1])
        if cfg.family in ("dense", "vlm", "moe"):
            x, _ = _stack_dense(cfg, params["layers"], x, positions)
        elif cfg.family == "ssm":
            x, _ = _stack_ssm(cfg, params["layers"], x, positions)
        elif cfg.family == "hybrid":
            x, _ = _stack_hybrid(cfg, params["layers"],
                                 params["shared_attn"], x, positions)
        else:
            raise ValueError(cfg.family)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps)


def loss_fn(cfg: ModelConfig, params, batch):
    x = forward_hidden(cfg, params, batch)
    labels = batch["labels"]
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    return lm_loss_from_hidden(cfg, params, x, labels,
                               mask.astype(jnp.float32))


def logits_fn(cfg: ModelConfig, params, batch):
    return unembed(cfg, params, forward_hidden(cfg, params, batch))
