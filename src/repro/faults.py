"""Deterministic, seedable fault injection for the serving stack.

The resilience subsystem (ISSUE 7) needs accelerator failures ON DEMAND:
compile failures, ``XlaRuntimeError``-style device OOMs, hung/slow
stages and cache-eviction storms, injected at the exact call sites where
the real ones would surface.  This module is the registry those sites
consult.  It is dependency-free and dormant by default: with no specs
installed, :func:`fire` is one attribute read and a falsy check.

Instrumented sites (grep for ``faults.fire``):

==================  =====================================================
site                where it fires
==================  =====================================================
``score.numpy``     every scoring call resolved to the numpy evaluator
``score.jax``       every scoring call resolved to the jax evaluator
``score.pallas``    every scoring call resolved to the pallas kernel
``partition.jax``   the device partition sweep (single and batched)
``fused``           entry of the fused whole-pipeline program
``kernel.mapscore`` inside the pallas wrapper, after its own fallbacks
``serve.compute``   the service's cold path, before the pipeline runs
``serve.cache``     every service request, before the LRU lookup (the
                    ``evict`` kind storms the result cache here)
==================  =====================================================

Faults are configured programmatically (:func:`install`,
:func:`injected`) or via the environment::

    REPRO_FAULTS="score.jax:error:count=1,partition.jax:slow:delay=0.2"

Each comma-separated spec is ``site:kind[:key=value]*``.  ``site`` is an
``fnmatch`` pattern (``score.*`` matches every scoring backend).  Kinds:

``error``    raise :class:`InjectedFault` (a generic backend failure).
``compile``  raise :class:`InjectedCompileError` (lowering/compile
             failure — the kind a new shape bucket can trigger).
``oom``      raise :class:`InjectedDeviceOOM` with an XLA
             ``RESOURCE_EXHAUSTED``-style message.
``slow``     sleep ``delay`` seconds (default 0.05), then continue —
             models a hung kernel / pathological recompile; pair with a
             service deadline to exercise timeout-driven degradation.
``evict``    invoke the site's eviction callback (the serve layer passes
             ``LRUCache.storm``) — a cache-eviction storm.

Options (all optional, integers/floats parsed from the env string):

``count``  fire at most N times, then stay dormant (default: unlimited).
``after``  skip the first N matching calls before arming.
``delay``  sleep seconds for ``slow``.
``prob``   per-call fire probability in [0, 1] (default 1).
``seed``   seeds the spec's private RNG stream, so a probabilistic
           schedule replays IDENTICALLY across runs — chaos testing
           stays deterministic.

Every spec keeps ``calls``/``fired`` counters (:func:`stats`), so tests
and benchmarks can assert a fault actually hit its site.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import os
import random
import threading
import time
from contextlib import contextmanager

ENV_VAR = "REPRO_FAULTS"
KINDS = ("error", "compile", "oom", "slow", "evict")


class InjectedFault(RuntimeError):
    """A fault raised by the injection registry (generic backend error)."""


class InjectedCompileError(InjectedFault):
    """Injected compilation/lowering failure."""


class InjectedDeviceOOM(InjectedFault):
    """Injected device allocator OOM (``RESOURCE_EXHAUSTED`` style)."""


@dataclasses.dataclass
class FaultSpec:
    """One armed fault: where, what, and the deterministic firing rule."""

    site: str                 # fnmatch pattern over site names
    kind: str                 # one of KINDS
    count: int | None = None  # max fires; None = unlimited
    after: int = 0            # skip the first N matching calls
    delay: float = 0.05       # sleep seconds (kind == "slow")
    prob: float = 1.0         # per-call fire probability
    seed: int = 0             # RNG stream for prob < 1 (deterministic)
    calls: int = 0            # matching calls seen (read-only)
    fired: int = 0            # times actually fired (read-only)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; options: {KINDS}")
        self._rng = random.Random(self.seed) if self.prob < 1.0 else None

    def _should_fire(self) -> bool:
        """Advance the spec's counters/RNG; True when the fault fires.

        The RNG draw happens on EVERY armed call (even ones vetoed by
        ``count``), so a spec's firing pattern depends only on its own
        call sequence — replayable under ``seed``.
        """
        self.calls += 1
        if self.calls <= self.after:
            return False
        if self._rng is not None and self._rng.random() >= self.prob:
            return False
        if self.count is not None and self.fired >= self.count:
            return False
        self.fired += 1
        return True


class FaultRegistry:
    """Thread-safe spec store; normally used via the module singleton."""

    def __init__(self):
        self._lock = threading.Lock()
        self._specs: list[FaultSpec] = []

    # -- configuration ---------------------------------------------------

    def install(self, site: str, kind: str, **opts) -> FaultSpec:
        spec = FaultSpec(site, kind, **opts)
        with self._lock:
            self._specs.append(spec)
        return spec

    def remove(self, spec: FaultSpec) -> None:
        with self._lock:
            if spec in self._specs:
                self._specs.remove(spec)

    def clear(self) -> None:
        with self._lock:
            self._specs.clear()

    def load_env(self, value: str | None = None) -> list[FaultSpec]:
        """(Re)install specs from ``REPRO_FAULTS`` (or ``value``)."""
        raw = os.environ.get(ENV_VAR, "") if value is None else value
        return [self.install(site, kind, **opts)
                for site, kind, opts in parse_schedule(raw)]

    # -- the hot hook ----------------------------------------------------

    def fire(self, site: str, on_evict=None) -> None:
        """Consult every armed spec matching ``site`` (cheap when none).

        Non-raising kinds (``slow``/``evict``) act and fall through so a
        later matching spec still gets its turn; raising kinds propagate
        immediately.
        """
        if not self._specs:
            return
        actions = []
        with self._lock:
            for spec in self._specs:
                if fnmatch.fnmatchcase(site, spec.site) \
                        and spec._should_fire():
                    actions.append(spec)
        for spec in actions:
            if spec.kind == "slow":
                time.sleep(spec.delay)
            elif spec.kind == "evict":
                if on_evict is not None:
                    on_evict()
            elif spec.kind == "compile":
                raise InjectedCompileError(
                    f"injected compile failure at {site!r}")
            elif spec.kind == "oom":
                raise InjectedDeviceOOM(
                    "RESOURCE_EXHAUSTED: injected out-of-memory while "
                    f"allocating device buffer at {site!r}")
            else:
                raise InjectedFault(f"injected fault at {site!r}")

    # -- observability ---------------------------------------------------

    def stats(self) -> list[dict]:
        with self._lock:
            return [{"site": s.site, "kind": s.kind, "calls": s.calls,
                     "fired": s.fired} for s in self._specs]

    @property
    def active(self) -> bool:
        return bool(self._specs)


def parse_schedule(raw: str) -> list[tuple]:
    """Parse a ``REPRO_FAULTS`` string into ``(site, kind, opts)`` rows."""
    out = []
    for item in raw.split(","):
        item = item.strip()
        if not item:
            continue
        fields = item.split(":")
        if len(fields) < 2:
            raise ValueError(
                f"bad fault spec {item!r}: want site:kind[:key=value]*")
        site, kind = fields[0], fields[1]
        opts: dict = {}
        for f in fields[2:]:
            key, sep, val = f.partition("=")
            if not sep:
                raise ValueError(f"bad fault option {f!r} in {item!r}")
            if key in ("count", "after", "seed"):
                opts[key] = int(val)
            elif key in ("delay", "prob"):
                opts[key] = float(val)
            else:
                raise ValueError(f"unknown fault option {key!r} in"
                                 f" {item!r}")
        out.append((site, kind, opts))
    return out


# -- module-level singleton API ------------------------------------------

_REGISTRY = FaultRegistry()
_REGISTRY.load_env()


def fire(site: str, on_evict=None) -> None:
    """The site hook: no-op unless a matching spec is armed."""
    if _REGISTRY._specs:
        _REGISTRY.fire(site, on_evict=on_evict)


def install(site: str, kind: str, **opts) -> FaultSpec:
    """Arm one fault programmatically; returns the spec (see
    :func:`remove`)."""
    return _REGISTRY.install(site, kind, **opts)


def remove(spec: FaultSpec) -> None:
    _REGISTRY.remove(spec)


def clear() -> None:
    """Drop every armed spec (including env-installed ones)."""
    _REGISTRY.clear()


def reload_env(value: str | None = None) -> list[FaultSpec]:
    """Install specs from ``REPRO_FAULTS`` (or an explicit string)."""
    return _REGISTRY.load_env(value)


def stats() -> list[dict]:
    """Per-spec ``calls``/``fired`` counters."""
    return _REGISTRY.stats()


def active() -> bool:
    return _REGISTRY.active


# the registry's per-spec counters join the process-wide telemetry
# snapshot (repro.obs is stdlib-only; no import cycle)
from repro import obs as _obs  # noqa: E402

_obs.register_provider("faults", stats)


@contextmanager
def injected(site: str, kind: str, **opts):
    """Arm one fault for the duration of a ``with`` block."""
    spec = install(site, kind, **opts)
    try:
        yield spec
    finally:
        remove(spec)


@contextmanager
def isolated():
    """Suspend every armed spec (env schedules included) for a block.

    Tests that assert EXACT failure counts use this so an ambient chaos
    schedule (the CI chaos job's ``REPRO_FAULTS``) cannot perturb them;
    the suspended specs are restored, counters intact, on exit.
    """
    with _REGISTRY._lock:
        saved, _REGISTRY._specs = _REGISTRY._specs, []
    try:
        yield
    finally:
        with _REGISTRY._lock:
            _REGISTRY._specs = saved
