"""Machine (network) models: meshes/tori with per-link bandwidths.

The paper's machines:

- Cray XK7 *Gemini* 3D torus (Titan): heterogeneous links — X cables
  75 GB/s; Y mezzanine 75 / Y cables 37.5; Z backplane 120 / Z cables 75.
- IBM BlueGene/Q 5D torus: uniform links, block allocations, E dim <= 2.

Our TPU targets (the machines the dry-run meshes run on):

- TPU v5e pod: 16x16 2D torus of chips, ICI ~50 GB/s per link/direction.
- Multi-pod: a slow "pod" dimension (DCN) stitched in front of the ICI
  torus: (npods, 16, 16) with no wraparound and much lower bandwidth.

A :class:`Machine` holds the *full* physical network; an
:class:`Allocation` is the subset of nodes a job received (contiguous on
BG/Q-like systems; sparse/fragmented on Cray/cloud-like systems).  Node
coordinates are integer router coordinates; multicore nodes are modelled
with a trailing "core" dimension whose links are infinitely fast (messages
inside a node cost zero hops — matching the paper's treatment).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .orderings import hilbert_index

INF_BW = float("inf")


@dataclasses.dataclass(frozen=True)
class Machine:
    """A mesh/torus network.

    dims      : physical grid extent per dimension.
    wrap      : per-dimension wraparound (torus) flag.
    link_bw   : per-dimension link bandwidth spec.  Either a scalar
                (uniform along the dim) or a 1D pattern array tiled along
                the dim: ``bw(dim d, link index i) = link_bw[d][i % len]``.
                Link *i* along dim *d* connects coord i -> i+1 (mod extent).
    name      : label for reports.
    core_dims : how many trailing dims are intra-node "core" dims (zero
                network hops; infinite bandwidth).
    """

    dims: tuple[int, ...]
    wrap: tuple[bool, ...]
    link_bw: tuple[np.ndarray, ...]
    name: str = "machine"
    core_dims: int = 0

    @property
    def ndim(self) -> int:
        return len(self.dims)

    @property
    def nnodes(self) -> int:
        return int(np.prod(self.dims))

    @property
    def network_ndim(self) -> int:
        """Network (router) dimensions — ``ndim`` minus the core dims."""
        return self.ndim - self.core_dims

    @property
    def cores_per_node(self) -> int:
        """Cores sharing one router (product of the core dims; 1 when
        the machine has none).  The node-level arity that
        :meth:`repro.hier.HierarchySpec.from_machine` derives."""
        if not self.core_dims:
            return 1
        return int(np.prod(self.dims[self.network_ndim:]))

    def bw(self, dim: int, index: np.ndarray | int):
        pat = self.link_bw[dim]
        return pat[np.asarray(index) % len(pat)]

    def bw_field(self, dim: int) -> np.ndarray:
        """Per-link bandwidth along ``dim`` broadcast to the full machine
        shape: entry at coordinate ``x`` is the bandwidth of the link
        x -> x+e_dim.  One shared helper for every latency computation
        (``Traffic.link_latency``, ``per_dim_stats``,
        ``evaluate_candidates`` and the JAX scoring backend); prepend a
        ``[None]`` axis to broadcast against candidate stacks.
        """
        bw = np.asarray(self.bw(dim, np.arange(self.dims[dim])),
                        dtype=np.float64)
        shape = [1] * self.ndim
        shape[dim] = self.dims[dim]
        return np.broadcast_to(bw.reshape(shape), self.dims)

    def all_coords(self) -> np.ndarray:
        """(nnodes, ndim) integer coordinates of every node, row-major."""
        grids = np.indices(self.dims)
        return np.stack([g.ravel() for g in grids], axis=1)

    def coords_to_index(self, coords: np.ndarray) -> np.ndarray:
        return np.ravel_multi_index(tuple(coords.T), self.dims)


def make_machine(dims, wrap=True, bw=1.0, name="machine", core_dims=0,
                 bw_patterns=None) -> Machine:
    dims = tuple(int(d) for d in dims)
    nd = len(dims)
    if isinstance(wrap, bool):
        wrap = tuple(wrap for _ in dims)
    else:
        wrap = tuple(bool(w) for w in wrap)
    if bw_patterns is None:
        if np.isscalar(bw):
            bw_patterns = [np.array([float(bw)])] * nd
        else:
            bw_patterns = [np.array([float(b)]) for b in bw]
    pats = tuple(np.asarray(p, dtype=np.float64) for p in bw_patterns)
    return Machine(dims, wrap, pats, name=name, core_dims=core_dims)


# ---------------------------------------------------------------------------
# Concrete machines
# ---------------------------------------------------------------------------

def gemini_xk7(dims=(25, 16, 24), cores_per_node: int = 16) -> Machine:
    """Cray XK7 Gemini 3D torus (Titan-like) with heterogeneous links.

    X: uniform cables 75 GB/s.
    Y: alternating mezzanine (75) / cable (37.5) — mezzanine links join
       node pairs, cables join neighbouring mezzanines.
    Z: backplane traces 120 GB/s inside groups of 8, cables 75 between.
    A trailing core dimension models the multicore node (free comms).
    """
    x = np.array([75.0])
    y = np.array([75.0, 37.5])
    z = np.array([120.0] * 7 + [75.0])
    dims = tuple(dims) + (cores_per_node,)
    wrap = (True, True, True, False)
    core = np.array([INF_BW])
    return Machine(dims, wrap, (x, y, z, core), name="cray-xk7", core_dims=1)


def bgq(dims=(4, 4, 4, 8, 2), cores_per_node: int = 16,
        bw_gbs: float = 2.0) -> Machine:
    """IBM BlueGene/Q 5D torus with uniform links (A,B,C,D,E) + core dim."""
    pats = tuple(np.array([bw_gbs]) for _ in dims) + (np.array([INF_BW]),)
    dims = tuple(dims) + (cores_per_node,)
    wrap = tuple(True for _ in range(len(dims) - 1)) + (False,)
    return Machine(dims, wrap, pats, name="bgq", core_dims=1)


def tpu_v5e_pod(side: int = 16, ici_gbs: float = 50.0) -> Machine:
    """Single TPU v5e pod: side x side 2D ICI torus."""
    pats = (np.array([ici_gbs]), np.array([ici_gbs]))
    return Machine((side, side), (True, True), pats, name="tpu-v5e-pod")


def tpu_v5e_multipod(npods: int = 2, side: int = 16,
                     ici_gbs: float = 50.0, dcn_gbs: float = 3.125) -> Machine:
    """Multi-pod v5e: slow non-wrapping DCN dim in front of the ICI torus.

    The DCN "links" connect corresponding chips of adjacent pods — a
    simplification of the real aggregated-NIC fabric, but it gives the
    mapper the right relative cost (DCN ~16x slower than ICI).
    """
    pats = (np.array([dcn_gbs]), np.array([ici_gbs]), np.array([ici_gbs]))
    return Machine((npods, side, side), (False, True, True), pats,
                   name=f"tpu-v5e-{npods}pod")


def tpu_v4_cube(dims=(8, 8, 8), ici_gbs: float = 45.0) -> Machine:
    """TPU v4-like 3D ICI torus."""
    pats = tuple(np.array([ici_gbs]) for _ in dims)
    return Machine(tuple(dims), (True,) * len(dims), pats, name="tpu-v4")


# ---------------------------------------------------------------------------
# Allocations
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Allocation:
    """A set of machine nodes given to a job.

    coords : (n, ndim) integer router coordinates (one row per *core* when
             the machine has a core dim).
    machine: the full machine the nodes belong to.
    """

    machine: Machine
    coords: np.ndarray

    @property
    def n(self) -> int:
        return len(self.coords)


def block_allocation(machine: Machine, block_dims=None) -> Allocation:
    """Contiguous block allocation (BG/Q style).  Default: whole machine."""
    dims = machine.dims if block_dims is None else tuple(block_dims)
    grids = np.indices(dims)
    coords = np.stack([g.ravel() for g in grids], axis=1)
    if len(dims) < machine.ndim:
        pad = np.zeros((len(coords), machine.ndim - len(dims)), dtype=int)
        coords = np.concatenate([coords, pad], axis=1)
    return Allocation(machine, coords)


def _first_free_window(occupied: np.ndarray, sz: int) -> int:
    """Start of the first run of >= ``sz`` consecutive free slots, or -1."""
    free = ~occupied
    if sz <= 0 or not free.any():
        return -1
    # run-length encode the free mask: starts/ends of maximal free runs
    edges = np.diff(free.astype(np.int8))
    starts = np.flatnonzero(edges == 1) + 1
    ends = np.flatnonzero(edges == -1) + 1
    if free[0]:
        starts = np.concatenate([[0], starts])
    if free[-1]:
        ends = np.concatenate([ends, [len(free)]])
    fits = np.flatnonzero(ends - starts >= sz)
    return int(starts[fits[0]]) if len(fits) else -1


def sfc_allocation(machine: Machine, nnodes: int, *, start: int | None = None,
                   nfragments: int = 1, seed: int = 0) -> Allocation:
    """ALPS-like sparse allocation: nodes ordered by a Hilbert SFC over the
    router grid; the job receives ``nfragments`` segments of that ordering
    (fragmentation models other jobs occupying interleaved segments).

    Only router dims participate in the SFC; core dims are expanded after
    selection (all cores of a selected node belong to the job).
    """
    rng = np.random.default_rng(seed)
    rdims = machine.dims[: machine.ndim - machine.core_dims]
    router_grid = np.indices(rdims)
    pts = np.stack([g.ravel() for g in router_grid], axis=1)
    bits = max(1, int(np.ceil(np.log2(max(max(rdims), 2)))))
    h = hilbert_index(pts, bits)
    order = np.argsort(h, kind="stable")
    total = len(pts)
    ncores = int(np.prod(machine.dims[machine.ndim - machine.core_dims:])) \
        if machine.core_dims else 1
    nrouters = (nnodes + ncores - 1) // ncores if machine.core_dims else nnodes
    if nrouters > total:
        raise ValueError("allocation larger than machine")
    if nfragments <= 1:
        s = rng.integers(0, total - nrouters + 1) if start is None else start
        chosen = order[s: s + nrouters]
    else:
        # split the request into fragments placed at random SFC offsets
        sizes = np.full(nfragments, nrouters // nfragments)
        sizes[: nrouters % nfragments] += 1
        segs = []
        occupied = np.zeros(total, dtype=bool)
        for sz in sizes:
            if sz == 0:
                continue
            for _ in range(64):
                s = int(rng.integers(0, total - sz + 1))
                if not occupied[s: s + sz].any():
                    occupied[s: s + sz] = True
                    segs.append(order[s: s + sz])
                    break
            else:
                # fallback: first window of >= sz genuinely free slots
                # (blindly taking free[0] could overlap earlier fragments
                # and duplicate coordinates in the allocation)
                s = _first_free_window(occupied, sz)
                if s < 0:
                    raise ValueError(
                        f"no free window of {sz} contiguous SFC slots for "
                        f"a fragment ({int(occupied.sum())}/{total} "
                        f"occupied); lower nfragments or nnodes")
                occupied[s: s + sz] = True
                segs.append(order[s: s + sz])
        chosen = (np.concatenate(segs)[:nrouters] if segs
                  else np.zeros(0, dtype=np.int64))
    router_coords = pts[chosen]
    if machine.core_dims:
        cdims = machine.dims[machine.ndim - machine.core_dims:]
        cores = np.indices(cdims).reshape(len(cdims), -1).T
        coords = np.concatenate(
            [np.repeat(router_coords, len(cores), axis=0),
             np.tile(cores, (len(router_coords), 1))], axis=1)
        # trim the core expansion of the last router to the exact request
        # (nnodes == 0 must give an EMPTY allocation, not the full grid)
        coords = coords[:nnodes]
    else:
        coords = router_coords
    return Allocation(machine, coords)


def random_allocation(machine: Machine, nnodes: int, seed: int = 0
                      ) -> Allocation:
    """Worst-case scattered allocation (uniform random nodes)."""
    rng = np.random.default_rng(seed)
    rdims = machine.dims[: machine.ndim - machine.core_dims]
    total = int(np.prod(rdims))
    ncores = int(np.prod(machine.dims[machine.ndim - machine.core_dims:])) \
        if machine.core_dims else 1
    nrouters = (nnodes + ncores - 1) // ncores if machine.core_dims else nnodes
    idx = rng.choice(total, size=nrouters, replace=False)
    pts = np.stack(np.unravel_index(idx, rdims), axis=1)
    if machine.core_dims:
        cdims = machine.dims[machine.ndim - machine.core_dims:]
        cores = np.indices(cdims).reshape(len(cdims), -1).T
        coords = np.concatenate(
            [np.repeat(pts, len(cores), axis=0),
             np.tile(cores, (len(pts), 1))], axis=1)[:nnodes]
    else:
        coords = pts
    return Allocation(machine, coords)
