"""JAX scoring backend for the candidate search (accelerator path).

Mirrors :func:`repro.core.metrics.evaluate_candidates` — vectorised
hop metrics plus the batched dimension-ordered router — as one
jit-compiled function so candidate scoring can run on the accelerator
next to the repo's Pallas kernels:

- the circular difference-array range-add of the router becomes a flat
  ``jax.ops.segment_sum`` over ``row*(s+1)+col`` keys followed by a
  per-row prefix sum (the same scatter-free formulation the numpy
  backend uses through ``np.bincount``);
- candidates are ``jax.vmap``-ped over the leading axis of the
  coordinate stack, so one compiled program scores the whole sweep;
- machine structure (dims / wrap / core-dim count) is static, and BOTH
  dynamic shape axes are bucketed to padded power-of-two sizes — the
  message count (zero-weight self-edge padding is exact in the
  difference-array formulation) and the per-chunk candidate count
  (zero-coordinate rows, sliced away) — so one benchmark scenario set
  compiles O(1) times per machine instead of once per (machine, nmsg)
  pair.  :func:`scorer_cache_stats` exposes the hit/miss counters that
  ``benchmarks/run.py --json`` records and tests assert on.

Numbers match the numpy backend within floating-point tolerance (the
router sums in f32 on CPU/TPU defaults; tests/test_batched.py pins the
parity).  This module imports jax at module level — callers go through
``evaluate_candidates(backend="jax")``, which falls back to numpy when
the import fails.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs

from .machine import Machine

MSG_BUCKET_MIN = 128  # smallest padded message-count bucket


def bucket_size(n: int, lo: int = MSG_BUCKET_MIN) -> int:
    """Next power of two >= max(n, lo) — the padded shape every dynamic
    axis is bucketed to before entering jit (zero-weight padding is
    exact, see module docstring)."""
    return max(lo, 1 << max(int(n) - 1, 0).bit_length())


def _circular_range_add(row, start, length, w, nrows, s):
    """Segment-sum difference-array range-add: add ``w`` to the circular
    interval [start, start+length) of each row's length-``s`` lane.
    Column ``s`` is the dump bucket closing wrapped head intervals;
    zero-length messages contribute exact zeros (static shapes — no
    boolean compaction under jit)."""
    base = row * (s + 1)
    end = start + length
    wz = jnp.where(length > 0, w, 0.0)
    wrapped = end > s
    wwr = jnp.where(wrapped, wz, 0.0)
    idx = jnp.concatenate([
        base + start,                         # open [start, ...)
        base + jnp.minimum(end, s),           # close at end (or dump)
        base,                                 # wrapped tail opens at 0
        base + jnp.where(wrapped, end - s, 0),  # ... and closes at end-s
    ])
    val = jnp.concatenate([wz, -wz, wwr, -wwr])
    diff = jax.ops.segment_sum(val, idx, num_segments=nrows * (s + 1))
    return jnp.cumsum(diff.reshape(nrows, s + 1)[:, :s], axis=1)


def _route_one(src, dst, w, dims, wrap, nd):
    """Dimension-ordered routing of one candidate's messages; returns
    per network dim the (+, -) link-load arrays of full machine shape.
    Same traversal as ``metrics._batched_route`` (dim 0 first, shortest
    direction on tori, core coords held at the source's)."""
    pos, neg = [], []
    cur = src
    for k in range(nd):
        s = dims[k]
        a = cur[:, k]
        b = dst[:, k]
        if wrap[k]:
            fwd = (b - a) % s
            bwd = (a - b) % s
            use_fwd = fwd <= bwd
            len_f = jnp.where(use_fwd, fwd, 0)
            len_b = jnp.where(use_fwd, 0, bwd)
            start_b = (a - len_b) % s
        else:
            use_fwd = b >= a
            len_f = jnp.where(use_fwd, b - a, 0)
            len_b = jnp.where(use_fwd, 0, a - b)
            start_b = a - len_b
        row = jnp.zeros_like(a)
        row_dims = tuple(d for j, d in enumerate(dims) if j != k)
        for j in range(len(dims)):
            if j != k:
                row = row * dims[j] + cur[:, j]
        nrows = 1
        for d in row_dims:
            nrows *= d
        lane_p = _circular_range_add(row, a, len_f, w, nrows, s)
        lane_n = _circular_range_add(row, start_b, len_b, w, nrows, s)
        pos.append(jnp.moveaxis(lane_p.reshape(row_dims + (s,)), -1, k))
        neg.append(jnp.moveaxis(lane_n.reshape(row_dims + (s,)), -1, k))
        cur = cur.at[:, k].set(b)
    return tuple(pos), tuple(neg)


def _score_chunk(src, dst, w, bw_fields, *, dims, wrap, core_dims, traffic):
    """Sums-only scoring of one padded (nb_b, ne_b) chunk: averages are
    derived on the host from the TRUE message count (padded entries
    carry zero weight and zero length, so every sum is exact)."""
    nd = len(dims) - core_dims
    hops = jnp.zeros(src.shape[:-1], dtype=jnp.int32)
    for k in range(nd):
        s = dims[k]
        d = jnp.abs(src[..., k] - dst[..., k])
        if wrap[k]:
            d = jnp.minimum(d, s - d)
        hops = hops + d
    hf = hops.astype(jnp.float32)
    out = {
        "weighted_hops": (hf * w[None, :]).sum(axis=-1),
        "total_hops": hops.sum(axis=-1),
    }
    if traffic:
        pos, neg = jax.vmap(
            lambda s_, d_: _route_one(s_, d_, w, dims, wrap, nd))(src, dst)
        nb = src.shape[0]
        data = jnp.zeros(nb)
        lat = jnp.zeros(nb)
        for k in range(nd):
            inv_bw = (1.0 / bw_fields[k])[None]
            for arr in (pos[k], neg[k]):
                data = jnp.maximum(data, arr.reshape(nb, -1).max(axis=1))
                lat = jnp.maximum(
                    lat, (arr * inv_bw).reshape(nb, -1).max(axis=1))
        out["data_max"] = data
        out["latency_max"] = lat
    return out


@functools.lru_cache(maxsize=None)
def _scorer(dims, wrap, core_dims, traffic, ne_bucket, nb_bucket):
    """One jit-compiled scorer per (machine structure, shape bucket).

    ``ne_bucket`` / ``nb_bucket`` are part of the key even though the
    returned function never reads them: every cache entry then sees
    exactly ONE input shape, so jax compiles each entry once and the
    ``lru_cache`` hit/miss counters are a truthful compile-count proxy
    (:func:`scorer_cache_stats`).
    """
    del ne_bucket, nb_bucket  # shape part of the key only
    return jax.jit(functools.partial(_score_chunk, dims=dims, wrap=wrap,
                                     core_dims=core_dims, traffic=traffic))


# registry-backed stat/reset pair (repro.obs): ``misses`` is the number
# of distinct (machine, bucket) programs compiled this process, ``hits``
# the number of calls that reused one; auto-registers with
# ``obs.snapshot()`` under "scorer_jax"
scorer_cache_stats, reset_scorer_cache = obs.instrument_compile_cache(
    "scorer_jax", _scorer)


def pad_axis(arr, size, axis=0):
    """Zero-pad ``arr`` along ``axis`` to ``size`` entries (shared by
    the jax and pallas bucketing paths)."""
    pad = size - arr.shape[axis]
    if pad <= 0:
        return arr
    widths = [(0, 0)] * arr.ndim
    widths[axis] = (0, pad)
    return np.pad(arr, widths)


def evaluate_candidates_jax(machine: Machine, task_edges: np.ndarray,
                            edge_weights: np.ndarray | None,
                            coord_stack: np.ndarray, *,
                            traffic: bool = False,
                            chunk_elems: int = 1 << 24) -> dict:
    """JAX implementation of ``evaluate_candidates`` (same contract,
    results within fp tolerance of the numpy backend).

    Message counts are padded to the enclosing power-of-two bucket with
    zero-weight self-edges (task 0 -> task 0): zero length in every
    dimension and zero weight contribute exact zeros to every sum, max
    and link load, so bucketing changes no result bit while collapsing
    the compile count from O(distinct nmsg) to O(distinct buckets).
    Candidate chunks are likewise processed at power-of-two sizes
    (zero-coordinate padding rows, sliced away).
    """
    coord_stack = np.asarray(coord_stack)
    nb = len(coord_stack)
    ne = len(task_edges)
    out = {
        "weighted_hops": np.zeros(nb),
        "total_hops": np.zeros(nb, dtype=np.int64),
        "average_hops": np.zeros(nb),
    }
    if traffic:
        out["data_max"] = np.zeros(nb)
        out["latency_max"] = np.zeros(nb)
    if ne == 0 or nb == 0:
        return out
    nd = machine.ndim - machine.core_dims
    dims = tuple(int(x) for x in machine.dims)
    wrap = tuple(bool(x) for x in machine.wrap)
    bw_fields = tuple(jnp.asarray(machine.bw_field(k), dtype=jnp.float32)
                      for k in range(nd))

    ne_b = bucket_size(ne)
    edges = pad_axis(np.asarray(task_edges, dtype=np.int64), ne_b)
    w_np = np.ones(ne) if edge_weights is None else \
        np.asarray(edge_weights, dtype=np.float64)
    w = jnp.asarray(pad_axis(w_np, ne_b), dtype=jnp.float32)

    per_cand = max(ne_b * machine.ndim, 1)
    if traffic:
        per_cand += 2 * nd * machine.nnodes
    # power-of-two chunk, rounded DOWN so a chunk never exceeds the
    # caller's chunk_elems bound: full chunks run at one shape, the
    # tail at its enclosing bucket — O(log chunk) compiled shapes per
    # machine total
    chunk = 1 << (max(1, chunk_elems // per_cand).bit_length() - 1)
    c0 = 0
    while c0 < nb:
        n_here = min(chunk, nb - c0)
        nb_b = n_here if n_here == chunk else bucket_size(n_here, lo=1)
        cs = pad_axis(coord_stack[c0:c0 + n_here], nb_b)
        src = jnp.asarray(cs[:, edges[:, 0]], dtype=jnp.int32)
        dst = jnp.asarray(cs[:, edges[:, 1]], dtype=jnp.int32)
        misses0 = _scorer.cache_info().misses
        fn = _scorer(dims, wrap, machine.core_dims, traffic, ne_b, nb_b)
        obs.annotate(compile_cache=(
            "miss" if _scorer.cache_info().misses > misses0 else "hit"))
        ev = fn(src, dst, w, bw_fields)
        sl = slice(c0, c0 + n_here)
        for key in ev:
            if key in out:
                out[key][sl] = np.asarray(ev[key][:n_here],
                                          dtype=out[key].dtype)
        c0 += n_here
    # averages from the TRUE count (int sums are exact, so this is
    # bit-identical to numpy's h.mean over the unpadded edge list)
    out["average_hops"] = out["total_hops"] / ne
    return out
