"""JAX scoring backend for the candidate search (accelerator path).

Mirrors :func:`repro.core.metrics.evaluate_candidates` — vectorised
hop metrics plus the batched dimension-ordered router — as one
jit-compiled function so candidate scoring can run on the accelerator
next to the repo's Pallas kernels:

- the circular difference-array range-add of the router becomes a flat
  ``jax.ops.segment_sum`` over ``row*(s+1)+col`` keys followed by a
  per-row prefix sum (the same scatter-free formulation the numpy
  backend uses through ``np.bincount``);
- candidates are ``jax.vmap``-ped over the leading axis of the
  coordinate stack, so one compiled program scores the whole sweep;
- machine structure (dims / wrap / core-dim count) is static, so each
  (machine, message-count) shape compiles once and is cached for the
  repeated sweeps of the benchmarks.

Numbers match the numpy backend within floating-point tolerance (the
router sums in f32 on CPU/TPU defaults; tests/test_batched.py pins the
parity).  This module imports jax at module level — callers go through
``evaluate_candidates(backend="jax")``, which falls back to numpy when
the import fails.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp

from .machine import Machine


def _circular_range_add(row, start, length, w, nrows, s):
    """Segment-sum difference-array range-add: add ``w`` to the circular
    interval [start, start+length) of each row's length-``s`` lane.
    Column ``s`` is the dump bucket closing wrapped head intervals;
    zero-length messages contribute exact zeros (static shapes — no
    boolean compaction under jit)."""
    base = row * (s + 1)
    end = start + length
    wz = jnp.where(length > 0, w, 0.0)
    wrapped = end > s
    wwr = jnp.where(wrapped, wz, 0.0)
    idx = jnp.concatenate([
        base + start,                         # open [start, ...)
        base + jnp.minimum(end, s),           # close at end (or dump)
        base,                                 # wrapped tail opens at 0
        base + jnp.where(wrapped, end - s, 0),  # ... and closes at end-s
    ])
    val = jnp.concatenate([wz, -wz, wwr, -wwr])
    diff = jax.ops.segment_sum(val, idx, num_segments=nrows * (s + 1))
    return jnp.cumsum(diff.reshape(nrows, s + 1)[:, :s], axis=1)


def _route_one(src, dst, w, dims, wrap, nd):
    """Dimension-ordered routing of one candidate's messages; returns
    per network dim the (+, -) link-load arrays of full machine shape.
    Same traversal as ``metrics._batched_route`` (dim 0 first, shortest
    direction on tori, core coords held at the source's)."""
    pos, neg = [], []
    cur = src
    for k in range(nd):
        s = dims[k]
        a = cur[:, k]
        b = dst[:, k]
        if wrap[k]:
            fwd = (b - a) % s
            bwd = (a - b) % s
            use_fwd = fwd <= bwd
            len_f = jnp.where(use_fwd, fwd, 0)
            len_b = jnp.where(use_fwd, 0, bwd)
            start_b = (a - len_b) % s
        else:
            use_fwd = b >= a
            len_f = jnp.where(use_fwd, b - a, 0)
            len_b = jnp.where(use_fwd, 0, a - b)
            start_b = a - len_b
        row = jnp.zeros_like(a)
        row_dims = tuple(d for j, d in enumerate(dims) if j != k)
        for j in range(len(dims)):
            if j != k:
                row = row * dims[j] + cur[:, j]
        nrows = 1
        for d in row_dims:
            nrows *= d
        lane_p = _circular_range_add(row, a, len_f, w, nrows, s)
        lane_n = _circular_range_add(row, start_b, len_b, w, nrows, s)
        pos.append(jnp.moveaxis(lane_p.reshape(row_dims + (s,)), -1, k))
        neg.append(jnp.moveaxis(lane_n.reshape(row_dims + (s,)), -1, k))
        cur = cur.at[:, k].set(b)
    return tuple(pos), tuple(neg)


@functools.partial(jax.jit,
                   static_argnames=("dims", "wrap", "core_dims", "traffic"))
def _score_chunk(src, dst, w, bw_fields, *, dims, wrap, core_dims, traffic):
    nd = len(dims) - core_dims
    hops = jnp.zeros(src.shape[:-1], dtype=jnp.int32)
    for k in range(nd):
        s = dims[k]
        d = jnp.abs(src[..., k] - dst[..., k])
        if wrap[k]:
            d = jnp.minimum(d, s - d)
        hops = hops + d
    hf = hops.astype(jnp.float32)
    out = {
        "weighted_hops": (hf * w[None, :]).sum(axis=-1),
        "total_hops": hops.sum(axis=-1),
        "average_hops": hf.mean(axis=-1),
    }
    if traffic:
        pos, neg = jax.vmap(
            lambda s_, d_: _route_one(s_, d_, w, dims, wrap, nd))(src, dst)
        nb = src.shape[0]
        data = jnp.zeros(nb)
        lat = jnp.zeros(nb)
        for k in range(nd):
            inv_bw = (1.0 / bw_fields[k])[None]
            for arr in (pos[k], neg[k]):
                data = jnp.maximum(data, arr.reshape(nb, -1).max(axis=1))
                lat = jnp.maximum(
                    lat, (arr * inv_bw).reshape(nb, -1).max(axis=1))
        out["data_max"] = data
        out["latency_max"] = lat
    return out


def evaluate_candidates_jax(machine: Machine, task_edges: np.ndarray,
                            edge_weights: np.ndarray | None,
                            coord_stack: np.ndarray, *,
                            traffic: bool = False,
                            chunk_elems: int = 1 << 24) -> dict:
    """JAX implementation of ``evaluate_candidates`` (same contract,
    same chunking; results within fp tolerance of the numpy backend)."""
    coord_stack = np.asarray(coord_stack)
    nb = len(coord_stack)
    ne = len(task_edges)
    out = {
        "weighted_hops": np.zeros(nb),
        "total_hops": np.zeros(nb, dtype=np.int64),
        "average_hops": np.zeros(nb),
    }
    if traffic:
        out["data_max"] = np.zeros(nb)
        out["latency_max"] = np.zeros(nb)
    if ne == 0 or nb == 0:
        return out
    nd = machine.ndim - machine.core_dims
    dims = tuple(int(x) for x in machine.dims)
    wrap = tuple(bool(x) for x in machine.wrap)
    bw_fields = tuple(jnp.asarray(machine.bw_field(k), dtype=jnp.float32)
                      for k in range(nd))
    w = jnp.asarray(np.ones(ne) if edge_weights is None else edge_weights,
                    dtype=jnp.float32)
    per_cand = max(ne * machine.ndim, 1)
    if traffic:
        per_cand += 2 * nd * machine.nnodes
    chunk = int(max(1, chunk_elems // per_cand))
    for c0 in range(0, nb, chunk):
        cs = coord_stack[c0:c0 + chunk]
        src = jnp.asarray(cs[:, task_edges[:, 0]], dtype=jnp.int32)
        dst = jnp.asarray(cs[:, task_edges[:, 1]], dtype=jnp.int32)
        ev = _score_chunk(src, dst, w, bw_fields, dims=dims, wrap=wrap,
                          core_dims=machine.core_dims, traffic=traffic)
        sl = slice(c0, c0 + len(cs))
        for key, arr in ev.items():
            out[key][sl] = np.asarray(arr, dtype=out[key].dtype)
    return out
