"""Level-synchronous vectorised Multi-Jagged partitioning engine.

The reference implementation of paper Algorithm 2
(:func:`repro.core.orderings.order_points_recursive`) recurses part by
part in Python: every one of the ~``2*nparts`` recursion nodes pays a
Python call, a fancy-index gather, an ``argsort`` and a ``cumsum``.  At
Table-1 scale (2^18+ points, thousands of parts) the interpreter
overhead dominates and the partitioner runs orders of magnitude below
NumPy speed.

This module replaces the recursion with *level-synchronous* sweeps that
cut every active part of one recursion level simultaneously.  Two
engines implement the sweep; both return part numbers **bit-identical**
to the recursive reference (cross-checked in tests/test_partition.py):

``_fast_order`` (primary)
    Sorts each coordinate dimension ONCE up front, then maintains, for
    every dimension, a permutation in which each active segment
    (= recursion node) occupies one contiguous, value-sorted block.
    A level then needs no sorting at all: cut positions come from
    segmented weight prefix sums over the presorted blocks, and the
    splits are applied to every dimension's permutation with O(n)
    vectorised *stable partitions* (one cumsum + one scatter per dim).
    Coordinate flips (FZ / FZlow / Gray) are never materialised — a
    flip only negates one dimension of one segment, so it is tracked as
    a per-(segment, dim) sign and handled by walking the presorted
    block from the other end.  Segment extents are sign-invariant
    (``max(s*x) - min(s*x) == max(x) - min(x)`` exactly in IEEE
    arithmetic), so longest-dimension selection reads only the block
    ends of each presorted permutation.

    The one thing presorted permutations cannot reproduce is the
    reference's *evolving* tie order: ``argsort(kind="stable")`` inside
    the recursion breaks equal coordinates by the order the previous
    levels produced, while the presorted blocks keep the original-index
    order.  Tie order is observable only when a tie group straddles a
    cut boundary (the cut then splits equal points by current order) or
    when float weights are summed across a tie group (different
    summation order, different rounding).  Both situations are detected
    exactly — O(#segments) boundary comparisons per level — and the
    engine restarts on the exact fallback below.  Power-of-two grids,
    distinct coordinates (any weights), and every paper benchmark stay
    on the fast path.

``_exact_order`` (fallback)
    One segmented ``np.lexsort`` per level keyed by ``(segment,
    coordinate)`` — the stability-preserving equivalent of the per-part
    stable ``argsort`` — with materialised coordinate flips.  Handles
    arbitrary tie structure; ~an order of magnitude slower than the
    fast engine but still free of per-part Python overhead.

Both engines are *batched over rotation candidates*: the paper's §4.3
rotation search only permutes the cut-dimension priority of the same
point cloud, so B candidates run as B outermost segments of ONE
level-synchronous sweep (:func:`vectorized_order_batched`).  The
per-dimension presorts are computed once and shared by every candidate;
the per-segment tables (sizes, part counts, flip signs, dim priorities)
simply start with B rows instead of one.  A full rotation sweep thus
costs one engine pass instead of B Python-level partitioner calls —
the mapping pipeline's batched candidate sweep relies on this.

Total fast-path work is O(d * n log n) for the initial sorts plus
O(levels * B * n * d) for the sweeps; ``order_points`` through this
engine is >=10x faster than the recursion at 2^18 points / 4096 parts
(see the ``partition`` entry of ``benchmarks/run.py``; the ``candidates``
entry guards the batched-sweep speedup).
"""

from __future__ import annotations

import numpy as np

__all__ = ["vectorized_order", "vectorized_order_batched"]


# Ceiling for the padded per-segment cumsum buffer (entries).  Above it
# weighted cuts fall back to the recursive-equivalent exact path with a
# per-segment loop (huge, pathologically unbalanced inputs only).
_PAD_CAP = 1 << 26


class _TieFallback(Exception):
    """Fast path detected a cut whose result depends on evolving tie
    order (or an oversized weighted buffer); restart on _exact_order."""


def vectorized_order(
    coords: np.ndarray,
    nparts: int,
    sfc: str,
    *,
    weights: np.ndarray | None = None,
    dim_order: np.ndarray | None = None,
    longest_dim: bool = True,
    uneven_prime: bool = False,
) -> np.ndarray:
    """Level-synchronous Algorithm 2; same contract as ``order_points``.

    Returns the ``(n,)`` int64 part numbers ``mu``, bit-identical to the
    recursive reference.  ``sfc`` must be one of Z | Gray | FZ | FZlow
    (Hilbert is dispatched before reaching the MJ engine).
    """
    coords = np.asarray(coords, dtype=np.float64)
    n = len(coords)
    if nparts <= 1 or n == 0:
        return np.zeros(n, dtype=np.int64)
    d = coords.shape[1]
    dimo = (np.arange(d) if dim_order is None
            else np.asarray(dim_order, dtype=np.int64))
    w = None if weights is None else np.asarray(weights, dtype=np.float64)
    try:
        return _fast_order(coords, nparts, sfc, w, dimo[None], longest_dim,
                           uneven_prime)[0]
    except _TieFallback:
        return _exact_order(coords, nparts, sfc, w, dimo[None], longest_dim,
                            uneven_prime)[0]


def vectorized_order_batched(
    coords: np.ndarray,
    nparts: int,
    sfc: str,
    *,
    dim_orders: np.ndarray,
    weights: np.ndarray | None = None,
    longest_dim: bool = True,
    uneven_prime: bool = False,
) -> np.ndarray:
    """Cut B rotation candidates of one point cloud in a single sweep.

    ``dim_orders`` is a ``(B, d)`` stack of cut-dimension priority
    permutations; row ``b`` of the returned ``(B, n)`` int64 array is
    bit-identical to ``vectorized_order(coords, ..., dim_order=
    dim_orders[b])`` — which in turn equals the partition of the
    column-permuted cloud ``coords[:, dim_orders[b]]``.  Each candidate
    is one outermost segment of the shared level-synchronous machinery,
    so the whole batch costs one engine pass (shared presorts, one
    segment table) instead of B separate calls.
    """
    coords = np.asarray(coords, dtype=np.float64)
    dim_orders = np.atleast_2d(np.asarray(dim_orders, dtype=np.int64))
    nb = len(dim_orders)
    n = len(coords)
    if nparts <= 1 or n == 0:
        return np.zeros((nb, n), dtype=np.int64)
    w = None if weights is None else np.asarray(weights, dtype=np.float64)
    try:
        return _fast_order(coords, nparts, sfc, w, dim_orders, longest_dim,
                           uneven_prime)
    except _TieFallback:
        return _exact_order(coords, nparts, sfc, w, dim_orders, longest_dim,
                            uneven_prime)


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _split_counts_table(values: np.ndarray, uneven_prime: bool):
    """Vectorised mapping nparts -> (npl, npr) through the reference's
    own ``_split_counts`` (largest-prime uneven bisection when
    requested), so the engines can never desync from the oracle."""
    from .orderings import _split_counts
    npl = np.zeros_like(values)
    npr = np.zeros_like(values)
    for v in np.unique(values):
        if v < 2:
            continue
        l, r = _split_counts(int(v), uneven_prime)
        m = values == v
        npl[m] = l
        npr[m] = r
    return npl, npr


def _pick_cut_dims(ext: np.ndarray, sdo: np.ndarray) -> np.ndarray:
    """Longest-dim selection for all segments at once.  Replicates
    ``orderings._longest_dim``: scan each segment's own priority row
    ``sdo[s]``, replacing the best only on a strict ``> best + 1e-12``
    improvement (per-segment rows because batched candidates carry
    different rotation priorities).  One ``take_along_axis`` reorders
    the extents into priority order so the scan runs on plain columns.
    """
    nseg, d = ext.shape
    pri = np.take_along_axis(ext, sdo, axis=1)
    best_p = np.zeros(nseg, dtype=np.int64)
    best_ext = pri[:, 0].copy()
    for p in range(1, d):
        better = pri[:, p] > best_ext + 1e-12
        best_p[better] = p
        best_ext[better] = pri[better, p]
    return np.take_along_axis(sdo, best_p[:, None], axis=1)[:, 0]


def _uniform_cuts(sizes: np.ndarray, ratio: np.ndarray,
                  base: np.ndarray | None = None) -> np.ndarray:
    """Reference cut index for unit weights: ``cw`` of every segment is
    a prefix of [1, 2, 3, ...], so one shared base array serves all
    segments (identical float comparisons to the reference)."""
    maxlen = int(sizes.max())
    if base is None or len(base) < maxlen:
        base = np.arange(1, maxlen + 1, dtype=np.float64)
    targets = sizes.astype(np.float64) * ratio
    k = np.searchsorted(base[:maxlen], targets, side="left") + 1
    return np.minimum(np.maximum(k, 1), sizes - 1)


def _padded_cuts(w_seq: np.ndarray, starts: np.ndarray, sizes: np.ndarray,
                 ratio: np.ndarray, *, on_overflow: str = "raise"
                 ) -> np.ndarray:
    """Reference cut index for per-point weights.

    ``w_seq`` holds the weights in reference visit order, segments
    contiguous at ``starts``/``sizes``.  Row-wise ``np.cumsum`` over a
    padded (nseg, maxlen) buffer performs the exact same sequence of
    float additions as the reference's fresh per-segment ``np.cumsum``,
    keeping the cut placement bit-identical.
    """
    nseg = len(starts)
    maxlen = int(sizes.max())
    if nseg * maxlen > _PAD_CAP:
        if on_overflow == "raise":
            raise _TieFallback
        # exact-engine fallback for pathologically unbalanced inputs: a
        # per-segment loop (still the reference arithmetic)
        k = np.empty(nseg, dtype=np.int64)  # pragma: no cover - huge only
        for i in range(nseg):  # pragma: no cover
            cw = np.cumsum(w_seq[starts[i]:starts[i] + sizes[i]])
            k[i] = np.searchsorted(cw, cw[-1] * ratio[i], side="left") + 1
        return np.minimum(np.maximum(k, 1), sizes - 1)  # pragma: no cover
    pad = np.zeros((nseg, maxlen), dtype=np.float64)
    rows = np.repeat(np.arange(nseg), sizes)
    cols = np.arange(len(w_seq)) - np.repeat(starts, sizes)
    pad[rows, cols] = w_seq
    cw = np.cumsum(pad, axis=1)
    totals = cw[np.arange(nseg), sizes - 1]
    targets = totals * ratio
    in_seg = np.arange(maxlen)[None, :] < sizes[:, None]
    k = ((cw < targets[:, None]) & in_seg).sum(axis=1) + 1
    return np.minimum(np.maximum(k, 1), sizes - 1)


# ---------------------------------------------------------------------------
# fast engine: presorted per-dim permutations + segmented stable partitions
# ---------------------------------------------------------------------------

_IEEE_TOP = np.uint64(0x8000000000000000)
_IEEE_ONES = np.uint64(0xFFFFFFFFFFFFFFFF)
_IEEE_63 = np.uint64(63)


def _presort(col: np.ndarray) -> np.ndarray:
    """Value-ascending argsort at quicksort speed (tie order arbitrary).

    Maps the doubles to order-preserving uint64 keys (IEEE total-order
    trick; ``+0.0`` canonicalises ``-0.0`` first) and argsorts those
    with the default introsort.  The fast engine never relies on tie
    ORDER — every cut whose outcome could depend on it is caught by the
    value-based straddle/tie detection and rerouted to the exact engine
    — so the stable-sort repair pass is unnecessary.
    """
    u = (col + 0.0).view(np.uint64)  # owns its buffer: in-place is safe
    m = u >> _IEEE_63
    m *= _IEEE_ONES
    m |= _IEEE_TOP
    u ^= m
    return np.argsort(u)


def _fast_order(coords, nparts, sfc, w, dim_orders, longest_dim,
                uneven_prime):
    npts, d = coords.shape
    if dim_orders is None:
        dim_orders = np.arange(d, dtype=np.int64)[None]
    nb = len(dim_orders)
    N = nb * npts  # total rows: candidate b owns rows [b*npts, (b+1)*npts)
    if N >= 1 << 31:  # int32 row ids bound the batch (no realistic input)
        raise _TieFallback  # pragma: no cover - exact engine handles it
    # (d, npts) coordinate values, SHARED by every candidate block.  Q
    # stores BLOCK-LOCAL point ids (0..npts-1): values, weights and the
    # first-child table are all indexed locally, so every per-point
    # structure a block touches is candidate-sized and cache-resident
    # however many candidates are stacked — the batched sweep pays no
    # working-set blowup over B separate runs, it only saves.
    cols1 = np.ascontiguousarray(coords.T)
    cols1_flat = cols1.reshape(-1)
    offs = np.arange(nb, dtype=np.int32) * npts
    # int32 permutations: the gather/scatter passes over Q dominate the
    # sweep and are memory-bound, so halving the index width ~halves the
    # engine's DRAM traffic (the batched sweep works on nb*n rows)
    Q = np.empty((d, N), dtype=np.int32)
    for j in range(d):
        ps = _presort(cols1[j]).astype(np.int32)  # shared by every block
        Q[j] = np.tile(ps, nb) if nb > 1 else ps
    q_buf = np.empty_like(Q) if d > 1 else Q  # partition double-buffer
    g_loc = np.empty(npts, dtype=bool) if d > 1 else None  # per-block
    loc = np.arange(npts, dtype=np.int32) if d > 1 else None
    pos = None  # built lazily: unused on the pure-1D fast path

    def _positions():
        nonlocal pos
        if pos is None:
            pos = np.arange(N, dtype=np.int64)
        return pos

    cut_base = np.arange(1, npts + 1, dtype=np.float64)
    weighted = w is not None  # w indexed by block-local ids: no tiling

    # segment table (sorted by start; int32 throughout — the table is
    # rebuilt every level and its traffic shows at deep levels);
    # signs[s, j] = net flip of dim j; base[s] = part offset of the
    # segment (mu is scattered once at end); sdo[s] = the segment's
    # cut-dimension priority row (candidates of a batched sweep differ
    # only here; children inherit their parent's row)
    starts = offs.copy()
    sizes = np.full(nb, npts, dtype=np.int32)
    pnum = np.full(nb, nparts, dtype=np.int32)
    base = np.zeros(nb, dtype=np.int32)
    signs = np.ones((nb, d), dtype=np.int8)
    sdo = np.ascontiguousarray(np.asarray(dim_orders, dtype=np.int64))
    level = 0
    # ``pending``: when the final level's split was computed but never
    # applied to the permutation rows, the closing scatter must read
    # each candidate block through that level's cut layout (a block's
    # own cut-dim row splits in place).  None = every row matches the
    # final segment table.
    pending = None

    while True:
        act = (pnum > 1) & (sizes > 1)
        if not act.any():
            break
        nseg = len(starts)
        ends = starts + sizes

        # --- candidate blocks of the segment table ----------------------
        # Each candidate's segments stay inside its contiguous row block
        # [b*npts, (b+1)*npts); per-point work below runs per block, so
        # every pass operates on cache-sized slices however many
        # candidates are stacked.
        if nb == 1:
            seg_b = np.array([0, nseg])
        else:
            seg_b = np.append(np.searchsorted(starts, offs), nseg)

        # --- cut dimension ----------------------------------------------
        # Each block of Q[j] is ascending along dim j, so per-segment
        # extents are just the block-end values; extents are also
        # flip-invariant (max(s*x) - min(s*x) == max(x) - min(x) exactly)
        if d == 1:  # only one dimension to cut
            cut = np.zeros(nseg, dtype=np.int64)
            sgn = signs[:, 0]
        elif longest_dim:
            lo = np.empty((nseg, d))
            hi = np.empty((nseg, d))
            for j in range(d):
                lo[:, j] = cols1[j][Q[j, starts]]
                hi[:, j] = cols1[j][Q[j, ends - 1]]
            cut = _pick_cut_dims(hi - lo, sdo)
            sgn = signs[np.arange(nseg), cut]
        else:
            cut = sdo[:, level % d]  # never mutated: view is fine
            sgn = signs[np.arange(nseg), cut]
        one_dim = d == 1 or (cut[act] == cut[act][0]).all()
        c0 = int(cut[act][0])

        # per-block uniform cut dim over the ACTIVE segments (-1 = no
        # active segment in the block, -2 = mixed cut dims)
        if d > 1:
            blocks = []
            for bi in range(nb):
                s0, s1 = int(seg_b[bi]), int(seg_b[bi + 1])
                a_sl = act[s0:s1]
                if not a_sl.any():
                    u = -1
                else:
                    cb = cut[s0:s1][a_sl]
                    u = int(cb[0]) if (cb == cb[0]).all() else -2
                blocks.append((bi * npts, (bi + 1) * npts, s0, s1, u))

        # --- split counts + cut index k (in reference visit order) ------
        if uneven_prime:
            npl, npr = _split_counts_table(np.where(act, pnum, 0), True)
        else:  # plain bisection: no table walk needed
            npl = np.where(act, pnum >> 1, 0)
            npr = np.where(act, pnum - (pnum >> 1), 0)
        ratio = np.where(act, npl / np.maximum(pnum, 1), 0.0)
        if weighted and not one_dim:
            cut_pt = np.repeat(cut, sizes)
        if not weighted:
            k = _uniform_cuts(sizes, ratio, cut_base).astype(np.int32)
        else:
            # weight sequence in reference order: ascending block for
            # sign +1, descending for sign -1 (no ties on this path)
            start_pt = np.repeat(starts, sizes)
            sgn_pt = np.repeat(sgn, sizes)
            end_pt = np.repeat(ends, sizes)
            p_ = _positions()
            asc = np.where(sgn_pt > 0, p_, start_pt + end_pt - 1 - p_)
            if one_dim:
                w_seq = w[Q[c0, asc]]
            else:
                w_seq = w[Q.reshape(-1)[cut_pt * N + asc]]
            blk = np.repeat(np.arange(nseg), sizes)
            ab = act[blk]
            a_sz = sizes[act]
            k = np.ones(nseg, dtype=np.int32)
            k[act] = _padded_cuts(w_seq[ab], np.cumsum(a_sz) - a_sz, a_sz,
                                  ratio[act])
        # first child block holds kappa points: the reference's left
        # child for sign +1, its right child for sign -1
        kappa = np.where(act, np.where(sgn > 0, k, sizes - k), sizes)

        # --- tie detection ----------------------------------------------
        a = np.flatnonzero(act)
        if weighted:
            # any tie inside an active block reorders the weight cumsum;
            # compare adjacent sorted values per active block
            if one_dim:
                v_blk = cols1[c0][Q[c0]]
            else:
                qg = Q.reshape(-1)[cut_pt * N + _positions()]
                v_blk = cols1_flat[cut_pt * npts + qg]
            same = (blk[1:] == blk[:-1]) & ab[:-1]
            if (same & (v_blk[1:] == v_blk[:-1])).any():
                raise _TieFallback
        else:
            b0 = starts[a] + kappa[a] - 1
            b1 = b0 + 1
            ca = cut[a]
            q0 = Q.reshape(-1)[ca * N + b0]  # block-local point ids
            q1 = Q.reshape(-1)[ca * N + b1]
            if (cols1_flat[ca * npts + q0]
                    == cols1_flat[ca * npts + q1]).any():
                raise _TieFallback

        # --- next level's segment table (mu deferred via base) ----------
        prev_starts, prev_sizes = starts, sizes
        s2 = sizes - kappa  # 0 for inactive segments
        p1 = np.where(act, np.where(sgn > 0, npl, npr), pnum)
        p2 = np.where(sgn > 0, npr, npl)
        # reference: left child keeps base, right child gets base + npl
        b1_ = np.where(act, np.where(sgn > 0, base, base + npl), base)
        b2_ = np.where(sgn > 0, base + npl, base)
        new_starts = np.repeat(starts, 2)
        new_starts[1::2] += kappa
        new_sizes = np.stack([kappa, s2], axis=1).reshape(-1)
        new_pnum = np.stack([p1, p2], axis=1).reshape(-1)
        new_base = np.stack([b1_, b2_], axis=1).reshape(-1)
        new_signs = np.repeat(signs, 2, axis=0)
        new_sdo = np.repeat(sdo, 2, axis=0)
        if sfc in ("Gray", "FZ", "FZlow"):
            # reference-right child = second block for sign +1, first
            # block for sign -1; FZlow flips the reference-LEFT child
            flip_left = sfc == "FZlow"
            child = np.where((sgn > 0) != flip_left, 1, 0)
            rows = (2 * np.arange(nseg) + child)[act]
            if sfc == "Gray":
                new_signs[rows] = -new_signs[rows]
            else:
                new_signs[rows, cut[act]] = -new_signs[rows, cut[act]]
        keep = new_sizes > 0
        if keep.all():  # common case: every active split fills both sides
            starts, sizes, pnum = new_starts, new_sizes, new_pnum
            base, signs, sdo = new_base, new_signs, new_sdo
        else:
            starts = new_starts[keep]
            sizes = new_sizes[keep]
            pnum = new_pnum[keep]
            base = new_base[keep]
            signs = new_signs[keep]
            sdo = new_sdo[keep]
        level += 1

        if not ((pnum > 1) & (sizes > 1)).any():
            if d > 1:
                pending = (blocks, cut, prev_sizes)
            break
        if d == 1:
            continue  # blocks of the only dim split in place

        # --- apply the splits to the other dims' permutations -----------
        # Stable partition per dim, per candidate block: each segment's
        # first-child members move to the front, second-child members to
        # the back, both in block order, so every segment stays
        # value-sorted.  A block's own cut-dim row splits in place (its
        # partition is the identity), so that row is just copied.  The
        # first-child table ``g_loc`` is block-local and reused block to
        # block, so the whole inner loop runs on candidate-sized arrays.
        for r0, r1, s0, s1, u in blocks:
            if u == -1:  # no active segment: rows already match
                q_buf[:, r0:r1] = Q[:, r0:r1]
                continue
            thr = np.repeat(prev_starts[s0:s1] + kappa[s0:s1] - r0,
                            prev_sizes[s0:s1])
            g_sl = loc < thr  # first-child membership by local position
            if u >= 0:
                g_loc[Q[u, r0:r1]] = g_sl
            else:
                cp = np.repeat(cut[s0:s1], prev_sizes[s0:s1])
                flat = cp * N
                flat += np.arange(r0, r1, dtype=np.int64)
                g_loc[Q.reshape(-1)[flat]] = g_sl
            bs = prev_starts[s0:s1] - r0
            szs = prev_sizes[s0:s1]
            kp = kappa[s0:s1]
            # Positions [0, bs) of EVERY row hold the points of the
            # earlier segments (rows share the segment blocks, only the
            # order within differs), so the first-child count before a
            # segment is the row-independent exclusive cumsum of kappa
            # — both destination offsets hoist out of the dim loop:
            # dest_first = T + (start - c_ex);  dest_second = local +
            # (kappa + c_ex) - T, with T the row's first-child prefix.
            c_ex = np.cumsum(kp, dtype=np.int32) - kp
            a_pt = np.repeat(bs - c_ex, szs)
            b_pt = np.repeat(kp + c_ex, szs)
            b_pt += loc
            for j in range(d):
                if u == j:
                    q_buf[j, r0:r1] = Q[j, r0:r1]
                    continue
                Qj = Q[j, r0:r1]
                G = g_loc[Qj]
                T = np.empty(npts, dtype=np.int32)  # exclusive prefix of G
                T[0] = 0
                np.cumsum(G[:-1], dtype=np.int32, out=T[1:])
                d2 = b_pt - T
                T += a_pt
                np.logical_not(G, out=G)
                np.copyto(T, d2, where=G)  # T becomes dest in place
                q_buf[j, r0:r1][T] = Qj
        Q, q_buf = q_buf, Q

    mu = np.empty(N, dtype=np.int32)  # nparts <= npts, N < 2^31
    vals = np.repeat(base.astype(np.int32), sizes)
    if pending is None:  # every row matches the final table
        for bi in range(nb):
            r0 = bi * npts
            mu[r0:r0 + npts][Q[0, r0:r0 + npts]] = vals[r0:r0 + npts]
    else:
        blocks, cut, prev_sizes = pending
        for r0, r1, s0, s1, u in blocks:
            if u == -2:
                cp = np.repeat(cut[s0:s1], prev_sizes[s0:s1])
                flat = cp * N
                flat += np.arange(r0, r1, dtype=np.int64)
                mu[r0:r1][Q.reshape(-1)[flat]] = vals[r0:r1]
            else:  # untouched block or uniform cut: one row matches
                mu[r0:r1][Q[max(u, 0), r0:r1]] = vals[r0:r1]
    return mu.astype(np.int64).reshape(nb, npts)


# ---------------------------------------------------------------------------
# exact engine: one segmented lexsort per level, materialised flips
# ---------------------------------------------------------------------------

def _exact_order(coords, nparts, sfc, w, dim_orders, longest_dim,
                 uneven_prime):
    npts, d = coords.shape
    if dim_orders is None:
        dim_orders = np.arange(d, dtype=np.int64)[None]
    nb = len(dim_orders)
    # candidates flip coordinates independently -> each owns a copy
    coords = np.tile(coords, (nb, 1)) if nb > 1 else coords.copy()
    N = nb * npts
    mu = np.zeros(N, dtype=np.int64)
    weighted = w is not None
    if weighted and nb > 1:
        w = np.tile(w, nb)

    order = np.arange(N)
    starts = np.arange(nb, dtype=np.int64) * npts
    sizes = np.full(nb, npts, dtype=np.int64)
    seg_np = np.full(nb, nparts, dtype=np.int64)
    sdo = np.ascontiguousarray(np.asarray(dim_orders, dtype=np.int64))
    level = 0

    while True:
        active = (seg_np > 1) & (sizes > 1)
        if not active.any():
            break
        a_starts = starts[active]
        a_sizes = sizes[active]
        a_np = seg_np[active]

        # --- cut dimension per active segment ---------------------------
        if longest_dim:
            vals = coords[order]
            hi = np.maximum.reduceat(vals, starts, axis=0)
            lo = np.minimum.reduceat(vals, starts, axis=0)
            cut = _pick_cut_dims(hi - lo, sdo)[active]
        else:
            cut = sdo[active][:, level % d].copy()

        # active-point positions (a union of contiguous blocks of order)
        p_starts = np.cumsum(a_sizes) - a_sizes  # packed per-segment starts
        pos = (np.repeat(a_starts - p_starts, a_sizes)
               + np.arange(int(a_sizes.sum())))
        seg_of = np.repeat(np.arange(len(a_starts)), a_sizes)

        # --- segmented stable sort along each segment's cut dim ---------
        pts = order[pos]
        key = coords[pts, cut[seg_of]]
        perm = np.lexsort((key, seg_of))
        pts = pts[perm]
        order[pos] = pts

        # --- cut placement ----------------------------------------------
        npl, npr = _split_counts_table(a_np, uneven_prime)
        ratio = npl / a_np
        if not weighted:
            k = _uniform_cuts(a_sizes, ratio)
        else:
            k = _padded_cuts(w[pts], p_starts, a_sizes, ratio,
                             on_overflow="loop")

        # --- flips + part-number updates --------------------------------
        in_seg_idx = np.arange(len(pts)) - p_starts[seg_of]
        right = in_seg_idx >= k[seg_of]
        r_pts = pts[right]
        if sfc == "Gray":
            coords[r_pts] = -coords[r_pts]
        elif sfc == "FZ":
            rc = cut[seg_of[right]]
            coords[r_pts, rc] = -coords[r_pts, rc]
        elif sfc == "FZlow":
            l_pts = pts[~right]
            lc = cut[seg_of[~right]]
            coords[l_pts, lc] = -coords[l_pts, lc]
        mu[r_pts] += npl[seg_of[right]]

        # --- next level's segment table ---------------------------------
        # The start-sorted rebuild needs no argsort: children occupy
        # their parent's position (the left child keeps the parent's
        # start, the right child's start is strictly between the parent
        # and its successor), so with ``c_ex`` = exclusive running count
        # of splits, segment i of the old table lands at ``i + c_ex[i]``
        # and active segments' right children at the slot after — an
        # O(nseg) interleave instead of the former concatenate +
        # O(nseg log nseg) ``argsort(starts)`` whose traffic showed at
        # deep part counts.
        nseg = len(starts)
        c_ex = np.cumsum(active) - active
        pos_all = np.arange(nseg) + c_ex
        pos_r = pos_all[active] + 1
        n_out = nseg + int(c_ex[-1] + active[-1])
        k_full = np.zeros(nseg, dtype=k.dtype)
        k_full[active] = k
        npl_full = np.zeros(nseg, dtype=npl.dtype)
        npl_full[active] = npl

        new_starts = np.empty(n_out, dtype=starts.dtype)
        new_starts[pos_all] = starts  # left child keeps the parent start
        new_starts[pos_r] = a_starts + k
        new_sizes = np.empty(n_out, dtype=sizes.dtype)
        new_sizes[pos_all] = np.where(active, k_full, sizes)
        new_sizes[pos_r] = a_sizes - k
        new_np = np.empty(n_out, dtype=seg_np.dtype)
        new_np[pos_all] = np.where(active, npl_full, seg_np)
        new_np[pos_r] = npr
        new_sdo = np.empty((n_out, sdo.shape[1]), dtype=sdo.dtype)
        new_sdo[pos_all] = sdo  # children inherit the parent's row
        new_sdo[pos_r] = sdo[active]
        starts, sizes, seg_np, sdo = new_starts, new_sizes, new_np, new_sdo
        level += 1

    return mu.reshape(nb, npts)
