"""Subset selection for tnum < pnum (paper §4.2 case 3).

When a job has fewer tasks than allocated cores, the mapper selects the
"closest" subset of ``tnum`` cores (modified K-means in the paper, after
Hartigan & Wong): iteratively take the ``tnum`` cores nearest to the
centroid of the current selection until the selection stabilises.
"""

from __future__ import annotations

import numpy as np


def closest_subset(coords: np.ndarray, k: int, *, iters: int = 32,
                   seed: int = 0) -> np.ndarray:
    """Indices of a compact subset of ``k`` points of ``coords``."""
    coords = np.asarray(coords, dtype=np.float64)
    n = len(coords)
    if k >= n:
        return np.arange(n)
    centre = coords.mean(axis=0)
    chosen = None
    for _ in range(iters):
        d = np.linalg.norm(coords - centre, axis=1)
        new = np.argpartition(d, k)[:k]
        new_sorted = np.sort(new)
        if chosen is not None and np.array_equal(new_sorted, chosen):
            break
        chosen = new_sorted
        centre = coords[chosen].mean(axis=0)
    return chosen
