"""JAX backend for the level-synchronous Multi-Jagged partitioner.

Implements the ``vectorized_order`` / ``vectorized_order_batched``
contract on device: the recursion-level loop of paper Algorithm 2 runs
as a ``lax.while_loop`` over fixed-shape state, with

- the per-level *segmented stable partition* expressed as one
  ``lax.sort`` over composite ``(segment, coordinate)`` keys,
- segment extents (longest-dimension selection) via
  ``jax.ops.segment_max/min`` keyed by segment start,
- cut placement via per-position rank comparisons (unit weights) or a
  sequential per-segment weight prefix scan (``lax.scan``), and
- the SFC coordinate flips as masked negations.

The representation is *positional*: instead of a growing segment table,
every one of the ``nb_b * npts_b`` padded point slots carries its
segment's ``(start, size, nparts)`` — constant within a segment, so the
within-segment stable sorts never have to move the table at all and the
whole state is fixed-shape int32/float64 arrays (what ``while_loop``
requires).  Candidate ``b`` of a batched sweep owns slot block
``[b*npts_b, (b+1)*npts_b)``; padding tails are closed single-part
segments that the sweep never activates, so bucketing changes no result
bit.

Bit-identity with the numpy engines (the ``np.lexsort`` tie order of
``partition._exact_order`` is the oracle) requires three deliberate
choices, each load-bearing:

- ``jax_enable_x64``: every cut target (``size * npl/np``) and weight
  prefix sum in the oracle is float64; f32 rounds cuts differently.
- ``+ 0.0`` key canonicalisation before ``lax.sort``: XLA's total-order
  float comparator sorts ``-0.0 < 0.0`` where numpy treats them as
  equal ties — and the FZ/FZlow/Gray flips mint ``-0.0`` routinely.
- a *sequential* ``lax.scan`` for weighted prefix sums: a parallel
  prefix scan re-associates the additions and rounds differently from
  numpy's left-to-right ``np.cumsum``.

Shape bucketing + the keyed compile cache mirror
:mod:`repro.core.metrics_jax`: point counts pad to power-of-two buckets
(``PART_BUCKET_MIN`` floor) with the real count passed as a *traced*
scalar, the ``uneven_prime`` split table ships as a padded traced
array, and :func:`partition_cache_stats` exposes the truthful
compile-count counters benchmarks assert on.

This module imports jax at module level — callers go through
``repro.core.orderings`` (``backend="jax"`` / the
``resolve_partition_backend`` chain), which falls back silently to the
numpy engine when the import fails.
"""

from __future__ import annotations

import functools

import numpy as np

import jax

# Bit-identity with the float64 numpy oracle is impossible at f32 (cut
# targets and weight prefix sums round differently), so this backend
# requires x64.  Process-wide but safe: the score backends pin f32
# explicitly on every array they build.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax import lax  # noqa: E402

from repro import obs  # noqa: E402

from .metrics_jax import bucket_size, pad_axis  # noqa: E402
from .orderings import _split_counts, hilbert_bits  # noqa: E402

__all__ = ["order_points_jax", "order_points_batched_jax",
           "partition_cache_stats", "reset_partition_cache"]

PART_BUCKET_MIN = 256  # smallest padded point-count bucket
TAB_MIN = 16           # smallest padded split-table bucket

_I32 = jnp.int32
_F64 = jnp.float64


# ---------------------------------------------------------------------------
# split-count table (host side, shipped as a traced array)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _split_table(nparts: int, uneven_prime: bool) -> np.ndarray:
    """``tab[v] = npl`` of splitting ``v`` parts, for every v <= nparts
    (0 for v < 2).  Matches ``orderings._split_counts`` exactly; for
    ``uneven_prime`` a smallest-slice sieve supplies largest prime
    factors so Table-scale part counts stay cheap to tabulate."""
    m = int(nparts)
    tab = np.zeros(m + 1, dtype=np.int32)
    if m < 2:
        return tab
    v = np.arange(2, m + 1, dtype=np.int64)
    if not uneven_prime:
        tab[2:] = (v // 2).astype(np.int32)
        return tab
    lpf = np.zeros(m + 1, dtype=np.int64)
    for p in range(2, m + 1):
        if lpf[p] == 0:  # p is prime: overwrite multiples ascending
            lpf[p::p] = p
    p = lpf[2:]
    k = p // 2
    npl = np.where(p <= 2, v // 2, (k * v) // p)
    # cross-check the reference on a few rows (cheap, catches drift)
    for probe in {2, 3, m, _largest(m)} & set(range(2, m + 1)):
        assert int(npl[probe - 2]) == _split_counts(probe, True)[0]
    tab[2:] = npl.astype(np.int32)
    return tab


def _largest(m: int) -> int:
    return max(2, m - 1)


# ---------------------------------------------------------------------------
# the device sweep
# ---------------------------------------------------------------------------

def _sweep(cols, sdo, w, npl_tab, n, B, nparts, *, d, sfc, longest_dim,
           weighted, npts_b, nb_b):
    """One whole batched partition on device.

    cols    : (d, npts_b) f64 — the shared, padded point cloud.
    sdo     : (nb_b, d) i32 — per-candidate cut-dimension priority rows.
    w       : (npts_b,) f64 — point weights (ignored unless weighted).
    npl_tab : (tab_b,) i32 — npl lookup by current part count.
    n, B, nparts : traced scalars (real points / candidates / parts), so
        they stay OUT of the compile key; only the buckets are static.

    Returns (nb_b, npts_b) i32 part numbers in original point order.
    """
    N = nb_b * npts_b
    pos = jnp.arange(N, dtype=_I32)
    local = pos % npts_b
    block = pos // npts_b
    blk0 = block * npts_b
    real = (block < B) & (local < n)
    # segment layout: block b = [one real segment of n points][pad tail
    # as its own closed segment]; whole pad blocks are closed segments
    seg_start = jnp.where(real, blk0, jnp.where(block < B, blk0 + n, blk0)
                          ).astype(_I32)
    seg_size = jnp.where(real, n, jnp.where(block < B, npts_b - n, npts_b)
                         ).astype(_I32)
    seg_np = jnp.where(real, nparts, 1).astype(_I32)
    mu = jnp.zeros(N, dtype=_I32)
    pts = pos  # global slot id of the point at each position
    colsN = jnp.tile(cols, (1, nb_b))          # (d, N), diverges via flips
    wN = jnp.tile(w, nb_b) if weighted else None
    sdoN = jnp.repeat(sdo, npts_b, axis=0)     # (N, d), loop-invariant
    dims_col = jnp.arange(d, dtype=_I32)[:, None]

    def cond(state):
        _, _, _, _, seg_size, seg_np, _ = state
        return jnp.any((seg_np > 1) & (seg_size > 1))

    def body(state):
        level, pts, mu, seg_start, seg_size, seg_np, colsN = state
        act = (seg_np > 1) & (seg_size > 1)

        # --- cut dimension (reference: _pick_cut_dims / alternation) ----
        if d == 1:
            cut = jnp.zeros(N, dtype=_I32)
        elif longest_dim:
            exts = []
            for j in range(d):
                hi = jax.ops.segment_max(colsN[j], seg_start,
                                         num_segments=N,
                                         indices_are_sorted=True)
                lo = jax.ops.segment_min(colsN[j], seg_start,
                                         num_segments=N,
                                         indices_are_sorted=True)
                exts.append((hi - lo)[seg_start])
            ext = jnp.stack(exts, axis=1)                      # (N, d)
            pri = jnp.take_along_axis(ext, sdoN, axis=1)
            best_p = jnp.zeros(N, dtype=_I32)
            best_e = pri[:, 0]
            for p in range(1, d):
                better = pri[:, p] > best_e + 1e-12
                best_p = jnp.where(better, p, best_p)
                best_e = jnp.where(better, pri[:, p], best_e)
            cut = jnp.take_along_axis(sdoN, best_p[:, None], axis=1)[:, 0]
        else:
            cut = jnp.take(sdoN, level % d, axis=1).astype(_I32)

        # --- segmented stable sort by the cut coordinate ----------------
        # ``+ 0.0`` canonicalises -0.0 so XLA's total-order comparator
        # agrees with numpy's equal-zeros tie handling
        flat = cut.astype(jnp.int64) * N + jnp.arange(N, dtype=jnp.int64)
        ckey = colsN.reshape(-1)[flat] + 0.0
        ops = (seg_start, ckey, pts) + tuple(colsN[j] for j in range(d))
        ops = lax.sort(ops, num_keys=2, is_stable=True)
        pts = ops[2]
        colsN = jnp.stack(ops[3:], axis=0)
        # mu / seg_* are constant within segments, so the within-segment
        # permutation leaves them correct without riding the sort
        rank = pos - seg_start

        # --- cut placement (reference: _uniform_cuts / _padded_cuts) ----
        npl = npl_tab[seg_np]
        ratio = npl.astype(_F64) / seg_np.astype(_F64)
        target = seg_size.astype(_F64) * ratio
        if not weighted:
            below = (rank + 1).astype(_F64) < target
        else:
            w_cur = wN[pts]
            is_start = rank == 0

            def scan_f(c, xw):
                wi, st = xw
                c = jnp.where(st, wi, c + wi)
                return c, c

            _, cw = lax.scan(scan_f, jnp.float64(0.0), (w_cur, is_start),
                             unroll=8)
            last = (seg_start + seg_size - 1).astype(jnp.int64)
            below = cw < cw[last] * ratio
        k = jax.ops.segment_sum(below.astype(_I32), seg_start,
                                num_segments=N,
                                indices_are_sorted=True)[seg_start] + 1
        k = jnp.clip(k, 1, jnp.maximum(seg_size - 1, 1)).astype(_I32)

        # --- flips + part numbers + child segments ----------------------
        right = rank >= k
        ar = act & right
        mu = mu + jnp.where(ar, npl, 0).astype(_I32)
        if sfc == "Gray":
            colsN = jnp.where(ar[None, :], -colsN, colsN)
        elif sfc == "FZ":
            oh = (dims_col == cut[None, :]) & ar[None, :]
            colsN = jnp.where(oh, -colsN, colsN)
        elif sfc == "FZlow":
            oh = (dims_col == cut[None, :]) & (act & ~right)[None, :]
            colsN = jnp.where(oh, -colsN, colsN)
        seg_np = jnp.where(act, jnp.where(right, seg_np - npl, npl),
                           seg_np).astype(_I32)
        seg_start = jnp.where(ar, seg_start + k, seg_start).astype(_I32)
        seg_size = jnp.where(act, jnp.where(right, seg_size - k, k),
                             seg_size).astype(_I32)
        return (level + 1, pts, mu, seg_start, seg_size, seg_np, colsN)

    state = (jnp.int32(0), pts, mu, seg_start, seg_size, seg_np, colsN)
    state = lax.while_loop(cond, body, state)
    _, pts, mu = state[0], state[1], state[2]
    out = jnp.zeros(N, dtype=_I32).at[pts].set(mu, unique_indices=True)
    return out.reshape(nb_b, npts_b)


def _hilbert_sweep(cols, sdo, w, npl_tab, n, B, nparts, *, d, bits,
                   weighted, npts_b, nb_b):
    """Batched Hilbert numbering on device (Skilling's transpose
    algorithm, mirroring ``orderings.hilbert_index`` /
    ``orderings._hilbert_split`` op for op).

    Same call signature as :func:`_sweep` so both share ``_engine``'s
    compile cache.  ``bits`` is static — the Skilling state machine and
    the bit interleave unroll over it.  The per-candidate dim-order is
    folded in as a *column gather on the quantised grid*: quantisation
    is per-dimension, so it commutes with column permutation and runs
    once for the whole sweep; candidate ``b`` is then bit-identical to
    the host ``order_points(coords[:, dim_orders[b]], nparts, "H")``.
    """
    del npl_tab  # Hilbert splits need no npl table
    N = nb_b * npts_b
    side = 1 << bits
    # --- quantise once (reference: orderings._hilbert_quantise) --------
    maskp = jnp.arange(npts_b) < n
    lo = jnp.min(jnp.where(maskp[None, :], cols, jnp.inf), axis=1)
    hi = jnp.max(jnp.where(maskp[None, :], cols, -jnp.inf), axis=1)
    span = hi - lo
    span = jnp.where(span > 0, span, 1.0)
    q = jnp.clip(jnp.round((cols - lo[:, None]) / span[:, None]
                           * (side - 1)).astype(jnp.int64), 0, side - 1)
    # --- fold the dim-order: Xc[i] = q[sdo[b, i]] per candidate --------
    sdo64 = sdo.astype(jnp.int64)
    Xc = [jnp.take(q, sdo64[:, i], axis=0).reshape(-1) for i in range(d)]
    # --- Skilling transpose (reference: orderings.hilbert_index) -------
    if d == 1:
        h = Xc[0]
    else:
        M = 1 << (bits - 1)
        Q = M
        while Q > 1:  # inverse undo excess work
            P = Q - 1
            for i in range(d):
                has = (Xc[i] & Q) != 0
                Xc[0] = jnp.where(has, Xc[0] ^ P, Xc[0])
                t = jnp.where(has, 0, (Xc[0] ^ Xc[i]) & P)
                Xc[0] = Xc[0] ^ t
                Xc[i] = Xc[i] ^ t
            Q >>= 1
        for i in range(1, d):  # Gray encode
            Xc[i] = Xc[i] ^ Xc[i - 1]
        t = jnp.zeros(N, dtype=jnp.int64)
        Q = M
        while Q > 1:
            has = (Xc[d - 1] & Q) != 0
            t = jnp.where(has, t ^ (Q - 1), t)
            Q >>= 1
        for i in range(d):
            Xc[i] = Xc[i] ^ t
        h = jnp.zeros(N, dtype=jnp.int64)
        for i in range(d):  # interleave: bit b of dim i -> b*d + (d-1-i)
            for b in range(bits):
                h = h | (((Xc[i] >> b) & 1) << (b * d + (d - 1 - i)))
    # --- segmented stable sort + split (ref: _hilbert_split) -----------
    pos = jnp.arange(N, dtype=_I32)
    block = pos // npts_b
    realN = ((pos % npts_b) < n) & (block < B)
    # h < 2^(bits*d) <= 2^62, so this pad key sorts strictly last
    hkey = jnp.where(realN, h, jnp.int64(1) << 62)
    ops = (block, hkey, pos, w[pos % npts_b])
    ops = lax.sort(ops, num_keys=2, is_stable=True)
    pts, w_srt = ops[2], ops[3]
    rank = (jnp.arange(N) % npts_b).astype(jnp.int64)
    n64 = n.astype(jnp.int64)
    np64 = nparts.astype(jnp.int64)
    if not weighted:
        # closed form of searchsorted((arange(1,np)*n)//np, j, "right")
        part = jnp.minimum(((rank + 1) * np64 - 1) // n64, np64 - 1)
    else:
        is_start = rank == 0

        def scan_f(c, xw):
            wi, st = xw
            c = jnp.where(st, wi, c + wi)
            return c, c

        _, incl = lax.scan(scan_f, jnp.float64(0.0), (w_srt, is_start),
                           unroll=8)
        cwx = incl - w_srt  # EXCLUSIVE prefix, like cumsum(w) - w
        blk0 = (jnp.arange(N) // npts_b) * npts_b
        last = blk0 + n64 - 1
        w_last = w_srt[last]
        # total = cw[-1] + w[-1] with cw[-1] = incl[-1] - w[-1]: keep the
        # host's exact association (NOT plain incl[last])
        total = (incl[last] - w_last) + w_last
        part = jnp.minimum((cwx / total * np64.astype(_F64))
                           .astype(jnp.int64), np64 - 1)
    out = jnp.zeros(N, dtype=_I32).at[pts].set(
        part.astype(_I32), unique_indices=True)
    return out.reshape(nb_b, npts_b)


@functools.lru_cache(maxsize=None)
def _engine(d, sfc, longest_dim, weighted, npts_b, nb_b, tab_b, bits):
    """One jit-compiled sweep per (engine knobs, shape bucket).

    ``tab_b`` is part of the key even though the function never reads
    it: every cache entry then sees exactly ONE input shape set, so the
    ``lru_cache`` hit/miss counters are a truthful compile-count proxy
    (mirrors ``metrics_jax._scorer``).  ``bits`` is the static Hilbert
    resolution (0 for the MJ sweeps); call sites MUST pass it
    positionally — ``lru_cache`` keys keyword spellings separately and
    a split key would double-compile.  For ``sfc == "H"`` callers
    canonicalise ``longest_dim=True`` (Hilbert has no cut dimensions)
    so the knob cannot fragment the cache either.
    """
    del tab_b  # shape part of the key only
    if sfc == "H":
        return jax.jit(functools.partial(
            _hilbert_sweep, d=d, bits=bits, weighted=weighted,
            npts_b=npts_b, nb_b=nb_b))
    del bits
    return jax.jit(functools.partial(
        _sweep, d=d, sfc=sfc, longest_dim=longest_dim, weighted=weighted,
        npts_b=npts_b, nb_b=nb_b))


# registry-backed stat/reset pair (repro.obs); auto-registers with
# ``obs.snapshot()`` under "partition_jax"
partition_cache_stats, reset_partition_cache = \
    obs.instrument_compile_cache("partition_jax", _engine)


# ---------------------------------------------------------------------------
# host entry points (the orderings-module backend contract)
# ---------------------------------------------------------------------------

def _prepare(coords, nparts, dim_orders, weights, uneven_prime):
    """Padded device inputs + static bucket keys for one batched call."""
    n, d = coords.shape
    B = len(dim_orders)
    npts_b = bucket_size(n, PART_BUCKET_MIN)
    nb_b = bucket_size(B, lo=1)
    cols = pad_axis(np.ascontiguousarray(coords.T), npts_b, axis=1)
    sdo = np.tile(np.arange(d, dtype=np.int32), (nb_b, 1))
    sdo[:B] = dim_orders
    if weights is None:
        w = np.ones(npts_b, dtype=np.float64)
    else:
        w = pad_axis(np.asarray(weights, dtype=np.float64), npts_b)
    tab = _split_table(int(nparts), bool(uneven_prime))
    tab_b = bucket_size(len(tab), lo=TAB_MIN)
    tab = pad_axis(tab, tab_b)
    return cols, sdo, w, tab, npts_b, nb_b, tab_b


def order_points_batched_jax(
    coords: np.ndarray,
    nparts: int,
    sfc: str,
    *,
    dim_orders: np.ndarray,
    weights: np.ndarray | None = None,
    longest_dim: bool = True,
    uneven_prime: bool = False,
) -> np.ndarray:
    """Device implementation of ``vectorized_order_batched`` (same
    contract; results bit-identical, asserted in
    tests/test_partition_jax.py)."""
    coords = np.asarray(coords, dtype=np.float64)
    dim_orders = np.atleast_2d(np.asarray(dim_orders, dtype=np.int64))
    B = len(dim_orders)
    n, d = coords.shape if coords.ndim == 2 else (len(coords), 1)
    if nparts <= 1 or n == 0:
        return np.zeros((B, n), dtype=np.int64)
    npts_b = bucket_size(n, PART_BUCKET_MIN)
    if bucket_size(B, lo=1) * npts_b >= 1 << 31:
        # int32 slot ids bound the device batch; no realistic input
        from .partition import vectorized_order_batched  # pragma: no cover
        return vectorized_order_batched(  # pragma: no cover
            coords, nparts, sfc, dim_orders=dim_orders, weights=weights,
            longest_dim=longest_dim, uneven_prime=uneven_prime)
    cols, sdo, w, tab, npts_b, nb_b, tab_b = _prepare(
        coords, nparts, dim_orders, weights, uneven_prime)
    if sfc == "H":
        bits, ld = hilbert_bits(n, d), True  # canonical H cache key
    else:
        bits, ld = 0, bool(longest_dim)
    misses0 = _engine.cache_info().misses
    fn = _engine(d, sfc, ld, weights is not None,
                 npts_b, nb_b, tab_b, bits)
    obs.annotate(compile_cache=(
        "miss" if _engine.cache_info().misses > misses0 else "hit"))
    out = fn(cols, sdo, w, tab, np.int32(n), np.int32(B),
             np.int32(nparts))
    return np.asarray(out)[:B, :n].astype(np.int64)


def order_points_jax(
    coords: np.ndarray,
    nparts: int,
    sfc: str,
    *,
    weights: np.ndarray | None = None,
    dim_order: np.ndarray | None = None,
    longest_dim: bool = True,
    uneven_prime: bool = False,
) -> np.ndarray:
    """Device implementation of ``vectorized_order`` (same contract)."""
    coords = np.asarray(coords, dtype=np.float64)
    d = coords.shape[1] if coords.ndim == 2 else 1
    dimo = (np.arange(d, dtype=np.int64) if dim_order is None
            else np.asarray(dim_order, dtype=np.int64))
    return order_points_batched_jax(
        coords, nparts, sfc, dim_orders=dimo[None], weights=weights,
        longest_dim=longest_dim, uneven_prime=uneven_prime)[0]
