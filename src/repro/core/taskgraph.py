"""Task communication graphs (the application side of the mapping).

- :func:`stencil_graph`      — MiniGhost-style d-dim grid, 2d-point stencil.
- :func:`cube_sphere_graph`  — HOMME-style cubed-sphere element mesh, with
                               the paper's sphere / cube / 2D-face task
                               coordinate transforms (Fig. 7).
- :func:`logical_mesh_graph` — the TPU adaptation: a JAX logical device
                               mesh whose edges carry per-axis collective
                               traffic weights.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TaskGraph:
    """Tasks with coordinates and weighted communication edges.

    coords  : (n, td) float task coordinates (centroids).
    edges   : (E, 2) int task-index pairs (directed; a symmetric pattern
              lists both directions so per-link directed traffic is right).
    weights : (E,) message volumes.
    meta    : free-form info (grid dims, transforms applied, ...).
    """

    coords: np.ndarray
    edges: np.ndarray
    weights: np.ndarray
    meta: dict = dataclasses.field(default_factory=dict)

    @property
    def n(self) -> int:
        return len(self.coords)

    def with_coords(self, coords: np.ndarray, note: str = "") -> "TaskGraph":
        meta = dict(self.meta)
        if note:
            meta.setdefault("transforms", []).append(note) if isinstance(
                meta.get("transforms"), list) else meta.update(
                transforms=[note])
        return TaskGraph(np.asarray(coords, float), self.edges, self.weights,
                         meta)


# ---------------------------------------------------------------------------
# Structured stencils (MiniGhost, Table 1 generators)
# ---------------------------------------------------------------------------

def stencil_graph(dims: tuple[int, ...], *, torus: bool = False,
                  weight: float = 1.0, directed: bool = True) -> TaskGraph:
    """d-dim grid of tasks, each communicating with +-1 neighbours per dim.

    ``torus=False`` gives MiniGhost's non-periodic boundaries.  Edges are
    emitted in both directions when ``directed`` (volume ``weight`` each
    way, as a halo exchange sends both ways).
    """
    dims = tuple(int(x) for x in dims)
    n = int(np.prod(dims))
    idx = np.arange(n).reshape(dims)
    srcs, dsts = [], []
    for k in range(len(dims)):
        if dims[k] < 2:
            continue
        a = np.moveaxis(idx, k, 0)
        fwd_src, fwd_dst = a[:-1], a[1:]
        srcs.append(fwd_src.ravel())
        dsts.append(fwd_dst.ravel())
        if torus and dims[k] > 2:
            srcs.append(a[-1:].ravel())
            dsts.append(a[:1].ravel())
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    if directed:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    edges = np.stack([src, dst], axis=1)
    coords = np.stack(np.unravel_index(np.arange(n), dims), axis=1)
    return TaskGraph(coords.astype(float), edges,
                     np.full(len(edges), float(weight)),
                     meta={"dims": dims, "torus": torus})


# ---------------------------------------------------------------------------
# HOMME cubed-sphere
# ---------------------------------------------------------------------------
# Face layout (standard equatorial strip): faces 0..3 around the equator,
# face 4 = north pole (attached above face 0), face 5 = south pole
# (below face 0).  Within a face, (i, j) in [0, ne)^2.

def _face_uv(ne: int):
    u = (np.arange(ne) + 0.5) / ne * 2.0 - 1.0  # in (-1, 1)
    return np.meshgrid(u, u, indexing="ij")


def _cell(f: int, fi, fj, ne: int):
    return f * ne * ne + np.asarray(fi) * ne + np.asarray(fj)


def cube_sphere_graph(ne: int, weight: float = 1.0) -> TaskGraph:
    """Cubed-sphere element mesh: 6*ne*ne tasks, 4-neighbour connectivity
    including across cube edges (exact stitching tables for the standard
    equatorial-strip face parameterisation; see _cube_shell_points).
    Coordinates are 3D points on the unit sphere (the paper's 'Sphere'
    task coordinates)."""
    n = 6 * ne * ne
    ids = np.arange(n)
    coords = _sphere_coords(ne)

    srcs, dsts = [], []
    # In-face neighbours
    grid = ids.reshape(6, ne, ne)
    for axis in (1, 2):
        a = np.moveaxis(grid, axis, 1)
        srcs.append(a[:, :-1].ravel())
        dsts.append(a[:, 1:].ravel())

    r = np.arange(ne)
    rr = ne - 1 - r
    last = ne - 1
    # Equatorial ring: face f edge fi=last <-> face (f+1)%4 edge fi=0,
    # fj aligned.
    for f in range(4):
        srcs.append(_cell(f, last, r, ne))
        dsts.append(_cell((f + 1) % 4, 0, r, ne))
    # North face 4 (+z): derived from the gnomonic parameterisation
    stitches = [
        (_cell(4, r, 0, ne), _cell(0, r, last, ne)),       # v=-1 <-> f0 top
        (_cell(4, r, last, ne), _cell(2, rr, last, ne)),   # v=+1 <-> f2 top
        (_cell(4, 0, r, ne), _cell(3, rr, last, ne)),      # u=-1 <-> f3 top
        (_cell(4, last, r, ne), _cell(1, r, last, ne)),    # u=+1 <-> f1 top
        # South face 5 (-z)
        (_cell(5, r, 0, ne), _cell(2, rr, 0, ne)),         # v=-1 <-> f2 bot
        (_cell(5, r, last, ne), _cell(0, r, 0, ne)),       # v=+1 <-> f0 bot
        (_cell(5, 0, r, ne), _cell(3, r, 0, ne)),          # u=-1 <-> f3 bot
        (_cell(5, last, r, ne), _cell(1, rr, 0, ne)),      # u=+1 <-> f1 bot
    ]
    for s_, d_ in stitches:
        srcs.append(s_)
        dsts.append(d_)
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    edges = np.stack([src, dst], axis=1)
    return TaskGraph(coords, edges, np.full(len(edges), float(weight)),
                     meta={"ne": ne, "kind": "cube_sphere"})


def _cube_shell_points(ne: int) -> np.ndarray:
    """Cell centres on the surface of the unit cube (gnomonic grid)."""
    pts = np.zeros((6 * ne * ne, 3))
    uu, vv = _face_uv(ne)
    u, v = uu.ravel(), vv.ravel()
    one = np.ones_like(u)
    # faces: +x, +y, -x, -y (equatorial), +z (north), -z (south)
    faces = [
        np.stack([one, u, v], axis=1),
        np.stack([-u, one, v], axis=1),
        np.stack([-one, -u, v], axis=1),
        np.stack([u, -one, v], axis=1),
        np.stack([-v, u, one], axis=1),
        np.stack([v, u, -one], axis=1),
    ]
    for f in range(6):
        pts[f * ne * ne:(f + 1) * ne * ne] = faces[f]
    return pts


def _sphere_coords(ne: int) -> np.ndarray:
    p = _cube_shell_points(ne)
    return p / np.linalg.norm(p, axis=1, keepdims=True)


def _boundary_ids(ne: int) -> np.ndarray:
    rem = np.arange(6 * ne * ne) % (ne * ne)
    fi, fj = rem // ne, rem % ne
    onb = (fi == 0) | (fi == ne - 1) | (fj == 0) | (fj == ne - 1)
    return np.flatnonzero(onb)


def cube_coords(ne: int) -> np.ndarray:
    """The paper's 'Cube' transform: gnomonic cube-surface coordinates."""
    return _cube_shell_points(ne)


def face2d_coords(ne: int) -> np.ndarray:
    """The paper's '2DFace' transform (Fig. 7c/d): unfold the cube onto a
    2D plane — four equatorial faces in a strip (x wraps around), polar
    faces attached above/below the first face.  Locality across the strip
    ends is captured downstream by treating x as a torus dimension (the
    shift transform / FZ ordering exploit it)."""
    n = 6 * ne * ne
    rem = np.arange(n) % (ne * ne)
    fi, fj = (rem // ne).astype(float), (rem % ne).astype(float)
    out = np.zeros((n, 2))
    for f in range(4):  # equatorial strip along x (wraps at 4*ne)
        s = slice(f * ne * ne, (f + 1) * ne * ne)
        out[s, 0] = fi[s] + f * ne
        out[s, 1] = fj[s]
    # north pole above face 0, south pole below face 0
    s = slice(4 * ne * ne, 5 * ne * ne)
    out[s, 0] = fi[s]
    out[s, 1] = fj[s] + ne
    s = slice(5 * ne * ne, 6 * ne * ne)
    out[s, 0] = fi[s]
    out[s, 1] = fj[s] - ne
    return out


# ---------------------------------------------------------------------------
# Logical JAX mesh (the TPU adaptation's task graph)
# ---------------------------------------------------------------------------

def logical_mesh_graph(axis_sizes: tuple[int, ...],
                       axis_bytes: tuple[float, ...],
                       axis_names: tuple[str, ...] | None = None,
                       ring: bool = True) -> TaskGraph:
    """Task graph of a logical device mesh.

    One task per logical mesh coordinate.  Along each mesh axis we add
    ring edges (XLA lowers all-reduce/all-gather/reduce-scatter to
    bidirectional ring passes on TPU), weighted by ``axis_bytes`` — the
    per-step collective bytes crossing each link of that axis (e.g. TP
    all-reduces dominate, FSDP all-gathers are lighter, cross-pod DP is
    lightest).  Task coordinates are the logical mesh indices.
    """
    axis_sizes = tuple(int(s) for s in axis_sizes)
    n = int(np.prod(axis_sizes))
    idx = np.arange(n).reshape(axis_sizes)
    srcs, dsts, ws = [], [], []
    for k, size in enumerate(axis_sizes):
        if size < 2:
            continue
        a = np.moveaxis(idx, k, 0)
        s_, d_ = a[:-1].ravel(), a[1:].ravel()
        srcs.append(s_)
        dsts.append(d_)
        ws.append(np.full(len(s_), float(axis_bytes[k])))
        if ring and size > 2:
            srcs.append(a[-1:].ravel())
            dsts.append(a[:1].ravel())
            ws.append(np.full(a[:1].size, float(axis_bytes[k])))
    src = np.concatenate(srcs)
    dst = np.concatenate(dsts)
    w = np.concatenate(ws)
    src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
    w = np.concatenate([w, w])
    edges = np.stack([src, dst], axis=1)
    coords = np.stack(np.unravel_index(np.arange(n), axis_sizes), axis=1)
    return TaskGraph(coords.astype(float), edges, w,
                     meta={"axis_sizes": axis_sizes,
                           "axis_names": axis_names,
                           "axis_bytes": axis_bytes})
