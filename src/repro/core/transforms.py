"""Coordinate transforms that improve mapping quality (paper §4.3, §5).

- :func:`shift_torus` — cut each torus dimension at its largest coordinate
  gap so wrap-around neighbours become geometrically adjacent.
- :func:`permutations` / :func:`apply_permutation` — the rotation search.
- :func:`scale_by_bandwidth` — Gemini/DCN-style heterogeneous links: node
  distance across a link becomes proportional to 1/bw (Z2_2).
- :func:`box_lift` — Z2_3's 3D->6D lift: (box coords * big weight, coords
  within box * small weight) so the partitioner cuts between boxes first.
- :func:`drop_dims` — BG/Q "+E": remove a dimension from processor coords
  so the partitioner keeps the fast-dim pairs together.
"""

from __future__ import annotations

import itertools

import numpy as np

from .machine import Machine


def shift_torus(coords: np.ndarray, machine: Machine) -> np.ndarray:
    """Shift coordinates along each wrapped dimension at the largest gap.

    For each torus dimension: find the largest circular gap between the
    *occupied* coordinates; if it exceeds one grid step, rotate the
    coordinate system so the gap sits at the boundary.  Nodes that were
    split across the wrap-around then appear contiguous to the geometric
    partitioner (paper §4.3 "Shifting the machine coordinates").
    """
    coords = np.asarray(coords, dtype=np.float64).copy()
    nd = machine.ndim - machine.core_dims
    for k in range(nd):
        if not machine.wrap[k]:
            continue
        s = machine.dims[k]
        occ = np.unique(coords[:, k].astype(np.int64))
        if len(occ) <= 1:
            continue
        gaps = np.diff(np.concatenate([occ, occ[:1] + s]))
        g = int(np.argmax(gaps))
        if gaps[g] <= 1:
            continue
        # gap follows occ[g]; rotate so the first coordinate AFTER the gap
        # becomes 0.
        origin = (occ[g] + gaps[g]) % s
        coords[:, k] = (coords[:, k] - origin) % s
    return coords


def permutations(ndim: int, limit: int | None = None) -> list[tuple[int, ...]]:
    """All dimension orderings (the paper's td!/pd! rotations)."""
    perms = list(itertools.permutations(range(ndim)))
    if limit is not None and len(perms) > limit:
        idx = np.linspace(0, len(perms) - 1, limit).astype(int)
        perms = [perms[i] for i in idx]
    return perms


def apply_permutation(coords: np.ndarray, perm) -> np.ndarray:
    return np.asarray(coords)[:, list(perm)]


def scale_by_bandwidth(coords: np.ndarray, machine: Machine,
                       ref_bw: float | None = None) -> np.ndarray:
    """Z2_2: stretch each dimension so crossing a link costs distance
    proportional to 1/bandwidth.  Integer coordinate x along dim k maps to
    the cumulative inverse-bandwidth of links 0..x-1 (times ref_bw)."""
    coords = np.asarray(coords, dtype=np.float64)
    out = coords.copy()
    nd = machine.ndim - machine.core_dims
    if ref_bw is None:
        ref_bw = max(float(np.max(p[np.isfinite(p)])) if np.isfinite(
            p).any() else 1.0 for p in machine.link_bw[:nd])
    for k in range(nd):
        s = machine.dims[k]
        idx = np.arange(s)
        bw = np.asarray(machine.bw(k, idx), dtype=np.float64)
        cost = np.where(np.isfinite(bw), ref_bw / bw, 0.0)
        cum = np.concatenate([[0.0], np.cumsum(cost)])
        # interpolate (coords may already be shifted/fractional)
        x = coords[:, k]
        xi = np.clip(np.floor(x).astype(int), 0, s - 1)
        frac = x - xi
        out[:, k] = cum[xi] + frac * cost[np.clip(xi, 0, s - 1)]
    return out


def box_lift(coords: np.ndarray, box: tuple[int, ...],
             outer_weight: float = 16.0, inner_weight: float = 1.0
             ) -> np.ndarray:
    """Z2_3: lift d-dim coords to 2d dims — (box index, index within box).

    ``outer_weight`` >> ``inner_weight`` guides the partitioner to cut
    between boxes before cutting within them (the paper uses 2x2x8 boxes
    on Gemini so a box == the set of nodes sharing fast local links)."""
    coords = np.asarray(coords, dtype=np.float64)
    box = np.asarray(box, dtype=np.float64)
    outer = np.floor(coords / box) * outer_weight
    inner = (coords % box) * inner_weight
    return np.concatenate([outer, inner], axis=1)


def drop_dims(coords: np.ndarray, dims_to_drop) -> np.ndarray:
    """BG/Q "+E": remove dimensions from processor coordinates."""
    keep = [i for i in range(coords.shape[1]) if i not in set(dims_to_drop)]
    return np.asarray(coords)[:, keep]


def normalize_extents(coords: np.ndarray) -> np.ndarray:
    """Scale every dimension to unit extent (useful before comparing task
    and processor spaces of very different scales)."""
    coords = np.asarray(coords, dtype=np.float64)
    lo = coords.min(axis=0)
    span = coords.max(axis=0) - lo
    span = np.where(span > 0, span, 1.0)
    return (coords - lo) / span
