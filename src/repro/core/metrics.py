"""Mapping quality metrics (paper §3, Eqns. 1-7).

All metrics assume static dimension-ordered routing (dim 0 first, then dim
1, ...), shortest direction per dimension on tori, messages never split
across paths — the paper's assumptions.  Links are directed (the paper's
Fig. 12 reports X+/X- separately).

Core dimensions of a machine (intra-node) contribute zero hops and carry
no accountable traffic (infinite bandwidth), matching the paper's
treatment of multicore nodes.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .machine import Machine


# ---------------------------------------------------------------------------
# Hops (Eqns. 1-3)
# ---------------------------------------------------------------------------

def pairwise_hops(machine: Machine, src: np.ndarray, dst: np.ndarray
                  ) -> np.ndarray:
    """Shortest-path hop count between coordinate rows (per message)."""
    src = np.asarray(src)
    dst = np.asarray(dst)
    nd = machine.ndim - machine.core_dims
    total = np.zeros(len(src), dtype=np.int64)
    for k in range(nd):
        s = machine.dims[k]
        d = np.abs(src[:, k] - dst[:, k])
        if machine.wrap[k]:
            d = np.minimum(d, s - d)
        total += d
    return total


def total_hops(machine, src, dst) -> int:
    return int(pairwise_hops(machine, src, dst).sum())


def average_hops(machine, src, dst) -> float:
    h = pairwise_hops(machine, src, dst)
    return float(h.mean()) if len(h) else 0.0


def weighted_hops(machine, src, dst, weights) -> float:
    h = pairwise_hops(machine, src, dst)
    return float((h * np.asarray(weights)).sum())


# ---------------------------------------------------------------------------
# Per-link traffic under dimension-ordered routing (Eqns. 4-7)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Traffic:
    """Directed per-link traffic.

    ``pos[k]`` has the machine's full shape; entry at coordinate ``x`` is
    the bytes crossing the + link of ``x`` along dim ``k`` (from x to
    x+e_k, wrapping).  ``neg[k]`` likewise for the - direction (from
    x+e_k down to x).  Core dims carry no entries (None).
    """

    machine: Machine
    pos: list
    neg: list

    def link_data(self) -> np.ndarray:
        """All directed link loads as one flat vector (network dims only)."""
        parts = []
        nd = self.machine.ndim - self.machine.core_dims
        for k in range(nd):
            parts.append(self.pos[k].ravel())
            parts.append(self.neg[k].ravel())
        return np.concatenate(parts) if parts else np.zeros(0)

    def link_latency(self) -> np.ndarray:
        parts = []
        nd = self.machine.ndim - self.machine.core_dims
        for k in range(nd):
            idx = np.arange(self.machine.dims[k])
            bw = self.machine.bw(k, idx)  # pattern along dim k
            shape = [1] * self.machine.ndim
            shape[k] = self.machine.dims[k]
            bw_full = np.broadcast_to(bw.reshape(shape), self.machine.dims)
            parts.append((self.pos[k] / bw_full).ravel())
            parts.append((self.neg[k] / bw_full).ravel())
        return np.concatenate(parts) if parts else np.zeros(0)


def route_traffic(machine: Machine, src: np.ndarray, dst: np.ndarray,
                  weights: np.ndarray | None = None) -> Traffic:
    """Accumulate per-link traffic for messages src->dst (dim-ordered)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    nmsg = len(src)
    if weights is None:
        weights = np.ones(nmsg)
    w = np.asarray(weights, dtype=np.float64)
    nd = machine.ndim - machine.core_dims
    dims = machine.dims

    pos = [np.zeros(dims) for _ in range(nd)]
    neg = [np.zeros(dims) for _ in range(nd)]

    # current position starts at src; after routing dim k it holds dst[:k+1]
    cur = src.copy()
    for k in range(nd):
        s = dims[k]
        a = cur[:, k]
        b = dst[:, k]
        if machine.wrap[k]:
            fwd = (b - a) % s
            bwd = (a - b) % s
            use_fwd = fwd <= bwd
            length_f = np.where(use_fwd, fwd, 0)
            length_b = np.where(use_fwd, 0, bwd)
        else:
            use_fwd = b >= a
            length_f = np.where(use_fwd, b - a, 0)
            length_b = np.where(use_fwd, 0, a - b)

        # rows: all machine dims fixed except k. Row coordinate is `cur`
        # with dim k removed.  (Core dims stay at the src's core coords —
        # they are free, routing order irrelevant.)
        other = [cur[:, j] for j in range(machine.ndim) if j != k]
        row_dims = tuple(d for j, d in enumerate(dims) if j != k)
        if row_dims:
            row = np.ravel_multi_index(other, row_dims)
        else:
            row = np.zeros(nmsg, dtype=np.int64)
        nrows = int(np.prod(row_dims)) if row_dims else 1

        # + direction: links a, a+1, ..., a+len-1 (mod s)
        _accumulate_circular(pos[k], row, nrows, s, a, length_f, w,
                             dims, k)
        # - direction: crossing from a down by len uses - channels at
        # indices (a-1, a-2, ..., a-len) mod s == start (a-len) length len
        start_b = (a - length_b) % s if machine.wrap[k] else a - length_b
        _accumulate_circular(neg[k], row, nrows, s, start_b, length_b, w,
                             dims, k)
        cur = cur.copy()
        cur[:, k] = b
    return Traffic(machine, pos, neg)


def _accumulate_circular(out, row, nrows, s, start, length, w, dims, k):
    """Range-add ``w`` to circular intervals [start, start+length) of each
    row's 1D link array, writing into ``out`` (full machine shape)."""
    m = length > 0
    if not m.any():
        return
    row = row[m]
    start = start[m] % s
    length = length[m]
    ww = w[m]
    diff = np.zeros((nrows, s + 1))
    end = start + length
    nowrap = end <= s
    # non-wrapping part
    np.add.at(diff, (row, start), ww)
    np.add.at(diff, (row[nowrap], end[nowrap]), -ww[nowrap])
    # wrapping tail: [0, end-s)
    wr = ~nowrap
    if wr.any():
        np.add.at(diff, (row[wr], np.zeros(wr.sum(), dtype=int)), ww[wr])
        np.add.at(diff, (row[wr], end[wr] - s), -ww[wr])
        np.add.at(diff, (row[wr], np.full(wr.sum(), s)), -ww[wr])
    lane = np.cumsum(diff[:, :s], axis=1)
    # scatter back into the machine-shaped array: move axis k last
    shape_rows = tuple(d for j, d in enumerate(dims) if j != k)
    lane = lane.reshape(shape_rows + (s,)) if shape_rows else lane.reshape(s)
    out += np.moveaxis(lane, -1, k)


# ---------------------------------------------------------------------------
# Aggregate metrics
# ---------------------------------------------------------------------------

def data_metric(traffic: Traffic) -> float:
    """Data(M) = max over links of Data(e)  (Eqn. 5)."""
    d = traffic.link_data()
    return float(d.max()) if len(d) else 0.0


def latency_metric(traffic: Traffic) -> float:
    """Latency(M) = max over links of Data(e)/bw(e)  (Eqn. 7)."""
    l = traffic.link_latency()
    return float(l.max()) if len(l) else 0.0


def per_dim_stats(traffic: Traffic) -> dict:
    """Per-dimension, per-direction max/mean Data and Latency (Figs 9/12)."""
    out = {}
    m = traffic.machine
    nd = m.ndim - m.core_dims
    for k in range(nd):
        idx = np.arange(m.dims[k])
        bw = m.bw(k, idx)
        shape = [1] * m.ndim
        shape[k] = m.dims[k]
        bw_full = np.broadcast_to(np.asarray(bw).reshape(shape), m.dims)
        for sign, arr in (("+", traffic.pos[k]), ("-", traffic.neg[k])):
            key = f"dim{k}{sign}"
            out[key] = {
                "data_max": float(arr.max()),
                "data_mean": float(arr.mean()),
                "lat_max": float((arr / bw_full).max()),
                "lat_mean": float((arr / bw_full).mean()),
            }
    return out


def evaluate_mapping(machine: Machine, task_edges: np.ndarray,
                     edge_weights: np.ndarray | None,
                     task_to_coord: np.ndarray) -> dict:
    """All paper metrics for a mapping.

    task_edges    : (E, 2) task index pairs.
    edge_weights  : (E,) message volumes (None = uniform 1).
    task_to_coord : (ntasks, ndim) machine coordinate of each task.
    """
    src = task_to_coord[task_edges[:, 0]]
    dst = task_to_coord[task_edges[:, 1]]
    if edge_weights is None:
        edge_weights = np.ones(len(task_edges))
    h = pairwise_hops(machine, src, dst)
    traffic = route_traffic(machine, src, dst, edge_weights)
    nz = int(np.count_nonzero(h))
    return {
        "total_hops": int(h.sum()),
        "average_hops": float(h.mean()) if len(h) else 0.0,
        "weighted_hops": float((h * edge_weights).sum()),
        "data_max": data_metric(traffic),
        "latency_max": latency_metric(traffic),
        "num_messages": len(task_edges),
        "num_offnode_messages": nz,
        "per_dim": per_dim_stats(traffic),
    }
