"""Mapping quality metrics (paper §3, Eqns. 1-7).

All metrics assume static dimension-ordered routing (dim 0 first, then dim
1, ...), shortest direction per dimension on tori, messages never split
across paths — the paper's assumptions.  Links are directed (the paper's
Fig. 12 reports X+/X- separately).

Core dimensions of a machine (intra-node) contribute zero hops and carry
no accountable traffic (infinite bandwidth), matching the paper's
treatment of multicore nodes.
"""

from __future__ import annotations

import dataclasses
import functools
import warnings

import numpy as np

from repro import faults, obs

from .machine import Machine


# ---------------------------------------------------------------------------
# Hops (Eqns. 1-3)
# ---------------------------------------------------------------------------

def pairwise_hops(machine: Machine, src: np.ndarray, dst: np.ndarray
                  ) -> np.ndarray:
    """Shortest-path hop count between coordinate rows (per message).

    ``src``/``dst`` may carry leading batch dimensions (``(..., E, nd)``)
    — the candidate-search engine scores whole candidate stacks in one
    call; the result has shape ``src.shape[:-1]``.
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    nd = machine.ndim - machine.core_dims
    total = np.zeros(src.shape[:-1], dtype=np.int64)
    for k in range(nd):
        s = machine.dims[k]
        d = np.abs(src[..., k] - dst[..., k])
        if machine.wrap[k]:
            d = np.minimum(d, s - d)
        total += d
    return total


def total_hops(machine, src, dst) -> int:
    return int(pairwise_hops(machine, src, dst).sum())


def average_hops(machine, src, dst) -> float:
    h = pairwise_hops(machine, src, dst)
    return float(h.mean()) if len(h) else 0.0


def weighted_hops(machine, src, dst, weights) -> float:
    h = pairwise_hops(machine, src, dst)
    return float((h * np.asarray(weights)).sum())


# ---------------------------------------------------------------------------
# Per-link traffic under dimension-ordered routing (Eqns. 4-7)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Traffic:
    """Directed per-link traffic.

    ``pos[k]`` has the machine's full shape; entry at coordinate ``x`` is
    the bytes crossing the + link of ``x`` along dim ``k`` (from x to
    x+e_k, wrapping).  ``neg[k]`` likewise for the - direction (from
    x+e_k down to x).  Core dims carry no entries (None).
    """

    machine: Machine
    pos: list
    neg: list

    def link_data(self) -> np.ndarray:
        """All directed link loads as one flat vector (network dims only)."""
        parts = []
        nd = self.machine.ndim - self.machine.core_dims
        for k in range(nd):
            parts.append(self.pos[k].ravel())
            parts.append(self.neg[k].ravel())
        return np.concatenate(parts) if parts else np.zeros(0)

    def link_latency(self) -> np.ndarray:
        parts = []
        nd = self.machine.ndim - self.machine.core_dims
        for k in range(nd):
            bw_full = self.machine.bw_field(k)
            parts.append((self.pos[k] / bw_full).ravel())
            parts.append((self.neg[k] / bw_full).ravel())
        return np.concatenate(parts) if parts else np.zeros(0)


def route_traffic(machine: Machine, src: np.ndarray, dst: np.ndarray,
                  weights: np.ndarray | None = None) -> Traffic:
    """Accumulate per-link traffic for messages src->dst (dim-ordered)."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    pos, neg = _batched_route(machine, src[None], dst[None], weights)
    return Traffic(machine, [p[0] for p in pos], [p[0] for p in neg])


def _batched_route(machine: Machine, src: np.ndarray, dst: np.ndarray,
                   weights: np.ndarray | None = None):
    """Dimension-ordered routing for a whole STACK of mappings at once.

    ``src``/``dst``: (B, E, ndim) integer coordinates — one candidate
    mapping per leading index.  Returns ``(pos, neg)``: per network dim,
    a ``(B, *machine.dims)`` array of directed link loads.  The batch is
    folded into the row index of the shared difference-array range-add,
    so scoring B candidates costs one vectorised pass instead of B
    python-level routing loops (the mapping pipeline's candidate search
    relies on this).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    nb, nmsg, _ = src.shape
    if weights is None:
        w = np.ones(nb * nmsg)
    else:
        w = np.broadcast_to(np.asarray(weights, dtype=np.float64),
                            (nb, nmsg)).reshape(-1)
    nd = machine.ndim - machine.core_dims
    dims = machine.dims

    pos = [np.zeros((nb,) + dims) for _ in range(nd)]
    neg = [np.zeros((nb,) + dims) for _ in range(nd)]

    # current position starts at src; after routing dim k it holds dst[:k+1]
    cur = src.copy()
    for k in range(nd):
        s = dims[k]
        a = cur[..., k].reshape(-1)
        b = dst[..., k].reshape(-1)
        if machine.wrap[k]:
            fwd = (b - a) % s
            bwd = (a - b) % s
            use_fwd = fwd <= bwd
            length_f = np.where(use_fwd, fwd, 0)
            length_b = np.where(use_fwd, 0, bwd)
        else:
            use_fwd = b >= a
            length_f = np.where(use_fwd, b - a, 0)
            length_b = np.where(use_fwd, 0, a - b)

        # rows: all machine dims fixed except k, plus the candidate index
        # as the leading coordinate.  (Core dims stay at the src's core
        # coords — they are free, routing order irrelevant.)
        other = [cur[..., j].reshape(-1)
                 for j in range(machine.ndim) if j != k]
        row_dims = tuple(d for j, d in enumerate(dims) if j != k)
        if row_dims:
            row = np.ravel_multi_index(other, row_dims)
        else:
            row = np.zeros(nb * nmsg, dtype=np.int64)
        nrows = int(np.prod(row_dims)) if row_dims else 1
        row = row + np.repeat(np.arange(nb, dtype=np.int64) * nrows, nmsg)

        # + direction: links a, a+1, ..., a+len-1 (mod s)
        _accumulate_circular(pos[k], row, nb * nrows, s, a, length_f, w,
                             dims, k)
        # - direction: crossing from a down by len uses - channels at
        # indices (a-1, a-2, ..., a-len) mod s == start (a-len) length len
        start_b = (a - length_b) % s if machine.wrap[k] else a - length_b
        _accumulate_circular(neg[k], row, nb * nrows, s, start_b, length_b,
                             w, dims, k)
        cur = cur.copy()
        cur[..., k] = dst[..., k]
    return pos, neg


def _accumulate_circular(out, row, nrows, s, start, length, w, dims, k):
    """Range-add ``w`` to circular intervals [start, start+length) of each
    row's 1D link array, writing into ``out`` ((B,) + machine shape).

    The difference-array contributions are summed with one flat
    ``np.bincount`` over ``row*(s+1)+col`` keys — a contiguous segment
    sum instead of the ``np.add.at`` scatters this used to run, which
    serialise on repeated indices and dominated the routing profile.
    Column ``s`` is the overflow bucket for wrapped intervals (it is
    excluded from the prefix sum).
    """
    m = length > 0
    if not m.any():
        return
    row = row[m]
    start = start[m] % s
    length = length[m]
    ww = w[m]
    base = row * (s + 1)
    end = start + length
    nowrap = end <= s
    wr = ~nowrap
    # non-wrapping part: +w at start, -w at end (s = dump bucket)
    idx = [base + start, base[nowrap] + end[nowrap]]
    val = [ww, -ww[nowrap]]
    if wr.any():
        # wrapping tail [0, end-s): +w at 0, -w at end-s; close the head
        # interval [start, s) in the dump bucket
        idx += [base[wr], base[wr] + end[wr] - s, base[wr] + s]
        val += [ww[wr], -ww[wr], -ww[wr]]
    diff = np.bincount(np.concatenate(idx), weights=np.concatenate(val),
                       minlength=nrows * (s + 1)).reshape(nrows, s + 1)
    lane = np.cumsum(diff[:, :s], axis=1)
    # scatter back into the batched machine-shaped array: axis k of the
    # machine sits at position k+1 of ``out``
    shape_rows = tuple(d for j, d in enumerate(dims) if j != k)
    lane = lane.reshape((len(out),) + shape_rows + (s,))
    out += np.moveaxis(lane, -1, k + 1)


# ---------------------------------------------------------------------------
# Batched candidate evaluation (the mapping pipeline's scoring engine)
# ---------------------------------------------------------------------------

SCORE_BACKENDS = ("numpy", "jax", "pallas")

# Silent fallback chain per requested backend: the first importable
# entry wins, so "pallas" degrades to the jit-compiled jax scorer and
# finally to numpy when the accelerator stack is unavailable.
_BACKEND_CHAIN = {
    "numpy": ("numpy",),
    "jax": ("jax", "numpy"),
    "pallas": ("pallas", "jax", "numpy"),
}

_JAX_EVAL = False     # memoised import: False = untried, None = unavailable
_PALLAS_EVAL = False  # likewise for the Pallas mapscore kernel

# Import/initialisation failures that legitimately disable an
# accelerator backend.  Anything else (a genuine bug, a runtime device
# fault) must PROPAGATE — the serve layer's degradation ladder handles
# those per-request instead of silently pinning the process to a slower
# rung (ISSUE 7 satellite: the old guards were bare ``except
# Exception``, which swallowed everything).
_IMPORT_FAILURES = (ImportError, AttributeError, OSError, RuntimeError)

# cause of each unavailable backend (repr), for the one-shot warning
_FALLBACK_CAUSE: dict[str, str] = {}
_WARNED: set = set()


def _warn_fallback(requested: str, resolved: str) -> None:
    """Once-per-process warning naming the rung that actually runs."""
    key = (requested, resolved)
    if key in _WARNED:
        return
    _WARNED.add(key)
    causes = "; ".join(
        f"{n}: {_FALLBACK_CAUSE[n]}" for n in _BACKEND_CHAIN[requested]
        if n in _FALLBACK_CAUSE) or "backend unavailable"
    warnings.warn(
        f"score backend {requested!r} unavailable, resolved to "
        f"{resolved!r} ({causes})", RuntimeWarning, stacklevel=3)


def _jax_evaluator():
    """The JAX scoring entry point, or None when jax cannot be imported
    (the numpy path is then used transparently)."""
    global _JAX_EVAL
    if _JAX_EVAL is False:
        try:
            from . import metrics_jax
            _JAX_EVAL = metrics_jax.evaluate_candidates_jax
        except _IMPORT_FAILURES as e:  # pragma: no cover - jax in image
            _JAX_EVAL = None
            _FALLBACK_CAUSE["jax"] = repr(e)
    return _JAX_EVAL


def _pallas_evaluator():
    """The Pallas mapscore kernel entry point, or None when the kernel
    stack cannot be imported (jax falls in next, then numpy)."""
    global _PALLAS_EVAL
    if _PALLAS_EVAL is False:
        try:
            from repro.kernels.mapscore import ops as mapscore_ops
            _PALLAS_EVAL = mapscore_ops.evaluate_candidates_pallas
        except _IMPORT_FAILURES as e:  # pragma: no cover - jax in image
            _PALLAS_EVAL = None
            _FALLBACK_CAUSE["pallas"] = repr(e)
    return _PALLAS_EVAL


def _hooked(name: str, fn):
    """Wrap a resolved evaluator with its fault-injection site.

    One wrapper per resolved backend (cached): every scoring call —
    candidate search, hier refinement, direct ``evaluate_candidates`` —
    passes through ``faults.fire("score.<resolved>")`` so injected
    compile failures / device OOMs surface exactly where real ones
    would.
    """
    cached = _HOOKED.get(name)
    if cached is not None and cached.__wrapped__ is fn:
        return cached
    site = f"score.{name}"

    @functools.wraps(fn)
    def wrapper(*args, **kwargs):
        # the span wraps the fire too: an injected backend fault shows
        # up in the trace exactly like a real one (error-annotated span)
        with obs.span(site) as sp:
            faults.fire(site)
            out = fn(*args, **kwargs)
            if len(args) >= 4:  # (machine, edges, weights, coord_stack)
                sp.annotate(candidates=int(len(args[3])))
            return out

    _HOOKED[name] = wrapper
    return wrapper


_HOOKED: dict = {}


def get_evaluator(backend: str):
    """Resolve a scoring backend ONCE: ``(resolved_name, callable)``.

    The callable has :func:`evaluate_candidates`' signature minus
    ``backend``.  Resolution walks the fallback chain
    (pallas -> jax -> numpy) — warning once per process when a
    requested backend is unavailable — so hot loops (the hier swap
    refinement, the candidate search) can hoist it out instead of
    re-resolving per scoring call.  ``resolved_name`` is what actually
    runs (recorded by ``benchmarks/run.py --json`` so trajectories stay
    attributable).  The callable fires the ``score.<resolved>``
    fault-injection site (:mod:`repro.faults`) on every call.
    """
    if backend not in SCORE_BACKENDS:
        raise ValueError(f"unknown scoring backend {backend!r}")
    for name in _BACKEND_CHAIN[backend]:
        if name == "numpy":
            if backend != "numpy":
                _warn_fallback(backend, "numpy")
            return "numpy", _hooked("numpy", evaluate_candidates_numpy)
        fn = _pallas_evaluator() if name == "pallas" else _jax_evaluator()
        if fn is not None:
            if name != backend:
                _warn_fallback(backend, name)
            return name, _hooked(name, fn)
    raise AssertionError("unreachable: numpy terminates every chain")


def evaluate_candidates(machine: Machine, task_edges: np.ndarray,
                        edge_weights: np.ndarray | None,
                        coord_stack: np.ndarray, *,
                        traffic: bool = False,
                        chunk_elems: int = 1 << 24,
                        backend: str = "numpy") -> dict:
    """Score a stack of candidate mappings in vectorised passes.

    ``coord_stack``: (B, ntasks, ndim) — machine coordinate of every task
    under each of B candidate mappings.  Returns a dict of (B,) arrays:
    ``weighted_hops``, ``total_hops``, ``average_hops`` and — when
    ``traffic`` is requested — ``data_max`` / ``latency_max`` from the
    batched dimension-ordered router.  Candidates are processed in
    chunks bounded by ``chunk_elems`` message-coordinates so arbitrarily
    large candidate sets cannot blow up memory.

    ``backend="jax"`` routes the whole scoring pass (hops + the
    dimension-ordered router) through the jit-compiled accelerator
    implementation (:mod:`repro.core.metrics_jax`: ``segment_sum`` for
    the circular range-add, ``vmap`` over candidates, message counts
    bucketed to padded power-of-two shapes so a benchmark scenario
    compiles O(1) times).  ``backend="pallas"`` fuses routing and
    reduction into one on-chip kernel launch
    (:mod:`repro.kernels.mapscore`): only the small per-candidate
    metric vector returns to host.  Both match the numpy path within
    floating-point tolerance and fall back silently down the
    pallas -> jax -> numpy chain when an import fails.  ``"numpy"``
    (default) is the bit-exact parity-tested reference.
    """
    _, fn = get_evaluator(backend)
    return fn(machine, task_edges, edge_weights, coord_stack,
              traffic=traffic, chunk_elems=chunk_elems)


def evaluate_candidates_numpy(machine: Machine, task_edges: np.ndarray,
                              edge_weights: np.ndarray | None,
                              coord_stack: np.ndarray, *,
                              traffic: bool = False,
                              chunk_elems: int = 1 << 24) -> dict:
    """The numpy scoring implementation (the bit-exact reference)."""
    coord_stack = np.asarray(coord_stack)
    nb = len(coord_stack)
    ne = len(task_edges)
    w = np.ones(ne) if edge_weights is None else \
        np.asarray(edge_weights, dtype=np.float64)
    out = {
        "weighted_hops": np.empty(nb),
        "total_hops": np.empty(nb, dtype=np.int64),
        "average_hops": np.empty(nb),
    }
    if traffic:
        out["data_max"] = np.empty(nb)
        out["latency_max"] = np.empty(nb)
    nd = machine.ndim - machine.core_dims
    per_cand = max(ne * machine.ndim, 1)
    if traffic:
        per_cand += 2 * nd * machine.nnodes
    chunk = int(max(1, chunk_elems // per_cand))
    for c0 in range(0, nb, chunk):
        cs = coord_stack[c0:c0 + chunk]
        src = cs[:, task_edges[:, 0]]
        dst = cs[:, task_edges[:, 1]]
        h = pairwise_hops(machine, src, dst)  # (chunk, E)
        sl = slice(c0, c0 + len(cs))
        out["weighted_hops"][sl] = (h * w).sum(axis=-1)
        out["total_hops"][sl] = h.sum(axis=-1)
        out["average_hops"][sl] = h.mean(axis=-1) if ne else 0.0
        if traffic:
            pos, neg = _batched_route(machine, src.astype(np.int64),
                                      dst.astype(np.int64), w)
            b = len(cs)
            data = np.zeros(b)
            lat = np.zeros(b)
            for k in range(nd):
                bw_full = machine.bw_field(k)[None]
                for arr in (pos[k], neg[k]):
                    data = np.maximum(data, arr.reshape(b, -1).max(axis=1))
                    lat = np.maximum(
                        lat, (arr / bw_full).reshape(b, -1).max(axis=1))
            out["data_max"][sl] = data
            out["latency_max"][sl] = lat
    return out


# ---------------------------------------------------------------------------
# Aggregate metrics
# ---------------------------------------------------------------------------

def data_metric(traffic: Traffic) -> float:
    """Data(M) = max over links of Data(e)  (Eqn. 5)."""
    d = traffic.link_data()
    return float(d.max()) if len(d) else 0.0


def latency_metric(traffic: Traffic) -> float:
    """Latency(M) = max over links of Data(e)/bw(e)  (Eqn. 7)."""
    lat = traffic.link_latency()
    return float(lat.max()) if len(lat) else 0.0


def per_dim_stats(traffic: Traffic) -> dict:
    """Per-dimension, per-direction max/mean Data and Latency (Figs 9/12)."""
    out = {}
    m = traffic.machine
    nd = m.ndim - m.core_dims
    for k in range(nd):
        bw_full = m.bw_field(k)
        for sign, arr in (("+", traffic.pos[k]), ("-", traffic.neg[k])):
            key = f"dim{k}{sign}"
            out[key] = {
                "data_max": float(arr.max()),
                "data_mean": float(arr.mean()),
                "lat_max": float((arr / bw_full).max()),
                "lat_mean": float((arr / bw_full).mean()),
            }
    return out


def evaluate_mapping(machine: Machine, task_edges: np.ndarray,
                     edge_weights: np.ndarray | None,
                     task_to_coord: np.ndarray) -> dict:
    """All paper metrics for a mapping.

    task_edges    : (E, 2) task index pairs.
    edge_weights  : (E,) message volumes (None = uniform 1).
    task_to_coord : (ntasks, ndim) machine coordinate of each task.
    """
    src = task_to_coord[task_edges[:, 0]]
    dst = task_to_coord[task_edges[:, 1]]
    if edge_weights is None:
        edge_weights = np.ones(len(task_edges))
    h = pairwise_hops(machine, src, dst)
    traffic = route_traffic(machine, src, dst, edge_weights)
    nz = int(np.count_nonzero(h))
    return {
        "total_hops": int(h.sum()),
        "average_hops": float(h.mean()) if len(h) else 0.0,
        "weighted_hops": float((h * edge_weights).sum()),
        "data_max": data_metric(traffic),
        "latency_max": latency_metric(traffic),
        "num_messages": len(task_edges),
        "num_offnode_messages": nz,
        "per_dim": per_dim_stats(traffic),
    }
