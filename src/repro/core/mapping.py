"""Task mapping via consistent geometric ordering (paper §4, Alg. 1).

``geometric_map`` is Algorithm 1: order task coordinates and processor
coordinates with the same Multi-Jagged recursion (+SFC part numbering)
and match equal part numbers.  ``Mapper`` wraps the full Z2 pipeline
(shift, bandwidth scaling, box lift, +E, rotation search).

Both are thin adapters over :mod:`repro.mapping` — the unified mapping
pipeline whose stages (machine transforms -> partitioner backend ->
part matching -> batched candidate scoring) are shared with the JAX
mesh builder (:mod:`repro.meshmap.device_mesh`) and every benchmark, so
the rotation/candidate-search loop exists exactly once in the repo.
This module hosts the result/matching primitives; the pipeline imports
them (never the other way around), keeping the import graph acyclic.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import metrics as M
from .machine import Allocation
from .taskgraph import TaskGraph


@dataclasses.dataclass
class MappingResult:
    """task_to_proc[t] = index into the processor coordinate array.

    For tnum > pnum several tasks share a processor.  ``proc_to_tasks`` is
    a list of task-index arrays per processor.  ``rotation`` records the
    winning (task_perm, proc_perm) of the rotation search; ``score`` its
    objective value (WeightedHops for the classic search).  ``stats``
    carries pipeline-reported accounting (engine-pass point counts, the
    hierarchical coarsening/refinement summary, ...) for benchmarks and
    tests; it never affects the mapping itself.
    """

    task_to_proc: np.ndarray
    rotation: tuple = ((), ())
    score: float = float("nan")
    stats: dict = dataclasses.field(default_factory=dict)

    def proc_to_tasks(self, pnum: int) -> list:
        out = [[] for _ in range(pnum)]
        for t, p in enumerate(self.task_to_proc):
            out[p].append(t)
        return [np.array(x, dtype=np.int64) for x in out]


def match_parts(mu_task: np.ndarray, mu_proc: np.ndarray) -> np.ndarray:
    """task_to_proc from equal part numbers (paper GETMAPPINGARRAYS).

    When several tasks share a part (tnum > pnum) they all map to the
    processor holding that part.  When parts hold one task and one
    processor (tnum == pnum) this is the paper's 1:1 case.
    """
    nparts = int(mu_proc.max()) + 1
    part_to_proc = np.full(nparts, -1, dtype=np.int64)
    part_to_proc[mu_proc] = np.arange(len(mu_proc))
    if (part_to_proc < 0).any():
        # multiple procs per part (pnum > nparts): keep first occurrence
        missing = np.flatnonzero(part_to_proc < 0)
        raise ValueError(f"parts with no processor: {missing[:5]}")
    return part_to_proc[mu_task]


_match_parts = match_parts  # backwards-compatible alias


def geometric_map(
    task_coords: np.ndarray,
    proc_coords: np.ndarray,
    *,
    sfc: str = "FZ",
    mfz: str | bool = "auto",
    longest_dim: bool = True,
    uneven_prime: bool = False,
    task_weights: np.ndarray | None = None,
    task_perm=None,
    proc_perm=None,
    backend: str = "vectorized",
) -> MappingResult:
    """Paper Algorithm 1 for one (task_perm, proc_perm) rotation."""
    from repro.mapping.pipeline import MappingPipeline, PipelineConfig
    pipe = MappingPipeline(PipelineConfig(
        sfc=sfc, mfz=mfz, longest_dim=longest_dim,
        uneven_prime=uneven_prime, backend=backend))
    return pipe.map_candidate(task_coords, proc_coords,
                              task_weights=task_weights,
                              task_perm=task_perm, proc_perm=proc_perm)


# ---------------------------------------------------------------------------
# Full Z2 pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MapperConfig:
    """Configuration of the Z2-style mapper.

    sfc            : part-numbering ordering ("FZ" is the paper's winner).
    mfz            : use the MFZ task-side variant when pd % td == 0.
    shift          : torus wrap-around shifting of machine coords.
    bandwidth_scale: Z2_2 — scale distances by 1/link-bandwidth.
    box            : Z2_3 — lift machine coords by this box shape (None=off).
    box_outer_weight: scale of the between-box coordinates.
    drop           : dims to drop from machine coords (BG/Q "+E").
    rotations      : 0 = identity only; otherwise max number of
                     (task!, proc!) permutation pairs evaluated, keeping
                     the best WeightedHops (paper's rotation search).
    uneven_prime   : Z2_2 — largest-prime-divisor uneven bisection.
    longest_dim    : cut the longest dimension (False = strict alternation).
    backend        : partitioner engine ("vectorized" or "recursive").
    partition_backend : partition device backend ("numpy" or "jax";
                     silent jax -> numpy fallback, resolved once).
    fused          : "auto" engages the fused whole-pipeline program
                     when backends allow; "off" forces the staged path.
    sweep          : rotation-sweep mode ("batched" = ~2 engine passes
                     for the whole sweep; "loop" = per-candidate oracle).
    score_backend  : candidate scoring engine ("numpy", "jax" or
                     "pallas"; silent pallas -> jax -> numpy fallback).
    hierarchy      : a :class:`repro.hier.HierarchySpec` (ordered
                     coarsening levels with per-level refine budgets;
                     ``HierarchySpec.flat()`` / ``.node()`` /
                     ``.with_depth(n)``); the legacy "flat"/"node"
                     strings are deprecated aliases.
    refine_rounds / refine_top / refine_degree : DEPRECATED flat
                     refinement knobs — non-None values fold into every
                     spec level with a DeprecationWarning.
    """

    sfc: str = "FZ"
    mfz: str | bool = "auto"
    shift: bool = True
    bandwidth_scale: bool = False
    box: tuple | None = None
    box_outer_weight: float = 16.0
    drop: tuple = ()
    rotations: int = 0
    uneven_prime: bool = False
    longest_dim: bool = True
    backend: str = "vectorized"
    partition_backend: str = "numpy"
    fused: str = "auto"
    sweep: str = "batched"
    score_backend: str = "numpy"
    hierarchy: object = "flat"
    refine_rounds: int | None = None
    refine_top: int | None = None
    refine_degree: int | None = None

    def __post_init__(self):
        from repro.hier.spec import normalize_config_hierarchy
        normalize_config_hierarchy(self)


class Mapper:
    """Maps a TaskGraph onto an Allocation (the paper's Z2).

    Delegates to :class:`repro.mapping.MappingPipeline` with the
    paper's objective (WeightedHops) — kept as the stable public API.
    """

    def __init__(self, config: MapperConfig | None = None):
        from repro.mapping.pipeline import MappingPipeline, PipelineConfig
        self.config = config or MapperConfig()
        # shallow per-field forwarding (NOT dataclasses.asdict, which
        # would deep-convert the nested HierarchySpec into plain dicts)
        kw = {f.name: getattr(self.config, f.name)
              for f in dataclasses.fields(self.config)}
        self.pipeline = MappingPipeline(PipelineConfig(
            objective="weighted_hops", **kw))

    def machine_coords(self, alloc: Allocation) -> np.ndarray:
        """Machine-side transform stage (see MappingPipeline)."""
        return self.pipeline.machine_coords(alloc)

    def map(self, graph: TaskGraph, alloc: Allocation,
            task_coords: np.ndarray | None = None) -> MappingResult:
        return self.pipeline.map(graph, alloc, task_coords=task_coords)


def evaluate(graph: TaskGraph, alloc: Allocation, res: MappingResult) -> dict:
    """All paper metrics (§3) for a mapping result."""
    task_to_coord = alloc.coords[res.task_to_proc]
    return M.evaluate_mapping(alloc.machine, graph.edges, graph.weights,
                              task_to_coord)


def identity_mapping(graph: TaskGraph, alloc: Allocation) -> MappingResult:
    """Task i -> core i in allocation order (the "default" mapping of the
    paper's applications: MPI rank order)."""
    n = graph.n
    return MappingResult(np.arange(n) % alloc.n)
