"""Task mapping via consistent geometric ordering (paper §4, Alg. 1).

``geometric_map`` is Algorithm 1: order task coordinates and processor
coordinates with the same Multi-Jagged recursion (+SFC part numbering) and
match equal part numbers.  ``Mapper`` wraps the full Z2 pipeline with the
paper's transforms (shift, bandwidth scaling, box lift, +E, rotation
search) so applications and the JAX mesh builder call one entry point.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from . import metrics as M
from .kmeans import closest_subset
from .machine import Allocation, Machine
from .orderings import order_points
from .taskgraph import TaskGraph
from .transforms import (apply_permutation, box_lift, drop_dims,
                         permutations, scale_by_bandwidth, shift_torus)


@dataclasses.dataclass
class MappingResult:
    """task_to_proc[t] = index into the processor coordinate array.

    For tnum > pnum several tasks share a processor.  ``proc_to_tasks`` is
    a list of task-index arrays per processor.  ``rotation`` records the
    winning (task_perm, proc_perm) of the rotation search; ``score`` its
    WeightedHops.
    """

    task_to_proc: np.ndarray
    rotation: tuple = ((), ())
    score: float = float("nan")

    def proc_to_tasks(self, pnum: int) -> list:
        out = [[] for _ in range(pnum)]
        for t, p in enumerate(self.task_to_proc):
            out[p].append(t)
        return [np.array(x, dtype=np.int64) for x in out]


def _match_parts(mu_task: np.ndarray, mu_proc: np.ndarray) -> np.ndarray:
    """task_to_proc from equal part numbers (paper GETMAPPINGARRAYS).

    When several tasks share a part (tnum > pnum) they all map to the
    processor holding that part.  When parts hold one task and one
    processor (tnum == pnum) this is the paper's 1:1 case.
    """
    nparts = int(mu_proc.max()) + 1
    part_to_proc = np.full(nparts, -1, dtype=np.int64)
    part_to_proc[mu_proc] = np.arange(len(mu_proc))
    if (part_to_proc < 0).any():
        # multiple procs per part (pnum > nparts): keep first occurrence
        missing = np.flatnonzero(part_to_proc < 0)
        raise ValueError(f"parts with no processor: {missing[:5]}")
    return part_to_proc[mu_task]


def geometric_map(
    task_coords: np.ndarray,
    proc_coords: np.ndarray,
    *,
    sfc: str = "FZ",
    mfz: str | bool = "auto",
    longest_dim: bool = True,
    uneven_prime: bool = False,
    task_weights: np.ndarray | None = None,
    task_perm=None,
    proc_perm=None,
) -> MappingResult:
    """Paper Algorithm 1 for one (task_perm, proc_perm) rotation."""
    tc = np.asarray(task_coords, dtype=np.float64)
    pc = np.asarray(proc_coords, dtype=np.float64)
    if task_perm is not None:
        tc = apply_permutation(tc, task_perm)
    if proc_perm is not None:
        pc = apply_permutation(pc, proc_perm)
    tnum, td = tc.shape
    pnum, pd = pc.shape

    subset = None
    if tnum < pnum:
        subset = closest_subset(pc, tnum)
        pc = pc[subset]
        pnum = tnum
    np_parts = min(tnum, pnum)

    task_sfc = proc_sfc = sfc
    use_mfz = (mfz is True) or (
        mfz == "auto" and sfc == "FZ" and pd != td and pd % max(td, 1) == 0)
    if use_mfz:
        task_sfc = "FZlow"  # MFZ: flip the LOW half on the smaller-dim side
        proc_sfc = "FZ"

    mu_t = order_points(tc, np_parts, task_sfc, weights=task_weights,
                        longest_dim=longest_dim, uneven_prime=uneven_prime)
    mu_p = order_points(pc, np_parts, proc_sfc, longest_dim=longest_dim,
                        uneven_prime=uneven_prime)
    t2p = _match_parts(mu_t, mu_p)
    if subset is not None:
        t2p = subset[t2p]
    return MappingResult(t2p, rotation=(tuple(task_perm or ()),
                                        tuple(proc_perm or ())))


# ---------------------------------------------------------------------------
# Full Z2 pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MapperConfig:
    """Configuration of the Z2-style mapper.

    sfc            : part-numbering ordering ("FZ" is the paper's winner).
    mfz            : use the MFZ task-side variant when pd % td == 0.
    shift          : torus wrap-around shifting of machine coords.
    bandwidth_scale: Z2_2 — scale distances by 1/link-bandwidth.
    box            : Z2_3 — lift machine coords by this box shape (None=off).
    box_outer_weight: scale of the between-box coordinates.
    drop           : dims to drop from machine coords (BG/Q "+E").
    rotations      : 0 = identity only; otherwise max number of
                     (task!, proc!) permutation pairs evaluated, keeping
                     the best WeightedHops (paper's rotation search).
    uneven_prime   : Z2_2 — largest-prime-divisor uneven bisection.
    longest_dim    : cut the longest dimension (False = strict alternation).
    """

    sfc: str = "FZ"
    mfz: str | bool = "auto"
    shift: bool = True
    bandwidth_scale: bool = False
    box: tuple | None = None
    box_outer_weight: float = 16.0
    drop: tuple = ()
    rotations: int = 0
    uneven_prime: bool = False
    longest_dim: bool = True


class Mapper:
    """Maps a TaskGraph onto an Allocation (the paper's Z2)."""

    def __init__(self, config: MapperConfig | None = None):
        self.config = config or MapperConfig()

    def machine_coords(self, alloc: Allocation) -> np.ndarray:
        """Apply the machine-side transforms of the pipeline.

        Core dims are dropped first: every core of a node carries its
        ROUTER's coordinates (paper §2 — coordinates come from the
        router; intra-node communication is free).  MJ then keeps a
        node's cores in consecutive parts automatically (equal
        coordinates are never separated before everything else is cut).
        """
        cfg = self.config
        machine = alloc.machine
        coords = alloc.coords.astype(np.float64)
        if machine.core_dims:
            nd = machine.ndim - machine.core_dims
            coords = coords[:, :nd]
        if cfg.shift:
            coords = shift_torus(coords, machine)
        if cfg.bandwidth_scale:
            coords = scale_by_bandwidth(coords, machine)
        if cfg.drop:
            coords = drop_dims(coords, cfg.drop)
        if cfg.box is not None:
            nd = coords.shape[1]
            box = tuple(cfg.box) + (1,) * (nd - len(cfg.box))
            coords = box_lift(coords, box, outer_weight=cfg.box_outer_weight)
        return coords

    def map(self, graph: TaskGraph, alloc: Allocation,
            task_coords: np.ndarray | None = None) -> MappingResult:
        cfg = self.config
        pc = self.machine_coords(alloc)
        tc = np.asarray(task_coords if task_coords is not None
                        else graph.coords, dtype=np.float64)
        combos = [(None, None)]
        if cfg.rotations:
            tperms = permutations(tc.shape[1])
            pperms = permutations(pc.shape[1])
            combos = [(a, b) for a in tperms for b in pperms]
            if len(combos) > cfg.rotations:
                sel = np.linspace(0, len(combos) - 1,
                                  cfg.rotations).astype(int)
                combos = [combos[i] for i in sel]
        best = None
        for tp, pp in combos:
            res = geometric_map(
                tc, pc, sfc=cfg.sfc, mfz=cfg.mfz,
                longest_dim=cfg.longest_dim, uneven_prime=cfg.uneven_prime,
                task_perm=tp, proc_perm=pp)
            if len(combos) == 1:
                res.score = float("nan")
                return res
            score = self._weighted_hops(graph, alloc, res)
            if best is None or score < best.score:
                res.score = score
                best = res
        return best

    @staticmethod
    def _weighted_hops(graph: TaskGraph, alloc: Allocation,
                       res: MappingResult) -> float:
        coords = alloc.coords[res.task_to_proc]
        src = coords[graph.edges[:, 0]]
        dst = coords[graph.edges[:, 1]]
        return M.weighted_hops(alloc.machine, src, dst, graph.weights)


def evaluate(graph: TaskGraph, alloc: Allocation, res: MappingResult) -> dict:
    """All paper metrics (§3) for a mapping result."""
    task_to_coord = alloc.coords[res.task_to_proc]
    return M.evaluate_mapping(alloc.machine, graph.edges, graph.weights,
                              task_to_coord)


def identity_mapping(graph: TaskGraph, alloc: Allocation) -> MappingResult:
    """Task i -> core i in allocation order (the "default" mapping of the
    paper's applications: MPI rank order)."""
    n = graph.n
    return MappingResult(np.arange(n) % alloc.n)
