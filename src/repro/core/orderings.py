"""Space-filling-curve part numberings (paper Alg. 2 + Appendix A).

The paper's Multi-Jagged partitioner assigns part numbers to the parts it
creates.  The *ordering* of those part numbers determines which task part is
matched with which processor part, and is the paper's headline algorithmic
contribution:

- ``Z``     : Morton order — lower part numbers below each cut (no flips).
- ``Gray``  : flip *all* coordinates of the high half after each bisection.
- ``FZ``    : Flipped-Z — flip only the *cut dimension* of the high half.
- ``FZlow`` : the MFZ companion — flip the cut dimension of the *low* half.
              (MFZ = number one side with FZ and the other with FZlow; used
              when ``pd mod td == 0``, see :mod:`repro.core.mapping`.)
- ``H``     : Hilbert order (Skilling's transpose algorithm, any dimension).

Three implementations are provided:

``order_points``            — generic Algorithm 2 on arbitrary coordinates.
                              Dispatches to the level-synchronous
                              vectorised engine (:mod:`repro.core.partition`)
                              by default; ``backend="recursive"`` selects
                              the original per-part Python recursion, kept
                              as the cross-check oracle.
``grid_order`` / fast paths — closed-form bit-twiddling for structured
                              2^k-per-side grids (used by the Table-1
                              benchmark at up to 2^20 points).  The generic
                              and closed-form paths are cross-checked in
                              tests/test_orderings.py.

The two generic backends return bit-identical part numbers (the
equivalence suite in tests/test_partition.py asserts this across random
point sets, weights, ``uneven_prime`` and every SFC kind); the vectorised
engine is the default because it removes the Python-per-part overhead
(>=10x faster at 2^18 points / 4096 parts — see ``benchmarks/run.py``'s
``partition`` entry).
"""

from __future__ import annotations

import warnings

import numpy as np

from repro import faults, obs

SFC_KINDS = ("Z", "Gray", "FZ", "FZlow", "H")
BACKENDS = ("vectorized", "recursive", "jax")

# Partitioner device backends (the pipeline-level knob): "jax" runs the
# level-synchronous sweep on accelerator (:mod:`repro.core.partition_jax`)
# and falls back silently to "numpy" (the vectorized engine) when the
# jax stack is unavailable — same resolved-once discipline as the
# score-backend chain in :mod:`repro.core.metrics`.
PARTITION_BACKENDS = ("numpy", "jax")
_PARTITION_CHAIN = {"numpy": ("numpy",), "jax": ("jax", "numpy")}

# memoised import result: False = untried, None = unavailable,
# module object = ready
_JAX_PART = False

# Import/initialisation failures that legitimately disable the device
# partition engine; runtime faults must propagate to the caller (the
# serve layer's degradation ladder owns per-request recovery).  Mirrors
# ``metrics._IMPORT_FAILURES``.
_IMPORT_FAILURES = (ImportError, AttributeError, OSError, RuntimeError)

_JAX_PART_CAUSE: str | None = None
_WARNED_FALLBACK = False


def _warn_partition_fallback() -> None:
    """Once-per-process warning when "jax" resolves to the host engine."""
    global _WARNED_FALLBACK
    if _WARNED_FALLBACK:
        return
    _WARNED_FALLBACK = True
    cause = _JAX_PART_CAUSE or "backend unavailable"
    warnings.warn(
        "partition backend 'jax' unavailable, resolved to the host "
        f"vectorized engine ({cause})", RuntimeWarning, stacklevel=3)


def _jax_partition_module():
    global _JAX_PART, _JAX_PART_CAUSE
    if _JAX_PART is False:
        try:
            from . import partition_jax
            _JAX_PART = partition_jax
        except _IMPORT_FAILURES as e:  # pragma: no cover - jax in image
            _JAX_PART = None
            _JAX_PART_CAUSE = repr(e)
    return _JAX_PART


def resolve_partition_backend(backend: str) -> str:
    """Resolve a partition-backend request down its fallback chain.

    Returns the backend that will actually run ("jax" only when the
    device engine imports cleanly).  Callers resolve ONCE per pipeline
    (mirrors ``metrics.get_evaluator``) so the import cost and the
    fallback decision are not paid per request.
    """
    if backend not in PARTITION_BACKENDS:
        raise ValueError(f"unknown partition backend {backend!r}; "
                         f"options: {PARTITION_BACKENDS}")
    for name in _PARTITION_CHAIN[backend]:
        if name == "numpy" or _jax_partition_module() is not None:
            if name != backend:
                _warn_partition_fallback()
            return name
    return "numpy"  # pragma: no cover - chain always ends in numpy


# ---------------------------------------------------------------------------
# Generic Algorithm 2: recursive bisection with coordinate flips.
# ---------------------------------------------------------------------------

def _longest_dim(coords: np.ndarray, dim_order: np.ndarray | None) -> int:
    """Pick the cut dimension: largest extent, ties broken by ``dim_order``.

    ``dim_order`` is a permutation of the dimensions giving tie-break
    priority (the rotation-search in mapping.py permutes it).
    """
    ext = coords.max(axis=0) - coords.min(axis=0)
    if dim_order is None:
        dim_order = np.arange(coords.shape[1])
    # argmax over ext in priority order
    best = dim_order[0]
    for d in dim_order:
        if ext[d] > ext[best] + 1e-12:
            best = d
    return int(best)


def order_points(
    coords: np.ndarray,
    nparts: int,
    sfc: str = "FZ",
    *,
    weights: np.ndarray | None = None,
    dim_order: np.ndarray | None = None,
    longest_dim: bool = True,
    uneven_prime: bool = False,
    backend: str = "vectorized",
) -> np.ndarray:
    """Paper Algorithm 2: assign part numbers ``mu`` to ``coords``.

    Parameters
    ----------
    coords : (n, d) float array.  Not modified (we copy; the algorithm flips
        coordinates in place as it recurses).
    nparts : target number of parts.  Need not be a power of two when
        ``uneven_prime`` (Z2_2's largest-prime-divisor bisection) is on.
    sfc : one of ``Z | Gray | FZ | FZlow | H``.
    weights : optional per-point weights; cuts balance total weight.
    dim_order : tie-break priority permutation for the cut dimension.
    longest_dim : if False, strictly alternate dimensions per recursion
        level (the paper's earlier [21] behaviour).
    uneven_prime : Z2_2 — split ``nparts`` by its largest prime divisor
        (3/5 vs 2/5 for p=5) instead of requiring powers of two.
    backend : ``"vectorized"`` (level-synchronous engine, default),
        ``"recursive"`` (the original reference recursion) or ``"jax"``
        (the device engine of :mod:`repro.core.partition_jax`, falling
        back silently to the vectorized engine when jax is missing).
        All return bit-identical part numbers.

    Returns
    -------
    mu : (n,) int64 part numbers in ``[0, nparts)``.
    """
    coords = np.asarray(coords, dtype=np.float64)
    if sfc == "H":
        if backend == "jax":
            mod = _jax_partition_module()
            if mod is not None:
                with obs.span("partition.jax", points=len(coords),
                              nparts=int(nparts)):
                    faults.fire("partition.jax")
                    return mod.order_points_jax(
                        coords, nparts, "H", weights=weights)
            _warn_partition_fallback()  # host Hilbert is bit-identical
        return _hilbert_order_points(coords.copy(), nparts, weights=weights)
    if sfc not in SFC_KINDS:
        raise ValueError(f"unknown sfc {sfc!r}")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "recursive":
        return order_points_recursive(
            coords, nparts, sfc, weights=weights, dim_order=dim_order,
            longest_dim=longest_dim, uneven_prime=uneven_prime)
    if backend == "jax":
        mod = _jax_partition_module()
        if mod is not None:
            with obs.span("partition.jax", points=len(coords),
                          nparts=int(nparts)):
                faults.fire("partition.jax")
                return mod.order_points_jax(
                    coords, nparts, sfc, weights=weights,
                    dim_order=dim_order, longest_dim=longest_dim,
                    uneven_prime=uneven_prime)
        _warn_partition_fallback()  # vectorized engine is bit-identical
    from .partition import vectorized_order
    return vectorized_order(
        coords, nparts, sfc, weights=weights, dim_order=dim_order,
        longest_dim=longest_dim, uneven_prime=uneven_prime)


def order_points_batched(
    coords: np.ndarray,
    nparts: int,
    sfc: str = "FZ",
    *,
    dim_orders: np.ndarray,
    weights: np.ndarray | None = None,
    longest_dim: bool = True,
    uneven_prime: bool = False,
    backend: str = "vectorized",
) -> np.ndarray:
    """Paper Algorithm 2 for a whole stack of dimension rotations at once.

    The §4.3 rotation search evaluates many column permutations of the
    SAME point cloud.  Permuting columns only changes which dimension
    the cut-selection rule prefers — the cut values, weights and flips
    are untouched — so rotation ``perm`` is exactly ``order_points(
    coords, ..., dim_order=perm)``.  This entry point exploits that:
    all B rotations run as outermost segments of ONE level-synchronous
    engine pass (shared per-dimension presorts, one segment table),
    instead of B Python-level ``order_points`` calls.

    Parameters
    ----------
    coords : (n, d) float array, shared by every candidate.
    nparts : target number of parts (same contract as ``order_points``).
    sfc : one of ``Z | Gray | FZ | FZlow | H``.
    dim_orders : (B, d) int array; row ``b`` is candidate ``b``'s
        cut-dimension priority permutation (the rotation itself).  For
        ``"H"`` there is no cut priority — the Hilbert index depends on
        the column order itself — so each row acts as a COLUMN
        permutation of the cloud instead: row ``b`` equals
        ``order_points(coords[:, dim_orders[b]], nparts, "H")``, which
        is exactly what the rotation sweep's per-candidate
        ``apply_permutation`` + ``order_points`` pair computes.
    weights, longest_dim, uneven_prime : as in ``order_points``.
    backend : ``"vectorized"`` runs the single batched engine pass (for
        ``"H"`` a per-candidate Hilbert-index loop over ONE memoised
        quantisation); ``"jax"`` the on-device batched sweep (silent
        fallback to vectorized); ``"recursive"`` loops the reference
        per-candidate oracle (slow, kept for equivalence tests).

    Returns
    -------
    mu : (B, n) int64 part numbers.  Row ``b`` is bit-identical to both
        ``order_points(coords, nparts, sfc, dim_order=dim_orders[b])``
        and ``order_points(coords[:, dim_orders[b]], nparts, sfc)``
        (asserted in tests/test_batched.py; for ``"H"`` only the
        latter identity holds — see ``dim_orders`` above).
    """
    coords = np.asarray(coords, dtype=np.float64)
    dim_orders = np.atleast_2d(np.asarray(dim_orders, dtype=np.int64))
    if sfc == "H":
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        if backend == "recursive":
            # the per-candidate oracle: quantise per permuted cloud
            return np.stack([
                _hilbert_order_points(coords[:, do].copy(), nparts,
                                      weights=weights)
                for do in dim_orders])
        if backend == "jax":
            mod = _jax_partition_module()
            if mod is not None:
                with obs.span("partition.jax", points=len(coords),
                              nparts=int(nparts), batch=len(dim_orders)):
                    faults.fire("partition.jax")
                    return mod.order_points_batched_jax(
                        coords, nparts, "H", dim_orders=dim_orders,
                        weights=weights)
            _warn_partition_fallback()  # host Hilbert is bit-identical
        return _hilbert_order_batched(coords, nparts, dim_orders,
                                      weights=weights)
    if sfc not in SFC_KINDS:
        raise ValueError(f"unknown sfc {sfc!r}")
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}")
    if backend == "recursive":
        return np.stack([
            order_points_recursive(
                coords, nparts, sfc, weights=weights, dim_order=do,
                longest_dim=longest_dim, uneven_prime=uneven_prime)
            for do in dim_orders])
    if backend == "jax":
        mod = _jax_partition_module()
        if mod is not None:
            with obs.span("partition.jax", points=len(coords),
                          nparts=int(nparts), batch=len(dim_orders)):
                faults.fire("partition.jax")
                return mod.order_points_batched_jax(
                    coords, nparts, sfc, dim_orders=dim_orders,
                    weights=weights, longest_dim=longest_dim,
                    uneven_prime=uneven_prime)
        _warn_partition_fallback()  # vectorized engine is bit-identical
    from .partition import vectorized_order_batched
    return vectorized_order_batched(
        coords, nparts, sfc, dim_orders=dim_orders, weights=weights,
        longest_dim=longest_dim, uneven_prime=uneven_prime)


def order_points_recursive(
    coords: np.ndarray,
    nparts: int,
    sfc: str = "FZ",
    *,
    weights: np.ndarray | None = None,
    dim_order: np.ndarray | None = None,
    longest_dim: bool = True,
    uneven_prime: bool = False,
) -> np.ndarray:
    """The original per-part recursion (paper Alg. 2), kept as the
    cross-check oracle for the vectorised engine."""
    coords = np.asarray(coords, dtype=np.float64).copy()
    n, d = coords.shape
    if sfc == "H":
        return _hilbert_order_points(coords, nparts, weights=weights)
    if sfc not in SFC_KINDS:
        raise ValueError(f"unknown sfc {sfc!r}")
    if weights is None:
        weights = np.ones(n, dtype=np.float64)
    else:
        weights = np.asarray(weights, dtype=np.float64)
    mu = np.zeros(n, dtype=np.int64)
    idx = np.arange(n)
    _mj_helper(
        coords, idx, weights, nparts, sfc, mu,
        dim_order=dim_order,
        longest=longest_dim,
        level=0,
        uneven_prime=uneven_prime,
    )
    return mu


def _split_counts(nparts: int, uneven_prime: bool) -> tuple[int, int]:
    """How many parts go left/right of the bisection cut.

    Z2_2 (uneven_prime): split by the largest prime divisor p — e.g.
    10800 = 2^4*3^3*5^2 splits 2/5 vs 3/5 (the paper's example), so node
    boundaries are respected high in the hierarchy.  Power-of-two counts
    reduce to plain bisection.
    """
    if not uneven_prime:
        return nparts // 2, nparts - nparts // 2
    p = _largest_prime_factor(nparts)
    if p <= 2:
        return nparts // 2, nparts - nparts // 2
    k = p // 2
    return (k * nparts) // p, nparts - (k * nparts) // p


def _largest_prime_factor(x: int) -> int:
    best = 1
    n = x
    f = 2
    while f * f <= n:
        while n % f == 0:
            best = max(best, f)
            n //= f
        f += 1
    if n > 1:
        best = max(best, n)
    return best


def _mj_helper(coords, idx, weights, nparts, sfc, mu, *, dim_order, longest,
               level, uneven_prime):
    if nparts <= 1 or len(idx) == 0:
        return
    d = coords.shape[1]
    if longest:
        cut_dim = _longest_dim(coords[idx], dim_order)
    else:
        order = dim_order if dim_order is not None else np.arange(d)
        cut_dim = int(order[level % d])

    npl, npr = _split_counts(nparts, uneven_prime)
    # 1D partition: cut so the left side holds npl/nparts of the weight.
    sub = idx[np.argsort(coords[idx, cut_dim], kind="stable")]
    cw = np.cumsum(weights[sub])
    total = cw[-1]
    target = total * (npl / nparts)
    # number of points on the left = first index where cumweight >= target
    k = int(np.searchsorted(cw, target, side="left")) + 1
    k = min(max(k, 1), len(sub) - 1) if len(sub) > 1 else 0
    if len(sub) <= 1:
        # fewer points than parts: everything stays in part 0 of this range
        return
    left, right = sub[:k], sub[k:]

    if sfc == "Gray":
        coords[right] = -coords[right]
    elif sfc == "FZ":
        coords[right, cut_dim] = -coords[right, cut_dim]
    elif sfc == "FZlow":
        coords[left, cut_dim] = -coords[left, cut_dim]
    mu[right] += npl

    _mj_helper(coords, left, weights, npl, sfc, mu, dim_order=dim_order,
               longest=longest, level=level + 1, uneven_prime=uneven_prime)
    _mj_helper(coords, right, weights, npr, sfc, mu, dim_order=dim_order,
               longest=longest, level=level + 1, uneven_prime=uneven_prime)


# ---------------------------------------------------------------------------
# Closed-form grid orderings (power-of-two sides, equal extents).
#
# On an equal-sided grid the longest-dimension rule visits dimensions
# cyclically (0, 1, .., d-1, 0, ..), so part numbers have the structure
# analysed in Appendix A: the bits of dimension i land at bit positions
# ``cuts_i = [i, i+d, i+2d, ...]`` (0-based from the *least* significant
# cut).  Z interleaves plain binary per-dim indices; FZ interleaves
# Gray-coded per-dim indices (Appendix A.2); FZlow interleaves
# reflected-Gray-coded indices.  Cross-checked against order_points.
# ---------------------------------------------------------------------------

def gray_encode(x: np.ndarray) -> np.ndarray:
    return x ^ (x >> 1)


def gray_decode(g: np.ndarray) -> np.ndarray:
    x = np.asarray(g).copy()
    s = 1
    while True:
        shifted = x >> s
        if not shifted.any():
            break
        x = x ^ shifted
        s *= 2
    return x


def _fzlow_encode(x: np.ndarray, bits: int) -> np.ndarray:
    """Per-dimension index ordering induced by FZlow on 2^bits points.

    Derived by running Algorithm 2 with the flip on the *low* half: the
    resulting sequence is the reflection-complement of the Gray sequence.
    Computed by simulation once and memoised (bits is small).
    """
    table = _fzlow_table(bits)
    return table[x]


_FZLOW_CACHE: dict[int, np.ndarray] = {}


def _fzlow_table(bits: int) -> np.ndarray:
    if bits in _FZLOW_CACHE:
        return _FZLOW_CACHE[bits]
    n = 1 << bits
    coords = np.arange(n, dtype=np.float64)[:, None]
    mu = order_points(coords, n, "FZlow")
    _FZLOW_CACHE[bits] = mu.astype(np.int64)
    return _FZLOW_CACHE[bits]


def grid_order(shape: tuple[int, ...], sfc: str) -> np.ndarray:
    """Part number for every cell of a structured grid.

    ``shape`` must be power-of-two per side with equal sides (the Table-1
    setting).  Returns an int64 array of ``shape`` giving each cell's part
    number under the requested ordering, matching ``order_points`` on the
    grid's cell-centre coordinates.
    """
    d = len(shape)
    side = shape[0]
    if any(s != side for s in shape):
        raise ValueError("grid_order requires equal sides")
    bits = int(side).bit_length() - 1
    if (1 << bits) != side:
        raise ValueError("grid_order requires power-of-two sides")

    ix = np.indices(shape)  # (d, *shape)
    if sfc == "Z":
        per_dim = ix
    elif sfc == "FZ":
        per_dim = gray_encode(ix)
    elif sfc == "FZlow":
        tab = _fzlow_table(bits)
        per_dim = tab[ix]
    elif sfc == "H":
        return _hilbert_grid(shape, bits)
    elif sfc == "Gray":
        # No independent per-dim structure; fall back to the generic path.
        coords = np.stack([c.ravel() for c in ix], axis=1).astype(np.float64)
        mu = order_points(coords, side ** d, "Gray")
        return mu.reshape(shape)
    else:
        raise ValueError(f"unknown sfc {sfc!r}")

    # Interleave bits: cut with reverse-index j (0 = last/least significant)
    # along dimension i takes bit (j) of per_dim[i] into part-number bit
    # position i + d*j ... but numbered from the most significant cut first:
    # the FIRST cut is along dim 0 and is the most significant bit.
    # Equivalently: part = sum over dims i, bits j of
    #   bit_j(per_dim[i]) << (j*d + (d-1-i)).
    part = np.zeros(shape, dtype=np.int64)
    for i in range(d):
        v = per_dim[i].astype(np.int64)
        for j in range(bits):
            bit = (v >> j) & 1
            part |= bit << (j * d + (d - 1 - i))
    return part


# ---------------------------------------------------------------------------
# Hilbert (Skilling's transpose algorithm), vectorised, any dimension.
# ---------------------------------------------------------------------------

def hilbert_index(X: np.ndarray, bits: int) -> np.ndarray:
    """Hilbert index of integer points ``X`` (n, d) with ``bits`` per dim."""
    X = np.asarray(X, dtype=np.int64).copy()
    n, d = X.shape
    if d == 1:
        return X[:, 0].copy()
    M = np.int64(1) << (bits - 1)
    # Inverse undo excess work
    Q = M
    while Q > 1:
        P = Q - 1
        for i in range(d):
            has = (X[:, i] & Q) != 0
            # if bit set: invert low bits of X[0]
            X[:, 0] = np.where(has, X[:, 0] ^ P, X[:, 0])
            # else: exchange low bits of X[0] and X[i]
            t = np.where(has, 0, (X[:, 0] ^ X[:, i]) & P)
            X[:, 0] ^= t
            X[:, i] ^= t
        Q >>= 1
    # Gray encode
    for i in range(1, d):
        X[:, i] ^= X[:, i - 1]
    t = np.zeros(n, dtype=np.int64)
    Q = M
    while Q > 1:
        has = (X[:, d - 1] & Q) != 0
        t = np.where(has, t ^ (Q - 1), t)
        Q >>= 1
    for i in range(d):
        X[:, i] ^= t
    # Interleave transposed bits into a single index: bit b of dim i goes to
    # position b*d + (d-1-i).
    out = np.zeros(n, dtype=np.int64)
    for i in range(d):
        for b in range(bits):
            bit = (X[:, i] >> b) & 1
            out |= bit << (b * d + (d - 1 - i))
    return out


def _hilbert_grid(shape: tuple[int, ...], bits: int) -> np.ndarray:
    ix = np.indices(shape)
    pts = np.stack([c.ravel() for c in ix], axis=1)
    h = hilbert_index(pts, bits)
    # ranks = part numbers (h is a permutation of 0..n-1 for full grids)
    return h.reshape(shape)


def hilbert_bits(n: int, d: int) -> int:
    """Default Hilbert quantisation resolution (bits per dimension).

    Enough bits to separate ~n points per dimension, capped so the
    interleaved index fits int64.  One definition shared by the host
    quantiser below and the device kernel
    (:mod:`repro.core.partition_jax`), which unrolls its Skilling loop
    over this static value — the two MUST agree for bit-identity.
    """
    return max(1, min(62 // max(d, 1),
                      int(np.ceil(np.log2(max(n, 2)) / max(d, 1))) + 2))


def _hilbert_quantise(coords: np.ndarray, bits: int) -> np.ndarray:
    """Quantise float points onto the 2^bits-per-side Hilbert grid."""
    side = 1 << bits
    lo = coords.min(axis=0)
    span = coords.max(axis=0) - lo
    span = np.where(span > 0, span, 1.0)
    return np.clip(((coords - lo) / span * (side - 1))
                   .round().astype(np.int64), 0, side - 1)


def hilbert_key(coords: np.ndarray, bits: int | None = None) -> np.ndarray:
    """Hilbert index of arbitrary float points (quantised to a grid).

    Default resolution: :func:`hilbert_bits`.  Shared by the generic
    Hilbert part numbering below and the hierarchical subsystem's
    intra-node task ordering (:mod:`repro.hier.refine`).
    """
    coords = np.asarray(coords, dtype=np.float64)
    n, d = coords.shape
    if bits is None:
        bits = hilbert_bits(n, d)
    return hilbert_index(_hilbert_quantise(coords, bits), bits)


def _hilbert_split(h: np.ndarray, nparts: int,
                   weights: np.ndarray | None) -> np.ndarray:
    """Part numbers from Hilbert indices: stable-sort, split the run."""
    n = len(h)
    order = np.argsort(h, kind="stable")
    mu = np.zeros(n, dtype=np.int64)
    if weights is None:
        # equal-count split
        bounds = (np.arange(1, nparts) * n) // nparts
        mu[order] = np.searchsorted(bounds, np.arange(n), side="right")
    else:
        # weight-proportional split on the EXCLUSIVE prefix (the weight
        # strictly before each point): part = floor(prefix/total *
        # nparts).  The old inclusive cumsum shifted every boundary by
        # one point — part 0 was always empty and the last part doubled
        # (equal weights with n == nparts were not even a permutation).
        w = np.asarray(weights, dtype=np.float64)[order]
        cw = np.cumsum(w) - w
        total = cw[-1] + w[-1]
        mu[order] = np.minimum((cw / total * nparts).astype(np.int64),
                               nparts - 1)
    return mu


def _hilbert_order_points(coords: np.ndarray, nparts: int,
                          weights: np.ndarray | None) -> np.ndarray:
    """Hilbert ordering for arbitrary point sets: quantise to a grid,
    order by Hilbert index, split into equal-count parts."""
    return _hilbert_split(hilbert_key(coords), nparts, weights)


def _hilbert_order_batched(coords: np.ndarray, nparts: int,
                           dim_orders: np.ndarray,
                           weights: np.ndarray | None) -> np.ndarray:
    """Batched Hilbert numbering: one candidate per ``dim_orders`` row,
    row ``b`` bit-identical to ``order_points(coords[:, dim_orders[b]],
    nparts, "H")``.

    Per-column quantisation commutes with column permutation (lo/span
    are per-dimension), so the grid coordinates are computed ONCE and
    each candidate only re-runs the Skilling index on a column gather —
    the memoisation that lets rotation sweeps include "H" without B
    full quantisation passes.
    """
    n, d = coords.shape
    bits = hilbert_bits(n, d)
    q = _hilbert_quantise(coords, bits)
    out = np.empty((len(dim_orders), n), dtype=np.int64)
    for b, do in enumerate(dim_orders):
        out[b] = _hilbert_split(hilbert_index(q[:, do], bits), nparts,
                                weights)
    return out
