"""Core: the paper's geometric task-mapping contribution.

Public API re-exports.
"""

from .kmeans import closest_subset
from .machine import (Allocation, Machine, bgq, block_allocation,
                      gemini_xk7, make_machine, random_allocation,
                      sfc_allocation, tpu_v4_cube, tpu_v5e_multipod,
                      tpu_v5e_pod)
from .mapping import (Mapper, MapperConfig, MappingResult, evaluate,
                      geometric_map, identity_mapping)
from .metrics import (Traffic, average_hops, data_metric,
                      evaluate_candidates, evaluate_mapping, latency_metric,
                      pairwise_hops, per_dim_stats, route_traffic,
                      total_hops, weighted_hops)
from .signature import (allocation_signature, array_digest,
                        config_signature, machine_signature,
                        mapping_signature, taskgraph_signature)
from .orderings import (BACKENDS, SFC_KINDS, gray_decode, gray_encode,
                        grid_order, hilbert_index, hilbert_key,
                        order_points, order_points_batched,
                        order_points_recursive)
from .taskgraph import (TaskGraph, cube_coords, cube_sphere_graph,
                        face2d_coords, logical_mesh_graph, stencil_graph)
from .transforms import (apply_permutation, box_lift, drop_dims,
                         normalize_extents, permutations, scale_by_bandwidth,
                         shift_torus)

__all__ = [
    "Allocation", "BACKENDS", "Machine", "Mapper", "MapperConfig",
    "MappingResult", "SFC_KINDS", "TaskGraph", "Traffic",
    "allocation_signature", "array_digest",
    "apply_permutation", "average_hops", "bgq", "block_allocation",
    "box_lift", "closest_subset", "config_signature", "cube_coords",
    "cube_sphere_graph",
    "data_metric", "drop_dims", "evaluate", "evaluate_candidates",
    "evaluate_mapping", "face2d_coords", "gemini_xk7", "geometric_map",
    "gray_decode", "gray_encode", "grid_order", "hilbert_index",
    "hilbert_key", "identity_mapping", "latency_metric",
    "logical_mesh_graph",
    "machine_signature", "mapping_signature",
    "make_machine", "normalize_extents", "order_points",
    "order_points_batched", "order_points_recursive",
    "pairwise_hops", "per_dim_stats",
    "permutations", "random_allocation", "route_traffic",
    "scale_by_bandwidth", "sfc_allocation", "shift_torus",
    "stencil_graph", "taskgraph_signature", "total_hops",
    "tpu_v4_cube", "tpu_v5e_multipod",
    "tpu_v5e_pod", "weighted_hops",
]
