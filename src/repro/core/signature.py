"""Content-addressed signatures for mapping inputs (the serve layer's
cache keys).

A *signature* is a short hex digest that identifies a mapping problem by
CONTENT, not by object identity: two ``TaskGraph``s built independently
from the same arrays hash to the same signature, so a request cache keyed
by signatures (``repro.serve``) deduplicates repeat and concurrent
requests across processes, sessions and callers.

Canonicalisation rules (what is — and is not — part of the identity):

- arrays hash their dtype, shape and raw bytes (C-contiguous);
- ``TaskGraph.meta`` is EXCLUDED — it is free-form provenance that never
  affects the mapping;
- ``Machine.name`` is EXCLUDED — it is a report label; two machines with
  the same dims/wrap/bandwidths/core-dims are the same network;
- dataclass configs (``PipelineConfig`` & co) hash a canonical-JSON
  field-by-field form (nested dataclasses such as ``HierarchySpec``
  keep their own type tag), so tuple/list spelling differences or the
  construction path of an equal spec do not split the cache.

The digest is SHA-1 truncated to 128 bits.  Signatures are CACHE KEYS,
not a security boundary — SHA-1 is the fastest (hardware-accelerated)
primitive hashlib offers on the serving hosts (~2.6x blake2b here), the
warm-path request latency is dominated by exactly this hash, and 128
bits keeps accidental collisions out of reach for any realistic request
volume.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json

import numpy as np

DIGEST_BYTES = 16  # 128-bit keys (truncated SHA-1)


def _hasher():
    return hashlib.sha1(usedforsecurity=False)


def _hexdigest(h) -> str:
    return h.hexdigest()[: 2 * DIGEST_BYTES]


def _update_array(h, arr: np.ndarray) -> None:
    arr = np.ascontiguousarray(arr)
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    # feed the array's buffer directly (no tobytes() copy); writeable
    # arrays export a buffer fine, and C-contiguity is guaranteed above
    h.update(arr.view(np.uint8).reshape(-1).data)


def array_digest(arr: np.ndarray) -> str:
    """Digest of one array (dtype + shape + bytes)."""
    h = _hasher()
    _update_array(h, np.asarray(arr))
    return _hexdigest(h)


def _canonical(obj):
    """JSON-encodable canonical form: tuples -> lists, arrays -> digests,
    numpy scalars -> python scalars, non-finite floats -> strings."""
    if isinstance(obj, np.ndarray):
        return {"__array__": array_digest(obj)}
    if isinstance(obj, (tuple, list)):
        return [_canonical(x) for x in obj]
    if isinstance(obj, dict):
        return {str(k): _canonical(v) for k, v in sorted(obj.items())}
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating, float)):
        f = float(obj)
        return f if np.isfinite(f) else repr(f)
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        # recurse FIELD BY FIELD (not dataclasses.asdict, which
        # deep-converts nested dataclasses to anonymous dicts): a
        # nested config value — e.g. PipelineConfig.hierarchy holding a
        # HierarchySpec — keeps its own ``__dataclass__`` tag, so two
        # structurally-different specs can never canonicalise to the
        # same key by field coincidence
        return {"__dataclass__": type(obj).__name__,
                **_canonical({f.name: getattr(obj, f.name)
                              for f in dataclasses.fields(obj)})}
    return obj


def config_signature(cfg) -> str:
    """Signature of a config dataclass (or any JSON-able structure)."""
    h = _hasher()
    h.update(json.dumps(_canonical(cfg), sort_keys=True).encode())
    return _hexdigest(h)


def taskgraph_signature(graph) -> str:
    """Signature of a :class:`repro.core.TaskGraph` (coords + edges +
    weights; ``meta`` excluded — provenance only)."""
    h = _hasher()
    h.update(b"taskgraph")
    _update_array(h, np.asarray(graph.coords))
    _update_array(h, np.asarray(graph.edges))
    _update_array(h, np.asarray(graph.weights))
    return _hexdigest(h)


def machine_signature(machine) -> str:
    """Signature of a :class:`repro.core.Machine` (dims + wrap + per-dim
    bandwidth patterns + core-dim count; ``name`` excluded)."""
    h = _hasher()
    h.update(b"machine")
    h.update(repr(tuple(machine.dims)).encode())
    h.update(repr(tuple(bool(w) for w in machine.wrap)).encode())
    h.update(str(int(machine.core_dims)).encode())
    for pat in machine.link_bw:
        _update_array(h, np.asarray(pat, dtype=np.float64))
    return _hexdigest(h)


def allocation_signature(alloc) -> str:
    """Signature of an :class:`repro.core.Allocation` (machine + the
    exact set AND order of allocated coordinate rows — allocation order
    is the identity-mapping baseline, so it is part of the problem)."""
    h = _hasher()
    h.update(b"allocation")
    h.update(machine_signature(alloc.machine).encode())
    _update_array(h, np.asarray(alloc.coords))
    return _hexdigest(h)


def mapping_signature(graph, alloc, config=None,
                      extra: dict | None = None) -> str:
    """Signature of a full mapping problem: task graph + allocation +
    pipeline config (+ optional extra fields, e.g. per-request task
    coordinate overrides)."""
    h = _hasher()
    h.update(b"mapping")
    h.update(taskgraph_signature(graph).encode())
    h.update(allocation_signature(alloc).encode())
    if config is not None:
        h.update(config_signature(config).encode())
    if extra:
        h.update(json.dumps(_canonical(extra), sort_keys=True).encode())
    return _hexdigest(h)
