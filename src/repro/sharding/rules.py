"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Rules map logical names ("embed", "heads", "vocab", ...) to mesh axes
("pod", "data", "model").  A mapping is dropped (replicated) when the
tensor dim is not divisible by the mesh-axis product or when the mesh
axis was already consumed by an earlier dim of the same tensor — this is
what lets one rule set serve configs whose kv_heads / experts are smaller
than the model axis.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


# The baseline parallelism recipe: DP+FSDP over (pod, data); TP/SP/EP over
# model.  See DESIGN.md §6.
DEFAULT_RULES: dict = {
    # --- parameters ---
    "embed": ("data",),          # FSDP (ZeRO-3) shards the embed dim
    "embed_no_fsdp": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": (),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "layers": (),
    "conv": (),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "ssm_state": (),
    "frontend": (),
    # --- activations ---
    "batch": ("pod", "data"),
    "act_seq": (),               # attention-region sequence: unsharded
    "sp_seq": ("model",),        # megatron-SP residual sequence sharding
    "act_embed": (),
    "act_heads": ("model",),
    "act_kv_heads": ("model",),
    "act_mlp": ("model",),
    "act_vocab": ("model",),
    "act_experts": ("model",),
    # --- kv cache / decode ---
    "kv_batch": ("pod", "data"),
    "kv_seq": ("model",),        # flash-decoding style seq sharding
    # --- optimizer ---
    "opt": (),
}


def _mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for_axes(axes, rules: dict, mesh: Mesh, shape=None) -> PartitionSpec:
    """PartitionSpec for one tensor's logical axes.

    Drops mappings that don't divide the dim size or reuse a mesh axis.
    """
    sizes = _mesh_axis_sizes(mesh)
    used: set = set()
    entries = []
    for i, name in enumerate(axes):
        if name is None or name == "":
            entries.append(None)
            continue
        if name not in rules:
            entries.append(None)
            continue
        want = rules[name]
        if want is None:
            entries.append(None)
            continue
        if isinstance(want, str):
            want = (want,)
        chosen = []
        prod = 1
        for ax in want:
            if ax not in sizes or ax in used:
                continue
            chosen.append(ax)
            prod *= sizes[ax]
        if not chosen:
            entries.append(None)
            continue
        if shape is not None and shape[i] % prod != 0:
            # try a prefix of the requested axes that divides
            chosen2 = []
            prod2 = 1
            for ax in chosen:
                if shape[i] % (prod2 * sizes[ax]) == 0:
                    chosen2.append(ax)
                    prod2 *= sizes[ax]
            chosen = chosen2
        if not chosen:
            entries.append(None)
            continue
        used.update(chosen)
        entries.append(tuple(chosen) if len(chosen) > 1 else chosen[0])
    # trim trailing Nones for tidiness
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def tree_shardings(axes_tree, abstract_tree, rules: dict, mesh: Mesh):
    """NamedSharding tree matching the params tree."""
    def f(axes, ab):
        return NamedSharding(mesh,
                             spec_for_axes(axes, rules, mesh, ab.shape))
    return jax.tree.map(f, axes_tree, abstract_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def logical(x, axes, rules: dict | None = None, mesh: Mesh | None = None):
    """with_sharding_constraint by logical axis names (no-op off-mesh)."""
    if mesh is None:
        mesh = _current_mesh()
    if mesh is None or mesh.empty:
        return x
    rules = rules or DEFAULT_RULES
    spec = spec_for_axes(axes, rules, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh():
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        return m
    except Exception:
        return None
