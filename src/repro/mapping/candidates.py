"""Candidate generation + the batched candidate-search engine.

A *candidate* is one configuration of the rotation / coordinate-scaling
search of paper §4.3: a (task_perm, proc_perm) dimension rotation,
optionally a per-axis task-coordinate scaling (the traffic-weighted
variant used by the TPU mesh builder), or the identity assignment
(task i -> processor i, the "default order" baseline every search must
never lose to).

:class:`CandidateSearch` scores a whole list of candidate
``task_to_proc`` arrays in vectorised passes — one
``pairwise_hops`` evaluation over the stacked coordinate tensor and,
for latency objectives, one batched dimension-ordered routing pass
(:func:`repro.core.metrics.evaluate_candidates`) — instead of the
per-candidate Python loops that ``core/mapping.py`` and
``meshmap/device_mesh.py`` used to duplicate.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.metrics import evaluate_candidates
from repro.core.transforms import permutations


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the candidate search space.

    task_perm / proc_perm : dimension rotations (None = identity).
    task_scale            : per-axis divisor of the task coordinates
                            (None = raw coordinates).
    identity              : bypass the geometric mapper entirely and use
                            task i -> proc (i mod pnum).
    label                 : free-form tag for reports.
    """

    task_perm: tuple | None = None
    proc_perm: tuple | None = None
    task_scale: tuple | None = None
    identity: bool = False
    label: str = ""


def rotation_candidates(td: int, pd: int, rotations: int) -> list[Candidate]:
    """The paper's td! x pd! rotation search, subsampled to ``rotations``
    entries (index 0 — the identity rotation — is always kept).
    ``rotations == 0`` means identity only.

    Subsampling uses a balanced factorial design: the smallest
    ``na x nb`` grid of evenly spaced task-side and proc-side
    permutations whose product covers the budget.  Compared to a flat
    ``linspace`` over the pair list this spreads the budget evenly over
    BOTH factors of the search space, and it minimises the number of
    UNIQUE per-side rotations — exactly the quantity the batched
    rotation sweep partitions (one engine segment per unique
    permutation), so a budget of B candidates costs ~``2*sqrt(B)``
    partition-equivalents instead of ~``2*B``.
    """
    if not rotations:
        return [Candidate()]
    ta, pa = permutations(td), permutations(pd)
    if len(ta) * len(pa) <= rotations:
        combos = [(a, b) for a in ta for b in pa]
    else:
        best = None
        for na in range(1, len(ta) + 1):
            nb = min(-(-rotations // na), len(pa))  # ceil division
            if na * nb < rotations:
                continue
            key = (na + nb, abs(na - nb))  # smallest grid, then balanced
            if best is None or key < best[0]:
                best = (key, na, nb)
        _, na, nb = best
        sa = [ta[i] for i in np.linspace(0, len(ta) - 1, na).astype(int)]
        sb = [pa[i] for i in np.linspace(0, len(pa) - 1, nb).astype(int)]
        combos = [(a, b) for a in sa for b in sb][:rotations]
    return [Candidate(task_perm=a, proc_perm=b, label=f"rot{i}")
            for i, (a, b) in enumerate(combos)]


class CandidateSearch:
    """Batched scorer: rank candidate mappings by a metric objective.

    objective : metric key or tuple of keys compared lexicographically
        (e.g. ``"weighted_hops"`` for the paper's rotation search,
        ``("latency_max", "weighted_hops")`` for the TPU mesh builder).
        Ties keep the EARLIER candidate, so listing the identity /
        default mapping first guarantees never-worse-than-default.
    backend : scoring engine — ``"numpy"`` (default, bit-exact
        reference), ``"jax"`` (jit-compiled ``segment_sum`` path) or
        ``"pallas"`` (fused on-chip kernel,
        :mod:`repro.kernels.mapscore`); non-numpy backends fall back
        silently down the pallas -> jax -> numpy chain when an import
        fails.
    """

    def __init__(self, objective="weighted_hops", backend="numpy"):
        self.objective = (objective,) if isinstance(objective, str) \
            else tuple(objective)
        self.backend = backend

    @property
    def needs_traffic(self) -> bool:
        return any(k in ("latency_max", "data_max") for k in self.objective)

    def score(self, graph, alloc, results) -> np.ndarray:
        """(len(results), len(objective)) score matrix, lower is better.

        Scoring uses the allocation's RAW coordinates (transforms only
        steer the partitioner; the metrics model the physical network).
        """
        coord_stack = np.stack(
            [alloc.coords[r.task_to_proc] for r in results])
        ev = evaluate_candidates(
            alloc.machine, graph.edges, graph.weights, coord_stack,
            traffic=self.needs_traffic, backend=self.backend)
        return np.stack([ev[k] for k in self.objective], axis=1)

    def best(self, graph, alloc, results):
        """(winner, winner_index, scores); first-of-ties wins.

        One stable ``np.lexsort`` over the objective columns (last key
        is most significant, so the columns go in reversed) — stability
        keeps the FIRST index among equal scores, preserving the
        never-worse-than-default guarantee.
        """
        scores = self.score(graph, alloc, results)
        keys = tuple(scores[:, j] for j in reversed(range(scores.shape[1])))
        best_i = int(np.lexsort(keys)[0])
        return results[best_i], best_i, scores
