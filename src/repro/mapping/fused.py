"""One-compiled-program rotation sweep: partition -> match -> score ->
select -> (optionally) refine, entirely on device.

When the pipeline resolves ``partition_backend="jax"`` AND a jax/pallas
scoring backend, the whole batched rotation sweep of
:meth:`MappingPipeline.map` collapses into a single jitted program per
candidate stack: both sides' level-synchronous partitions
(:mod:`repro.core.partition_jax`), the part->processor matching
gathers, the per-candidate coordinate-stack assembly, the metric
evaluation (the bucketed jax scorer or the fused Pallas kernel), and
the lexicographic winner selection.  Only the winning permutation, its
index and the score matrix return to host — zero host<->device
transfers between the partition and score stages.

Results are bit-identical to the unfused path by construction: the
partitioner is the bit-identity-tested jax engine (all five SFC kinds,
including the unrolled Skilling Hilbert state machine), the matching
gathers mirror ``map_candidates``'s ``part_to_proc``/``mu_t`` assembly
integer for integer, and the score columns are the same f32-derived
values the host :class:`CandidateSearch` lexsorts (f32->f64 casts are
exact).  With a ``refine`` spec (the hier path), the bounded greedy
swap-refinement loop of :func:`repro.hier.refine.refine_swaps` also
runs inside the program — a ``lax.while_loop`` over propose ->
delta-score -> monotone apply with early exit — so coarse sweep AND
refinement are one compile and only the final permutation lands on
host.

The compile cache mirrors ``metrics_jax._scorer`` /
``partition_jax._engine``: every entry is keyed by the full static
shape set (machine structure, both partition buckets, message/candidate
buckets, rotation selector tuples), so one scenario compiles O(1)
fused programs and :func:`fused_cache_stats` is a truthful
compile-count proxy.  This module imports jax at module level — the
pipeline only imports it after ``resolve_partition_backend`` returned
``"jax"``.
"""

from __future__ import annotations

import functools

import numpy as np

from repro import faults, obs
from repro.core import partition_jax as _pj  # noqa: F401  (enables x64)

import jax.numpy as jnp  # noqa: E402
from jax import lax  # noqa: E402

from repro.core import metrics_jax  # noqa: E402
from repro.core.mapping import MappingResult  # noqa: E402
from repro.core.metrics_jax import bucket_size, pad_axis  # noqa: E402
from repro.kernels.mapscore import ops as _mapscore  # noqa: E402

# metric keys the in-program column builder understands (== the full
# evaluate_candidates contract; anything else bails to the unfused path)
_KNOWN_KEYS = frozenset(("weighted_hops", "total_hops", "average_hops",
                         "data_max", "latency_max"))

# bail threshold on the in-program src/dst gather footprint (elements):
# beyond this the chunked host paths are the better memory citizens
MAX_FUSED_ELEMS = 1 << 27


def _build(*, d_t, task_sfc, d_p, proc_sfc, longest_dim, weighted,
           tnum, pnum, t_sel, p_sel, npts_bt, nbt_b, npts_bp, nbp_b,
           tab_b, dims, wrap, core_dims, objective, traffic, score_kind,
           ne, ne_b, nb_b, ncols, tile, interpret, refine):
    """The traced body of one fused program (all kwargs static).

    ``refine`` is ``None`` (sweep only) or a static ``(rounds, top,
    degree)`` triple: the hier swap-refinement loop then runs INSIDE
    the same program, after winner selection, as a ``lax.while_loop``
    over propose -> delta-score -> monotone apply (mirroring
    ``repro.hier.refine.refine_swaps`` decision for decision), and only
    the refined cluster -> router permutation returns to host.
    """
    from repro.core.orderings import hilbert_bits

    # Hilbert has no cut dimensions: canonicalise longest_dim so the
    # knob cannot fragment the partition-engine compile cache, and
    # derive the static quantisation resolution both engines unroll over
    ld_t = True if task_sfc == "H" else longest_dim
    ld_p = True if proc_sfc == "H" else longest_dim
    bits_t = hilbert_bits(tnum, d_t) if task_sfc == "H" else 0
    bits_p = hilbert_bits(pnum, d_p) if proc_sfc == "H" else 0
    eng_t = _pj._engine(d_t, task_sfc, ld_t, weighted,
                        npts_bt, nbt_b, tab_b, bits_t)
    eng_p = _pj._engine(d_p, proc_sfc, ld_p, False,
                        npts_bp, nbp_b, tab_b, bits_p)
    if score_kind == "jax":
        score_fn = metrics_jax._scorer(dims, wrap, core_dims, traffic,
                                       ne_b, nb_b)
    else:
        score_fn = _mapscore._compiled(dims, wrap, core_dims, traffic,
                                       ne_b, tile, nb_b, ncols, interpret)
    ncand = len(t_sel)
    nut = max(t_sel) + 1   # unique task-side rotations (selector covers
    nup = max(p_sel) + 1   # 0..nut-1; likewise proc side)
    t_sel_a = np.asarray(t_sel, dtype=np.int32)
    p_sel_a = np.asarray(p_sel, dtype=np.int32)
    nobj = len(objective)
    nd = len(dims) - core_dims
    wrapped = tuple(bool(x) for x in wrap)

    # static refinement bounds (host: top/degree clamps + k <= 0 break)
    if refine is not None:
        rounds, top, degree = refine
        top_s = min(int(top), tnum)
        k_s = min(int(degree), pnum - 1)
        rounds_eff = int(rounds) if (rounds > 0 and top_s > 0
                                     and k_s > 0) else 0
        P = max(top_s * k_s, 1)
        separable = all(k in ("weighted_hops", "total_hops")
                        for k in objective)
        if not separable:
            nb2 = bucket_size(k_s, lo=1)  # one hot-row chunk per launch
            if score_kind == "jax":
                score_fn2 = metrics_jax._scorer(dims, wrap, core_dims,
                                                traffic, ne_b, nb2)
            else:
                score_fn2 = _mapscore._compiled(dims, wrap, core_dims,
                                                traffic, ne_b, tile, nb2,
                                                ncols, interpret)

    def _hops(x, y):
        """int64 network hop distance, mirrors ``metrics.pairwise_hops``
        (reads the first ``nd`` columns; wrap dims take the torus min)."""
        tot = jnp.zeros(jnp.broadcast_shapes(x.shape[:-1], y.shape[:-1]),
                        dtype=jnp.int64)
        for kk in range(nd):
            dk = jnp.abs(x[..., kk].astype(jnp.int64)
                         - y[..., kk].astype(jnp.int64))
            if wrapped[kk]:
                dk = jnp.minimum(dk, dims[kk] - dk)
            tot = tot + dk
        return tot

    def _lex_less1(s, t):
        """Vectorised mirror of ``hier.refine._lex_less`` (tol 1e-12):
        reversed fold so component 0 dominates."""
        res = jnp.bool_(False)
        for j in reversed(range(nobj)):
            res = jnp.where(s[j] < t[j] - 1e-12, True,
                            jnp.where(s[j] > t[j] + 1e-12, False, res))
        return res

    def run(cols_t, sdo_t, w_t, cols_p, sdo_p, w_p1, tab, edges, ew,
            ew64, acoords, bw):
        # --- stage 2: both partitions (inner jit calls inline) ---------
        mu_t = eng_t(cols_t, sdo_t, w_t, tab, jnp.int32(tnum),
                     jnp.int32(nut), jnp.int32(pnum))[:, :tnum]
        mu_p = eng_p(cols_p, sdo_p, w_p1, tab, jnp.int32(pnum),
                     jnp.int32(nup), jnp.int32(pnum))[:nup, :pnum]

        # --- stage 3: vectorised GETMAPPINGARRAYS ----------------------
        # (mirrors map_candidates' part_to_proc / mu_t gathers)
        ptp = jnp.full((nup, pnum), -1, dtype=jnp.int32)
        ptp = ptp.at[jnp.arange(nup)[:, None], mu_p].set(
            jnp.arange(pnum, dtype=jnp.int32)[None, :])
        ok = jnp.min(ptp) >= 0
        t2p = jnp.take_along_axis(ptp[p_sel_a], mu_t[t_sel_a], axis=1)

        e0, e1 = edges[:, 0], edges[:, 1]

        def batch_cols(cs_padded, fn):
            """(B, nobj) f64 objective matrix for a padded stack batch
            (the same column builder the sweep's winner selection and
            the host CandidateSearch lexsort use)."""
            src = cs_padded[:, e0, :ncols]
            dst = cs_padded[:, e1, :ncols]
            if score_kind == "jax":
                ev = fn(src, dst, ew, bw)
                wh, th = ev["weighted_hops"], ev["total_hops"]
                data, lat = ev.get("data_max"), ev.get("latency_max")
            else:
                args = [src, dst, ew.reshape(-1, 1)]
                if traffic:
                    args.append(bw)
                outf, outi = fn(*args)
                wh, th = outf[:, 0], outi[:, 0]
                data = outf[:, 1] if traffic else None
                lat = outf[:, 2] if traffic else None

            def col(key):
                if key == "weighted_hops":
                    return wh.astype(jnp.float64)
                if key == "total_hops":
                    return th.astype(jnp.float64)
                if key == "average_hops":
                    return th.astype(jnp.float64) / ne
                return (data if key == "data_max"
                        else lat).astype(jnp.float64)

            return jnp.stack([col(k) for k in objective], axis=1)

        # --- stage 4: score + select -----------------------------------
        cs = acoords[t2p]                          # (ncand, tnum, ndim)
        cs = jnp.pad(cs, ((0, nb_b - ncand), (0, 0), (0, 0)))
        scores = batch_cols(cs, score_fn)[:ncand]
        keys = tuple(scores[:, j]
                     for j in reversed(range(scores.shape[1])))
        best_i = jnp.lexsort(keys)[0].astype(jnp.int32)
        if refine is None:
            return best_i, t2p[best_i], scores, ok

        # --- stage 5: fused swap refinement ----------------------------
        # (mirrors hier.refine.refine_swaps decision for decision; the
        # host pass is the oracle — see tests/test_hier.py)
        rc = acoords.astype(jnp.int64)            # (pnum, ncols) rows
        c2r0 = t2p[best_i]                        # cluster -> router
        r2c0 = jnp.full((pnum,), -1, jnp.int32).at[c2r0].set(
            jnp.arange(tnum, dtype=jnp.int32))

        def full_cols(c2r_vec):
            """Objective tuple of a whole assignment.  Separable keys
            sum exactly in f64 (bit-identical to the host's numpy
            evaluator for integer-valued volumes); otherwise the same
            f32 scorer the host jax/pallas evaluator runs."""
            if separable:
                st = rc[c2r_vec]
                h = _hops(st[e0], st[e1]).astype(jnp.float64)
                outc = []
                for kkey in objective:
                    outc.append(jnp.sum(ew64 * h)
                                if kkey == "weighted_hops"
                                else jnp.sum(h))
                return jnp.stack(outc)
            cs1 = jnp.pad(acoords[c2r_vec][None],
                          ((0, nb_b - 1), (0, 0), (0, 0)))
            return batch_cols(cs1, score_fn)[0]

        base0 = full_cols(c2r0)
        hist0 = jnp.zeros((rounds_eff + 1, nobj),
                          dtype=jnp.float64).at[0].set(base0)

        def body(state):
            rnd, done, c2r, r2c, base, hist, hist_len, acc_t, ev_t = state
            cc = rc[c2r]                          # (tnum, ncols) i64
            ho = _hops(cc[e0], cc[e1])            # pad edges (0,0) -> 0
            h_e = ho.astype(jnp.float64) * ew64
            contrib = (jnp.zeros(tnum, jnp.float64)
                       .at[e0].add(h_e).at[e1].add(h_e))
            _, hot = lax.sort(
                (-contrib, jnp.arange(tnum, dtype=jnp.int32)),
                num_keys=1, is_stable=True)       # == argsort(-contrib)
            hot = hot[:top_s]
            hot_valid = contrib[hot] > 0

            # network-nearest allocated routers (full stable argsort:
            # ties break on router id, matching the host after ISSUE 9)
            dm = _hops(cc[hot][:, None, :], rc[None, :, :]
                       ).astype(jnp.float64)
            dm = dm.at[jnp.arange(top_s), c2r[hot]].set(jnp.inf)
            colid = jnp.broadcast_to(jnp.arange(pnum, dtype=jnp.int32),
                                     (top_s, pnum))
            _, nearf = lax.sort((dm, colid), dimension=1, num_keys=1,
                                is_stable=True)
            near = nearf[:, :k_s]

            # proposals in host generation order (hot-major), deduped by
            # unordered router pair, first occurrence wins: valid hot
            # rows precede invalid ones, so padding can never steal a key
            a = jnp.repeat(hot, k_s)
            va = jnp.repeat(hot_valid, k_s)
            ra = c2r[a]
            rb = near.reshape(-1)
            b = r2c[rb]
            gen = jnp.arange(P, dtype=jnp.int32)
            dkey = (jnp.minimum(ra, rb).astype(jnp.int64) * pnum
                    + jnp.maximum(ra, rb))
            skey, sgen = lax.sort((dkey, gen), num_keys=1, is_stable=True)
            firstk = jnp.concatenate([jnp.ones(1, bool),
                                      skey[1:] != skey[:-1]])
            valid = va & jnp.zeros(P, bool).at[sgen].set(firstk)
            n_valid = jnp.sum(valid.astype(jnp.int32))

            def chunk_scores(args):
                """Scores of one hot row's k_s proposals (chunked so the
                edited-stack footprint stays k_s * ne_b, not P * ne_b)."""
                ac, bc, rac, rbc = args
                if separable:
                    # score = base + sum_incident w * (h_new - h_old):
                    # exactly the host's base - base_union + union value
                    # for integer-valued f64 volumes
                    pa, pb = rc[rbc], rc[rac]     # (k_s, ncols) new rows
                    isa0 = e0[None, :] == ac[:, None]
                    isb0 = (e0[None, :] == bc[:, None]) & \
                        (bc[:, None] >= 0)
                    isa1 = e1[None, :] == ac[:, None]
                    isb1 = (e1[None, :] == bc[:, None]) & \
                        (bc[:, None] >= 0)
                    hn = jnp.zeros((k_s, ne_b), jnp.int64)
                    for kk in range(nd):
                        x0 = jnp.where(
                            isa0, pa[:, kk][:, None],
                            jnp.where(isb0, pb[:, kk][:, None],
                                      cc[e0, kk][None, :]))
                        x1 = jnp.where(
                            isa1, pa[:, kk][:, None],
                            jnp.where(isb1, pb[:, kk][:, None],
                                      cc[e1, kk][None, :]))
                        dk = jnp.abs(x0 - x1)
                        if wrapped[kk]:
                            dk = jnp.minimum(dk, dims[kk] - dk)
                        hn = hn + dk
                    dh = (hn - ho[None, :]).astype(jnp.float64)
                    outc = []
                    for j, kkey in enumerate(objective):
                        dcol = (jnp.sum(ew64[None, :] * dh, axis=1)
                                if kkey == "weighted_hops"
                                else jnp.sum(dh, axis=1))
                        outc.append(base[j] + dcol)
                    return jnp.stack(outc, axis=1)
                stacks = jnp.broadcast_to(acoords[c2r][None],
                                          (k_s, tnum, ncols))
                rowb = jnp.where(bc >= 0, bc, tnum)
                stacks = stacks.at[jnp.arange(k_s), ac].set(acoords[rbc])
                stacks = stacks.at[jnp.arange(k_s), rowb].set(
                    acoords[rac], mode="drop")
                cs2 = jnp.pad(stacks, ((0, nb2 - k_s), (0, 0), (0, 0)))
                return batch_cols(cs2, score_fn2)[:k_s]

            pscores = lax.map(
                chunk_scores,
                (a.reshape(top_s, k_s), b.reshape(top_s, k_s),
                 ra.reshape(top_s, k_s), rb.reshape(top_s, k_s))
            ).reshape(P, nobj)
            pscores = jnp.where(valid[:, None], pscores, jnp.inf)

            # host np.lexsort order: primary = objective[0], ties by gen
            outs = lax.sort(
                tuple(pscores[:, j] + 0.0 for j in range(nobj))
                + (gen, a, ra, rb, b),
                num_keys=nobj, is_stable=True)
            s_srt = jnp.stack(outs[:nobj], axis=1)
            a_s, ra_s, rb_s, b_s = outs[nobj + 1:nobj + 5]

            # greedy disjoint accept, break at first non-improving
            def accept(carry, xs):
                touched, stop = carry
                s_i, ra_i, rb_i = xs
                improving = _lex_less1(s_i, base)
                take = improving & ~stop & ~(touched[ra_i] | touched[rb_i])
                stop = stop | ~improving
                touched = touched.at[ra_i].set(touched[ra_i] | take)
                touched = touched.at[rb_i].set(touched[rb_i] | take)
                return (touched, stop), take

            (_, _), take = lax.scan(
                accept, (jnp.zeros(pnum, bool), jnp.bool_(False)),
                (s_srt, ra_s, rb_s))

            def apply_take(tm):
                ia = jnp.where(tm, a_s, tnum)
                ib = jnp.where(tm & (b_s >= 0), b_s, tnum)
                nc = c2r.at[ia].set(rb_s, mode="drop")
                nc = nc.at[ib].set(ra_s, mode="drop")
                nr = r2c.at[jnp.where(tm, rb_s, pnum)].set(a_s,
                                                           mode="drop")
                nr = nr.at[jnp.where(tm, ra_s, pnum)].set(b_s,
                                                          mode="drop")
                return nc, nr

            nc, nr = apply_take(take)
            combined = full_cols(nc)
            nchosen = jnp.sum(take.astype(jnp.int32))
            # interacting swaps made it worse: fall back to the single
            # best proposal, whose exact score is known to improve
            use_single = (nchosen > 1) & ~_lex_less1(combined, base)
            take1 = (gen == 0) & _lex_less1(s_srt[0], base)
            nc1, nr1 = apply_take(take1)
            c2r_n = jnp.where(use_single, nc1, nc)
            r2c_n = jnp.where(use_single, nr1, nr)
            comb_f = jnp.where(use_single, s_srt[0], combined)
            nchosen_f = jnp.where(use_single, 1, nchosen)
            improved = (nchosen > 0) & _lex_less1(comb_f, base)

            hist_n = jnp.where(improved, hist.at[hist_len].set(comb_f),
                               hist)
            return (rnd + 1, ~improved,
                    jnp.where(improved, c2r_n, c2r),
                    jnp.where(improved, r2c_n, r2c),
                    jnp.where(improved, comb_f, base),
                    hist_n, hist_len + improved.astype(jnp.int32),
                    (acc_t + jnp.where(improved, nchosen_f, 0))
                    .astype(jnp.int32),
                    (ev_t + n_valid).astype(jnp.int32))

        state = (jnp.int32(0), jnp.bool_(False), c2r0, r2c0, base0,
                 hist0, jnp.int32(1), jnp.int32(0), jnp.int32(0))
        state = lax.while_loop(
            lambda st: (st[0] < rounds_eff) & ~st[1], body, state)
        _, _, c2r, _, _, hist, hist_len, acc_t, ev_t = state
        return (best_i, c2r, scores, ok, hist, hist_len, acc_t, ev_t)

    return run


@functools.lru_cache(maxsize=None)
def _program(d_t, task_sfc, d_p, proc_sfc, longest_dim, weighted,
             tnum, pnum, t_sel, p_sel, npts_bt, nbt_b, npts_bp, nbp_b,
             tab_b, dims, wrap, core_dims, objective, traffic, score_kind,
             ne, ne_b, nb_b, ncols, tile, interpret, refine):
    """One jitted fused program per (pipeline knobs, shape bucket).

    Every cache entry sees exactly one input shape set, so the
    ``lru_cache`` hit/miss counters are a truthful compile-count proxy
    (:func:`fused_cache_stats`)."""
    import jax
    return jax.jit(_build(
        d_t=d_t, task_sfc=task_sfc, d_p=d_p, proc_sfc=proc_sfc,
        longest_dim=longest_dim, weighted=weighted, tnum=tnum, pnum=pnum,
        t_sel=t_sel, p_sel=p_sel, npts_bt=npts_bt, nbt_b=nbt_b,
        npts_bp=npts_bp, nbp_b=nbp_b, tab_b=tab_b, dims=dims, wrap=wrap,
        core_dims=core_dims, objective=objective, traffic=traffic,
        score_kind=score_kind, ne=ne, ne_b=ne_b, nb_b=nb_b, ncols=ncols,
        tile=tile, interpret=interpret, refine=refine))


# registry-backed stat/reset pair (repro.obs); auto-registers with
# ``obs.snapshot()`` under "fused"
fused_cache_stats, reset_fused_cache = obs.instrument_compile_cache(
    "fused", _program)


class FusedSweep:
    """Runs a whole rotation sweep as one compiled device program.

    Constructed by :class:`MappingPipeline` only when the partition
    backend resolved to ``"jax"`` and the score backend resolved to
    ``"jax"``/``"pallas"`` with the batched vectorized sweep.
    :meth:`run` returns the winning :class:`MappingResult` (score and
    winner index filled in) or ``None`` when the stack is ineligible —
    the caller then takes the ordinary unfused path.
    """

    def __init__(self, pipe, score_kind: str):
        self.pipe = pipe
        self.score_kind = score_kind

    def run(self, graph, alloc, task_coords, proc_coords, cands,
            task_weights=None, refine=None):
        """``refine`` (hier only): a ``{"rounds", "top", "degree"}``
        dict folds the bounded swap-refinement loop into the same
        program; the returned result then carries the REFINED
        cluster -> router assignment plus the full ``refine_*`` stats
        the host :func:`repro.hier.refine.refine_swaps` would emit
        (``stats["fused_refine"]`` marks it for the caller)."""
        faults.fire("fused")
        pipe = self.pipe
        cfg = pipe.config
        tc = np.asarray(task_coords, dtype=np.float64)
        pc = np.asarray(proc_coords, dtype=np.float64)
        (tnum, td), (pnum, pd) = tc.shape, pc.shape
        if tnum < pnum or len(cands) < 2:
            return None
        objective = pipe.search.objective
        if not set(objective) <= _KNOWN_KEYS:
            return None
        ne = len(graph.edges)
        if ne == 0:
            return None
        machine = alloc.machine
        traffic = pipe.search.needs_traffic
        kind = self.score_kind
        if (kind == "pallas" and traffic
                and _mapscore.vmem_accumulator_bytes(machine)
                > _mapscore.VMEM_ACC_BUDGET):
            kind = "jax"  # same silent fallback the pallas wrapper takes

        ncand = len(cands)
        ne_b = bucket_size(ne)
        nb_b = bucket_size(ncand, lo=1)
        ncols = machine.ndim
        if 2 * nb_b * ne_b * ncols > MAX_FUSED_ELEMS:
            return None
        if bucket_size(tnum, _pj.PART_BUCKET_MIN) * 8 >= 1 << 31:
            return None  # pragma: no cover - int32 slot-id bound

        # dedup rotations exactly as map_candidates does
        t_perms = [tuple(c.task_perm) if c.task_perm is not None
                   else tuple(range(td)) for c in cands]
        p_perms = [tuple(c.proc_perm) if c.proc_perm is not None
                   else tuple(range(pd)) for c in cands]
        ut = sorted(set(t_perms))
        up = sorted(set(p_perms))
        t_of = {p: i for i, p in enumerate(ut)}
        p_of = {p: i for i, p in enumerate(up)}
        t_sel = tuple(t_of[p] for p in t_perms)
        p_sel = tuple(p_of[p] for p in p_perms)
        task_sfc, proc_sfc = pipe._sfc_pair(td, pd)

        cols_t, sdo_t, w_t, tab, npts_bt, nbt_b, tab_b = _pj._prepare(
            tc, pnum, np.array(ut, dtype=np.int64), task_weights,
            cfg.uneven_prime)
        cols_p, sdo_p, w_p1, _, npts_bp, nbp_b, _ = _pj._prepare(
            pc, pnum, np.array(up, dtype=np.int64), None,
            cfg.uneven_prime)

        edges = jnp.asarray(
            pad_axis(np.asarray(graph.edges, dtype=np.int32), ne_b))
        w_np = np.ones(ne) if graph.weights is None else \
            np.asarray(graph.weights, dtype=np.float64)
        ew = jnp.asarray(pad_axis(w_np.astype(np.float32), ne_b))
        ew64 = jnp.asarray(pad_axis(w_np, ne_b))  # exact refine deltas
        acoords = jnp.asarray(alloc.coords, dtype=jnp.int32)

        # refinement folds in only for the bijective cluster -> router
        # case (hier always has tnum == pnum here; anything else keeps
        # the host refine_swaps pass, which handles it loosely)
        refine_t = (int(refine["rounds"]), int(refine["top"]),
                    int(refine["degree"])) \
            if refine is not None and tnum == pnum else None

        nd = machine.ndim - machine.core_dims
        tile = min(_mapscore.TILE_MAX, ne_b)
        interpret = not _mapscore._on_tpu()
        if kind == "jax":
            bw = tuple(jnp.asarray(machine.bw_field(k), dtype=jnp.float32)
                       for k in range(nd)) if traffic else ()
        else:
            if traffic:
                inv = np.concatenate([
                    1.0 / np.asarray(
                        machine.bw(k, np.arange(int(machine.dims[k]))),
                        dtype=np.float64)
                    for k in range(nd)]) if nd else np.zeros(0)
                bw = jnp.asarray(inv.reshape(-1, 1), dtype=jnp.float32)
            else:
                bw = ()

        misses0 = _program.cache_info().misses
        fn = _program(td, task_sfc, pd, proc_sfc, bool(cfg.longest_dim),
                      task_weights is not None, tnum, pnum, t_sel, p_sel,
                      npts_bt, nbt_b, npts_bp, nbp_b, tab_b,
                      tuple(int(x) for x in machine.dims),
                      tuple(bool(x) for x in machine.wrap),
                      machine.core_dims, tuple(objective), traffic, kind,
                      ne, ne_b, nb_b, ncols, tile, bool(interpret),
                      refine_t)
        obs.annotate(
            score_backend=kind, candidates=ncand,
            compile_cache=("miss"
                           if _program.cache_info().misses > misses0
                           else "hit"))
        out = fn(cols_t, sdo_t, w_t, cols_p, sdo_p, w_p1, tab, edges,
                 ew, ew64, acoords, bw)
        best_i, t2p, scores, ok = out[:4]
        if not bool(ok):
            return None  # a part got no processor: unfused path raises
        best_i = int(best_i)
        c = cands[best_i]
        best = MappingResult(
            np.asarray(t2p, dtype=np.int32),
            rotation=(tuple(c.task_perm or ()), tuple(c.proc_perm or ())))
        best.score = float(np.asarray(scores)[best_i][0])
        best.stats.update(fused=True, fused_score_backend=kind,
                          winner_index=best_i)
        if refine_t is not None:
            hist, hist_len, acc_t, ev_t = out[4:]
            hist_len = int(hist_len)
            history = [tuple(float(x) for x in row)
                       for row in np.asarray(hist)[:hist_len]]
            best.stats.update(
                fused_refine=True,
                refine_rounds_run=hist_len - 1,
                refine_accepted=int(acc_t),
                refine_evaluated=int(ev_t),
                refine_history=history,
                refine_initial=history[0][0],
                refine_final=history[-1][0])
            best.score = history[-1][0]
            obs.annotate(refine_rounds=hist_len - 1,
                         refine_accepted=int(acc_t))
        return best
