"""One-compiled-program rotation sweep: partition -> match -> score ->
select, entirely on device.

When the pipeline resolves ``partition_backend="jax"`` AND a jax/pallas
scoring backend, the whole batched rotation sweep of
:meth:`MappingPipeline.map` collapses into a single jitted program per
candidate stack: both sides' level-synchronous partitions
(:mod:`repro.core.partition_jax`), the part->processor matching
gathers, the per-candidate coordinate-stack assembly, the metric
evaluation (the bucketed jax scorer or the fused Pallas kernel), and
the lexicographic winner selection.  Only the winning permutation, its
index and the score matrix return to host — zero host<->device
transfers between the partition and score stages.

Results are bit-identical to the unfused path by construction: the
partitioner is the bit-identity-tested jax engine, the matching gathers
mirror ``map_candidates``'s ``part_to_proc``/``mu_t`` assembly integer
for integer, and the score columns are the same f32-derived values the
host :class:`CandidateSearch` lexsorts (f32->f64 casts are exact).

The compile cache mirrors ``metrics_jax._scorer`` /
``partition_jax._engine``: every entry is keyed by the full static
shape set (machine structure, both partition buckets, message/candidate
buckets, rotation selector tuples), so one scenario compiles O(1)
fused programs and :func:`fused_cache_stats` is a truthful
compile-count proxy.  This module imports jax at module level — the
pipeline only imports it after ``resolve_partition_backend`` returned
``"jax"``.
"""

from __future__ import annotations

import functools

import numpy as np

from repro import faults, obs
from repro.core import partition_jax as _pj  # noqa: F401  (enables x64)

import jax.numpy as jnp  # noqa: E402

from repro.core import metrics_jax  # noqa: E402
from repro.core.mapping import MappingResult  # noqa: E402
from repro.core.metrics_jax import bucket_size, pad_axis  # noqa: E402
from repro.kernels.mapscore import ops as _mapscore  # noqa: E402

# metric keys the in-program column builder understands (== the full
# evaluate_candidates contract; anything else bails to the unfused path)
_KNOWN_KEYS = frozenset(("weighted_hops", "total_hops", "average_hops",
                         "data_max", "latency_max"))

# bail threshold on the in-program src/dst gather footprint (elements):
# beyond this the chunked host paths are the better memory citizens
MAX_FUSED_ELEMS = 1 << 27


def _build(*, d_t, task_sfc, d_p, proc_sfc, longest_dim, weighted,
           tnum, pnum, t_sel, p_sel, npts_bt, nbt_b, npts_bp, nbp_b,
           tab_b, dims, wrap, core_dims, objective, traffic, score_kind,
           ne, ne_b, nb_b, ncols, tile, interpret):
    """The traced body of one fused program (all kwargs static)."""
    eng_t = _pj._engine(d_t, task_sfc, longest_dim, weighted,
                        npts_bt, nbt_b, tab_b)
    eng_p = _pj._engine(d_p, proc_sfc, longest_dim, False,
                        npts_bp, nbp_b, tab_b)
    if score_kind == "jax":
        score_fn = metrics_jax._scorer(dims, wrap, core_dims, traffic,
                                       ne_b, nb_b)
    else:
        score_fn = _mapscore._compiled(dims, wrap, core_dims, traffic,
                                       ne_b, tile, nb_b, ncols, interpret)
    ncand = len(t_sel)
    nut = max(t_sel) + 1   # unique task-side rotations (selector covers
    nup = max(p_sel) + 1   # 0..nut-1; likewise proc side)
    t_sel_a = np.asarray(t_sel, dtype=np.int32)
    p_sel_a = np.asarray(p_sel, dtype=np.int32)

    def run(cols_t, sdo_t, w_t, cols_p, sdo_p, w_p1, tab, edges, ew,
            acoords, bw):
        # --- stage 2: both partitions (inner jit calls inline) ---------
        mu_t = eng_t(cols_t, sdo_t, w_t, tab, jnp.int32(tnum),
                     jnp.int32(nut), jnp.int32(pnum))[:, :tnum]
        mu_p = eng_p(cols_p, sdo_p, w_p1, tab, jnp.int32(pnum),
                     jnp.int32(nup), jnp.int32(pnum))[:nup, :pnum]

        # --- stage 3: vectorised GETMAPPINGARRAYS ----------------------
        # (mirrors map_candidates' part_to_proc / mu_t gathers)
        ptp = jnp.full((nup, pnum), -1, dtype=jnp.int32)
        ptp = ptp.at[jnp.arange(nup)[:, None], mu_p].set(
            jnp.arange(pnum, dtype=jnp.int32)[None, :])
        ok = jnp.min(ptp) >= 0
        t2p = jnp.take_along_axis(ptp[p_sel_a], mu_t[t_sel_a], axis=1)

        # --- stage 4: score + select -----------------------------------
        cs = acoords[t2p]                          # (ncand, tnum, ndim)
        cs = jnp.pad(cs, ((0, nb_b - ncand), (0, 0), (0, 0)))
        src = cs[:, edges[:, 0], :ncols]
        dst = cs[:, edges[:, 1], :ncols]
        if score_kind == "jax":
            ev = score_fn(src, dst, ew, bw)
            wh = ev["weighted_hops"]
            th = ev["total_hops"]
            data = ev.get("data_max")
            lat = ev.get("latency_max")
        else:
            args = [src, dst, ew.reshape(-1, 1)]
            if traffic:
                args.append(bw)
            outf, outi = score_fn(*args)
            wh, th = outf[:, 0], outi[:, 0]
            data = outf[:, 1] if traffic else None
            lat = outf[:, 2] if traffic else None

        def col(key):
            if key == "weighted_hops":
                return wh.astype(jnp.float64)
            if key == "total_hops":
                return th.astype(jnp.float64)
            if key == "average_hops":
                return th.astype(jnp.float64) / ne
            return (data if key == "data_max" else lat).astype(jnp.float64)

        scores = jnp.stack([col(k) for k in objective], axis=1)[:ncand]
        keys = tuple(scores[:, j]
                     for j in reversed(range(scores.shape[1])))
        best_i = jnp.lexsort(keys)[0].astype(jnp.int32)
        return best_i, t2p[best_i], scores, ok

    return run


@functools.lru_cache(maxsize=None)
def _program(d_t, task_sfc, d_p, proc_sfc, longest_dim, weighted,
             tnum, pnum, t_sel, p_sel, npts_bt, nbt_b, npts_bp, nbp_b,
             tab_b, dims, wrap, core_dims, objective, traffic, score_kind,
             ne, ne_b, nb_b, ncols, tile, interpret):
    """One jitted fused program per (pipeline knobs, shape bucket).

    Every cache entry sees exactly one input shape set, so the
    ``lru_cache`` hit/miss counters are a truthful compile-count proxy
    (:func:`fused_cache_stats`)."""
    import jax
    return jax.jit(_build(
        d_t=d_t, task_sfc=task_sfc, d_p=d_p, proc_sfc=proc_sfc,
        longest_dim=longest_dim, weighted=weighted, tnum=tnum, pnum=pnum,
        t_sel=t_sel, p_sel=p_sel, npts_bt=npts_bt, nbt_b=nbt_b,
        npts_bp=npts_bp, nbp_b=nbp_b, tab_b=tab_b, dims=dims, wrap=wrap,
        core_dims=core_dims, objective=objective, traffic=traffic,
        score_kind=score_kind, ne=ne, ne_b=ne_b, nb_b=nb_b, ncols=ncols,
        tile=tile, interpret=interpret))


# registry-backed stat/reset pair (repro.obs); auto-registers with
# ``obs.snapshot()`` under "fused"
fused_cache_stats, reset_fused_cache = obs.instrument_compile_cache(
    "fused", _program)


class FusedSweep:
    """Runs a whole rotation sweep as one compiled device program.

    Constructed by :class:`MappingPipeline` only when the partition
    backend resolved to ``"jax"`` and the score backend resolved to
    ``"jax"``/``"pallas"`` with the batched vectorized sweep.
    :meth:`run` returns the winning :class:`MappingResult` (score and
    winner index filled in) or ``None`` when the stack is ineligible —
    the caller then takes the ordinary unfused path.
    """

    def __init__(self, pipe, score_kind: str):
        self.pipe = pipe
        self.score_kind = score_kind

    def run(self, graph, alloc, task_coords, proc_coords, cands,
            task_weights=None):
        faults.fire("fused")
        pipe = self.pipe
        cfg = pipe.config
        tc = np.asarray(task_coords, dtype=np.float64)
        pc = np.asarray(proc_coords, dtype=np.float64)
        (tnum, td), (pnum, pd) = tc.shape, pc.shape
        if tnum < pnum or len(cands) < 2:
            return None
        objective = pipe.search.objective
        if not set(objective) <= _KNOWN_KEYS:
            return None
        ne = len(graph.edges)
        if ne == 0:
            return None
        machine = alloc.machine
        traffic = pipe.search.needs_traffic
        kind = self.score_kind
        if (kind == "pallas" and traffic
                and _mapscore.vmem_accumulator_bytes(machine)
                > _mapscore.VMEM_ACC_BUDGET):
            kind = "jax"  # same silent fallback the pallas wrapper takes

        ncand = len(cands)
        ne_b = bucket_size(ne)
        nb_b = bucket_size(ncand, lo=1)
        ncols = machine.ndim
        if 2 * nb_b * ne_b * ncols > MAX_FUSED_ELEMS:
            return None
        if bucket_size(tnum, _pj.PART_BUCKET_MIN) * 8 >= 1 << 31:
            return None  # pragma: no cover - int32 slot-id bound

        # dedup rotations exactly as map_candidates does
        t_perms = [tuple(c.task_perm) if c.task_perm is not None
                   else tuple(range(td)) for c in cands]
        p_perms = [tuple(c.proc_perm) if c.proc_perm is not None
                   else tuple(range(pd)) for c in cands]
        ut = sorted(set(t_perms))
        up = sorted(set(p_perms))
        t_of = {p: i for i, p in enumerate(ut)}
        p_of = {p: i for i, p in enumerate(up)}
        t_sel = tuple(t_of[p] for p in t_perms)
        p_sel = tuple(p_of[p] for p in p_perms)
        task_sfc, proc_sfc = pipe._sfc_pair(td, pd)

        cols_t, sdo_t, w_t, tab, npts_bt, nbt_b, tab_b = _pj._prepare(
            tc, pnum, np.array(ut, dtype=np.int64), task_weights,
            cfg.uneven_prime)
        cols_p, sdo_p, w_p1, _, npts_bp, nbp_b, _ = _pj._prepare(
            pc, pnum, np.array(up, dtype=np.int64), None,
            cfg.uneven_prime)

        edges = jnp.asarray(
            pad_axis(np.asarray(graph.edges, dtype=np.int32), ne_b))
        w_np = np.ones(ne) if graph.weights is None else \
            np.asarray(graph.weights, dtype=np.float64)
        ew = jnp.asarray(pad_axis(w_np.astype(np.float32), ne_b))
        acoords = jnp.asarray(alloc.coords, dtype=jnp.int32)

        nd = machine.ndim - machine.core_dims
        tile = min(_mapscore.TILE_MAX, ne_b)
        interpret = not _mapscore._on_tpu()
        if kind == "jax":
            bw = tuple(jnp.asarray(machine.bw_field(k), dtype=jnp.float32)
                       for k in range(nd)) if traffic else ()
        else:
            if traffic:
                inv = np.concatenate([
                    1.0 / np.asarray(
                        machine.bw(k, np.arange(int(machine.dims[k]))),
                        dtype=np.float64)
                    for k in range(nd)]) if nd else np.zeros(0)
                bw = jnp.asarray(inv.reshape(-1, 1), dtype=jnp.float32)
            else:
                bw = ()

        misses0 = _program.cache_info().misses
        fn = _program(td, task_sfc, pd, proc_sfc, bool(cfg.longest_dim),
                      task_weights is not None, tnum, pnum, t_sel, p_sel,
                      npts_bt, nbt_b, npts_bp, nbp_b, tab_b,
                      tuple(int(x) for x in machine.dims),
                      tuple(bool(x) for x in machine.wrap),
                      machine.core_dims, tuple(objective), traffic, kind,
                      ne, ne_b, nb_b, ncols, tile, bool(interpret))
        obs.annotate(
            score_backend=kind, candidates=ncand,
            compile_cache=("miss"
                           if _program.cache_info().misses > misses0
                           else "hit"))
        best_i, t2p, scores, ok = fn(cols_t, sdo_t, w_t, cols_p, sdo_p,
                                     w_p1, tab, edges, ew, acoords, bw)
        if not bool(ok):
            return None  # a part got no processor: unfused path raises
        best_i = int(best_i)
        c = cands[best_i]
        best = MappingResult(
            np.asarray(t2p, dtype=np.int32),
            rotation=(tuple(c.task_perm or ()), tuple(c.proc_perm or ())))
        best.score = float(np.asarray(scores)[best_i][0])
        best.stats.update(fused=True, fused_score_backend=kind,
                          winner_index=best_i)
        return best
