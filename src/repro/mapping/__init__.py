"""repro.mapping — the unified geometric task-mapping pipeline.

One engine behind every mapping entry point in the repo.  The paper's
Algorithm 1 (consistent geometric ordering of tasks and processors) is
decomposed into pluggable stages:

1. **machine transforms** — torus shifting, bandwidth scaling, dim
   drops, box lifts (:meth:`MappingPipeline.machine_coords`);
2. **partitioner backend** — the level-synchronous vectorised
   Multi-Jagged engine (:mod:`repro.core.partition`) or the recursive
   reference, selected per call;
3. **part matching** — equal part numbers task<->processor, including
   the tnum<pnum closest-subset case (:func:`match_parts`);
4. **candidate search** — one batched engine scoring every rotation /
   coordinate-scaling candidate with vectorised ``weighted_hops`` and
   per-link traffic evaluation (:class:`CandidateSearch`).

``repro.core.mapping.Mapper`` (the paper's Z2), ``repro.meshmap``'s
``topology_mesh``/``select_mapping`` and all benchmarks delegate here —
there is exactly one rotation/candidate-search loop in the codebase.
"""

from .candidates import Candidate, CandidateSearch, rotation_candidates
from .pipeline import (HierarchySpec, Level, MappingPipeline,
                       MappingResult, PipelineConfig, match_parts,
                       shared_pipeline)

__all__ = [
    "Candidate", "CandidateSearch", "HierarchySpec", "Level",
    "MappingPipeline", "MappingResult", "PipelineConfig", "match_parts",
    "rotation_candidates", "shared_pipeline",
]
