"""The mapping pipeline: machine transforms -> partitioner -> matching
-> candidate scoring (paper Alg. 1 + §4.3, one engine for every caller).

``MappingPipeline`` owns the full Z2-style flow that used to be split
(and partially duplicated) between ``core/mapping.py::Mapper`` and
``meshmap/device_mesh.py::select_mapping``:

- :meth:`machine_coords` applies the machine-side transforms (core-dim
  drop, torus shift, bandwidth scaling, "+E" dim drops, box lift);
- :meth:`map_candidate` runs one geometric mapping (Algorithm 1) for a
  single rotation/scaling candidate through the level-synchronous
  vectorised partitioner (``backend`` selects the engine);
- :meth:`map_candidates` runs a WHOLE rotation sweep in ~2 batched
  engine passes (``sweep="batched"``, the default): the unique task and
  processor dimension permutations become outermost segments of one
  ``order_points_batched`` call per side, bit-identical to the
  per-candidate loop (``sweep="loop"``, kept as the oracle);
- :meth:`map` enumerates the rotation candidates and scores them with
  the batched :class:`repro.mapping.candidates.CandidateSearch`
  (``score_backend`` selects numpy or the jit-compiled JAX scorer).

``core.mapping.Mapper`` and ``meshmap.select_mapping`` are thin
adapters over this class; benchmarks therefore all route through one
search implementation.
"""

from __future__ import annotations

import dataclasses
import threading

import numpy as np

from repro import obs
from repro.core.kmeans import closest_subset
from repro.core.machine import Allocation
from repro.core.mapping import MappingResult, match_parts
from repro.core.orderings import (order_points, order_points_batched,
                                  resolve_partition_backend)
from repro.core.transforms import (apply_permutation, box_lift, drop_dims,
                                   scale_by_bandwidth, shift_torus)
from repro.hier.spec import (HierarchySpec, Level,
                             normalize_config_hierarchy)
from repro.mapping.candidates import CandidateSearch, rotation_candidates


@dataclasses.dataclass
class PipelineConfig:
    """Configuration of the unified mapping pipeline.

    Partitioner stage:
      sfc          : part-numbering ordering ("FZ" is the paper's winner).
      mfz          : use the MFZ task-side variant when pd % td == 0.
      longest_dim  : cut the longest dimension (False = strict
                     alternation).
      uneven_prime : Z2_2 — largest-prime-divisor uneven bisection.
      backend      : ``order_points`` backend ("vectorized"/"recursive").
      partition_backend : partition engine — "numpy" (host reference)
                     or "jax" (device ``partition_jax`` engine,
                     bit-identical permutations, resolved ONCE per
                     pipeline down the silent jax -> numpy chain).
                     With a jax/pallas score backend and the batched
                     vectorized sweep, the whole partition -> match ->
                     score -> select chain fuses into ONE compiled
                     program per candidate stack
                     (:mod:`repro.mapping.fused`).
      fused        : "auto" (default) engages the fused whole-pipeline
                     program whenever the backends allow it; "off"
                     forces the unfused staged path — the first rung of
                     the serve layer's degradation ladder
                     (:mod:`repro.serve.resilience`).

    Machine-transform stage:
      shift           : torus wrap-around shifting of machine coords.
      bandwidth_scale : Z2_2 — scale distances by 1/link-bandwidth.
      box             : Z2_3 — lift machine coords by this box shape.
      box_outer_weight: scale of the between-box coordinates.
      drop            : dims to drop from machine coords (BG/Q "+E").

    Candidate-search stage:
      rotations : 0 = identity rotation only; otherwise max number of
                  (task_perm, proc_perm) pairs evaluated.
      objective : metric key (or tuple, lexicographic) minimised by the
                  search; "weighted_hops" is the paper's choice.
      sweep     : "batched" partitions every rotation of a sweep in ~2
                  engine passes (one task-side, one proc-side batched
                  ``order_points_batched`` call over the UNIQUE
                  permutations of each side); "loop" runs
                  ``map_candidate`` per candidate — the bit-identical
                  oracle the ``candidates`` benchmark times against.
      score_backend : candidate scoring engine — "numpy" (default),
                  "jax" (jit-compiled, message counts bucketed to
                  power-of-two shapes so scenarios compile O(1) times)
                  or "pallas" (one fused kernel launch per stack,
                  :mod:`repro.kernels.mapscore`); silent fallback down
                  the pallas -> jax -> numpy chain.

    Hierarchy stage (:mod:`repro.hier`):
      hierarchy : a :class:`repro.hier.HierarchySpec` — ordered
                  coarsening levels (fine -> coarse), each with its own
                  arity and refinement budget.  ``HierarchySpec.flat()``
                  partitions one point per core (classic);
                  ``HierarchySpec.node()`` coarsens tasks into
                  node-sized clusters and sweeps at router granularity
                  (~cores_per_node x fewer points per engine pass);
                  ``HierarchySpec.with_depth(n)`` adds geometric
                  grouping levels above the node level, each dividing
                  the top-sweep point count by its arity and refining
                  on the way down.  The legacy strings "flat"/"node"
                  are accepted as deprecated aliases (normalised to
                  the equivalent spec with one DeprecationWarning).
      refine_rounds / refine_top / refine_degree : DEPRECATED — the old
                  flat refinement knobs.  When set (non-None) they fold
                  into every level of the spec and warn; use the
                  per-level ``Level`` budgets instead.
    """

    sfc: str = "FZ"
    mfz: str | bool = "auto"
    shift: bool = True
    bandwidth_scale: bool = False
    box: tuple | None = None
    box_outer_weight: float = 16.0
    drop: tuple = ()
    rotations: int = 0
    uneven_prime: bool = False
    longest_dim: bool = True
    backend: str = "vectorized"
    partition_backend: str = "numpy"
    fused: str = "auto"
    objective: str | tuple = "weighted_hops"
    sweep: str = "batched"
    score_backend: str = "numpy"
    hierarchy: HierarchySpec | str = "flat"
    refine_rounds: int | None = None
    refine_top: int | None = None
    refine_degree: int | None = None

    def __post_init__(self):
        normalize_config_hierarchy(self)


# Process-wide pipeline registry: one MappingPipeline per distinct
# config.  Pipelines are stateless between map() calls but EXPENSIVE to
# warm (the jax/pallas scorers compile per (machine, bucket) and those
# compile caches are module-level), so every repeat-config caller —
# the serve layer, meshmap's per-call candidate grid, benchmarks —
# should resolve its pipeline here instead of constructing anew.
_SHARED_PIPELINES: dict = {}
_SHARED_LOCK = threading.Lock()


def shared_pipeline(config: PipelineConfig | None = None
                    ) -> "MappingPipeline":
    """The process-wide :class:`MappingPipeline` for ``config``.

    Keyed by content (:func:`repro.core.signature.config_signature`), so
    two independently-built equal configs share one pipeline instance
    and therefore one resolved evaluator — including under concurrent
    first calls (the serve layer maps from many threads).  The registry
    never evicts: distinct pipeline configs are few (they are small
    dataclasses of knobs, not per-request data).
    """
    from repro.core.signature import config_signature

    cfg = config or PipelineConfig()
    key = config_signature(cfg)
    with _SHARED_LOCK:
        pipe = _SHARED_PIPELINES.get(key)
        if pipe is None:
            pipe = _SHARED_PIPELINES[key] = MappingPipeline(cfg)
    return pipe


class MappingPipeline:
    """Maps a TaskGraph onto an Allocation through pluggable stages."""

    def __init__(self, config: PipelineConfig | None = None):
        self.config = config or PipelineConfig()
        cfg = self.config
        self.search = CandidateSearch(cfg.objective,
                                      backend=cfg.score_backend)
        # resolve the partition backend ONCE (silent jax -> numpy chain,
        # mirrors the score-backend discipline): hot paths then dispatch
        # on plain strings instead of re-probing the import per call
        self.partition_backend = resolve_partition_backend(
            cfg.partition_backend)
        self.order_backend = ("jax" if (self.partition_backend == "jax"
                                        and cfg.backend == "vectorized")
                              else cfg.backend)
        # fused whole-pipeline program: partition + match + score +
        # select as ONE compiled program per candidate stack — only when
        # both stages resolved to device backends and the sweep is the
        # batched vectorized one (the fused gathers mirror it exactly)
        self._fused = None
        if (cfg.fused != "off" and self.order_backend == "jax"
                and cfg.sweep == "batched"):
            from repro.core.metrics import get_evaluator
            resolved_score, _ = get_evaluator(cfg.score_backend)
            if resolved_score in ("jax", "pallas"):
                from repro.mapping.fused import FusedSweep
                self._fused = FusedSweep(self, resolved_score)

    # -- stage 1: machine transforms ------------------------------------

    def machine_coords(self, alloc: Allocation) -> np.ndarray:
        """Apply the machine-side transforms of the pipeline.

        Core dims are dropped first: every core of a node carries its
        ROUTER's coordinates (paper §2 — coordinates come from the
        router; intra-node communication is free).  MJ then keeps a
        node's cores in consecutive parts automatically (equal
        coordinates are never separated before everything else is cut).
        """
        cfg = self.config
        machine = alloc.machine
        coords = alloc.coords.astype(np.float64)
        if machine.core_dims:
            nd = machine.ndim - machine.core_dims
            coords = coords[:, :nd]
        if cfg.shift:
            coords = shift_torus(coords, machine)
        if cfg.bandwidth_scale:
            coords = scale_by_bandwidth(coords, machine)
        if cfg.drop:
            coords = drop_dims(coords, cfg.drop)
        if cfg.box is not None:
            nd = coords.shape[1]
            box = tuple(cfg.box) + (1,) * (nd - len(cfg.box))
            coords = box_lift(coords, box, outer_weight=cfg.box_outer_weight)
        return coords

    # -- stages 2+3: partition + match for ONE candidate -----------------

    def _sfc_pair(self, td: int, pd: int) -> tuple[str, str]:
        """(task_sfc, proc_sfc) with the MFZ task-side variant when the
        processor dimensionality is a multiple of the task's."""
        cfg = self.config
        use_mfz = (cfg.mfz is True) or (
            cfg.mfz == "auto" and cfg.sfc == "FZ" and pd != td
            and pd % max(td, 1) == 0)
        if use_mfz:
            return "FZlow", "FZ"  # MFZ: flip the LOW half, smaller side
        return cfg.sfc, cfg.sfc

    def map_candidate(
        self,
        task_coords: np.ndarray,
        proc_coords: np.ndarray,
        *,
        task_weights: np.ndarray | None = None,
        task_perm=None,
        proc_perm=None,
    ) -> MappingResult:
        """Paper Algorithm 1 for one (task_perm, proc_perm) rotation."""
        cfg = self.config
        tc = np.asarray(task_coords, dtype=np.float64)
        pc = np.asarray(proc_coords, dtype=np.float64)
        if task_perm is not None:
            tc = apply_permutation(tc, task_perm)
        if proc_perm is not None:
            pc = apply_permutation(pc, proc_perm)
        tnum, td = tc.shape
        pnum, pd = pc.shape

        subset = None
        if tnum < pnum:
            subset = closest_subset(pc, tnum)
            pc = pc[subset]
            pnum = tnum
        np_parts = min(tnum, pnum)
        task_sfc, proc_sfc = self._sfc_pair(td, pd)

        mu_t = order_points(tc, np_parts, task_sfc, weights=task_weights,
                            longest_dim=cfg.longest_dim,
                            uneven_prime=cfg.uneven_prime,
                            backend=self.order_backend)
        mu_p = order_points(pc, np_parts, proc_sfc,
                            longest_dim=cfg.longest_dim,
                            uneven_prime=cfg.uneven_prime,
                            backend=self.order_backend)
        t2p = match_parts(mu_t, mu_p)
        if subset is not None:
            t2p = subset[t2p]
        return MappingResult(t2p, rotation=(tuple(task_perm or ()),
                                            tuple(proc_perm or ())))

    # -- stages 2+3 for a WHOLE rotation sweep ---------------------------

    def map_candidates(
        self,
        task_coords: np.ndarray,
        proc_coords: np.ndarray,
        cands,
        *,
        task_weights: np.ndarray | None = None,
    ) -> list:
        """Algorithm 1 for every rotation candidate of a sweep.

        A rotation only permutes the columns of one shared point cloud,
        which is equivalent to permuting the partitioner's cut-dimension
        priority (``dim_order``) over the un-permuted cloud.  The
        batched sweep therefore partitions the UNIQUE task-side and
        proc-side permutations in one ``order_points_batched`` call per
        side — ~2 engine passes for the whole sweep — and assembles the
        per-candidate ``task_to_proc`` arrays with one vectorised
        part-matching gather.  Results are bit-identical to the
        ``sweep="loop"`` per-candidate path (guarded by the
        ``candidates`` benchmark and tests/test_batched.py).

        Falls back to the loop only for the tnum < pnum closest-subset
        case (the subset's centroid iteration sums coordinates in
        column order).  Hilbert batches too: ``order_points_batched``
        treats each ``dim_orders`` row as a COLUMN permutation of the
        cloud (quantisation commutes with it), bit-identical to the
        per-candidate loop.
        """
        cfg = self.config
        tc = np.asarray(task_coords, dtype=np.float64)
        pc = np.asarray(proc_coords, dtype=np.float64)
        (tnum, td), (pnum, pd) = tc.shape, pc.shape
        if cfg.sweep == "loop" or len(cands) == 1 or tnum < pnum:
            return [
                self.map_candidate(tc, pc, task_weights=task_weights,
                                   task_perm=c.task_perm,
                                   proc_perm=c.proc_perm)
                for c in cands
            ]
        if cfg.sweep != "batched":
            raise ValueError(f"unknown sweep mode {cfg.sweep!r}")

        np_parts = min(tnum, pnum)
        task_sfc, proc_sfc = self._sfc_pair(td, pd)

        # dedup each side: many (task_perm, proc_perm) pairs share a perm
        t_perms = [tuple(c.task_perm) if c.task_perm is not None
                   else tuple(range(td)) for c in cands]
        p_perms = [tuple(c.proc_perm) if c.proc_perm is not None
                   else tuple(range(pd)) for c in cands]
        ut = sorted(set(t_perms))
        up = sorted(set(p_perms))
        t_of = {p: i for i, p in enumerate(ut)}
        p_of = {p: i for i, p in enumerate(up)}

        common = dict(longest_dim=cfg.longest_dim,
                      uneven_prime=cfg.uneven_prime,
                      backend=self.order_backend)
        mu_t = order_points_batched(tc, np_parts, task_sfc,
                                    dim_orders=np.array(ut),
                                    weights=task_weights, **common)
        mu_p = order_points_batched(pc, np_parts, proc_sfc,
                                    dim_orders=np.array(up), **common)

        # vectorised GETMAPPINGARRAYS: part -> processor per proc rotation
        # (pnum == np_parts here, so every part holds exactly one proc);
        # int32 keeps the per-candidate assembly gathers cache-friendly
        part_to_proc = np.full((len(up), np_parts), -1, dtype=np.int32)
        part_to_proc[np.arange(len(up))[:, None], mu_p] = \
            np.arange(pnum, dtype=np.int32)[None, :]
        if (part_to_proc < 0).any():
            missing = np.flatnonzero((part_to_proc < 0).any(axis=0))
            raise ValueError(f"parts with no processor: {missing[:5]}")
        mu_t = mu_t.astype(np.int32)

        return [
            MappingResult(
                part_to_proc[p_of[pp]][mu_t[t_of[tp]]],
                rotation=(tuple(c.task_perm or ()),
                          tuple(c.proc_perm or ())))
            for c, tp, pp in zip(cands, t_perms, p_perms)
        ]

    # -- stage 4: candidate search ---------------------------------------

    def map(self, graph, alloc: Allocation,
            task_coords: np.ndarray | None = None,
            task_weights: np.ndarray | None = None) -> MappingResult:
        """Full pipeline: transforms, one batched rotation sweep through
        the partitioner, batched scoring; returns the best MappingResult
        (score = objective).

        A non-flat :class:`HierarchySpec` routes through
        :mod:`repro.hier` instead: coarsen tasks level by level, run
        the SAME rotation sweep at the TOP granularity, then expand
        downward one level at a time with a refinement pass per level.
        """
        cfg = self.config
        if not isinstance(cfg.hierarchy, HierarchySpec):
            # configs are validated at construction; this only fires
            # when a caller mutates cfg.hierarchy afterwards
            normalize_config_hierarchy(cfg)
        if not cfg.hierarchy.is_flat:
            from repro.hier.levels import map_hierarchical
            return map_hierarchical(self, graph, alloc,
                                    task_coords=task_coords,
                                    task_weights=task_weights)
        # span-derived stage timings (repro.obs): the spans ARE the
        # clocks — ``stats["timings"]`` keeps its exact legacy schema
        # ({fused_s | partition_s + score_s} + total_s), derived from
        # the span durations instead of a third ad-hoc timer dict
        timings = {}
        with obs.span("pipeline.map", hierarchy="flat",
                      partition_backend=self.partition_backend,
                      score_backend=cfg.score_backend) as root:
            pc = self.machine_coords(alloc)
            tc = np.asarray(task_coords if task_coords is not None
                            else graph.coords, dtype=np.float64)
            cands = rotation_candidates(tc.shape[1], pc.shape[1],
                                        cfg.rotations)
            sweep_points = int(len(tc) + alloc.n)
            root.annotate(sweep_points=sweep_points,
                          candidates=len(cands))
            best = None
            if self._fused is not None:
                with obs.span("pipeline.fused") as sp:
                    best = self._fused.run(graph, alloc, tc, pc, cands,
                                           task_weights=task_weights)
                if best is not None:
                    # partition + match + score ran as one device
                    # program; the stage split does not exist here
                    timings["fused_s"] = sp.duration_s
            if best is None:
                with obs.span("pipeline.partition",
                              points=sweep_points) as sp:
                    results = self.map_candidates(
                        tc, pc, cands, task_weights=task_weights)
                timings["partition_s"] = sp.duration_s
                with obs.span("pipeline.score",
                              candidates=len(cands)) as sp:
                    if len(results) == 1:
                        best = results[0]
                    else:
                        best, best_i, scores = self.search.best(
                            graph, alloc, results)
                        best.score = float(scores[best_i][0])
                timings["score_s"] = sp.duration_s
        timings["total_s"] = root.duration_s
        # stats schema v2: ONE per-level entry per mapped granularity
        # (flat = the single core level), replacing the ad-hoc
        # flat/hier key split; the legacy keys (hierarchy /
        # sweep_points / timings) stay derived for one release — see
        # README "MappingResult.stats schema"
        map_s = timings.get(
            "fused_s",
            timings.get("partition_s", 0.0) + timings.get("score_s", 0.0))
        best.stats.update(
            schema=2,
            hierarchy="flat",
            depth=1,
            levels=[{"level": 0, "name": "core",
                     "points": sweep_points,
                     "clusters": int(len(tc)), "units": int(alloc.n),
                     "coarsen_s": 0.0, "map_s": map_s, "refine_s": 0.0,
                     "refine_accepted": 0, "refine_evaluated": 0}],
            sweep_points=sweep_points,
            partition_backend=self.partition_backend,
            timings=timings,
            trace_id=root.trace_id)
        return best
