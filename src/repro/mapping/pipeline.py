"""The mapping pipeline: machine transforms -> partitioner -> matching
-> candidate scoring (paper Alg. 1 + §4.3, one engine for every caller).

``MappingPipeline`` owns the full Z2-style flow that used to be split
(and partially duplicated) between ``core/mapping.py::Mapper`` and
``meshmap/device_mesh.py::select_mapping``:

- :meth:`machine_coords` applies the machine-side transforms (core-dim
  drop, torus shift, bandwidth scaling, "+E" dim drops, box lift);
- :meth:`map_candidate` runs one geometric mapping (Algorithm 1) for a
  single rotation/scaling candidate through the level-synchronous
  vectorised partitioner (``backend`` selects the engine);
- :meth:`map` enumerates the rotation candidates and scores them with
  the batched :class:`repro.mapping.candidates.CandidateSearch`.

``core.mapping.Mapper`` and ``meshmap.select_mapping`` are thin
adapters over this class; benchmarks therefore all route through one
search implementation.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.kmeans import closest_subset
from repro.core.machine import Allocation
from repro.core.mapping import MappingResult, match_parts
from repro.core.orderings import order_points
from repro.core.transforms import (apply_permutation, box_lift, drop_dims,
                                   scale_by_bandwidth, shift_torus)
from repro.mapping.candidates import CandidateSearch, rotation_candidates


@dataclasses.dataclass
class PipelineConfig:
    """Configuration of the unified mapping pipeline.

    Partitioner stage:
      sfc          : part-numbering ordering ("FZ" is the paper's winner).
      mfz          : use the MFZ task-side variant when pd % td == 0.
      longest_dim  : cut the longest dimension (False = strict
                     alternation).
      uneven_prime : Z2_2 — largest-prime-divisor uneven bisection.
      backend      : ``order_points`` backend ("vectorized"/"recursive").

    Machine-transform stage:
      shift           : torus wrap-around shifting of machine coords.
      bandwidth_scale : Z2_2 — scale distances by 1/link-bandwidth.
      box             : Z2_3 — lift machine coords by this box shape.
      box_outer_weight: scale of the between-box coordinates.
      drop            : dims to drop from machine coords (BG/Q "+E").

    Candidate-search stage:
      rotations : 0 = identity rotation only; otherwise max number of
                  (task_perm, proc_perm) pairs evaluated.
      objective : metric key (or tuple, lexicographic) minimised by the
                  search; "weighted_hops" is the paper's choice.
    """

    sfc: str = "FZ"
    mfz: str | bool = "auto"
    shift: bool = True
    bandwidth_scale: bool = False
    box: tuple | None = None
    box_outer_weight: float = 16.0
    drop: tuple = ()
    rotations: int = 0
    uneven_prime: bool = False
    longest_dim: bool = True
    backend: str = "vectorized"
    objective: str | tuple = "weighted_hops"


class MappingPipeline:
    """Maps a TaskGraph onto an Allocation through pluggable stages."""

    def __init__(self, config: PipelineConfig | None = None):
        self.config = config or PipelineConfig()
        self.search = CandidateSearch(self.config.objective)

    # -- stage 1: machine transforms ------------------------------------

    def machine_coords(self, alloc: Allocation) -> np.ndarray:
        """Apply the machine-side transforms of the pipeline.

        Core dims are dropped first: every core of a node carries its
        ROUTER's coordinates (paper §2 — coordinates come from the
        router; intra-node communication is free).  MJ then keeps a
        node's cores in consecutive parts automatically (equal
        coordinates are never separated before everything else is cut).
        """
        cfg = self.config
        machine = alloc.machine
        coords = alloc.coords.astype(np.float64)
        if machine.core_dims:
            nd = machine.ndim - machine.core_dims
            coords = coords[:, :nd]
        if cfg.shift:
            coords = shift_torus(coords, machine)
        if cfg.bandwidth_scale:
            coords = scale_by_bandwidth(coords, machine)
        if cfg.drop:
            coords = drop_dims(coords, cfg.drop)
        if cfg.box is not None:
            nd = coords.shape[1]
            box = tuple(cfg.box) + (1,) * (nd - len(cfg.box))
            coords = box_lift(coords, box, outer_weight=cfg.box_outer_weight)
        return coords

    # -- stages 2+3: partition + match for ONE candidate -----------------

    def map_candidate(
        self,
        task_coords: np.ndarray,
        proc_coords: np.ndarray,
        *,
        task_weights: np.ndarray | None = None,
        task_perm=None,
        proc_perm=None,
    ) -> MappingResult:
        """Paper Algorithm 1 for one (task_perm, proc_perm) rotation."""
        cfg = self.config
        tc = np.asarray(task_coords, dtype=np.float64)
        pc = np.asarray(proc_coords, dtype=np.float64)
        if task_perm is not None:
            tc = apply_permutation(tc, task_perm)
        if proc_perm is not None:
            pc = apply_permutation(pc, proc_perm)
        tnum, td = tc.shape
        pnum, pd = pc.shape

        subset = None
        if tnum < pnum:
            subset = closest_subset(pc, tnum)
            pc = pc[subset]
            pnum = tnum
        np_parts = min(tnum, pnum)

        task_sfc = proc_sfc = cfg.sfc
        use_mfz = (cfg.mfz is True) or (
            cfg.mfz == "auto" and cfg.sfc == "FZ" and pd != td
            and pd % max(td, 1) == 0)
        if use_mfz:
            task_sfc = "FZlow"  # MFZ: flip the LOW half, smaller-dim side
            proc_sfc = "FZ"

        mu_t = order_points(tc, np_parts, task_sfc, weights=task_weights,
                            longest_dim=cfg.longest_dim,
                            uneven_prime=cfg.uneven_prime,
                            backend=cfg.backend)
        mu_p = order_points(pc, np_parts, proc_sfc,
                            longest_dim=cfg.longest_dim,
                            uneven_prime=cfg.uneven_prime,
                            backend=cfg.backend)
        t2p = match_parts(mu_t, mu_p)
        if subset is not None:
            t2p = subset[t2p]
        return MappingResult(t2p, rotation=(tuple(task_perm or ()),
                                            tuple(proc_perm or ())))

    # -- stage 4: candidate search ---------------------------------------

    def map(self, graph, alloc: Allocation,
            task_coords: np.ndarray | None = None,
            task_weights: np.ndarray | None = None) -> MappingResult:
        """Full pipeline: transforms, rotation candidates, batched
        scoring; returns the best MappingResult (score = objective)."""
        cfg = self.config
        pc = self.machine_coords(alloc)
        tc = np.asarray(task_coords if task_coords is not None
                        else graph.coords, dtype=np.float64)
        cands = rotation_candidates(tc.shape[1], pc.shape[1], cfg.rotations)
        results = [
            self.map_candidate(tc, pc, task_weights=task_weights,
                               task_perm=c.task_perm, proc_perm=c.proc_perm)
            for c in cands
        ]
        if len(results) == 1:
            return results[0]
        best, best_i, scores = self.search.best(graph, alloc, results)
        best.score = float(scores[best_i][0])
        return best
