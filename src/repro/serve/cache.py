"""Bounded, thread-safe LRU for mapping responses (the serve layer's
result store).

Keys are content-addressed request signatures
(:mod:`repro.core.signature`); values are whatever the service caches
(a :class:`repro.core.MappingResult` in practice).  The cache is a
plain ``OrderedDict`` under one lock — mapping results are small (one
int array per request) so capacity bounds entry COUNT, and every
operation is O(1).

Counters (``hits`` / ``misses`` / ``evictions``) are cumulative for the
cache's lifetime; :meth:`LRUCache.stats` snapshots them for benchmark
records and tests (the serve benchmark's warm-path accounting).
"""

from __future__ import annotations

import collections
import threading

from repro import obs


class LRUCache:
    """Least-recently-used cache with a hard entry-count bound."""

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._data: collections.OrderedDict = collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.storms = 0
        # weakly tracked: this instance's stats() joins obs.snapshot()
        obs.register_object("lrus", self)

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._data

    def get(self, key, default=None, *, count: bool = True):
        """Value for ``key`` (refreshing its recency), else ``default``.

        ``count=False`` skips the hit/miss counters — for internal
        rechecks (e.g. the service's under-lock recheck) that would
        otherwise double-count one logical lookup.
        """
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                if count:
                    self.hits += 1
                return self._data[key]
            if count:
                self.misses += 1
            return default

    def put(self, key, value) -> None:
        """Insert/refresh ``key``; evicts the LRU entry when full."""
        with self._lock:
            if key in self._data:
                self._data.move_to_end(key)
                self._data[key] = value
                return
            self._data[key] = value
            if len(self._data) > self.capacity:
                self._data.popitem(last=False)
                self.evictions += 1

    def clear(self) -> None:
        """Drop every entry (counters keep accumulating)."""
        with self._lock:
            self._data.clear()

    def storm(self) -> None:
        """An eviction storm: drop every entry, booking each as an
        eviction.  This is the ``evict``-kind fault-injection callback
        (:mod:`repro.faults`, site ``serve.cache``) — the service keeps
        answering, every post-storm request recomputing cold."""
        with self._lock:
            self.evictions += len(self._data)
            self.storms += 1
            self._data.clear()

    def stats(self) -> dict:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions, "storms": self.storms,
                    "size": len(self._data),
                    "capacity": self.capacity}
