"""Mapping-as-a-service: a batching request server over the unified
mapping pipeline.

The paper's mapper is cheap enough to run at job-launch time for every
allocation shape it meets — so this module serves mapping decisions as
REQUESTS instead of one-shot scripts.  A :class:`MappingRequest` (task
graph + machine/allocation + objective/config) is canonicalised to a
content-addressed signature (:mod:`repro.core.signature`);
:class:`MappingService` then

- serves repeat requests from a bounded LRU of mapping results
  (``warm`` responses — no partitioning, no scoring, no backend
  compiles);
- coalesces duplicate in-flight requests: concurrent submissions of the
  same problem share ONE pipeline pass and receive bit-identical
  results (``coalesced`` responses);
- routes misses through the process-wide shared
  :class:`repro.mapping.MappingPipeline` registry
  (:func:`repro.mapping.shared_pipeline`), so the evaluator fallback
  chain and the jax/pallas compile caches (PR 4) are resolved/warmed
  once per process, not once per request (``cold`` responses).

Response schema (see README "repro.serve"): ``result`` (the
:class:`repro.core.MappingResult` — treat as read-only, it is shared
with the cache), ``signature``, ``status`` (cold/warm/coalesced) and
``latency_s``.

The cold path is RESILIENT (ISSUE 7, :mod:`repro.serve.resilience`):
a failed or deadline-exceeded pipeline pass walks the degradation
ladder one rung down (fused -> unfused, pallas -> jax -> numpy scoring,
jax -> numpy partitioning, hierarchy depth -> 2, refine rounds -> 0)
instead of surfacing the error, per-rung circuit breakers skip known-bad backends outright, a
bounded admission queue sheds overload, and the served rung lands in
``MappingResult.stats["degraded"]`` plus the service counters
(:meth:`MappingService.stats`).  Errors never enter the result LRU, and
a failed in-flight computation is recomputed by its waiters rather than
replayed to them.

The token-decode model server that used to live here moved to
:mod:`repro.serve.decode`; ``ServeEngine`` is re-exported below for
compatibility.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from repro import faults, obs
from repro.core.signature import (array_digest, config_signature,
                                  mapping_signature)
from repro.mapping import PipelineConfig, shared_pipeline
from repro.serve.cache import LRUCache
from repro.serve.resilience import (BreakerBoard, DeadlineExceeded,
                                    ServiceOverloaded, degradation_ladder,
                                    rung_key)


def __getattr__(name):
    # compat: the token-decode ServeEngine moved to repro.serve.decode;
    # resolve it lazily so the mapping service never pays the jax/model
    # import (PEP 562)
    if name == "ServeEngine":
        from repro.serve.decode import ServeEngine
        return ServeEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

# Short objective aliases accepted by make_request / the scenario
# registry ("wh" is the paper's WeightedHops rotation-search objective;
# "latency" is the TPU mesh builder's lexicographic pair).
OBJECTIVES = {
    "wh": "weighted_hops",
    "latency": ("latency_max", "weighted_hops"),
}


@dataclasses.dataclass
class MappingRequest:
    """One mapping problem: what to map, onto what, optimising what.

    graph        : :class:`repro.core.TaskGraph`.
    alloc        : :class:`repro.core.Allocation` (machine + node rows).
    config       : :class:`repro.mapping.PipelineConfig` — the full
                   pipeline knob set, including ``objective``.
    task_coords  : optional task-coordinate override (the TPU mesh
                   builder's traffic-scaled coordinates).
    task_weights : optional per-task weights for the partitioner.

    The request's identity is its CONTENT — two independently-built
    requests with equal arrays/config share a signature and therefore a
    cache entry.  The signature is computed once and memoised on the
    instance (requests are cheap handles; reuse them for hot paths).
    """

    graph: object
    alloc: object
    config: PipelineConfig = dataclasses.field(
        default_factory=PipelineConfig)
    task_coords: np.ndarray | None = None
    task_weights: np.ndarray | None = None
    _signature: str | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def signature(self) -> str:
        if self._signature is None:
            extra = {}
            if self.task_coords is not None:
                extra["task_coords"] = array_digest(self.task_coords)
            if self.task_weights is not None:
                extra["task_weights"] = array_digest(self.task_weights)
            self._signature = mapping_signature(
                self.graph, self.alloc, self.config, extra or None)
        return self._signature


def make_request(graph, alloc, objective="wh", *, config=None,
                 task_coords=None, task_weights=None,
                 **overrides) -> MappingRequest:
    """Build a :class:`MappingRequest`.

    ``objective`` accepts an alias from :data:`OBJECTIVES`, a metric
    key, or a tuple of keys (lexicographic).  ``overrides`` are
    :class:`PipelineConfig` fields (``rotations=8``,
    ``hierarchy=HierarchySpec.node()``, ...); pass ``config`` to supply
    a full config instead (mutually exclusive with
    ``objective``/``overrides``).  Config validation happens at
    CONSTRUCTION — an unknown hierarchy raises a 4xx-style
    ``ValueError`` (listing the accepted values) here, before the
    request is ever admitted to the service or burns a
    degradation-ladder rung.
    """
    if config is None:
        config = PipelineConfig(
            objective=OBJECTIVES.get(objective, objective), **overrides)
    elif overrides:
        raise ValueError("pass either config= or config-field overrides,"
                         " not both")
    return MappingRequest(graph, alloc, config,
                          task_coords=task_coords,
                          task_weights=task_weights)


@dataclasses.dataclass
class MappingResponse:
    """What the service returns for one request.

    result    : the mapping (shared with the cache — read-only).
    signature : the request's content signature (the cache key).
    status    : "cold" (pipeline ran), "warm" (LRU hit) or "coalesced"
                (shared an in-flight computation or a batch duplicate).
    latency_s : wall-clock seconds this request spent in the service.
    trace_id  : this request's trace (repro.obs) — per-REQUEST, unlike
                ``result.stats["trace_id"]`` which names the trace that
                COMPUTED the (possibly cached, shared) result.
    """

    result: object
    signature: str
    status: str
    latency_s: float
    trace_id: str | None = None


class _InFlight:
    """One in-progress computation; duplicate requests wait on it."""

    __slots__ = ("event", "result", "error")

    def __init__(self):
        self.event = threading.Event()
        self.result = None
        self.error = None


class MappingService:
    """The request server: canonicalise, coalesce, cache, compute —
    and degrade gracefully instead of failing (ISSUE 7).

    capacity   : bound of the result LRU (entries, not bytes — a result
                 is one int array per request).
    deadline_s : optional per-request compute deadline.  A NON-terminal
                 ladder rung that overruns the remaining budget is
                 abandoned (its worker finishes off-thread) and the
                 request drops a rung; the terminal host rung always
                 runs to completion so a slow stage degrades latency
                 class, never availability.
    max_inflight / max_queue : bounded admission (None = unlimited,
                 the default).  At most ``max_inflight`` cold
                 computations run concurrently; up to ``max_queue``
                 more block waiting; beyond that requests are SHED with
                 :class:`ServiceOverloaded` (counted in ``stats()``).
                 Warm hits and coalesced waiters are never queued.
    breaker_threshold / breaker_cooldown_s : per-rung circuit breakers
                 (:class:`repro.serve.resilience.CircuitBreaker`) keyed
                 by RESOLVED backend combination; an open breaker skips
                 its rung without paying the failure latency.
    clock      : injectable monotonic clock for the breakers (tests).
    """

    def __init__(self, capacity: int = 256, *,
                 deadline_s: float | None = None,
                 max_inflight: int | None = None,
                 max_queue: int = 8,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 30.0,
                 clock=time.monotonic):
        self.results = LRUCache(capacity)
        self.deadline_s = deadline_s
        self.max_inflight = max_inflight
        self.max_queue = max_queue
        self._inflight: dict[str, _InFlight] = {}
        self._lock = threading.Lock()
        self._counts = {"cold": 0, "warm": 0, "coalesced": 0}
        self._res_counts = {"degraded": 0, "shed": 0, "rung_failures": 0,
                            "deadline_misses": 0, "deadline_skips": 0,
                            "breaker_skips": 0}
        self._rung_counts: dict[str, int] = {}
        self._ladders: dict[str, list] = {}
        self._breakers = BreakerBoard(breaker_threshold,
                                      breaker_cooldown_s, clock)
        self._adm = threading.Condition(threading.Lock())
        self._active = 0
        self._queued = 0
        # weakly tracked: this instance's stats() joins obs.snapshot()
        obs.register_object("services", self)

    # -- the miss path ---------------------------------------------------

    def _compute(self, request: MappingRequest):
        """Run the pipeline for a cache miss (test seam: override to
        instrument/block the cold path).  Every ladder rung routes
        through here, so overrides see degraded configs too."""
        faults.fire("serve.compute")
        pipe = shared_pipeline(request.config)
        return pipe.map(request.graph, request.alloc,
                        task_coords=request.task_coords,
                        task_weights=request.task_weights)

    def _ladder_for(self, config: PipelineConfig) -> list:
        """The config's ``(rung_name, config, breaker_key)`` ladder,
        built once per config signature (backend resolution is not paid
        per request)."""
        key = config_signature(config)
        with self._lock:
            ladder = self._ladders.get(key)
        if ladder is None:
            ladder = [(name, cfg, rung_key(cfg))
                      for name, cfg in degradation_ladder(config)]
            with self._lock:
                ladder = self._ladders.setdefault(key, ladder)
        return ladder

    def _call_rung(self, request: MappingRequest,
                   budget_s: float | None):
        """One `_compute` attempt, bounded by ``budget_s`` when set.

        The deadline run executes on a daemon worker so an overrun can
        be abandoned; the worker's eventual result is discarded (the
        ladder has already moved on).
        """
        if budget_s is None:
            return self._compute(request)
        box: dict = {}
        # contextvars do not cross thread starts: re-parent the worker
        # under the submitting thread's span so the rung's pipeline and
        # backend spans stay inside the request's trace
        parent = obs.current_span()

        def worker():
            try:
                with obs.attach(parent):
                    box["result"] = self._compute(request)
            except BaseException as e:
                box["error"] = e

        th = threading.Thread(target=worker, daemon=True,
                              name="mapping-rung")
        th.start()
        th.join(budget_s)
        if th.is_alive():
            raise DeadlineExceeded(
                f"rung exceeded remaining deadline ({budget_s:.3f}s)")
        if "error" in box:
            raise box["error"]
        return box["result"]

    def _execute(self, request: MappingRequest, t0: float):
        """Walk the degradation ladder until a rung serves the request.

        Rung failures trip the rung's breaker and drop one rung; open
        breakers and an exhausted deadline budget skip rungs outright.
        The terminal rung runs unconditionally (no breaker gate, no
        deadline) — availability beats latency at the floor.  Only a
        terminal-rung failure propagates to the caller.
        """
        ladder = self._ladder_for(request.config)
        last_err = None
        for i, (name, cfg, key) in enumerate(ladder):
            breaker = self._breakers.get(key)
            terminal = i == len(ladder) - 1
            # one span per rung CONSIDERED: skips close instantly with
            # a ``skipped`` attr, failures get ``error`` (span __exit__),
            # so a degraded request's trace shows the whole walk
            with obs.span("serve.rung", rung=name, index=i,
                          backend_key=key, terminal=terminal) as sp:
                if not terminal and not breaker.allow():
                    self._bump("breaker_skips")
                    obs.counter("serve.breaker_skips")
                    sp.annotate(skipped="breaker")
                    continue
                budget = None
                if self.deadline_s is not None and not terminal:
                    budget = self.deadline_s - (time.perf_counter() - t0)
                    if budget <= 0:
                        self._bump("deadline_skips")
                        obs.counter("serve.deadline_skips")
                        sp.annotate(skipped="deadline")
                        continue
                req = request if i == 0 else dataclasses.replace(
                    request, config=cfg, _signature=None)
                try:
                    result = self._call_rung(req, budget)
                except Exception as e:
                    last_err = e
                    breaker.record_failure()
                    self._bump("rung_failures")
                    obs.counter("serve.rung_failures")
                    sp.annotate(error=type(e).__name__)
                    if isinstance(e, DeadlineExceeded):
                        self._bump("deadline_misses")
                        obs.counter("serve.deadline_misses")
                    if terminal:
                        raise
                    continue
                breaker.record_success()
                if i > 0:
                    result.stats["degraded"] = name
                    self._bump("degraded")
                    obs.counter("serve.degraded")
                    sp.annotate(degraded=name)
                    with self._lock:
                        self._rung_counts[name] = \
                            self._rung_counts.get(name, 0) + 1
                return result
        raise last_err if last_err is not None else RuntimeError(
            "degradation ladder exhausted")  # pragma: no cover

    # -- bounded admission ------------------------------------------------

    def _admit(self) -> None:
        if self.max_inflight is None:
            return
        with self._adm:
            if self._active < self.max_inflight:
                self._active += 1
                return
            if self._queued >= self.max_queue:
                self._bump("shed")
                raise ServiceOverloaded(
                    f"admission queue full ({self._active} active, "
                    f"{self._queued} queued)")
            self._queued += 1
            try:
                while self._active >= self.max_inflight:
                    self._adm.wait()
            finally:
                self._queued -= 1
            self._active += 1

    def _release(self) -> None:
        if self.max_inflight is None:
            return
        with self._adm:
            self._active -= 1
            self._adm.notify()

    def _bump(self, key: str) -> None:
        with self._lock:
            self._res_counts[key] += 1

    # -- public API ------------------------------------------------------

    def map(self, request: MappingRequest) -> MappingResponse:
        """Serve one request (thread-safe).

        Warm path: signature + one LRU lookup.  Concurrent duplicates
        of an uncached signature share one compute pass; exactly one
        caller is the owner, the rest block until it publishes.  A
        FAILED flight is never replayed to its waiters: each waiter
        retries the full lookup once (recomputing if it becomes the new
        owner) so a transient fault poisons nothing — only a repeated
        failure propagates.

        The whole walk runs inside one ``serve.request`` span: the root
        of the request's trace (``MappingResponse.trace_id``), covering
        every ladder rung attempted and the pipeline/backend spans
        below them.
        """
        with obs.span("serve.request") as root:
            resp = self._serve(request)
            root.annotate(status=resp.status,
                          signature=resp.signature[:16])
        return resp

    def _serve(self, request: MappingRequest) -> MappingResponse:
        t0 = time.perf_counter()
        faults.fire("serve.cache", on_evict=self.results.storm)
        sig = request.signature()
        waited = False
        while True:
            result = self.results.get(sig, count=not waited)
            if result is not None:
                return self._respond(result, sig, "warm", t0)
            with self._lock:
                # recheck under the lock: the owner may have published
                # between the miss above and here (uncounted — one
                # logical lookup must not book two misses)
                result = self.results.get(sig, count=False)
                if result is not None:
                    return self._respond(result, sig, "warm", t0)
                entry = self._inflight.get(sig)
                owner = entry is None
                if owner:
                    entry = self._inflight[sig] = _InFlight()
            if owner:
                break
            entry.event.wait()
            if entry.result is not None:
                return self._respond(entry.result, sig, "coalesced", t0)
            # the flight failed (or aborted) without publishing
            if waited:
                if entry.error is not None:
                    raise entry.error
                raise RuntimeError(
                    "in-flight mapping computation was aborted")
            waited = True  # retry once: recompute, don't replay errors

        try:
            self._admit()
            try:
                entry.result = self._execute(request, t0)
            finally:
                self._release()
            self.results.put(sig, entry.result)
        except BaseException as e:  # record aborts for waiters too
            entry.error = e
            raise
        finally:
            with self._lock:
                del self._inflight[sig]
            entry.event.set()
        return self._respond(entry.result, sig, "cold", t0)

    def map_many(self, requests, max_workers: int = 0) -> list:
        """Serve a batch, coalescing duplicates WITHIN the batch.

        Unique signatures are computed once (in submission order, or
        concurrently when ``max_workers > 1``); every later duplicate
        receives the first occurrence's result as a ``coalesced``
        response.  Responses line up with ``requests``.
        """
        first: dict[str, MappingRequest] = {}
        for req in requests:
            first.setdefault(req.signature(), req)
        unique = list(first.values())
        if max_workers > 1 and len(unique) > 1:
            import concurrent.futures as cf
            with cf.ThreadPoolExecutor(max_workers=max_workers) as pool:
                primary = list(pool.map(self.map, unique))
        else:
            primary = [self.map(r) for r in unique]
        by_sig = {r.signature: r for r in primary}
        out = []
        seen: set[str] = set()
        for req in requests:
            sig = req.signature()
            resp = by_sig[sig]
            if sig in seen:
                with self._lock:
                    self._counts["coalesced"] += 1
                obs.counter("serve.responses.coalesced")
                resp = dataclasses.replace(resp, status="coalesced",
                                           latency_s=0.0)
            seen.add(sig)
            out.append(resp)
        return out

    def stats(self) -> dict:
        """Cumulative service counters + the result-cache stats.

        Beyond the PR 5 counters (``cold``/``warm``/``coalesced``/
        ``requests``/``inflight``/``cache``): ``degraded`` (requests
        served below their full rung) with the per-rung breakdown in
        ``rungs``, ``rung_failures`` (individual rung attempts that
        failed), ``deadline_misses`` (rungs abandoned at the deadline),
        ``deadline_skips`` (rungs never tried — budget already gone),
        ``breaker_skips`` (rungs skipped on an open breaker), ``shed``
        (requests refused by admission control), and ``breakers`` (the
        per-rung-key breaker states).
        """
        with self._lock:
            counts = dict(self._counts)
            res = dict(self._res_counts)
            rungs = dict(self._rung_counts)
        return {**counts, **res,
                "requests": (counts["cold"] + counts["warm"]
                             + counts["coalesced"]),
                "inflight": len(self._inflight),
                "rungs": rungs,
                "breakers": self._breakers.states(),
                "cache": self.results.stats()}

    def _respond(self, result, sig, status, t0) -> MappingResponse:
        with self._lock:
            self._counts[status] += 1
        latency = time.perf_counter() - t0
        obs.counter(f"serve.responses.{status}")
        obs.observe("serve.latency_s", latency)
        sp = obs.current_span()
        return MappingResponse(result, sig, status, latency,
                               trace_id=(sp.trace_id if sp is not None
                                         else None))


# Process-wide convenience instance for ad-hoc callers that want one
# shared cache without owning a service object — e.g. pass it to
# ``topology_mesh(..., service=default_service())`` so REPEAT mesh
# builds in one process hit the same cache.
_DEFAULT: MappingService | None = None
_DEFAULT_LOCK = threading.Lock()


def default_service(capacity: int = 256) -> MappingService:
    """The lazily-created process-wide :class:`MappingService`.

    ``capacity`` only applies to the first call (it sizes the
    singleton's LRU); later calls return the existing instance.
    """
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = MappingService(capacity)
        return _DEFAULT
