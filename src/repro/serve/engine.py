"""Mapping-as-a-service: a batching request server over the unified
mapping pipeline.

The paper's mapper is cheap enough to run at job-launch time for every
allocation shape it meets — so this module serves mapping decisions as
REQUESTS instead of one-shot scripts.  A :class:`MappingRequest` (task
graph + machine/allocation + objective/config) is canonicalised to a
content-addressed signature (:mod:`repro.core.signature`);
:class:`MappingService` then

- serves repeat requests from a bounded LRU of mapping results
  (``warm`` responses — no partitioning, no scoring, no backend
  compiles);
- coalesces duplicate in-flight requests: concurrent submissions of the
  same problem share ONE pipeline pass and receive bit-identical
  results (``coalesced`` responses);
- routes misses through the process-wide shared
  :class:`repro.mapping.MappingPipeline` registry
  (:func:`repro.mapping.shared_pipeline`), so the evaluator fallback
  chain and the jax/pallas compile caches (PR 4) are resolved/warmed
  once per process, not once per request (``cold`` responses).

Response schema (see README "repro.serve"): ``result`` (the
:class:`repro.core.MappingResult` — treat as read-only, it is shared
with the cache), ``signature``, ``status`` (cold/warm/coalesced) and
``latency_s``.

The token-decode model server that used to live here moved to
:mod:`repro.serve.decode`; ``ServeEngine`` is re-exported below for
compatibility.
"""

from __future__ import annotations

import dataclasses
import threading
import time

import numpy as np

from repro.core.signature import array_digest, mapping_signature
from repro.mapping import PipelineConfig, shared_pipeline
from repro.serve.cache import LRUCache


def __getattr__(name):
    # compat: the token-decode ServeEngine moved to repro.serve.decode;
    # resolve it lazily so the mapping service never pays the jax/model
    # import (PEP 562)
    if name == "ServeEngine":
        from repro.serve.decode import ServeEngine
        return ServeEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

# Short objective aliases accepted by make_request / the scenario
# registry ("wh" is the paper's WeightedHops rotation-search objective;
# "latency" is the TPU mesh builder's lexicographic pair).
OBJECTIVES = {
    "wh": "weighted_hops",
    "latency": ("latency_max", "weighted_hops"),
}


@dataclasses.dataclass
class MappingRequest:
    """One mapping problem: what to map, onto what, optimising what.

    graph        : :class:`repro.core.TaskGraph`.
    alloc        : :class:`repro.core.Allocation` (machine + node rows).
    config       : :class:`repro.mapping.PipelineConfig` — the full
                   pipeline knob set, including ``objective``.
    task_coords  : optional task-coordinate override (the TPU mesh
                   builder's traffic-scaled coordinates).
    task_weights : optional per-task weights for the partitioner.

    The request's identity is its CONTENT — two independently-built
    requests with equal arrays/config share a signature and therefore a
    cache entry.  The signature is computed once and memoised on the
    instance (requests are cheap handles; reuse them for hot paths).
    """

    graph: object
    alloc: object
    config: PipelineConfig = dataclasses.field(
        default_factory=PipelineConfig)
    task_coords: np.ndarray | None = None
    task_weights: np.ndarray | None = None
    _signature: str | None = dataclasses.field(
        default=None, repr=False, compare=False)

    def signature(self) -> str:
        if self._signature is None:
            extra = {}
            if self.task_coords is not None:
                extra["task_coords"] = array_digest(self.task_coords)
            if self.task_weights is not None:
                extra["task_weights"] = array_digest(self.task_weights)
            self._signature = mapping_signature(
                self.graph, self.alloc, self.config, extra or None)
        return self._signature


def make_request(graph, alloc, objective="wh", *, config=None,
                 task_coords=None, task_weights=None,
                 **overrides) -> MappingRequest:
    """Build a :class:`MappingRequest`.

    ``objective`` accepts an alias from :data:`OBJECTIVES`, a metric
    key, or a tuple of keys (lexicographic).  ``overrides`` are
    :class:`PipelineConfig` fields (``rotations=8``,
    ``hierarchy="node"``, ...); pass ``config`` to supply a full config
    instead (mutually exclusive with ``objective``/``overrides``).
    """
    if config is None:
        config = PipelineConfig(
            objective=OBJECTIVES.get(objective, objective), **overrides)
    elif overrides:
        raise ValueError("pass either config= or config-field overrides,"
                         " not both")
    return MappingRequest(graph, alloc, config,
                          task_coords=task_coords,
                          task_weights=task_weights)


@dataclasses.dataclass
class MappingResponse:
    """What the service returns for one request.

    result    : the mapping (shared with the cache — read-only).
    signature : the request's content signature (the cache key).
    status    : "cold" (pipeline ran), "warm" (LRU hit) or "coalesced"
                (shared an in-flight computation or a batch duplicate).
    latency_s : wall-clock seconds this request spent in the service.
    """

    result: object
    signature: str
    status: str
    latency_s: float


class _InFlight:
    """One in-progress computation; duplicate requests wait on it."""

    __slots__ = ("event", "result", "error")

    def __init__(self):
        self.event = threading.Event()
        self.result = None
        self.error = None


class MappingService:
    """The request server: canonicalise, coalesce, cache, compute.

    capacity : bound of the result LRU (entries, not bytes — a result
               is one int array per request).
    """

    def __init__(self, capacity: int = 256):
        self.results = LRUCache(capacity)
        self._inflight: dict[str, _InFlight] = {}
        self._lock = threading.Lock()
        self._counts = {"cold": 0, "warm": 0, "coalesced": 0}

    # -- the miss path ---------------------------------------------------

    def _compute(self, request: MappingRequest):
        """Run the pipeline for a cache miss (test seam: override to
        instrument/block the cold path)."""
        pipe = shared_pipeline(request.config)
        return pipe.map(request.graph, request.alloc,
                        task_coords=request.task_coords,
                        task_weights=request.task_weights)

    # -- public API ------------------------------------------------------

    def map(self, request: MappingRequest) -> MappingResponse:
        """Serve one request (thread-safe).

        Warm path: signature + one LRU lookup.  Concurrent duplicates
        of an uncached signature share one `_compute` pass; exactly one
        caller is the owner, the rest block until it publishes.
        """
        t0 = time.perf_counter()
        sig = request.signature()
        result = self.results.get(sig)
        if result is not None:
            return self._respond(result, sig, "warm", t0)

        with self._lock:
            # recheck under the lock: the owner may have published
            # between the miss above and here (uncounted — one logical
            # lookup must not book two misses)
            result = self.results.get(sig, count=False)
            if result is not None:
                return self._respond(result, sig, "warm", t0)
            entry = self._inflight.get(sig)
            owner = entry is None
            if owner:
                entry = self._inflight[sig] = _InFlight()

        if not owner:
            entry.event.wait()
            if entry.error is not None:
                raise entry.error
            if entry.result is None:
                # the owner died without publishing (e.g. a
                # KeyboardInterrupt that unwound past its except)
                raise RuntimeError(
                    "in-flight mapping computation was aborted")
            return self._respond(entry.result, sig, "coalesced", t0)

        try:
            entry.result = self._compute(request)
            self.results.put(sig, entry.result)
        except BaseException as e:  # record aborts for waiters too
            entry.error = e
            raise
        finally:
            with self._lock:
                del self._inflight[sig]
            entry.event.set()
        return self._respond(entry.result, sig, "cold", t0)

    def map_many(self, requests, max_workers: int = 0) -> list:
        """Serve a batch, coalescing duplicates WITHIN the batch.

        Unique signatures are computed once (in submission order, or
        concurrently when ``max_workers > 1``); every later duplicate
        receives the first occurrence's result as a ``coalesced``
        response.  Responses line up with ``requests``.
        """
        first: dict[str, MappingRequest] = {}
        for req in requests:
            first.setdefault(req.signature(), req)
        unique = list(first.values())
        if max_workers > 1 and len(unique) > 1:
            import concurrent.futures as cf
            with cf.ThreadPoolExecutor(max_workers=max_workers) as pool:
                primary = list(pool.map(self.map, unique))
        else:
            primary = [self.map(r) for r in unique]
        by_sig = {r.signature: r for r in primary}
        out = []
        seen: set[str] = set()
        for req in requests:
            sig = req.signature()
            resp = by_sig[sig]
            if sig in seen:
                with self._lock:
                    self._counts["coalesced"] += 1
                resp = dataclasses.replace(resp, status="coalesced",
                                           latency_s=0.0)
            seen.add(sig)
            out.append(resp)
        return out

    def stats(self) -> dict:
        """Cumulative service counters + the result-cache stats."""
        with self._lock:
            counts = dict(self._counts)
        return {**counts,
                "requests": sum(counts.values()),
                "inflight": len(self._inflight),
                "cache": self.results.stats()}

    def _respond(self, result, sig, status, t0) -> MappingResponse:
        with self._lock:
            self._counts[status] += 1
        return MappingResponse(result, sig, status,
                               time.perf_counter() - t0)


# Process-wide convenience instance for ad-hoc callers that want one
# shared cache without owning a service object — e.g. pass it to
# ``topology_mesh(..., service=default_service())`` so REPEAT mesh
# builds in one process hit the same cache.
_DEFAULT: MappingService | None = None
_DEFAULT_LOCK = threading.Lock()


def default_service(capacity: int = 256) -> MappingService:
    """The lazily-created process-wide :class:`MappingService`.

    ``capacity`` only applies to the first call (it sizes the
    singleton's LRU); later calls return the existing instance.
    """
    global _DEFAULT
    with _DEFAULT_LOCK:
        if _DEFAULT is None:
            _DEFAULT = MappingService(capacity)
        return _DEFAULT
