"""Scenario registry: every workload x allocation x hierarchy x
objective combination the repo serves, generated from ONE source of
truth.

Benchmarks, tests and the mapping service all draw their problems here
instead of hand-rolling graph/allocation builders per caller.  A
:class:`Scenario` is a frozen, named point of the cross-product

    workloads   : minighost (3D stencil), homme (cubed-sphere element
                  mesh), random (sparse random geometric graph)
    allocations : xk7_sparse (fragmented SFC allocation on the
                  heterogeneous Gemini torus), bgq_block (contiguous
                  BG/Q 5D-torus prefix), tpu_mesh (v5e ICI torus),
                  fat_tree (three-level tree approximated as a
                  non-wrapping grid with per-level bandwidth taper +
                  an intra-node core dim)
    hierarchy   : flat | node (PR 3's two-level coarsen -> map ->
                  refine) | depth3 (the recursive N-level hierarchy of
                  :class:`repro.hier.HierarchySpec` with one grouping
                  level above the nodes)
    objective   : wh (WeightedHops) | latency (Latency, WeightedHops)

and everything it builds is a pure function of ``(scale, seed)`` — the
same scenario always yields bit-identical graphs and allocations (the
determinism the serve layer's content-addressed cache relies on, and
tests assert).
"""

from __future__ import annotations

import dataclasses
import zlib

import numpy as np

from repro.core import (Allocation, TaskGraph, bgq, block_allocation,
                        cube_sphere_graph, gemini_xk7, make_machine,
                        sfc_allocation, stencil_graph, tpu_v5e_pod)
from repro.mapping import HierarchySpec, PipelineConfig
from repro.serve.engine import OBJECTIVES, MappingRequest

WORKLOADS = ("minighost", "homme", "random")
ALLOCATIONS = ("xk7_sparse", "bgq_block", "tpu_mesh", "fat_tree")
HIERARCHIES = ("flat", "node", "depth3")
OBJECTIVE_KEYS = ("wh", "latency")

DEFAULT_SCALE = 4096  # target task count (builders may round, see below)
DEFAULT_ROTATIONS = 4


def _rng(seed: int, *tags: str) -> np.random.Generator:
    """Deterministic per-(seed, tag...) generator — scenario components
    never share or race on one stream."""
    return np.random.default_rng(
        [int(seed)] + [zlib.crc32(t.encode()) for t in tags])


def _pow2_grid(n: int) -> tuple[int, int, int]:
    """Near-cubic power-of-two 3D grid with product 2^floor(log2 n)."""
    e = max(int(np.log2(max(n, 8))), 3)
    a = e // 3
    return (1 << (e - 2 * a), 1 << a, 1 << a)


def _pow2_split(total_exp: int, parts: int) -> tuple[int, ...]:
    """Split ``2**total_exp`` into ``parts`` near-equal pow2 factors."""
    base, extra = divmod(total_exp, parts)
    return tuple(1 << (base + (1 if i < extra else 0))
                 for i in range(parts))


# ---------------------------------------------------------------------------
# Workload builders (task graphs)
# ---------------------------------------------------------------------------

def _graph_minighost(scale: int, seed: int) -> TaskGraph:
    return stencil_graph(_pow2_grid(scale), torus=False)


def _graph_homme(scale: int, seed: int) -> TaskGraph:
    ne = max(2, int(round(np.sqrt(scale / 6.0))))
    return cube_sphere_graph(ne)


def _graph_random(scale: int, seed: int, degree: int = 6) -> TaskGraph:
    """Sparse random geometric graph: ``scale`` tasks at uniform 3D
    coordinates, each sending to ``degree`` random peers (both
    directions, lognormal volumes)."""
    rng = _rng(seed, "random-graph")
    n = int(scale)
    coords = rng.random((n, 3)) * n ** (1.0 / 3.0)
    src = np.repeat(np.arange(n), degree)
    dst = rng.integers(0, n - 1, size=n * degree)
    dst = np.where(dst >= src, dst + 1, dst)  # no self-edges
    w = rng.lognormal(mean=0.0, sigma=1.0, size=n * degree)
    edges = np.stack([np.concatenate([src, dst]),
                      np.concatenate([dst, src])], axis=1)
    return TaskGraph(coords, edges, np.concatenate([w, w]),
                     meta={"kind": "random", "degree": degree})


_GRAPHS = {
    "minighost": _graph_minighost,
    "homme": _graph_homme,
    "random": _graph_random,
}


# ---------------------------------------------------------------------------
# Allocation builders (machine + node rows for exactly n tasks)
# ---------------------------------------------------------------------------

def fat_tree_machine(nnodes: int, cores_per_node: int = 4,
                     bw_gbs: tuple = (12.5, 25.0, 100.0)):
    """Three-level fat tree approximated in the mesh machine model:
    non-wrapping (pods, racks, nodes) dims whose per-dim bandwidth
    tapers toward the root (crossing pods is the oversubscribed slow
    level), plus an intra-node core dim (free, like XK7/BG/Q nodes).
    """
    nrouters = max(1, -(-nnodes // cores_per_node))
    e = max(int(np.ceil(np.log2(nrouters))), 3)
    dims = _pow2_split(e, 3) + (cores_per_node,)
    pats = tuple(np.array([b]) for b in bw_gbs) + \
        (np.array([float("inf")]),)
    return make_machine(dims, wrap=False, name="fat-tree", core_dims=1,
                        bw_patterns=pats)


def _alloc_xk7_sparse(n: int, seed: int) -> Allocation:
    """Fragmented ALPS-style allocation on the heterogeneous XK7 torus,
    sized ~2x the job so fragments scatter."""
    cores = 16
    e = int(np.ceil(np.log2(max(2 * n // cores, 8))))
    a = e // 3
    rdims = (1 << (e - 2 * a), 1 << a, 1 << a)
    machine = gemini_xk7(dims=rdims, cores_per_node=cores)
    return sfc_allocation(machine, n, nfragments=8, seed=seed)


def _alloc_bgq_block(n: int, seed: int) -> Allocation:
    """Contiguous BG/Q allocation: the first ``n`` cores of a 5D-torus
    block in row-major order (E dim fixed at 2, cores 16/node)."""
    cores = 16
    e = max(int(np.ceil(np.log2(max(-(-n // cores), 2)))), 1)
    dims = _pow2_split(e - 1, 4) + (2,)
    machine = bgq(dims=dims, cores_per_node=cores)
    return Allocation(machine, block_allocation(machine).coords[:n])


def _alloc_tpu_mesh(n: int, seed: int) -> Allocation:
    """TPU v5e ICI torus sized to the job (first ``n`` chips)."""
    side = 1 << max(int(np.ceil(np.log2(np.sqrt(max(n, 4))))), 1)
    machine = tpu_v5e_pod(side=side)
    return Allocation(machine, block_allocation(machine).coords[:n])


def _alloc_fat_tree(n: int, seed: int) -> Allocation:
    machine = fat_tree_machine(n)
    return Allocation(machine, block_allocation(machine).coords[:n])


_ALLOCS = {
    "xk7_sparse": _alloc_xk7_sparse,
    "bgq_block": _alloc_bgq_block,
    "tpu_mesh": _alloc_tpu_mesh,
    "fat_tree": _alloc_fat_tree,
}


# ---------------------------------------------------------------------------
# The registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named point of the scenario cross-product."""

    workload: str
    allocation: str
    hierarchy: str = "flat"
    objective: str = "wh"
    scale: int = DEFAULT_SCALE
    seed: int = 0
    rotations: int = DEFAULT_ROTATIONS

    def __post_init__(self):
        for field, options in (("workload", WORKLOADS),
                               ("allocation", ALLOCATIONS),
                               ("hierarchy", HIERARCHIES),
                               ("objective", OBJECTIVE_KEYS)):
            if getattr(self, field) not in options:
                raise ValueError(
                    f"unknown {field} {getattr(self, field)!r}; "
                    f"options: {options}")

    @property
    def name(self) -> str:
        return (f"{self.workload}-{self.allocation}-{self.hierarchy}-"
                f"{self.objective}")

    def graph(self) -> TaskGraph:
        return _GRAPHS[self.workload](self.scale, self.seed)

    def alloc_for(self, graph: TaskGraph) -> Allocation:
        return _ALLOCS[self.allocation](graph.n, self.seed)

    def config(self) -> PipelineConfig:
        # resolve the registry's hierarchy tag through the structured
        # spec constructor (the supported API) — never the deprecated
        # string aliases, so scenario configs are warning-free
        return PipelineConfig(sfc="FZ", shift=True,
                              rotations=self.rotations,
                              objective=OBJECTIVES[self.objective],
                              hierarchy=HierarchySpec.from_string(
                                  self.hierarchy))

    def request(self) -> MappingRequest:
        """The scenario as a serve-layer request (deterministic: same
        (scale, seed) -> bit-identical arrays -> same signature)."""
        graph = self.graph()
        return MappingRequest(graph, self.alloc_for(graph),
                              self.config())


def all_scenarios(scale: int = DEFAULT_SCALE, seed: int = 0,
                  rotations: int = DEFAULT_ROTATIONS) -> list[Scenario]:
    """The full cross-product (|workloads| x |allocations| x
    |hierarchies| x |objectives| scenarios) at one scale/seed."""
    return [Scenario(w, a, h, o, scale, seed, rotations)
            for w in WORKLOADS for a in ALLOCATIONS
            for h in HIERARCHIES for o in OBJECTIVE_KEYS]


def scenario_names() -> list[str]:
    return [s.name for s in all_scenarios()]


def get_scenario(name: str, scale: int = DEFAULT_SCALE, seed: int = 0,
                 rotations: int = DEFAULT_ROTATIONS) -> Scenario:
    """Scenario by ``workload-allocation-hierarchy-objective`` name."""
    parts = name.split("-")
    if len(parts) != 4:
        raise ValueError(
            f"scenario name {name!r} is not "
            f"'workload-allocation-hierarchy-objective'")
    return Scenario(*parts, scale=scale, seed=seed, rotations=rotations)
