"""Circuit breakers and the graceful-degradation ladder (ISSUE 7).

PR 4-6 gave every accelerator stage a *resolved-once* fallback chain:
``pallas -> jax -> numpy`` scoring, ``jax -> numpy`` partitioning, the
fused whole-pipeline program over both.  Resolution happens at pipeline
construction, so a RUNTIME device fault (compile failure, VMEM/HBM OOM,
hung kernel) mid-request escapes straight to the caller.  This module
upgrades resolved-once to **resolved-with-health**:

- :class:`CircuitBreaker` — one per resolved backend rung
  (:func:`rung_key`), the classic closed / open / half-open machine.
  ``threshold`` consecutive failures open the breaker; after
  ``cooldown_s`` one probe request is let through (half-open) and its
  outcome closes or re-opens it.  While open, the service skips the
  rung WITHOUT paying its failure latency.

- :func:`degradation_ladder` — the ordered list of
  ``(rung_name, PipelineConfig)`` a failed request walks, each rung a
  cumulative downgrade of the one above:

  1. ``full``            : the request's own config (PR 6 fused path
                           when eligible).
  2. ``unfused``         : same backends, staged host-driven pipeline
                           (``fused="off"``) — skips the fused program
                           but keeps device partition + scoring.
  3. ``score_jax``       : pallas scoring -> the jit jax scorer.
  4. ``score_numpy``     : accelerator scoring -> the numpy reference.
  5. ``partition_numpy`` : device partition sweep -> the host
                           vectorized engine.
  6. ``depth_2``         : deep hierarchies (``HierarchySpec`` depth
                           > 2) truncate to the classic two-level
                           node scheme — fewer coarsen/refine passes,
                           no grouping-level QAP search.
  7. ``refine_0``        : every level's refine AND polish rounds -> 0
                           (skip the swap/QAP/polish scoring loops
                           entirely).

  Rungs that do not apply to a config (numpy-only configs, flat
  hierarchy, depth <= 2) are elided; the FIRST rung is always the
  unmodified config and the LAST rung of every non-trivial ladder runs
  entirely on host numpy.

  **Quality bound:** rungs 2-5 only move WHERE the same algorithm runs
  — the repo's backend-equivalence guarantees (bit-identity oracles in
  tests/benchmarks) make their permutations bit-identical to the
  healthy path, so the objective score is unchanged.  Only the depth
  rungs change the result: ``depth_2`` trades the deep hierarchy's
  speed for the well-characterised two-level quality, and
  ``refine_0`` forfeits the (monotone) refinement improvement, i.e.
  the degraded score is at worst the UNREFINED hierarchical score —
  within 5% of flat on the benchmark suite (the ``hier`` entry's
  ``wh_ratio`` guard).

:class:`MappingService` (:mod:`repro.serve.engine`) walks the ladder on
failure or deadline expiry and records the rung that served the
request in ``MappingResult.stats["degraded"]``.
"""

from __future__ import annotations

import dataclasses
import threading
import time

from repro import obs
from repro.core.metrics import _BACKEND_CHAIN, get_evaluator
from repro.core.orderings import resolve_partition_backend
from repro.mapping import PipelineConfig


class ServiceOverloaded(RuntimeError):
    """Admission queue full: the request was shed, not executed."""


class DeadlineExceeded(TimeoutError):
    """A ladder rung exceeded the request deadline (internal signal —
    the service degrades instead of surfacing this to callers)."""


# -- circuit breaker ------------------------------------------------------

class CircuitBreaker:
    """Closed / open / half-open breaker with probe-based recovery.

    closed    : requests flow; ``threshold`` CONSECUTIVE failures trip
                the breaker.
    open      : requests are refused (the ladder skips this rung) until
                ``cooldown_s`` has elapsed.
    half_open : exactly ONE probe request is admitted; success closes
                the breaker, failure re-opens it for another cooldown.

    ``clock`` is injectable for deterministic tests (defaults to
    ``time.monotonic``).
    """

    def __init__(self, threshold: int = 3, cooldown_s: float = 30.0,
                 clock=time.monotonic):
        if threshold < 1:
            raise ValueError(f"threshold must be >= 1, got {threshold}")
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self._lock = threading.Lock()
        self._state = "closed"
        self._failures = 0
        self._opened_at = 0.0
        self._probing = False
        self.opens = 0      # cumulative trips (observability)
        self.failures = 0   # cumulative recorded failures

    def _state_locked(self) -> str:
        if (self._state == "open"
                and self._clock() - self._opened_at >= self.cooldown_s):
            self._state = "half_open"
            self._probing = False
        return self._state

    @property
    def state(self) -> str:
        with self._lock:
            return self._state_locked()

    def allow(self) -> bool:
        """May a request use this rung right now?"""
        with self._lock:
            st = self._state_locked()
            if st == "closed":
                return True
            if st == "half_open" and not self._probing:
                self._probing = True  # one probe per cooldown window
                return True
            return False

    def record_success(self) -> None:
        with self._lock:
            self._state = "closed"
            self._failures = 0
            self._probing = False

    def record_failure(self) -> None:
        with self._lock:
            self.failures += 1
            st = self._state_locked()
            if st == "half_open":
                self._trip_locked()  # failed probe: straight back open
                return
            self._failures += 1
            if self._failures >= self.threshold:
                self._trip_locked()

    def _trip_locked(self) -> None:
        self._state = "open"
        self._opened_at = self._clock()
        self._failures = 0
        self._probing = False
        self.opens += 1
        obs.counter("serve.breaker_trips")

    def stats(self) -> dict:
        with self._lock:
            return {"state": self._state_locked(), "opens": self.opens,
                    "failures": self.failures}


class BreakerBoard:
    """One :class:`CircuitBreaker` per rung key, created on demand.

    Keys come from :func:`rung_key`, so health is tracked per RESOLVED
    backend combination and shared across every scenario/config that
    lands on the same backends.
    """

    def __init__(self, threshold: int = 3, cooldown_s: float = 30.0,
                 clock=time.monotonic):
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._lock = threading.Lock()
        self._breakers: dict[str, CircuitBreaker] = {}

    def get(self, key: str) -> CircuitBreaker:
        with self._lock:
            br = self._breakers.get(key)
            if br is None:
                br = self._breakers[key] = CircuitBreaker(
                    self.threshold, self.cooldown_s, self._clock)
            return br

    def states(self) -> dict:
        with self._lock:
            items = list(self._breakers.items())
        return {k: br.stats() for k, br in items}


# -- the degradation ladder ----------------------------------------------

def fused_candidate(config: PipelineConfig) -> bool:
    """Would this config engage the fused whole-pipeline program?

    Mirrors :class:`MappingPipeline`'s construction gate (including
    backend RESOLUTION, so a machine without jax never gets a spurious
    ``unfused`` rung).
    """
    if (config.fused == "off" or config.sweep != "batched"
            or config.backend != "vectorized"):
        return False
    if resolve_partition_backend(config.partition_backend) != "jax":
        return False
    return get_evaluator(config.score_backend)[0] in ("jax", "pallas")


def rung_key(config: PipelineConfig) -> str:
    """The breaker key: which RESOLVED backends serve this config."""
    score = get_evaluator(config.score_backend)[0]
    part = resolve_partition_backend(config.partition_backend)
    parts = ["fused" if fused_candidate(config) else "staged",
             f"score={score}", f"partition={part}"]
    spec = config.hierarchy
    if not spec.is_flat:
        # depth + total refine budget: a depth-degraded or refine-
        # stripped config is a DIFFERENT rung with its own breaker
        parts.append(f"depth={spec.depth}")
        parts.append(f"refine={spec.refine_rounds_total}")
    return "/".join(parts)


def degradation_ladder(config: PipelineConfig) -> list:
    """Ordered ``(rung_name, PipelineConfig)`` downgrades for ``config``.

    The first entry is always ``("full", config)`` unchanged; each
    later rung is a cumulative ``dataclasses.replace`` of the previous
    one (see the module docstring for the rung semantics and quality
    bound).  Rungs that would not change the config are elided, so a
    host-only config gets a single-rung ladder.
    """
    rungs = [("full", config)]
    cur = config

    def push(name, **changes):
        nonlocal cur
        new = dataclasses.replace(cur, **changes)
        if new != cur:
            cur = new
            rungs.append((name, new))

    if fused_candidate(config):
        push("unfused", fused="off")
    for backend in _BACKEND_CHAIN[config.score_backend][1:]:
        push(f"score_{backend}", score_backend=backend)
    if config.partition_backend != "numpy":
        push("partition_numpy", partition_backend="numpy")
    # hierarchy-depth degradation: hierarchy is a normalized
    # HierarchySpec here, so the rungs are built with its own
    # combinators (never the deprecated legacy kwargs)
    spec = cur.hierarchy
    if spec.depth > 2:
        push("depth_2", hierarchy=spec.truncated(2))
    spec = cur.hierarchy
    if not spec.is_flat and (spec.refine_rounds_total > 0
                             or spec.polish_rounds_total > 0):
        push("refine_0", hierarchy=spec.with_refine(rounds=0, polish=0))
    return rungs
