"""repro.serve — mapping-as-a-service over the unified pipeline.

Two serving stacks live here:

- :mod:`repro.serve.engine` — the mapping request server
  (:class:`MappingService`): content-addressed request signatures, a
  bounded LRU of mapping results, in-flight request coalescing, and a
  process-wide shared pipeline/compile-cache pool behind cache misses.
- :mod:`repro.serve.scenarios` — the scenario registry: the full
  workload x allocation x hierarchy x objective cross-product that
  benchmarks, tests and the server draw problems from.
- :mod:`repro.serve.resilience` — circuit breakers and the
  graceful-degradation ladder the service walks on backend failures
  (ISSUE 7; fault injection lives in :mod:`repro.faults`).
- :mod:`repro.serve.decode` — the token-decode model server
  (:class:`ServeEngine`, prefill + greedy decode over a KV/SSM cache).
"""

from .cache import LRUCache
from .engine import (OBJECTIVES, MappingRequest, MappingResponse,
                     MappingService, default_service, make_request)
from .resilience import (CircuitBreaker, DeadlineExceeded,
                         ServiceOverloaded, degradation_ladder, rung_key)
from .scenarios import (ALLOCATIONS, HIERARCHIES, OBJECTIVE_KEYS,
                        WORKLOADS, Scenario, all_scenarios, get_scenario,
                        scenario_names)


def __getattr__(name):
    # lazy re-export: ServeEngine pulls in jax + the model stack, which
    # the mapping service itself never needs (PEP 562)
    if name == "ServeEngine":
        from .decode import ServeEngine
        return ServeEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "ALLOCATIONS", "CircuitBreaker", "DeadlineExceeded", "HIERARCHIES",
    "LRUCache", "MappingRequest", "MappingResponse", "MappingService",
    "OBJECTIVES", "OBJECTIVE_KEYS", "Scenario", "ServeEngine",
    "ServiceOverloaded", "WORKLOADS", "all_scenarios",
    "default_service", "degradation_ladder", "get_scenario",
    "make_request", "rung_key", "scenario_names",
]
