"""Batched serving engine: prefill + greedy decode over a KV/SSM cache.

For attention families the prompt is prefETCHED in one forward pass
(collecting per-layer k/v); SSM/hybrid prompts replay through the
single-token recurrence inside a lax.fori_loop (state capture during a
full-sequence SSD pass is an optimisation left to the kernel path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import (ModelConfig, decode_step, init_cache, prefill)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_seq: int,
                 batch: int):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.batch = batch
        self._step = jax.jit(functools.partial(decode_step, cfg),
                             donate_argnums=(1,))
        self._prefill = jax.jit(functools.partial(prefill, cfg),
                                static_argnames=("max_seq",))

    # -- prompt ingestion ---------------------------------------------------

    def _ingest_attention(self, batch_inputs, prompt_len: int):
        logits, cache = self._prefill(self.params, batch_inputs,
                                      max_seq=self.max_seq)
        return logits[:, -1], cache

    def _ingest_recurrent(self, tokens):
        cache = init_cache(self.cfg, tokens.shape[0], self.max_seq)

        def body(t, carry):
            cache, logits = carry
            lg, cache = decode_step(self.cfg, self.params, cache,
                                    jax.lax.dynamic_slice_in_dim(
                                        tokens, t, 1, axis=1),
                                    t)
            return cache, lg[:, 0]

        cache, last = jax.lax.fori_loop(
            0, tokens.shape[1], body,
            (cache, jnp.zeros((tokens.shape[0], self.cfg.vocab_size),
                              jnp.dtype(self.cfg.dtype))))
        return last, cache

    # -- public API ----------------------------------------------------------

    def generate(self, tokens: np.ndarray, *, max_new_tokens: int,
                 extras: dict | None = None) -> np.ndarray:
        """Greedy continuation of ``tokens`` (B, prompt_len)."""
        cfg = self.cfg
        tokens = jnp.asarray(tokens, jnp.int32)
        b, plen = tokens.shape
        inputs = {"tokens": tokens, **(extras or {})}
        if cfg.family in ("ssm", "hybrid"):
            ingest = jax.jit(self._ingest_recurrent)
            last_logits, cache = ingest(tokens)
        else:
            last_logits, cache = self._ingest_attention(inputs, plen)
        out = [jnp.argmax(last_logits, axis=-1).astype(jnp.int32)]
        pos = plen
        for _ in range(max_new_tokens - 1):
            lg, cache = self._step(self.params, cache, out[-1][:, None],
                                   jnp.int32(pos))
            out.append(jnp.argmax(lg[:, 0], axis=-1).astype(jnp.int32))
            pos += 1
        return np.stack([np.asarray(t) for t in out], axis=1)
