"""Request-scoped tracing: thread-safe, context-manager spans.

The mapping stack's wall-clock story used to live in ad-hoc ``timings``
dicts (duplicated between ``mapping/pipeline.py`` and
``hier/levels.py``) with no nesting, no identity and no export.  This
module replaces them with SPANS:

- :func:`span` opens a named span as a context manager.  Spans nest:
  the innermost open span of the calling context is the parent, tracked
  through a :class:`contextvars.ContextVar` so concurrent requests on
  different threads never share a lineage.
- A span opened with no parent becomes a ROOT and mints a fresh
  **trace id**; every descendant inherits it.  One service request =
  one trace, covering the ladder rungs it attempted, the pipeline
  stages that ran and the backend call sites they resolved to.
- Clocks are monotonic (``time.perf_counter``); ``wall`` records the
  epoch start time for export alignment only and never feeds a
  duration.
- Finished spans land in a bounded ring (:func:`finished`) and are
  offered to registered SINKS (:func:`add_sink`) — the JSONL exporter
  in :mod:`repro.obs.export` is one.  A sink that raises is dropped
  from the hot path silently: observability must never fail a request.
- ``contextvars`` do not cross thread boundaries; code that hops
  threads (the serve layer's deadline worker) re-parents explicitly
  with :func:`attach`.

The module is stdlib-only and allocation-light: an unsampled process
pays one contextvar read per span plus a deque append on exit.
"""

from __future__ import annotations

import contextvars
import itertools
import threading
import time
import uuid
from collections import deque
from contextlib import contextmanager

# bounded ring of finished spans kept for snapshot/export (old spans
# fall off; exporters that need everything attach a sink instead)
MAX_FINISHED = 4096

_IDS = itertools.count(1)
_CURRENT: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_span", default=None)


class Span:
    """One timed, attributed node of a trace tree.

    name      : dotted stage/site name ("serve.request", "score.jax").
    trace_id  : shared by every span of one request's tree.
    span_id   : unique within the process.
    parent_id : ``None`` for a root span.
    attrs     : flat str -> scalar annotations (backend resolved,
                degraded rung, cache outcome, candidate/point counts).
    """

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "attrs",
                 "t0", "t1", "wall", "thread")

    def __init__(self, name: str, trace_id: str,
                 parent_id: int | None = None, **attrs):
        self.name = name
        self.trace_id = trace_id
        self.span_id = next(_IDS)
        self.parent_id = parent_id
        self.attrs = dict(attrs)
        self.t0 = time.perf_counter()
        self.t1: float | None = None
        self.wall = time.time()
        self.thread = threading.get_ident()

    @property
    def duration_s(self) -> float:
        """Monotonic seconds; measured up to now while still open."""
        end = self.t1 if self.t1 is not None else time.perf_counter()
        return end - self.t0

    def annotate(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def to_dict(self) -> dict:
        return {
            "name": self.name, "trace_id": self.trace_id,
            "span_id": self.span_id, "parent_id": self.parent_id,
            "t0_s": self.t0, "duration_s": self.duration_s,
            "wall": self.wall, "thread": self.thread,
            "attrs": dict(self.attrs),
        }

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"Span({self.name!r}, trace={self.trace_id}, "
                f"id={self.span_id}, parent={self.parent_id}, "
                f"dur={self.duration_s * 1e3:.3f}ms, {self.attrs})")


class Tracer:
    """Span factory + finished-span ring + sink fan-out (thread-safe).

    Normally used through the module-level singleton (:data:`TRACER`)
    and the module functions below; tests construct private tracers.
    """

    def __init__(self, max_finished: int = MAX_FINISHED):
        self._lock = threading.Lock()
        self._finished: deque = deque(maxlen=max_finished)
        self._sinks: list = []

    # -- span lifecycle ---------------------------------------------------

    @contextmanager
    def span(self, name: str, **attrs):
        """Open a child of the context's current span (or a new root).

        The span closes on block exit; an escaping exception is recorded
        as ``attrs["error"]`` (exception type name) before propagating,
        so failed backend calls and ladder rungs are visible in the
        trace without any per-site boilerplate.
        """
        parent: Span | None = _CURRENT.get()
        if parent is None:
            sp = Span(name, uuid.uuid4().hex[:16], None, **attrs)
        else:
            sp = Span(name, parent.trace_id, parent.span_id, **attrs)
        token = _CURRENT.set(sp)
        try:
            yield sp
        except BaseException as e:
            sp.attrs.setdefault("error", type(e).__name__)
            raise
        finally:
            sp.t1 = time.perf_counter()
            _CURRENT.reset(token)
            self._finish(sp)

    @contextmanager
    def attach(self, parent: Span | None):
        """Adopt ``parent`` as the current span for this block.

        Contextvars never cross a ``threading.Thread`` start, so worker
        threads (the serve layer's deadline rung runner) re-parent with
        the span captured on the submitting thread — their descendants
        then join the request's trace instead of rooting new ones.
        ``attach(None)`` is a no-op passthrough.
        """
        if parent is None:
            yield None
            return
        token = _CURRENT.set(parent)
        try:
            yield parent
        finally:
            _CURRENT.reset(token)

    def current(self) -> Span | None:
        return _CURRENT.get()

    def _finish(self, sp: Span) -> None:
        with self._lock:
            self._finished.append(sp)
            sinks = list(self._sinks)
        for sink in sinks:
            try:
                sink(sp)
            except Exception:
                self.remove_sink(sink)

    # -- finished spans ---------------------------------------------------

    def finished(self, trace_id: str | None = None) -> list:
        """Snapshot of the ring, oldest first; optionally one trace."""
        with self._lock:
            spans = list(self._finished)
        if trace_id is not None:
            spans = [s for s in spans if s.trace_id == trace_id]
        return spans

    def reset(self) -> None:
        with self._lock:
            self._finished.clear()

    # -- sinks ------------------------------------------------------------

    def add_sink(self, sink) -> None:
        with self._lock:
            if sink not in self._sinks:
                self._sinks.append(sink)

    def remove_sink(self, sink) -> None:
        with self._lock:
            if sink in self._sinks:
                self._sinks.remove(sink)


def span_tree(spans) -> list:
    """Nest a flat span list into ``(span, children)`` root tuples.

    Children keep finish order.  Spans whose parent is not in ``spans``
    (e.g. fell off the ring) surface as roots rather than vanishing.
    """
    by_id = {s.span_id: s for s in spans}
    children: dict = {s.span_id: [] for s in spans}
    roots = []
    for s in spans:
        if s.parent_id in by_id:
            children[s.parent_id].append(s)
        else:
            roots.append(s)

    def build(s):
        return (s, [build(c) for c in children[s.span_id]])

    return [build(r) for r in roots]


def format_tree(spans, indent: str = "  ") -> str:
    """Human-readable span tree (the tracing demo's output)."""
    lines = []

    def walk(node, depth):
        s, kids = node
        attrs = " ".join(f"{k}={v}" for k, v in sorted(s.attrs.items()))
        lines.append(f"{indent * depth}{s.name}  "
                     f"{s.duration_s * 1e3:8.3f}ms"
                     f"{('  ' + attrs) if attrs else ''}")
        for kid in kids:
            walk(kid, depth + 1)

    for root in span_tree(spans):
        walk(root, 0)
    return "\n".join(lines)


# -- module-level singleton API -------------------------------------------

TRACER = Tracer()


def span(name: str, **attrs):
    """Open a span on the process tracer (see :meth:`Tracer.span`)."""
    return TRACER.span(name, **attrs)


def attach(parent: Span | None):
    """Re-parent this context under ``parent`` (cross-thread traces)."""
    return TRACER.attach(parent)


def current_span() -> Span | None:
    """The innermost open span of the calling context (or ``None``)."""
    return TRACER.current()


def annotate(**attrs) -> None:
    """Attach attributes to the current span; no-op without one."""
    sp = TRACER.current()
    if sp is not None:
        sp.attrs.update(attrs)


def finished(trace_id: str | None = None) -> list:
    return TRACER.finished(trace_id)


def add_sink(sink) -> None:
    TRACER.add_sink(sink)


def remove_sink(sink) -> None:
    TRACER.remove_sink(sink)


def reset() -> None:
    TRACER.reset()
