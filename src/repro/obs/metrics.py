"""One process-wide telemetry registry for the whole mapping stack.

Before this module the stack's counters were scattered: four
copy-pasted compile-cache stat/reset pairs (jax scorer, pallas scorer,
device partitioner, fused program), per-instance ``LRUCache.stats()``,
``MappingService.stats()``, ``BreakerBoard.states()`` and
``faults.stats()`` — no single call could answer "what is this process
doing".  :func:`snapshot` is that call.  Three provider groups feed it:

- **caches** — compile caches registered by
  :func:`instrument_compile_cache`, the registry-backed helper that
  replaced the copy-pasted ``*_cache_stats()`` / ``reset_*_cache()``
  quadruplet.  New caches auto-register by construction.
- **objects** — live ``.stats()``-bearing instances
  (:class:`repro.serve.MappingService`, ``LRUCache``) held by WEAK
  reference in bounded most-recent-first groups, so the registry never
  keeps test fixtures alive and never grows without bound.
- **providers** — named singletons (``faults.stats``).

On top of the adapters sits a plain named-series store: bounded,
lock-guarded :func:`counter` / :func:`gauge` / :func:`observe`
primitives for code that has no stats object to adapt (response
status counts, breaker trips, latency histograms).

Everything here is stdlib-only and import-light: the instrumented
modules import ``repro.obs``, never the reverse — :func:`snapshot`
lazily imports the known cache/fault modules so one call returns every
counter family even in a process that has not touched them yet.
"""

from __future__ import annotations

import math
import threading
import weakref
from collections import OrderedDict

# hard bounds: series/providers beyond these are dropped (counted in
# the snapshot's meta) rather than growing without limit
MAX_SERIES = 1024
MAX_OBJECTS_PER_GROUP = 64

# log2 histogram bucket upper bounds, seconds-flavoured: 1us .. ~64s
_BUCKET_BOUNDS = tuple(2.0 ** e for e in range(-20, 7))


class Histogram:
    """Fixed-bound log2 histogram: count/sum/min/max + bucket counts."""

    __slots__ = ("count", "total", "vmin", "vmax", "buckets")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.buckets = [0] * (len(_BUCKET_BOUNDS) + 1)

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        for i, bound in enumerate(_BUCKET_BOUNDS):
            if v <= bound:
                self.buckets[i] += 1
                return
        self.buckets[-1] += 1

    def to_dict(self) -> dict:
        out = {"count": self.count, "sum": self.total}
        if self.count:
            out["min"] = self.vmin
            out["max"] = self.vmax
            out["mean"] = self.total / self.count
        return out


class MetricsRegistry:
    """Bounded, lock-guarded named counters/gauges/histograms plus the
    three adapter groups (see module docstring)."""

    def __init__(self, max_series: int = MAX_SERIES,
                 max_objects: int = MAX_OBJECTS_PER_GROUP):
        self._lock = threading.Lock()
        self.max_series = max_series
        self.max_objects = max_objects
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, Histogram] = {}
        self._dropped = 0
        # name -> (stats_fn, reset_fn): compile caches are module-level
        # singletons, strong refs are correct and bounded by the code
        self._caches: "OrderedDict[str, tuple]" = OrderedDict()
        # group -> OrderedDict[id(obj) -> weakref]: most recent last
        self._objects: dict[str, OrderedDict] = {}
        self._providers: dict[str, object] = {}

    # -- primitive series -------------------------------------------------

    def _slot(self, table: dict, name: str, factory):
        """Existing series or a new one; None when over the cap."""
        got = table.get(name)
        if got is None:
            if (len(self._counters) + len(self._gauges)
                    + len(self._hists)) >= self.max_series:
                self._dropped += 1
                return None
            got = table[name] = factory()
        return got

    def counter(self, name: str, inc: float = 1) -> None:
        with self._lock:
            if self._slot(self._counters, name, float) is not None:
                self._counters[name] += inc

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            if self._slot(self._gauges, name, float) is not None:
                self._gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            h = self._slot(self._hists, name, Histogram)
            if h is not None:
                h.observe(value)

    # -- adapter groups ---------------------------------------------------

    def register_cache(self, name: str, stats_fn, reset_fn) -> None:
        with self._lock:
            self._caches[name] = (stats_fn, reset_fn)

    def register_object(self, group: str, obj) -> None:
        """Track ``obj`` (has ``.stats()``) weakly under ``group``."""
        with self._lock:
            od = self._objects.setdefault(group, OrderedDict())
            od[id(obj)] = weakref.ref(obj)
            self._prune_locked(od)

    def register_provider(self, name: str, fn) -> None:
        with self._lock:
            self._providers.setdefault(name, fn)

    def _prune_locked(self, od: OrderedDict) -> None:
        dead = [k for k, r in od.items() if r() is None]
        for k in dead:
            del od[k]
        while len(od) > self.max_objects:
            od.popitem(last=False)

    def cache_names(self) -> list:
        with self._lock:
            return list(self._caches)

    def reset_cache(self, name: str) -> None:
        with self._lock:
            reset = self._caches[name][1]
        reset()

    # -- snapshot ---------------------------------------------------------

    def snapshot(self) -> dict:
        """Everything, in one dict — see :func:`snapshot` below."""
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = {k: h.to_dict() for k, h in self._hists.items()}
            caches = list(self._caches.items())
            groups = {}
            for group, od in self._objects.items():
                self._prune_locked(od)
                groups[group] = [r() for r in od.values()]
            providers = dict(self._providers)
            dropped = self._dropped
        out = {
            "counters": counters, "gauges": gauges,
            "histograms": hists,
            "caches": {}, "meta": {"dropped_series": dropped},
        }
        for name, (stats_fn, _) in caches:
            try:
                out["caches"][name] = stats_fn()
            except Exception as e:  # a cache module mid-teardown
                out["caches"][name] = {"error": type(e).__name__}
        for group, objs in groups.items():
            section = {}
            for i, obj in enumerate(objs):
                if obj is None:
                    continue
                try:
                    section[f"{group[:-1] if group.endswith('s') else group}"
                            f"#{i}"] = obj.stats()
                except Exception as e:
                    section[f"{group}#{i}"] = {"error": type(e).__name__}
            out[group] = section
        for name, fn in providers.items():
            try:
                out[name] = fn()
            except Exception as e:
                out[name] = {"error": type(e).__name__}
        out["derived"] = _derived(out)
        return out

    def reset_series(self) -> None:
        """Zero the primitive series (adapters keep their own state)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._dropped = 0


def _rate(hit: float, total: float) -> float | None:
    return (hit / total) if total > 0 else None


def _derived(snap: dict) -> dict:
    """Cross-family rates: one place that answers "is the process
    healthy" without the caller summing counters by hand."""
    caches = snap.get("caches", {})
    chits = sum(c.get("hits", 0) for c in caches.values())
    cmiss = sum(c.get("misses", 0) for c in caches.values())
    lrus = snap.get("lrus", {}).values()
    lhits = sum(c.get("hits", 0) for c in lrus)
    lmiss = sum(c.get("misses", 0) for c in lrus)
    out = {
        "compile_cache_hit_rate": _rate(chits, chits + cmiss),
        "compiles": cmiss,
        "result_cache_hit_rate": _rate(lhits, lhits + lmiss),
    }
    services = snap.get("services", {}).values()
    if services:
        requests = sum(s.get("requests", 0) for s in services)
        shed = sum(s.get("shed", 0) for s in services)
        degraded = sum(s.get("degraded", 0) for s in services)
        cold = sum(s.get("cold", 0) for s in services)
        misses = sum(s.get("deadline_misses", 0) for s in services)
        out.update(
            requests=requests,
            availability=_rate(requests, requests + shed),
            degraded_ratio=_rate(degraded, max(cold, 1)),
            deadline_miss_ratio=_rate(misses, max(requests, 1)),
        )
    faults = snap.get("faults")
    if isinstance(faults, list):
        out["faults_fired"] = sum(s.get("fired", 0) for s in faults)
    return out


# -- module-level singleton API -------------------------------------------

REGISTRY = MetricsRegistry()

counter = REGISTRY.counter
gauge = REGISTRY.gauge
observe = REGISTRY.observe
register_cache = REGISTRY.register_cache
register_object = REGISTRY.register_object
register_provider = REGISTRY.register_provider


def instrument_compile_cache(name: str, cached_fn):
    """The registry-backed replacement for the copy-pasted
    ``*_cache_stats()`` / ``reset_*_cache()`` quadruplet.

    ``cached_fn`` is a ``functools.lru_cache``-wrapped callable whose
    hit/miss counters are a truthful compile-count proxy (each entry
    sees exactly one input shape — see the call sites).  Returns
    ``(stats_fn, reset_fn)`` with the legacy contract — ``stats_fn()``
    is ``{"hits", "misses", "entries"}``, ``reset_fn()`` clears entries
    and zeroes the counters — and registers the pair under ``name`` so
    the cache appears in :func:`snapshot` with no further wiring.
    """

    def stats_fn() -> dict:
        info = cached_fn.cache_info()
        return {"hits": int(info.hits), "misses": int(info.misses),
                "entries": int(info.currsize)}

    def reset_fn() -> None:
        cached_fn.cache_clear()

    stats_fn.__name__ = f"{name}_cache_stats"
    reset_fn.__name__ = f"reset_{name}_cache"
    REGISTRY.register_cache(name, stats_fn, reset_fn)
    return stats_fn, reset_fn


_ENSURED = False
_ENSURE_LOCK = threading.Lock()

# modules whose import registers a compile cache (instrument_compile_
# cache at module level); jax-less processes skip the device ones
_CACHE_MODULES = (
    "repro.core.metrics_jax",
    "repro.core.partition_jax",
    "repro.kernels.mapscore.ops",
    "repro.mapping.fused",
)


def _ensure_providers() -> None:
    """Best-effort import of every known counter-family module, so ONE
    :func:`snapshot` call covers the whole stack even in a process that
    has not exercised it yet.  Failures (no jax in the container) leave
    that family absent rather than erroring the snapshot."""
    global _ENSURED
    with _ENSURE_LOCK:
        if _ENSURED:
            return
        import importlib
        for mod in _CACHE_MODULES:
            try:
                importlib.import_module(mod)
            except Exception:
                pass
        try:
            from repro import faults
            REGISTRY.register_provider("faults", faults.stats)
        except Exception:  # pragma: no cover - faults is stdlib-only
            pass
        _ENSURED = True


def snapshot() -> dict:
    """The whole process's telemetry, one call:

    ``counters`` / ``gauges`` / ``histograms``
        the primitive series (response statuses, breaker trips,
        latency distributions).
    ``caches``
        every registered compile cache's ``{hits, misses, entries}`` —
        values identical to the legacy per-module accessors
        (``scorer_cache_stats`` et al.), which are now thin aliases of
        the same closures.
    ``services`` / ``lrus``
        ``.stats()`` of the live (weakly-tracked) service and
        result-cache instances.
    ``faults``
        the armed fault specs' ``calls``/``fired`` counters.
    ``derived``
        cross-family rates: compile/result cache hit-rates,
        availability, degraded and deadline-miss ratios.
    """
    _ensure_providers()
    return REGISTRY.snapshot()


def span_rollup(spans) -> dict:
    """Aggregate spans by name: ``{name: {count, total_s, max_s}}`` —
    the per-phase rollup ``benchmarks/run.py --json`` records."""
    out: dict = {}
    for s in spans:
        agg = out.setdefault(s.name, {"count": 0, "total_s": 0.0,
                                      "max_s": 0.0})
        d = s.duration_s
        agg["count"] += 1
        agg["total_s"] += d
        agg["max_s"] = max(agg["max_s"], d)
    return out
