"""repro.obs — unified tracing + metrics for the whole mapping stack.

One dependency-free observability substrate (ISSUE 8), three parts:

- :mod:`repro.obs.trace` — request-scoped SPANS: thread-safe context
  managers over monotonic clocks, nested parent/child structure, one
  trace id per service request, propagated through every degradation
  rung, pipeline stage and backend call site.
- :mod:`repro.obs.metrics` — the process-wide telemetry REGISTRY:
  bounded named counters/gauges/histograms plus adapters absorbing the
  stack's scattered counters (compile caches, LRUs, services,
  breakers, faults) into one :func:`snapshot`.
- :mod:`repro.obs.export` — JSONL span logs (``REPRO_TRACE=path``),
  Chrome trace-event JSON for Perfetto, Prometheus text exposition,
  and an optional ``jax.profiler`` bridge (``REPRO_JAX_PROFILE=dir``).

This package imports only the stdlib; the instrumented modules import
it, never the reverse, so it is safe at the bottom of every layer.
"""

from .export import (JsonlSink, chrome_trace, install_env_sink,
                     jax_profile, prometheus_text, read_jsonl,
                     write_chrome_trace)
from .metrics import (REGISTRY, counter, gauge, instrument_compile_cache,
                      observe, register_cache, register_object,
                      register_provider, snapshot, span_rollup)
from .trace import (TRACER, Span, Tracer, add_sink, annotate, attach,
                    current_span, finished, format_tree, remove_sink,
                    reset, span, span_tree)

# arm the process-wide JSONL event log when REPRO_TRACE names a path
_ENV_SINK = install_env_sink()

__all__ = [
    "JsonlSink", "REGISTRY", "Span", "TRACER", "Tracer", "add_sink",
    "annotate", "attach", "chrome_trace", "counter", "current_span",
    "finished", "format_tree", "gauge", "install_env_sink",
    "instrument_compile_cache", "jax_profile", "observe",
    "prometheus_text", "read_jsonl", "register_cache",
    "register_object", "register_provider", "remove_sink", "reset",
    "snapshot", "span", "span_rollup", "span_tree",
    "write_chrome_trace",
]
