"""Exporters: JSONL span log, Chrome trace events, Prometheus text.

Three consumption paths for the spans/snapshot the rest of
:mod:`repro.obs` collects, all dependency-free:

- :class:`JsonlSink` — an append-only JSON-lines event log, one object
  per finished span.  Armed process-wide by the ``REPRO_TRACE=path``
  environment variable (checked once at ``repro.obs`` import); the CI
  chaos job parses the emitted file and asserts the degraded-rung
  spans are present.
- :func:`chrome_trace` — the Chrome trace-event JSON format
  (``"X"``-phase complete events), loadable in Perfetto / DevTools
  for a flame view of a request.
- :func:`prometheus_text` — text exposition of :func:`obs.snapshot`
  for scrape-style collection.

Plus an optional accelerator bridge: :func:`jax_profile` wraps a block
in ``jax.profiler.trace`` when ``REPRO_JAX_PROFILE=dir`` is set (and
jax is importable), so device-level traces land next to the host spans
without the serving stack importing jax itself.
"""

from __future__ import annotations

import contextlib
import json
import os
import re
import threading

from . import metrics as _metrics
from . import trace as _trace

TRACE_ENV = "REPRO_TRACE"
JAX_PROFILE_ENV = "REPRO_JAX_PROFILE"


class JsonlSink:
    """Span sink appending one JSON object per line to ``path``.

    Thread-safe; the file opens lazily on the first span and flushes
    per write so a crashed process still leaves a parseable log.
    """

    def __init__(self, path: str):
        self.path = path
        self._lock = threading.Lock()
        self._fh = None

    def __call__(self, span) -> None:
        line = json.dumps(span.to_dict(), sort_keys=True,
                          default=str)
        with self._lock:
            if self._fh is None:
                self._fh = open(self.path, "a", encoding="utf-8")
            self._fh.write(line + "\n")
            self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.close()
                self._fh = None


def install_env_sink(tracer=None) -> JsonlSink | None:
    """Arm a :class:`JsonlSink` from ``REPRO_TRACE`` (None when unset).

    Called once by ``repro.obs`` at import; callers wanting a second
    log (or a log after changing the env) call it again themselves.
    """
    path = os.environ.get(TRACE_ENV)
    if not path:
        return None
    sink = JsonlSink(path)
    (tracer or _trace.TRACER).add_sink(sink)
    return sink


def read_jsonl(path: str) -> list:
    """Parse a JSONL span log back into dicts (the CI validation)."""
    out = []
    with open(path, encoding="utf-8") as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# -- Chrome trace-event format --------------------------------------------

def chrome_trace(spans=None) -> dict:
    """Spans as a Chrome trace-event document (Perfetto-viewable).

    ``spans`` defaults to the tracer's finished ring.  Timestamps are
    the spans' monotonic starts in microseconds (one shared origin per
    process); each trace id becomes a distinct ``pid`` row so requests
    separate visually, threads map to ``tid``.
    """
    spans = _trace.finished() if spans is None else list(spans)
    pids: dict = {}
    events = []
    for s in spans:
        pid = pids.setdefault(s.trace_id, len(pids))
        events.append({
            "ph": "X", "name": s.name,
            "ts": s.t0 * 1e6, "dur": s.duration_s * 1e6,
            "pid": pid, "tid": s.thread,
            "args": {"trace_id": s.trace_id,
                     "span_id": s.span_id,
                     "parent_id": s.parent_id, **s.attrs},
        })
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "otherData": {
            "producer": "repro.obs",
            "traces": {tid: pid for tid, pid in pids.items()},
        },
    }


def write_chrome_trace(path: str, spans=None) -> dict:
    doc = chrome_trace(spans)
    with open(path, "w", encoding="utf-8") as f:
        json.dump(doc, f)
    return doc


# -- Prometheus text exposition -------------------------------------------

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def _metric_name(name: str) -> str:
    return "repro_" + _NAME_RE.sub("_", name)


def _fmt(value) -> str:
    if value is None:
        return "NaN"
    if isinstance(value, bool):
        return str(int(value))
    return repr(float(value))


def prometheus_text(snap: dict | None = None) -> str:
    """The snapshot in Prometheus text exposition format (0.0.4).

    Scalar counters/gauges become plain series, histograms expose
    ``_count``/``_sum``, compile caches and LRUs become labelled
    families (``repro_compile_cache_hits{cache="fused"}``), service
    stats flatten to labelled scalars, and every derived rate is a
    gauge.  Nested non-scalar stats (breaker states, rung breakdowns)
    are skipped — traces, not scrapes, carry structure.
    """
    snap = _metrics.snapshot() if snap is None else snap
    # one (kind, samples) family per metric name: the exposition format
    # allows each name exactly one TYPE line
    families: dict = {}

    def add(name, kind, labels, value):
        fam = families.setdefault(name, (kind, []))
        fam[1].append((labels, value))

    for key, val in sorted(snap.get("counters", {}).items()):
        add(_metric_name(key + "_total"), "counter", {}, val)
    for key, val in sorted(snap.get("gauges", {}).items()):
        add(_metric_name(key), "gauge", {}, val)
    for key, h in sorted(snap.get("histograms", {}).items()):
        base = _metric_name(key)
        add(base + "_count", "counter", {}, h.get("count", 0))
        add(base + "_sum", "counter", {}, h.get("sum", 0.0))
    for name, stats in sorted(snap.get("caches", {}).items()):
        for field in ("hits", "misses", "entries"):
            add(f"repro_compile_cache_{field}", "counter",
                {"cache": name}, stats.get(field, 0))
    for group in ("lrus", "services"):
        for name, stats in sorted(snap.get(group, {}).items()):
            for field, value in sorted(stats.items()):
                if isinstance(value, (bool, int, float)):
                    add(_metric_name(f"{group}_{field}"), "gauge",
                        {"instance": name}, value)
    for key, val in sorted(snap.get("derived", {}).items()):
        if isinstance(val, (bool, int, float)) or val is None:
            add(_metric_name("derived_" + key), "gauge", {}, val)

    lines = []
    for name, (kind, samples) in families.items():
        lines.append(f"# TYPE {name} {kind}")
        for labels, value in samples:
            lab = ("{" + ",".join(f'{k}="{v}"'
                                  for k, v in labels.items()) + "}"
                   if labels else "")
            lines.append(f"{name}{lab} {_fmt(value)}")
    return "\n".join(lines) + "\n"


# -- optional jax profiler bridge -----------------------------------------

@contextlib.contextmanager
def jax_profile(label: str = "repro"):
    """``jax.profiler.trace`` around a block when ``REPRO_JAX_PROFILE``
    names a directory (created if missing); a silent no-op otherwise or
    when jax is unavailable — host-only processes pay nothing."""
    outdir = os.environ.get(JAX_PROFILE_ENV)
    if not outdir:
        yield None
        return
    try:
        import jax
    except Exception:
        yield None
        return
    os.makedirs(outdir, exist_ok=True)
    with jax.profiler.trace(outdir):
        with _trace.span("jax.profile", label=label, outdir=outdir):
            yield outdir
