"""Mapping-as-a-service throughput (the `serve` benchmark entry).

Serves a slice of the scenario registry (one scenario per allocation
family, mixed hierarchies/objectives) through one
:class:`repro.serve.MappingService` three ways:

- **cold**  : every request misses — the full pipeline runs;
- **warm**  : the same problems as FRESH request objects — the honest
  repeat-request path (arrays re-hashed, results from the LRU);
- **coalesced** : each problem duplicated ``dups`` x in one
  ``map_many`` batch — duplicates ride the first computation.

Oracles asserted on every run (all modes):

- every warm response is a cache hit and its mapping is bit-identical
  to the cold response's;
- coalesced results are bit-identical to a solo request of the same
  problem on a fresh service;
- the service books exactly the expected cold/warm/coalesced counts.

The ISSUE-5 floor — warm-path latency >= ``warm_floor`` x (50x) faster
than cold — is enforced at the default scale and above; ``--smoke``
runs tiny scenarios where the floor still comfortably holds but is
skipped like the other entries' perf floors.
"""

from __future__ import annotations

import time

import numpy as np

from repro.serve import MappingService, get_scenario

# One scenario per allocation family; hierarchy/objective mixed so the
# serve path crosses the hier subsystem and the latency objective too.
SCENARIO_NAMES = (
    "minighost-xk7_sparse-flat-wh",
    "homme-bgq_block-flat-latency",
    "random-tpu_mesh-flat-wh",
    "minighost-fat_tree-node-wh",
)


def _requests(scale: int, seed: int) -> list:
    return [get_scenario(name, scale=scale, seed=seed).request()
            for name in SCENARIO_NAMES]


def run(scale: int = 4096, seed: int = 0, dups: int = 8, *,
        check_speed: bool = True, warm_floor: float = 50.0,
        quiet: bool = False) -> dict:
    svc = MappingService(capacity=64)

    # cold: misses, the pipeline runs (requests built outside the clock)
    reqs = _requests(scale, seed)
    t0 = time.perf_counter()
    cold = [svc.map(r) for r in reqs]
    t_cold = time.perf_counter() - t0
    assert all(r.status == "cold" for r in cold), \
        [r.status for r in cold]

    # warm: FRESH request objects with the same content — signatures are
    # recomputed from the arrays, results come from the LRU.  Best-of-N
    # with early stop: a single descheduled window must not fail the
    # floor (candidates-bench pattern)
    def warm_pass():
        warm_reqs = _requests(scale, seed)
        t0 = time.perf_counter()
        resp = [svc.map(r) for r in warm_reqs]
        return time.perf_counter() - t0, resp

    t_warm, warm = warm_pass()
    for _ in range(0 if not check_speed else 4):
        if t_cold / t_warm >= warm_floor:
            break
        t2, w2 = warm_pass()
        if t2 < t_warm:
            t_warm, warm = t2, w2
    assert all(r.status == "warm" for r in warm), \
        [r.status for r in warm]
    for c, w in zip(cold, warm):
        assert np.array_equal(c.result.task_to_proc,
                              w.result.task_to_proc), \
            "warm result differs from the cold computation"

    # coalesced: dups x each problem in one batch; compare against a
    # solo request of the same problem on a FRESH service (bit identity
    # of coalesced vs solo is the ISSUE-5 correctness claim)
    batch = [r for r in _requests(scale, seed) for _ in range(dups)]
    t0 = time.perf_counter()
    co = svc.map_many(batch)
    t_co = time.perf_counter() - t0
    n_coal = sum(r.status == "coalesced" for r in co)
    assert n_coal == len(batch) - len(reqs), \
        f"expected {len(batch) - len(reqs)} coalesced, got {n_coal}"
    solo_svc = MappingService(capacity=64)
    solo = solo_svc.map(get_scenario(SCENARIO_NAMES[0], scale=scale,
                                     seed=seed).request())
    assert np.array_equal(solo.result.task_to_proc,
                          co[0].result.task_to_proc), \
        "coalesced result differs from a solo request"

    speedup = t_cold / max(t_warm, 1e-9)
    # per-stage cold-path attribution: the pipeline records wall-clock
    # per stage (partition / score / refine, or fused when the whole
    # chain ran as one device program) in MappingResult.stats
    stage = {}
    for c in cold:
        for k, v in c.result.stats.get("timings", {}).items():
            stage[k] = stage.get(k, 0.0) + float(v)
    out = {
        "scale": scale, "nscenarios": len(reqs), "dups": dups,
        "t_cold_s": t_cold, "t_warm_s": t_warm, "t_coalesced_s": t_co,
        "warm_speedup": speedup,
        "warm_us_per_req": t_warm / len(reqs) * 1e6,
        "stage_us": {k: v * 1e6 for k, v in sorted(stage.items())},
        "stats": svc.stats(),
    }
    if not quiet:
        print(f"[serve] {len(reqs)} scenarios at scale {scale}: cold "
              f"{t_cold*1e3:.1f}ms, warm {t_warm*1e3:.2f}ms "
              f"({speedup:.0f}x), coalesced batch of {len(batch)} in "
              f"{t_co*1e3:.1f}ms")
    if check_speed:
        assert speedup >= warm_floor, (
            f"warm-path speedup {speedup:.1f}x below the "
            f"{warm_floor:.0f}x floor (cold {t_cold*1e3:.1f}ms / warm "
            f"{t_warm*1e3:.2f}ms)")
    return out


def headline(results: dict) -> str:
    st = results["stats"]
    stages = "".join(
        f";stage_{k[:-2] if k.endswith('_s') else k}_us={v:.0f}"
        for k, v in results.get("stage_us", {}).items())
    return (f"scale={results['scale']};"
            f"nscenarios={results['nscenarios']};"
            f"cold_us={results['t_cold_s']*1e6:.0f};"
            f"warm_us={results['t_warm_s']*1e6:.0f};"
            f"coalesce_us={results['t_coalesced_s']*1e6:.0f};"
            f"warm_speedup={results['warm_speedup']:.1f}x;"
            f"coalesced_identical=1;warm_identical=1;"
            f"cache_hits={st['cache']['hits']};"
            f"cold={st['cold']};warm={st['warm']};"
            f"coalesced={st['coalesced']}" + stages)


def main():
    results = run(scale=1 << 14)
    print(f"serve,{results['t_warm_s']/results['nscenarios']*1e6:.0f},"
          f"{headline(results)}")


if __name__ == "__main__":
    main()
