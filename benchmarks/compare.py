"""Benchmark-regression gate: diff a ``run.py --json`` output against a
committed baseline and FAIL on regression (ISSUE 5).

Usage (the CI ``bench-guards`` job):

    python benchmarks/run.py --json bench-results.json
    python benchmarks/compare.py bench-results.json \
        --baseline benchmarks/baseline.json

    # regenerate the baseline after an intentional change:
    python benchmarks/compare.py bench-results.json \
        --write-baseline benchmarks/baseline.json

What is compared is declared per benchmark in :data:`POLICY`, in three
classes:

- ``exact``   : metric ORACLES — winner/identity/monotonicity flags the
                benchmarks compute deterministically.  Any drift fails.
- ``near``    : quality metrics (mapping-quality ratios).  Deterministic
                on one host, but allowed ``rtol`` relative drift so a
                numpy/BLAS version bump does not false-positive.
- ``min_ratio``: speedup ratios (higher is better).  The current value
                must stay >= ``frac`` x baseline — a loose floor that
                catches real regressions (a batched path silently
                falling back to a loop) while tolerating runner noise.
- ``max_value``: absolute ceilings (lower is better), checked against
                the POLICY bound itself rather than the baseline — the
                overhead oracle: the traced end-to-end cold path must
                stay within 2% of the untraced one (ISSUE 8).

Timing fields (``us_per_call``, ``*_us``) are never compared — wall
clocks differ per host; the ratios already normalise them.

The gate also fails when a baseline benchmark is missing from the
current run, when a current record is not ``ok``, or when the run MODE
(smoke/default/full) differs from the baseline's — cross-mode ratio
comparisons are meaningless.
"""

from __future__ import annotations

import argparse
import json
import sys

# benchmark name -> comparison classes (keys are ``derived`` fields)
POLICY = {
    "partition": {"min_ratio": {"best": 0.5}},
    "candidates": {"exact": ["winner_identical", "winner"],
                   "min_ratio": {"speedup": 0.5}},
    "mapscore": {"exact": ["winner_identical"]},
    "end2end": {"exact": ["winner_identical"],
                "min_ratio": {"speedup": 0.5},
                "max_value": {"trace_overhead": 1.02}},
    "fused_h": {"exact": ["winner_identical", "refine_identical",
                          "refine_monotone", "compile_once",
                          "fused_compiles", "refine_rounds",
                          "refine_accepted"]},
    "serve": {"exact": ["coalesced_identical", "warm_identical"],
              "min_ratio": {"warm_speedup": 0.5}},
    "faults": {"exact": ["failed", "degraded_all", "bijection_ok",
                         "identical", "healthy_fused_identical",
                         "availability", "breakers_open"],
               "near": {"quality_worst": 0.05}},
    "hier": {"exact": ["refine_monotone"],
             "near": {"wh_ratio": 0.05, "wh_ratio_sparse": 0.05,
                      "points_ratio": 0.02, "wh_ratio_d3": 0.05,
                      "wh_ratio_d3_sparse": 0.05, "points_ratio_d3": 0.02,
                      "points_ratio_d4": 0.02},
             "min_ratio": {"flat_vs_hier": 0.5, "d3_vs_d2": 0.5}},
    "table1_orderings": {"exact": ["rows"],
                         "near": {"max_rel_err_vs_paper_ZFZMFZ": 0.10}},
    "minighost": {"near": {"lat_red_vs_default": 0.10,
                           "geo_growth": 0.10}},
    "homme_bgq": {"near": {"best_data_vs_sfc": 0.10}},
    "homme_titan": {"near": {"z2_2_wh_vs_sfc": 0.10,
                             "z2_2_lat_vs_sfc": 0.25}},
}

_TIMING_SUFFIXES = ("_us", "_s")


def _mode(doc: dict) -> str:
    if doc.get("full"):
        return "full"
    if doc.get("smoke"):
        return "smoke"
    return "default"


def _by_name(doc: dict) -> dict:
    """records list (run.py output) OR baseline mapping -> {name: rec}."""
    bench = doc.get("benchmarks", doc)
    if isinstance(bench, dict):
        return bench
    return {r["name"]: r for r in bench}


def compare(current: dict, baseline: dict) -> list[str]:
    """All regression problems of ``current`` vs ``baseline`` (empty =
    gate passes)."""
    problems = []
    if _mode(current) != _mode(baseline):
        return [f"run mode {_mode(current)!r} != baseline mode "
                f"{_mode(baseline)!r}; compare like against like"]
    cur = _by_name(current)
    base = _by_name(baseline)
    for name, brec in sorted(base.items()):
        crec = cur.get(name)
        if crec is None:
            problems.append(f"{name}: missing from the current run")
            continue
        if not crec.get("ok", False):
            problems.append(
                f"{name}: current run failed: "
                f"{crec.get('error', 'ok=false')}")
            continue
        policy = POLICY.get(name, {})
        cd = crec.get("derived", {})
        bd = brec.get("derived", {})
        for key in policy.get("exact", []):
            if key not in bd:
                continue  # baseline predates the field
            if key not in cd:
                problems.append(f"{name}: oracle field {key!r} missing")
            elif cd[key] != bd[key]:
                problems.append(
                    f"{name}: oracle {key} changed: "
                    f"{bd[key]!r} -> {cd[key]!r}")
        for key, rtol in policy.get("near", {}).items():
            if key not in bd:
                continue
            if key not in cd:
                problems.append(f"{name}: metric field {key!r} missing")
                continue
            b, c = float(bd[key]), float(cd[key])
            if abs(c - b) > rtol * max(abs(b), 1e-12):
                problems.append(
                    f"{name}: {key} drifted beyond {rtol:.0%}: "
                    f"{b:.6g} -> {c:.6g}")
        for key, frac in policy.get("min_ratio", {}).items():
            if key not in bd:
                continue
            if key not in cd:
                problems.append(f"{name}: ratio field {key!r} missing")
                continue
            b, c = float(bd[key]), float(cd[key])
            if c < frac * b:
                problems.append(
                    f"{name}: {key} regressed below {frac:.0%} of "
                    f"baseline: {b:.3g} -> {c:.3g}")
        for key, ceiling in policy.get("max_value", {}).items():
            if key not in cd:
                problems.append(f"{name}: bounded field {key!r} missing")
                continue
            c = float(cd[key])
            if c > ceiling:
                problems.append(
                    f"{name}: {key} {c:.4g} exceeds the {ceiling:g} "
                    f"ceiling")
    return problems


def make_baseline(current: dict) -> dict:
    """Strip a run into a committed baseline: names, ok flags and
    non-timing derived fields (timings never gate)."""
    out = {}
    for name, rec in sorted(_by_name(current).items()):
        derived = {
            k: v for k, v in rec.get("derived", {}).items()
            if not any(k.endswith(sfx) for sfx in _TIMING_SUFFIXES)
        }
        out[name] = {"ok": bool(rec.get("ok", False)), "derived": derived}
    return {"benchmarks": out, "full": bool(current.get("full")),
            "smoke": bool(current.get("smoke"))}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="run.py --json output to check")
    ap.add_argument("--baseline", default="benchmarks/baseline.json")
    ap.add_argument("--write-baseline", default=None, metavar="PATH",
                    help="write PATH from the current run and exit")
    args = ap.parse_args(argv)
    with open(args.current) as f:
        current = json.load(f)
    if args.write_baseline:
        with open(args.write_baseline, "w") as f:
            json.dump(make_baseline(current), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"[compare] wrote baseline {args.write_baseline}")
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)
    problems = compare(current, baseline)
    for p in problems:
        print(f"[compare] REGRESSION: {p}")
    if problems:
        print(f"[compare] FAIL: {len(problems)} regression(s) vs "
              f"{args.baseline}")
        return 1
    n = len(_by_name(baseline))
    print(f"[compare] OK: {n} benchmark(s) within policy vs "
          f"{args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
