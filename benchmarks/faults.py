"""Resilience under injected faults (the `faults` benchmark entry,
ISSUE 7).

Replays the standard single-fault schedules against a fresh
:class:`repro.serve.MappingService` each — scorer compile failure,
device OOM, device-partition failure, a hung stage under a request
deadline, and a cache-eviction storm — and asserts the availability and
quality oracles of the degradation ladder:

- **availability = 1**: every submitted request is served; no schedule
  surfaces an error to the caller (``failed = 0``).
- **degraded_all = 1**: every schedule that CAN degrade (a device rung
  exists to shed) served at least one request on exactly the expected
  ladder rung, recorded in ``MappingResult.stats["degraded"]``.
- **bijection_ok / identical = 1**: every served mapping is a valid
  task->processor bijection, and every degraded result is bit-identical
  to the healthy (no-fault) result of the same request — the ladder's
  backend rungs only move WHERE the algorithm runs.
- **quality_worst**: the worst relative objective-score drift of any
  degraded result vs its healthy reference (0.0 when bit-identity
  holds, bounded by the documented 5% ``refine_0`` bound otherwise).
- **healthy_fused_identical = 1**: with nothing injected, the service
  returns exactly the direct pipeline result (the PR 6 fused program
  where eligible) and no breaker ever opens.

The whole run executes inside ``faults.isolated()`` so an ambient
``REPRO_FAULTS`` schedule (the CI chaos job) cannot perturb the exact
counts; each schedule's specs are installed programmatically.

Without jax the device schedules have no rung to shed and are skipped
(``degraded_all`` is then vacuously 1); the host-path schedules (slow
stage, eviction storm) still run.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro import faults
from repro.mapping import shared_pipeline
from repro.serve import MappingService, get_scenario

BASE = "minighost-xk7_sparse-flat-wh"
SEEDS = (0, 1)

# the slow-stage schedule: the first rung hangs DELAY seconds, well past
# the service deadline; the terminal host rung then serves the request
SLOW_DELAY = 1.0
DEADLINE_S = 0.25


def _has_jax() -> bool:
    from repro.core.orderings import resolve_partition_backend
    return resolve_partition_backend("jax") == "jax"


def _schedules(jax: bool) -> list[dict]:
    """The standard single-fault schedules: (name, config overrides,
    fault specs, service kwargs, expected ladder rung)."""
    out = []
    if jax:
        out += [
            dict(name="scorer_compile",
                 cfg=dict(score_backend="jax", partition_backend="numpy"),
                 specs=[("score.jax", "compile", {})],
                 svc={}, rung="score_numpy"),
            dict(name="device_oom",
                 cfg=dict(score_backend="pallas",
                          partition_backend="numpy"),
                 specs=[("score.pallas", "oom", {}),
                        ("score.jax", "oom", {})],
                 svc={}, rung="score_numpy"),
            dict(name="partition_fault",
                 cfg=dict(score_backend="numpy", partition_backend="jax"),
                 specs=[("partition.jax", "error", {})],
                 svc={}, rung="partition_numpy"),
            dict(name="slow_deadline",
                 cfg=dict(score_backend="jax", partition_backend="numpy"),
                 specs=[("serve.compute", "slow",
                         {"delay": SLOW_DELAY, "count": 1})],
                 svc=dict(deadline_s=DEADLINE_S), rung="score_numpy"),
        ]
    out.append(
        dict(name="cache_storm", cfg={},
             specs=[("serve.cache", "evict", {"after": 1, "count": 1})],
             svc={}, rung=None))
    return out


def _requests(scale: int, cfg_overrides: dict, rotations: int = 4):
    reqs = []
    for seed in SEEDS:
        sc = get_scenario(BASE, scale=scale, seed=seed)
        cfg = dataclasses.replace(sc.config(), rotations=rotations,
                                  **cfg_overrides)
        reqs.append(dataclasses.replace(sc.request(), config=cfg,
                                        _signature=None))
    return reqs


def _bijection_ok(result) -> bool:
    t2p = np.asarray(result.task_to_proc)
    return bool(np.array_equal(np.sort(t2p), np.arange(len(t2p))))


def _quality(degraded, healthy) -> float:
    """Relative objective drift of a degraded result vs healthy (0.0
    under bit-identity; score-based when the permutations differ)."""
    if np.array_equal(degraded.task_to_proc, healthy.task_to_proc):
        return 0.0
    d, h = degraded.score, healthy.score
    if (isinstance(d, float) and isinstance(h, float)
            and np.isfinite(d) and np.isfinite(h)):
        return abs(d - h) / max(abs(h), 1e-12)
    return 1.0  # scores incomparable and permutations differ: worst case


def run(scale: int = 4096, *, quiet: bool = False) -> dict:
    jax = _has_jax()
    schedules = _schedules(jax)
    with faults.isolated():
        # healthy references: the no-fault result of every (seed,
        # config) pair, computed ONCE through the shared pipeline pool
        healthy: dict[tuple, object] = {}

        def reference(sched, req, seed):
            key = (sched["name"], seed)
            if key not in healthy:
                healthy[key] = shared_pipeline(req.config).map(
                    req.graph, req.alloc)
            return healthy[key]

        submitted = served = failed = degraded = 0
        bijection_ok = identical = True
        degraded_all = True
        quality_worst = 0.0
        deadline_misses = storms = 0
        t0 = time.perf_counter()
        per_schedule = {}
        for sched in schedules:
            svc = MappingService(**sched["svc"])
            specs = [faults.install(site, kind, **opts)
                     for site, kind, opts in sched["specs"]]
            reqs = _requests(scale, sched["cfg"])
            responses = []
            try:
                for seed, req in zip(SEEDS, reqs):
                    submitted += 1
                    try:
                        responses.append((seed, req, svc.map(req)))
                    except Exception:  # noqa: BLE001 - the oracle itself
                        failed += 1
            finally:
                for spec in specs:
                    faults.remove(spec)
            # oracles run with the schedule's specs REMOVED, so the
            # healthy references cannot themselves hit the fault
            hits = 0
            for seed, req, resp in responses:
                served += 1
                ref = reference(sched, req, seed)
                bijection_ok &= _bijection_ok(resp.result)
                q = _quality(resp.result, ref)
                quality_worst = max(quality_worst, q)
                identical &= q == 0.0
                rung = resp.result.stats.get("degraded")
                if rung is not None:
                    degraded += 1
                    hits += rung == sched["rung"]
            st = svc.stats()
            deadline_misses += st["deadline_misses"]
            storms += st["cache"]["storms"]
            if sched["rung"] is not None:
                degraded_all &= hits >= 1
            per_schedule[sched["name"]] = {
                "degraded": st["degraded"], "expected_rung_hits": hits,
                "rung_failures": st["rung_failures"]}
        t_faulted = time.perf_counter() - t0

        # healthy pass: no faults, fresh service — bit-identical to the
        # direct pipeline (fused where eligible) and breakers stay shut
        hcfg = (dict(score_backend="jax", partition_backend="jax")
                if jax else {})
        svc = MappingService()
        healthy_identical = True
        breakers_open = 0
        t0 = time.perf_counter()
        for req in _requests(scale, hcfg):
            resp = svc.map(req)
            direct = shared_pipeline(req.config).map(req.graph, req.alloc)
            healthy_identical &= bool(np.array_equal(
                resp.result.task_to_proc, direct.task_to_proc))
            healthy_identical &= "degraded" not in resp.result.stats
        t_healthy = time.perf_counter() - t0
        st = svc.stats()
        breakers_open = sum(v["state"] != "closed" or v["opens"] > 0
                            for v in st["breakers"].values())

    availability = served / max(submitted, 1)
    out = {
        "scale": scale, "jax": int(jax),
        "nschedules": len(schedules), "submitted": submitted,
        "served": served, "failed": failed, "degraded": degraded,
        "degraded_all": int(degraded_all),
        "availability": availability,
        "bijection_ok": int(bijection_ok), "identical": int(identical),
        "quality_worst": quality_worst,
        "deadline_misses": deadline_misses, "storms": storms,
        "healthy_fused_identical": int(healthy_identical),
        "breakers_open": breakers_open,
        "t_faulted_s": t_faulted, "t_healthy_s": t_healthy,
        "per_schedule": per_schedule,
    }
    if not quiet:
        print(f"[faults] {len(schedules)} schedules x {len(SEEDS)} "
              f"requests at scale {scale}: {served}/{submitted} served "
              f"({failed} failed), {degraded} degraded, worst quality "
              f"drift {quality_worst:.4f}, faulted {t_faulted*1e3:.0f}ms"
              f" / healthy {t_healthy*1e3:.0f}ms")
    assert failed == 0, f"{failed} requests surfaced errors"
    assert bijection_ok, "a served mapping was not a bijection"
    assert degraded_all, f"expected rung missed: {per_schedule}"
    assert healthy_identical, "healthy path diverged from the pipeline"
    assert breakers_open == 0, st["breakers"]
    assert quality_worst <= 0.05, (
        f"degraded quality drift {quality_worst:.4f} above the "
        f"documented 5% refine_0 bound")
    return out


def headline(results: dict) -> str:
    return (f"scale={results['scale']};"
            f"nschedules={results['nschedules']};"
            f"submitted={results['submitted']};"
            f"failed={results['failed']};"
            f"availability={results['availability']:.2f};"
            f"degraded={results['degraded']};"
            f"degraded_all={results['degraded_all']};"
            f"bijection_ok={results['bijection_ok']};"
            f"identical={results['identical']};"
            f"quality_worst={results['quality_worst']:.4f};"
            f"deadline_misses={results['deadline_misses']};"
            f"storms={results['storms']};"
            f"healthy_fused_identical={results['healthy_fused_identical']};"
            f"breakers_open={results['breakers_open']};"
            f"faulted_us={results['t_faulted_s']*1e6:.0f};"
            f"healthy_us={results['t_healthy_s']*1e6:.0f}")


def main():
    results = run(scale=1 << 14)
    print(f"faults,{results['t_faulted_s']*1e6:.0f},{headline(results)}")


if __name__ == "__main__":
    main()
