"""Roofline analysis (deliverable g) from the dry-run artifacts.

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (spec constants).

Terms, all in seconds per step, computed from the PER-DEVICE compiled
SPMD module (trip-count-scaled; see repro/launch/hlo_cost.py):

  compute    = flops_per_device / 197e12
  memory     = hbm_bytes_per_device / 819e9
  collective = collective_bytes_per_device / 50e9

MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (prefill) /
2*N_active*B (decode, one token per sequence), N_active = parameters
touched per token (MoE counts k/E of expert weights).

flops_ratio = MODEL_FLOPS / HLO_FLOPs — how much compiled compute is
"useful" (catches remat recompute, attention score FLOPs, MoE dispatch
overhead).  roofline_fraction = ideal compute time (MODEL_FLOPS at peak)
/ max(term) — the MFU upper bound implied by the compiled program.
"""

from __future__ import annotations

import glob
import json
import os
import time

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ART = os.path.join(os.path.dirname(__file__), "artifacts", "dryrun")

SHAPE_TOKENS = {  # (kind, global tokens processed per step)
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,       # one token per sequence
    "long_500k": 1,
}


def active_params(arch: str) -> float:
    from repro.configs import get_config
    from repro.models import count_params, params_spec
    cfg = get_config(arch)
    total = count_params(params_spec(cfg))
    if cfg.num_experts and cfg.experts_per_tok:
        e, f, x, nl = cfg.d_model, cfg.d_ff, cfg.num_experts, cfg.num_layers
        expert_params = nl * x * 3 * e * f
        active = total - expert_params * (1 - cfg.experts_per_tok / x)
        return active
    return total


def model_flops(arch: str, shape: str, nchips: int) -> float:
    n_active = active_params(arch)
    tokens = SHAPE_TOKENS[shape]
    mult = 6.0 if shape.startswith("train") else 2.0
    return mult * n_active * tokens / nchips  # per device


def load_cells(mesh_tag: str = "pod1") -> list:
    cells = []
    for path in sorted(glob.glob(os.path.join(ART, mesh_tag, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def analyze_cell(rec: dict) -> dict | None:
    if rec.get("status") != "ok":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "status": rec.get("status"),
                "reason": rec.get("reason", rec.get("error", ""))[:90]}
    nchips = 1
    for d in rec["mesh_shape"]:
        nchips *= d
    flops = rec["cost"]["flops"]
    hbm = rec["cost"]["bytes_hbm"]
    coll = rec["collectives"]["total_bytes"]
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_n = coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_n}
    dom = max(terms, key=terms.get)
    mf = model_flops(rec["arch"], rec["shape"], nchips)
    ideal = mf / PEAK_FLOPS
    frac = ideal / max(max(terms.values()), 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
        "nchips": nchips,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dom,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": flops,
        "flops_ratio": mf / max(flops, 1e-30),
        "roofline_fraction": frac,
        "args_gib": rec["memory"].get("argument_size_in_bytes", 0) / 2**30,
        "temp_gib": rec["memory"].get("temp_size_in_bytes", 0) / 2**30,
    }


IMPROVE_HINTS = {
    "collective": ("shrink or overlap the dominant collective: "
                   "reduce-scatter instead of all-reduce, avoid resharding "
                   "copies, keep MoE dispatch local to the model axis"),
    "compute": ("compute-bound: raise MFU by cutting remat recompute and "
                "non-matmul FLOPs (masking, softmax tails)"),
    "memory": ("memory-bound: fuse elementwise chains, cut activation "
               "materialisation, widen per-chip batch to raise intensity"),
}


def table(mesh_tag: str = "pod1") -> list:
    return [analyze_cell(r) for r in load_cells(mesh_tag)]


def markdown(rows: list) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | "
           "dominant | MODEL/HLO flops | roofline frac |",
           "|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r.get('status')} | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3g} | "
            f"{r['memory_s']:.3g} | {r['collective_s']:.3g} | "
            f"{r['dominant']} | {r['flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(out)


def main():
    t0 = time.perf_counter()
    rows = table("pod1")
    ok = [r for r in rows if r.get("status") == "ok"]
    print(markdown(rows))
    by_dom = {}
    for r in ok:
        by_dom[r["dominant"]] = by_dom.get(r["dominant"], 0) + 1
    mean_frac = sum(r["roofline_fraction"] for r in ok) / max(len(ok), 1)
    dt = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    print(f"roofline,{dt:.0f},cells={len(ok)};mean_fraction={mean_frac:.3f}"
          f";dominants={by_dom}")


if __name__ == "__main__":
    main()
