"""Beyond-paper: the paper's mapper applied to TPU v5e logical meshes.

Scenarios (the TPU analogue of the paper's §5 experiments):

1. aligned     : (16,16) logical on a 16x16 ICI torus — the default
   enumeration is already optimal; the candidate search must TIE it
   (the paper's "similar ordering -> little room" finding).
2. mismatched  : logical shapes that don't match the physical torus
   ((64,4), (4,64), (8,32), (2,8,16)) — the paper's "task ordering vs
   network ordering mismatch", where geometric mapping wins.
3. sparse      : 256 chips allocated as Hilbert fragments of a 32x32
   four-pod super-torus (Cray-style sparse allocation).
4. two_pod     : (2,16,16) across a slow DCN dim.

The mapper uses the framework's candidate selection (default + FZ
mappings x coordinate scalings x rotations, scored by Latency(M)) —
exactly the paper's §4.3 rotation-search methodology — so it is never
worse than the default enumeration.  All candidate generation and
scoring run through the unified ``repro.mapping`` pipeline
(``select_mapping`` is a thin adapter): the vectorised Multi-Jagged
partitioner orders both sides and one batched metrics pass ranks every
candidate.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (Allocation, logical_mesh_graph, make_machine,
                        sfc_allocation, tpu_v5e_multipod, tpu_v5e_pod)
from repro.meshmap.device_mesh import DEFAULT_AXIS_BYTES, select_mapping


def _ratio(graph, alloc, ab) -> dict:
    best, ours, base = select_mapping(graph, alloc, ab)
    return {
        "wh_ratio": ours["weighted_hops"] / max(base["weighted_hops"],
                                                1e-9),
        "lat_ratio": ours["latency_max"] / max(base["latency_max"], 1e-9),
    }


def run(quiet=False) -> dict:
    m1 = tpu_v5e_pod(16)
    a1 = Allocation(m1, m1.all_coords())
    out = {}

    def add(tag, shape, weights, alloc):
        g = logical_mesh_graph(shape, weights, None)
        out[tag] = _ratio(g, alloc, weights)
        if not quiet:
            print(f"[mapping_tpu] {tag}: Latency(M) x"
                  f"{out[tag]['lat_ratio']:.3f}, WeightedHops x"
                  f"{out[tag]['wh_ratio']:.3f} vs default order")

    dm = (DEFAULT_AXIS_BYTES["data"], DEFAULT_AXIS_BYTES["model"])
    add("aligned_16x16", (16, 16), dm, a1)
    add("mismatch_64x4", (64, 4), dm, a1)
    add("mismatch_4x64", (4, 64), dm, a1)
    add("mismatch_8x32", (8, 32), dm, a1)
    add("mismatch_2x8x16", (2, 8, 16),
        (DEFAULT_AXIS_BYTES["pod"],) + dm, a1)

    ms = make_machine((32, 32), wrap=True, bw=50.0, name="supertorus")
    sp = [None] * 3
    for i, seed in enumerate((0, 1, 2)):
        al = sfc_allocation(ms, 256, nfragments=4, seed=seed)
        g = logical_mesh_graph((16, 16), dm, None)
        sp[i] = _ratio(g, al, dm)
    out["sparse_16x16"] = {k: float(np.mean([s[k] for s in sp]))
                           for k in sp[0]}
    if not quiet:
        print(f"[mapping_tpu] sparse_16x16: Latency(M) x"
              f"{out['sparse_16x16']['lat_ratio']:.3f} (mean of 3 allocs)")

    m2 = tpu_v5e_multipod(2, 16)
    a2 = Allocation(m2, m2.all_coords())
    add("two_pod_2x16x16", (2, 16, 16),
        (DEFAULT_AXIS_BYTES["pod"],) + dm, a2)
    return out


def main():
    t0 = time.perf_counter()
    r = run()
    dt = (time.perf_counter() - t0) * 1e6 / max(len(r), 1)
    worst = max(v["lat_ratio"] for v in r.values())
    best = min(v["lat_ratio"] for v in r.values())
    print(f"mapping_tpu,{dt:.0f},best_lat_ratio={best:.3f}"
          f";worst_lat_ratio={worst:.3f}")


if __name__ == "__main__":
    main()
