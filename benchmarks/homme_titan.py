"""HOMME on Titan's Gemini network (paper Figs. 10-12).

Strong scaling of an ne=120 cubed sphere (86,400 elements) on sparse
SFC allocations of a Cray XK7.  Mappings (paper §5.3.1):

- SFC  : HOMME's Hilbert partition + Titan's default SFC rank order
         (locality-preserving on both sides — the hard-to-beat baseline).
- Z2_1 : plain geometric map+partition (FZ).
- Z2_2 : + largest-prime uneven bisection + bandwidth-scaled coords.
- Z2_3 : + 2x2x8 box lift to 6D node coordinates.

Findings to match (Figs. 10-12): Z2_1 HURTS (splits nodes); Z2_2 ~
matches SFC; Z2_3 cuts Latency(M) (up to ~18% at 86,400 ranks in the
paper) while RAISING WeightedHops ~25% — the bandwidth-aware trade.
Per-dim: SFC's worst latency sits on the slow Y cables; Z2_3 moves
traffic to fast X/Z links.  Z2 variants run through the unified
``repro.mapping`` pipeline via ``repro.core.Mapper``.
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (Mapper, MapperConfig, MappingResult, cube_coords,
                        cube_sphere_graph, evaluate, gemini_xk7,
                        sfc_allocation)
from repro.core.orderings import hilbert_index

NE = 120
CORES_PER_ROUTER = 32

# §4.3 rotation-search budget per Z2 variant (the batched sweep makes a
# real search affordable; pre-batching this was 0 = identity only).
ROTATIONS = 8


def homme_sfc_parts(ne: int, nparts: int) -> np.ndarray:
    n = 6 * ne * ne
    rem = np.arange(n) % (ne * ne)
    fi, fj = rem // ne, rem % ne
    bits = int(np.ceil(np.log2(ne)))
    h = hilbert_index(np.stack([fi, fj], axis=1), bits)
    order = np.argsort(np.arange(n) // (ne * ne) * (4 ** bits + 1) + h,
                       kind="stable")
    parts = np.zeros(n, dtype=np.int64)
    bounds = (np.arange(1, nparts) * n) // nparts
    parts[order] = np.searchsorted(bounds, np.arange(n), side="right")
    return parts


def cray_rank_order(alloc, box=(2, 2, 4)):
    """Titan's default rank ordering: traverse a*2*4 router boxes fully
    before crossing slow links (ALPS bandwidth-prioritised order)."""
    from repro.core.machine import Allocation
    c = alloc.coords
    b = np.asarray(box)
    outer = c[:, :3] // b
    inner = c[:, :3] % b
    order = np.lexsort((c[:, 3], inner[:, 2], inner[:, 1], inner[:, 0],
                        outer[:, 2], outer[:, 1], outer[:, 0]))
    return Allocation(alloc.machine, c[order])


def run_point(nranks: int, seed: int) -> dict:
    machine = gemini_xk7(dims=(25, 16, 24),
                         cores_per_node=CORES_PER_ROUTER)
    alloc_raw = sfc_allocation(machine, nranks, nfragments=4, seed=seed)
    # rank r runs on core r of the *Cray-ordered* allocation (the real
    # Titan default); "SFC-ideal" uses the allocator's own Hilbert order
    # (an idealised upper bound where both curves coincide exactly).
    alloc = cray_rank_order(alloc_raw)
    graph = cube_sphere_graph(NE)
    tc = cube_coords(NE)

    out = {}
    parts = homme_sfc_parts(NE, nranks)
    out["SFC"] = evaluate(graph, alloc, MappingResult(parts))
    out["SFC-ideal"] = evaluate(graph, alloc_raw, MappingResult(parts))
    variants = {
        "Z2_1": MapperConfig(sfc="FZ", shift=True, rotations=ROTATIONS),
        "Z2_2": MapperConfig(sfc="FZ", shift=True, uneven_prime=True,
                             bandwidth_scale=True, rotations=ROTATIONS),
        "Z2_3": MapperConfig(sfc="FZ", shift=True, uneven_prime=True,
                             bandwidth_scale=True, box=(2, 2, 8),
                             rotations=ROTATIONS),
    }
    for name, mc in variants.items():
        res = Mapper(mc).map(graph, alloc, task_coords=tc)
        out[name] = evaluate(graph, alloc, res)
    return out


def normalize(res: dict) -> dict:
    base = res["SFC"]
    table = {}
    for k, v in res.items():
        table[k] = {
            "WH": v["weighted_hops"] / max(base["weighted_hops"], 1e-9),
            "TM": v["num_offnode_messages"] / max(
                base["num_offnode_messages"], 1),
            "Data": v["data_max"] / max(base["data_max"], 1e-9),
            "Latency": v["latency_max"] / max(base["latency_max"], 1e-9),
        }
    return table


def per_dim(res: dict, keys=("SFC", "Z2_3")) -> dict:
    """Fig. 12: Data and Latency per (dim, direction), normalized to
    SFC X+."""
    base = res["SFC"]["per_dim"]["dim0+"]
    table = {}
    for k in keys:
        pd_ = res[k]["per_dim"]
        table[k] = {
            f"{'XYZ'[d]}{s}": {
                "data": pd_[f"dim{d}{s}"]["data_max"] /
                max(base["data_max"], 1e-9),
                "lat": pd_[f"dim{d}{s}"]["lat_max"] /
                max(base["lat_max"], 1e-9),
            }
            for d in range(3) for s in "+-"}
    return table


def run(rank_counts=(10800, 21600, 43200, 86400), seeds=(0, 1),
        quiet=False):
    results = {}
    for n in rank_counts:
        tabs = [normalize(run_point(n, s)) for s in seeds]
        agg = {}
        for k in tabs[0]:
            agg[k] = {m: float(np.mean([t[k][m] for t in tabs]))
                      for m in tabs[0][k]}
        results[n] = agg
        if not quiet:
            print(f"[homme_titan] {n}: " + "  ".join(
                f"{k}: lat={v['Latency']:.2f} wh={v['WH']:.2f}"
                for k, v in agg.items()))
    return results


def main():
    t0 = time.perf_counter()
    results = run()
    top = max(results)
    z3 = results[top]["Z2_3"]
    dt = (time.perf_counter() - t0) * 1e6 / len(results)
    print(f"homme_titan,{dt:.0f},z2_3_latency_vs_sfc_at_{top}="
          f"{z3['Latency']:.3f};z2_3_wh_vs_sfc={z3['WH']:.3f}")


if __name__ == "__main__":
    main()
