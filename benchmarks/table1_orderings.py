"""Paper Table 1: AverageHops of geometric mapping under H/Z/FZ/MFZ.

td-dimensional stencil tasks are one-to-one mapped onto pd-dimensional
block-allocated nodes; both sides are ordered by the same SFC (MFZ = FZ on
the node side + FZlow on the task side, applied when pd % td == 0).  The
three column groups are Mesh->Mesh, Mesh->Torus and Torus->Torus.

Every value is deterministic, so this benchmark doubles as the paper
reproduction check: PAPER_TABLE1 below holds the published values and the
benchmark reports ours next to theirs.  Our Z/FZ/MFZ match the paper to
the printed precision on all rows; Hilbert differs by a few percent on
some rows because d-dimensional Hilbert curves are only defined up to
orientation convention (we use Skilling's transpose algorithm).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.orderings import grid_order

# (ntasks, pd, td) -> {order: (mesh2mesh, mesh2torus, torus2torus)}
# Published values (Table 1).  MFZ entries exist only when pd % td == 0.
PAPER_TABLE1 = {
    (262144, 1, 2): {"H": (311.05, 246.92, 411.01), "Z": (256.50, 256.50, 426.67), "FZ": (384.00, 351.94, 447.25)},
    (32768, 1, 3): {"H": (380.49, 292.40, 518.62), "Z": (352.33, 352.33, 633.90), "FZ": (410.67, 322.58, 525.83)},
    (1048576, 1, 4): {"H": (8755.69, 6641.63, 12324.09), "Z": (8456.25, 8456.25, 15837.86), "FZ": (9060.00, 6945.94, 12360.88)},
    (32768, 1, 5): {"H": (951.63, 717.57, 1229.50), "Z": (936.20, 936.20, 1611.95), "FZ": (967.20, 733.14, 1230.30)},
    (262144, 1, 6): {"H": (6291.69, 4731.31, 8193.25), "Z": (6241.50, 6241.50, 10835.96), "FZ": (6342.00, 4781.62, 8194.58)},
    (65536, 1, 8): {"H": (2735.92, 2053.25, 3071.94), "Z": (2730.63, 2730.63, 4087.94), "FZ": (2741.25, 2058.58, 3071.94)},
    (262144, 2, 1): {"H": (1.00, 1.00, 1.00), "Z": (2.00, 1.99, 1.99), "FZ": (1.99, 1.99, 1.99), "MFZ": (1.20, 1.20, 1.20)},
    (262144, 2, 3): {"H": (11.55, 10.79, 14.03), "Z": (13.45, 13.45, 17.81), "FZ": (10.67, 9.31, 11.17)},
    (1048576, 2, 4): {"H": (24.63, 21.15, 32.93), "Z": (16.50, 16.50, 26.66), "FZ": (24.00, 21.94, 27.25)},
    (1048576, 2, 5): {"H": (40.11, 34.38, 53.28), "Z": (39.92, 39.92, 62.20), "FZ": (34.56, 27.73, 40.40)},
    (262144, 2, 6): {"H": (31.22, 26.14, 41.43), "Z": (24.33, 24.33, 39.58), "FZ": (28.00, 21.90, 32.50)},
    (65536, 2, 8): {"H": (25.73, 21.28, 30.59), "Z": (21.25, 21.25, 30.88), "FZ": (22.50, 17.17, 23.88)},
    (32768, 3, 1): {"H": (1.00, 1.00, 1.00), "Z": (2.00, 1.99, 1.99), "FZ": (1.33, 1.32, 1.32), "MFZ": (1.04, 1.04, 1.04)},
    (262144, 3, 2): {"H": (2.56, 2.50, 2.55), "Z": (3.30, 3.28, 3.40), "FZ": (1.97, 1.88, 1.89)},
    (4096, 3, 4): {"H": (3.46, 3.18, 3.80), "Z": (3.54, 3.54, 4.50), "FZ": (2.57, 2.14, 2.38)},
    (32768, 3, 5): {"H": (5.33, 4.79, 6.10), "Z": (5.11, 5.11, 6.80), "FZ": (3.89, 3.20, 3.80)},
    (262144, 3, 6): {"H": (7.15, 6.23, 8.97), "Z": (4.50, 4.50, 6.63), "FZ": (6.00, 5.43, 6.25)},
    (262144, 3, 9): {"H": (9.89, 8.41, 11.67), "Z": (7.00, 7.00, 9.83), "FZ": (7.78, 6.00, 7.83)},
    (1048576, 4, 1): {"H": (1.00, 1.00, 1.00), "Z": (2.00, 2.00, 2.00), "FZ": (1.14, 1.14, 1.14), "MFZ": (1.01, 1.01, 1.01)},
    (1048576, 4, 2): {"H": (1.80, 1.80, 1.82), "Z": (1.94, 1.91, 1.91), "FZ": (1.91, 1.82, 1.82), "MFZ": (1.17, 1.17, 1.18)},
    (4096, 4, 3): {"H": (2.38, 2.21, 2.37), "Z": (2.58, 2.58, 3.00), "FZ": (1.60, 1.38, 1.42)},
    (1048576, 4, 5): {"H": (4.91, 4.61, 5.47), "Z": (4.75, 4.75, 6.00), "FZ": (3.20, 2.77, 3.10)},
    (4096, 4, 6): {"H": (2.83, 2.48, 2.89), "Z": (2.44, 2.44, 3.00), "FZ": (2.00, 1.56, 1.67)},
    (65536, 4, 8): {"H": (3.79, 3.24, 4.25), "Z": (2.50, 2.50, 3.25), "FZ": (3.00, 2.67, 2.75)},
    (32768, 5, 1): {"H": (1.00, 1.00, 1.00), "Z": (2.00, 1.99, 1.99), "FZ": (1.07, 1.06, 1.06), "MFZ": (1.00, 1.00, 1.00)},
    (1048576, 5, 2): {"H": (1.96, 1.94, 1.95), "Z": (2.43, 2.42, 2.44), "FZ": (1.27, 1.24, 1.24)},
    (32768, 5, 3): {"H": (2.38, 2.27, 2.37), "Z": (2.55, 2.55, 2.83), "FZ": (1.46, 1.31, 1.33)},
    (1048576, 5, 4): {"H": (3.18, 3.03, 3.24), "Z": (3.27, 3.27, 3.75), "FZ": (1.94, 1.74, 1.81)},
    (1048576, 5, 10): {"H": (3.93, 3.36, 4.38), "Z": (2.50, 2.50, 3.25), "FZ": (3.00, 2.67, 2.75)},
    (262144, 6, 1): {"H": (1.00, 1.00, 1.00), "Z": (2.00, 2.00, 2.00), "FZ": (1.03, 1.03, 1.03), "MFZ": (1.00, 1.00, 1.00)},
    (262144, 6, 2): {"H": (1.67, 1.65, 1.67), "Z": (1.96, 1.91, 1.91), "FZ": (1.30, 1.22, 1.22), "MFZ": (1.03, 1.03, 1.03)},
    (262144, 6, 3): {"H": (1.91, 1.84, 1.91), "Z": (1.78, 1.68, 1.69), "FZ": (1.67, 1.38, 1.38), "MFZ": (1.10, 1.10, 1.13)},
    (4096, 6, 4): {"H": (1.97, 1.77, 1.89), "Z": (1.93, 1.93, 2.25), "FZ": (1.29, 1.00, 1.00)},
    (262144, 6, 9): {"H": (3.05, 2.67, 3.12), "Z": (2.44, 2.44, 3.00), "FZ": (2.00, 1.56, 1.67)},
    (65536, 8, 1): {"H": (1.00, 1.00, 1.00), "Z": (2.00, 1.99, 1.99), "FZ": (1.01, 1.00, 1.00), "MFZ": (1.00, 1.00, 1.00)},
    (65536, 8, 2): {"H": (1.60, 1.57, 1.59), "Z": (1.95, 1.87, 1.88), "FZ": (1.12, 1.00, 1.00), "MFZ": (1.00, 1.00, 1.00)},
    (65536, 8, 4): {"H": (1.74, 1.60, 1.73), "Z": (1.60, 1.47, 1.50), "FZ": (1.40, 1.00, 1.00), "MFZ": (1.00, 1.00, 1.00)},
    (262144, 9, 1): {"H": (1.00, 1.00, 1.00), "Z": (2.00, 2.00, 2.00), "FZ": (1.00, 1.00, 1.00), "MFZ": (1.00, 1.00, 1.00)},
    (262144, 9, 2): {"H": (1.68, 1.64, 1.64), "Z": (2.06, 2.06, 2.09), "FZ": (1.05, 1.00, 1.00)},
    (262144, 9, 3): {"H": (1.78, 1.70, 1.74), "Z": (1.86, 1.73, 1.75), "FZ": (1.22, 1.00, 1.00), "MFZ": (1.00, 1.00, 1.00)},
    (262144, 9, 6): {"H": (2.14, 1.88, 2.00), "Z": (1.93, 1.93, 2.25), "FZ": (1.29, 1.00, 1.00)},
    (1048576, 10, 1): {"H": (1.00, 1.00, 1.00), "Z": (2.00, 2.00, 2.00), "FZ": (1.00, 1.00, 1.00), "MFZ": (1.00, 1.00, 1.00)},
    (1048576, 10, 2): {"H": (1.61, 1.59, 1.59), "Z": (1.99, 1.93, 1.94), "FZ": (1.06, 1.00, 1.00), "MFZ": (1.00, 1.00, 1.00)},
    (1048576, 10, 4): {"H": (2.08, 1.92, 2.00), "Z": (2.08, 2.08, 2.25), "FZ": (1.16, 1.00, 1.00)},
    (1048576, 10, 5): {"H": (1.76, 1.61, 1.74), "Z": (1.60, 1.47, 1.50), "FZ": (1.40, 1.00, 1.00), "MFZ": (1.00, 1.00, 1.00)},
}

ORDER_SIDES = {  # column order -> (node sfc, task sfc)
    "H": ("H", "H"),
    "Z": ("Z", "Z"),
    "FZ": ("FZ", "FZ"),
    "MFZ": ("FZ", "FZlow"),
}


def table1_cell(ntask: int, td: int, pd: int, order: str,
                torus_tasks: bool, torus_nodes: bool) -> float:
    """AverageHops for one Table-1 cell (vectorised; exact arithmetic)."""
    sfc_nodes, sfc_tasks = ORDER_SIDES[order]
    ts = round(ntask ** (1.0 / td))
    ps = round(ntask ** (1.0 / pd))
    if ts ** td != ntask or ps ** pd != ntask:
        raise ValueError(f"no integer grids for {ntask} in {td}/{pd} dims")
    gt = grid_order((ts,) * td, sfc_tasks)
    gp = grid_order((ps,) * pd, sfc_nodes)
    pos = np.zeros((ntask, pd), dtype=np.int64)
    ix = np.indices((ps,) * pd)
    pos[gp.ravel()] = np.stack([c.ravel() for c in ix], axis=1)
    tpos = pos[gt]  # node position of each task cell, (ts,)*td + (pd,)
    hops_sum = 0
    nedges = 0
    for k in range(td):
        a = np.moveaxis(tpos, k, 0)
        pairs = [(a[:-1], a[1:])]
        if torus_tasks and ts > 2:
            pairs.append((a[-1:], a[:1]))
        for u, v in pairs:
            d = np.abs(u.astype(np.int64) - v.astype(np.int64))
            if torus_nodes:
                d = np.minimum(d, ps - d)
            hops_sum += int(d.sum())
            nedges += u.size // pd
    return hops_sum / nedges


def table1_row(ntask, pd, td, orders=("H", "Z", "FZ", "MFZ")):
    out = {}
    for o in orders:
        if o == "MFZ" and (td == pd or pd % td != 0):
            continue
        out[o] = (
            table1_cell(ntask, td, pd, o, False, False),
            table1_cell(ntask, td, pd, o, False, True),
            table1_cell(ntask, td, pd, o, True, True),
        )
    return out


def run(max_tasks: int | None = None, quiet: bool = False):
    """Compute the full table; returns (rows, max relative error vs paper
    for Z/FZ/MFZ)."""
    results = {}
    worst = 0.0
    for (ntask, pd, td), paper in sorted(PAPER_TABLE1.items()):
        if max_tasks is not None and ntask > max_tasks:
            continue
        t0 = time.perf_counter()
        ours = table1_row(ntask, pd, td, orders=tuple(paper.keys()))
        dt = time.perf_counter() - t0
        results[(ntask, pd, td)] = ours
        for o, vals in ours.items():
            for i, v in enumerate(vals):
                pv = paper[o][i]
                rel = abs(v - pv) / max(pv, 1e-9)
                if o in ("Z", "FZ", "MFZ"):
                    worst = max(worst, rel)
        if not quiet:
            msg = " ".join(
                f"{o}=" + "/".join(f"{v:.2f}" for v in vals)
                for o, vals in ours.items())
            print(f"[table1] n={ntask} pd={pd} td={td} ({dt:.2f}s): {msg}")
    return results, worst


def main():
    t0 = time.perf_counter()
    results, worst = run()
    dt = time.perf_counter() - t0
    print(f"table1_orderings,{dt*1e6/max(len(results),1):.0f},"
          f"max_rel_err_vs_paper_ZFZMFZ={worst:.4f}")


if __name__ == "__main__":
    main()
