"""Benchmark harness: one entry per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV lines (one per benchmark); with
``--json PATH`` the same records are also written as machine-readable
JSON (name, us_per_call, parsed derived fields) for the bench
trajectory.

  partition        : vectorised vs recursive Multi-Jagged engine
                     (order_points at 2^18 points / 4096 parts) with a
                     bit-identity check and a speedup smoke guard
  candidates       : batched rotation sweep vs the per-candidate loop
                     oracle (2^16 tasks / 24 rotations) with a winner
                     bit-identity check and a speedup smoke guard
  hier             : flat vs hierarchical (coarsen->map->refine) engine
                     on sparse XK7 scenarios — records the flat-vs-hier
                     wall-clock ratio, the ~cores_per_node x engine-pass
                     point reduction, and the quality ratios in the
                     JSON bench trajectory; asserts quality within 5%,
                     monotone refinement and the >=4x speedup floor
  table1_orderings : paper Table 1  (AverageHops of H/Z/FZ/MFZ)
  minighost        : paper Figs. 13-15 (weak scaling, sparse Gemini)
  homme_bgq        : paper Table 2 + Figs. 8-9 (BG/Q 5D torus)
  homme_titan      : paper Figs. 10-12 (sparse Gemini, Z2_1/2/3)
  mapping_tpu      : beyond-paper TPU v5e logical-mesh mapping
  roofline         : deliverable (g) from the dry-run artifacts

``--full`` runs the complete Table 1 (up to 2^20-point rows, ~4 min) and
all scaling points; the default caps sizes for a fast harness pass.
``--smoke`` shrinks the perf-guarded entries to tiny sizes and drops
their speedup floors (constant overheads dominate there) while still
executing every equivalence/quality oracle — the mode CI runs on every
PR for the ``hier`` and ``candidates`` entries.  ``--only`` accepts a
comma-separated list of entry names.
"""

import argparse
import contextlib
import io
import json
import re
import sys
import time

_CSV_LINE = re.compile(r"^([A-Za-z0-9_]+),([0-9.]+),(.*)$")


def _parse_derived(text: str) -> dict:
    """``k=v;k=v`` derived fields -> dict (floats where they parse;
    trailing speedup ``x`` suffixes stripped)."""
    out = {}
    for item in text.split(";"):
        if "=" not in item:
            if item:
                out[item] = True
            continue
        k, v = item.split("=", 1)
        try:
            out[k] = float(v[:-1] if v.endswith("x") else v)
        except ValueError:
            out[k] = v
    return out


def _run(name, fn, records):
    """Run one benchmark, echo its output, and collect its CSV records."""
    buf = io.StringIO()
    t0 = time.perf_counter()
    try:
        with contextlib.redirect_stdout(buf):
            fn()
        ok = True
    except Exception as e:  # noqa: BLE001
        dt = (time.perf_counter() - t0) * 1e6
        buf.write(f"{name},{dt:.0f},ERROR:{type(e).__name__}:{e}\n")
        ok = False
    text = buf.getvalue()
    sys.stdout.write(text)
    for line in text.splitlines():
        m = _CSV_LINE.match(line.strip())
        if not m:
            continue
        rec = {"name": m.group(1), "us_per_call": float(m.group(2))}
        derived = m.group(3)
        if derived.startswith("ERROR:"):
            rec["ok"] = False
            rec["error"] = derived[len("ERROR:"):]
        else:
            rec["ok"] = ok
            rec["derived"] = _parse_derived(derived)
        records.append(rec)
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="run full-size Table 1 and all scaling points")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, oracles only (no speedup floors)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names to run")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the results as machine-readable JSON")
    args, _ = ap.parse_known_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (hier, homme_bgq, homme_titan, mapping_tpu,
                            minighost, roofline, table1_orderings)

    def partition_bench():
        """Vectorised level-synchronous engine vs recursive reference.

        Demonstrates the engine-swap speedup at the ISSUE's pinned size
        (2^18 points / 4096 parts) across task dimensionalities, checks
        the two backends stay bit-identical, and acts as a smoke guard:
        if the vectorised engine ever regresses below the floor the
        harness exits nonzero.
        """
        import numpy as np

        from repro.core.orderings import order_points, order_points_recursive

        n, parts = 1 << 18, 4096
        floor = 10.0 if args.full else 4.0
        fields = []
        best = 0.0
        t_best = 0.0
        for d in (1, 2, 3):
            coords = np.random.default_rng(1).normal(size=(n, d))
            order_points(coords[:4096], 64, "FZ")  # warm the engine path
            tv = min(_time_once(order_points, coords, parts)
                     for _ in range(2))
            t0 = time.perf_counter()
            mu_ref = order_points_recursive(coords, parts, "FZ")
            tr = time.perf_counter() - t0
            assert np.array_equal(
                order_points(coords, parts, "FZ"), mu_ref), \
                f"backends disagree at d={d}"
            speed = tr / max(tv, 1e-9)
            if speed > best:
                best, t_best = speed, tv
            fields.append(f"d{d}_speedup={speed:.1f}x")
        print(f"partition,{t_best*1e6:.0f},n={n};parts={parts};"
              + ";".join(fields) + f";best={best:.1f}x")
        assert best >= floor, (
            f"vectorised partitioner speedup {best:.1f}x below the "
            f"{floor:.0f}x smoke floor")

    def _time_once(fn, coords, parts):
        t0 = time.perf_counter()
        fn(coords, parts, "FZ")
        return time.perf_counter() - t0

    def candidates_bench():
        """Batched rotation sweep vs the per-candidate loop oracle.

        The paper's §4.3 rotation sweep at 2^16 tasks / 24 (task_perm,
        proc_perm) candidates, mapped onto a 4096-node (16,16,16) torus
        (16 tasks per processor) under strict dimension alternation —
        the setting where a rotation IS the cut order, so the sweep is
        the search.  ``sweep="batched"`` partitions the whole sweep in
        ~2 engine passes over the unique per-side permutations;
        ``sweep="loop"`` is the 2-partitions-per-candidate oracle.  All
        24 mappings must be bit-identical between the paths (hence so
        is the scored winner, asserted too); the speedup floor guards
        the batched path against regression (ISSUE 2: >=5x here).
        """
        import numpy as np

        from repro.core import block_allocation, make_machine, stencil_graph
        from repro.mapping import MappingPipeline, PipelineConfig
        from repro.mapping.candidates import rotation_candidates

        # The ISSUE-2 claim (>=5x at this size) is asserted in --full;
        # the default floor is lowered to 4x purely for scheduling
        # noise, mirroring the partition bench's 4x/10x pattern.
        # --smoke shrinks to 2^12 tasks and drops the floor entirely
        # (constant overheads dominate): only the bit-identity oracles
        # between the batched sweep and the loop run there.
        if args.smoke:
            n, rotations, floor = 1 << 12, 24, None
            graph = stencil_graph((16, 16, 16), torus=False)
        else:
            n, rotations = 1 << 16, 24
            floor = 5.0 if args.full else 4.0
            graph = stencil_graph((64, 32, 32), torus=False)
        machine = make_machine((16, 16, 16), wrap=True)
        alloc = block_allocation(machine)
        tc = graph.coords.astype(np.float64)
        cands = rotation_candidates(3, 3, rotations)
        assert graph.n == n and len(cands) == rotations
        pipes = {
            s: MappingPipeline(PipelineConfig(
                sfc="FZ", shift=True, rotations=rotations,
                longest_dim=False, sweep=s))
            for s in ("loop", "batched")
        }
        pc = pipes["loop"].machine_coords(alloc)

        def sweep(mode):
            t0 = time.perf_counter()
            res = pipes[mode].map_candidates(tc, pc, cands)
            return time.perf_counter() - t0, res

        sweep("batched")  # warm both engine paths at full size once
        t_loop, res_loop = min((sweep("loop") for _ in range(2)),
                               key=lambda tr: tr[0])
        # best-of-N with early stop: a single descheduled window must
        # not fail the floor, so keep sampling until the ISSUE-2 claim
        # (or a higher configured floor) holds or the budget runs out
        target = max(floor or 0.0, 5.0)
        t_bat, res_bat = sweep("batched")
        for _ in range(0 if floor is None else 5):
            if t_loop / t_bat >= target:
                break
            t2, r2 = sweep("batched")
            if t2 < t_bat:
                t_bat, res_bat = t2, r2
        for rl, rb in zip(res_loop, res_bat):
            assert np.array_equal(rl.task_to_proc, rb.task_to_proc), \
                "batched sweep mapping differs from the loop oracle"
        best_l, i_l, _ = pipes["loop"].search.best(graph, alloc, res_loop)
        best_b, i_b, _ = pipes["batched"].search.best(graph, alloc, res_bat)
        assert i_l == i_b and np.array_equal(best_l.task_to_proc,
                                             best_b.task_to_proc), \
            "scored winner differs between sweep modes"
        speed = t_loop / max(t_bat, 1e-9)
        print(f"candidates,{t_bat*1e6:.0f},n={n};rotations={rotations};"
              f"loop_us={t_loop*1e6:.0f};speedup={speed:.1f}x;"
              f"winner=rot{i_b};winner_identical=1")
        assert floor is None or speed >= floor, (
            f"batched candidate sweep speedup {speed:.1f}x below the "
            f"{floor:.0f}x smoke floor")

    def hier_bench():
        """Flat vs hierarchical (coarsen -> map -> refine) engine.

        Runs both sparse-XK7 scenarios of benchmarks/hier.py; every
        pass asserts the quality (within 5% of flat), monotone
        refinement and ~cores_per_node x engine-pass point reduction
        oracles.  The >=4x end-to-end speedup floor (ISSUE 3) is
        enforced at 2^18+ tasks — ``--smoke`` runs 2^14 tasks where
        constant overheads dominate, so only the oracles run there.
        The ``flat_vs_hier`` derived field lands in the JSON records
        so the bench trajectory tracks mapping-engine scaling.
        """
        if args.full:
            hier.main()  # 2^20 tasks / 64K+ allocated nodes
            return
        n = (1 << 14) if args.smoke else (1 << 18)
        results = hier.run(n=n, quiet=True, check_speed=not args.smoke)
        t = results["scenarios"][hier.SCENARIOS[0][0]]["t_node_s"]
        print(f"hier,{t*1e6:.0f},{hier.headline(results)}")

    def table1():
        if args.full:
            table1_orderings.main()
        else:
            t0 = time.perf_counter()
            results, worst = table1_orderings.run(max_tasks=65536,
                                                  quiet=True)
            dt = (time.perf_counter() - t0) * 1e6 / max(len(results), 1)
            print(f"table1_orderings,{dt:.0f},"
                  f"rows={len(results)};max_rel_err_vs_paper_ZFZMFZ="
                  f"{worst:.4f}")

    def mini():
        if args.full:
            minighost.main()
        else:
            t0 = time.perf_counter()
            res = minighost.run(core_counts=(8192, 32768), seeds=(0,),
                                quiet=True)
            h = minighost.headline(res)
            dt = (time.perf_counter() - t0) * 1e6 / len(res)
            print(f"minighost,{dt:.0f},"
                  f"lat_red_vs_default="
                  f"{h['latency_reduction_vs_default']:.2f}"
                  f";geo_growth={h['geo_hops_growth_weak_scaling']:.2f}")

    def bgq():
        if args.full:
            homme_bgq.main()
        else:
            t0 = time.perf_counter()
            res = homme_bgq.run(rank_counts=(8192,), quiet=True)
            best = min((v["data_vs_sfc"], k)
                       for k, v in res[8192].items()
                       if not k.startswith("_"))
            dt = (time.perf_counter() - t0) * 1e6
            print(f"homme_bgq,{dt:.0f},best_data_vs_sfc={best[0]:.3f}"
                  f";variant={best[1]}")

    def titan():
        if args.full:
            homme_titan.main()
        else:
            t0 = time.perf_counter()
            res = homme_titan.run(rank_counts=(10800,), seeds=(0,),
                                  quiet=True)
            z = res[10800]["Z2_2"]
            dt = (time.perf_counter() - t0) * 1e6
            print(f"homme_titan,{dt:.0f},z2_2_wh_vs_sfc={z['WH']:.3f}"
                  f";z2_2_lat_vs_sfc={z['Latency']:.3f}")

    benches = {
        "partition": partition_bench,
        "candidates": candidates_bench,
        "hier": hier_bench,
        "table1_orderings": table1,
        "minighost": mini,
        "homme_bgq": bgq,
        "homme_titan": titan,
        "mapping_tpu": mapping_tpu.main,
        "roofline": roofline.main,
    }
    ok = True
    records = []
    for name, fn in benches.items():
        if only is not None and name not in only:
            continue
        ok = _run(name, fn, records) and ok
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmarks": records, "full": bool(args.full)},
                      f, indent=2, sort_keys=True)
        print(f"[run] wrote {len(records)} records to {args.json}")
    sys.exit(0 if ok else 1)


if __name__ == '__main__':
    main()
