"""Benchmark harness: one entry per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV lines (one per benchmark).

  partition        : vectorised vs recursive Multi-Jagged engine
                     (order_points at 2^18 points / 4096 parts) with a
                     bit-identity check and a speedup smoke guard
  table1_orderings : paper Table 1  (AverageHops of H/Z/FZ/MFZ)
  minighost        : paper Figs. 13-15 (weak scaling, sparse Gemini)
  homme_bgq        : paper Table 2 + Figs. 8-9 (BG/Q 5D torus)
  homme_titan      : paper Figs. 10-12 (sparse Gemini, Z2_1/2/3)
  mapping_tpu      : beyond-paper TPU v5e logical-mesh mapping
  roofline         : deliverable (g) from the dry-run artifacts

``--full`` runs the complete Table 1 (up to 2^20-point rows, ~4 min) and
all scaling points; the default caps sizes for a fast harness pass.
"""

import argparse
import sys
import time


def _run(name, fn):
    t0 = time.perf_counter()
    try:
        fn()
    except Exception as e:  # noqa: BLE001
        dt = (time.perf_counter() - t0) * 1e6
        print(f"{name},{dt:.0f},ERROR:{type(e).__name__}:{e}")
        return False
    return True


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="run full-size Table 1 and all scaling points")
    ap.add_argument("--only", default=None)
    args, _ = ap.parse_known_args()

    from benchmarks import (homme_bgq, homme_titan, mapping_tpu, minighost,
                            roofline, table1_orderings)

    def partition_bench():
        """Vectorised level-synchronous engine vs recursive reference.

        Demonstrates the engine-swap speedup at the ISSUE's pinned size
        (2^18 points / 4096 parts) across task dimensionalities, checks
        the two backends stay bit-identical, and acts as a smoke guard:
        if the vectorised engine ever regresses below the floor the
        harness exits nonzero.
        """
        import numpy as np

        from repro.core.orderings import order_points, order_points_recursive

        n, parts = 1 << 18, 4096
        floor = 10.0 if args.full else 4.0
        fields = []
        best = 0.0
        t_best = 0.0
        for d in (1, 2, 3):
            coords = np.random.default_rng(1).normal(size=(n, d))
            order_points(coords[:4096], 64, "FZ")  # warm the engine path
            tv = min(_time_once(order_points, coords, parts)
                     for _ in range(2))
            t0 = time.perf_counter()
            mu_ref = order_points_recursive(coords, parts, "FZ")
            tr = time.perf_counter() - t0
            assert np.array_equal(
                order_points(coords, parts, "FZ"), mu_ref), \
                f"backends disagree at d={d}"
            speed = tr / max(tv, 1e-9)
            if speed > best:
                best, t_best = speed, tv
            fields.append(f"d{d}_speedup={speed:.1f}x")
        print(f"partition,{t_best*1e6:.0f},n={n};parts={parts};"
              + ";".join(fields) + f";best={best:.1f}x")
        assert best >= floor, (
            f"vectorised partitioner speedup {best:.1f}x below the "
            f"{floor:.0f}x smoke floor")

    def _time_once(fn, coords, parts):
        t0 = time.perf_counter()
        fn(coords, parts, "FZ")
        return time.perf_counter() - t0

    def table1():
        if args.full:
            table1_orderings.main()
        else:
            t0 = time.perf_counter()
            results, worst = table1_orderings.run(max_tasks=65536,
                                                  quiet=True)
            dt = (time.perf_counter() - t0) * 1e6 / max(len(results), 1)
            print(f"table1_orderings,{dt:.0f},"
                  f"rows={len(results)};max_rel_err_vs_paper_ZFZMFZ="
                  f"{worst:.4f}")

    def mini():
        if args.full:
            minighost.main()
        else:
            t0 = time.perf_counter()
            res = minighost.run(core_counts=(8192, 32768), seeds=(0,),
                                quiet=True)
            h = minighost.headline(res)
            dt = (time.perf_counter() - t0) * 1e6 / len(res)
            print(f"minighost,{dt:.0f},"
                  f"lat_red_vs_default="
                  f"{h['latency_reduction_vs_default']:.2f}"
                  f";geo_growth={h['geo_hops_growth_weak_scaling']:.2f}")

    def bgq():
        if args.full:
            homme_bgq.main()
        else:
            t0 = time.perf_counter()
            res = homme_bgq.run(rank_counts=(8192,), quiet=True)
            best = min((v["data_vs_sfc"], k)
                       for k, v in res[8192].items()
                       if not k.startswith("_"))
            dt = (time.perf_counter() - t0) * 1e6
            print(f"homme_bgq,{dt:.0f},best_data_vs_sfc={best[0]:.3f}"
                  f";variant={best[1]}")

    def titan():
        if args.full:
            homme_titan.main()
        else:
            t0 = time.perf_counter()
            res = homme_titan.run(rank_counts=(10800,), seeds=(0,),
                                  quiet=True)
            z = res[10800]["Z2_2"]
            dt = (time.perf_counter() - t0) * 1e6
            print(f"homme_titan,{dt:.0f},z2_2_wh_vs_sfc={z['WH']:.3f}"
                  f";z2_2_lat_vs_sfc={z['Latency']:.3f}")

    benches = {
        "partition": partition_bench,
        "table1_orderings": table1,
        "minighost": mini,
        "homme_bgq": bgq,
        "homme_titan": titan,
        "mapping_tpu": mapping_tpu.main,
        "roofline": roofline.main,
    }
    ok = True
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        ok = _run(name, fn) and ok
    sys.exit(0 if ok else 1)


if __name__ == '__main__':
    main()
