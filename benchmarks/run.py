"""Benchmark harness: one entry per paper table/figure + the roofline.

Prints ``name,us_per_call,derived`` CSV lines (one per benchmark); with
``--json PATH`` the same records are also written as machine-readable
JSON (name, us_per_call, parsed derived fields) for the bench
trajectory.

  partition        : vectorised vs recursive Multi-Jagged engine
                     (order_points at 2^18 points / 4096 parts) with a
                     bit-identity check and a speedup smoke guard
  candidates       : batched rotation sweep vs the per-candidate loop
                     oracle (2^16 tasks / 24 rotations) with a winner
                     bit-identity check, a speedup smoke guard, the
                     REPRO_SCORE_BACKEND winner-vs-numpy-oracle check
                     and the compile-once-per-(machine, bucket) guard
                     of the bucketed jax scorer
  mapscore         : fused Pallas candidate-scoring kernel vs the jax
                     scorer (ISSUE 4): parity + winner bit-identity vs
                     the numpy oracle (interpret mode on CPU), the
                     jax-vs-pallas wall-clock ratio for the bench
                     trajectory, and a >=5x floor where a TPU is
                     available
  end2end          : cold whole-pipeline ``select_mapping`` with the
                     numpy vs jax partition backend (ISSUE 6): with
                     ``partition_backend="jax"`` + a device scorer the
                     partition -> match -> score -> select chain runs
                     as ONE compiled program per candidate stack;
                     winner bit-identity oracle always, >=3x cold
                     speedup floor on TPU only
  fused_h          : Hilbert + fused refinement one-program cold path
                     (ISSUE 9): cold ``select_mapping`` with
                     ``sfc="H"`` and the device Hilbert state machine,
                     winner bit-identical to the numpy oracle; the
                     hierarchical path's swap refinement folded into
                     the same compiled program, trajectory identical
                     to the host ``refine_swaps``, one compile per
                     (machine, bucket) candidate stack
  serve            : mapping-as-a-service cold vs warm vs coalesced
                     throughput (ISSUE 5): scenario-registry requests
                     through one MappingService — warm responses must
                     be cache hits bit-identical to the cold pipeline
                     pass, coalesced duplicates must match a solo
                     request bit for bit, and the warm path must beat
                     the >=50x latency floor (skipped in --smoke)
  hier             : flat vs hierarchical (coarsen->map->refine) engine
                     on sparse XK7 scenarios — records the flat-vs-hier
                     wall-clock ratio, the ~cores_per_node x engine-pass
                     point reduction, and the quality ratios in the
                     JSON bench trajectory; asserts quality within 5%,
                     monotone refinement and the >=4x speedup floor
  table1_orderings : paper Table 1  (AverageHops of H/Z/FZ/MFZ)
  minighost        : paper Figs. 13-15 (weak scaling, sparse Gemini)
  homme_bgq        : paper Table 2 + Figs. 8-9 (BG/Q 5D torus)
  homme_titan      : paper Figs. 10-12 (sparse Gemini, Z2_1/2/3)
  mapping_tpu      : beyond-paper TPU v5e logical-mesh mapping
  roofline         : deliverable (g) from the dry-run artifacts

``--full`` runs the complete Table 1 (up to 2^20-point rows, ~4 min) and
all scaling points; the default caps sizes for a fast harness pass.
``--smoke`` shrinks the perf-guarded entries to tiny sizes and drops
their speedup floors (constant overheads dominate there) while still
executing every equivalence/quality oracle — the mode CI runs on every
PR for the ``hier`` and ``candidates`` entries.  ``--only`` accepts a
comma-separated list of entry names.
"""

import argparse
import contextlib
import io
import json
import os
import re
import sys
import time

_CSV_LINE = re.compile(r"^([A-Za-z0-9_]+),([0-9.]+),(.*)$")

# Scoring backend the perf-guarded entries run their candidate-search
# passes with ("numpy" | "jax" | "pallas"); CI's pallas smoke job sets
# this to "pallas" so winner-vs-oracle divergence fails the build.
SCORE_BACKEND = os.environ.get("REPRO_SCORE_BACKEND", "numpy")

# Partition backend ("numpy" | "jax") the pipeline-level entries run
# with; CI's pallas smoke job sets this to "jax" so the fused
# whole-pipeline program is exercised against the numpy oracles.
PARTITION_BACKEND = os.environ.get("REPRO_PARTITION_BACKEND", "numpy")


# record key -> obs cache-registry name: the legacy per-record keys the
# bench trajectory already carries, now read from the one telemetry
# registry (repro.obs) instead of four hand-written module imports
_CACHE_KEYS = {"jax": "scorer_jax", "pallas": "scorer_pallas",
               "partition": "partition_jax", "fused": "fused"}


def _cache_stats() -> dict:
    """Current compile-cache counters of the bucketed device engines
    (jax/pallas scorers, jax partitioner, fused whole-pipeline
    programs), for the per-benchmark attribution records.  Backed by
    ``obs.snapshot()`` — absent engines (no jax) are simply missing."""
    from repro import obs
    caches = obs.snapshot().get("caches", {})
    return {rec: caches[name] for rec, name in _CACHE_KEYS.items()
            if name in caches}


def _resolved_backend() -> str:
    try:
        from repro.core.metrics import get_evaluator
        return get_evaluator(SCORE_BACKEND)[0]
    except Exception:  # noqa: BLE001
        return "numpy"


def _resolved_partition() -> str:
    try:
        from repro.core.orderings import resolve_partition_backend
        return resolve_partition_backend(PARTITION_BACKEND)
    except Exception:  # noqa: BLE001
        return "numpy"


def _parse_derived(text: str) -> dict:
    """``k=v;k=v`` derived fields -> dict (floats where they parse;
    trailing speedup ``x`` suffixes stripped)."""
    out = {}
    for item in text.split(";"):
        if "=" not in item:
            if item:
                out[item] = True
            continue
        k, v = item.split("=", 1)
        try:
            out[k] = float(v[:-1] if v.endswith("x") else v)
        except ValueError:
            out[k] = v
    return out


def _run(name, fn, records):
    """Run one benchmark, echo its output, and collect its CSV records.

    Every record additionally carries the requested/resolved scoring
    backend and the compile-cache hit/miss deltas of the bucketed
    scorers accumulated while the benchmark ran, so cross-backend
    trajectory comparisons stay attributable (ISSUE 4) — plus the full
    process telemetry snapshot (``obs.snapshot()``) and a per-span-name
    rollup of every span the benchmark finished (ISSUE 8).  With
    ``REPRO_JAX_PROFILE`` set, each benchmark also runs under a
    ``jax.profiler`` trace named after it.
    """
    from repro import obs

    buf = io.StringIO()
    before = _cache_stats()
    done = obs.finished()
    mark = done[-1].span_id if done else 0  # span ids are monotonic
    t0 = time.perf_counter()
    try:
        with contextlib.redirect_stdout(buf), obs.jax_profile(name):
            fn()
        ok = True
    except Exception as e:  # noqa: BLE001
        dt = (time.perf_counter() - t0) * 1e6
        buf.write(f"{name},{dt:.0f},ERROR:{type(e).__name__}:{e}\n")
        ok = False
    spans = obs.span_rollup(
        s for s in obs.finished() if s.span_id > mark)
    snap = obs.snapshot()
    cache = {}
    for eng, after in _cache_stats().items():
        base = before.get(eng, {})
        # a benchmark may reset the cache mid-run (counters restart at
        # zero); the post-reset absolute count is then the best delta
        cache[eng] = {
            k: (after[k] - base.get(k, 0)
                if after[k] >= base.get(k, 0) else after[k])
            for k in ("hits", "misses")}
    text = buf.getvalue()
    sys.stdout.write(text)
    for line in text.splitlines():
        m = _CSV_LINE.match(line.strip())
        if not m:
            continue
        rec = {"name": m.group(1), "us_per_call": float(m.group(2)),
               "score_backend": SCORE_BACKEND,
               "resolved_backend": _resolved_backend(),
               "partition_backend": PARTITION_BACKEND,
               "resolved_partition": _resolved_partition(),
               "compile_cache": cache,
               "obs": {"snapshot": snap, "spans": spans}}
        derived = m.group(3)
        if derived.startswith("ERROR:"):
            rec["ok"] = False
            rec["error"] = derived[len("ERROR:"):]
        else:
            rec["ok"] = ok
            rec["derived"] = _parse_derived(derived)
        records.append(rec)
    return ok


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="run full-size Table 1 and all scaling points")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes, oracles only (no speedup floors)")
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names to run")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the results as machine-readable JSON")
    args, _ = ap.parse_known_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (faults, hier, homme_bgq, homme_titan,
                            mapping_tpu, minighost, roofline, serve,
                            table1_orderings)

    def partition_bench():
        """Vectorised level-synchronous engine vs recursive reference.

        Demonstrates the engine-swap speedup at the ISSUE's pinned size
        (2^18 points / 4096 parts) across task dimensionalities, checks
        the two backends stay bit-identical, and acts as a smoke guard:
        if the vectorised engine ever regresses below the floor the
        harness exits nonzero.
        """
        import numpy as np

        from repro.core.orderings import order_points, order_points_recursive

        n, parts = 1 << 18, 4096
        floor = 10.0 if args.full else 4.0
        fields = []
        best = 0.0
        t_best = 0.0
        for d in (1, 2, 3):
            coords = np.random.default_rng(1).normal(size=(n, d))
            order_points(coords[:4096], 64, "FZ")  # warm the engine path
            tv = min(_time_once(order_points, coords, parts)
                     for _ in range(2))
            t0 = time.perf_counter()
            mu_ref = order_points_recursive(coords, parts, "FZ")
            tr = time.perf_counter() - t0
            assert np.array_equal(
                order_points(coords, parts, "FZ"), mu_ref), \
                f"backends disagree at d={d}"
            speed = tr / max(tv, 1e-9)
            if speed > best:
                best, t_best = speed, tv
            fields.append(f"d{d}_speedup={speed:.1f}x")
        print(f"partition,{t_best*1e6:.0f},n={n};parts={parts};"
              + ";".join(fields) + f";best={best:.1f}x")
        assert best >= floor, (
            f"vectorised partitioner speedup {best:.1f}x below the "
            f"{floor:.0f}x smoke floor")

    def _time_once(fn, coords, parts):
        t0 = time.perf_counter()
        fn(coords, parts, "FZ")
        return time.perf_counter() - t0

    def candidates_bench():
        """Batched rotation sweep vs the per-candidate loop oracle.

        The paper's §4.3 rotation sweep at 2^16 tasks / 24 (task_perm,
        proc_perm) candidates, mapped onto a 4096-node (16,16,16) torus
        (16 tasks per processor) under strict dimension alternation —
        the setting where a rotation IS the cut order, so the sweep is
        the search.  ``sweep="batched"`` partitions the whole sweep in
        ~2 engine passes over the unique per-side permutations;
        ``sweep="loop"`` is the 2-partitions-per-candidate oracle.  All
        24 mappings must be bit-identical between the paths (hence so
        is the scored winner, asserted too); the speedup floor guards
        the batched path against regression (ISSUE 2: >=5x here).
        """
        import numpy as np

        from repro.core import block_allocation, make_machine, stencil_graph
        from repro.mapping import MappingPipeline, PipelineConfig
        from repro.mapping.candidates import rotation_candidates

        # The ISSUE-2 claim (>=5x at this size) is asserted in --full;
        # the default floor is lowered to 4x purely for scheduling
        # noise, mirroring the partition bench's 4x/10x pattern.
        # --smoke shrinks to 2^12 tasks and drops the floor entirely
        # (constant overheads dominate): only the bit-identity oracles
        # between the batched sweep and the loop run there.
        if args.smoke:
            n, rotations, floor = 1 << 12, 24, None
            graph = stencil_graph((16, 16, 16), torus=False)
        else:
            n, rotations = 1 << 16, 24
            floor = 5.0 if args.full else 4.0
            graph = stencil_graph((64, 32, 32), torus=False)
        machine = make_machine((16, 16, 16), wrap=True)
        alloc = block_allocation(machine)
        tc = graph.coords.astype(np.float64)
        cands = rotation_candidates(3, 3, rotations)
        assert graph.n == n and len(cands) == rotations
        pipes = {
            s: MappingPipeline(PipelineConfig(
                sfc="FZ", shift=True, rotations=rotations,
                longest_dim=False, sweep=s,
                score_backend=SCORE_BACKEND,
                partition_backend=PARTITION_BACKEND))
            for s in ("loop", "batched")
        }
        pc = pipes["loop"].machine_coords(alloc)

        def sweep(mode):
            t0 = time.perf_counter()
            res = pipes[mode].map_candidates(tc, pc, cands)
            return time.perf_counter() - t0, res

        sweep("batched")  # warm both engine paths at full size once
        t_loop, res_loop = min((sweep("loop") for _ in range(2)),
                               key=lambda tr: tr[0])
        # best-of-N with early stop: a single descheduled window must
        # not fail the floor, so keep sampling until the ISSUE-2 claim
        # (or a higher configured floor) holds or the budget runs out
        target = max(floor or 0.0, 5.0)
        t_bat, res_bat = sweep("batched")
        for _ in range(0 if floor is None else 5):
            if t_loop / t_bat >= target:
                break
            t2, r2 = sweep("batched")
            if t2 < t_bat:
                t_bat, res_bat = t2, r2
        for rl, rb in zip(res_loop, res_bat):
            assert np.array_equal(rl.task_to_proc, rb.task_to_proc), \
                "batched sweep mapping differs from the loop oracle"
        best_l, i_l, _ = pipes["loop"].search.best(graph, alloc, res_loop)
        best_b, i_b, _ = pipes["batched"].search.best(graph, alloc, res_bat)
        assert i_l == i_b and np.array_equal(best_l.task_to_proc,
                                             best_b.task_to_proc), \
            "scored winner differs between sweep modes"

        # REPRO_SCORE_BACKEND winner oracle: the searches above scored
        # with the env backend, so re-score with numpy and require the
        # SAME winner as its lexsort order (ISSUE 4 — CI's pallas smoke
        # job fails here on divergence)
        from repro.core.metrics import evaluate_candidates
        from repro.mapping import CandidateSearch
        if SCORE_BACKEND != "numpy":
            s_np = CandidateSearch("weighted_hops", backend="numpy")
            bn, i_np, _ = s_np.best(graph, alloc, res_bat)
            assert i_np == i_b and np.array_equal(bn.task_to_proc,
                                                  best_b.task_to_proc), \
                (f"{SCORE_BACKEND} winner rot{i_b} diverges from the "
                 f"numpy oracle rot{i_np}")

        # compile-once-per-(machine, bucket) guard: two message counts
        # sharing a bucket must reuse ONE compiled jax scorer — the
        # recompile-storm fix the bucketing exists for.  Skipped (not
        # failed) on numpy-only installs, where scoring falls back
        # silently and there is no compile cache to guard.
        try:
            from repro.core import metrics_jax
        except Exception:  # noqa: BLE001 - jax optional
            metrics_jax = None
        cst = {"misses": 0, "hits": 0}
        if metrics_jax is not None:
            stack4 = np.stack([alloc.coords[r.task_to_proc]
                               for r in res_bat[:4]])
            ne = len(graph.edges)
            ne2 = max(metrics_jax.bucket_size(ne) // 2 + 1, ne - 64)
            metrics_jax.reset_scorer_cache()
            for cut in (ne, ne2):
                evaluate_candidates(machine, graph.edges[:cut],
                                    graph.weights[:cut], stack4,
                                    backend="jax")
            cst = metrics_jax.scorer_cache_stats()
            assert cst["misses"] == 1 and cst["hits"] >= 1, (
                f"jax scorer recompiled within one (machine, bucket): "
                f"{cst}")

        speed = t_loop / max(t_bat, 1e-9)
        print(f"candidates,{t_bat*1e6:.0f},n={n};rotations={rotations};"
              f"loop_us={t_loop*1e6:.0f};speedup={speed:.1f}x;"
              f"winner=rot{i_b};winner_identical=1;"
              f"score_backend={_resolved_backend()};"
              f"jax_cache_misses={cst['misses']};"
              f"jax_cache_hits={cst['hits']}")
        assert floor is None or speed >= floor, (
            f"batched candidate sweep speedup {speed:.1f}x below the "
            f"{floor:.0f}x smoke floor")

    def mapscore_bench():
        """Fused Pallas scoring kernel vs the bucketed jax scorer.

        Scores the ``candidates``-style rotation sweep (traffic
        objective, so the dimension-ordered router runs) with both
        accelerator backends and the numpy oracle.  The pallas winners
        must be bit-identical to the numpy lexsort order and every
        metric must agree within fp tolerance.  The jax-vs-pallas
        wall-clock ratio lands in the JSON records for the bench
        trajectory; the >=5x floor (ISSUE 4, 2^16 tasks) is enforced
        only where a TPU is available — on CPU the kernel runs in
        interpret mode (``interpret=1`` in the record) on a capped
        subproblem purely as a parity/winner oracle.
        """
        import numpy as np

        try:  # accelerator-only entry: SKIP (not fail) on numpy-only
            import jax
            from repro.core import metrics_jax
            from repro.kernels.mapscore import ops as mapscore_ops
        except Exception:  # noqa: BLE001 - jax optional
            print("mapscore,0,skipped=no_jax")
            return
        from repro.core import block_allocation, make_machine, stencil_graph
        from repro.core.metrics import evaluate_candidates
        from repro.mapping import MappingPipeline, PipelineConfig
        from repro.mapping.candidates import rotation_candidates

        on_tpu = jax.default_backend() == "tpu"
        if args.smoke:
            shape, rotations = (16, 16, 16), 12            # 2^12 tasks
        elif args.full or on_tpu:
            shape, rotations = (64, 32, 32), 24            # 2^16 (ISSUE 4)
        else:
            shape, rotations = (32, 32, 16), 24            # 2^14 capped
        machine = make_machine((16, 16, 16), wrap=True)
        alloc = block_allocation(machine)
        graph = stencil_graph(shape, torus=False)
        pipe = MappingPipeline(PipelineConfig(
            sfc="FZ", shift=True, rotations=rotations, longest_dim=False))
        pc = pipe.machine_coords(alloc)
        cands = rotation_candidates(3, 3, rotations)
        res = pipe.map_candidates(graph.coords.astype(np.float64), pc,
                                  cands)
        stack = np.stack([alloc.coords[r.task_to_proc] for r in res])
        edges, w = graph.edges, graph.weights

        def timed(backend, stk, e, wt):
            t0 = time.perf_counter()
            ev = evaluate_candidates(machine, e, wt, stk, traffic=True,
                                     backend=backend)
            return time.perf_counter() - t0, ev

        # jax timing on the full problem (warm the compile first)
        timed("jax", stack, edges, w)
        t_jax = min(timed("jax", stack, edges, w)[0] for _ in range(2))

        # pallas: full problem on TPU; capped interpret-mode subproblem
        # on CPU (the parity/winner oracle, not a meaningful timing)
        if on_tpu:  # pragma: no cover - no TPU in this container
            p_stack, p_edges, p_w = stack, edges, w
        else:
            ncap, ecap = 8, 8192
            p_stack = stack[:ncap]
            p_edges, p_w = edges[:ecap], w[:ecap]
        timed("pallas", p_stack, p_edges, p_w)  # warm
        t_pal, ev_pal = timed("pallas", p_stack, p_edges, p_w)
        timed("jax", p_stack, p_edges, p_w)  # warm the capped shapes too
        t_jax_p, _ = timed("jax", p_stack, p_edges, p_w)
        _, ev_np = timed("numpy", p_stack, p_edges, p_w)

        for key in ev_np:
            assert np.allclose(ev_np[key], ev_pal[key], rtol=1e-4,
                               atol=1e-4), \
                f"pallas {key} diverges from the numpy oracle"
        for objective in (("weighted_hops",),
                          ("latency_max", "weighted_hops")):
            keys_np = tuple(ev_np[k] for k in reversed(objective))
            keys_pl = tuple(ev_pal[k] for k in reversed(objective))
            w_np = int(np.lexsort(keys_np)[0])
            w_pl = int(np.lexsort(keys_pl)[0])
            assert w_np == w_pl, (
                f"pallas winner {w_pl} != numpy oracle {w_np} for "
                f"{objective}")

        ratio = t_jax_p / max(t_pal, 1e-9)
        jst = metrics_jax.scorer_cache_stats()
        pst = mapscore_ops.scorer_cache_stats()
        print(f"mapscore,{t_pal*1e6:.0f},n={graph.n};"
              f"rotations={rotations};nmsg={len(edges)};"
              f"jax_full_us={t_jax*1e6:.0f};"
              f"pallas_nmsg={len(p_edges)};pallas_ncand={len(p_stack)};"
              f"jax_vs_pallas={ratio:.2f}x;"
              f"interpret={0 if on_tpu else 1};winner_identical=1;"
              f"score_backend={_resolved_backend()};"
              f"jax_cache_misses={jst['misses']};"
              f"jax_cache_hits={jst['hits']};"
              f"pallas_cache_misses={pst['misses']};"
              f"pallas_cache_hits={pst['hits']}")
        if on_tpu:  # pragma: no cover - floor only where it means something
            floor = 5.0 if args.full else 4.0
            assert ratio >= floor, (
                f"pallas scorer speedup {ratio:.1f}x below the "
                f"{floor:.0f}x floor vs the jax backend")

    def end2end_bench():
        """Cold whole-pipeline ``select_mapping``: numpy vs jax
        partition backend (ISSUE 6).

        Times the mesh builder's full candidate search — transforms,
        batched rotation sweeps, scoring, outer selection — with the
        host partitioner vs ``partition_backend="jax"``, where the
        partition -> match -> score -> select chain of every pipeline
        pass runs as ONE compiled program (:mod:`repro.mapping.fused`).
        Compile caches are warmed first, so "cold" is the honest
        no-result-cache request path the serve layer pays per new
        problem.  The winner must be bit-identical between backends
        (the jax partitioner's permutations equal numpy's bit for bit;
        the score columns are the same f32-derived values).  The >=3x
        speedup floor is enforced only on TPU — on CPU the entry is a
        correctness oracle and the ratio simply lands in the JSON
        trajectory.
        """
        import numpy as np

        try:  # accelerator-only entry: SKIP (not fail) on numpy-only
            import jax
            from repro.core import partition_jax
            from repro.mapping import fused as fused_mod
        except Exception:  # noqa: BLE001 - jax optional
            print("end2end,0,skipped=no_jax")
            return
        from repro.core import (block_allocation, logical_mesh_graph,
                                tpu_v5e_pod)
        from repro.meshmap.device_mesh import select_mapping

        on_tpu = jax.default_backend() == "tpu"
        if args.smoke:
            side = 64                      # 2^12 tasks
        elif args.full:
            side = 512                     # 2^18 (ISSUE 6 upper size)
        else:
            side = 256                     # 2^16 (ISSUE 6 default size)
        machine = tpu_v5e_pod(side=side)
        alloc = block_allocation(machine)
        graph = logical_mesh_graph((side, side), (8.0, 64.0),
                                   ("data", "model"))
        ab = [8.0, 64.0]
        rotations = 4
        # device scoring is what the fused program needs; respect the
        # env override, but never run interpret-mode pallas at full
        # sweep sizes off-TPU outside --smoke (hours, not seconds)
        sb = SCORE_BACKEND if SCORE_BACKEND != "numpy" else "jax"
        if sb == "pallas" and not on_tpu and not args.smoke:
            sb = "jax"

        def cold(pb, score):
            t0 = time.perf_counter()
            best, _, _ = select_mapping(graph, alloc, ab,
                                        rotations=rotations,
                                        partition_backend=pb,
                                        score_backend=score)
            return time.perf_counter() - t0, best

        cold("numpy", "numpy")  # warm the numpy pipelines
        cold("jax", sb)         # compile the fused programs once
        t_np, best_np = min((cold("numpy", "numpy") for _ in range(2)),
                            key=lambda tb: tb[0])
        t_jx, best_jx = min((cold("jax", sb) for _ in range(2)),
                            key=lambda tb: tb[0])
        identical = np.array_equal(best_np.task_to_proc,
                                   best_jx.task_to_proc)
        assert identical, (
            "jax-partition select_mapping winner differs from the "
            "numpy oracle")

        # tracing-overhead oracle (ISSUE 8): spans are always on, so
        # what can regress is the EXPORT path — re-run the numpy cold
        # path with the JSONL sink armed and bound the slowdown (the
        # compare.py ceiling is 2%); best-of-N with early stop so one
        # descheduled window cannot fail the oracle
        import tempfile

        from repro import obs
        fd, trace_path = tempfile.mkstemp(suffix=".jsonl")
        os.close(fd)
        sink = obs.JsonlSink(trace_path)

        def cold_traced():
            obs.add_sink(sink)
            try:
                return cold("numpy", "numpy")[0]
            finally:
                obs.remove_sink(sink)

        try:
            t_tr = min(cold_traced() for _ in range(2))
            overhead = t_tr / max(t_np, 1e-9)
            for _ in range(4):
                if overhead <= 1.02:
                    break
                t_np = min(t_np, cold("numpy", "numpy")[0])
                t_tr = min(t_tr, cold_traced())
                overhead = t_tr / max(t_np, 1e-9)
            with open(trace_path) as f:
                nevents = sum(1 for _ in f)
        finally:
            sink.close()
            os.unlink(trace_path)
        assert nevents > 0, "traced cold path exported no span events"

        pst = partition_jax.partition_cache_stats()
        fst = fused_mod.fused_cache_stats()
        speed = t_np / max(t_jx, 1e-9)
        print(f"end2end,{t_jx*1e6:.0f},n={graph.n};"
              f"rotations={rotations};numpy_us={t_np*1e6:.0f};"
              f"speedup={speed:.2f}x;winner_identical=1;"
              f"trace_overhead={overhead:.3f};trace_events={nevents};"
              f"partition_backend={_resolved_partition() if PARTITION_BACKEND != 'numpy' else 'jax'};"
              f"score_backend={sb};interpret={0 if on_tpu else 1};"
              f"partition_cache_misses={pst['misses']};"
              f"partition_cache_hits={pst['hits']};"
              f"fused_cache_misses={fst['misses']};"
              f"fused_cache_hits={fst['hits']}")
        if on_tpu:  # pragma: no cover - floor only where it means something
            assert speed >= 3.0, (
                f"fused on-device pipeline speedup {speed:.2f}x below "
                f"the 3x floor vs the host partitioner")

    def fused_h_bench():
        """Hilbert + fused refinement: the ISSUE-9 one-program cold
        path.

        Part 1 — cold flat ``select_mapping`` with ``sfc="H"`` and the
        jax partition backend: the device Hilbert state machine
        (Skilling's transpose) feeds the fused program, and the winner
        must be bit-identical to the all-numpy Hilbert oracle.  Part 2
        — node-level hierarchy: the bounded greedy swap refinement
        folds into the SAME compiled program; the refine trajectory
        must equal the host ``refine_swaps`` decision-for-decision
        (monotone), and the fused compile-cache counters must show one
        program per (machine, bucket) candidate stack — a repeat run
        may only add hits.  No speedup floor: this entry is a
        correctness + compile-accounting oracle; the cold timings land
        in the JSON trajectory.
        """
        import numpy as np

        try:  # accelerator-only entry: SKIP (not fail) on numpy-only
            import jax
            from repro.mapping import fused as fused_mod
        except Exception:  # noqa: BLE001 - jax optional
            print("fused_h,0,skipped=no_jax")
            return
        from repro.core import (block_allocation, gemini_xk7,
                                logical_mesh_graph, sfc_allocation,
                                stencil_graph, tpu_v5e_pod)
        from repro.mapping import (HierarchySpec, MappingPipeline,
                                   PipelineConfig)
        from repro.meshmap.device_mesh import select_mapping

        on_tpu = jax.default_backend() == "tpu"
        if args.smoke:
            side, n, dims, cores = 32, 1 << 8, (8, 4, 4), 4
        elif args.full:
            side, n, dims, cores = 256, 1 << 14, (32, 16, 16), 8
        else:
            side, n, dims, cores = 128, 1 << 12, (16, 16, 8), 4
        sb = SCORE_BACKEND if SCORE_BACKEND != "numpy" else "jax"
        if sb == "pallas" and not on_tpu and not args.smoke:
            sb = "jax"

        # part 1: cold flat select_mapping, device Hilbert vs host
        machine = tpu_v5e_pod(side=side)
        alloc = block_allocation(machine)
        graph = logical_mesh_graph((side, side), (8.0, 64.0),
                                   ("data", "model"))
        ab = [8.0, 64.0]

        def cold(pb, score):
            t0 = time.perf_counter()
            best, _, _ = select_mapping(graph, alloc, ab, rotations=4,
                                        sfc="H", partition_backend=pb,
                                        score_backend=score)
            return time.perf_counter() - t0, best

        cold("numpy", "numpy")  # warm the numpy pipelines
        cold("jax", sb)         # compile the fused Hilbert programs
        t_np, best_np = min((cold("numpy", "numpy") for _ in range(2)),
                            key=lambda tb: tb[0])
        t_jx, best_jx = min((cold("jax", sb) for _ in range(2)),
                            key=lambda tb: tb[0])
        assert np.array_equal(best_np.task_to_proc,
                              best_jx.task_to_proc), (
            "device-Hilbert select_mapping winner differs from the "
            "numpy oracle")

        # part 2: fused refinement vs the host refine_swaps trajectory
        e = n.bit_length() - 1
        a = e // 3
        g = stencil_graph((1 << (e - 2 * a), 1 << a, 1 << a))
        m2 = gemini_xk7(dims=dims, cores_per_node=cores)
        alloc2 = sfc_allocation(m2, n, nfragments=2, seed=3)
        kw = dict(sfc="H", rotations=6,
                  hierarchy=HierarchySpec.node())
        pipe_jx = MappingPipeline(PipelineConfig(
            partition_backend="jax", score_backend=sb, **kw))

        fused_mod.reset_fused_cache()
        t0 = time.perf_counter()
        rj = pipe_jx.map(g, alloc2)
        t_h_cold = time.perf_counter() - t0
        fst = fused_mod.fused_cache_stats()
        compiles = fst["misses"]
        assert compiles == fst["entries"], (
            "fused cache entries != compiles")
        t0 = time.perf_counter()
        rj2 = pipe_jx.map(g, alloc2)
        t_h_warm = time.perf_counter() - t0
        fst = fused_mod.fused_cache_stats()
        assert fst["misses"] == compiles and fst["hits"] >= compiles, (
            "repeat fused Hilbert run recompiled: one program per "
            "(machine, bucket) candidate stack is the contract")
        t0 = time.perf_counter()
        rn = MappingPipeline(PipelineConfig(**kw)).map(g, alloc2)
        t_h_np = time.perf_counter() - t0

        assert rj.stats.get("fused_refine") is True, (
            "hierarchical fused path did not refine on device")
        assert np.array_equal(rj.task_to_proc, rn.task_to_proc), (
            "fused-refinement mapping differs from the host pipeline")
        assert np.array_equal(rj.task_to_proc, rj2.task_to_proc)
        assert rj.stats["refine_history"] == rn.stats["refine_history"], (
            "fused refine trajectory != host refine_swaps")
        hist = [h[0] for h in rj.stats["refine_history"]]
        assert all(y <= x + 1e-9 for x, y in zip(hist, hist[1:])), (
            "fused refinement worsened the objective")

        print(f"fused_h,{t_jx*1e6:.0f},n={graph.n};hier_n={n};"
              f"numpy_us={t_np*1e6:.0f};"
              f"speedup={t_np/max(t_jx, 1e-9):.2f}x;"
              f"hier_cold_us={t_h_cold*1e6:.0f};"
              f"hier_warm_us={t_h_warm*1e6:.0f};"
              f"hier_numpy_us={t_h_np*1e6:.0f};"
              f"winner_identical=1;refine_identical=1;"
              f"refine_monotone=1;compile_once=1;"
              f"fused_compiles={compiles};"
              f"refine_rounds={rj.stats['refine_rounds_run']};"
              f"refine_accepted={rj.stats['refine_accepted']};"
              f"score_backend={sb};interpret={0 if on_tpu else 1}")

    def serve_bench():
        """Mapping-as-a-service: cold vs warm vs coalesced (ISSUE 5).

        Every mode runs the full oracle set (warm/coalesced results
        bit-identical to the cold pipeline pass, exact status
        accounting); the >=50x warm-path floor is enforced at the
        default scale and above, where the cold pipeline pass is long
        enough for the ratio to mean something.
        """
        if args.full:
            serve.main()  # 2^14-scale scenarios
            return
        scale = (1 << 9) if args.smoke else (1 << 12)
        results = serve.run(scale=scale, quiet=True,
                            check_speed=not args.smoke)
        t = results["t_warm_s"] / results["nscenarios"]
        print(f"serve,{t*1e6:.0f},{serve.headline(results)}")

    def faults_bench():
        """Resilience under injected faults (ISSUE 7).

        Replays the standard single-fault schedules (scorer compile
        failure, device OOM, partition failure, slow stage + deadline,
        eviction storm) through MappingService and asserts the
        availability/quality oracles: zero surfaced errors, every
        schedule served on its expected degradation-ladder rung,
        degraded results bit-identical to the healthy path, and the
        no-fault pass identical to the direct (fused) pipeline with
        every breaker closed.  Oracles run at every size — there is no
        perf floor here, availability IS the product.
        """
        if args.full:
            faults.main()  # 2^14-scale scenarios
            return
        scale = (1 << 9) if args.smoke else (1 << 12)
        results = faults.run(scale=scale, quiet=True)
        print(f"faults,{results['t_faulted_s']*1e6:.0f},"
              f"{faults.headline(results)}")

    def hier_bench():
        """Flat vs hierarchical (coarsen -> map -> refine) engine.

        Runs both sparse-XK7 scenarios of benchmarks/hier.py at depth
        2, 3 and 4; every pass asserts the quality budgets (depth-2
        within 5% of flat, depth-3 within 5% of depth-2), monotone
        refinement/polish at every level, core-level bijection and the
        per-level engine-pass point reduction oracles.  The speed
        floors (>=4x flat vs depth-2, ISSUE 3; depth-3 at least
        matching depth-2 wall-clock, ISSUE 10) are enforced at 2^18+
        tasks — ``--smoke`` runs 2^14 tasks where constant overheads
        dominate, so only the oracles run there.  The ``flat_vs_hier``
        / ``d3_vs_d2`` / ``wh_ratio_d3`` derived fields land in the
        JSON records so the bench trajectory tracks engine scaling.
        """
        if args.full:
            hier.main()  # 2^20 tasks / 64K+ allocated nodes
            return
        n = (1 << 14) if args.smoke else (1 << 18)
        results = hier.run(n=n, quiet=True, check_speed=not args.smoke)
        t = results["scenarios"][hier.SCENARIOS[0][0]]["t_node_s"]
        print(f"hier,{t*1e6:.0f},{hier.headline(results)}")

    def table1():
        if args.full:
            table1_orderings.main()
        else:
            t0 = time.perf_counter()
            results, worst = table1_orderings.run(max_tasks=65536,
                                                  quiet=True)
            dt = (time.perf_counter() - t0) * 1e6 / max(len(results), 1)
            print(f"table1_orderings,{dt:.0f},"
                  f"rows={len(results)};max_rel_err_vs_paper_ZFZMFZ="
                  f"{worst:.4f}")

    def mini():
        if args.full:
            minighost.main()
        else:
            t0 = time.perf_counter()
            res = minighost.run(core_counts=(8192, 32768), seeds=(0,),
                                quiet=True)
            h = minighost.headline(res)
            dt = (time.perf_counter() - t0) * 1e6 / len(res)
            print(f"minighost,{dt:.0f},"
                  f"lat_red_vs_default="
                  f"{h['latency_reduction_vs_default']:.2f}"
                  f";geo_growth={h['geo_hops_growth_weak_scaling']:.2f}")

    def bgq():
        if args.full:
            homme_bgq.main()
        else:
            t0 = time.perf_counter()
            res = homme_bgq.run(rank_counts=(8192,), quiet=True)
            best = min((v["data_vs_sfc"], k)
                       for k, v in res[8192].items()
                       if not k.startswith("_"))
            dt = (time.perf_counter() - t0) * 1e6
            print(f"homme_bgq,{dt:.0f},best_data_vs_sfc={best[0]:.3f}"
                  f";variant={best[1]}")

    def titan():
        if args.full:
            homme_titan.main()
        else:
            t0 = time.perf_counter()
            res = homme_titan.run(rank_counts=(10800,), seeds=(0,),
                                  quiet=True)
            z = res[10800]["Z2_2"]
            dt = (time.perf_counter() - t0) * 1e6
            print(f"homme_titan,{dt:.0f},z2_2_wh_vs_sfc={z['WH']:.3f}"
                  f";z2_2_lat_vs_sfc={z['Latency']:.3f}")

    benches = {
        "partition": partition_bench,
        "candidates": candidates_bench,
        "mapscore": mapscore_bench,
        "end2end": end2end_bench,
        "fused_h": fused_h_bench,
        "serve": serve_bench,
        "faults": faults_bench,
        "hier": hier_bench,
        "table1_orderings": table1,
        "minighost": mini,
        "homme_bgq": bgq,
        "homme_titan": titan,
        "mapping_tpu": mapping_tpu.main,
        "roofline": roofline.main,
    }
    ok = True
    records = []
    for name, fn in benches.items():
        if only is not None and name not in only:
            continue
        ok = _run(name, fn, records) and ok
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"benchmarks": records, "full": bool(args.full),
                       "smoke": bool(args.smoke)},
                      f, indent=2, sort_keys=True)
        print(f"[run] wrote {len(records)} records to {args.json}")
    sys.exit(0 if ok else 1)


if __name__ == '__main__':
    main()
