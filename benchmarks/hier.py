"""Flat vs hierarchical mapping engine (the `hier` benchmark entry).

MiniGhost-style weak-scaling scenarios on a sparse-allocation Cray XK7:
a 3D stencil with one task per core, mapped by the flat pipeline (one
point per core) and the hierarchical subsystem at increasing depth
(``PipelineConfig(hierarchy=HierarchySpec...)``):

- depth 2 (``HierarchySpec.node()``): coarsen to node-sized clusters,
  rotation-sweep at router granularity, monotone swap refinement,
  expand in intra-node SFC order — PR 3's scheme;
- depth 3 / depth 4 (``HierarchySpec.with_depth(n)``): additional
  geometric grouping levels above the nodes — every level divides the
  top sweep's point count by its arity; on the way down each group
  expansion is repaired by the exact-delta intra-group polish and the
  grouping levels run the sparse-QAP local search.

Reported per scenario (and recorded by ``run.py --json`` for the bench
trajectory): the flat/hier wall-clock ratio, the engine-pass point
ratio per depth, the quality ratios (weighted_hops, latency_max) per
depth, and the per-level point/cluster/polish breakdown from the
schema-v2 ``stats["levels"]``.  Oracles asserted on every run:

- hier partitions ~cores_per_node x fewer points per engine pass
  (depth 2), and each added level divides the sweep points further;
- depth-2 ``weighted_hops`` within 5% of (or better than) flat AND
  depth-3 within 5% of (or better than) depth-2, on BOTH scenarios;
- every refinement/polish trajectory is monotone at every level;
- every expanded mapping is a core-level bijection.

The speedup floors (flat/depth-2 >= 4x end-to-end at 2^18 tasks, ISSUE
3; depth-3 at least matching depth-2 wall-clock, ISSUE 10) are enforced
unless ``check_speed=False`` (the CI smoke pass runs tiny sizes where
constant overheads dominate and only the oracles are meaningful).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (Mapper, MapperConfig, evaluate, gemini_xk7,
                        sfc_allocation, stencil_graph)
from repro.hier import HierarchySpec

ROTATIONS = 8  # the MiniGhost benchmark's §4.3 search budget

SCENARIOS = (
    ("minighost", dict(nfragments=8, seed=0)),
    ("xk7_sparse", dict(nfragments=32, seed=3)),
)

DEPTHS = (3, 4)  # deep-hierarchy entries recorded alongside depth 2


def _grid(n: int) -> tuple[int, int, int]:
    """Near-cubic power-of-two task grid with prod = n."""
    e = int(np.log2(n))
    a = e // 3
    return (1 << (e - 2 * a), 1 << a, 1 << a)


def _machine(n: int, cores_per_node: int):
    """XK7-like torus with ~2x the routers the job needs (so sparse
    fragmented allocations have room to scatter)."""
    e = int(np.log2(max(2 * n // cores_per_node, 8)))
    a = e // 3
    rdims = (1 << (e - 2 * a), 1 << a, 1 << a)
    return gemini_xk7(dims=rdims, cores_per_node=cores_per_node)


def _assert_hier_invariants(name: str, res, n: int) -> None:
    """Bijection + per-level monotone refinement/polish, any depth."""
    assert np.array_equal(np.sort(res.task_to_proc), np.arange(n)), \
        f"{name}: hierarchical mapping is not a core-level bijection"
    for lv in res.stats["levels"]:
        hist = [h[0] for h in lv.get("refine_history", [])]
        assert all(b <= a + 1e-9 for a, b in zip(hist, hist[1:])), \
            (f"{name}: level {lv['level']} refinement worsened the "
             f"objective: {hist}")
        if "polish_initial" in lv:
            assert lv["polish_final"] <= lv["polish_initial"] + 1e-9, \
                (f"{name}: level {lv['level']} polish worsened the "
                 f"objective")


def _level_summary(res) -> list:
    """The per-level record the JSON trajectory keeps (points + what
    each level's passes did)."""
    return [{k: lv[k] for k in ("level", "name", "points", "clusters",
                                "units", "refine_accepted")
             if k in lv} | {k: lv[k] for k in ("polish_accepted",)
                            if k in lv}
            for lv in res.stats["levels"]]


def run(n: int = 1 << 18, cores_per_node: int = 16, *,
        rotations: int = ROTATIONS, check_speed: bool = True,
        speed_floor: float = 4.0, quiet: bool = False,
        depths: tuple = DEPTHS) -> dict:
    machine = _machine(n, cores_per_node)
    graph = stencil_graph(_grid(n), torus=False)
    flat = Mapper(MapperConfig(sfc="FZ", shift=True, rotations=rotations))
    node = Mapper(MapperConfig(sfc="FZ", shift=True, rotations=rotations,
                               hierarchy=HierarchySpec.node()))
    deep = {d: Mapper(MapperConfig(sfc="FZ", shift=True,
                                   rotations=rotations,
                                   hierarchy=HierarchySpec.with_depth(d)))
            for d in depths}

    out: dict = {"n": n, "cores_per_node": cores_per_node,
                 "scenarios": {}}
    for i, (name, alloc_kw) in enumerate(SCENARIOS):
        alloc = sfc_allocation(machine, n, **alloc_kw)

        def _timed(mapper, alloc=alloc):
            t0 = time.perf_counter()
            res = mapper.map(graph, alloc)
            return time.perf_counter() - t0, res

        t_flat, res_f = _timed(flat)
        t_node, res_n = _timed(node)
        if i == 0 and check_speed:
            # symmetric best-of-2 on BOTH sides, then keep resampling
            # the hier side while the floor fails (a single descheduled
            # window must not fail the floor — candidates-bench style)
            t_flat = min(t_flat, _timed(flat)[0])
            t_node = min(t_node, _timed(node)[0])
            for _ in range(3):
                if t_flat / t_node >= speed_floor:
                    break
                t_node = min(t_node, _timed(node)[0])

        _assert_hier_invariants(name, res_n, n)

        ev_f = evaluate(graph, alloc, res_f)
        ev_n = evaluate(graph, alloc, res_n)
        points_ratio = (res_f.stats["sweep_points"]
                        / res_n.stats["sweep_points"])
        wh_ratio = ev_n["weighted_hops"] / ev_f["weighted_hops"]
        lat_ratio = ev_n["latency_max"] / max(ev_f["latency_max"], 1e-12)
        assert wh_ratio <= 1.05, \
            (f"{name}: hierarchical weighted_hops {wh_ratio:.3f}x flat "
             f"exceeds the 5% budget")
        assert points_ratio >= 0.75 * cores_per_node, \
            (f"{name}: engine-pass point ratio {points_ratio:.1f} below "
             f"~{cores_per_node}x (hier must partition ~cores_per_node x "
             f"fewer points)")
        rec = {
            "t_flat_s": t_flat, "t_node_s": t_node,
            "speedup": t_flat / max(t_node, 1e-9),
            "points_ratio": points_ratio,
            "wh_ratio": wh_ratio, "lat_ratio": lat_ratio,
            "wh_flat": ev_f["weighted_hops"],
            "wh_node": ev_n["weighted_hops"],
            "refine_accepted": res_n.stats["refine_accepted"],
        }

        # -- deep hierarchies: depth-3 must MATCH depth-2 wall-clock
        # while staying within 5% quality; depth-4 is recorded for the
        # trajectory (its sweep shrinks another arity x) --------------
        for d in depths:
            t_d, res_d = _timed(deep[d])
            if d == 3 and check_speed:
                t_d = min(t_d, _timed(deep[d])[0])
                for _ in range(3):
                    if t_node / t_d >= 1.0:
                        break
                    t_d = min(t_d, _timed(deep[d])[0])
            _assert_hier_invariants(f"{name}-depth{d}", res_d, n)
            ev_d = evaluate(graph, alloc, res_d)
            wh_vs_d2 = ev_d["weighted_hops"] / ev_n["weighted_hops"]
            if d == 3:
                assert wh_vs_d2 <= 1.05, \
                    (f"{name}: depth-3 weighted_hops {wh_vs_d2:.3f}x "
                     f"depth-2 exceeds the 5% budget")
            rec[f"t_d{d}_s"] = t_d
            rec[f"d{d}_vs_d2"] = t_node / max(t_d, 1e-9)
            rec[f"wh_ratio_d{d}"] = (ev_d["weighted_hops"]
                                     / ev_f["weighted_hops"])
            rec[f"wh_d{d}_vs_d2"] = wh_vs_d2
            rec[f"points_ratio_d{d}"] = (res_f.stats["sweep_points"]
                                         / res_d.stats["sweep_points"])
            rec[f"levels_d{d}"] = _level_summary(res_d)
        if check_speed:
            assert rec["d3_vs_d2"] >= 1.0, \
                (f"{name}: depth-3 wall-clock {rec['d3_vs_d2']:.2f}x "
                 f"depth-2 — must at least match it at n={n}")

        out["scenarios"][name] = rec
        if not quiet:
            print(f"[hier] {name}: flat {t_flat:.2f}s / d2 "
                  f"{t_node:.2f}s ({rec['speedup']:.1f}x) / d3 "
                  f"{rec['t_d3_s']:.2f}s (d3_vs_d2 "
                  f"{rec['d3_vs_d2']:.2f}x), wh_ratio {wh_ratio:.3f}, "
                  f"wh_ratio_d3 {rec['wh_ratio_d3']:.3f}, points "
                  f"{points_ratio:.0f}x / {rec['points_ratio_d3']:.0f}x "
                  f"fewer")

    first = out["scenarios"][SCENARIOS[0][0]]
    if check_speed:
        assert first["speedup"] >= speed_floor, \
            (f"hierarchical end-to-end speedup {first['speedup']:.1f}x "
             f"below the {speed_floor:.0f}x floor at n={n}")
    return out


def headline(results: dict) -> str:
    first = results["scenarios"][SCENARIOS[0][0]]
    second = results["scenarios"][SCENARIOS[1][0]]
    return (f"n={results['n']};cores_per_node={results['cores_per_node']};"
            f"flat_vs_hier={first['speedup']:.1f}x;"
            f"points_ratio={first['points_ratio']:.1f};"
            f"wh_ratio={first['wh_ratio']:.4f};"
            f"wh_ratio_sparse={second['wh_ratio']:.4f};"
            f"lat_ratio={first['lat_ratio']:.4f};"
            f"d3_vs_d2={first['d3_vs_d2']:.2f}x;"
            f"wh_ratio_d3={first['wh_ratio_d3']:.4f};"
            f"wh_ratio_d3_sparse={second['wh_ratio_d3']:.4f};"
            f"points_ratio_d3={first['points_ratio_d3']:.1f};"
            f"points_ratio_d4={first['points_ratio_d4']:.1f};"
            f"refine_monotone=1")


def main():
    # the ISSUE-3 flagship point: 2^20 tasks / 64K+ allocated nodes
    results = run(n=1 << 20, cores_per_node=16)
    t = results["scenarios"][SCENARIOS[0][0]]["t_node_s"]
    print(f"hier,{t*1e6:.0f},{headline(results)}")


if __name__ == "__main__":
    main()
