"""Flat vs hierarchical mapping engine (the `hier` benchmark entry).

MiniGhost-style weak-scaling scenarios on a sparse-allocation Cray XK7:
a 3D stencil with one task per core, mapped by the flat pipeline (one
point per core) and the hierarchical subsystem
(``PipelineConfig(hierarchy="node")``: coarsen to node-sized clusters,
rotation-sweep at router granularity, monotone swap refinement, expand
in intra-node SFC order).

Reported per scenario (and recorded by ``run.py --json`` for the bench
trajectory): the flat/hier wall-clock ratio, the engine-pass point
ratio (~cores_per_node x fewer points per sweep pass), and the
hier/flat quality ratios (weighted_hops, latency_max).  Oracles
asserted on every run:

- hier partitions ~cores_per_node x fewer points per engine pass;
- hier ``weighted_hops`` within 5% of (or better than) flat on BOTH
  scenarios;
- the refinement trajectory is monotone (never worsens the objective);
- the expanded mapping is a core-level bijection.

The speedup floor (>=4x end-to-end at 2^18 tasks, ISSUE 3) is enforced
unless ``check_speed=False`` (the CI smoke pass runs tiny sizes where
constant overheads dominate and only the oracles are meaningful).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (Mapper, MapperConfig, evaluate, gemini_xk7,
                        sfc_allocation, stencil_graph)

ROTATIONS = 8  # the MiniGhost benchmark's §4.3 search budget

SCENARIOS = (
    ("minighost", dict(nfragments=8, seed=0)),
    ("xk7_sparse", dict(nfragments=32, seed=3)),
)


def _grid(n: int) -> tuple[int, int, int]:
    """Near-cubic power-of-two task grid with prod = n."""
    e = int(np.log2(n))
    a = e // 3
    return (1 << (e - 2 * a), 1 << a, 1 << a)


def _machine(n: int, cores_per_node: int):
    """XK7-like torus with ~2x the routers the job needs (so sparse
    fragmented allocations have room to scatter)."""
    e = int(np.log2(max(2 * n // cores_per_node, 8)))
    a = e // 3
    rdims = (1 << (e - 2 * a), 1 << a, 1 << a)
    return gemini_xk7(dims=rdims, cores_per_node=cores_per_node)


def run(n: int = 1 << 18, cores_per_node: int = 16, *,
        rotations: int = ROTATIONS, check_speed: bool = True,
        speed_floor: float = 4.0, quiet: bool = False) -> dict:
    machine = _machine(n, cores_per_node)
    graph = stencil_graph(_grid(n), torus=False)
    flat = Mapper(MapperConfig(sfc="FZ", shift=True, rotations=rotations))
    node = Mapper(MapperConfig(sfc="FZ", shift=True, rotations=rotations,
                               hierarchy="node"))

    out: dict = {"n": n, "cores_per_node": cores_per_node,
                 "scenarios": {}}
    for i, (name, alloc_kw) in enumerate(SCENARIOS):
        alloc = sfc_allocation(machine, n, **alloc_kw)

        def _timed(mapper, alloc=alloc):
            t0 = time.perf_counter()
            res = mapper.map(graph, alloc)
            return time.perf_counter() - t0, res

        t_flat, res_f = _timed(flat)
        t_node, res_n = _timed(node)
        if i == 0 and check_speed:
            # symmetric best-of-2 on BOTH sides, then keep resampling
            # the hier side while the floor fails (a single descheduled
            # window must not fail the floor — candidates-bench style)
            t_flat = min(t_flat, _timed(flat)[0])
            t_node = min(t_node, _timed(node)[0])
            for _ in range(3):
                if t_flat / t_node >= speed_floor:
                    break
                t_node = min(t_node, _timed(node)[0])

        assert np.array_equal(np.sort(res_n.task_to_proc), np.arange(n)), \
            f"{name}: hierarchical mapping is not a core-level bijection"
        hist = [h[0] for h in res_n.stats["refine_history"]]
        assert all(b <= a + 1e-9 for a, b in zip(hist, hist[1:])), \
            f"{name}: refinement worsened the objective: {hist}"

        ev_f = evaluate(graph, alloc, res_f)
        ev_n = evaluate(graph, alloc, res_n)
        points_ratio = (res_f.stats["sweep_points"]
                        / res_n.stats["sweep_points"])
        wh_ratio = ev_n["weighted_hops"] / ev_f["weighted_hops"]
        lat_ratio = ev_n["latency_max"] / max(ev_f["latency_max"], 1e-12)
        assert wh_ratio <= 1.05, \
            (f"{name}: hierarchical weighted_hops {wh_ratio:.3f}x flat "
             f"exceeds the 5% budget")
        assert points_ratio >= 0.75 * cores_per_node, \
            (f"{name}: engine-pass point ratio {points_ratio:.1f} below "
             f"~{cores_per_node}x (hier must partition ~cores_per_node x "
             f"fewer points)")
        out["scenarios"][name] = {
            "t_flat_s": t_flat, "t_node_s": t_node,
            "speedup": t_flat / max(t_node, 1e-9),
            "points_ratio": points_ratio,
            "wh_ratio": wh_ratio, "lat_ratio": lat_ratio,
            "wh_flat": ev_f["weighted_hops"],
            "wh_node": ev_n["weighted_hops"],
            "refine_accepted": res_n.stats["refine_accepted"],
        }
        if not quiet:
            s = out["scenarios"][name]
            print(f"[hier] {name}: flat {t_flat:.2f}s / node "
                  f"{t_node:.2f}s ({s['speedup']:.1f}x), wh_ratio "
                  f"{wh_ratio:.3f}, lat_ratio {lat_ratio:.3f}, "
                  f"points {points_ratio:.0f}x fewer")

    first = out["scenarios"][SCENARIOS[0][0]]
    if check_speed:
        assert first["speedup"] >= speed_floor, \
            (f"hierarchical end-to-end speedup {first['speedup']:.1f}x "
             f"below the {speed_floor:.0f}x floor at n={n}")
    return out


def headline(results: dict) -> str:
    first = results["scenarios"][SCENARIOS[0][0]]
    second = results["scenarios"][SCENARIOS[1][0]]
    return (f"n={results['n']};cores_per_node={results['cores_per_node']};"
            f"flat_vs_hier={first['speedup']:.1f}x;"
            f"points_ratio={first['points_ratio']:.1f};"
            f"wh_ratio={first['wh_ratio']:.4f};"
            f"wh_ratio_sparse={second['wh_ratio']:.4f};"
            f"lat_ratio={first['lat_ratio']:.4f};"
            f"refine_monotone=1")


def main():
    # the ISSUE-3 flagship point: 2^20 tasks / 64K+ allocated nodes
    results = run(n=1 << 20, cores_per_node=16)
    t = results["scenarios"][SCENARIOS[0][0]]["t_node_s"]
    print(f"hier,{t*1e6:.0f},{headline(results)}")


if __name__ == "__main__":
    main()
