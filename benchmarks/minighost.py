"""MiniGhost weak scaling on a sparse-allocation Cray XK7 (Figs. 13-15).

Reproduces the paper's experiment structure: a 3D 7-point stencil, one
task per core, weak scaling 8K -> 128K cores on ALPS-style (Hilbert SFC)
sparse allocations of a Titan-like Gemini torus.  Mappings compared:

- Default : task i -> core i of the allocation (MPI rank order).
- Group   : MiniGhost's application-specific 2x2x4 blocking per node.
- Z2_1    : geometric mapping, FZ ordering (paper Alg. 1).
- Z2_2    : + largest-prime uneven bisection + bandwidth-scaled coords.
- Z2_3    : + 2x2x8 box lift (3D -> 6D node coordinates).

We report AverageHops and Latency(M) (Eqn. 7).  The paper's findings to
match: Default's hops/latency GROW with core count; Z2_1/Z2_2 stay ~flat
(the scalability claim); Z2_3 trades higher hops for lower bottleneck
Latency; geometric mappings beat Default by large factors at 128K.

All Z2 variants run through ``repro.core.Mapper`` -> the unified
``repro.mapping`` pipeline (vectorised partitioner + shared candidate
search).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (Mapper, MapperConfig, MappingResult, evaluate,
                        gemini_xk7, identity_mapping, sfc_allocation,
                        stencil_graph)

TASK_GRIDS = {
    8192: (32, 16, 16),
    16384: (32, 32, 16),
    32768: (32, 32, 32),
    65536: (64, 32, 32),
    131072: (64, 64, 32),
}

CORES_PER_ROUTER = 32  # 2 nodes x 16 cores share a Gemini router on XK7

# §4.3 rotation-search budget per Z2 variant (the batched sweep makes a
# real search affordable; pre-batching this was 0 = identity only).
ROTATIONS = 8


def group_mapping(dims, alloc, block=(2, 2, 4)) -> MappingResult:
    """MiniGhost's Group reordering: tasks in 2x2x4 blocks fill a node."""
    dims = np.asarray(dims)
    blk = np.asarray(block)
    nb = dims // blk
    idx = np.arange(int(np.prod(dims))).reshape(dims)
    # order: iterate blocks row-major, then cells within a block
    order = (idx.reshape(nb[0], blk[0], nb[1], blk[1], nb[2], blk[2])
             .transpose(0, 2, 4, 1, 3, 5).reshape(-1))
    # rank r (allocation core r) executes task order[r]
    t2p = np.empty_like(order)
    t2p[order] = np.arange(len(order))
    return MappingResult(t2p)


def run_point(ncores: int, seed: int, *, nfragments: int = 8) -> dict:
    machine = gemini_xk7(dims=(25, 16, 24), cores_per_node=CORES_PER_ROUTER)
    alloc = sfc_allocation(machine, ncores, nfragments=nfragments,
                           seed=seed)
    dims = TASK_GRIDS[ncores]
    graph = stencil_graph(dims, torus=False, weight=1.0)

    mappers = {
        "Default": None,
        "Group": "group",
        "Z2_1": Mapper(MapperConfig(sfc="FZ", shift=True,
                                    rotations=ROTATIONS)),
        "Z2_2": Mapper(MapperConfig(sfc="FZ", shift=True,
                                    bandwidth_scale=True,
                                    uneven_prime=True,
                                    rotations=ROTATIONS)),
        "Z2_3": Mapper(MapperConfig(sfc="FZ", shift=True,
                                    bandwidth_scale=True,
                                    uneven_prime=True, box=(2, 2, 8),
                                    rotations=ROTATIONS)),
    }
    out = {}
    for name, mapper in mappers.items():
        if mapper is None:
            res = identity_mapping(graph, alloc)
        elif mapper == "group":
            res = group_mapping(dims, alloc)
        else:
            res = mapper.map(graph, alloc)
        m = evaluate(graph, alloc, res)
        out[name] = {"average_hops": m["average_hops"],
                     "latency_max": m["latency_max"],
                     "data_max": m["data_max"],
                     "weighted_hops": m["weighted_hops"]}
    return out


def run(core_counts=(8192, 16384, 32768, 65536, 131072), seeds=(0, 1),
        quiet=False) -> dict:
    results: dict = {}
    for n in core_counts:
        per_seed = [run_point(n, s) for s in seeds]
        agg = {}
        for name in per_seed[0]:
            agg[name] = {k: float(np.mean([p[name][k] for p in per_seed]))
                         for k in per_seed[0][name]}
        results[n] = agg
        if not quiet:
            msg = "  ".join(
                f"{m}: hops={v['average_hops']:.2f} lat={v['latency_max']:.1f}"
                for m, v in agg.items())
            print(f"[minighost] {n}: {msg}")
    return results


def headline(results) -> dict:
    """Paper-comparable summary: reduction vs Default / Group at top."""
    top = max(results)
    r = results[top]
    best_geo = min(r[k]["latency_max"] for k in ("Z2_1", "Z2_2", "Z2_3"))
    geo_hops_top = min(r[k]["average_hops"] for k in ("Z2_1", "Z2_2"))
    geo_hops_bot = min(results[min(results)][k]["average_hops"]
                       for k in ("Z2_1", "Z2_2"))
    return {
        "latency_reduction_vs_default": 1 - best_geo / r["Default"][
            "latency_max"],
        "latency_reduction_vs_group": 1 - best_geo / r["Group"][
            "latency_max"],
        "geo_hops_growth_weak_scaling": geo_hops_top / max(geo_hops_bot,
                                                           1e-9),
        "default_hops_growth": (r["Default"]["average_hops"] /
                                results[min(results)]["Default"][
                                    "average_hops"]),
    }


def main():
    t0 = time.perf_counter()
    results = run()
    h = headline(results)
    dt = (time.perf_counter() - t0) * 1e6 / max(len(results), 1)
    print(f"minighost,{dt:.0f},lat_red_vs_default={h['latency_reduction_vs_default']:.2f}"
          f";lat_red_vs_group={h['latency_reduction_vs_group']:.2f}"
          f";geo_growth={h['geo_hops_growth_weak_scaling']:.2f}"
          f";default_growth={h['default_hops_growth']:.2f}")


if __name__ == "__main__":
    main()
