"""HOMME on BlueGene/Q (paper Table 2 + Figs. 8-9).

Cubed-sphere atmosphere mesh mapped onto a 5D-torus block allocation.
Compared mappings (paper §5.2):

- SFC      : HOMME's Hilbert SFC partition of cube faces + ABCDET rank
             order (the application default).
- SFC+Z2   : SFC partition, then OUR geometric mapping of the parts.
- Z2       : our one-step partition+map (tnum > pnum path of Alg. 1).

Each Z2 variant runs with Sphere / Cube / 2DFace task-coordinate
transforms (Fig. 7) and optionally the "+E" architecture optimisation
(drop the E dim from node coords so E-neighbour pairs stay together).

Wall-clock is not measurable here; we report the paper's §3 metrics.
Z2 variants run through the unified ``repro.mapping`` pipeline via
``repro.core.Mapper``.
Findings to match: per-dim Data — SFC overloads D/E links and starves
A/B/C; Z2 balances them and cuts max Data; improvements grow with rank
count (8K -> 32K).
"""

from __future__ import annotations

import time

import numpy as np

from repro.core import (Mapper, MapperConfig, MappingResult, bgq,
                        block_allocation, cube_coords, cube_sphere_graph,
                        evaluate, face2d_coords)
from repro.core.orderings import hilbert_index


# allocation shapes used on Mira for 512/1024/2048 nodes (x16 ranks/node)
ALLOC_DIMS = {
    8192: (4, 4, 4, 4, 2),
    16384: (4, 4, 4, 8, 2),
    32768: (4, 4, 4, 16, 2),
}

NE = 128  # 6*128*128 = 98,304 elements (the paper's hybrid/MPI dataset)

# §4.3 rotation-search budget per Z2 mapping.  The batched sweep
# partitions all rotations in ~2 engine passes, so a real search is now
# affordable where the pre-batching default was 0 (identity only).
ROTATIONS = 8


def homme_sfc_parts(ne: int, nparts: int) -> np.ndarray:
    """HOMME's default partition: Hilbert SFC on each cube face,
    faces concatenated, split into equal contiguous chunks."""
    n = 6 * ne * ne
    rem = np.arange(n) % (ne * ne)
    fi, fj = rem // ne, rem % ne
    bits = int(np.ceil(np.log2(ne)))
    h = hilbert_index(np.stack([fi, fj], axis=1), bits)
    order = np.argsort(np.arange(n) // (ne * ne) * (4 ** bits + 1) + h,
                       kind="stable")
    parts = np.zeros(n, dtype=np.int64)
    bounds = (np.arange(1, nparts) * n) // nparts
    parts[order] = np.searchsorted(bounds, np.arange(n), side="right")
    return parts


def sfc_mapping(graph, alloc, nranks: int) -> MappingResult:
    """SFC partition + ABCDET rank order == part i -> core i."""
    parts = homme_sfc_parts(NE, nranks)
    return MappingResult(parts)  # core index == part index (ABCDET order)


def run_point(nranks: int, *, transforms=("sphere", "cube", "face2d"),
              plus_e=(False, True)) -> dict:
    machine = bgq(dims=ALLOC_DIMS[nranks], cores_per_node=16)
    alloc = block_allocation(machine)
    graph = cube_sphere_graph(NE)
    assert alloc.n == nranks

    coords_by_name = {
        "sphere": graph.coords,
        "cube": cube_coords(NE),
        "face2d": face2d_coords(NE),
    }
    out = {}
    base = evaluate(graph, alloc, sfc_mapping(graph, alloc, nranks))
    out["SFC"] = base

    for tname in transforms:
        tc = coords_by_name[tname]
        for pe in plus_e:
            drop = (4,) if pe else ()   # E is dim index 4 of (A,B,C,D,E)
            tag = f"Z2-{tname}" + ("+E" if pe else "")
            mapper = Mapper(MapperConfig(sfc="FZ", shift=True, drop=drop,
                                         rotations=ROTATIONS))
            res = mapper.map(graph, alloc, task_coords=tc)
            out[tag] = evaluate(graph, alloc, res)

            # SFC+Z2: map SFC parts (with centroid coords) via Z2
            parts = homme_sfc_parts(NE, nranks)
            cent = np.zeros((nranks, tc.shape[1]))
            np.add.at(cent, parts, tc)
            cent /= np.bincount(parts, minlength=nranks)[:, None]
            pres = mapper.map(
                _part_graph(graph, parts, nranks), alloc,
                task_coords=cent)
            # compose: element -> part -> core
            res2 = MappingResult(pres.task_to_proc[parts])
            out[f"SFC+Z2-{tname}" + ("+E" if pe else "")] = evaluate(
                graph, alloc, res2)
    return out


def _part_graph(graph, parts, nparts):
    """Quotient graph of parts (edges between parts, summed weights)."""
    from repro.core.taskgraph import TaskGraph
    pe = parts[graph.edges]
    m = pe[:, 0] != pe[:, 1]
    eid = pe[m]
    w = graph.weights[m]
    key = eid[:, 0] * nparts + eid[:, 1]
    uniq, inv = np.unique(key, return_inverse=True)
    ww = np.zeros(len(uniq))
    np.add.at(ww, inv, w)
    edges = np.stack([uniq // nparts, uniq % nparts], axis=1)
    coords = np.zeros((nparts, 1))
    return TaskGraph(coords, edges, ww)


def summarize(res: dict) -> dict:
    base = res["SFC"]
    out = {}
    for k, v in res.items():
        out[k] = {
            "weighted_hops": v["weighted_hops"],
            "data_max": v["data_max"],
            "latency_max": v["latency_max"],
            "wh_vs_sfc": v["weighted_hops"] / max(base["weighted_hops"], 1),
            "data_vs_sfc": v["data_max"] / max(base["data_max"], 1e-9),
        }
    return out


def per_dim_table(res: dict, keys=("SFC", "Z2-face2d+E")) -> dict:
    """Fig. 9 analogue: max Data per network dimension A..E."""
    dims = "ABCDE"
    table = {}
    for k in keys:
        if k not in res:
            continue
        per = res[k]["per_dim"]
        table[k] = {dims[i]: per[f"dim{i}+"]["data_max"] +
                    per[f"dim{i}-"]["data_max"] for i in range(5)}
    return table


def run(rank_counts=(8192, 16384, 32768), quiet=False):
    results = {}
    for n in rank_counts:
        r = run_point(n)
        results[n] = summarize(r)
        results[n]["_per_dim"] = per_dim_table(r)
        if not quiet:
            best = min((v["data_vs_sfc"], k) for k, v in results[n].items()
                       if not k.startswith("_"))
            print(f"[homme_bgq] {n} ranks: best Data(M) vs SFC = "
                  f"{best[0]:.2f} ({best[1]})")
    return results


def main():
    t0 = time.perf_counter()
    results = run()
    top = max(results)
    best = min((v["data_vs_sfc"], k) for k, v in results[top].items()
               if not k.startswith("_"))
    dt = (time.perf_counter() - t0) * 1e6 / len(results)
    print(f"homme_bgq,{dt:.0f},best_data_vs_sfc_at_{top}={best[0]:.3f}"
          f";variant={best[1]}")


if __name__ == "__main__":
    main()
