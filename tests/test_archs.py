"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates a REDUCED same-family config and runs a
real forward + train step on CPU, asserting output shapes and finiteness.
The FULL configs are exercised via the dry-run only (no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import (ModelConfig, init_cache, decode_step, logits_fn,
                          loss_fn, params_spec, reduced, tree_init)
from repro.train.optimizer import OptConfig, apply_updates, init_state


def _batch(cfg: ModelConfig, b=2, s=32):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)),
                              jnp.int32),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder_seq, cfg.frontend_dim)),
            jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.num_patches, cfg.frontend_dim)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    full = get_config(arch)
    cfg = reduced(full, dtype="float32")
    assert cfg.family == full.family
    params = tree_init(params_spec(cfg), jax.random.PRNGKey(0), cfg.dtype)
    batch = _batch(cfg)

    logits = jax.jit(lambda p, b: logits_fn(cfg, p, b))(params, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    opt_cfg = OptConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    opt = init_state(opt_cfg, params)

    def train_step(p, o, b):
        loss, grads = jax.value_and_grad(lambda pp: loss_fn(cfg, pp, b))(p)
        p2, o2, m = apply_updates(opt_cfg, p, grads, o)
        return p2, o2, loss

    p2, o2, loss = jax.jit(train_step)(params, opt, batch)
    assert np.isfinite(float(loss))
    # params actually moved
    moved = jax.tree.map(lambda a, b_: float(jnp.abs(a - b_).max()),
                         params, p2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_decode_step(arch):
    full = get_config(arch)
    cfg = reduced(full, dtype="float32")
    params = tree_init(params_spec(cfg), jax.random.PRNGKey(1), cfg.dtype)
    b, max_seq = 2, 16
    cache = init_cache(cfg, b, max_seq)
    if cfg.family == "encdec":
        # cross-kv cache must be populated for a meaningful check; zeros OK
        pass
    tok = jnp.ones((b, 1), jnp.int32)
    logits, new_cache = jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, t, jnp.int32(3)))(
        params, cache, tok)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_all_archs_registered():
    assert len(ARCHS) == 10
    names = {get_config(a).name for a in ARCHS}
    assert names == {
        "whisper-small", "yi-6b", "gemma3-27b", "minitron-4b", "gemma2-27b",
        "grok-1-314b", "mixtral-8x22b", "zamba2-1.2b", "mamba2-2.7b",
        "internvl2-26b"}


def test_full_configs_match_assignment():
    """Spot-check the exact assigned hyperparameters."""
    g = get_config("grok-1-314b")
    assert (g.num_layers, g.d_model, g.num_heads, g.num_kv_heads,
            g.d_ff, g.vocab_size) == (64, 6144, 48, 8, 32768, 131072)
    assert (g.num_experts, g.experts_per_tok) == (8, 2)
    m = get_config("mamba2-2.7b")
    assert (m.num_layers, m.d_model, m.vocab_size, m.ssm_state) == (
        64, 2560, 50280, 128)
    assert m.num_heads == 0
    z = get_config("zamba2-1.2b")
    assert (z.num_layers, z.d_model, z.num_heads, z.num_kv_heads,
            z.d_ff, z.vocab_size, z.ssm_state) == (
        38, 2048, 32, 32, 8192, 32000, 64)
    w = get_config("whisper-small")
    assert (w.num_layers, w.d_model, w.num_heads, w.d_ff,
            w.vocab_size) == (12, 768, 12, 3072, 51865)
    g3 = get_config("gemma3-27b")
    assert (g3.num_layers, g3.d_model, g3.vocab_size) == (62, 5376, 262144)
    assert g3.global_every == 6  # 5:1 local:global
