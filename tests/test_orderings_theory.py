"""Appendix A closed-form validation (Eqns. 17-23).

The paper derives the total directed hop count of mapping a 1D task chain
(td=1) onto a pd=2 grid (the m = pd/td = 2 case of A.3):

  TotalHopsZ = 2^{C+2} - 4*2^{C/2}            (C even)
               2^{C+2} - 3*2^{(C+1)/2}        (C odd)
  TotalHopsF = 2^{C+2} - 6*2^{C/2} + 2        (C even)
               2^{C+2} - 4*2^{(C+1)/2} + 2    (C odd)

where C = log2(#tasks) and hops are counted once per *directed* message
(each neighbouring pair exchanges two messages).  We check our orderings
against these exactly; FZ < Z for every C, as the paper concludes.
"""

import numpy as np
import pytest

from repro.core.orderings import grid_order, gray_encode


def _chain_total_hops_directed(C: int, order: str) -> int:
    """Total directed hops of the 1D->2D mapping under Z or FZ."""
    assert C % 2 == 0, "pd=2 grid needs even C"
    n = 2 ** C
    side = 2 ** (C // 2)
    gp = grid_order((side, side), order)
    pos = np.zeros((n, 2), dtype=np.int64)
    ix = np.indices((side, side))
    pos[gp.ravel()] = np.stack([c.ravel() for c in ix], axis=1)
    mu = np.arange(n) if order == "Z" else gray_encode(np.arange(n))
    p = pos[mu]
    return 2 * int(np.abs(p[1:] - p[:-1]).sum())  # both directions


@pytest.mark.parametrize("C", [4, 6, 8, 10])
def test_total_hops_z_closed_form(C):
    pred = 2 ** (C + 2) - 4 * 2 ** (C // 2)
    assert _chain_total_hops_directed(C, "Z") == pred


@pytest.mark.parametrize("C", [4, 6, 8, 10])
def test_total_hops_fz_closed_form(C):
    pred = 2 ** (C + 2) - 6 * 2 ** (C // 2) + 2
    assert _chain_total_hops_directed(C, "FZ") == pred


@pytest.mark.parametrize("C", [4, 6, 8, 10])
def test_fz_beats_z(C):
    assert (_chain_total_hops_directed(C, "FZ")
            < _chain_total_hops_directed(C, "Z"))


def test_nhf_single_dimension_property():
    """App. A.2: FZ neighbours differ in one Gray bit => hops along only a
    single processor dimension (pd=td case: always exactly 1 hop)."""
    side, d = 8, 2
    n = side ** d
    gt = grid_order((side,) * d, "FZ")
    pos = np.zeros((n, d), dtype=np.int64)
    ix = np.indices((side,) * d)
    pos[gt.ravel()] = np.stack([c.ravel() for c in ix], axis=1)
    # pd == td: identical partitions -> every task sits on "its" node and
    # task neighbours are node neighbours (NHZ == NHF == 1)
    tpos = pos[gt]
    for k in range(d):
        a = np.moveaxis(tpos, k, 0)
        dist = np.abs(a[1:] - a[:-1]).sum(axis=-1)
        assert (dist == 1).all()
