"""ISSUE-4 coverage: the Pallas mapscore kernel and the bucketed
compile caches.

- the fused kernel (interpret mode on CPU) must match the naive
  per-message reference router of tests/test_batched.py across
  wrap/non-wrap dims, heterogeneous bandwidths, core dims, wrapped
  torus edges, zero-length messages and zero-weight padding;
- power-of-two message bucketing must be EXACT (adding explicit
  zero-weight self-edges changes no output bit), including the padded
  tail of non-power-of-two message counts;
- ``backend="pallas"`` winners through :class:`CandidateSearch` must be
  bit-identical to the numpy oracle's lexsort order;
- the fallback chain pallas -> jax -> numpy must degrade silently,
  including the VMEM-budget fallback for oversized machines;
- both bucketed compile caches (jax scorer, pallas kernel) must HIT
  when message counts share a bucket — the recompile-storm guard.
"""

import numpy as np
import pytest

from test_batched import MACHINES, _route_naive

from repro.core import (block_allocation, make_machine, stencil_graph,
                        tpu_v5e_multipod)
from repro.core import metrics as M
from repro.core import metrics_jax
from repro.core.metrics import evaluate_candidates, get_evaluator
from repro.kernels.mapscore import ops as mops
from repro.kernels.mapscore.ref import mapscore_ref
from repro.mapping import (CandidateSearch, HierarchySpec,
                           MappingPipeline, PipelineConfig)
from repro.mapping.candidates import rotation_candidates


def _random_problem(machine, seed, ntasks=40, ne=120, nb=4):
    rng = np.random.default_rng(seed)
    stack = np.stack([
        np.stack([rng.integers(0, machine.dims[j], size=ntasks)
                  for j in range(machine.ndim)], axis=1)
        for _ in range(nb)])
    edges = rng.integers(0, ntasks, size=(ne, 2))
    w = rng.uniform(0.5, 2.0, size=ne)
    return stack, edges, w


# ---------------------------------------------------------------------------
# kernel parity vs the naive reference router / numpy backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mi", range(len(MACHINES)))
def test_pallas_route_matches_naive_reference(mi):
    assert M._pallas_evaluator() is not None  # parity must not be vacuous
    machine = MACHINES[mi]
    stack, edges, w = _random_problem(machine, 31 * mi + 7, nb=1)
    ref_pos, ref_neg = _route_naive(
        machine, stack[0][edges[:, 0]], stack[0][edges[:, 1]], w)
    ev = evaluate_candidates(machine, edges, w, stack, traffic=True,
                             backend="pallas")
    nd = machine.ndim - machine.core_dims
    data_ref = max(float(a.max()) for k in range(nd)
                   for a in (ref_pos[k], ref_neg[k]))
    lat_ref = max(float((a / machine.bw_field(k)).max()) for k in range(nd)
                  for a in (ref_pos[k], ref_neg[k]))
    assert np.allclose(ev["data_max"][0], data_ref, rtol=1e-4)
    assert np.allclose(ev["latency_max"][0], lat_ref, rtol=1e-4)


@pytest.mark.parametrize("mi", range(len(MACHINES)))
def test_pallas_scoring_parity_all_keys(mi):
    machine = MACHINES[mi]
    stack, edges, w = _random_problem(machine, mi)
    a = evaluate_candidates(machine, edges, w, stack, traffic=True,
                            backend="numpy")
    b = evaluate_candidates(machine, edges, w, stack, traffic=True,
                            backend="pallas")
    assert set(a) == set(b)
    for key in a:
        assert np.allclose(a[key], b[key], rtol=1e-4, atol=1e-4), key
    # integer metrics cross the kernel exactly, not just within tolerance
    assert np.array_equal(a["total_hops"], b["total_hops"])
    assert np.array_equal(a["average_hops"], b["average_hops"])


def test_pallas_wrapped_torus_edges():
    """Messages crossing the wraparound seam in both directions."""
    machine = make_machine((6, 5), wrap=(True, True))
    coords = np.array([[5, 4], [0, 0], [1, 1], [4, 3]])
    # 5->0 wraps +x; 0->5 wraps -x; 4->1 wraps +y via 4,0; long both ways
    edges = np.array([[0, 1], [1, 0], [3, 2], [0, 3]])
    w = np.array([2.0, 3.0, 1.5, 2.5])
    ref_pos, ref_neg = _route_naive(machine, coords[edges[:, 0]],
                                    coords[edges[:, 1]], w)
    ev = evaluate_candidates(machine, edges, w, coords[None], traffic=True,
                             backend="pallas")
    data_ref = max(float(a.max())
                   for arrs in (ref_pos, ref_neg) for a in arrs)
    assert np.allclose(ev["data_max"][0], data_ref, rtol=1e-5)


def test_pallas_zero_length_and_zero_weight():
    machine = make_machine((8, 8), wrap=True)
    rng = np.random.default_rng(3)
    stack = rng.integers(0, 8, size=(3, 30, 2))
    edges = np.array([[0, 0], [1, 1], [2, 5], [7, 7]])
    w = np.array([3.0, 0.0, 2.0, 0.0])
    a = evaluate_candidates(machine, edges, w, stack, traffic=True,
                            backend="numpy")
    b = evaluate_candidates(machine, edges, w, stack, traffic=True,
                            backend="pallas")
    for key in a:
        assert np.allclose(a[key], b[key], rtol=1e-4, atol=1e-4), key


def test_ref_matches_naive_reference():
    machine = MACHINES[3]  # gemini: core dims + heterogeneous bandwidth
    stack, edges, w = _random_problem(machine, 11, nb=2)
    src = stack[:, edges[:, 0]]
    dst = stack[:, edges[:, 1]]
    ref = mapscore_ref(machine, src, dst, w, traffic=True)
    for b in range(2):
        pos, neg = _route_naive(machine, src[b], dst[b], w)
        data = max(float(a.max()) for arrs in (pos, neg) for a in arrs)
        assert np.isclose(ref["data_max"][b], data)


# ---------------------------------------------------------------------------
# bucketed / padded shapes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ne", [1, 31, 127, 128, 129])
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_bucket_tail_parity(ne, backend):
    """Message counts straddling the padded power-of-two buckets."""
    machine = make_machine((4, 5, 3), wrap=(True, False, True),
                           bw=(2.0, 1.0, 4.0))
    stack, edges, w = _random_problem(machine, ne, ne=ne, nb=3)
    a = evaluate_candidates(machine, edges, w, stack, traffic=True,
                            backend="numpy")
    b = evaluate_candidates(machine, edges, w, stack, traffic=True,
                            backend=backend)
    for key in a:
        assert np.allclose(a[key], b[key], rtol=1e-4, atol=1e-4), key


@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_explicit_zero_weight_padding_is_exact(backend):
    """Hand-padding with zero-weight self-edges must change NO bit —
    the property the power-of-two bucketing relies on."""
    machine = make_machine((6, 6), wrap=True)
    stack, edges, w = _random_problem(machine, 5, ne=50, nb=2)
    pad = np.zeros((30, 2), dtype=edges.dtype)  # task 0 -> task 0
    a = evaluate_candidates(machine, edges, w, stack, traffic=True,
                            backend=backend)
    b = evaluate_candidates(machine, np.vstack([edges, pad]),
                            np.concatenate([w, np.zeros(30)]), stack,
                            traffic=True, backend=backend)
    for key in ("weighted_hops", "total_hops", "data_max", "latency_max"):
        assert np.array_equal(a[key], b[key]), key
    # averages differ only by the true-count denominator
    assert np.allclose(b["average_hops"] * 80 / 50, a["average_hops"])


# ---------------------------------------------------------------------------
# winner bit-identity through the candidate search
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("objective", [
    "weighted_hops", ("latency_max", "weighted_hops")],
    ids=["wh", "latency"])
def test_pallas_winner_bit_identical_to_numpy(objective):
    machine = tpu_v5e_multipod(2, 4)
    alloc = block_allocation(machine)
    g = stencil_graph((4, 8))
    pipe = MappingPipeline(PipelineConfig(sfc="FZ", rotations=10))
    pc = pipe.machine_coords(alloc)
    cands = rotation_candidates(2, pc.shape[1], 10)
    results = pipe.map_candidates(g.coords.astype(float), pc, cands)
    ref = CandidateSearch(objective, backend="numpy")
    best_np, i_np, _ = ref.best(g, alloc, results)
    best_pl, i_pl, _ = CandidateSearch(objective, backend="pallas").best(
        g, alloc, results)
    assert i_pl == i_np
    assert np.array_equal(best_pl.task_to_proc, best_np.task_to_proc)


def test_pipeline_pallas_scoring_backend_end_to_end():
    machine = tpu_v5e_multipod(2, 4)
    alloc = block_allocation(machine)
    g = stencil_graph((4, 8))
    res_np = MappingPipeline(PipelineConfig(
        sfc="FZ", rotations=10, score_backend="numpy")).map(g, alloc)
    res_pl = MappingPipeline(PipelineConfig(
        sfc="FZ", rotations=10, score_backend="pallas")).map(g, alloc)
    assert np.array_equal(res_np.task_to_proc, res_pl.task_to_proc)
    assert np.isclose(res_np.score, res_pl.score, rtol=1e-4)


def test_hier_refine_pallas_backend_monotone():
    """The hier swap refinement accepts the pallas scorer and stays
    monotone (same contract as numpy)."""
    machine = make_machine((4, 4, 4), wrap=True, core_dims=1,
                           name="m", bw=1.0)
    alloc = block_allocation(machine)
    g = stencil_graph((8, 8))
    res = MappingPipeline(PipelineConfig(
        hierarchy=HierarchySpec.node(refine_rounds=2), rotations=4,
        score_backend="pallas")).map(g, alloc)
    hist = res.stats["refine_history"]
    for earlier, later in zip(hist, hist[1:]):
        assert later[0] <= earlier[0] + 1e-9


# ---------------------------------------------------------------------------
# fallback chain
# ---------------------------------------------------------------------------

def test_pallas_falls_back_to_jax_then_numpy(monkeypatch):
    machine = make_machine((4, 4), wrap=True)
    rng = np.random.default_rng(0)
    stack = rng.integers(0, 4, size=(2, 10, 2))
    edges = rng.integers(0, 10, size=(20, 2))
    ref = evaluate_candidates(machine, edges, None, stack, traffic=True,
                              backend="numpy")
    monkeypatch.setattr(M, "_PALLAS_EVAL", None)  # kernel import failed
    assert get_evaluator("pallas")[0] == "jax"
    a = evaluate_candidates(machine, edges, None, stack, traffic=True,
                            backend="pallas")
    for key in ref:
        assert np.allclose(a[key], ref[key], rtol=1e-4), key
    monkeypatch.setattr(M, "_JAX_EVAL", None)  # jax gone too
    assert get_evaluator("pallas")[0] == "numpy"
    b = evaluate_candidates(machine, edges, None, stack, traffic=True,
                            backend="pallas")
    for key in ref:
        assert np.array_equal(b[key], ref[key]), key


def test_oversized_machine_falls_back_to_jax():
    machine = make_machine((128, 128, 64), wrap=True)
    assert mops.vmem_accumulator_bytes(machine) > mops.VMEM_ACC_BUDGET
    rng = np.random.default_rng(1)
    stack = np.stack([np.stack([rng.integers(0, machine.dims[j], size=12)
                                for j in range(3)], axis=1)])
    edges = rng.integers(0, 12, size=(20, 2))
    before = mops.scorer_cache_stats()
    a = evaluate_candidates(machine, edges, None, stack, traffic=True,
                            backend="pallas")
    after = mops.scorer_cache_stats()
    assert after["misses"] == before["misses"]  # no kernel was launched
    ref = evaluate_candidates(machine, edges, None, stack, traffic=True,
                              backend="numpy")
    for key in ref:
        assert np.allclose(a[key], ref[key], rtol=1e-4), key


def test_unknown_backend_rejected_by_resolver():
    with pytest.raises(ValueError):
        get_evaluator("torch")


# ---------------------------------------------------------------------------
# compile caches: shared buckets must HIT, not recompile
# ---------------------------------------------------------------------------

def test_jax_scorer_cache_hits_across_message_counts():
    machine = make_machine((5, 4), wrap=False)
    metrics_jax.reset_scorer_cache()
    for ne in (100, 120, 97):  # one bucket (128): ONE compile for all
        stack, edges, w = _random_problem(machine, ne, ne=ne, nb=4)
        evaluate_candidates(machine, edges, w, stack, traffic=True,
                            backend="jax")
    stats = metrics_jax.scorer_cache_stats()
    assert stats["misses"] == 1 and stats["hits"] == 2, stats
    # the underlying jitted program compiled exactly once
    fn = metrics_jax._scorer(
        tuple(machine.dims), tuple(machine.wrap), machine.core_dims,
        True, 128, 4)
    if hasattr(fn, "_cache_size"):
        assert fn._cache_size() == 1


def test_pallas_kernel_cache_hits_across_message_counts():
    machine = make_machine((6,), wrap=True)
    mops.reset_scorer_cache()
    for ne in (60, 90, 128):
        stack, edges, w = _random_problem(machine, ne, ne=ne, nb=2)
        evaluate_candidates(machine, edges, w, stack, traffic=True,
                            backend="pallas")
    stats = mops.scorer_cache_stats()
    assert stats["misses"] == 1 and stats["hits"] == 2, stats


def test_bucket_size_properties():
    assert metrics_jax.bucket_size(1) == metrics_jax.MSG_BUCKET_MIN
    assert metrics_jax.bucket_size(128) == 128
    assert metrics_jax.bucket_size(129) == 256
    assert metrics_jax.bucket_size(3, lo=1) == 4
    assert metrics_jax.bucket_size(1, lo=1) == 1
