"""Bit-identity suite for the device (JAX) partition backend.

The numpy engines (``partition.vectorized_order*``, whose lexsort tie
order is the oracle) define the contract: the jax backend must return
IDENTICAL permutations for every configuration — random dims, weights,
duplicate coordinates, uneven prime part counts, padded-bucket tails —
plus the device Hilbert kernel (Skilling's transpose, bit-identical to
``orderings.hilbert_index`` + stable lexsort), the resolved-once
fallback chain, truthful compile-cache counters, and the fused
whole-pipeline program (partition + match + score + select as ONE
jitted program).  Property-style via seeded numpy RNG (no
hypothesis dependency, matching tests/test_partition.py)."""

import numpy as np
import pytest

pytest.importorskip("jax")

from repro.core import orderings
from repro.core import partition_jax
from repro.core.orderings import (order_points, order_points_batched,
                                  resolve_partition_backend)

SFCS = ("Z", "Gray", "FZ", "FZlow")


def _assert_jax_equiv(coords, nparts, sfc, **kw):
    a = order_points(coords, nparts, sfc, backend="vectorized", **kw)
    b = order_points(coords, nparts, sfc, backend="jax", **kw)
    assert np.array_equal(a, b), (
        f"jax backend mismatch: sfc={sfc} nparts={nparts} kw={kw} "
        f"ndiff={(a != b).sum()}/{len(a)}")
    return a


# ---------------------------------------------------------------------------
# property-style bit-identity across every knob
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(16))
def test_random_points_all_knobs(seed):
    rng = np.random.default_rng(seed)
    d = int(rng.integers(1, 5))
    n = int(rng.integers(2, 400))
    nparts = int(rng.integers(1, 70))
    sfc = SFCS[seed % 4]
    weights = rng.random(n) if seed % 3 == 0 else None
    uneven = bool(seed % 2)
    longest = seed % 5 != 0
    dim_order = rng.permutation(d) if seed % 4 == 0 else None
    coords = rng.normal(size=(n, d))
    if seed % 6 == 0:  # duplicate-heavy: exercises the tie lexsort order
        coords = np.repeat(coords[: max(n // 5, 1)], 5, axis=0)
        if weights is not None:
            weights = rng.random(len(coords))
    _assert_jax_equiv(coords, nparts, sfc, weights=weights,
                      uneven_prime=uneven, longest_dim=longest,
                      dim_order=dim_order)


@pytest.mark.parametrize("seed", range(8))
def test_batched_bit_identity(seed):
    rng = np.random.default_rng(100 + seed)
    d = int(rng.integers(2, 4))
    n = int(rng.integers(8, 300))
    nparts = int(rng.integers(2, 48))
    sfc = SFCS[seed % 4]
    B = int(rng.integers(1, 5))
    dim_orders = np.stack([rng.permutation(d) for _ in range(B)])
    weights = rng.random(n) if seed % 2 else None
    coords = rng.normal(size=(n, d))
    kw = dict(dim_orders=dim_orders, weights=weights,
              uneven_prime=bool(seed % 3 == 0), longest_dim=seed % 4 != 1)
    a = order_points_batched(coords, nparts, sfc, backend="vectorized",
                             **kw)
    b = order_points_batched(coords, nparts, sfc, backend="jax", **kw)
    assert np.array_equal(a, b)


@pytest.mark.parametrize("nparts", [7, 97])
def test_uneven_prime_parts_on_grids(nparts):
    ix = np.indices((16, 16))
    coords = np.stack([c.ravel() for c in ix], axis=1).astype(float)
    _assert_jax_equiv(coords, nparts, "FZ", uneven_prime=True)


def test_zero_weight_points_and_more_parts_than_points():
    rng = np.random.default_rng(7)
    coords = rng.normal(size=(40, 2))
    w = rng.random(40)
    w[::3] = 0.0
    _assert_jax_equiv(coords, 8, "Gray", weights=w)
    _assert_jax_equiv(rng.normal(size=(5, 2)), 16, "FZ")


def test_padded_bucket_tails():
    """Point counts straddling the pow2 bucket boundary: the padded
    tail slots must never leak into the result."""
    rng = np.random.default_rng(11)
    for n in (partition_jax.PART_BUCKET_MIN - 1,
              partition_jax.PART_BUCKET_MIN,
              partition_jax.PART_BUCKET_MIN + 1, 511, 513):
        coords = rng.normal(size=(n, 3))
        _assert_jax_equiv(coords, 32, "FZ", weights=rng.random(n))


# ---------------------------------------------------------------------------
# device Hilbert: Skilling's transpose as a batched jitted kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(12))
def test_hilbert_device_bit_identity(seed):
    """The device Hilbert state machine must reproduce the host
    ``hilbert_index`` + stable-lexsort split exactly: random clouds and
    duplicate-heavy integer grids (all-ties quantisation), dims 1-3,
    weighted and unweighted cuts."""
    rng = np.random.default_rng(500 + seed)
    d = int(rng.integers(1, 4))
    n = int(rng.integers(2, 400))
    nparts = int(rng.integers(1, 48))
    weights = rng.random(n) if seed % 3 == 0 else None
    coords = rng.normal(size=(n, d))
    if seed % 4 == 0:  # duplicate-heavy grid: the tie order is the test
        coords = rng.integers(0, 4, size=(n, d)).astype(float)
    a = order_points(coords, nparts, "H", backend="vectorized",
                     weights=weights)
    b = order_points(coords, nparts, "H", backend="jax", weights=weights)
    assert np.array_equal(a, b), (
        f"device Hilbert mismatch: d={d} n={n} nparts={nparts} "
        f"weighted={weights is not None}")


def test_hilbert_padded_bucket_tails():
    """Hilbert shares the pow2 point buckets: counts straddling the
    bucket boundary must keep padded tail slots out of the result."""
    rng = np.random.default_rng(13)
    for n in (partition_jax.PART_BUCKET_MIN - 1,
              partition_jax.PART_BUCKET_MIN,
              partition_jax.PART_BUCKET_MIN + 1, 511, 513):
        coords = rng.normal(size=(n, 3))
        w = rng.random(n)
        a = order_points(coords, 16, "H", backend="vectorized", weights=w)
        b = order_points(coords, 16, "H", backend="jax", weights=w)
        assert np.array_equal(a, b), n


def test_hilbert_batched_dim_order_candidates():
    """Batched H folds the dim-order into per-candidate gathers (no host
    pre-permutation): every row must equal the column-permuted
    per-candidate oracle, all 3! permutations at once."""
    import itertools

    rng = np.random.default_rng(21)
    coords = rng.normal(size=(200, 3))
    dos = np.array(list(itertools.permutations(range(3))))
    for weights in (None, rng.random(200)):
        a = order_points_batched(coords, 12, "H", dim_orders=dos,
                                 weights=weights, backend="vectorized")
        b = order_points_batched(coords, 12, "H", dim_orders=dos,
                                 weights=weights, backend="jax")
        assert np.array_equal(a, b)
        for i, p in enumerate(dos):
            ref = order_points(coords[:, list(p)], 12, "H",
                               weights=weights)
            assert np.array_equal(b[i], ref), tuple(p)


def test_hilbert_compile_cache_counters():
    """H shares the keyed compile cache: one compile per (d, bits,
    weighted, bucket); a second cloud in the same bucket must hit."""
    partition_jax.reset_partition_cache()
    rng = np.random.default_rng(17)
    order_points(rng.normal(size=(100, 3)), 8, "H", backend="jax")
    stats = partition_jax.partition_cache_stats()
    assert stats == {"hits": 0, "misses": 1, "entries": 1}
    order_points(rng.normal(size=(90, 3)), 12, "H", backend="jax")
    stats = partition_jax.partition_cache_stats()
    assert stats["misses"] == 1 and stats["hits"] == 1


# ---------------------------------------------------------------------------
# scenario registry: every machine x workload partitions identically
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("workload", ["minighost", "homme", "random"])
@pytest.mark.parametrize("allocation",
                         ["xk7_sparse", "bgq_block", "tpu_mesh",
                          "fat_tree"])
def test_scenario_registry_bit_identity(workload, allocation):
    from repro.mapping.pipeline import MappingPipeline, PipelineConfig
    from repro.serve.scenarios import Scenario

    sc = Scenario(workload, allocation, scale=192)
    graph = sc.graph()
    alloc = sc.alloc_for(graph)
    pipe = MappingPipeline(PipelineConfig(sfc="FZ", shift=True))
    pc = pipe.machine_coords(alloc)
    for coords, w in ((graph.coords.astype(float), None),
                      (pc, None)):
        d = coords.shape[1]
        dim_orders = np.stack([np.arange(d), np.arange(d)[::-1]])
        nparts = min(len(coords), len(pc))
        a = order_points_batched(coords, nparts, "FZ",
                                 dim_orders=dim_orders, weights=w,
                                 backend="vectorized")
        b = order_points_batched(coords, nparts, "FZ",
                                 dim_orders=dim_orders, weights=w,
                                 backend="jax")
        assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# fallback chain + compile-cache counters
# ---------------------------------------------------------------------------

def test_resolve_partition_backend():
    assert resolve_partition_backend("numpy") == "numpy"
    assert resolve_partition_backend("jax") == "jax"  # jax importable here
    with pytest.raises(ValueError):
        resolve_partition_backend("pallas")


def test_fallback_when_jax_absent(monkeypatch):
    """With the import sentinel pinned to 'unavailable' the jax backend
    silently produces the numpy result and the pipeline resolves
    numpy."""
    from repro.mapping.pipeline import MappingPipeline, PipelineConfig

    monkeypatch.setattr(orderings, "_JAX_PART", None)
    assert resolve_partition_backend("jax") == "numpy"
    rng = np.random.default_rng(3)
    coords = rng.normal(size=(64, 2))
    a = order_points(coords, 8, "FZ", backend="jax")
    b = order_points(coords, 8, "FZ", backend="vectorized")
    assert np.array_equal(a, b)
    pipe = MappingPipeline(PipelineConfig(partition_backend="jax"))
    assert pipe.partition_backend == "numpy"
    assert pipe.order_backend == "vectorized"
    assert pipe._fused is None


def test_compile_cache_counters():
    """One compile per (knobs, bucket); repeat shapes must hit."""
    partition_jax.reset_partition_cache()
    rng = np.random.default_rng(5)
    coords = rng.normal(size=(100, 3))
    order_points(coords, 8, "FZ", backend="jax")
    stats = partition_jax.partition_cache_stats()
    assert stats == {"hits": 0, "misses": 1, "entries": 1}
    order_points(rng.normal(size=(90, 3)), 12, "FZ", backend="jax")
    stats = partition_jax.partition_cache_stats()
    assert stats["misses"] == 1 and stats["hits"] == 1


def test_split_table_matches_reference():
    from repro.core.orderings import _split_counts
    for uneven in (False, True):
        tab = partition_jax._split_table(97, uneven)
        for v in range(2, 98):
            assert tab[v] == _split_counts(v, uneven)[0], (v, uneven)


# ---------------------------------------------------------------------------
# fused whole-pipeline program
# ---------------------------------------------------------------------------

def _mesh_problem():
    from repro.core import (block_allocation, logical_mesh_graph,
                            tpu_v5e_pod)
    machine = tpu_v5e_pod(side=8)
    alloc = block_allocation(machine)
    graph = logical_mesh_graph((8, 8), (8.0, 64.0), ("data", "model"))
    return graph, alloc


@pytest.mark.parametrize("score_backend", ["jax", "pallas"])
@pytest.mark.parametrize("objective",
                         ["weighted_hops",
                          ("latency_max", "weighted_hops")])
def test_fused_pipeline_matches_numpy(score_backend, objective):
    """partition=jax + score=jax/pallas runs the sweep as ONE compiled
    program and returns the same winner as the all-numpy pipeline."""
    from repro.mapping.pipeline import MappingPipeline, PipelineConfig

    graph, alloc = _mesh_problem()
    base = MappingPipeline(PipelineConfig(rotations=4, objective=objective)
                           ).map(graph, alloc)
    pipe = MappingPipeline(PipelineConfig(
        rotations=4, objective=objective, score_backend=score_backend,
        partition_backend="jax"))
    assert pipe._fused is not None
    fused = pipe.map(graph, alloc)
    assert fused.stats.get("fused") is True
    assert "fused_s" in fused.stats["timings"]
    assert np.array_equal(base.task_to_proc, fused.task_to_proc)
    assert base.rotation == fused.rotation
    assert np.isclose(base.score, fused.score, rtol=1e-5)


def test_fused_pipeline_hilbert_matches_numpy():
    """sfc="H" engages the SAME fused program path: the device Hilbert
    sweep feeds the inlined scorer and the winner is bit-identical to
    the all-numpy pipeline."""
    from repro.mapping.pipeline import MappingPipeline, PipelineConfig

    graph, alloc = _mesh_problem()
    base = MappingPipeline(PipelineConfig(sfc="H", rotations=4)
                           ).map(graph, alloc)
    pipe = MappingPipeline(PipelineConfig(
        sfc="H", rotations=4, score_backend="jax",
        partition_backend="jax"))
    assert pipe._fused is not None
    fused = pipe.map(graph, alloc)
    assert fused.stats.get("fused") is True
    assert np.array_equal(base.task_to_proc, fused.task_to_proc)
    assert base.rotation == fused.rotation


def test_fused_program_compiles_once():
    """Repeat map() calls on the same shapes reuse ONE fused program
    (zero host<->device transfers between stages: the whole chain is a
    single cache entry)."""
    from repro.mapping import fused as fused_mod
    from repro.mapping.pipeline import MappingPipeline, PipelineConfig

    graph, alloc = _mesh_problem()
    pipe = MappingPipeline(PipelineConfig(
        rotations=4, score_backend="jax", partition_backend="jax"))
    fused_mod.reset_fused_cache()
    r1 = pipe.map(graph, alloc)
    stats = fused_mod.fused_cache_stats()
    assert stats["misses"] == 1 and stats["entries"] == 1
    r2 = pipe.map(graph, alloc)
    stats = fused_mod.fused_cache_stats()
    assert stats["misses"] == 1 and stats["hits"] == 1
    assert np.array_equal(r1.task_to_proc, r2.task_to_proc)


def test_fused_hierarchical_matches_numpy():
    from repro.mapping.pipeline import MappingPipeline, PipelineConfig

    graph, alloc = _mesh_problem()
    from repro.hier import HierarchySpec
    base = MappingPipeline(PipelineConfig(
        rotations=4, hierarchy=HierarchySpec.node())).map(graph, alloc)
    fused = MappingPipeline(PipelineConfig(
        rotations=4, hierarchy=HierarchySpec.node(), score_backend="jax",
        partition_backend="jax")).map(graph, alloc)
    assert np.array_equal(base.task_to_proc, fused.task_to_proc)
    assert "refine_s" in fused.stats["timings"]
    assert fused.stats["partition_backend"] == "jax"


def test_unfused_jax_partition_stage_timings():
    """partition=jax with the numpy scorer: no fused program, but the
    per-stage timings and backend attribution must still be recorded."""
    from repro.mapping.pipeline import MappingPipeline, PipelineConfig

    graph, alloc = _mesh_problem()
    pipe = MappingPipeline(PipelineConfig(rotations=4,
                                          partition_backend="jax"))
    assert pipe._fused is None and pipe.order_backend == "jax"
    base = MappingPipeline(PipelineConfig(rotations=4)).map(graph, alloc)
    res = pipe.map(graph, alloc)
    assert np.array_equal(base.task_to_proc, res.task_to_proc)
    t = res.stats["timings"]
    assert {"partition_s", "score_s", "total_s"} <= set(t)
    assert res.stats["partition_backend"] == "jax"
