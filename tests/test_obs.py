"""Observability tests (ISSUE 8): span nesting and thread-safety,
trace-id propagation through the serve layer (cold / warm / coalesced /
degraded requests), snapshot adapter parity with the legacy per-module
accessors, ``stats["timings"]`` schema compatibility, and the JSONL /
Chrome-trace / Prometheus export round-trips.

Tests that assert exact degradation behaviour run inside
``faults.isolated()`` so the CI chaos job's ambient ``REPRO_FAULTS``
schedule cannot perturb them.
"""

import dataclasses
import functools
import gc
import json
import threading

import numpy as np
import pytest

from repro import faults, obs
from repro.core import Mapper, MapperConfig, make_machine, stencil_graph
from repro.hier import HierarchySpec
from repro.core.machine import block_allocation
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import Tracer
from repro.serve import MappingService, get_scenario

SCALE = 256

BASE = "minighost-xk7_sparse-flat-wh"


def _req(name=BASE, seed=0, scale=SCALE, **overrides):
    sc = get_scenario(name, scale=scale, seed=seed)
    req = sc.request()
    if overrides:
        cfg = dataclasses.replace(sc.config(), **overrides)
        req = dataclasses.replace(req, config=cfg, _signature=None)
    return req


def _has_jax():
    from repro.core.orderings import resolve_partition_backend
    return resolve_partition_backend("jax") == "jax"


# ---------------------------------------------------------------------------
# Spans: nesting, identity, errors
# ---------------------------------------------------------------------------

def test_span_nesting_and_trace_identity():
    t = Tracer()
    with t.span("outer", k=1) as outer:
        assert outer.parent_id is None
        assert len(outer.trace_id) == 16
        assert t.current() is outer
        with t.span("inner") as inner:
            assert inner.parent_id == outer.span_id
            assert inner.trace_id == outer.trace_id
            assert t.current() is inner
        assert t.current() is outer
    assert t.current() is None
    done = t.finished()
    assert [s.name for s in done] == ["inner", "outer"]  # finish order
    assert all(s.t1 is not None and s.duration_s >= 0 for s in done)


def test_sibling_roots_mint_distinct_traces():
    t = Tracer()
    with t.span("a"):
        pass
    with t.span("b"):
        pass
    a, b = t.finished()
    assert a.trace_id != b.trace_id


def test_span_records_escaping_exception():
    t = Tracer()
    with pytest.raises(ValueError):
        with t.span("boom"):
            raise ValueError("no")
    (sp,) = t.finished()
    assert sp.attrs["error"] == "ValueError"
    assert sp.t1 is not None


def test_annotate_and_duration_while_open():
    t = Tracer()
    with t.span("x") as sp:
        sp.annotate(points=4).annotate(backend="numpy")
        assert sp.duration_s >= 0  # measurable while still open
    assert sp.attrs == {"points": 4, "backend": "numpy"}


def test_span_tree_and_format():
    t = Tracer()
    with t.span("root"):
        with t.span("kid1"):
            pass
        with t.span("kid2"):
            pass
    tree = obs.span_tree(t.finished())
    assert len(tree) == 1
    root, kids = tree[0]
    assert root.name == "root"
    assert [k[0].name for k in kids] == ["kid1", "kid2"]
    text = obs.format_tree(t.finished())
    lines = text.splitlines()
    assert lines[0].startswith("root") and "  kid1" in lines[1]


def test_span_tree_orphans_surface_as_roots():
    from repro.obs.trace import Span
    parent = Span("p", "t1")
    child = Span("k", "t1", parent.span_id)
    # parent fell off the ring: the child surfaces instead of vanishing
    roots = [n[0].name for n in obs.span_tree([child])]
    assert roots == ["k"]


# ---------------------------------------------------------------------------
# Spans: threads
# ---------------------------------------------------------------------------

def test_threads_do_not_share_span_context():
    t = Tracer()
    seen = []

    def work(i):
        with t.span(f"thread{i}") as sp:
            assert sp.parent_id is None  # no inherited parent
            seen.append(sp.trace_id)

    with t.span("main"):
        threads = [threading.Thread(target=work, args=(i,))
                   for i in range(4)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
    assert len(set(seen)) == 4  # each thread rooted its own trace


def test_attach_joins_a_cross_thread_trace():
    t = Tracer()
    with t.span("request") as root:
        parent = t.current()

        def worker():
            with t.attach(parent):
                with t.span("rung"):
                    pass

        th = threading.Thread(target=worker)
        th.start()
        th.join()
    rung = next(s for s in t.finished() if s.name == "rung")
    assert rung.trace_id == root.trace_id
    assert rung.parent_id == root.span_id


def test_attach_none_is_a_passthrough():
    t = Tracer()
    with t.attach(None) as got:
        assert got is None
        assert t.current() is None


def test_tracer_is_thread_safe_under_contention():
    t = Tracer(max_finished=10_000)
    n_threads, per_thread = 8, 50

    def work():
        for _ in range(per_thread):
            with t.span("a"):
                with t.span("b"):
                    pass

    threads = [threading.Thread(target=work) for _ in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    done = t.finished()
    assert len(done) == n_threads * per_thread * 2
    ids = [s.span_id for s in done]
    assert len(set(ids)) == len(ids)


def test_finished_ring_is_bounded():
    t = Tracer(max_finished=8)
    for i in range(20):
        with t.span(f"s{i}"):
            pass
    done = t.finished()
    assert len(done) == 8
    assert done[-1].name == "s19"  # newest kept, oldest dropped


def test_sinks_receive_spans_and_bad_sinks_are_dropped():
    t = Tracer()
    got = []

    def bad(span):
        raise RuntimeError("sink died")

    t.add_sink(got.append)
    t.add_sink(bad)
    with t.span("one"):
        pass
    with t.span("two"):
        pass
    assert [s.name for s in got] == ["one", "two"]
    assert bad not in t._sinks  # dropped after the first raise


# ---------------------------------------------------------------------------
# Metrics registry
# ---------------------------------------------------------------------------

def test_primitive_series_and_snapshot():
    r = MetricsRegistry()
    r.counter("reqs")
    r.counter("reqs", 2)
    r.gauge("depth", 7)
    r.observe("lat", 0.25)
    r.observe("lat", 0.75)
    snap = r.snapshot()
    assert snap["counters"]["reqs"] == 3
    assert snap["gauges"]["depth"] == 7.0
    h = snap["histograms"]["lat"]
    assert h["count"] == 2 and h["sum"] == 1.0
    assert h["min"] == 0.25 and h["max"] == 0.75 and h["mean"] == 0.5


def test_series_cap_drops_not_grows():
    r = MetricsRegistry(max_series=2)
    r.counter("a")
    r.gauge("b", 1)
    r.counter("c")       # over the cap: dropped
    r.observe("d", 1.0)  # dropped too
    r.counter("a")       # existing series still bump
    snap = r.snapshot()
    assert set(snap["counters"]) == {"a"}
    assert snap["counters"]["a"] == 2
    assert snap["meta"]["dropped_series"] == 2


def test_registered_objects_are_weak_and_bounded():
    class Obj:
        def __init__(self, i):
            self.i = i

        def stats(self):
            return {"i": self.i}

    r = MetricsRegistry(max_objects=2)
    keep = [Obj(0), Obj(1)]
    for o in keep:
        r.register_object("objs", o)
    gone = Obj(99)
    r.register_object("objs", gone)  # pushes Obj(0) past the cap
    assert len(r.snapshot()["objs"]) == 2
    del gone
    gc.collect()
    vals = [v["i"] for v in r.snapshot()["objs"].values()]
    assert vals == [keep[1].i]  # dead ref pruned, live one kept


def test_provider_sections_and_errors_are_contained():
    r = MetricsRegistry()
    r.register_provider("good", lambda: {"x": 1})
    r.register_provider("bad", lambda: 1 / 0)
    snap = r.snapshot()
    assert snap["good"] == {"x": 1}
    assert snap["bad"] == {"error": "ZeroDivisionError"}


def test_instrument_compile_cache_contract_and_autoregistration():
    @functools.lru_cache(maxsize=None)
    def compiled(key):
        return object()

    stats_fn, reset_fn = obs.instrument_compile_cache(
        "obs_test_cache", compiled)
    assert stats_fn() == {"hits": 0, "misses": 0, "entries": 0}
    compiled("a")
    compiled("a")
    compiled("b")
    assert stats_fn() == {"hits": 1, "misses": 2, "entries": 2}
    # the same counters appear in the process snapshot, unasked
    assert obs.snapshot()["caches"]["obs_test_cache"] == stats_fn()
    reset_fn()
    assert stats_fn() == {"hits": 0, "misses": 0, "entries": 0}


def test_snapshot_parity_with_legacy_cache_accessors():
    if not _has_jax():
        pytest.skip("compile caches need jax")
    from repro.core import metrics_jax, partition_jax
    from repro.kernels.mapscore import ops as mapscore_ops
    from repro.mapping import fused
    caches = obs.snapshot()["caches"]
    legacy = {"scorer_jax": metrics_jax.scorer_cache_stats,
              "scorer_pallas": mapscore_ops.scorer_cache_stats,
              "partition_jax": partition_jax.partition_cache_stats,
              "fused": fused.fused_cache_stats}
    for name, accessor in legacy.items():
        assert caches[name] == accessor(), name


def test_snapshot_covers_live_services_and_lrus():
    with faults.isolated():
        svc = MappingService(capacity=4)
        svc.map(_req())
        svc.map(_req())
    snap = obs.snapshot()
    assert any(sec == svc.stats()
               for sec in snap["services"].values())
    assert any(sec == svc.results.stats()
               for sec in snap["lrus"].values())
    d = snap["derived"]
    assert d["availability"] is None or 0.0 <= d["availability"] <= 1.0
    assert "result_cache_hit_rate" in d and "compiles" in d


def test_span_rollup():
    t = Tracer()
    for _ in range(3):
        with t.span("stage"):
            pass
    with t.span("other"):
        pass
    roll = obs.span_rollup(t.finished())
    assert roll["stage"]["count"] == 3 and roll["other"]["count"] == 1
    assert roll["stage"]["total_s"] >= roll["stage"]["max_s"] >= 0


# ---------------------------------------------------------------------------
# Pipeline integration: timings schema + trace ids
# ---------------------------------------------------------------------------

def _flat_case():
    m = make_machine((8, 8), wrap=True)
    return m, block_allocation(m), stencil_graph((8, 8))


def test_flat_timings_schema_is_span_derived():
    m, alloc, g = _flat_case()
    res = Mapper(MapperConfig(sfc="FZ", rotations=4)).map(g, alloc)
    t = res.stats["timings"]
    assert {"partition_s", "score_s", "total_s"} <= set(t)
    assert "fused_s" not in t
    assert t["total_s"] >= t["partition_s"] + t["score_s"] - 1e-9
    root = next(s for s in obs.finished(res.stats["trace_id"])
                if s.name == "pipeline.map")
    assert root.parent_id is None
    assert root.attrs["hierarchy"] == "flat"
    assert t["total_s"] == root.duration_s


def test_hier_timings_schema_is_span_derived():
    m, alloc, g = _flat_case()
    res = Mapper(MapperConfig(
        sfc="FZ", rotations=4,
        hierarchy=HierarchySpec.node())).map(g, alloc)
    t = res.stats["timings"]
    assert {"coarsen_s", "partition_s", "score_s", "refine_s",
            "total_s"} <= set(t)
    spans = obs.finished(res.stats["trace_id"])
    names = [s.name for s in spans]
    for stage in ("pipeline.coarsen", "pipeline.partition",
                  "pipeline.score", "pipeline.refine", "pipeline.map"):
        assert stage in names
    root = next(s for s in spans if s.name == "pipeline.map")
    assert all(s.trace_id == root.trace_id for s in spans)


# ---------------------------------------------------------------------------
# Serve integration: one trace per request
# ---------------------------------------------------------------------------

def test_cold_and_warm_requests_trace_distinctly():
    with faults.isolated():
        svc = MappingService()
        cold = svc.map(_req())
        warm = svc.map(_req())
    assert cold.status == "cold" and warm.status == "warm"
    assert cold.trace_id and warm.trace_id
    assert cold.trace_id != warm.trace_id
    # the shared result names the trace that COMPUTED it
    assert cold.result.stats["trace_id"] == cold.trace_id
    assert warm.result.stats["trace_id"] == cold.trace_id
    names = {s.name for s in obs.finished(cold.trace_id)}
    assert {"serve.request", "serve.rung", "pipeline.map",
            "pipeline.partition", "pipeline.score"} <= names
    warm_names = {s.name for s in obs.finished(warm.trace_id)}
    assert "serve.request" in warm_names
    assert "pipeline.map" not in warm_names  # warm = lookup only
    root = next(s for s in obs.finished(cold.trace_id)
                if s.name == "serve.request")
    assert root.attrs["status"] == "cold"


def test_coalesced_batch_shares_the_primary_trace():
    with faults.isolated():
        svc = MappingService()
        resps = svc.map_many([_req(), _req(), _req(seed=1)])
    assert [r.status for r in resps] == ["cold", "coalesced", "cold"]
    assert resps[1].trace_id == resps[0].trace_id
    assert resps[2].trace_id != resps[0].trace_id


def test_degraded_request_yields_one_trace_covering_all_rungs():
    if not _has_jax():
        pytest.skip("degradation off the jax rung needs jax")
    with faults.isolated():
        svc = MappingService()
        req = _req(score_backend="jax", rotations=4)
        with faults.injected("score.jax", "error", count=1):
            resp = svc.map(req)
        assert resp.result.stats["degraded"] == "score_numpy"
        spans = obs.finished(resp.trace_id)
        rungs = [s for s in spans if s.name == "serve.rung"]
        assert [s.attrs["rung"] for s in rungs] == ["full", "score_numpy"]
        assert rungs[0].attrs["error"] == "InjectedFault"
        assert rungs[1].attrs["degraded"] == "score_numpy"
        # the failed backend call site is in the SAME trace
        failed = next(s for s in spans if s.name == "score.jax")
        assert failed.attrs["error"] == "InjectedFault"
        assert {s.trace_id for s in spans} == {resp.trace_id}


def test_deadline_worker_spans_join_the_request_trace():
    with faults.isolated():
        # a finite deadline routes non-terminal rungs through a daemon
        # worker thread; score_backend="jax" gives the ladder a second
        # rung so "full" is non-terminal
        svc = MappingService(deadline_s=30.0)
        req = _req(score_backend="jax", rotations=4)
        resp = svc.map(req)
    spans = obs.finished(resp.trace_id)
    assert any(s.name == "pipeline.map" for s in spans)
    threads = {s.thread for s in spans}
    assert len(threads) > 1  # rung ran off-thread yet stayed in-trace


# ---------------------------------------------------------------------------
# Exporters
# ---------------------------------------------------------------------------

def test_jsonl_sink_round_trip(tmp_path):
    path = str(tmp_path / "trace.jsonl")
    sink = obs.JsonlSink(path)
    obs.add_sink(sink)
    try:
        with obs.span("outer", k=1):
            with obs.span("inner"):
                pass
    finally:
        obs.remove_sink(sink)
        sink.close()
    rows = obs.read_jsonl(path)
    assert [r["name"] for r in rows] == ["inner", "outer"]
    inner, outer = rows
    assert inner["trace_id"] == outer["trace_id"]
    assert inner["parent_id"] == outer["span_id"]
    assert outer["parent_id"] is None
    assert outer["attrs"] == {"k": 1}
    assert all(r["duration_s"] >= 0 for r in rows)


def test_env_sink_installation(tmp_path, monkeypatch):
    path = str(tmp_path / "env.jsonl")
    monkeypatch.setenv(obs.export.TRACE_ENV, path)
    sink = obs.install_env_sink()
    try:
        with obs.span("env-armed"):
            pass
    finally:
        obs.remove_sink(sink)
        sink.close()
    assert any(r["name"] == "env-armed" for r in obs.read_jsonl(path))
    monkeypatch.delenv(obs.export.TRACE_ENV)
    assert obs.install_env_sink() is None


def test_chrome_trace_round_trip(tmp_path):
    t = Tracer()
    with t.span("req", backend="numpy"):
        with t.span("stage"):
            pass
    with t.span("req2"):
        pass
    doc = obs.chrome_trace(t.finished())
    events = doc["traceEvents"]
    assert [e["name"] for e in events] == ["stage", "req", "req2"]
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in events)
    by_name = {e["name"]: e for e in events}
    assert by_name["stage"]["pid"] == by_name["req"]["pid"]
    assert by_name["req2"]["pid"] != by_name["req"]["pid"]
    assert by_name["req"]["args"]["backend"] == "numpy"
    path = str(tmp_path / "chrome.json")
    obs.write_chrome_trace(path, t.finished())
    with open(path) as f:
        assert json.load(f) == json.loads(json.dumps(doc))


def test_prometheus_text_is_valid_exposition():
    obs.counter("obs_test_requests", 2)
    obs.observe("obs_test_latency_s", 0.5)
    text = obs.prometheus_text()
    assert text.endswith("\n")
    assert "# TYPE repro_obs_test_requests_total counter" in text
    assert "repro_obs_test_requests_total 2.0" in text
    assert "repro_obs_test_latency_s_count 2" in text or \
        "repro_obs_test_latency_s_count 1" in text
    typed = [ln.split()[2] for ln in text.splitlines()
             if ln.startswith("# TYPE")]
    assert len(typed) == len(set(typed))  # one TYPE line per family
    for ln in text.splitlines():
        if not ln or ln.startswith("#"):
            continue
        name_labels, value = ln.rsplit(" ", 1)
        float(value)  # every sample parses (NaN included)
        assert name_labels.startswith("repro_")


def test_prometheus_includes_labelled_cache_families():
    if not _has_jax():
        pytest.skip("compile-cache families need jax")
    text = obs.prometheus_text()
    assert 'repro_compile_cache_entries{cache="scorer_jax"}' in text
    assert 'repro_compile_cache_entries{cache="fused"}' in text


def test_jax_profile_is_a_noop_without_env(monkeypatch):
    monkeypatch.delenv(obs.export.JAX_PROFILE_ENV, raising=False)
    before = len(obs.finished())
    with obs.jax_profile("bench") as got:
        assert got is None
    assert len(obs.finished()) == before  # no span, no trace


def test_service_map_and_direct_pipeline_agree():
    # the obs instrumentation must not perturb results: a traced
    # service request equals the bare pipeline output bit for bit
    with faults.isolated():
        sc = get_scenario(BASE, scale=SCALE, seed=3)
        req = sc.request()
        svc = MappingService()
        resp = svc.map(req)
        from repro.mapping import MappingPipeline
        direct = MappingPipeline(req.config).map(req.graph, req.alloc)
    assert np.array_equal(resp.result.task_to_proc, direct.task_to_proc)
