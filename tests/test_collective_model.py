"""Mapping-aware collective model tests (meshmap/collective_model)."""

from repro.core import (Allocation, identity_mapping, logical_mesh_graph,
                        make_machine, sfc_allocation, tpu_v5e_pod)
from repro.meshmap.collective_model import (collective_term,
                                            compare_mappings,
                                            split_axis_bytes)
from repro.meshmap.device_mesh import select_mapping


def test_split_axis_bytes_proportional():
    ab = split_axis_bytes(100.0, (16, 16), axis_weights=(1.0, 3.0))
    assert ab == [25.0, 75.0]
    # size-1 axes carry no collectives
    ab = split_axis_bytes(100.0, (1, 16), axis_weights=(1.0, 3.0))
    assert ab == [0.0, 100.0]


def test_contiguous_pod_close_to_flat_term():
    """On a contiguous pod with aligned logical shape, per-link traffic
    should be within ~2x of the ideal flat bytes/bw term (rings map to
    disjoint physical links)."""
    m = tpu_v5e_pod(8)
    alloc = Allocation(m, m.all_coords())
    axis_bytes = (1e9, 8e9)
    g = logical_mesh_graph((8, 8), axis_bytes)
    t = collective_term(alloc, (8, 8), identity_mapping(g, alloc),
                        axis_bytes)
    flat = sum(axis_bytes) / 50e9
    assert t < 2.0 * flat
    assert t > 0.2 * flat


def test_fragmented_allocation_dilates_term():
    """Fragmented allocations must show a strictly larger bottleneck-link
    term than the contiguous pod — the cost the paper's technique
    targets."""
    axis_bytes = (1e9, 8e9)
    m1 = tpu_v5e_pod(8)
    a1 = Allocation(m1, m1.all_coords())
    g = logical_mesh_graph((8, 8), axis_bytes)
    t1 = collective_term(a1, (8, 8), identity_mapping(g, a1), axis_bytes)
    ms = make_machine((16, 16), wrap=True, bw=50.0)
    a2 = sfc_allocation(ms, 64, nfragments=4, seed=3)
    t2 = collective_term(a2, (8, 8), identity_mapping(g, a2), axis_bytes)
    assert t2 > t1


def test_candidate_mapping_not_worse_on_collective_term():
    axis_bytes = (1e9, 8e9)
    ms = make_machine((16, 16), wrap=True, bw=50.0)
    alloc = sfc_allocation(ms, 64, nfragments=4, seed=1)
    g = logical_mesh_graph((8, 8), axis_bytes)
    best, _, _ = select_mapping(g, alloc, axis_bytes, rotations=2)
    res = compare_mappings(alloc, (8, 8), axis_bytes,
                           {"default": identity_mapping(g, alloc),
                            "mapped": best})
    assert res["mapped"] <= res["default"] * 1.001
