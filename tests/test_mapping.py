"""Algorithm 1 mapping tests + transforms + machines."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (Mapper, MapperConfig, block_allocation,
                        closest_subset, cube_sphere_graph, evaluate,
                        geometric_map, identity_mapping, make_machine,
                        sfc_allocation, shift_torus, stencil_graph,
                        tpu_v5e_multipod)
from repro.core.transforms import box_lift, scale_by_bandwidth


def _grid_coords(shape):
    ix = np.indices(shape)
    return np.stack([c.ravel() for c in ix], axis=1).astype(float)


# ---------------------------------------------------------------------------
# geometric_map (Algorithm 1)
# ---------------------------------------------------------------------------

def test_one_to_one_same_coords_is_identity():
    coords = _grid_coords((4, 4))
    res = geometric_map(coords, coords, sfc="FZ")
    assert np.array_equal(res.task_to_proc, np.arange(16))


@pytest.mark.parametrize("sfc", ["Z", "FZ", "H"])
def test_one_to_one_is_bijection(sfc):
    rng = np.random.default_rng(0)
    tc = rng.normal(size=(64, 2))
    pc = rng.normal(size=(64, 3))
    res = geometric_map(tc, pc, sfc=sfc)
    assert sorted(res.task_to_proc.tolist()) == list(range(64))


def test_more_tasks_than_procs_balanced():
    rng = np.random.default_rng(1)
    tc = rng.normal(size=(64, 2))
    pc = rng.normal(size=(16, 2))
    res = geometric_map(tc, pc, sfc="FZ")
    counts = np.bincount(res.task_to_proc, minlength=16)
    assert (counts == 4).all()


def test_fewer_tasks_than_procs_uses_subset():
    rng = np.random.default_rng(2)
    tc = rng.normal(size=(8, 2))
    pc = np.concatenate([rng.normal(size=(8, 2)),
                         rng.normal(size=(24, 2)) + 100.0])
    res = geometric_map(tc, pc, sfc="FZ")
    # chosen procs must form ONE compact cluster (either group works; the
    # selection must not straddle the 100-unit gap)
    chosen = res.task_to_proc
    assert len(set(chosen.tolist())) == 8
    spread = pc[chosen].max(axis=0) - pc[chosen].min(axis=0)
    assert (spread < 10.0).all()


def test_closest_subset_picks_cluster():
    rng = np.random.default_rng(3)
    tight = rng.normal(scale=0.1, size=(10, 2))
    far = rng.normal(scale=0.1, size=(30, 2)) + 50.0
    pts = np.concatenate([far[:15], tight, far[15:]])
    # 30 far points dominate the initial centroid, but iteration converges
    sel = closest_subset(pts, 10)
    assert len(sel) == 10


def test_mfz_auto_engages_only_when_pd_multiple_of_td():
    tc = _grid_coords((64,))  # td=1
    pc = _grid_coords((8, 8))  # pd=2
    res_mfz = geometric_map(tc, pc, sfc="FZ", mfz="auto")
    res_fz = geometric_map(tc, pc, sfc="FZ", mfz=False)
    assert not np.array_equal(res_mfz.task_to_proc, res_fz.task_to_proc)
    # pd == td: MFZ must be identical to FZ
    pc2 = _grid_coords((64,))
    a = geometric_map(tc, pc2, sfc="FZ", mfz="auto")
    b = geometric_map(tc, pc2, sfc="FZ", mfz=False)
    assert np.array_equal(a.task_to_proc, b.task_to_proc)


# ---------------------------------------------------------------------------
# Transforms
# ---------------------------------------------------------------------------

def test_shift_torus_closes_gap():
    m = make_machine((16,), wrap=True)
    # occupy two clusters split across the wrap: {14,15} and {0,1}
    coords = np.array([[14.0], [15.0], [0.0], [1.0]])
    alloc_like = shift_torus(coords, m)
    ext = alloc_like.max() - alloc_like.min()
    assert ext == 3.0  # contiguous after the shift


def test_shift_torus_skips_mesh_dims():
    m = make_machine((16,), wrap=False)
    coords = np.array([[14.0], [15.0], [0.0], [1.0]])
    assert np.array_equal(shift_torus(coords, m), coords)


def test_scale_by_bandwidth_stretches_slow_dims():
    m = make_machine((4, 4), wrap=False, bw=(100.0, 25.0))
    coords = _grid_coords((4, 4))
    out = scale_by_bandwidth(coords, m)
    # dim 1 links are 4x slower -> 4x the geometric distance
    assert np.isclose(out[:, 1].max() / coords[:, 1].max(), 4.0)
    assert np.isclose(out[:, 0].max(), coords[:, 0].max())


def test_box_lift_shape_and_weighting():
    coords = _grid_coords((8, 8))
    out = box_lift(coords, (2, 2), outer_weight=10.0, inner_weight=1.0)
    assert out.shape == (64, 4)
    assert out[:, :2].max() == 30.0  # 3 boxes * 10
    assert out[:, 2:].max() == 1.0


# ---------------------------------------------------------------------------
# Mapper end-to-end on machines
# ---------------------------------------------------------------------------

def test_mapper_beats_identity_on_sparse_allocation():
    """Sparse SFC allocation on a torus: the geometric mapping should cut
    total hops vs rank-order (the paper's headline result)."""
    m = make_machine((16, 16), wrap=True)
    alloc = sfc_allocation(m, 64, nfragments=4, seed=7)
    g = stencil_graph((8, 8))
    mapper = Mapper(MapperConfig(sfc="FZ", shift=True))
    res = mapper.map(g, alloc)
    ours = evaluate(g, alloc, res)
    base = evaluate(g, alloc, identity_mapping(g, alloc))
    assert ours["total_hops"] <= base["total_hops"]


def test_mapper_rotation_search_not_worse():
    m = make_machine((8, 8, 8), wrap=True)
    alloc = sfc_allocation(m, 64, nfragments=2, seed=3)
    g = stencil_graph((8, 8))
    plain = Mapper(MapperConfig(sfc="FZ", rotations=0)).map(g, alloc)
    rot = Mapper(MapperConfig(sfc="FZ", rotations=12)).map(g, alloc)
    h_plain = evaluate(g, alloc, plain)["weighted_hops"]
    h_rot = evaluate(g, alloc, rot)["weighted_hops"]
    assert h_rot <= h_plain + 1e-9


def test_mapper_on_tpu_multipod():
    m = tpu_v5e_multipod(npods=2, side=4)
    alloc = block_allocation(m)
    g = stencil_graph((4, 8))
    res = Mapper(MapperConfig(sfc="FZ")).map(g, alloc)
    assert sorted(res.task_to_proc.tolist()) == list(range(32))


def test_cube_sphere_graph_degree():
    ne = 8
    g = cube_sphere_graph(ne)
    assert g.n == 6 * ne * ne
    # directed degree 4 everywhere on a cubed sphere
    deg = np.bincount(g.edges[:, 0], minlength=g.n)
    assert (deg == 4).all()
    assert len(g.edges) == 24 * ne * ne


def test_sfc_allocation_properties():
    m = make_machine((8, 8), wrap=True)
    a = sfc_allocation(m, 16, nfragments=1, seed=0)
    assert a.n == 16
    assert len(np.unique(a.coords, axis=0)) == 16
    b = sfc_allocation(m, 16, nfragments=4, seed=0)
    assert b.n == 16


@given(st.integers(2, 4), st.integers(2, 3), st.integers(0, 5))
@settings(max_examples=20, deadline=None)
def test_mapping_valid_any_machine(side, d, seed):
    """Property: mapping a matching-size stencil onto any torus block is a
    bijection."""
    m = make_machine((side,) * d, wrap=True)
    alloc = block_allocation(m)
    n = side ** d
    g = stencil_graph((n,))  # 1D chain of matching size
    res = Mapper(MapperConfig(sfc="FZ")).map(g, alloc)
    assert sorted(res.task_to_proc.tolist()) == list(range(n))
