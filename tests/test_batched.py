"""ISSUE-2 coverage: the batched rotation sweep and the routing/scoring
backends.

- ``_batched_route`` (numpy and jax) must agree with a naive
  per-message reference router that literally walks every hop, across
  wrap/non-wrap dims, core dims and weighted edges;
- ``order_points_batched`` must match per-candidate ``order_points``
  (both the ``dim_order`` form and the column-permuted-cloud form, and
  the recursive backend) across SFC kinds — including the Hilbert
  curve, whose batched rows are column permutations — weights and
  ``uneven_prime``;
- the jax scoring backend must match numpy within fp tolerance on every
  metric key and fall back to numpy cleanly when jax is unavailable;
- ``MappingPipeline`` with ``sweep="batched"`` must return mappings and
  winners bit-identical to the ``sweep="loop"`` oracle.

Property-style via seeded numpy RNG (no hypothesis dependency)."""

import numpy as np
import pytest

from repro.core import (block_allocation, make_machine, sfc_allocation,
                        stencil_graph, tpu_v5e_multipod)
from repro.core import metrics as M
# Imported directly (not via the silent-fallback dispatcher) so a broken
# jax backend fails the suite loudly instead of letting the parity tests
# compare numpy against numpy.
from repro.core import metrics_jax  # noqa: F401
from repro.core.machine import gemini_xk7
from repro.core.metrics import _batched_route, evaluate_candidates
from repro.core.orderings import (order_points, order_points_batched,
                                  order_points_recursive)
from repro.mapping import CandidateSearch, MappingPipeline, PipelineConfig
from repro.mapping.candidates import rotation_candidates

SFCS = ("Z", "Gray", "FZ", "FZlow")


# ---------------------------------------------------------------------------
# reference router: walk every message link by link
# ---------------------------------------------------------------------------

def _route_naive(machine, src, dst, w):
    """Dimension-ordered routing, one hop at a time (the spec)."""
    nd = machine.ndim - machine.core_dims
    pos = [np.zeros(machine.dims) for _ in range(nd)]
    neg = [np.zeros(machine.dims) for _ in range(nd)]
    for s, t, wt in zip(src, dst, w):
        cur = list(s)
        for k in range(nd):
            size = machine.dims[k]
            a, b = int(cur[k]), int(t[k])
            if machine.wrap[k]:
                go_fwd = (b - a) % size <= (a - b) % size
            else:
                go_fwd = b >= a
            while cur[k] != b:
                if go_fwd:
                    pos[k][tuple(cur)] += wt
                    cur[k] = (cur[k] + 1) % size
                else:
                    nxt = (cur[k] - 1) % size
                    step = list(cur)
                    step[k] = nxt
                    neg[k][tuple(step)] += wt
                    cur[k] = nxt
    return pos, neg


MACHINES = [
    make_machine((6,), wrap=True),
    make_machine((5, 4), wrap=False),
    make_machine((4, 5, 3), wrap=(True, False, True), bw=(2.0, 1.0, 4.0)),
    gemini_xk7(dims=(4, 4, 8), cores_per_node=2),
    tpu_v5e_multipod(2, 4),
]


@pytest.mark.parametrize("mi", range(len(MACHINES)))
@pytest.mark.parametrize("seed", range(3))
def test_batched_route_matches_naive_reference(mi, seed):
    machine = MACHINES[mi]
    rng = np.random.default_rng(100 * mi + seed)
    nmsg = int(rng.integers(1, 40))
    src = np.stack([rng.integers(0, machine.dims[j], size=nmsg)
                    for j in range(machine.ndim)], axis=1)
    dst = np.stack([rng.integers(0, machine.dims[j], size=nmsg)
                    for j in range(machine.ndim)], axis=1)
    w = rng.uniform(0.5, 3.0, size=nmsg)
    ref_pos, ref_neg = _route_naive(machine, src, dst, w)
    pos, neg = _batched_route(machine, src[None], dst[None], w)
    nd = machine.ndim - machine.core_dims
    for k in range(nd):
        assert np.allclose(pos[k][0], ref_pos[k]), f"pos dim {k}"
        assert np.allclose(neg[k][0], ref_neg[k]), f"neg dim {k}"


@pytest.mark.parametrize("mi", range(len(MACHINES)))
def test_jax_route_matches_naive_reference(mi):
    assert M._jax_evaluator() is not None  # parity must not be vacuous
    machine = MACHINES[mi]
    rng = np.random.default_rng(7 * mi + 1)
    nmsg = 25
    tasks = nmsg + 5
    coords = np.stack([rng.integers(0, machine.dims[j], size=tasks)
                       for j in range(machine.ndim)], axis=1)
    edges = rng.integers(0, tasks, size=(nmsg, 2))
    w = rng.uniform(0.5, 3.0, size=nmsg)
    ref_pos, ref_neg = _route_naive(
        machine, coords[edges[:, 0]], coords[edges[:, 1]], w)
    ev = evaluate_candidates(machine, edges, w, coords[None],
                             traffic=True, backend="jax")
    nd = machine.ndim - machine.core_dims
    data_ref = max(float(a.max()) for k in range(nd)
                   for a in (ref_pos[k], ref_neg[k]))
    lat_ref = max(float((a / machine.bw_field(k)).max()) for k in range(nd)
                  for a in (ref_pos[k], ref_neg[k]))
    assert np.allclose(ev["data_max"][0], data_ref, rtol=1e-4)
    assert np.allclose(ev["latency_max"][0], lat_ref, rtol=1e-4)


# ---------------------------------------------------------------------------
# jax scoring backend parity + fallback
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mi", range(len(MACHINES)))
def test_jax_scoring_parity_all_keys(mi):
    assert M._jax_evaluator() is not None  # parity must not be vacuous
    machine = MACHINES[mi]
    rng = np.random.default_rng(mi)
    nb, ntasks, ne = 4, 40, 120
    stack = np.stack([
        np.stack([rng.integers(0, machine.dims[j], size=ntasks)
                  for j in range(machine.ndim)], axis=1)
        for _ in range(nb)])
    edges = rng.integers(0, ntasks, size=(ne, 2))
    w = rng.uniform(0.5, 2.0, size=ne)
    a = evaluate_candidates(machine, edges, w, stack, traffic=True,
                            backend="numpy")
    b = evaluate_candidates(machine, edges, w, stack, traffic=True,
                            backend="jax")
    assert set(a) == set(b)
    for key in a:
        assert np.allclose(a[key], b[key], rtol=1e-4, atol=1e-4), key


def test_jax_backend_falls_back_cleanly(monkeypatch):
    """backend="jax" must transparently use numpy when jax is absent."""
    monkeypatch.setattr(M, "_JAX_EVAL", None)  # simulate failed import
    machine = make_machine((4, 4), wrap=True)
    rng = np.random.default_rng(0)
    stack = rng.integers(0, 4, size=(2, 10, 2))
    edges = rng.integers(0, 10, size=(20, 2))
    a = evaluate_candidates(machine, edges, None, stack, traffic=True,
                            backend="jax")
    b = evaluate_candidates(machine, edges, None, stack, traffic=True,
                            backend="numpy")
    for key in b:
        assert np.array_equal(a[key], b[key]), key


def test_unknown_scoring_backend_rejected():
    machine = make_machine((4,))
    with pytest.raises(ValueError):
        evaluate_candidates(machine, np.zeros((0, 2), int), None,
                            np.zeros((1, 0, 1)), backend="torch")


# ---------------------------------------------------------------------------
# batched partitioner sweep vs the per-candidate loop
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(8))
def test_order_points_batched_matches_loop(seed):
    rng = np.random.default_rng(seed)
    d = int(rng.integers(1, 4))
    n = int(rng.integers(20, 400))
    nparts = int(rng.integers(2, 64))
    sfc = SFCS[seed % 4]
    uneven = bool(seed % 2)
    weights = rng.random(n) if seed % 3 == 0 else None
    coords = rng.normal(size=(n, d))
    perms = [tuple(rng.permutation(d)) for _ in range(4)]
    mu = order_points_batched(coords, nparts, sfc,
                              dim_orders=np.array(perms), weights=weights,
                              uneven_prime=uneven)
    for b, p in enumerate(perms):
        by_dim_order = order_points(coords, nparts, sfc, weights=weights,
                                    dim_order=np.array(p),
                                    uneven_prime=uneven)
        by_permuted = order_points(coords[:, list(p)], nparts, sfc,
                                   weights=weights, uneven_prime=uneven)
        assert np.array_equal(mu[b], by_dim_order), (sfc, p, "dim_order")
        assert np.array_equal(mu[b], by_permuted), (sfc, p, "permuted")


def test_order_points_batched_recursive_backend_agrees():
    rng = np.random.default_rng(5)
    coords = rng.normal(size=(120, 3))
    dos = np.array([(0, 1, 2), (2, 1, 0), (1, 0, 2)])
    a = order_points_batched(coords, 16, "FZ", dim_orders=dos)
    b = order_points_batched(coords, 16, "FZ", dim_orders=dos,
                             backend="recursive")
    assert np.array_equal(a, b)


def test_order_points_batched_tie_heavy_grid():
    """Structured grids (the rotation search's home turf) must stay
    bit-identical through the exact-engine fallback."""
    ix = np.indices((8, 8))
    coords = np.stack([c.ravel() for c in ix], axis=1).astype(float)
    dos = np.array([(0, 1), (1, 0)])
    for sfc in SFCS:
        mu = order_points_batched(coords, 16, sfc, dim_orders=dos)
        for b, p in enumerate(dos):
            ref = order_points_recursive(coords[:, list(p)], 16, sfc)
            assert np.array_equal(mu[b], ref), (sfc, tuple(p))


@pytest.mark.parametrize("seed", range(6))
def test_order_points_batched_hilbert_parity(seed):
    """Batched Hilbert rows are COLUMN permutations: row ``b`` must
    equal ``order_points(coords[:, dim_orders[b]], ..., "H")`` — the
    quantisation grid commutes with column permutation, so one memoised
    quantise pass serves every candidate."""
    rng = np.random.default_rng(300 + seed)
    d = int(rng.integers(1, 4))
    n = int(rng.integers(20, 300))
    nparts = int(rng.integers(2, 48))
    weights = rng.random(n) if seed % 2 else None
    coords = rng.normal(size=(n, d))
    if seed % 3 == 0:  # duplicate-heavy: exercises the stable tie order
        coords = np.repeat(coords[: max(n // 4, 1)], 4, axis=0)
        if weights is not None:
            weights = rng.random(len(coords))
    perms = [tuple(rng.permutation(d)) for _ in range(3)]
    dos = np.array(perms)
    mu = order_points_batched(coords, nparts, "H", dim_orders=dos,
                              weights=weights)
    rec = order_points_batched(coords, nparts, "H", dim_orders=dos,
                               weights=weights, backend="recursive")
    assert np.array_equal(mu, rec)
    for b, p in enumerate(perms):
        ref = order_points(coords[:, list(p)], nparts, "H",
                           weights=weights)
        assert np.array_equal(mu[b], ref), (p, "permuted")


# ---------------------------------------------------------------------------
# pipeline: batched sweep == loop oracle, end to end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg_kw", [
    dict(sfc="FZ", rotations=12),
    dict(sfc="FZ", rotations=12, uneven_prime=True, bandwidth_scale=True),
    dict(sfc="Gray", rotations=8, longest_dim=False),
    dict(sfc="FZ", rotations=10, objective=("latency_max", "weighted_hops")),
], ids=["fz", "uneven+bw", "gray-alt", "latency-objective"])
def test_pipeline_sweep_matches_loop(cfg_kw):
    m = make_machine((8, 8, 8), wrap=True)
    alloc = sfc_allocation(m, 64, nfragments=2, seed=3)
    g = stencil_graph((8, 8))
    loop = MappingPipeline(PipelineConfig(sweep="loop", **cfg_kw))
    bat = MappingPipeline(PipelineConfig(sweep="batched", **cfg_kw))
    pc = loop.machine_coords(alloc)
    tc = g.coords.astype(float)
    cands = rotation_candidates(2, 3, cfg_kw["rotations"])
    rl = loop.map_candidates(tc, pc, cands)
    rb = bat.map_candidates(tc, pc, cands)
    for x, y in zip(rl, rb):
        assert np.array_equal(x.task_to_proc, y.task_to_proc)
    res_l = loop.map(g, alloc)
    res_b = bat.map(g, alloc)
    assert np.array_equal(res_l.task_to_proc, res_b.task_to_proc)
    assert res_l.rotation == res_b.rotation


def test_pipeline_sweep_matches_loop_tnum_gt_pnum():
    """Tasks sharing processors (tnum > pnum) through the batched part
    matching."""
    m = make_machine((8, 8, 8), wrap=True)
    alloc = sfc_allocation(m, 64, seed=1)
    g = stencil_graph((16, 16))
    kw = dict(sfc="FZ", rotations=8)
    res_l = MappingPipeline(PipelineConfig(sweep="loop", **kw)).map(g, alloc)
    res_b = MappingPipeline(
        PipelineConfig(sweep="batched", **kw)).map(g, alloc)
    assert np.array_equal(res_l.task_to_proc, res_b.task_to_proc)


def test_pipeline_jax_scoring_backend_end_to_end():
    m = tpu_v5e_multipod(2, 4)
    alloc = block_allocation(m)
    g = stencil_graph((4, 8))
    res_np = MappingPipeline(PipelineConfig(
        sfc="FZ", rotations=10, score_backend="numpy")).map(g, alloc)
    res_jx = MappingPipeline(PipelineConfig(
        sfc="FZ", rotations=10, score_backend="jax")).map(g, alloc)
    assert sorted(res_jx.task_to_proc.tolist()) == list(range(32))
    assert np.isclose(res_np.score, res_jx.score, rtol=1e-4)


# ---------------------------------------------------------------------------
# candidate search details
# ---------------------------------------------------------------------------

def test_best_lexsort_keeps_first_of_ties():
    class _FakeSearch(CandidateSearch):
        def __init__(self, scores):
            super().__init__(("a", "b"))
            self._scores = np.asarray(scores, dtype=float)

        def score(self, graph, alloc, results):
            return self._scores

    scores = [[2.0, 1.0], [1.0, 5.0], [1.0, 3.0], [1.0, 3.0], [3.0, 0.0]]
    s = _FakeSearch(scores)
    results = list(range(len(scores)))
    best, best_i, got = s.best(None, None, results)
    # lexicographic minimum is (1.0, 3.0); FIRST holder is index 2
    assert best_i == 2 and best == 2
    assert np.array_equal(got, np.asarray(scores))


def test_rotation_candidates_identity_first_and_distinct():
    cands = rotation_candidates(3, 3, 24)
    assert len(cands) == 24
    assert cands[0].task_perm == tuple(range(3))
    assert cands[0].proc_perm == tuple(range(3))
    pairs = {(c.task_perm, c.proc_perm) for c in cands}
    assert len(pairs) == 24  # no duplicate rotations in the budget
    # balanced design: the batched sweep partitions few unique perms
    assert len({c.task_perm for c in cands}) <= 5
    assert len({c.proc_perm for c in cands}) <= 5


def test_bw_field_matches_manual_broadcast():
    m = gemini_xk7(dims=(4, 4, 8), cores_per_node=2)
    for k in range(3):
        idx = np.arange(m.dims[k])
        bw = np.asarray(m.bw(k, idx), dtype=float)
        shape = [1] * m.ndim
        shape[k] = m.dims[k]
        manual = np.broadcast_to(bw.reshape(shape), m.dims)
        assert np.array_equal(m.bw_field(k), manual)
