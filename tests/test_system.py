"""End-to-end behaviour tests for the full system.

These exercise the integrated story: the paper's geometric mapper builds
the device mesh; a model trains on it (loss decreases deterministically);
the serving engine decodes consistently with training; the dry-run
lowering machinery produces coherent artifacts for a small config.
"""

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import (Allocation, Mapper, MapperConfig, evaluate,
                        identity_mapping, logical_mesh_graph,
                        stencil_graph, sfc_allocation, tpu_v5e_pod,
                        make_machine)
from repro.models import ModelConfig, params_spec, tree_init
from repro.models.config import ShapeConfig
from repro.serve.engine import ServeEngine
from repro.train.driver import JobConfig, train
from repro.train.optimizer import OptConfig

TINY = ModelConfig(name="sys-tiny", family="dense", num_layers=2,
                   d_model=48, num_heads=4, num_kv_heads=2, d_ff=96,
                   vocab_size=64, head_dim=12, remat="none", loss_chunk=0,
                   dtype="float32")


def test_end_to_end_training_learns():
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    job = JobConfig(steps=25, log_every=0)
    hist = train(TINY, OptConfig(lr=1e-2, warmup_steps=2, total_steps=25,
                                 weight_decay=0.0),
                 job, mesh, shape=ShapeConfig("t", "train", 32, 8),
                 log=lambda *a: None)
    assert hist["loss"][-1] < hist["loss"][0] * 0.7


def test_end_to_end_serving_matches_training_forward():
    from repro.models import logits_fn
    params = tree_init(params_spec(TINY), jax.random.PRNGKey(0),
                       TINY.dtype)
    eng = ServeEngine(TINY, params, max_seq=24, batch=2)
    prompt = np.random.default_rng(0).integers(0, 64, (2, 8)).astype(
        np.int32)
    out = eng.generate(prompt, max_new_tokens=4)
    full = logits_fn(TINY, params, {"tokens": jnp.asarray(prompt)})
    assert (out[:, 0] == np.asarray(jnp.argmax(full[:, -1], -1))).all()


def test_geometric_mapping_improves_sparse_stencil():
    """The paper's headline behaviour: on a fragmented allocation, the
    geometric mapping cuts hops vs rank order."""
    m = make_machine((16, 16, 16), wrap=True, bw=1.0)
    alloc = sfc_allocation(m, 512, nfragments=8, seed=11)
    g = stencil_graph((8, 8, 8))
    geo = Mapper(MapperConfig(sfc="FZ", shift=True)).map(g, alloc)
    ours = evaluate(g, alloc, geo)
    base = evaluate(g, alloc, identity_mapping(g, alloc))
    assert ours["average_hops"] < base["average_hops"]


def test_candidate_selection_never_worse():
    from repro.meshmap.device_mesh import select_mapping
    m = tpu_v5e_pod(8)
    alloc = Allocation(m, m.all_coords())
    for shape, w in [((8, 8), (8.0, 64.0)), ((4, 16), (8.0, 64.0)),
                     ((16, 4), (8.0, 64.0))]:
        g = logical_mesh_graph(shape, w, None)
        best, ours, base = select_mapping(g, alloc, w, rotations=4)
        assert ours["latency_max"] <= base["latency_max"] + 1e-9


def test_dryrun_cell_small_mesh(tmp_path):
    """lower+compile+analyze pipeline end-to-end on the CPU's 1 device."""
    from repro.launch import dryrun
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    import repro.models.config as mc
    small = mc.ShapeConfig("tiny_train", "train", 32, 4)
    mc.SHAPES["tiny_train"] = small
    try:
        rec = dryrun.run_cell("zamba2_1p2b", "tiny_train", mesh,
                              str(tmp_path), "test")
    finally:
        mc.SHAPES.pop("tiny_train")
    assert rec["status"] == "ok", rec.get("error")
    assert rec["cost"]["flops"] > 0
    assert rec["memory"]["argument_size_in_bytes"] > 0
