"""N-level hierarchy equivalence suite (ISSUE 10).

Covers the :class:`repro.hier.HierarchySpec` API end to end:

- depth-2 spec reproduces the legacy ``hierarchy="node"`` path BIT
  FOR BIT (winners and refine trajectory);
- depth-1 spec is the flat pipeline;
- depth-3/4 maps keep the bijection and monotone-objective invariants
  across the scenario registry;
- equal specs built through different constructors canonicalise to
  the SAME config signature (cache-key stability);
- unknown hierarchies fail at CONFIG CONSTRUCTION with a ValueError
  listing the accepted values;
- the deprecation shim: legacy strings / flat refine kwargs map onto
  the equivalent spec with a single DeprecationWarning.
"""

import dataclasses
import warnings

import numpy as np
import pytest

from repro.core import (Mapper, MapperConfig, evaluate, gemini_xk7,
                        sfc_allocation, stencil_graph)
from repro.core.signature import config_signature
from repro.hier import (HierarchySpec, Level, group_units, polish_groups,
                        refine_qap)
from repro.hier.spec import (DEFAULT_GROUP_ARITY, DEFAULT_QAP_ROUNDS,
                             DEFAULT_REFINE_ROUNDS)
from repro.mapping import PipelineConfig
from repro.serve.scenarios import Scenario


def _grid(n):
    e = int(np.log2(n))
    a = e // 3
    return (1 << (e - 2 * a), 1 << a, 1 << a)


def _xk7_case(side=8, cores=16, nfragments=4, seed=1):
    m = gemini_xk7(dims=(2 * side, side, side), cores_per_node=cores)
    n = side ** 3 * cores  # half the machine
    alloc = sfc_allocation(m, n, nfragments=nfragments, seed=seed)
    g = stencil_graph(_grid(n))
    return m, alloc, g


def _map(g, alloc, **kw):
    kw.setdefault("sfc", "H")
    kw.setdefault("rotations", 4)
    return Mapper(MapperConfig(**kw)).map(g, alloc)


# ---------------------------------------------------------------------------
# spec construction and validation
# ---------------------------------------------------------------------------

def test_depth_accessors():
    assert HierarchySpec.flat().depth == 1
    assert HierarchySpec.flat().is_flat
    assert HierarchySpec.node().depth == 2
    assert HierarchySpec.node().kind == "node"
    s = HierarchySpec.with_depth(4)
    assert s.depth == 4 and s.kind == "depth4"
    assert [lv.name for lv in s.levels] == ["node", "socket", "rack"]


def test_with_depth_2_equals_node():
    assert HierarchySpec.with_depth(2) == HierarchySpec.node()
    assert HierarchySpec.from_string("depth2") == HierarchySpec.node()
    assert HierarchySpec.from_string("depth1") == HierarchySpec.flat()


def test_with_depth_default_budgets():
    s = HierarchySpec.with_depth(3)
    # polish supersedes the node level's bounded pass at depth >= 3
    assert s.levels[0].refine_rounds == 0
    assert s.levels[0].polish_rounds > 0
    assert s.levels[1].refine_mode == "qap"
    assert s.levels[1].refine_rounds == DEFAULT_QAP_ROUNDS
    assert s.levels[1].arity == DEFAULT_GROUP_ARITY
    # an explicit refine_rounds applies to every level (legacy fold)
    s5 = HierarchySpec.with_depth(3, refine_rounds=5)
    assert all(lv.refine_rounds == 5 for lv in s5.levels)
    # depth 2 keeps the bit-identity budget
    assert (HierarchySpec.with_depth(2).levels[0].refine_rounds
            == DEFAULT_REFINE_ROUNDS)


def test_from_machine_derives_node_arity():
    m = gemini_xk7(dims=(4, 2, 2), cores_per_node=8)
    s = HierarchySpec.from_machine(m, depth=3)
    assert s.levels[0].arity == 8
    assert s.levels[1].arity == DEFAULT_GROUP_ARITY
    # machines without core dims keep arity=None (legacy derivation)
    from repro.core import make_machine
    flat_m = make_machine((4, 4), wrap=True)
    assert HierarchySpec.from_machine(flat_m).levels[0].arity is None


def test_level_validation():
    with pytest.raises(ValueError, match="arity"):
        Level("node", 1)
    with pytest.raises(ValueError, match="refine_mode"):
        Level("node", refine_mode="anneal")
    with pytest.raises(ValueError, match="polish_rounds"):
        Level("node", polish_rounds=-1)


def test_unknown_hierarchy_raises_at_config_construction():
    # the 4xx-style error arrives when the CONFIG is built — before any
    # mapping work, serve admission or degradation rung — and lists the
    # accepted values
    for bad in ("mesh", "depthX", "three-level"):
        with pytest.raises(ValueError, match="flat.*node.*depth<N>"):
            MapperConfig(hierarchy=bad)
    with pytest.raises(ValueError, match="HierarchySpec"):
        MapperConfig(hierarchy=42)
    with pytest.raises(ValueError, match="depth<N>"):
        PipelineConfig(hierarchy="nod")


def test_spec_combinators():
    s = HierarchySpec.with_depth(4)
    assert s.truncated(2) == HierarchySpec(s.levels[:1])
    assert s.truncated(1).is_flat
    z = s.with_refine(rounds=0, polish=0)
    assert z.refine_rounds_total == 0 and z.polish_rounds_total == 0
    assert [lv.name for lv in z.levels] == [lv.name for lv in s.levels]


# ---------------------------------------------------------------------------
# deprecation shim
# ---------------------------------------------------------------------------

def test_legacy_node_string_warns_once_and_normalises():
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        cfg = MapperConfig(hierarchy="node")
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "HierarchySpec" in str(dep[0].message)
    assert cfg.hierarchy == HierarchySpec.node()
    assert cfg.refine_rounds is None  # folded into the spec


def test_legacy_refine_kwargs_fold_into_spec():
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        cfg = MapperConfig(hierarchy="node", refine_rounds=3,
                           refine_top=16)
    assert len([w for w in rec
                if issubclass(w.category, DeprecationWarning)]) == 1
    lv = cfg.hierarchy.levels[0]
    assert lv.refine_rounds == 3 and lv.refine_top == 16
    # kwargs also fold onto an explicit spec
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        cfg = PipelineConfig(hierarchy=HierarchySpec.with_depth(3),
                             refine_rounds=0)
    assert len([w for w in rec
                if issubclass(w.category, DeprecationWarning)]) == 1
    assert cfg.hierarchy.refine_rounds_total == 0


def test_flat_default_is_warning_free():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cfg = MapperConfig()
        assert cfg.hierarchy == HierarchySpec.flat()
        cfg = PipelineConfig(hierarchy=HierarchySpec.with_depth(3))
        assert cfg.hierarchy.depth == 3


def test_renormalising_is_idempotent():
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cfg = PipelineConfig(hierarchy=HierarchySpec.node())
        again = dataclasses.replace(cfg, rotations=2)  # re-runs shim
    assert again.hierarchy == cfg.hierarchy


# ---------------------------------------------------------------------------
# equivalence: depth-2 == legacy node, depth-1 == flat
# ---------------------------------------------------------------------------

def test_depth2_bit_identical_to_legacy_node():
    _, alloc, g = _xk7_case()
    new = _map(g, alloc, hierarchy=HierarchySpec.node())
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        old = _map(g, alloc, hierarchy="node")
    assert np.array_equal(new.task_to_proc, old.task_to_proc)
    assert new.rotation == old.rotation
    assert new.score == old.score
    assert new.stats["refine_history"] == old.stats["refine_history"]


def test_depth1_is_flat():
    _, alloc, g = _xk7_case(side=4)
    a = _map(g, alloc, hierarchy=HierarchySpec.flat())
    b = _map(g, alloc)  # default config: flat
    assert np.array_equal(a.task_to_proc, b.task_to_proc)
    assert a.stats["hierarchy"] == "flat"
    assert a.stats["depth"] == 1


# ---------------------------------------------------------------------------
# depth-3/4 invariants across the scenario registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("allocation", ["xk7_sparse", "bgq_block",
                                        "tpu_mesh", "fat_tree"])
@pytest.mark.parametrize("depth", [3, 4])
def test_deep_hierarchy_bijection_and_monotone(allocation, depth):
    sc = Scenario("minighost", allocation, "depth3", "wh", scale=512)
    g = sc.graph()
    alloc = sc.alloc_for(g)
    cfg = dataclasses.replace(sc.config(),
                              hierarchy=HierarchySpec.with_depth(depth))
    from repro.mapping import MappingPipeline
    res = MappingPipeline(cfg).map(g, alloc)
    t2p = res.task_to_proc
    assert len(t2p) == g.n
    if g.n == alloc.n:
        assert len(np.unique(t2p)) == g.n  # bijection
    assert res.stats["schema"] == 2
    assert res.stats["depth"] == depth
    assert len(res.stats["levels"]) == depth - 1
    # every per-level refine/polish history is monotone non-increasing
    for lv in res.stats["levels"]:
        hist = [h[0] for h in lv.get("refine_history", [])]
        assert all(x >= y - 1e-9 for x, y in zip(hist, hist[1:]))
        if "polish_initial" in lv:
            assert lv["polish_final"] <= lv["polish_initial"] + 1e-9
    # the reported score is the exact fine weighted hops
    assert evaluate(g, alloc, res)["weighted_hops"] == pytest.approx(
        res.score)


def test_deep_hierarchy_scenario_config_runs():
    from repro.mapping import MappingPipeline
    sc = Scenario("random", "xk7_sparse", "depth3", "wh", scale=512)
    req = sc.request()
    res = MappingPipeline(req.config).map(req.graph, req.alloc)
    assert res.stats["hierarchy"] == "depth3"


# ---------------------------------------------------------------------------
# refinement passes: sparse-QAP search and intra-group polish
# ---------------------------------------------------------------------------

def _cluster_case(seed=0):
    rng = np.random.default_rng(seed)
    m = gemini_xk7(dims=(8, 4, 4), cores_per_node=4)
    alloc = sfc_allocation(m, 256, nfragments=4, seed=3)
    from repro.hier import aggregate_tasks, router_view
    g = stencil_graph((8, 8, 4))
    rc, _, _ = router_view(alloc)
    agg = aggregate_tasks(g, len(rc))
    c2r = rng.permutation(len(rc))[:agg.nclusters]
    return m, agg.coarse, rc, c2r


def test_refine_qap_monotone_and_improves_random_start():
    m, coarse, rc, c2r = _cluster_case()
    out, stats = refine_qap(m, coarse, rc, c2r, rounds=4)
    hist = [h[0] for h in stats["refine_history"]]
    assert all(x >= y - 1e-9 for x, y in zip(hist, hist[1:]))
    assert stats["refine_final"] < stats["refine_initial"]
    assert sorted(out) == sorted(c2r)  # same units, re-ordered


def test_polish_groups_monotone_and_group_preserving():
    m, coarse, rc, c2r = _cluster_case(seed=1)
    member, _ = group_units(rc, len(rc) // 4)
    out, stats = polish_groups(m, coarse, rc, c2r, member, rounds=6)
    assert stats["polish_final"] <= stats["polish_initial"] + 1e-9
    assert stats["polish_accepted"] > 0  # random start: plenty to fix
    # polish NEVER moves a cluster out of its group
    assert np.array_equal(member[out], member[c2r])
    hist = [h[0] for h in stats["polish_history"]]
    assert all(x >= y - 1e-9 for x, y in zip(hist, hist[1:]))


def test_polish_zero_rounds_is_identity():
    m, coarse, rc, c2r = _cluster_case(seed=2)
    member, _ = group_units(rc, len(rc) // 4)
    out, stats = polish_groups(m, coarse, rc, c2r, member, rounds=0)
    assert np.array_equal(out, c2r)
    assert stats["polish_rounds_run"] == 0


# ---------------------------------------------------------------------------
# signature stability
# ---------------------------------------------------------------------------

def test_signature_stable_across_construction_paths():
    paths = [
        HierarchySpec.node(),
        HierarchySpec.with_depth(2),
        HierarchySpec.from_string("node"),
        HierarchySpec.from_string("depth2"),
        HierarchySpec((Level("node"),)),
    ]
    sigs = {config_signature(PipelineConfig(hierarchy=s)) for s in paths}
    assert len(sigs) == 1
    # ... and the legacy string lands on the SAME cache key as the spec
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = config_signature(MapperConfig(hierarchy="node"))
    assert legacy == config_signature(
        MapperConfig(hierarchy=HierarchySpec.node()))
    assert legacy != config_signature(MapperConfig())  # flat differs


def test_signature_distinguishes_depths_and_budgets():
    sigs = [config_signature(PipelineConfig(hierarchy=s)) for s in (
        HierarchySpec.flat(), HierarchySpec.node(),
        HierarchySpec.with_depth(3), HierarchySpec.with_depth(4),
        HierarchySpec.with_depth(3).with_refine(rounds=0, polish=0))]
    assert len(set(sigs)) == len(sigs)
