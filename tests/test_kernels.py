"""Per-kernel shape/dtype sweeps vs the ref.py oracles (interpret mode).

Per the deliverable spec: every Pallas kernel is validated against its
pure-jnp oracle across shapes and dtypes; hypothesis drives extra random
shape/value cases.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd.ops import ssd
from repro.kernels.ssd.ref import ssd_ref


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-5, rtol=2e-5)


def _mk_qkv(key, b, s, h, kh, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kh, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kh, d), jnp.float32).astype(dtype)
    return q, k, v


# ---------------------------------------------------------------------------
# Flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kh,d,bq,bk", [
    (1, 64, 4, 4, 32, 16, 16),     # MHA
    (2, 128, 8, 2, 32, 32, 32),    # GQA 4:1
    (1, 96, 4, 1, 16, 32, 32),     # MQA, padded seq (96 % 32 == 0)
    (1, 80, 4, 2, 64, 32, 32),     # q padding path (80 -> 96)
    (2, 64, 2, 2, 128, 64, 64),    # lane-width head dim
])
def test_flash_matches_ref(dtype, b, s, h, kh, d, bq, bk):
    q, k, v = _mk_qkv(jax.random.PRNGKey(b * s + d), b, s, h, kh, d, dtype)
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk,
                          interpret=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.transpose(0, 2, 1, 3), np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [16, 48])
@pytest.mark.parametrize("cap", [0.0, 30.0])
def test_flash_window_softcap(window, cap):
    q, k, v = _mk_qkv(jax.random.PRNGKey(5), 2, 128, 4, 2, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True, window=window, cap=cap,
                          bq=32, bk=32, interpret=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True, window=window,
                        cap=cap)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.transpose(0, 2, 1, 3)),
        atol=2e-5, rtol=2e-5)


@given(st.integers(1, 3), st.integers(1, 4), st.integers(0, 1),
       st.sampled_from([16, 32]), st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_flash_property(b, nblk, gqa, d, seed):
    s = nblk * 32
    h = 4
    kh = 4 if gqa == 0 else 2
    q, k, v = _mk_qkv(jax.random.PRNGKey(seed), b, s, h, kh, d, jnp.float32)
    out = flash_attention(q, k, v, causal=True, bq=32, bk=32,
                          interpret=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.transpose(0, 2, 1, 3)),
        atol=3e-5, rtol=3e-5)


def test_flash_rows_are_convex_combinations():
    """Attention output rows lie in the convex hull of V rows: max |out|
    <= max |v| (a structural property independent of the oracle)."""
    q, k, v = _mk_qkv(jax.random.PRNGKey(9), 1, 64, 4, 2, 32, jnp.float32)
    out = flash_attention(q, k, v, causal=True, bq=32, bk=32,
                          interpret=True)
    assert float(jnp.abs(out).max()) <= float(jnp.abs(v).max()) + 1e-4


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

def _mk_ssd(key, b, l, h, p, n, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (b, l, h, p), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, l, n), jnp.float32).astype(dtype)
    C = jax.random.normal(ks[4], (b, l, n), jnp.float32).astype(dtype)
    return x, dt, A, B, C


def _oracle(x, dt, A, B, C):
    b, l, h, p = x.shape
    n = B.shape[-1]
    xdt = (x * dt[..., None]).transpose(0, 2, 1, 3).reshape(b * h, l, p)
    a = (dt * A[None, None, :]).transpose(0, 2, 1).reshape(b * h, l)
    Bb = jnp.broadcast_to(B[:, None], (b, h, l, n)).reshape(b * h, l, n)
    Cb = jnp.broadcast_to(C[:, None], (b, h, l, n)).reshape(b * h, l, n)
    y, _ = ssd_ref(xdt.astype(jnp.float32), a.astype(jnp.float32),
                   Bb.astype(jnp.float32), Cb.astype(jnp.float32))
    return y.reshape(b, h, l, p).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,l,h,p,n,chunk", [
    (1, 32, 2, 16, 8, 8),
    (2, 64, 4, 16, 16, 16),
    (1, 128, 2, 32, 8, 32),
    (2, 48, 2, 8, 4, 16),       # chunk == 16, l = 48
])
def test_ssd_matches_oracle(dtype, b, l, h, p, n, chunk):
    x, dt, A, B, C = _mk_ssd(jax.random.PRNGKey(l + p), b, l, h, p, n,
                             dtype)
    y = ssd(x, dt, A, B, C, chunk=chunk, interpret=True)
    ref = _oracle(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@given(st.integers(1, 2), st.integers(1, 4), st.integers(1, 2),
       st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_ssd_property(b, nchunk, h, seed):
    slen = nchunk * 16
    x, dt, A, B, C = _mk_ssd(jax.random.PRNGKey(seed), b, slen, h, 8, 8)
    y = ssd(x, dt, A, B, C, chunk=16, interpret=True)
    ref = _oracle(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=5e-4, rtol=5e-4)


def test_ssd_decay_kills_history():
    """With a ~ -inf (instant decay), the output reduces to the purely
    local term y_t = C_t . (B_t^T x_t dt_t)."""
    b, l, h, p, n = 1, 32, 1, 8, 4
    x, dt, A, B, C = _mk_ssd(jax.random.PRNGKey(3), b, l, h, p, n)
    A = jnp.full((h,), -100.0)
    y = ssd(x, dt, A, B, C, chunk=8, interpret=True)
    local = jnp.einsum("bln,bln->bl", C, B)[..., None, None] * (
        x * dt[..., None])
    np.testing.assert_allclose(np.asarray(y), np.asarray(local),
                               atol=1e-4, rtol=1e-4)
