"""Hierarchical mapping subsystem tests (repro.hier).

Covers: geometric aggregation (balance, centroids, volume
conservation), the router view, the two-level map (bijection, exact
coarse == fine volume-weighted metrics, the ~cores_per_node x
engine-pass point reduction, quality vs flat), the monotone swap
refinement, and the Mapper / meshmap wiring."""

import numpy as np
import pytest

from repro.core import (Mapper, MapperConfig, TaskGraph, evaluate,
                        gemini_xk7, identity_mapping, logical_mesh_graph,
                        make_machine, sfc_allocation, stencil_graph,
                        tpu_v5e_multipod)
from repro.core.machine import Allocation
from repro.core.metrics import evaluate_candidates
from repro.hier import (aggregate_tasks, assign_cores, refine_swaps,
                        router_view)
from repro.mapping import (HierarchySpec, MappingPipeline,
                           PipelineConfig)


def _grid(n):
    e = int(np.log2(n))
    a = e // 3
    return (1 << (e - 2 * a), 1 << a, 1 << a)


def _xk7_case(side=8, cores=16, nfragments=4, seed=1):
    m = gemini_xk7(dims=(2 * side, side, side), cores_per_node=cores)
    n = side ** 3 * cores  # half the machine
    alloc = sfc_allocation(m, n, nfragments=nfragments, seed=seed)
    g = stencil_graph(_grid(n))
    assert g.n == n
    return m, alloc, g


# ---------------------------------------------------------------------------
# aggregation
# ---------------------------------------------------------------------------

def test_aggregate_balanced_sizes_and_labels():
    g = stencil_graph((16, 16))
    agg = aggregate_tasks(g, 16)
    assert agg.nclusters == 16
    assert agg.sizes.sum() == g.n
    assert (agg.sizes == 16).all()  # unit weights: perfectly balanced
    assert agg.labels.min() == 0 and agg.labels.max() == 15
    assert np.array_equal(np.bincount(agg.labels), agg.sizes)


def test_aggregate_centroids_are_member_means():
    rng = np.random.default_rng(0)
    g = stencil_graph((8, 8))
    agg = aggregate_tasks(g, 4)
    for c in range(4):
        members = g.coords[agg.labels == c]
        assert np.allclose(agg.coarse.coords[c], members.mean(axis=0))
    # weighted centroids
    w = rng.uniform(0.5, 2.0, g.n)
    aggw = aggregate_tasks(g, 4, task_weights=w)
    for c in range(4):
        mask = aggw.labels == c
        expect = np.average(g.coords[mask], axis=0, weights=w[mask])
        assert np.allclose(aggw.coarse.coords[c], expect)
        assert np.isclose(aggw.weights[c], w[mask].sum())


def test_aggregate_volume_conservation_no_self_edges():
    g = stencil_graph((8, 8, 8))
    agg = aggregate_tasks(g, 32)
    assert (agg.coarse.edges[:, 0] != agg.coarse.edges[:, 1]).all()
    total = g.weights.sum()
    assert np.isclose(agg.coarse.weights.sum() + agg.intra_volume, total)
    # contracted volume between a cluster pair equals the fine volume
    ce = agg.labels[g.edges]
    a, b = agg.coarse.edges[0]
    fine = g.weights[(ce[:, 0] == a) & (ce[:, 1] == b)].sum()
    assert np.isclose(agg.coarse.weights[0], fine)


def test_aggregate_bounds():
    g = stencil_graph((4, 4))
    with pytest.raises(ValueError):
        aggregate_tasks(g, 0)
    with pytest.raises(ValueError):
        aggregate_tasks(g, 17)
    one = aggregate_tasks(g, 1)
    assert one.nclusters == 1 and len(one.coarse.edges) == 0
    assert np.isclose(one.intra_volume, g.weights.sum())


# ---------------------------------------------------------------------------
# router view
# ---------------------------------------------------------------------------

def test_router_view_roundtrip():
    m = gemini_xk7(dims=(4, 4, 4), cores_per_node=16)
    alloc = sfc_allocation(m, 8 * 16, seed=0)
    rc, core_router, ralloc = router_view(alloc)
    assert len(rc) == 8
    assert len(core_router) == alloc.n
    # every core row matches its router's network coords
    assert np.array_equal(alloc.coords[:, :3], rc[core_router])
    # the router allocation zero-pads core dims
    assert ralloc.coords.shape == (8, 4)
    assert (ralloc.coords[:, 3] == 0).all()


# ---------------------------------------------------------------------------
# two-level map
# ---------------------------------------------------------------------------

def test_hier_bijection_and_quality_vs_flat():
    m, alloc, g = _xk7_case()
    flat = Mapper(MapperConfig(sfc="FZ", shift=True, rotations=8))
    node = Mapper(MapperConfig(sfc="FZ", shift=True, rotations=8,
                               hierarchy=HierarchySpec.node()))
    rf, rn = flat.map(g, alloc), node.map(g, alloc)
    assert np.array_equal(np.sort(rn.task_to_proc), np.arange(g.n))
    ef, en = evaluate(g, alloc, rf), evaluate(g, alloc, rn)
    assert en["weighted_hops"] <= 1.05 * ef["weighted_hops"]
    base = evaluate(g, alloc, identity_mapping(g, alloc))
    assert en["weighted_hops"] <= base["weighted_hops"]


def test_hier_point_reduction_matches_cores_per_node():
    m, alloc, g = _xk7_case()
    flat = Mapper(MapperConfig(sfc="FZ")).map(g, alloc)
    node = Mapper(MapperConfig(sfc="FZ", hierarchy=HierarchySpec.node())).map(g, alloc)
    assert flat.stats["sweep_points"] == 2 * g.n
    assert node.stats["sweep_points"] == 2 * g.n // 16
    assert flat.stats["sweep_points"] / node.stats["sweep_points"] == 16
    assert node.stats["cores_per_node"] == 16
    assert node.stats["nclusters"] == node.stats["nrouters"] == g.n // 16


def test_coarse_score_equals_fine_weighted_hops():
    """Every task carries its node's router coordinates, so the
    contracted graph's weighted_hops is EXACTLY the fine mapping's."""
    m, alloc, g = _xk7_case(nfragments=2, seed=5)
    rn = Mapper(MapperConfig(sfc="FZ", hierarchy=HierarchySpec.node())).map(g, alloc)
    fine = evaluate(g, alloc, rn)["weighted_hops"]
    assert rn.score == rn.stats["refine_final"] == fine


def test_hierarchy_flat_is_default_and_unchanged():
    m, alloc, g = _xk7_case(nfragments=2, seed=3)
    default = Mapper(MapperConfig(sfc="FZ", rotations=4)).map(g, alloc)
    flat = Mapper(MapperConfig(sfc="FZ", rotations=4,
                               hierarchy="flat")).map(g, alloc)
    assert np.array_equal(default.task_to_proc, flat.task_to_proc)
    assert default.stats["hierarchy"] == "flat"


def test_invalid_hierarchy_rejected():
    m, alloc, g = _xk7_case(nfragments=1)
    with pytest.raises(ValueError, match="hierarchy"):
        MappingPipeline(PipelineConfig(hierarchy="bogus")).map(g, alloc)


def test_hier_machine_without_core_dims():
    """No core dims: one cluster per router (degenerate coarsening) —
    still a valid bijection, never worse than identity."""
    m = make_machine((16, 16), wrap=True)
    alloc = sfc_allocation(m, 64, nfragments=4, seed=7)
    g = stencil_graph((8, 8))
    res = MappingPipeline(PipelineConfig(hierarchy=HierarchySpec.node())).map(g, alloc)
    assert np.array_equal(np.sort(res.task_to_proc), np.arange(64))
    base = evaluate(g, alloc, identity_mapping(g, alloc))
    assert evaluate(g, alloc, res)["weighted_hops"] \
        <= base["weighted_hops"]


def test_hier_fewer_tasks_than_nodes():
    m = gemini_xk7(dims=(8, 4, 4), cores_per_node=16)
    alloc = sfc_allocation(m, 64 * 16, seed=0)  # 64 routers
    g = stencil_graph((16, 16))  # 256 tasks -> 16 clusters of 16
    res = MappingPipeline(PipelineConfig(hierarchy=HierarchySpec.node())).map(g, alloc)
    assert res.stats["nclusters"] == 16
    procs = np.unique(res.task_to_proc)
    assert len(procs) == 256  # distinct cores (16 routers x 16 cores)
    routers = np.unique(alloc.coords[res.task_to_proc][:, :3], axis=0)
    assert len(routers) == 16


def test_hier_bijection_with_uneven_router_core_counts():
    """nnodes not a multiple of cores_per_node trims the last router:
    the expansion must spill over-capacity tasks to free cores instead
    of oversubscribing the trimmed node (was a silent bijection break)."""
    m = gemini_xk7(dims=(4, 4, 4), cores_per_node=16)
    alloc = sfc_allocation(m, 100, seed=0)  # 6 full routers + 4 cores
    g = stencil_graph((10, 10))
    res = MappingPipeline(PipelineConfig(hierarchy=HierarchySpec.node())).map(g, alloc)
    assert np.array_equal(np.sort(res.task_to_proc), np.arange(100))


def test_hier_hilbert_sfc():
    """sfc="H" runs Hilbert numbering through aggregation and the
    coarse sweep (no silent substitution)."""
    m, alloc, g = _xk7_case(side=4, nfragments=2, seed=2)
    res = MappingPipeline(PipelineConfig(sfc="H",
                                         hierarchy=HierarchySpec.node())).map(g, alloc)
    assert np.array_equal(np.sort(res.task_to_proc), np.arange(g.n))
    base = evaluate(g, alloc, identity_mapping(g, alloc))
    assert evaluate(g, alloc, res)["weighted_hops"] \
        <= base["weighted_hops"]


def test_hier_oversubscribed_cores():
    m = gemini_xk7(dims=(4, 4, 2), cores_per_node=4)
    alloc = sfc_allocation(m, 64, seed=0)  # 16 routers x 4 cores
    g = stencil_graph((16, 8))  # 128 tasks on 64 cores
    res = MappingPipeline(PipelineConfig(hierarchy=HierarchySpec.node())).map(g, alloc)
    counts = np.bincount(res.task_to_proc, minlength=64)
    assert (counts == 2).all()  # even 2-task-per-core round-robin


# ---------------------------------------------------------------------------
# refinement
# ---------------------------------------------------------------------------

def _coarse_problem(seed=0, nclusters=48, side=8):
    rng = np.random.default_rng(seed)
    machine = make_machine((side, side), wrap=True)
    routers = np.stack(np.unravel_index(
        rng.choice(side * side, nclusters, replace=False), (side, side)),
        axis=1)
    g = stencil_graph((8, nclusters // 8))
    agg = aggregate_tasks(g, nclusters)
    return machine, agg.coarse, routers


@pytest.mark.parametrize("seed", range(4))
def test_refine_monotone_from_scrambled_start(seed):
    machine, coarse, routers = _coarse_problem(seed)
    rng = np.random.default_rng(seed + 100)
    c2r = rng.permutation(len(routers))
    c2r, stats = refine_swaps(machine, coarse, routers, c2r,
                              rounds=6, top=16, degree=4)
    hist = [h[0] for h in stats["refine_history"]]
    assert all(b <= a + 1e-9 for a, b in zip(hist, hist[1:]))
    assert stats["refine_final"] <= stats["refine_initial"]
    # a scrambled start has plenty of slack: refinement must find some
    assert stats["refine_final"] < stats["refine_initial"]
    # the refined assignment is still a valid injection
    assert len(np.unique(c2r)) == len(c2r)
    # reported final score matches a fresh evaluation
    ev = evaluate_candidates(machine, coarse.edges, coarse.weights,
                             routers[c2r][None])
    assert np.isclose(ev["weighted_hops"][0], stats["refine_final"])


def test_refine_noop_on_optimal_line():
    """A contiguous 1D chain on a line of routers is hop-optimal:
    refinement must accept nothing and change nothing."""
    machine = make_machine((16,), wrap=False)
    g = stencil_graph((16,))
    coarse = TaskGraph(g.coords, g.edges, g.weights)
    routers = np.arange(16)[:, None]
    c2r, stats = refine_swaps(machine, coarse, routers, np.arange(16),
                              rounds=3, top=8, degree=2)
    assert np.array_equal(c2r, np.arange(16))
    assert stats["refine_final"] == stats["refine_initial"]


def test_refine_latency_objective_monotone():
    """Non-separable (latency) objectives take the full-stack scoring
    path and must stay monotone too."""
    machine, coarse, routers = _coarse_problem(2)
    rng = np.random.default_rng(9)
    start = rng.permutation(len(routers))
    c2r, stats = refine_swaps(
        machine, coarse, routers, start, rounds=3, top=12, degree=3,
        objective=("latency_max", "weighted_hops"))
    hist = stats["refine_history"]
    for a, b in zip(hist, hist[1:]):
        assert tuple(b) <= tuple(a)


def test_assign_cores_groups_clusters_on_nodes():
    m = gemini_xk7(dims=(4, 4, 2), cores_per_node=8)
    alloc = sfc_allocation(m, 4 * 8, seed=1)
    rc, core_router, _ = router_view(alloc)
    g = stencil_graph((8, 4))
    agg = aggregate_tasks(g, 4)
    c2r = np.array([2, 0, 3, 1])
    t2p = assign_cores(agg.labels, c2r, core_router, g.coords, len(rc))
    assert np.array_equal(np.sort(t2p), np.arange(32))
    # every task of a cluster lands on its assigned router
    for c in range(4):
        rows = alloc.coords[t2p[agg.labels == c]]
        assert (rows[:, :3] == rc[c2r[c]]).all()


# ---------------------------------------------------------------------------
# fused on-device refinement (ISSUE 9): bit-identity vs host refine_swaps
# ---------------------------------------------------------------------------

def _fused_refine_case():
    pytest.importorskip("jax")
    m = gemini_xk7(dims=(8, 4, 4), cores_per_node=4)
    alloc = sfc_allocation(m, 256, nfragments=2, seed=3)
    g = stencil_graph(_grid(256))
    return m, alloc, g


_REFINE_STAT_KEYS = ("refine_rounds_run", "refine_accepted",
                     "refine_evaluated", "refine_initial", "refine_final")


@pytest.mark.parametrize("sfc", ["FZ", "H"])
def test_fused_refinement_bit_identity_wh(sfc):
    """The device refinement folded into the fused program must
    reproduce the host refine_swaps trajectory decision-for-decision:
    same accepted swaps, same per-round history, same final mapping."""
    m, alloc, g = _fused_refine_case()
    kw = dict(sfc=sfc, rotations=6, hierarchy=HierarchySpec.node())
    host = MappingPipeline(PipelineConfig(**kw)).map(g, alloc)
    dev = MappingPipeline(PipelineConfig(
        partition_backend="jax", score_backend="jax", **kw)).map(g, alloc)
    assert dev.stats.get("fused_refine") is True
    assert not host.stats.get("fused_refine")
    assert np.array_equal(host.task_to_proc, dev.task_to_proc)
    assert dev.stats["refine_history"] == host.stats["refine_history"]
    for k in _REFINE_STAT_KEYS:
        assert dev.stats[k] == host.stats[k], k
    # monotone on device too
    hist = dev.stats["refine_history"]
    for a, b in zip(hist, hist[1:]):
        assert tuple(b) <= tuple(a)
    # span-derived timings keep the host schema (refine_s always there)
    assert {"fused_s", "refine_s", "coarsen_s", "total_s"} \
        <= set(dev.stats["timings"])
    assert {"partition_s", "score_s", "refine_s"} \
        <= set(host.stats["timings"])


def test_fused_refinement_bit_identity_latency_objective():
    """Non-separable objectives route the fused refinement through the
    SAME inlined scorer kind as the host comparison — the (latency_max,
    weighted_hops) trajectory must match exactly."""
    m, alloc, g = _fused_refine_case()
    kw = dict(sfc="FZ", rotations=6, hierarchy=HierarchySpec.node(),
              objective=("latency_max", "weighted_hops"))
    host = MappingPipeline(PipelineConfig(
        score_backend="jax", **kw)).map(g, alloc)
    dev = MappingPipeline(PipelineConfig(
        partition_backend="jax", score_backend="jax", **kw)).map(g, alloc)
    assert dev.stats.get("fused_refine") is True
    assert np.array_equal(host.task_to_proc, dev.task_to_proc)
    assert dev.stats["refine_history"] == host.stats["refine_history"]
    hist = dev.stats["refine_history"]
    for a, b in zip(hist, hist[1:]):
        assert tuple(b) <= tuple(a)


def test_fused_refinement_refine_rounds_zero():
    """refine_rounds=0 must skip the device loop but keep the stats and
    timings schema (history of length 1, nothing accepted)."""
    m, alloc, g = _fused_refine_case()
    res = MappingPipeline(PipelineConfig(
        sfc="FZ", rotations=4, hierarchy=HierarchySpec.node(refine_rounds=0),
        partition_backend="jax", score_backend="jax")).map(g, alloc)
    assert res.stats["refine_rounds_run"] == 0
    assert res.stats["refine_accepted"] == 0
    assert len(res.stats["refine_history"]) == 1
    assert res.stats["refine_final"] == res.stats["refine_initial"]
    assert "refine_s" in res.stats["timings"]


def test_fused_refinement_ladder_unfused_rung_bit_identical():
    """PR-7 ladder honesty: the ``unfused`` rung (fused="off") of a
    Hilbert + refinement config must exist and serve a bit-identical
    result — degradation moves WHERE the algorithm runs, not what it
    returns."""
    pytest.importorskip("jax")
    from repro.serve.resilience import degradation_ladder, fused_candidate
    m, alloc, g = _fused_refine_case()
    cfg = PipelineConfig(sfc="H", rotations=6, hierarchy=HierarchySpec.node(),
                         partition_backend="jax", score_backend="jax")
    assert fused_candidate(cfg)
    ladder = dict(degradation_ladder(cfg))
    assert "unfused" in ladder
    full = MappingPipeline(cfg).map(g, alloc)
    unfused = MappingPipeline(ladder["unfused"]).map(g, alloc)
    assert full.stats.get("fused_refine") is True
    assert not unfused.stats.get("fused_refine")
    assert np.array_equal(full.task_to_proc, unfused.task_to_proc)
    assert full.stats["refine_history"] == unfused.stats["refine_history"]


# ---------------------------------------------------------------------------
# meshmap wiring
# ---------------------------------------------------------------------------

def test_select_mapping_hierarchy_node_never_worse_than_default():
    from repro.meshmap.device_mesh import select_mapping
    m = tpu_v5e_multipod(npods=2, side=4)
    alloc = Allocation(m, np.stack(np.unravel_index(
        np.arange(32), m.dims), axis=1))
    ab = (1.0, 8.0, 64.0)
    g = logical_mesh_graph((2, 4, 4), ab)
    best, best_m, base_m = select_mapping(g, alloc, ab, rotations=4,
                                          hierarchy=HierarchySpec.node())
    assert best_m["latency_max"] <= base_m["latency_max"] + 1e-9
    assert np.array_equal(np.sort(best.task_to_proc), np.arange(32))
