"""Calibration tests for the HLO-text cost model (roofline foundation).

The roofline numbers are only as good as this model, so we pin it
against XLA's own cost_analysis on programs where XLA is correct
(no while loops), and against analytic truth on scans (where XLA
undercounts — the reason this module exists).
"""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze, xla_cost_analysis


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_matmul_flops_exact():
    n = 256
    c = _compile(lambda a, b: a @ b,
                 jax.ShapeDtypeStruct((n, n), jnp.float32),
                 jax.ShapeDtypeStruct((n, n), jnp.float32))
    r = analyze(c.as_text())
    assert abs(r["flops"] - 2 * n ** 3) / (2 * n ** 3) < 0.01


def test_scan_flops_scaled_by_trip_count():
    n, t = 128, 10

    def g(a, ws):
        def body(x, w):
            return x @ w, None
        y, _ = jax.lax.scan(body, a, ws)
        return y

    c = _compile(g, jax.ShapeDtypeStruct((n, n), jnp.float32),
                 jax.ShapeDtypeStruct((t, n, n), jnp.float32))
    r = analyze(c.as_text())
    expect = t * 2 * n ** 3
    assert abs(r["flops"] - expect) / expect < 0.02
    # XLA's own count misses the trip scaling (the bug we correct)
    xla = xla_cost_analysis(c)["flops"]
    assert xla < expect / 2


def test_nested_scan():
    n = 64

    def h(a, ws):
        def outer(x, wrow):
            def inner(y, w):
                return y @ w, None
            z, _ = jax.lax.scan(inner, x, wrow)
            return z, None
        y, _ = jax.lax.scan(outer, a, ws)
        return y

    c = _compile(h, jax.ShapeDtypeStruct((n, n), jnp.float32),
                 jax.ShapeDtypeStruct((3, 4, n, n), jnp.float32))
    r = analyze(c.as_text())
    expect = 12 * 2 * n ** 3
    assert abs(r["flops"] - expect) / expect < 0.05


def test_batched_dot_general():
    c = _compile(lambda a, b: jnp.einsum("bik,bkj->bij", a, b),
                 jax.ShapeDtypeStruct((8, 64, 32), jnp.float32),
                 jax.ShapeDtypeStruct((8, 32, 16), jnp.float32))
    r = analyze(c.as_text())
    expect = 8 * 2 * 64 * 32 * 16
    assert abs(r["flops"] - expect) / expect < 0.01


def test_collectives_inside_scan_are_scaled():
    import numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >1 device (run under forced host device count)")
    mesh = Mesh(np.array(devs[:2]), ("x",))
    n, t = 128, 5

    def g(a, ws):
        def body(x, w):
            y = x @ w
            return jax.lax.with_sharding_constraint(
                y, NamedSharding(mesh, PartitionSpec())), None
        y, _ = jax.lax.scan(body, a, ws)
        return y

    sh = NamedSharding(mesh, PartitionSpec(None, "x"))
    c = jax.jit(g, in_shardings=(sh, None)).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32),
        jax.ShapeDtypeStruct((t, n, n), jnp.float32)).compile()
    r = analyze(c.as_text())
    # at least t collectives' worth of bytes (trip-scaled)
    assert r["collective_total_bytes"] > 0


def test_bytes_match_xla_on_unrolled_model():
    from repro.models import ModelConfig, loss_fn, params_spec, tree_abstract
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
                      head_dim=16, remat="none", loss_chunk=0,
                      dtype="float32", attn_impl="quadratic",
                      scan_layers=False)
    ab = tree_abstract(params_spec(cfg), cfg.dtype)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((2, 32), jnp.int32),
             "mask": jax.ShapeDtypeStruct((2, 32), jnp.float32)}
    c = _compile(lambda p, b: loss_fn(cfg, p, b), ab, batch)
    ours = analyze(c.as_text())
    xla = xla_cost_analysis(c)
    assert abs(ours["flops"] - xla["flops"]) / xla["flops"] < 0.05
    assert abs(ours["bytes_hbm"] - xla["bytes accessed"]) / \
        xla["bytes accessed"] < 0.15
