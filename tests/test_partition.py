"""Equivalence suite: the level-synchronous vectorised partitioner must
return part numbers BIT-IDENTICAL to the recursive reference (paper
Alg. 2) for every configuration — random point sets, weights,
``uneven_prime``, ``dim_order``, strict-alternation mode, and every SFC
kind, including the tie-heavy structured-grid inputs that exercise the
engine's exact fallback.  Property-style via seeded numpy RNG (no
hypothesis dependency, so tier-1 runs everywhere)."""

import numpy as np
import pytest

from repro.core import partition
from repro.core.orderings import order_points, order_points_recursive

SFCS = ("Z", "Gray", "FZ", "FZlow")


def _grid_coords(shape):
    ix = np.indices(shape)
    return np.stack([c.ravel() for c in ix], axis=1).astype(float)


def _assert_equiv(coords, nparts, sfc, **kw):
    a = order_points(coords, nparts, sfc, backend="vectorized", **kw)
    b = order_points_recursive(coords, nparts, sfc, **kw)
    assert np.array_equal(a, b), (
        f"backend mismatch: sfc={sfc} nparts={nparts} kw={kw} "
        f"ndiff={(a != b).sum()}/{len(a)}")
    return a


# ---------------------------------------------------------------------------
# random point sets across every knob
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(24))
def test_random_points_all_knobs(seed):
    rng = np.random.default_rng(seed)
    d = int(rng.integers(1, 5))
    n = int(rng.integers(2, 500))
    nparts = int(rng.integers(1, 80))
    sfc = SFCS[seed % 4]
    weights = rng.random(n) if seed % 3 == 0 else None
    uneven = bool(seed % 2)
    longest = seed % 5 != 0
    dim_order = rng.permutation(d) if seed % 4 == 0 else None
    coords = rng.normal(size=(n, d))
    mu = _assert_equiv(coords, nparts, sfc, weights=weights,
                       uneven_prime=uneven, longest_dim=longest,
                       dim_order=dim_order)
    if nparts >= 1:
        assert mu.min() >= 0 and mu.max() < max(nparts, 1)


@pytest.mark.parametrize("sfc", SFCS)
def test_balanced_partition_sizes(sfc):
    rng = np.random.default_rng(7)
    coords = rng.normal(size=(256, 3))
    mu = _assert_equiv(coords, 64, sfc)
    assert (np.bincount(mu, minlength=64) == 4).all()


# ---------------------------------------------------------------------------
# tie-heavy grids (closed-form cross-check territory)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sfc", SFCS)
@pytest.mark.parametrize("shape", [(16,), (8, 8), (4, 4, 4), (2, 2, 2, 2)])
def test_grids_full_parts(sfc, shape):
    coords = _grid_coords(shape)
    _assert_equiv(coords, coords.shape[0], sfc)


@pytest.mark.parametrize("sfc", SFCS)
@pytest.mark.parametrize("nparts", [2, 4, 8])
def test_grids_coarse_parts(sfc, nparts):
    coords = _grid_coords((8, 8))
    _assert_equiv(coords, nparts, sfc)


@pytest.mark.parametrize("sfc", SFCS)
def test_duplicated_points_fall_back_exactly(sfc):
    """Straddling tie groups force the exact engine; results must still
    match the reference bit for bit."""
    rng = np.random.default_rng(5)
    coords = np.repeat(rng.normal(size=(37, 2)), 7, axis=0)
    _assert_equiv(coords, 16, sfc)
    _assert_equiv(coords, 16, sfc, weights=rng.random(len(coords)))


def test_tie_fallback_is_detected():
    """All-identical coordinates must route to the exact engine (and
    agree with the reference), not silently mis-split."""
    coords = np.zeros((64, 2))
    coords[:, 1] = np.arange(64) % 2  # ties along dim 0, the cut dim
    with pytest.raises(partition._TieFallback):
        partition._fast_order(coords, 4, "Z", None, None, True, False)
    _assert_equiv(coords, 4, "Z")


# ---------------------------------------------------------------------------
# weighted / uneven corner cases
# ---------------------------------------------------------------------------

def test_weighted_heavy_head():
    coords = np.arange(64, dtype=float)[:, None]
    w = np.ones(64)
    w[:8] = 8.0
    mu = _assert_equiv(coords, 2, "Z", weights=w)
    left = np.flatnonzero(mu == 0)
    assert abs(w[left].sum() - w.sum() / 2) <= w.max()


@pytest.mark.parametrize("nparts", [3, 5, 6, 20, 48, 10800 // 100])
def test_uneven_prime_counts(nparts):
    coords = np.arange(300, dtype=float)[:, None]
    mu = _assert_equiv(coords, nparts, "Z", uneven_prime=True)
    counts = np.bincount(mu, minlength=nparts)
    assert counts.sum() == 300 and mu.max() == nparts - 1
    assert counts.min() >= 300 // nparts - 1


def test_more_parts_than_points():
    rng = np.random.default_rng(3)
    coords = rng.normal(size=(5, 2))
    _assert_equiv(coords, 16, "FZ")


def test_single_point_and_single_part():
    assert order_points(np.zeros((1, 3)), 8, "FZ")[0] == 0
    assert (order_points(np.random.default_rng(0).normal(size=(32, 2)),
                         1, "FZ") == 0).all()


# ---------------------------------------------------------------------------
# engines under the hood
# ---------------------------------------------------------------------------

def test_padded_buffer_overflow_falls_back_to_loop(monkeypatch):
    """When the padded cumsum buffer would exceed the cap, the exact
    engine must take the per-segment loop — not leak _TieFallback."""
    monkeypatch.setattr(partition, "_PAD_CAP", 16)
    rng = np.random.default_rng(21)
    coords = rng.normal(size=(200, 2))
    w = rng.random(200)
    _assert_equiv(coords, 16, "FZ", weights=w)


def test_exact_engine_deep_part_counts():
    """Deep recursion (nparts ~ npoints): the O(nseg) segment-table
    interleave that replaced the per-level argsort rebuild must stay bit
    identical when the table grows by hundreds of segments per level."""
    rng = np.random.default_rng(29)
    coords = np.repeat(rng.normal(size=(256, 2)), 4, axis=0)  # force exact
    for nparts in (613, 1024):
        _assert_equiv(coords, nparts, "FZ")
    w = rng.random(len(coords))
    _assert_equiv(coords, 1021, "Gray", weights=w, uneven_prime=True)


def test_exact_engine_matches_reference_directly():
    rng = np.random.default_rng(11)
    coords = rng.normal(size=(200, 3))
    w = rng.random(200)
    a = partition._exact_order(coords, 32, "FZ", w, None, True, False)
    b = order_points_recursive(coords, 32, "FZ", weights=w)
    assert np.array_equal(a[0], b)


def test_presort_is_value_ascending():
    rng = np.random.default_rng(13)
    x = rng.normal(size=4096)
    # unique values: must equal the stable sort exactly
    assert np.array_equal(partition._presort(x),
                          np.argsort(x, kind="stable"))
    # duplicates (incl. -0.0 vs 0.0): still a value-ascending permutation
    x[:200] = x[200:400]
    x[100] = -0.0
    x[101] = 0.0
    p = partition._presort(x)
    assert sorted(p.tolist()) == list(range(len(x)))
    assert (np.diff(x[p]) >= 0).all()


def test_unknown_backend_rejected():
    with pytest.raises(ValueError):
        order_points(np.zeros((4, 1)), 2, "FZ", backend="nope")


def test_hilbert_backend_passthrough():
    rng = np.random.default_rng(17)
    coords = rng.normal(size=(64, 2))
    a = order_points(coords, 8, "H")
    b = order_points_recursive(coords, 8, "H")
    assert np.array_equal(a, b)
