"""Serve-layer tests (ISSUE 5): content-addressed signatures, LRU
hit/miss/eviction, request coalescing (solo bit-identity), scenario-
registry determinism, and the end-to-end warm path (zero new backend
compiles via PR 4's compile-cache counters)."""

import dataclasses
import threading

import numpy as np
import pytest

from repro.core import (Allocation, config_signature, machine_signature,
                        make_machine, mapping_signature, stencil_graph,
                        taskgraph_signature)
from repro.mapping import PipelineConfig, shared_pipeline
from repro.serve import (LRUCache, MappingService, all_scenarios,
                         get_scenario, make_request, scenario_names)
from repro.serve.scenarios import (ALLOCATIONS, HIERARCHIES,
                                   OBJECTIVE_KEYS, WORKLOADS)

SCALE = 256  # tiny but structurally real problems

BASE = "minighost-xk7_sparse-flat-wh"


def _req(name=BASE, seed=0, scale=SCALE):
    return get_scenario(name, scale=scale, seed=seed).request()


# ---------------------------------------------------------------------------
# Signatures
# ---------------------------------------------------------------------------

def test_signature_is_content_addressed():
    a, b = _req(), _req()
    assert a is not b and a.graph is not b.graph
    assert a.signature() == b.signature()


def test_signature_sensitivity():
    a = _req()
    g = a.graph
    w2 = g.weights.copy()
    g2 = dataclasses.replace(g, weights=w2)
    assert taskgraph_signature(g) == taskgraph_signature(g2)
    w2[3] += 1.0
    assert taskgraph_signature(g) != taskgraph_signature(g2)

    cfg = PipelineConfig(rotations=4)
    cfg2 = PipelineConfig(rotations=8)
    assert config_signature(cfg) != config_signature(cfg2)
    assert mapping_signature(g, a.alloc, cfg) != \
        mapping_signature(g, a.alloc, cfg2)


def test_machine_name_is_a_label_not_identity():
    m = make_machine((4, 4), wrap=True, name="one")
    m2 = dataclasses.replace(m, name="two")
    assert machine_signature(m) == machine_signature(m2)
    m3 = make_machine((4, 4), wrap=False, name="one")
    assert machine_signature(m) != machine_signature(m3)


def test_taskgraph_meta_excluded_from_signature():
    g = stencil_graph((4, 4))
    g2 = dataclasses.replace(g, meta={"totally": "different"})
    assert taskgraph_signature(g) == taskgraph_signature(g2)


# ---------------------------------------------------------------------------
# LRU cache
# ---------------------------------------------------------------------------

def test_lru_hit_miss_eviction():
    c = LRUCache(capacity=2)
    assert c.get("a") is None
    c.put("a", 1)
    c.put("b", 2)
    assert c.get("a") == 1  # refreshes "a": "b" is now LRU
    c.put("x", 3)           # evicts "b"
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("x") == 3
    st = c.stats()
    assert st["evictions"] == 1 and st["size"] == 2
    assert st["hits"] == 3 and st["misses"] == 2


def test_lru_put_refreshes_existing():
    c = LRUCache(capacity=2)
    c.put("a", 1)
    c.put("b", 2)
    c.put("a", 10)  # refresh, not insert: no eviction
    c.put("x", 3)   # evicts "b" (LRU), not "a"
    assert c.get("a") == 10 and c.get("b") is None
    assert c.stats()["evictions"] == 1


def test_lru_rejects_zero_capacity():
    with pytest.raises(ValueError):
        LRUCache(capacity=0)


# ---------------------------------------------------------------------------
# Service: warm/cold, eviction, coalescing
# ---------------------------------------------------------------------------

def test_warm_hit_is_bit_identical():
    svc = MappingService(capacity=8)
    cold = svc.map(_req())
    warm = svc.map(_req())  # fresh objects, same content
    assert cold.status == "cold" and warm.status == "warm"
    assert warm.signature == cold.signature
    assert np.array_equal(cold.result.task_to_proc,
                          warm.result.task_to_proc)
    st = svc.stats()
    assert st["cold"] == 1 and st["warm"] == 1
    assert st["cache"]["hits"] == 1


def test_eviction_forces_recompute():
    svc = MappingService(capacity=1)
    r1 = _req("minighost-xk7_sparse-flat-wh")
    r2 = _req("minighost-tpu_mesh-flat-wh")
    assert svc.map(r1).status == "cold"
    assert svc.map(r2).status == "cold"   # evicts r1
    assert svc.map(_req("minighost-xk7_sparse-flat-wh")).status == "cold"
    assert svc.results.stats()["evictions"] == 2
    assert svc.stats()["warm"] == 0


def test_map_many_coalesces_batch_duplicates():
    svc = MappingService(capacity=8)
    reqs = [_req() for _ in range(5)] + [_req("homme-bgq_block-flat-wh")]
    responses = svc.map_many(reqs)
    statuses = [r.status for r in responses]
    assert statuses.count("cold") == 2
    assert statuses.count("coalesced") == 4
    # coalesced results are bit-identical to a solo request
    solo = MappingService(capacity=8).map(_req())
    for r in responses[:5]:
        assert np.array_equal(r.result.task_to_proc,
                              solo.result.task_to_proc)


def test_concurrent_requests_share_one_computation():
    gate = threading.Event()
    computes = []

    class Blocking(MappingService):
        def _compute(self, request):
            computes.append(request.signature())
            assert gate.wait(timeout=30)
            return super()._compute(request)

    svc = Blocking(capacity=8)
    responses = [None] * 4

    def worker(i):
        responses[i] = svc.map(_req())

    threads = [threading.Thread(target=worker, args=(i,))
               for i in range(4)]
    for t in threads:
        t.start()
    # wait until the owner is inside _compute, then release everyone
    for _ in range(1000):
        if computes:
            break
        threading.Event().wait(0.01)
    gate.set()
    for t in threads:
        t.join(timeout=60)
    assert len(computes) == 1, "duplicate in-flight requests recomputed"
    statuses = sorted(r.status for r in responses)
    assert statuses == ["coalesced", "coalesced", "coalesced", "cold"]
    ref = responses[0].result.task_to_proc
    for r in responses[1:]:
        assert np.array_equal(r.result.task_to_proc, ref)


def test_compute_error_propagates_and_clears_inflight():
    class Broken(MappingService):
        def _compute(self, request):
            raise RuntimeError("boom")

    svc = Broken(capacity=8)
    with pytest.raises(RuntimeError):
        svc.map(_req())
    assert svc.stats()["inflight"] == 0
    # the failure is not cached: nothing poisoned for later requests
    assert svc.results.stats()["size"] == 0


def test_make_request_objective_aliases():
    g = stencil_graph((4, 4))
    alloc = Allocation(make_machine((4, 4), wrap=True),
                       np.stack(np.meshgrid(range(4), range(4),
                                            indexing="ij"),
                                axis=-1).reshape(-1, 2))
    r = make_request(g, alloc, "latency", rotations=2)
    assert r.config.objective == ("latency_max", "weighted_hops")
    r2 = make_request(g, alloc, "wh")
    assert r2.config.objective == "weighted_hops"
    with pytest.raises(ValueError):
        make_request(g, alloc, config=PipelineConfig(), rotations=2)


# ---------------------------------------------------------------------------
# Scenario registry
# ---------------------------------------------------------------------------

def test_registry_is_the_full_cross_product():
    scens = all_scenarios(scale=SCALE)
    expect = (len(WORKLOADS) * len(ALLOCATIONS) * len(HIERARCHIES)
              * len(OBJECTIVE_KEYS))
    assert len(scens) == expect
    names = [s.name for s in scens]
    assert len(set(names)) == expect
    assert scenario_names() == names


def test_get_scenario_roundtrip_and_validation():
    s = get_scenario(BASE, scale=SCALE, seed=3)
    assert s.name == BASE and s.seed == 3
    with pytest.raises(ValueError):
        get_scenario("minighost-xk7_sparse-flat")
    with pytest.raises(ValueError):
        get_scenario("nope-xk7_sparse-flat-wh")


def test_scenario_determinism_same_seed_same_graph():
    for name in ("random-tpu_mesh-flat-wh", "minighost-fat_tree-node-wh",
                 "homme-xk7_sparse-flat-latency"):
        a = get_scenario(name, scale=SCALE, seed=1).request()
        b = get_scenario(name, scale=SCALE, seed=1).request()
        assert np.array_equal(a.graph.coords, b.graph.coords)
        assert np.array_equal(a.graph.edges, b.graph.edges)
        assert np.array_equal(a.graph.weights, b.graph.weights)
        assert np.array_equal(a.alloc.coords, b.alloc.coords)
        assert a.signature() == b.signature()
    # scenarios with a stochastic component (random graphs, fragmented
    # sparse allocations) must CHANGE with the seed; fully structured
    # ones (stencil on a block prefix) are legitimately seed-free
    for name in ("random-tpu_mesh-flat-wh",
                 "homme-xk7_sparse-flat-latency"):
        a = get_scenario(name, scale=SCALE, seed=1).request()
        c = get_scenario(name, scale=SCALE, seed=2).request()
        assert a.signature() != c.signature(), name


def test_random_workload_seed_changes_graph():
    a = get_scenario("random-tpu_mesh-flat-wh", scale=SCALE,
                     seed=1).request()
    b = get_scenario("random-tpu_mesh-flat-wh", scale=SCALE,
                     seed=2).request()
    assert not np.array_equal(a.graph.coords, b.graph.coords)


def test_every_scenario_serves_a_valid_mapping():
    svc = MappingService(capacity=64)
    for s in all_scenarios(scale=SCALE):
        req = s.request()
        resp = svc.map(req)
        assert resp.status == "cold", s.name
        t2p = resp.result.task_to_proc
        assert len(t2p) == req.graph.n, s.name
        assert t2p.min() >= 0 and t2p.max() < req.alloc.n, s.name
        if req.graph.n == req.alloc.n:
            # 1:1 scenarios must be bijections
            assert len(np.unique(t2p)) == req.graph.n, s.name


# ---------------------------------------------------------------------------
# Warm path: shared pipelines, zero new backend compiles
# ---------------------------------------------------------------------------

def test_shared_pipeline_is_memoised_by_config_content():
    a = shared_pipeline(PipelineConfig(rotations=4))
    b = shared_pipeline(PipelineConfig(rotations=4))
    c = shared_pipeline(PipelineConfig(rotations=8))
    assert a is b and a is not c


def test_warm_path_zero_new_backend_compiles():
    """End-to-end: a repeat request must not trigger ANY new jax scorer
    compile — it never reaches the scoring engine at all (PR 4's
    compile-cache counters are the witness)."""
    metrics_jax = pytest.importorskip("repro.core.metrics_jax")
    svc = MappingService(capacity=8)
    scen = get_scenario("minighost-tpu_mesh-flat-latency", scale=SCALE)

    def jax_request():
        req = scen.request()
        return make_request(req.graph, req.alloc, "latency",
                            rotations=4, score_backend="jax")

    cold = svc.map(jax_request())
    assert cold.status == "cold"
    before = metrics_jax.scorer_cache_stats()
    warm = svc.map(jax_request())  # fresh objects, same content
    after = metrics_jax.scorer_cache_stats()
    assert warm.status == "warm"
    assert after["misses"] == before["misses"], \
        "warm-path request compiled a new backend scorer"
    assert after["hits"] == before["hits"], \
        "warm-path request re-entered the scoring engine"
    assert np.array_equal(cold.result.task_to_proc,
                          warm.result.task_to_proc)


def test_default_service_is_a_process_singleton():
    from repro.serve import default_service
    a = default_service()
    b = default_service(capacity=8)  # capacity only sizes the first call
    assert a is b
    resp = a.map(_req("homme-tpu_mesh-flat-wh"))
    assert resp.status == "cold"
    assert a.map(_req("homme-tpu_mesh-flat-wh")).status == "warm"


def test_select_mapping_through_the_service_cache():
    from repro.core import logical_mesh_graph, tpu_v5e_pod
    from repro.meshmap.device_mesh import select_mapping

    machine = tpu_v5e_pod(side=4)
    graph = logical_mesh_graph((4, 4), (8.0, 64.0), ("data", "model"))
    coords = machine.all_coords()
    alloc = Allocation(machine, coords)
    svc = MappingService(capacity=32)
    best1, m1, _ = select_mapping(graph, alloc, [8.0, 64.0],
                                  rotations=4, service=svc)
    warm_before = svc.stats()["warm"]
    best2, m2, _ = select_mapping(graph, alloc, [8.0, 64.0],
                                  rotations=4, service=svc)
    assert svc.stats()["warm"] > warm_before, \
        "repeat mesh build did not hit the service cache"
    assert np.array_equal(best1.task_to_proc, best2.task_to_proc)
    assert m1 == m2
