"""Metric (Eqns. 1-7) and routing tests."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.machine import make_machine, gemini_xk7
from repro.core.metrics import (average_hops, data_metric,
                                latency_metric, pairwise_hops,
                                per_dim_stats, route_traffic)


def test_hops_mesh_vs_torus():
    mesh = make_machine((8, 8), wrap=False)
    torus = make_machine((8, 8), wrap=True)
    src = np.array([[0, 0]])
    dst = np.array([[7, 7]])
    assert pairwise_hops(mesh, src, dst)[0] == 14
    assert pairwise_hops(torus, src, dst)[0] == 2  # wrap both dims


def test_hops_ignores_core_dims():
    m = make_machine((4, 4, 16), wrap=(True, True, False), core_dims=0)
    m2 = make_machine((4, 4, 16), wrap=(True, True, False))
    object.__setattr__(m2, "core_dims", 1)
    src = np.array([[0, 0, 0]])
    dst = np.array([[0, 0, 15]])
    assert pairwise_hops(m, src, dst)[0] == 15
    assert pairwise_hops(m2, src, dst)[0] == 0


def test_route_single_message_mesh():
    m = make_machine((4, 4), wrap=False)
    t = route_traffic(m, np.array([[0, 0]]), np.array([[2, 1]]),
                      np.array([5.0]))
    # dim 0: + links at (0,0) and (1,0); dim 1: + link at (2,0)
    assert t.pos[0][0, 0] == 5.0 and t.pos[0][1, 0] == 5.0
    assert t.pos[0].sum() == 10.0 and t.neg[0].sum() == 0.0
    assert t.pos[1][2, 0] == 5.0 and t.pos[1].sum() == 5.0


def test_route_wraparound_shortest():
    m = make_machine((8,), wrap=True)
    t = route_traffic(m, np.array([[7]]), np.array([[1]]), np.array([1.0]))
    # 7 -> 0 -> 1 forward (2 hops) beats 6 backward hops
    assert t.pos[0][7] == 1.0 and t.pos[0][0] == 1.0
    assert t.pos[0].sum() == 2.0 and t.neg[0].sum() == 0.0


def test_route_negative_direction():
    m = make_machine((8,), wrap=False)
    t = route_traffic(m, np.array([[5]]), np.array([[2]]), np.array([1.0]))
    # crossing 5->4->3->2 uses - channels at link indices 4, 3, 2
    assert t.neg[0][4] == 1.0 and t.neg[0][3] == 1.0 and t.neg[0][2] == 1.0
    assert t.pos[0].sum() == 0.0


@given(st.integers(2, 10), st.integers(1, 3), st.integers(1, 30),
       st.booleans())
@settings(max_examples=30, deadline=None)
def test_traffic_conserves_hop_bytes(side, d, nmsg, wrap):
    """sum(link data) == sum(weight * hops) for any message set."""
    m = make_machine((side,) * d, wrap=wrap)
    rng = np.random.default_rng(side * 100 + d * 10 + nmsg)
    src = rng.integers(0, side, size=(nmsg, d))
    dst = rng.integers(0, side, size=(nmsg, d))
    w = rng.uniform(0.5, 2.0, size=nmsg)
    t = route_traffic(m, src, dst, w)
    hop_bytes = (pairwise_hops(m, src, dst) * w).sum()
    assert np.isclose(t.link_data().sum(), hop_bytes)


def test_latency_uses_heterogeneous_bandwidth():
    # dim 0 has bw 10, dim 1 has bw 1: same data -> 10x latency on dim 1
    m = make_machine((4, 4), wrap=False, bw=(10.0, 1.0))
    t = route_traffic(m, np.array([[0, 0], [0, 0]]),
                      np.array([[1, 0], [0, 1]]), np.array([7.0, 7.0]))
    assert data_metric(t) == 7.0
    assert latency_metric(t) == 7.0  # bottleneck is the slow dim-1 link
    stats = per_dim_stats(t)
    assert stats["dim0+"]["lat_max"] == pytest.approx(0.7)
    assert stats["dim1+"]["lat_max"] == pytest.approx(7.0)


def test_gemini_patterned_bandwidth():
    m = gemini_xk7(dims=(4, 4, 8), cores_per_node=2)
    # y links alternate 75 / 37.5
    assert m.bw(1, 0) == 75.0 and m.bw(1, 1) == 37.5
    # z backplane within 8, cable at the boundary
    assert m.bw(2, 0) == 120.0 and m.bw(2, 7) == 75.0


def test_average_hops_stencil_identity():
    """Identity mapping of a grid onto the same grid: every neighbour is
    one hop."""
    m = make_machine((8, 8), wrap=False)
    ix = np.indices((8, 8))
    coords = np.stack([c.ravel() for c in ix], axis=1)
    # edges: +x neighbours
    src = coords[:-8]
    dst = coords[8:]
    assert average_hops(m, src, dst) == 1.0
