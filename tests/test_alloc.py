"""Allocation-helper tests: sfc_allocation fragment disjointness, exact
row counts (with and without core dims), the nnodes=0 edge case,
random_allocation uniqueness, the free-window fallback, and a
hypothesis in-bounds property (skipped when hypothesis is absent)."""

import numpy as np
import pytest

from repro.core import (bgq, gemini_xk7, make_machine, random_allocation,
                        sfc_allocation)
from repro.core.machine import _first_free_window

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - dev-only dependency
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# fragment disjointness (the silent-overlap bug of the fallback path)
# ---------------------------------------------------------------------------

def test_fragments_disjoint_under_pressure():
    """Heavy fragmentation of a small machine forces the random
    placement to collide, exercising the free-window fallback: every
    successful allocation must have DISJOINT fragments (no duplicated
    coordinates — the old fallback silently overlapped), and requests
    whose free space genuinely fragments must raise, not overlap.
    Both paths must occur across the seed sweep."""
    m = make_machine((8, 8), wrap=True)
    outcomes = {"ok": 0, "raised": 0}
    for seed in range(16):
        try:
            a = sfc_allocation(m, 48, nfragments=12, seed=seed)
        except ValueError as e:
            assert "free window" in str(e)
            outcomes["raised"] += 1
            continue
        assert a.n == 48
        assert len(np.unique(a.coords, axis=0)) == 48
        outcomes["ok"] += 1
    assert outcomes["ok"] > 0  # the fallback can succeed
    assert outcomes["raised"] > 0  # and refuses instead of overlapping


@pytest.mark.parametrize("seed", range(24))
def test_fragments_disjoint_or_explicit_error(seed):
    """Near-full allocations may leave no contiguous free window for a
    fragment; the allocator must either produce disjoint fragments or
    raise — never silently overlap."""
    m = make_machine((4,), wrap=True)
    try:
        a = sfc_allocation(m, 4, nfragments=2, seed=seed)
    except ValueError as e:
        assert "free window" in str(e)
        return
    assert len(np.unique(a.coords, axis=0)) == a.n == 4


def test_first_free_window():
    occ = np.array([1, 1, 0, 1, 0, 0, 0, 1], dtype=bool)
    assert _first_free_window(occ, 1) == 2
    assert _first_free_window(occ, 2) == 4
    assert _first_free_window(occ, 3) == 4
    assert _first_free_window(occ, 4) == -1
    assert _first_free_window(np.ones(4, dtype=bool), 1) == -1
    assert _first_free_window(np.zeros(4, dtype=bool), 4) == 0


# ---------------------------------------------------------------------------
# exact row counts + the nnodes=0 edge case
# ---------------------------------------------------------------------------

def test_nnodes_zero_is_empty():
    # with core dims the old code returned the FULL core expansion
    m = gemini_xk7(dims=(4, 4, 4), cores_per_node=16)
    assert sfc_allocation(m, 0, seed=1).n == 0
    assert sfc_allocation(m, 0, nfragments=3, seed=1).n == 0
    # and without core dims
    m2 = make_machine((8, 8), wrap=True)
    assert sfc_allocation(m2, 0, seed=1).n == 0


@pytest.mark.parametrize("nnodes", [1, 15, 16, 40, 64])
def test_exact_rows_with_core_dims(nnodes):
    """Requests that are not a multiple of cores-per-node still get
    exactly nnodes rows (the last router is partially used)."""
    m = gemini_xk7(dims=(4, 4, 4), cores_per_node=16)
    a = sfc_allocation(m, nnodes, seed=2)
    assert a.n == nnodes
    assert len(np.unique(a.coords, axis=0)) == nnodes
    b = random_allocation(m, nnodes, seed=2)
    assert b.n == nnodes
    assert len(np.unique(b.coords, axis=0)) == nnodes


@pytest.mark.parametrize("nnodes", [1, 7, 32])
def test_exact_rows_without_core_dims(nnodes):
    m = make_machine((8, 8), wrap=True)
    for a in (sfc_allocation(m, nnodes, seed=3),
              random_allocation(m, nnodes, seed=3)):
        assert a.n == nnodes
        assert len(np.unique(a.coords, axis=0)) == nnodes


def test_allocation_larger_than_machine_raises():
    m = make_machine((4, 4), wrap=True)
    with pytest.raises(ValueError):
        sfc_allocation(m, 17)


def test_random_allocation_unique_and_in_bounds():
    m = bgq(dims=(2, 2, 2, 4, 2), cores_per_node=8)
    a = random_allocation(m, 100, seed=5)
    assert a.n == 100
    assert len(np.unique(a.coords, axis=0)) == 100
    assert (a.coords >= 0).all()
    assert (a.coords < np.array(m.dims)).all()


# ---------------------------------------------------------------------------
# hypothesis property: every coordinate row is in-bounds for its machine
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @given(st.integers(2, 6), st.integers(1, 3), st.integers(1, 4),
           st.integers(0, 31), st.integers(1, 4), st.booleans())
    @settings(max_examples=40, deadline=None)
    def test_allocation_coords_in_bounds(side, d, cores, seed, nfrag,
                                         use_random):
        machine = make_machine((side,) * d + (cores,), wrap=True,
                               core_dims=1)
        total = side ** d * cores
        nnodes = 1 + seed % total
        try:
            if use_random:
                a = random_allocation(machine, nnodes, seed=seed)
            else:
                a = sfc_allocation(machine, nnodes, nfragments=nfrag,
                                   seed=seed)
        except ValueError:
            return  # explicit refusal (no free window) is acceptable
        assert a.n == nnodes
        assert (a.coords >= 0).all()
        assert (a.coords < np.array(machine.dims)).all()
        assert len(np.unique(a.coords, axis=0)) == nnodes
else:  # pragma: no cover - hypothesis present in CI
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_allocation_coords_in_bounds():
        pass
