"""Resilience tests (ISSUE 7): the fault-injection registry, circuit
breakers, the degradation ladder, and MappingService's fault handling —
a pallas/jax backend raising mid-request degrades one rung down with
winners bit-identical to the numpy oracle, deadlines drop slow rungs,
overload sheds, failed flights never poison the cache or their waiters.

Every test that asserts exact fire/failure counts runs inside
``faults.isolated()`` so the CI chaos job's ambient ``REPRO_FAULTS``
schedule cannot perturb it.
"""

import dataclasses
import threading
import time

import numpy as np
import pytest

from repro import faults
from repro.mapping import (HierarchySpec, PipelineConfig,
                           shared_pipeline)
from repro.serve import (MappingService, ServiceOverloaded, get_scenario,
                         degradation_ladder, rung_key)
from repro.serve.resilience import BreakerBoard, CircuitBreaker

SCALE = 256

BASE = "minighost-xk7_sparse-flat-wh"


def _scenario(name=BASE, seed=0, scale=SCALE):
    return get_scenario(name, scale=scale, seed=seed)


def _req(name=BASE, seed=0, scale=SCALE, **overrides):
    sc = _scenario(name, seed=seed, scale=scale)
    req = sc.request()
    if overrides:
        cfg = dataclasses.replace(sc.config(), **overrides)
        req = dataclasses.replace(req, config=cfg, _signature=None)
    return req


def _has_jax():
    from repro.core.orderings import resolve_partition_backend
    return resolve_partition_backend("jax") == "jax"


# ---------------------------------------------------------------------------
# The fault registry
# ---------------------------------------------------------------------------

def test_parse_schedule():
    rows = faults.parse_schedule(
        "score.jax:error:count=1, partition.*:slow:delay=0.25:after=2,"
        "serve.cache:evict:prob=0.5:seed=7")
    assert rows[0] == ("score.jax", "error", {"count": 1})
    assert rows[1] == ("partition.*", "slow", {"delay": 0.25, "after": 2})
    assert rows[2] == ("serve.cache", "evict", {"prob": 0.5, "seed": 7})
    assert faults.parse_schedule("") == []


def test_parse_schedule_rejects_garbage():
    with pytest.raises(ValueError):
        faults.parse_schedule("score.jax")  # no kind
    with pytest.raises(ValueError):
        faults.parse_schedule("a.b:error:count")  # option without =
    with pytest.raises(ValueError):
        faults.parse_schedule("a.b:error:frobnicate=1")
    with pytest.raises(ValueError):
        faults.install("a.b", "explode")  # unknown kind


def test_fire_kinds_raise_typed_exceptions():
    with faults.isolated():
        with faults.injected("x.compile", "compile"):
            with pytest.raises(faults.InjectedCompileError):
                faults.fire("x.compile")
        with faults.injected("x.oom", "oom"):
            with pytest.raises(faults.InjectedDeviceOOM) as ei:
                faults.fire("x.oom")
            assert "RESOURCE_EXHAUSTED" in str(ei.value)
        with faults.injected("x.err", "error"):
            with pytest.raises(faults.InjectedFault):
                faults.fire("x.err")
        # all injected kinds share one catchable base
        assert issubclass(faults.InjectedCompileError, faults.InjectedFault)
        assert issubclass(faults.InjectedDeviceOOM, faults.InjectedFault)


def test_count_and_after_windows():
    with faults.isolated():
        with faults.injected("s", "error", count=1, after=1) as spec:
            faults.fire("s")  # skipped by after=1
            with pytest.raises(faults.InjectedFault):
                faults.fire("s")
            faults.fire("s")  # count exhausted: dormant again
            assert (spec.calls, spec.fired) == (3, 1)


def test_site_patterns_match_fnmatch():
    with faults.isolated():
        with faults.injected("score.*", "error") as spec:
            with pytest.raises(faults.InjectedFault):
                faults.fire("score.pallas")
            faults.fire("partition.jax")  # no match
            assert spec.fired == 1


def test_seeded_probability_is_deterministic():
    def pattern():
        fired = []
        with faults.isolated(), \
                faults.injected("p", "error", prob=0.4, seed=123):
            for _ in range(32):
                try:
                    faults.fire("p")
                    fired.append(0)
                except faults.InjectedFault:
                    fired.append(1)
        return fired

    a, b = pattern(), pattern()
    assert a == b                      # replayable under the same seed
    assert 0 < sum(a) < 32             # actually probabilistic


def test_env_reload_and_isolated():
    with faults.isolated():
        specs = faults.reload_env("a.b:error:count=2,c.d:slow:delay=0.01")
        assert len(specs) == 2 and faults.active()
        with faults.isolated():
            # inner isolation suspends even programmatic specs
            faults.fire("a.b")
            assert not faults.active()
        with pytest.raises(faults.InjectedFault):
            faults.fire("a.b")
        for s in specs:
            faults.remove(s)
        assert not faults.active()


def test_evict_kind_invokes_callback_only():
    with faults.isolated():
        hits = []
        with faults.injected("cache", "evict"):
            faults.fire("cache", on_evict=lambda: hits.append(1))
            faults.fire("cache")  # no callback passed: harmless no-op
        assert hits == [1]


# ---------------------------------------------------------------------------
# Circuit breaker
# ---------------------------------------------------------------------------

class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def test_breaker_state_machine():
    clk = _Clock()
    br = CircuitBreaker(threshold=2, cooldown_s=10.0, clock=clk)
    assert br.state == "closed" and br.allow()
    br.record_failure()
    assert br.state == "closed"        # below threshold
    br.record_failure()
    assert br.state == "open" and br.opens == 1
    assert not br.allow()              # open: requests refused
    clk.t = 10.0
    assert br.state == "half_open"
    assert br.allow()                  # the single probe
    assert not br.allow()              # second prober refused
    br.record_success()
    assert br.state == "closed" and br.allow()


def test_breaker_failed_probe_reopens():
    clk = _Clock()
    br = CircuitBreaker(threshold=1, cooldown_s=5.0, clock=clk)
    br.record_failure()
    assert br.state == "open"
    clk.t = 5.0
    assert br.allow()                  # probe
    br.record_failure()                # probe failed
    assert br.state == "open" and br.opens == 2
    clk.t = 9.9
    assert not br.allow()              # new cooldown window


def test_breaker_success_resets_consecutive_failures():
    br = CircuitBreaker(threshold=2, cooldown_s=1.0)
    br.record_failure()
    br.record_success()
    br.record_failure()
    assert br.state == "closed"        # never two CONSECUTIVE failures
    assert br.failures == 2            # cumulative counter still counts


def test_breaker_board_shares_per_key():
    board = BreakerBoard(threshold=1, cooldown_s=1.0)
    assert board.get("k") is board.get("k")
    assert board.get("k") is not board.get("other")
    board.get("k").record_failure()
    assert board.states()["k"]["state"] == "open"
    assert board.states()["other"]["state"] == "closed"


# ---------------------------------------------------------------------------
# The degradation ladder
# ---------------------------------------------------------------------------

def test_ladder_host_config_is_single_rung():
    lad = degradation_ladder(PipelineConfig())
    assert [n for n, _ in lad] == ["full"]


def test_ladder_full_accelerator_config():
    if not _has_jax():
        pytest.skip("jax unavailable")
    cfg = PipelineConfig(score_backend="pallas", partition_backend="jax",
                        rotations=4, hierarchy=HierarchySpec.node())
    names = [n for n, _ in degradation_ladder(cfg)]
    assert names == ["full", "unfused", "score_jax", "score_numpy",
                     "partition_numpy", "refine_0"]
    # cumulative: the terminal rung is all-host with zero refine rounds
    last = degradation_ladder(cfg)[-1][1]
    assert (last.score_backend, last.partition_backend, last.fused,
            last.hierarchy.refine_rounds_total) == ("numpy", "numpy",
                                                    "off", 0)
    # the first rung is the caller's config, untouched
    assert degradation_ladder(cfg)[0][1] is cfg


def test_ladder_deep_hierarchy_gets_depth_rung():
    """depth > 2 configs degrade through the classic two-level scheme
    BEFORE shedding refinement entirely."""
    cfg = PipelineConfig(rotations=4,
                         hierarchy=HierarchySpec.with_depth(3))
    names = [n for n, _ in degradation_ladder(cfg)]
    assert names[-2:] == ["depth_2", "refine_0"]
    d2 = dict(degradation_ladder(cfg))["depth_2"]
    assert d2.hierarchy.depth == 2
    assert d2.hierarchy.levels == cfg.hierarchy.levels[:1]
    last = degradation_ladder(cfg)[-1][1]
    assert last.hierarchy.depth == 2
    assert last.hierarchy.refine_rounds_total == 0


def test_ladder_jax_score_only():
    names = [n for n, _ in
             degradation_ladder(PipelineConfig(score_backend="jax"))]
    if _has_jax():
        assert names == ["full", "score_numpy"]
    else:
        assert names == ["full"]  # resolves to numpy: nothing to shed


def test_rung_key_tracks_resolved_backends():
    k = rung_key(PipelineConfig())
    assert "score=numpy" in k and "partition=numpy" in k
    if _has_jax():
        kj = rung_key(PipelineConfig(score_backend="jax",
                                     partition_backend="jax"))
        assert kj.startswith("fused/") and "score=jax" in kj


# ---------------------------------------------------------------------------
# Service-level degradation (the satellite's oracle tests)
# ---------------------------------------------------------------------------

@pytest.mark.skipif(not _has_jax(), reason="jax unavailable")
def test_pallas_fault_degrades_to_jax_bit_identical():
    """Pallas scorer raising mid-request -> jax rung, winners
    bit-identical to the numpy oracle."""
    with faults.isolated():
        svc = MappingService()
        req = _req(score_backend="pallas", partition_backend="numpy",
                   rotations=4)
        with faults.injected("score.pallas", "error", count=1) as spec:
            resp = svc.map(req)
            assert spec.fired == 1
        assert resp.status == "cold"
        assert resp.result.stats["degraded"] == "score_jax"

        oracle = shared_pipeline(
            dataclasses.replace(req.config, score_backend="numpy")
        ).map(req.graph, req.alloc)
        np.testing.assert_array_equal(resp.result.task_to_proc,
                                      oracle.task_to_proc)
        s = svc.stats()
        assert s["degraded"] == 1 and s["rungs"] == {"score_jax": 1}
        assert s["rung_failures"] == 1


@pytest.mark.skipif(not _has_jax(), reason="jax unavailable")
def test_jax_partition_fault_degrades_to_numpy_bit_identical():
    """Device partition backend raising -> host engine rung,
    bit-identical to the numpy oracle."""
    with faults.isolated():
        svc = MappingService()
        req = _req(score_backend="numpy", partition_backend="jax",
                   rotations=4)
        with faults.injected("partition.jax", "error", count=1) as spec:
            resp = svc.map(req)
            assert spec.fired == 1
        assert resp.result.stats["degraded"] == "partition_numpy"

        oracle = shared_pipeline(
            dataclasses.replace(req.config, partition_backend="numpy")
        ).map(req.graph, req.alloc)
        np.testing.assert_array_equal(resp.result.task_to_proc,
                                      oracle.task_to_proc)


@pytest.mark.skipif(not _has_jax(), reason="jax unavailable")
def test_device_oom_degrades_and_result_is_valid():
    with faults.isolated():
        svc = MappingService()
        req = _req(score_backend="jax", rotations=4)
        with faults.injected("score.jax", "oom", count=1):
            resp = svc.map(req)
        assert resp.result.stats["degraded"] == "score_numpy"
        t2p = resp.result.task_to_proc
        assert np.array_equal(np.sort(t2p), np.arange(len(t2p)))  # bijection


@pytest.mark.skipif(not _has_jax(), reason="jax unavailable")
def test_fused_fault_degrades_to_unfused_bit_identical():
    with faults.isolated():
        svc = MappingService()
        req = _req(score_backend="jax", partition_backend="jax",
                   rotations=4)
        with faults.injected("fused", "compile", count=1):
            resp = svc.map(req)
        assert resp.result.stats["degraded"] == "unfused"
        oracle = shared_pipeline(
            dataclasses.replace(req.config, fused="off")
        ).map(req.graph, req.alloc)
        np.testing.assert_array_equal(resp.result.task_to_proc,
                                      oracle.task_to_proc)


@pytest.mark.skipif(not _has_jax(), reason="jax unavailable")
def test_slow_stage_with_deadline_degrades():
    with faults.isolated():
        svc = MappingService(deadline_s=0.15)
        req = _req(score_backend="jax", rotations=4)
        # the first rung hangs well past the deadline; the numpy rung
        # then serves the request
        with faults.injected("serve.compute", "slow", delay=5.0, count=1):
            resp = svc.map(req)
        assert resp.result.stats["degraded"] == "score_numpy"
        s = svc.stats()
        assert s["deadline_misses"] == 1
        assert s["degraded"] == 1


@pytest.mark.skipif(not _has_jax(), reason="jax unavailable")
def test_breaker_opens_skips_and_recovers():
    with faults.isolated():
        clk = _Clock()
        svc = MappingService(breaker_threshold=2, breaker_cooldown_s=30.0,
                             clock=clk)
        spec = faults.install("score.jax", "error")
        try:
            for seed in (0, 1):  # two failures trip the rung's breaker
                resp = svc.map(_req(seed=seed, score_backend="jax",
                                    rotations=4))
                assert resp.result.stats["degraded"] == "score_numpy"
            fired_before = spec.fired
            resp = svc.map(_req(seed=2, score_backend="jax", rotations=4))
            assert resp.result.stats["degraded"] == "score_numpy"
            assert spec.fired == fired_before  # rung skipped, not tried
            s = svc.stats()
            assert s["breaker_skips"] >= 1
            [open_key] = [k for k, v in s["breakers"].items()
                          if v["state"] == "open"]
            assert "score=jax" in open_key
        finally:
            faults.remove(spec)
        # cooldown elapses, fault gone: the probe closes the breaker
        clk.t = 30.0
        resp = svc.map(_req(seed=3, score_backend="jax", rotations=4))
        assert "degraded" not in resp.result.stats
        assert all(v["state"] == "closed"
                   for v in svc.stats()["breakers"].values())


def test_admission_shedding_and_queueing():
    with faults.isolated():
        gate = threading.Event()
        entered = threading.Event()

        class Blocking(MappingService):
            def _compute(self, request):
                entered.set()
                gate.wait()
                return super()._compute(request)

        svc = Blocking(max_inflight=1, max_queue=0)
        errs = []

        def owner():
            try:
                svc.map(_req(seed=10))
            except BaseException as e:  # pragma: no cover
                errs.append(e)

        t = threading.Thread(target=owner)
        t.start()
        assert entered.wait(5.0)
        with pytest.raises(ServiceOverloaded):
            svc.map(_req(seed=11))  # queue full: shed
        gate.set()
        t.join(10.0)
        assert not errs
        assert svc.stats()["shed"] == 1
        # warm hits bypass admission entirely
        assert svc.map(_req(seed=10)).status == "warm"


def test_failed_compute_not_cached_and_recomputable():
    """Satellite: an error must evict the in-flight entry — a later
    identical request recomputes instead of replaying the error, and
    errors never enter the LRU."""
    with faults.isolated():
        svc = MappingService()
        req = _req(seed=20)
        with faults.injected("serve.compute", "error", count=1):
            with pytest.raises(faults.InjectedFault):
                svc.map(req)
        assert len(svc.results) == 0       # errors never enter the LRU
        assert svc.stats()["inflight"] == 0
        resp = svc.map(_req(seed=20))      # fresh identical request
        assert resp.status == "cold"
        assert len(svc.results) == 1


def test_waiter_recomputes_after_owner_failure():
    with faults.isolated():
        release = threading.Event()
        entered = threading.Event()
        calls = []

        class FlakyOnce(MappingService):
            def _compute(self, request):
                calls.append(1)
                if len(calls) == 1:
                    entered.set()
                    release.wait(5.0)
                    raise RuntimeError("transient backend failure")
                return super()._compute(request)

        svc = FlakyOnce()
        req = _req(seed=21)
        outcomes = {}

        def run(tag):
            try:
                outcomes[tag] = svc.map(_req(seed=21))
            except RuntimeError as e:
                outcomes[tag] = e

        t1 = threading.Thread(target=run, args=("owner",))
        t1.start()
        assert entered.wait(5.0)
        t2 = threading.Thread(target=run, args=("waiter",))
        t2.start()
        time.sleep(0.1)  # let the waiter reach the in-flight wait
        release.set()
        t1.join(10.0)
        t2.join(10.0)
        assert isinstance(outcomes["owner"], RuntimeError)
        # the waiter did NOT replay the owner's error: it recomputed
        assert outcomes["waiter"].result is not None
        np.testing.assert_array_equal(
            outcomes["waiter"].result.task_to_proc,
            svc.map(req).result.task_to_proc)
        assert len(calls) == 2


def test_eviction_storm_recomputes_but_serves():
    with faults.isolated():
        svc = MappingService()
        req = _req(seed=22)
        first = svc.map(req)
        with faults.injected("serve.cache", "evict", count=1):
            resp = svc.map(_req(seed=22))
        assert resp.status == "cold"       # storm wiped the warm entry
        np.testing.assert_array_equal(resp.result.task_to_proc,
                                      first.result.task_to_proc)
        assert svc.results.stats()["storms"] == 1
        assert svc.map(_req(seed=22)).status == "warm"  # storm over


def test_no_faults_bit_identical_and_breakers_closed():
    """Acceptance: with nothing injected the service returns exactly
    the pipeline's (PR 6 fused, where eligible) result and no breaker
    ever opens."""
    with faults.isolated():
        kwargs = (dict(score_backend="jax", partition_backend="jax")
                  if _has_jax() else {})
        svc = MappingService()
        req = _req(rotations=4, **kwargs)
        resp = svc.map(req)
        direct = shared_pipeline(req.config).map(req.graph, req.alloc)
        np.testing.assert_array_equal(resp.result.task_to_proc,
                                      direct.task_to_proc)
        if _has_jax():
            assert resp.result.stats.get("fused") is True
        s = svc.stats()
        assert "degraded" not in resp.result.stats
        assert s["degraded"] == 0 and s["rung_failures"] == 0
        assert all(v["state"] == "closed" and v["opens"] == 0
                   for v in s["breakers"].values())


def test_stats_requests_counts_only_request_statuses():
    svc = MappingService()
    svc.map(_req(seed=23))
    svc.map(_req(seed=23))
    s = svc.stats()
    assert s["requests"] == 2 == s["cold"] + s["warm"] + s["coalesced"]
