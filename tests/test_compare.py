"""Benchmark-regression gate tests (ISSUE 5): the committed baseline
self-compares clean, and a deliberately perturbed baseline FAILS the
gate (the negative test the acceptance criteria require)."""

import copy
import importlib.util
import json
import os
import subprocess
import sys

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")
_COMPARE = os.path.join(_ROOT, "benchmarks", "compare.py")
_BASELINE = os.path.join(_ROOT, "benchmarks", "baseline.json")

_spec = importlib.util.spec_from_file_location("bench_compare", _COMPARE)
compare_mod = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(compare_mod)


@pytest.fixture()
def baseline():
    with open(_BASELINE) as f:
        return json.load(f)


def test_committed_baseline_self_compares_clean(baseline):
    assert compare_mod.compare(baseline, baseline) == []


def test_perturbed_exact_oracle_fails(baseline):
    bad = copy.deepcopy(baseline)
    bad["benchmarks"]["candidates"]["derived"]["winner_identical"] = 0.0
    problems = compare_mod.compare(baseline, bad)
    assert any("winner_identical" in p for p in problems)


def test_ratio_regression_beyond_tolerance_fails(baseline):
    bad = copy.deepcopy(baseline)
    # the baseline claims a 4x higher speedup than the current run has
    bad["benchmarks"]["hier"]["derived"]["flat_vs_hier"] *= 4.0
    problems = compare_mod.compare(baseline, bad)
    assert any("flat_vs_hier" in p for p in problems)


def test_ratio_noise_within_tolerance_passes(baseline):
    noisy = copy.deepcopy(baseline)
    # 20% slower than baseline is runner noise, not a regression
    noisy["benchmarks"]["hier"]["derived"]["flat_vs_hier"] *= 0.8
    assert compare_mod.compare(noisy, baseline) == []


def test_trace_overhead_ceiling_fails(baseline):
    bad = copy.deepcopy(baseline)
    # 8% tracing overhead busts the 2% absolute ceiling — max_value
    # gates against the POLICY bound, not the baseline value
    bad["benchmarks"]["end2end"]["derived"]["trace_overhead"] = 1.08
    problems = compare_mod.compare(bad, baseline)
    assert any("trace_overhead" in p and "ceiling" in p
               for p in problems)


def test_trace_overhead_within_ceiling_passes(baseline):
    ok = copy.deepcopy(baseline)
    ok["benchmarks"]["end2end"]["derived"]["trace_overhead"] = 1.015
    assert compare_mod.compare(ok, baseline) == []


def test_missing_bounded_field_fails(baseline):
    bad = copy.deepcopy(baseline)
    del bad["benchmarks"]["end2end"]["derived"]["trace_overhead"]
    problems = compare_mod.compare(bad, baseline)
    assert any("trace_overhead" in p and "missing" in p
               for p in problems)


def test_quality_metric_drift_fails(baseline):
    bad = copy.deepcopy(baseline)
    bad["benchmarks"]["hier"]["derived"]["wh_ratio"] *= 1.5
    problems = compare_mod.compare(bad, baseline)
    assert any("wh_ratio" in p for p in problems)


def test_missing_benchmark_fails(baseline):
    partial = copy.deepcopy(baseline)
    del partial["benchmarks"]["serve"]
    problems = compare_mod.compare(partial, baseline)
    assert any("serve" in p and "missing" in p for p in problems)


def test_failed_current_record_fails(baseline):
    bad = copy.deepcopy(baseline)
    bad["benchmarks"]["serve"]["ok"] = False
    problems = compare_mod.compare(bad, baseline)
    assert any("serve" in p and "failed" in p for p in problems)


def test_mode_mismatch_fails(baseline):
    smoke = copy.deepcopy(baseline)
    smoke["smoke"] = True
    problems = compare_mod.compare(smoke, baseline)
    assert problems and "mode" in problems[0]


def test_make_baseline_strips_timing_fields():
    current = {"benchmarks": [
        {"name": "x", "ok": True, "us_per_call": 123.0,
         "derived": {"speedup": 5.0, "loop_us": 999.0, "t_cold_s": 1.0}},
    ], "full": False, "smoke": False}
    base = compare_mod.make_baseline(current)
    derived = base["benchmarks"]["x"]["derived"]
    assert derived == {"speedup": 5.0}


def test_cli_positive_and_negative(tmp_path, baseline):
    """The CLI exits 0 against the committed baseline and 1 against a
    deliberately perturbed one (the ISSUE-5 negative test)."""
    current = tmp_path / "current.json"
    current.write_text(json.dumps(baseline))
    ok = subprocess.run(
        [sys.executable, _COMPARE, str(current),
         "--baseline", _BASELINE],
        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr

    bad = copy.deepcopy(baseline)
    bad["benchmarks"]["serve"]["derived"]["coalesced_identical"] = 0.0
    bad["benchmarks"]["partition"]["derived"]["best"] *= 10.0
    perturbed = tmp_path / "perturbed-baseline.json"
    perturbed.write_text(json.dumps(bad))
    fail = subprocess.run(
        [sys.executable, _COMPARE, str(current),
         "--baseline", str(perturbed)],
        capture_output=True, text=True)
    assert fail.returncode == 1, fail.stdout + fail.stderr
    assert "coalesced_identical" in fail.stdout
    assert "best" in fail.stdout


def test_write_baseline_cli_roundtrip(tmp_path, baseline):
    current = tmp_path / "current.json"
    current.write_text(json.dumps(baseline))
    out = tmp_path / "new-baseline.json"
    w = subprocess.run(
        [sys.executable, _COMPARE, str(current),
         "--write-baseline", str(out)],
        capture_output=True, text=True)
    assert w.returncode == 0 and out.exists()
    with open(out) as f:
        rebuilt = json.load(f)
    assert compare_mod.compare(baseline, rebuilt) == []
