"""Training substrate tests: optimizer, data, checkpointing (incl.
elastic restore across different meshes), fault-tolerant driver,
gradient compression, straggler monitor."""

import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.models import ModelConfig
from repro.models.config import ShapeConfig
from repro.train import checkpoint as ckpt
from repro.train.data import Prefetcher, SyntheticLM
from repro.train.driver import (JobConfig, StragglerMonitor, train,
                                train_with_restarts)
from repro.train.optimizer import (OptConfig, apply_updates,
                                   init_state, schedule_lr)

TINY = ModelConfig(name="tiny", family="dense", num_layers=2, d_model=32,
                   num_heads=4, num_kv_heads=2, d_ff=64, vocab_size=64,
                   head_dim=8, remat="none", loss_chunk=0, dtype="float32")
SHAPE = ShapeConfig("tiny", "train", 16, 4)


def _mesh():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=100,
                    weight_decay=0.0, schedule="constant")
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_state(cfg, params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_grad_clipping_bounds_update():
    cfg = OptConfig(lr=1.0, clip_norm=1e-3, warmup_steps=0,
                    schedule="constant", weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = init_state(cfg, params)
    huge = {"w": jnp.full(4, 1e9)}
    p2, _, m = apply_updates(cfg, params, huge, state)
    assert float(m["grad_norm"]) > 1e8
    assert float(jnp.abs(p2["w"]).max()) < 2.0  # clipped step


def test_lr_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(schedule_lr(cfg, jnp.int32(1))) < 0.2
    peak = float(schedule_lr(cfg, jnp.int32(10)))
    late = float(schedule_lr(cfg, jnp.int32(100)))
    assert peak > 0.9 and late < peak


def test_compressed_grads_error_feedback_converges():
    cfg = OptConfig(lr=0.05, warmup_steps=0, schedule="constant",
                    weight_decay=0.0, compress_grads=True)
    params = {"w": jnp.array([4.0, -2.0, 1.0])}
    state = init_state(cfg, params)
    assert "err" in state
    for _ in range(300):
        grads = {"w": 2 * params["w"]}
        params, state, _ = apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.3


def test_bf16_moments():
    cfg = OptConfig(moment_dtype="bfloat16")
    state = init_state(cfg, {"w": jnp.zeros(4)})
    assert state["m"]["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# Data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_resumable():
    d = SyntheticLM(64, 16, 4, seed=3)
    a = d.np_batch(7)
    b = d.np_batch(7)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = d.np_batch(8)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # labels are next tokens
    assert a["labels"].shape == (4, 16)


def test_data_has_learnable_structure():
    d = SyntheticLM(64, 128, 8, seed=0, noise=0.1)
    b = d.np_batch(0)
    pred = (d.a * b["tokens"] + d.b) % 64
    agree = (pred == b["labels"]).mean()
    assert agree > 0.8  # bigram structure dominates


def test_prefetcher_orders_batches():
    d = SyntheticLM(64, 8, 2, seed=0)
    pf = Prefetcher(d, start_step=5, depth=2)
    s1, b1 = pf.next()
    s2, b2 = pf.next()
    pf.close()
    assert (s1, s2) == (5, 6)
    assert np.array_equal(b1["tokens"], d.np_batch(5)["tokens"])


# ---------------------------------------------------------------------------
# Checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_prune():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones(4, jnp.bfloat16)}}
        for s in (1, 2, 3, 4, 5):
            ckpt.save(d, s, tree, keep=2)
        assert ckpt.all_steps(d) == [4, 5]
        back = ckpt.restore(d, 5, tree)
        assert np.array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
        assert back["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomic_no_partial():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.zeros(3)}
        ckpt.save(d, 1, tree)
        # simulate a crashed save: a stale .tmp dir must be ignored
        os.makedirs(os.path.join(d, "step_000000002.tmp"))
        assert ckpt.latest_step(d) == 1


ELASTIC_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec
from repro.train import checkpoint as ckpt

d = sys.argv[1]
mode = sys.argv[2]
devs = np.array(jax.devices())
if mode == "save":
    mesh = Mesh(devs.reshape(2, 4), ("data", "model"))
    sh = NamedSharding(mesh, PartitionSpec("data", "model"))
    x = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8), sh)
    ckpt.save(d, 1, {"x": x})
    print("SAVED")
else:
    mesh = Mesh(devs.reshape(4, 2), ("data", "model"))  # DIFFERENT mesh
    sh = NamedSharding(mesh, PartitionSpec("data", "model"))
    like = {"x": jax.ShapeDtypeStruct((8, 8), jnp.float32)}
    out = ckpt.restore(d, 1, like, shardings={"x": sh})
    got = np.asarray(out["x"])
    assert np.array_equal(got, np.arange(64, dtype=np.float32).reshape(8, 8))
    assert len(out["x"].sharding.device_set) == 8
    print("RESTORED-ELASTIC")
"""


def test_elastic_restore_across_meshes():
    """Save on a (2,4) mesh of 8 fake devices; restore on (4,2)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    with tempfile.TemporaryDirectory() as d:
        r1 = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT, d,
                             "save"], env=env, capture_output=True,
                            text=True, timeout=240)
        assert "SAVED" in r1.stdout, r1.stderr[-2000:]
        r2 = subprocess.run([sys.executable, "-c", ELASTIC_SCRIPT, d,
                             "restore"], env=env, capture_output=True,
                            text=True, timeout=240)
        assert "RESTORED-ELASTIC" in r2.stdout, r2.stderr[-2000:]


# ---------------------------------------------------------------------------
# Driver: failure/restart determinism + stragglers
# ---------------------------------------------------------------------------

def test_failure_restart_bit_identical():
    opt = OptConfig(lr=1e-2, warmup_steps=2, total_steps=30,
                    weight_decay=0.0)
    with tempfile.TemporaryDirectory() as d:
        job = JobConfig(steps=20, ckpt_dir=os.path.join(d, "ck"),
                        ckpt_every=5, log_every=0, fail_at_step=12)
        h1 = train_with_restarts(TINY, opt, job, _mesh(), shape=SHAPE,
                                 log=lambda *a: None)
        job2 = JobConfig(steps=20, ckpt_dir="", log_every=0)
        h2 = train(TINY, opt, job2, _mesh(), shape=SHAPE,
                   log=lambda *a: None)
        assert abs(h1["loss"][-1] - h2["loss"][-1]) < 1e-5


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(threshold=2.0)
    for i in range(10):
        m.add(i, 0.1)
    assert m.add(10, 0.5)   # 5x median -> flagged
    assert not m.add(11, 0.12)
    assert len(m.flagged) == 1
