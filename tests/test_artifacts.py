"""Dry-run artifact integrity (deliverable e's acceptance criteria).

Validates the committed artifacts: every (arch x shape) cell exists for
both production meshes, compiled without error, and carries coherent
cost/memory/collective records.  Skips cleanly if artifacts were wiped
(regenerate with `python -m repro.launch.dryrun --all [--multi-pod]`).
"""

import glob
import json
import os

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                   "artifacts", "dryrun")

EXPECTED_SKIPS = {
    ("yi_6b", "long_500k"), ("minitron_4b", "long_500k"),
    ("grok_1_314b", "long_500k"), ("internvl2_26b", "long_500k"),
    ("whisper_small", "long_500k"),
}


def _cells(tag):
    files = glob.glob(os.path.join(ART, tag, "*.json"))
    return {tuple(os.path.basename(f)[:-5].split("__")): json.load(open(f))
            for f in files}


@pytest.mark.parametrize("tag,nchips", [("pod1", 256), ("pod2", 512)])
def test_dryrun_artifacts_complete(tag, nchips):
    cells = _cells(tag)
    if not cells:
        pytest.skip(f"no artifacts for {tag}; run the dry-run first")
    assert len(cells) == 40, f"{tag}: expected 40 cells, got {len(cells)}"
    for (arch, shape), rec in cells.items():
        if (arch, shape) in EXPECTED_SKIPS:
            assert rec["status"] == "skipped", (arch, shape)
            continue
        assert rec["status"] == "ok", (arch, shape, rec.get("error"))
        chips = 1
        for d in rec["mesh_shape"]:
            chips *= d
        assert chips == nchips
        assert rec["cost"]["flops"] > 0
        assert rec["cost"]["bytes_hbm"] > 0
        assert rec["memory"]["argument_size_in_bytes"] > 0
        # params + opt + cache per device must be < v5e HBM
        assert rec["memory"]["argument_size_in_bytes"] < 16 * 2 ** 30


def test_train_cells_have_collectives():
    cells = _cells("pod1")
    if not cells:
        pytest.skip("no artifacts")
    for (arch, shape), rec in cells.items():
        if rec["status"] != "ok" or not shape.startswith("train"):
            continue
        # a sharded train step without collectives would mean the
        # sharding silently degenerated to replication
        assert rec["collectives"]["total_bytes"] > 0, (arch, shape)


def test_multipod_uses_pod_axis():
    cells = _cells("pod2")
    if not cells:
        pytest.skip("no artifacts")
    rec = cells.get(("yi_6b", "train_4k"))
    assert rec and rec["status"] == "ok"
    assert rec["mesh_axes"] == ["pod", "data", "model"]
    assert rec["mesh_shape"] == [2, 16, 16]
