"""Unit + property tests for SFC part orderings (paper Alg. 2, App. A)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.orderings import (gray_decode, gray_encode, grid_order,
                                  hilbert_index, order_points)


def _grid_coords(shape):
    ix = np.indices(shape)
    return np.stack([c.ravel() for c in ix], axis=1).astype(float)


# ---------------------------------------------------------------------------
# Generic Algorithm 2 vs closed-form grid paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sfc", ["Z", "FZ", "Gray", "FZlow"])
@pytest.mark.parametrize("shape", [(8,), (4, 4), (8, 8), (4, 4, 4),
                                   (2, 2, 2, 2)])
def test_grid_matches_generic(sfc, shape):
    if sfc in ("Gray",):  # grid_order falls back to generic for Gray
        pytest.skip("Gray uses the generic path by definition")
    g = grid_order(shape, sfc)
    mu = order_points(_grid_coords(shape), int(np.prod(shape)), sfc)
    assert np.array_equal(g.ravel(), mu)


@pytest.mark.parametrize("sfc", ["Z", "FZ", "Gray", "FZlow", "H"])
@pytest.mark.parametrize("shape", [(16,), (8, 8), (4, 4, 4)])
def test_orderings_are_permutations(sfc, shape):
    n = int(np.prod(shape))
    mu = order_points(_grid_coords(shape), n, sfc)
    assert sorted(mu.tolist()) == list(range(n))


def test_fz_1d_is_gray_code():
    n = 64
    mu = order_points(np.arange(n, dtype=float)[:, None], n, "FZ")
    assert np.array_equal(mu, gray_encode(np.arange(n)))


def test_paper_fz_third_level_pairs():
    """Paper §4.3: FZ 1D, 64 points — third-level cuts separate part pairs
    (4,12), (28,20), (52,60), (44,36)."""
    mu = order_points(np.arange(64, dtype=float)[:, None], 64, "FZ")
    got = [(mu[x], mu[x + 1]) for x in (7, 23, 39, 55)]
    assert got == [(4, 12), (28, 20), (52, 60), (44, 36)]


def test_z_respects_coordinate_order_1d():
    mu = order_points(np.arange(32, dtype=float)[:, None], 32, "Z")
    assert np.array_equal(mu, np.arange(32))


# ---------------------------------------------------------------------------
# Gray code helpers
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**20 - 1))
def test_gray_roundtrip(x):
    g = gray_encode(np.array([x]))
    assert gray_decode(g)[0] == x


@given(st.integers(0, 2**16 - 2))
def test_gray_neighbours_differ_one_bit(x):
    g1, g2 = gray_encode(np.array([x, x + 1]))
    assert bin(int(g1) ^ int(g2)).count("1") == 1


# ---------------------------------------------------------------------------
# Hilbert
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,bits", [(2, 3), (3, 2), (4, 2), (2, 5)])
def test_hilbert_is_bijection(d, bits):
    side = 1 << bits
    pts = _grid_coords((side,) * d).astype(np.int64)
    h = hilbert_index(pts, bits)
    assert sorted(h.tolist()) == list(range(side ** d))


@pytest.mark.parametrize("d,bits", [(2, 3), (3, 2), (2, 4)])
def test_hilbert_consecutive_are_adjacent(d, bits):
    """Hilbert is a continuous curve: consecutive indices are 1 apart."""
    side = 1 << bits
    pts = _grid_coords((side,) * d).astype(np.int64)
    h = hilbert_index(pts, bits)
    order = np.argsort(h)
    seq = pts[order]
    dist = np.abs(np.diff(seq, axis=0)).sum(axis=1)
    assert (dist == 1).all()


# ---------------------------------------------------------------------------
# Weighted / uneven partitioning
# ---------------------------------------------------------------------------

def test_weighted_cut_balances_weight():
    n = 64
    coords = np.arange(n, dtype=float)[:, None]
    w = np.ones(n)
    w[:8] = 8.0  # heavy head: the first cut should move left
    mu = order_points(coords, 2, "Z", weights=w)
    left = np.flatnonzero(mu == 0)
    # total weight 120, half = 60 -> left should hold ~60 of it
    assert abs(w[left].sum() - w.sum() / 2) <= w.max()


def test_weighted_hilbert_split():
    """Weighted Hilbert parts split on the EXCLUSIVE weight prefix:
    equal weights with n == nparts must be a permutation (the old
    inclusive cumsum left part 0 empty and doubled the last part), and
    unequal weights must balance total weight across parts."""
    n = 64
    coords = np.random.default_rng(0).normal(size=(n, 2))
    mu = order_points(coords, n, "H", weights=np.full(n, 16.0))
    assert sorted(mu.tolist()) == list(range(n))
    w = np.ones(n)
    w[:8] = 8.0
    mu2 = order_points(coords, 4, "H", weights=w)
    assert mu2.min() == 0 and mu2.max() == 3
    per_part = np.bincount(mu2, weights=w, minlength=4)
    assert per_part.max() <= w.sum() / 4 + w.max()


def test_uneven_prime_split():
    """Z2_2: nparts=20=2^2*5 -> first split 8/12 (2/5 vs 3/5)."""
    n = 100
    coords = np.arange(n, dtype=float)[:, None]
    mu = order_points(coords, 20, "Z", uneven_prime=True)
    counts = np.bincount(mu, minlength=20)
    assert counts.sum() == n
    assert mu.max() == 19
    assert counts.min() >= n // 20  # all parts populated


@given(
    st.integers(2, 5).flatmap(
        lambda logn: st.tuples(st.just(2 ** logn),
                               st.sampled_from(["Z", "FZ", "Gray", "FZlow"]))),
    st.integers(1, 3),
)
@settings(max_examples=25, deadline=None)
def test_random_coords_valid_partition(np_sfc, d):
    nparts, sfc = np_sfc
    rng = np.random.default_rng(nparts * 7 + d)
    coords = rng.normal(size=(4 * nparts, d))
    mu = order_points(coords, nparts, sfc)
    counts = np.bincount(mu, minlength=nparts)
    assert (counts == 4).all()  # balanced parts
