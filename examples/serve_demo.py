"""Mapping-as-a-service demo: cold / warm / coalesced request serving.

Serves one scenario per allocation family from the scenario registry
through a single MappingService, then repeats the requests (fresh
objects, same content) to show the warm path, and a duplicated batch to
show coalescing.  Prints the cold-vs-warm latency table the README
quotes.

Run:  PYTHONPATH=src python examples/serve_demo.py
(The token-decode serving demo lives in examples/decode_serve_demo.py.)
"""

import time

import numpy as np

from repro.core import evaluate
from repro.serve import MappingService, get_scenario

SCENARIOS = (
    "minighost-xk7_sparse-flat-wh",
    "homme-bgq_block-flat-latency",
    "random-tpu_mesh-flat-wh",
    "minighost-fat_tree-node-wh",
)
SCALE = 4096


def main():
    svc = MappingService(capacity=64)
    rows = []
    for name in SCENARIOS:
        scen = get_scenario(name, scale=SCALE)
        req = scen.request()
        t0 = time.perf_counter()
        cold = svc.map(req)
        t_cold = time.perf_counter() - t0

        # a REPEAT request: freshly built arrays, same content — the
        # service recognises it by signature and serves from the LRU
        req2 = scen.request()
        t0 = time.perf_counter()
        warm = svc.map(req2)
        t_warm = time.perf_counter() - t0
        assert warm.status == "warm"
        assert np.array_equal(cold.result.task_to_proc,
                              warm.result.task_to_proc)
        ev = evaluate(req.graph, req.alloc, cold.result)
        rows.append((name, req.graph.n, t_cold * 1e3, t_warm * 1e3,
                     t_cold / t_warm, ev["weighted_hops"]))

    print(f"{'scenario':44s} {'tasks':>6s} {'cold ms':>8s} "
          f"{'warm ms':>8s} {'speedup':>8s} {'wh':>10s}")
    for name, n, c, w, s, wh in rows:
        print(f"{name:44s} {n:6d} {c:8.1f} {w:8.2f} {s:7.0f}x "
              f"{wh:10.0f}")

    # coalescing: 8 copies of each request in one batch — every
    # duplicate rides the first computation (here: the warm cache)
    batch = [get_scenario(n, scale=SCALE).request()
             for n in SCENARIOS for _ in range(8)]
    t0 = time.perf_counter()
    responses = svc.map_many(batch)
    dt = time.perf_counter() - t0
    coalesced = sum(r.status == "coalesced" for r in responses)
    print(f"\nbatch of {len(batch)} requests served in {dt*1e3:.1f}ms "
          f"({coalesced} coalesced)")
    print(f"service stats: {svc.stats()}")


if __name__ == "__main__":
    main()
