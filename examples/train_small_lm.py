"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Demonstrates the full training substrate — synthetic data pipeline,
AdamW, checkpointing (resumable; kill and re-run to see it resume),
straggler monitoring — on whatever devices are available.

Run:  PYTHONPATH=src python examples/train_small_lm.py [--steps 300]
"""

import argparse

import jax
import numpy as np
from jax.sharding import Mesh

from repro.models import ModelConfig, count_params, params_spec
from repro.models.config import ShapeConfig
from repro.train.driver import JobConfig, train
from repro.train.optimizer import OptConfig


def small_lm() -> ModelConfig:
    # ~100M params: 12L x 512 with a 32k vocab (llama-style GQA)
    return ModelConfig(name="lm-100m", family="dense", num_layers=12,
                       d_model=768, num_heads=12, num_kv_heads=4,
                       d_ff=2048, vocab_size=32000, head_dim=64,
                       remat="none", loss_chunk=0, dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = small_lm()
    print(f"model: {cfg.name}, "
          f"{count_params(params_spec(cfg))/1e6:.0f}M params")
    devs = np.array(jax.devices())
    mesh = Mesh(devs.reshape(len(devs), 1), ("data", "model"))
    shape = ShapeConfig("train", "train", args.seq, args.batch)
    opt = OptConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    job = JobConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                    ckpt_every=100, log_every=10)
    hist = train(cfg, opt, job, mesh, shape=shape)
    print(f"loss: {hist['loss'][0]:.3f} -> {hist['loss'][-1]:.3f} "
          f"over {len(hist['loss'])} steps")
    if hist["stragglers"]:
        print(f"straggler steps flagged: {len(hist['stragglers'])}")


if __name__ == "__main__":
    main()
