"""Tracing demo: one degraded service request, rendered as a span tree.

Walks the README "Observability" section live:

1. serves a request whose jax scorer is rigged to fail, so the
   degradation ladder walks two rungs — the whole walk lands in ONE
   trace (every rung attempted, every pipeline stage, every backend
   call site, the failure annotated where it happened);
2. prints that trace as an indented span tree with durations;
3. prints the process-wide telemetry snapshot's derived rates and the
   Prometheus rendering of the compile-cache families;
4. shows where the exporters hook in (``REPRO_TRACE=path`` JSONL,
   ``obs.write_chrome_trace`` for Perfetto).

Run:  PYTHONPATH=src python examples/tracing_demo.py
"""

import dataclasses

from repro import faults, obs
from repro.serve import MappingService, get_scenario

BASE = "minighost-xk7_sparse-flat-wh"
SCALE = 2048


def _has_jax():
    from repro.core.orderings import resolve_partition_backend
    return resolve_partition_backend("jax") == "jax"


def _request(seed=0, **overrides):
    scen = get_scenario(BASE, scale=SCALE, seed=seed)
    req = scen.request()
    if overrides:
        cfg = dataclasses.replace(scen.config(), **overrides)
        req = dataclasses.replace(req, config=cfg, _signature=None)
    return req


def main():
    jax = _has_jax()
    overrides = dict(score_backend="jax", rotations=4) if jax \
        else dict(rotations=4)

    print("== a degraded request, as one trace ==")
    with faults.isolated():
        svc = MappingService()
        req = _request(**overrides)
        if jax:
            with faults.injected("score.jax", "error", count=1):
                resp = svc.map(req)
            print(f"served on rung {resp.result.stats['degraded']!r} "
                  f"(status={resp.status}, trace={resp.trace_id})\n")
        else:
            resp = svc.map(req)
            print("(jax unavailable: single-rung ladder, healthy path)"
                  f" trace={resp.trace_id}\n")
        print(obs.format_tree(obs.finished(resp.trace_id)))

        print("\n== warm replay: a new trace, no pipeline spans ==")
        warm = svc.map(_request(**overrides))
        print(f"status={warm.status}, trace={warm.trace_id}, computed "
              f"under {warm.result.stats['trace_id']}")
        print(obs.format_tree(obs.finished(warm.trace_id)))

    print("\n== one snapshot: derived rates ==")
    snap = obs.snapshot()
    for key, val in sorted(snap["derived"].items()):
        print(f"  {key:24s} "
              f"{'n/a' if val is None else format(val, '.3f')}")

    print("\n== Prometheus exposition (compile-cache families) ==")
    for line in obs.prometheus_text(snap).splitlines():
        if "compile_cache" in line:
            print(f"  {line}")

    print("\nExporters: set REPRO_TRACE=trace.jsonl for a process-wide"
          "\nJSONL span log, or obs.write_chrome_trace('trace.json')"
          "\nfor a Perfetto-loadable flame view (ui.perfetto.dev).")


if __name__ == "__main__":
    main()
