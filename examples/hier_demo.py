"""Hierarchical (node-level) mapping of a fragmented XK7 allocation.

A MiniGhost-style 3D stencil, one task per core, on a sparse Hilbert-
curve allocation of a Titan-like Gemini torus.  The flat pipeline
partitions one point per CORE; ``hierarchy="node"`` coarsens the tasks
into node-sized clusters and runs the same rotation sweep at ROUTER
granularity — ~cores_per_node x fewer points per engine pass — then
refines the node assignment with monotone greedy swaps and expands
clusters onto cores in intra-node SFC order.

Run:  PYTHONPATH=src python examples/hier_demo.py
"""

import time

from repro.core import (Mapper, MapperConfig, evaluate, gemini_xk7,
                        identity_mapping, sfc_allocation, stencil_graph)


def main():
    # A Titan-like Gemini torus; the job gets 32768 cores (2048 nodes)
    # scattered across 8 fragments of the Hilbert-curve allocator.
    machine = gemini_xk7(dims=(25, 16, 24), cores_per_node=16)
    alloc = sfc_allocation(machine, 32768, nfragments=8, seed=0)
    app = stencil_graph((64, 32, 16))  # 32768 tasks, 7-point stencil

    base = evaluate(app, alloc, identity_mapping(app, alloc))
    results = {}
    for name, hierarchy in (("flat", "flat"), ("node", "node")):
        mapper = Mapper(MapperConfig(sfc="FZ", shift=True, rotations=8,
                                     hierarchy=hierarchy))
        t0 = time.perf_counter()
        res = mapper.map(app, alloc)
        dt = time.perf_counter() - t0
        results[name] = (dt, evaluate(app, alloc, res), res)

    print(f"{'metric':>18s} {'default':>12s} {'flat':>12s} {'node':>12s}")
    for key in ("average_hops", "weighted_hops", "latency_max"):
        print(f"{key:>18s} {base[key]:12.2f} "
              f"{results['flat'][1][key]:12.2f} "
              f"{results['node'][1][key]:12.2f}")
    tf, tn = results["flat"][0], results["node"][0]
    stats = results["node"][2].stats
    print(f"\nflat mapped in {tf:.2f}s, hierarchical in {tn:.2f}s "
          f"({tf / tn:.1f}x) — each engine pass partitioned "
          f"{stats['flat_sweep_points'] // stats['sweep_points']}x fewer "
          f"points ({stats['nclusters']} node clusters instead of "
          f"{app.n} cores); refinement accepted "
          f"{stats['refine_accepted']} swaps "
          f"({stats['refine_initial']:.0f} -> "
          f"{stats['refine_final']:.0f} weighted hops).")


if __name__ == "__main__":
    main()
