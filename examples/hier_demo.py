"""Hierarchical mapping of a fragmented XK7 allocation, depth by depth.

A MiniGhost-style 3D stencil, one task per core, on a sparse Hilbert-
curve allocation of a Titan-like Gemini torus.  The flat pipeline
partitions one point per CORE; ``HierarchySpec.node()`` (depth 2)
coarsens the tasks into node-sized clusters and runs the same rotation
sweep at ROUTER granularity — ~cores_per_node x fewer points per engine
pass — then refines the node assignment with monotone greedy swaps and
expands clusters onto cores in intra-node SFC order.
``HierarchySpec.with_depth(3)`` adds a geometric grouping level above
the nodes: the sweep shrinks by another group-arity factor, the
grouping level runs the sparse-QAP local search, and each group
expansion is repaired by the exact-delta intra-group polish.

Run:  PYTHONPATH=src python examples/hier_demo.py
"""

import time

from repro.core import (Mapper, MapperConfig, evaluate, gemini_xk7,
                        identity_mapping, sfc_allocation, stencil_graph)
from repro.hier import HierarchySpec


def main():
    # A Titan-like Gemini torus; the job gets 32768 cores (2048 nodes)
    # scattered across 8 fragments of the Hilbert-curve allocator.
    machine = gemini_xk7(dims=(25, 16, 24), cores_per_node=16)
    alloc = sfc_allocation(machine, 32768, nfragments=8, seed=0)
    app = stencil_graph((64, 32, 16))  # 32768 tasks, 7-point stencil

    base = evaluate(app, alloc, identity_mapping(app, alloc))
    specs = (("flat", HierarchySpec.flat()),
             ("depth2", HierarchySpec.node()),
             ("depth3", HierarchySpec.with_depth(3)))
    results = {}
    for name, spec in specs:
        mapper = Mapper(MapperConfig(sfc="FZ", shift=True, rotations=8,
                                     hierarchy=spec))
        t0 = time.perf_counter()
        res = mapper.map(app, alloc)
        dt = time.perf_counter() - t0
        results[name] = (dt, evaluate(app, alloc, res), res)

    cols = [name for name, _ in specs]
    print(f"{'metric':>18s} {'default':>12s} "
          + " ".join(f"{c:>12s}" for c in cols))
    for key in ("average_hops", "weighted_hops", "latency_max"):
        print(f"{key:>18s} {base[key]:12.2f} "
              + " ".join(f"{results[c][1][key]:12.2f}" for c in cols))

    tf, t2, t3 = (results[c][0] for c in cols)
    stats2 = results["depth2"][2].stats
    print(f"\nflat mapped in {tf:.2f}s, depth-2 in {t2:.2f}s "
          f"({tf / t2:.1f}x), depth-3 in {t3:.2f}s ({tf / t3:.1f}x) — "
          f"each depth-2 engine pass partitioned "
          f"{stats2['flat_sweep_points'] // stats2['sweep_points']}x "
          f"fewer points ({stats2['nclusters']} node clusters instead "
          f"of {app.n} cores); refinement accepted "
          f"{stats2['refine_accepted']} swaps "
          f"({stats2['refine_initial']:.0f} -> "
          f"{stats2['refine_final']:.0f} weighted hops).")

    # schema-v2 per-level breakdown of the depth-3 run
    print("\ndepth-3 levels (stats['levels']):")
    for lv in results["depth3"][2].stats["levels"]:
        extra = ""
        if "polish_accepted" in lv:
            extra += f", polish_accepted={lv['polish_accepted']}"
        if lv.get("refine_accepted"):
            extra += f", refine_accepted={lv['refine_accepted']}"
        print(f"  level {lv['level']} ({lv['name']:>7s}): "
              f"{lv['points']:>6d} sweep points, "
              f"{lv['clusters']} clusters on {lv['units']} units{extra}")


if __name__ == "__main__":
    main()
