"""The paper's technique as a JAX feature: topology-aware device meshes.

Builds a logical (data, model) mesh over fake devices with the geometric
mapper choosing the device order (candidate search: default order + MJ/FZ
mappings x rotations, scored by the modeled bottleneck-link latency), and
shows the report for a mismatched logical shape where the geometric
mapping beats enumeration order.

Run:  PYTHONPATH=src python examples/topology_mesh_demo.py
(sets 256 fake host devices before importing jax)
"""

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=256")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec  # noqa: E402

from repro.meshmap.device_mesh import topology_mesh  # noqa: E402


def main():
    # a logical shape that does NOT match the 16x16 physical torus:
    # 64-way FSDP x 4-way TP.  Row-major enumeration wraps the heavy TP
    # rings across torus rows; the geometric mapping keeps them compact.
    mesh, report = topology_mesh((64, 4), ("data", "model"),
                                 return_report=True)
    print("mesh:", mesh)
    for k in ("default", "mapped"):
        m = report[k]
        print(f"{k:>8s}: weighted_hops={m['weighted_hops']:.0f} "
              f"latency_max={m['latency_max']:.4f}")
    win = 1 - report["mapped"]["latency_max"] / max(
        report["default"]["latency_max"], 1e-12)
    print(f"modeled bottleneck-link latency reduction: {win:.0%}")

    # the mesh is a first-class jax Mesh: shard a matmul over it
    x = jnp.ones((128, 256))
    w = jnp.ones((256, 512))
    with mesh:
        y = jax.jit(
            lambda a, b: a @ b,
            in_shardings=(NamedSharding(mesh, PartitionSpec("data", None)),
                          NamedSharding(mesh, PartitionSpec(None, "model"))),
        )(x, w)
    print("sharded matmul OK:", y.shape, float(y[0, 0]))


if __name__ == "__main__":
    main()
