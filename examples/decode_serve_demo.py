"""Batched serving demo: train a tiny model briefly, then serve batched
greedy generations through the KV-cache engine (prefill + decode), for
both an attention model and an attention-free Mamba2 (state cache).

Run:  PYTHONPATH=src python examples/decode_serve_demo.py
"""

import jax
import numpy as np
from jax.sharding import Mesh

from repro.models import ModelConfig
from repro.models.config import ShapeConfig
from repro.serve.engine import ServeEngine
from repro.train.driver import JobConfig, train
from repro.train.optimizer import OptConfig


def demo(cfg: ModelConfig):
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    hist = train(cfg, OptConfig(lr=1e-2, warmup_steps=5, total_steps=60,
                                weight_decay=0.0),
                 JobConfig(steps=60, log_every=0), mesh,
                 shape=ShapeConfig("t", "train", 64, 8),
                 log=lambda *a: None)
    params = hist["params"]
    print(f"{cfg.name}: trained to loss {hist['loss'][-1]:.3f}")
    eng = ServeEngine(cfg, params, max_seq=96, batch=4)
    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(4, 16)).astype(np.int32)
    out = eng.generate(prompts, max_new_tokens=12)
    for i in range(len(out)):
        print(f"  request {i}: prompt tail {prompts[i, -4:].tolist()} -> "
              f"generated {out[i].tolist()}")


def main():
    demo(ModelConfig(name="serve-dense", family="dense", num_layers=4,
                     d_model=128, num_heads=8, num_kv_heads=2, d_ff=256,
                     vocab_size=256, head_dim=16, remat="none",
                     loss_chunk=0, dtype="float32"))
    demo(ModelConfig(name="serve-mamba2", family="ssm", num_layers=4,
                     d_model=128, num_heads=0, num_kv_heads=0, d_ff=0,
                     vocab_size=256, head_dim=0, ssm_state=16,
                     ssm_head_dim=32, ssm_chunk=16, remat="none",
                     loss_chunk=0, dtype="float32"))


if __name__ == "__main__":
    main()
